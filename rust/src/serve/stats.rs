//! Serving metrics: per-request latency percentiles, sustained
//! throughput, and per-stage occupancy/backpressure distilled from the
//! DES trace and FIFO accounting.
//!
//! Latency is end-to-end as a user sees it: completion of the request's
//! last output row at the evaluation sink minus its *scheduled* arrival
//! — source-side queueing included. Percentiles use the nearest-rank
//! definition (`ceil(q·n)`-th smallest), so every reported number is an
//! actually-observed latency.

use crate::cycles_to_us;
use crate::eval::latency_model::LatencyComponents;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::FABRIC_CLOCK_HZ;

/// Nearest-rank percentile of a sorted sample: the smallest element with
/// at least `q` of the mass at or below it (q in (0, 1]).
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample");
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Latency distribution summary in cycles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub mean: f64,
    pub max: u64,
}

impl LatencySummary {
    /// The all-zero summary of a run in which nothing completed — what a
    /// fully degraded (lossy-unreliable or fault-hit) serving run reports
    /// instead of erroring out.
    pub fn empty() -> LatencySummary {
        LatencySummary { p50: 0, p95: 0, p99: 0, mean: 0.0, max: 0 }
    }

    pub fn from_unsorted(mut v: Vec<u64>) -> Option<LatencySummary> {
        if v.is_empty() {
            return None;
        }
        v.sort_unstable();
        let mean = v.iter().sum::<u64>() as f64 / v.len() as f64;
        Some(LatencySummary {
            p50: percentile(&v, 0.50),
            p95: percentile(&v, 0.95),
            p99: percentile(&v, 0.99),
            mean,
            max: *v.last().unwrap(),
        })
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("p50_cycles", Json::Num(self.p50 as f64)),
            ("p95_cycles", Json::Num(self.p95 as f64)),
            ("p99_cycles", Json::Num(self.p99 as f64)),
            ("mean_cycles", Json::Num(self.mean)),
            ("max_cycles", Json::Num(self.max as f64)),
            ("p50_us", Json::Num(cycles_to_us(self.p50))),
            ("p95_us", Json::Num(cycles_to_us(self.p95))),
            ("p99_us", Json::Num(cycles_to_us(self.p99))),
        ])
    }
}

/// Activity and backpressure of one encoder stage over the run.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReport {
    pub encoder: usize,
    /// fraction of the makespan during which the stage had work in
    /// flight (first gateway rx to last output tx)
    pub occupancy: f64,
    /// worst input-FIFO high-water mark across the stage's kernels, as a
    /// fraction of that FIFO's capacity (>1 means the §8.2.1 sizing rule
    /// was violated at this load)
    pub fifo_peak: f64,
    /// total FIFO overflow events across the stage's kernels
    pub fifo_overflows: u64,
    /// rows the stage ingested (gateway rx packets)
    pub rows_in: u64,
}

impl StageReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("encoder", Json::Num(self.encoder as f64)),
            ("occupancy", Json::Num(self.occupancy)),
            ("fifo_peak", Json::Num(self.fifo_peak)),
            ("fifo_overflows", Json::Num(self.fifo_overflows as f64)),
            ("rows_in", Json::Num(self.rows_in as f64)),
        ])
    }
}

/// Eq. 1 cross-check: the paper's analytic extrapolation against the
/// fully simulated N-encoder pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Eq1Check {
    pub encoders: usize,
    /// sequence length of the probe inference
    pub m: usize,
    /// single-encoder components the estimate is built from
    pub components: LatencyComponents,
    /// `T + (L-1)X + sum of per-boundary d` in cycles (reduces to Eq. 1's
    /// `T + (L-1)(X + d)` when every boundary has the same hop count)
    pub analytic: u64,
    /// simulated N-encoder last-output latency in cycles
    pub simulated: u64,
}

impl Eq1Check {
    /// Signed relative error of the analytic estimate vs the simulation.
    pub fn rel_err(&self) -> f64 {
        (self.analytic as f64 - self.simulated as f64) / self.simulated as f64
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("encoders", Json::Num(self.encoders as f64)),
            ("m", Json::Num(self.m as f64)),
            ("x_cycles", Json::Num(self.components.x as f64)),
            ("t_cycles", Json::Num(self.components.t as f64)),
            ("analytic_cycles", Json::Num(self.analytic as f64)),
            ("simulated_cycles", Json::Num(self.simulated as f64)),
            ("rel_err", Json::Num(self.rel_err())),
        ])
    }
}

/// The fault section of `serving_report/v2`: what a §6 failure injected
/// mid-serving did to the run, and how the platform recovered.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultReport {
    /// failed FPGA and the cluster that had to be re-configured
    pub fpga: usize,
    pub cluster: u8,
    pub fail_cycle: u64,
    /// the cluster came back (and its input buffer drained) here
    pub recover_cycle: u64,
    /// modeled reconfiguration latency (the outage length)
    pub reconfig_cycles: u64,
    /// kernels the incremental re-place moved off the failed board
    pub moved_kernels: usize,
    /// survivors overcommitted their budgets (serve at reduced headroom
    /// until the board is replaced)
    pub degraded_placement: bool,
    /// false when the run ended before the failure window was reached —
    /// the remaining fields then describe an outage that never happened
    pub recovered: bool,
    /// capacity of the failed cluster's input buffer (its gateway FIFO —
    /// the §6 "one input buffer per cluster")
    pub input_buffer_bytes: usize,
    /// worst observed occupancy of that buffer as a fraction of its
    /// capacity (> 1: the outage backlog overflowed the §8.2.1 sizing)
    pub input_buffer_peak: f64,
    /// packets buffered in the cluster input buffer during the outage
    pub held_packets: u64,
    /// intra-cluster events lost to the reconfiguration
    pub lost_events: u64,
    /// requests that never completed. With a failure injected and zero
    /// loss these are exactly the requests whose rows were in flight
    /// inside the failed cluster; when unreliable loss is ALSO enabled,
    /// loss-stalled requests count here too (the run cannot attribute
    /// them individually)
    pub incomplete_requests: usize,
    /// latency percentiles of completed requests that *arrived during
    /// the outage* — the degraded-mode tail a user saw while the cluster
    /// was down and draining (None: no request arrived in the window)
    pub recovery_window: Option<LatencySummary>,
}

impl FaultReport {
    /// Service-outage duration: failure to cluster-back-up.
    pub fn time_to_recover_cycles(&self) -> u64 {
        self.recover_cycle - self.fail_cycle
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fpga", Json::Num(self.fpga as f64)),
            ("cluster", Json::Num(self.cluster as f64)),
            ("fail_cycle", Json::Num(self.fail_cycle as f64)),
            ("recover_cycle", Json::Num(self.recover_cycle as f64)),
            ("reconfig_cycles", Json::Num(self.reconfig_cycles as f64)),
            ("time_to_recover_cycles", Json::Num(self.time_to_recover_cycles() as f64)),
            ("time_to_recover_us", Json::Num(cycles_to_us(self.time_to_recover_cycles()))),
            ("moved_kernels", Json::Num(self.moved_kernels as f64)),
            ("degraded_placement", Json::Bool(self.degraded_placement)),
            ("recovered", Json::Bool(self.recovered)),
            ("input_buffer_bytes", Json::Num(self.input_buffer_bytes as f64)),
            ("input_buffer_peak", Json::Num(self.input_buffer_peak)),
            ("held_packets", Json::Num(self.held_packets as f64)),
            ("lost_events", Json::Num(self.lost_events as f64)),
            ("incomplete_requests", Json::Num(self.incomplete_requests as f64)),
            (
                "recovery_window",
                self.recovery_window.map(|w| w.to_json()).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// The decode section of `serving_report/v4`: token-generation metrics
/// of an autoregressive serving run (`serve --decode`).
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeReport {
    /// decode passes per request after the prefill
    pub max_new_tokens: u32,
    /// decode tokens that actually completed, across all requests
    pub generated_tokens: u64,
    /// time to first token: prefill-pass completion minus the request's
    /// scheduled arrival, over requests whose prefill completed
    pub ttft: LatencySummary,
    /// inter-token latency: gaps between consecutive pass completions,
    /// pooled across all requests (all-zero at `max_new_tokens = 0` or
    /// when no decode pass completed)
    pub itl: LatencySummary,
    /// per-request KV-cache occupancy at end of generation — cached
    /// positions over the build point's `max_seq` — in request order
    pub kv_occupancy: Vec<f64>,
}

impl DecodeReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_new_tokens", Json::Num(self.max_new_tokens as f64)),
            ("generated_tokens", Json::Num(self.generated_tokens as f64)),
            ("ttft", self.ttft.to_json()),
            ("itl", self.itl.to_json()),
            (
                "kv_occupancy",
                Json::Arr(self.kv_occupancy.iter().map(|&o| Json::Num(o)).collect()),
            ),
        ])
    }
}

/// The batching section of `serving_report/v5`: continuous-batching
/// telemetry of a `serve --batch-max` run (requires decode — iteration
/// batches are made of decode tokens).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchingReport {
    /// KV slots / maximum rows per iteration batch (`--batch-max`)
    pub batch_max: u32,
    /// assembly window in cycles (`--batch-window`)
    pub batch_window: u64,
    /// iteration batches the assembler released
    pub batches: u64,
    /// batch-size histogram: `histogram[i]` = batches of `i + 1` rows
    /// (length `batch_max`)
    pub histogram: Vec<u64>,
    /// assembly wait over released tokens — the latency cost of waiting
    /// for batch-mates (all-zero when no token was ever held back)
    pub assembly_wait: LatencySummary,
    /// peak concurrently admitted sequences (KV slots in use)
    pub peak_active: u32,
    /// TTFT grouped by the size of the batch a request's *first* token
    /// rode in: `(batch size, summary)`, ascending by size
    pub ttft_by_size: Vec<(u32, LatencySummary)>,
    /// ITL grouped by the size of the batch of the gap's later token:
    /// `(batch size, summary)`, ascending by size
    pub itl_by_size: Vec<(u32, LatencySummary)>,
}

impl BatchingReport {
    fn to_json(&self) -> Json {
        let by_size = |v: &[(u32, LatencySummary)]| {
            Json::Arr(
                v.iter()
                    .map(|(size, s)| {
                        Json::obj(vec![
                            ("batch_size", Json::Num(*size as f64)),
                            ("latency", s.to_json()),
                        ])
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            ("batch_max", Json::Num(self.batch_max as f64)),
            ("batch_window_cycles", Json::Num(self.batch_window as f64)),
            ("batches", Json::Num(self.batches as f64)),
            (
                "histogram",
                Json::Arr(self.histogram.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("assembly_wait", self.assembly_wait.to_json()),
            ("peak_active", Json::Num(self.peak_active as f64)),
            ("ttft_by_size", by_size(&self.ttft_by_size)),
            ("itl_by_size", by_size(&self.itl_by_size)),
        ])
    }

    /// Mean released batch size (0 when no batch was released).
    pub fn mean_batch_size(&self) -> f64 {
        let rows: u64 =
            self.histogram.iter().enumerate().map(|(i, &n)| (i as u64 + 1) * n).sum();
        if self.batches == 0 {
            0.0
        } else {
            rows as f64 / self.batches as f64
        }
    }
}

/// One tenant's section of a `serving_report/v6` multi-tenant run.
///
/// Every value here is derived from THIS tenant's requests and sink
/// alone — throughput runs over the tenant's own makespan, not the
/// shared run's. That scoping is load-bearing: it is what lets the
/// failure-isolation contract assert a bystander tenant's section is
/// *byte-identical* whether or not another tenant's FPGA died.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantReport {
    pub name: String,
    /// traffic class name (`guaranteed` / `best-effort`)
    pub class: String,
    /// this tenant's encoder-chain depth
    pub encoders: usize,
    /// requests the tenant's schedule offered (admitted + rejected)
    pub offered: u64,
    /// requests past admission control
    pub admitted: u64,
    /// admission rejects: predicted wait blew the p99 budget
    pub rejected_slo: u64,
    /// admission rejects: every KV slot held by the backlog
    pub rejected_kv: u64,
    /// admitted requests whose full output reached the tenant's sink
    pub completed: u64,
    pub completed_tokens: u64,
    /// the tenant's contracted p99 target (microseconds)
    pub slo_p99_us: f64,
    /// did the measured p99 land within the contract?
    pub slo_met: bool,
    /// first scheduled arrival to last completion, THIS tenant only
    pub makespan_cycles: u64,
    /// end-to-end latency over the tenant's completed requests
    pub latency: LatencySummary,
    /// time to first output row at the tenant's sink (prefill TTFT)
    pub ttft: LatencySummary,
    /// per-request latencies in schedule order (determinism contract)
    pub latencies: Vec<u64>,
}

impl TenantReport {
    /// Sustained completions/s over the tenant's own makespan.
    pub fn seqs_per_s(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 * FABRIC_CLOCK_HZ as f64 / self.makespan_cycles as f64
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed_tokens as f64 * FABRIC_CLOCK_HZ as f64 / self.makespan_cycles as f64
    }

    /// Admission reject fraction of the offered load (0 when idle).
    pub fn reject_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        (self.rejected_slo + self.rejected_kv) as f64 / self.offered as f64
    }

    /// Fraction of the offered load actually delivered end to end.
    pub fn delivered_fraction(&self) -> f64 {
        if self.offered == 0 {
            return 1.0;
        }
        self.completed as f64 / self.offered as f64
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("class", Json::Str(self.class.clone())),
            ("encoders", Json::Num(self.encoders as f64)),
            ("offered", Json::Num(self.offered as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("rejected_slo", Json::Num(self.rejected_slo as f64)),
            ("rejected_kv", Json::Num(self.rejected_kv as f64)),
            ("reject_rate", Json::Num(self.reject_rate())),
            ("completed", Json::Num(self.completed as f64)),
            ("completed_tokens", Json::Num(self.completed_tokens as f64)),
            ("slo_p99_us", Json::Num(self.slo_p99_us)),
            ("slo_met", Json::Bool(self.slo_met)),
            ("makespan_cycles", Json::Num(self.makespan_cycles as f64)),
            ("seqs_per_s", Json::Num(self.seqs_per_s())),
            ("tokens_per_s", Json::Num(self.tokens_per_s())),
            ("latency", self.latency.to_json()),
            ("ttft", self.ttft.to_json()),
            (
                "latencies",
                Json::Arr(self.latencies.iter().map(|&l| Json::Num(l as f64)).collect()),
            ),
        ])
    }
}

/// Cross-tenant fairness / interference section of `serving_report/v6`.
#[derive(Debug, Clone, PartialEq)]
pub struct FairnessReport {
    /// Jain's fairness index over per-tenant delivered fractions
    /// (completed / offered): 1.0 = perfectly even service, 1/n = one
    /// tenant monopolized the fleet.
    pub jain_index: f64,
    /// worst tenant's measured p99 as a multiple of its own SLO budget
    /// (> 1: at least one tenant is out of contract)
    pub max_p99_over_slo: f64,
    /// name of the tenant behind `max_p99_over_slo`
    pub worst_tenant: String,
}

impl FairnessReport {
    /// Distill fairness from the per-tenant sections.
    pub fn from_tenants(tenants: &[TenantReport]) -> FairnessReport {
        let fractions: Vec<f64> = tenants.iter().map(|t| t.delivered_fraction()).collect();
        let sum: f64 = fractions.iter().sum();
        let sum_sq: f64 = fractions.iter().map(|f| f * f).sum();
        let jain_index = if fractions.is_empty() || sum_sq == 0.0 {
            1.0
        } else {
            sum * sum / (fractions.len() as f64 * sum_sq)
        };
        let (mut worst, mut worst_name) = (0.0f64, String::new());
        for t in tenants {
            let budget = t.slo_p99_us * 1e-6 * FABRIC_CLOCK_HZ as f64;
            let ratio = if budget > 0.0 { t.latency.p99 as f64 / budget } else { f64::INFINITY };
            if ratio > worst {
                worst = ratio;
                worst_name = t.name.clone();
            }
        }
        FairnessReport { jain_index, max_p99_over_slo: worst, worst_tenant: worst_name }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jain_index", Json::Num(self.jain_index)),
            ("max_p99_over_slo", Json::Num(self.max_p99_over_slo)),
            ("worst_tenant", Json::Str(self.worst_tenant.clone())),
        ])
    }
}

/// Everything one serving run produced.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub encoders: usize,
    pub workload: String,
    pub process: String,
    pub offered_seqs_per_s: f64,
    pub seed: u64,
    pub requests: usize,
    /// requests whose full output matrix reached the sink
    pub completed: usize,
    /// tokens offered by the schedule (completed or not)
    pub total_tokens: u64,
    /// tokens of the requests that actually completed
    pub completed_tokens: u64,
    /// first scheduled arrival to last completion
    pub makespan_cycles: u64,
    pub latency: LatencySummary,
    /// per-request end-to-end latency in cycles, request order (the
    /// seed-determinism contract covers this vector verbatim)
    pub latencies: Vec<u64>,
    pub stages: Vec<StageReport>,
    pub eq1: Option<Eq1Check>,
    /// wire copies the lossy network ate (0 on a clean run)
    pub dropped: u64,
    /// copies the reliable transport re-sent (== dropped when reliable)
    pub retransmits: u64,
    /// §6 failure outcome (None: no failure was injected)
    pub fault: Option<FaultReport>,
    /// DES events the run took (simulator cost, not model time)
    pub events: u64,
    /// bottleneck-attribution section from the cycle-domain telemetry
    /// (None: telemetry was off — the report then serializes as the
    /// byte-identical v2 schema)
    pub telemetry: Option<Json>,
    /// simulator self-profile (None: `--profile` was off). Wall-clock
    /// numbers — deliberately excluded from the determinism contract.
    pub sim_profile: Option<Json>,
    /// autoregressive-decoding section (None: plain prefill-only
    /// serving — the report then keeps its v2/v3 schema byte-for-byte)
    pub decode: Option<DecodeReport>,
    /// continuous-batching section (None: unbatched serving — the
    /// report then keeps its v2/v3/v4 schema byte-for-byte)
    pub batching: Option<BatchingReport>,
    /// per-tenant sections of a multi-tenant run (None: single-tenant
    /// serving — the report then keeps its v2..v5 schema byte-for-byte)
    pub tenants: Option<Vec<TenantReport>>,
    /// cross-tenant fairness/interference section; present exactly when
    /// `tenants` is
    pub fairness: Option<FairnessReport>,
}

impl ServingReport {
    /// Sustained sequences per second over the makespan (0 when nothing
    /// completed — a fully degraded run has no throughput, not an
    /// absurd one from a zero-cycle makespan).
    pub fn seqs_per_s(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 * FABRIC_CLOCK_HZ as f64 / self.makespan_cycles as f64
    }

    /// Sustained tokens per second over the makespan, counting only the
    /// tokens of completed requests (offered-but-incomplete tokens are
    /// not throughput; 0 when nothing completed).
    pub fn tokens_per_s(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed_tokens as f64 * FABRIC_CLOCK_HZ as f64 / self.makespan_cycles as f64
    }

    /// Mean requests in flight (Little's law: sum of latencies over the
    /// makespan) — the load metric that separates a saturated pipeline
    /// from a lightly loaded one when span-based occupancy cannot.
    pub fn mean_inflight(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.latencies.iter().map(|&l| l as f64).sum::<f64>() / self.makespan_cycles as f64
    }

    /// Schema this report serializes as: exactly `serving_report/v2`
    /// when no telemetry section is attached (the byte-stability
    /// contract of telemetry-off runs), `serving_report/v3` — v2 plus
    /// optional `telemetry` / `sim_profile` sections — otherwise,
    /// `serving_report/v4` — v3 plus the `decode` section — whenever
    /// the run decoded autoregressively, and `serving_report/v5` — v4
    /// plus the `batching` section — when it batched continuously.
    /// A multi-tenant run (per-tenant sections + fairness) is
    /// `serving_report/v6`; multi-tenant serving is prefill-only, so v6
    /// never carries decode/batching sections.
    pub fn schema(&self) -> &'static str {
        if self.tenants.is_some() {
            "serving_report/v6"
        } else if self.batching.is_some() {
            "serving_report/v5"
        } else if self.decode.is_some() {
            "serving_report/v4"
        } else if self.telemetry.is_none() && self.sim_profile.is_none() {
            "serving_report/v2"
        } else {
            "serving_report/v3"
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("schema", Json::Str(self.schema().into())),
            ("encoders", Json::Num(self.encoders as f64)),
            ("workload", Json::Str(self.workload.clone())),
            ("process", Json::Str(self.process.clone())),
            ("offered_seqs_per_s", Json::Num(self.offered_seqs_per_s)),
            ("seed", Json::Num(self.seed as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("total_tokens", Json::Num(self.total_tokens as f64)),
            ("completed_tokens", Json::Num(self.completed_tokens as f64)),
            ("makespan_cycles", Json::Num(self.makespan_cycles as f64)),
            ("seqs_per_s", Json::Num(self.seqs_per_s())),
            ("tokens_per_s", Json::Num(self.tokens_per_s())),
            ("mean_inflight", Json::Num(self.mean_inflight())),
            ("latency", self.latency.to_json()),
            ("stages", Json::Arr(self.stages.iter().map(|s| s.to_json()).collect())),
            ("eq1", self.eq1.map(|e| e.to_json()).unwrap_or(Json::Null)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("retransmits", Json::Num(self.retransmits as f64)),
            ("fault", self.fault.as_ref().map(|f| f.to_json()).unwrap_or(Json::Null)),
            ("events", Json::Num(self.events as f64)),
        ];
        if let Some(d) = &self.decode {
            pairs.push(("decode", d.to_json()));
        }
        if let Some(b) = &self.batching {
            pairs.push(("batching", b.to_json()));
        }
        if let Some(ts) = &self.tenants {
            pairs.push(("tenants", Json::Arr(ts.iter().map(|t| t.to_json()).collect())));
        }
        if let Some(f) = &self.fairness {
            pairs.push(("fairness", f.to_json()));
        }
        if let Some(t) = &self.telemetry {
            pairs.push(("telemetry", t.clone()));
        }
        if let Some(p) = &self.sim_profile {
            pairs.push(("sim_profile", p.clone()));
        }
        Json::obj(pairs)
    }

    /// Human-readable summary (the `serve` CLI's stdout).
    pub fn render(&self) -> String {
        let mut s = format!(
            "served {}/{} requests ({} tokens) through {} encoders \
             [{} arrivals, {} lengths, seed {}]\n",
            self.completed,
            self.requests,
            self.total_tokens,
            self.encoders,
            self.process,
            self.workload,
            self.seed
        );
        s.push_str(&format!(
            "offered {:.0} seqs/s -> sustained {:.0} seqs/s  ({:.0} tokens/s)  \
             over {:.2} ms of fabric time, {:.2} requests in flight on average\n",
            self.offered_seqs_per_s,
            self.seqs_per_s(),
            self.tokens_per_s(),
            cycles_to_us(self.makespan_cycles) / 1e3,
            self.mean_inflight(),
        ));
        s.push_str(&format!(
            "latency  p50 {:.1} us   p95 {:.1} us   p99 {:.1} us   mean {:.1} us   max {:.1} us\n",
            cycles_to_us(self.latency.p50),
            cycles_to_us(self.latency.p95),
            cycles_to_us(self.latency.p99),
            self.latency.mean * 1e6 / FABRIC_CLOCK_HZ as f64,
            cycles_to_us(self.latency.max),
        ));
        let mut t = Table::new(
            "per-stage pipeline view",
            &["encoder", "occupancy", "FIFO peak", "overflows", "rows in"],
        );
        for st in &self.stages {
            t.row(vec![
                st.encoder.to_string(),
                format!("{:.1}%", st.occupancy * 100.0),
                format!("{:.1}%", st.fifo_peak * 100.0),
                st.fifo_overflows.to_string(),
                st.rows_in.to_string(),
            ]);
        }
        s.push_str(&t.render());
        if self.dropped > 0 || self.retransmits > 0 {
            s.push_str(&format!(
                "transport: {} copies dropped, {} retransmitted ({})\n",
                self.dropped,
                self.retransmits,
                if self.retransmits > 0 {
                    "reliable: every packet delivered exactly once"
                } else {
                    "unreliable: losses stall their inferences"
                },
            ));
        }
        if let Some(f) = self.fault.as_ref().filter(|f| !f.recovered) {
            s.push_str(&format!(
                "fault: FPGA {} failure armed for cycle {}, but the run ended first — \
                 no outage occurred\n",
                f.fpga, f.fail_cycle,
            ));
        }
        if let Some(f) = self.fault.as_ref().filter(|f| f.recovered) {
            s.push_str(&format!(
                "fault: FPGA {} (cluster {}) down at cycle {} for {:.2} ms; {} kernels \
                 re-placed{}; {} packets buffered at the cluster input (peak {:.0}% of \
                 its {} B), {} intra-cluster events lost, {} requests incomplete\n",
                f.fpga,
                f.cluster,
                f.fail_cycle,
                cycles_to_us(f.reconfig_cycles) / 1e3,
                f.moved_kernels,
                if f.degraded_placement { " (degraded: survivors overcommitted)" } else { "" },
                f.held_packets,
                100.0 * f.input_buffer_peak,
                f.input_buffer_bytes,
                f.lost_events,
                f.incomplete_requests,
            ));
            if let Some(w) = f.recovery_window {
                s.push_str(&format!(
                    "  outage-window arrivals: p50 {:.1} us  p99 {:.1} us  max {:.1} us\n",
                    cycles_to_us(w.p50),
                    cycles_to_us(w.p99),
                    cycles_to_us(w.max),
                ));
            }
        }
        if let Some(e) = self.eq1 {
            s.push_str(&format!(
                "\nEq. 1 check @ m={}: analytic {} cycles vs simulated {} cycles \
                 ({:+.2}% error over {} encoders)\n",
                e.m,
                e.analytic,
                e.simulated,
                100.0 * e.rel_err(),
                e.encoders
            ));
        }
        if let Some(d) = &self.decode {
            let mean_kv = if d.kv_occupancy.is_empty() {
                0.0
            } else {
                d.kv_occupancy.iter().sum::<f64>() / d.kv_occupancy.len() as f64
            };
            s.push_str(&format!(
                "decode: {} tokens generated (max {} per request)   \
                 TTFT p50 {:.1} us  p99 {:.1} us   ITL p50 {:.1} us  p99 {:.1} us   \
                 KV occupancy {:.0}% mean\n",
                d.generated_tokens,
                d.max_new_tokens,
                cycles_to_us(d.ttft.p50),
                cycles_to_us(d.ttft.p99),
                cycles_to_us(d.itl.p50),
                cycles_to_us(d.itl.p99),
                100.0 * mean_kv,
            ));
        }
        if let Some(b) = &self.batching {
            s.push_str(&format!(
                "batching: {} iteration batches (mean size {:.2}, max {}), \
                 assembly wait p50 {:.1} us  p99 {:.1} us, window {} cycles, \
                 peak {} sequences in flight\n",
                b.batches,
                b.mean_batch_size(),
                b.batch_max,
                cycles_to_us(b.assembly_wait.p50),
                cycles_to_us(b.assembly_wait.p99),
                b.batch_window,
                b.peak_active,
            ));
        }
        if let Some(ts) = &self.tenants {
            let mut t = Table::new(
                "per-tenant view",
                &[
                    "tenant", "class", "offered", "admitted", "rej slo", "rej kv", "done",
                    "p99 (us)", "SLO (us)", "met",
                ],
            );
            for tr in ts {
                t.row(vec![
                    tr.name.clone(),
                    tr.class.clone(),
                    tr.offered.to_string(),
                    tr.admitted.to_string(),
                    tr.rejected_slo.to_string(),
                    tr.rejected_kv.to_string(),
                    tr.completed.to_string(),
                    format!("{:.1}", cycles_to_us(tr.latency.p99)),
                    format!("{:.1}", tr.slo_p99_us),
                    if tr.slo_met { "yes".into() } else { "NO".into() },
                ]);
            }
            s.push_str(&t.render());
        }
        if let Some(f) = &self.fairness {
            s.push_str(&format!(
                "fairness: Jain index {:.3} over delivered fractions; worst tenant {:?} \
                 at {:.2}x its p99 budget\n",
                f.jain_index, f.worst_tenant, f.max_p99_over_slo,
            ));
        }
        if let Some(t) = &self.telemetry {
            let n = t.get("requests_attributed").and_then(|v| v.as_i64()).unwrap_or(0);
            let mean = |k: &str| {
                t.path(&format!("attribution.mean_cycles.{k}"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0)
            };
            s.push_str(&format!(
                "telemetry: {} requests attributed — mean cycles split: queue {:.0}, \
                 compute {:.0}, serialize {:.0}, retransmit {:.0}, outage {:.0}\n",
                n,
                mean("queue"),
                mean("compute"),
                mean("serialize"),
                mean("retransmit"),
                mean("outage"),
            ));
            if let Some(w) = t.path("wakes.total").and_then(|v| v.as_i64()) {
                s.push_str(&format!("  kernel wakes over the run: {w}\n"));
            }
        }
        if let Some(p) = &self.sim_profile {
            s.push_str(&format!(
                "sim profile: {} engine, {:.1} wall-ns/sim-cycle, {} events\n",
                p.get("engine").and_then(|v| v.as_str()).unwrap_or("?"),
                p.get("wall_ns_per_sim_cycle").and_then(|v| v.as_f64()).unwrap_or(0.0),
                p.get("events").and_then(|v| v.as_i64()).unwrap_or(0),
            ));
        }
        s
    }
}

/// Structural check of a serialized serving report: accepts the
/// pre-telemetry `serving_report/v2`, its `serving_report/v3` superset
/// (v3 = v2 plus optional `telemetry` / `sim_profile` sections appended
/// after `events`), the decode-capable `serving_report/v4` (v3 plus a
/// mandatory `decode` section), the continuous-batching
/// `serving_report/v5` (v4 plus a mandatory `batching` section), and
/// the multi-tenant `serving_report/v6` (mandatory `tenants` +
/// `fairness` sections; prefill-only, so decode/batching are forbidden
/// there). The round-trip tests and the CI artifact check both go
/// through here, so all schemas stay parseable side by side.
pub fn validate_serving_report(j: &Json) -> anyhow::Result<()> {
    let schema = j.get("schema").and_then(|s| s.as_str()).unwrap_or("");
    anyhow::ensure!(
        schema == "serving_report/v2"
            || schema == "serving_report/v3"
            || schema == "serving_report/v4"
            || schema == "serving_report/v5"
            || schema == "serving_report/v6",
        "unknown serving report schema {schema:?}"
    );
    for key in [
        "encoders",
        "workload",
        "process",
        "offered_seqs_per_s",
        "seed",
        "requests",
        "completed",
        "total_tokens",
        "completed_tokens",
        "makespan_cycles",
        "seqs_per_s",
        "tokens_per_s",
        "mean_inflight",
        "latency",
        "stages",
        "eq1",
        "dropped",
        "retransmits",
        "fault",
        "events",
    ] {
        anyhow::ensure!(j.get(key).is_some(), "serving report missing key {key:?}");
    }
    anyhow::ensure!(j.path("latency.p50_cycles").is_some(), "latency section malformed");
    if schema == "serving_report/v2" {
        anyhow::ensure!(
            j.get("telemetry").is_none() && j.get("sim_profile").is_none(),
            "v2 reports must not carry telemetry sections"
        );
    }
    if schema == "serving_report/v3" {
        anyhow::ensure!(
            j.get("telemetry").is_some() || j.get("sim_profile").is_some(),
            "v3 reports must carry at least one telemetry section"
        );
    }
    if schema != "serving_report/v2" {
        if let Some(t) = j.get("telemetry") {
            anyhow::ensure!(
                t.path("attribution.totals_cycles").is_some(),
                "telemetry section missing attribution"
            );
        }
    }
    if schema == "serving_report/v4" || schema == "serving_report/v5" {
        let d = j
            .get("decode")
            .ok_or_else(|| anyhow::anyhow!("{schema} reports must carry a decode section"))?;
        for key in ["max_new_tokens", "generated_tokens", "ttft", "itl", "kv_occupancy"] {
            anyhow::ensure!(d.get(key).is_some(), "decode section missing key {key:?}");
        }
        anyhow::ensure!(d.path("ttft.p50_cycles").is_some(), "decode TTFT summary malformed");
        anyhow::ensure!(d.path("itl.p50_cycles").is_some(), "decode ITL summary malformed");
        anyhow::ensure!(
            d.get("kv_occupancy").and_then(Json::as_arr).is_some(),
            "decode kv_occupancy must be an array"
        );
    } else {
        anyhow::ensure!(
            j.get("decode").is_none(),
            "only v4/v5 reports may carry a decode section"
        );
    }
    if schema == "serving_report/v5" {
        let b = j
            .get("batching")
            .ok_or_else(|| anyhow::anyhow!("v5 reports must carry a batching section"))?;
        for key in [
            "batch_max",
            "batch_window_cycles",
            "batches",
            "histogram",
            "assembly_wait",
            "peak_active",
            "ttft_by_size",
            "itl_by_size",
        ] {
            anyhow::ensure!(b.get(key).is_some(), "batching section missing key {key:?}");
        }
        anyhow::ensure!(
            b.path("assembly_wait.p50_cycles").is_some(),
            "batching assembly_wait summary malformed"
        );
        anyhow::ensure!(
            b.get("histogram").and_then(Json::as_arr).is_some(),
            "batching histogram must be an array"
        );
        for key in ["ttft_by_size", "itl_by_size"] {
            let arr = b
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("batching {key} must be an array"))?;
            for entry in arr {
                anyhow::ensure!(
                    entry.get("batch_size").is_some()
                        && entry.path("latency.p50_cycles").is_some(),
                    "batching {key} entry malformed"
                );
            }
        }
    } else {
        anyhow::ensure!(
            j.get("batching").is_none(),
            "only v5 reports may carry a batching section"
        );
    }
    if schema == "serving_report/v6" {
        let ts = j
            .get("tenants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("v6 reports must carry a tenants array"))?;
        anyhow::ensure!(!ts.is_empty(), "v6 tenants array must be non-empty");
        for t in ts {
            for key in [
                "name",
                "class",
                "encoders",
                "offered",
                "admitted",
                "rejected_slo",
                "rejected_kv",
                "reject_rate",
                "completed",
                "completed_tokens",
                "slo_p99_us",
                "slo_met",
                "makespan_cycles",
                "seqs_per_s",
                "tokens_per_s",
                "latency",
                "ttft",
                "latencies",
            ] {
                anyhow::ensure!(t.get(key).is_some(), "tenant section missing key {key:?}");
            }
            anyhow::ensure!(
                t.path("latency.p99_cycles").is_some() && t.path("ttft.p50_cycles").is_some(),
                "tenant latency summaries malformed"
            );
        }
        let f = j
            .get("fairness")
            .ok_or_else(|| anyhow::anyhow!("v6 reports must carry a fairness section"))?;
        for key in ["jain_index", "max_p99_over_slo", "worst_tenant"] {
            anyhow::ensure!(f.get(key).is_some(), "fairness section missing key {key:?}");
        }
        // multi-tenant serving is prefill-only: a v6 report smuggling
        // decode/batching sections is structurally invalid
        anyhow::ensure!(
            j.get("decode").is_none() && j.get("batching").is_none(),
            "v6 reports are prefill-only (no decode/batching sections)"
        );
    } else {
        anyhow::ensure!(
            j.get("tenants").is_none() && j.get("fairness").is_none(),
            "only v6 reports may carry tenants/fairness sections"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.95), 95);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        // small samples: every answer is an observed value
        let w = vec![10u64, 20, 30, 40];
        assert_eq!(percentile(&w, 0.50), 20);
        assert_eq!(percentile(&w, 0.99), 40);
        assert_eq!(percentile(&[7], 0.50), 7);
    }

    #[test]
    fn summary_from_unsorted() {
        let s = LatencySummary::from_unsorted(vec![30, 10, 20]).unwrap();
        assert_eq!(s.p50, 20);
        assert_eq!(s.max, 30);
        assert!((s.mean - 20.0).abs() < 1e-9);
        assert!(LatencySummary::from_unsorted(vec![]).is_none());
    }

    #[test]
    fn eq1_rel_err_signed() {
        let c = LatencyComponents { x: 100, t: 200, i: 5 };
        let e = Eq1Check { encoders: 12, m: 38, components: c, analytic: 105, simulated: 100 };
        assert!((e.rel_err() - 0.05).abs() < 1e-12);
        let e2 = Eq1Check { analytic: 95, ..e };
        assert!((e2.rel_err() + 0.05).abs() < 1e-12);
    }

    #[test]
    fn report_json_shape() {
        let r = ServingReport {
            encoders: 6,
            workload: "glue".into(),
            process: "poisson".into(),
            offered_seqs_per_s: 1000.0,
            seed: 7,
            requests: 2,
            completed: 2,
            total_tokens: 70,
            completed_tokens: 70,
            makespan_cycles: 200_000, // 1 ms at 200 MHz
            latency: LatencySummary { p50: 100, p95: 200, p99: 200, mean: 150.0, max: 200 },
            latencies: vec![100, 200],
            stages: vec![],
            eq1: None,
            dropped: 0,
            retransmits: 0,
            fault: None,
            events: 42,
            telemetry: None,
            sim_profile: None,
            decode: None,
            batching: None,
            tenants: None,
            fairness: None,
        };
        assert!((r.seqs_per_s() - 2000.0).abs() < 1e-9);
        assert!((r.tokens_per_s() - 70_000.0).abs() < 1e-9);
        assert!((r.mean_inflight() - 300.0 / 200_000.0).abs() < 1e-12);
        let j = r.to_json();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "serving_report/v2");
        assert_eq!(j.path("latency.p50_cycles").unwrap().as_i64().unwrap(), 100);
        assert_eq!(j.get("eq1").unwrap(), &Json::Null);
        assert_eq!(j.get("fault").unwrap(), &Json::Null);
        assert!(j.get("telemetry").is_none(), "telemetry-off reports stay exactly v2");
        validate_serving_report(&j).unwrap();
        // render never panics and carries the headline numbers
        assert!(r.render().contains("p95"));
        assert!(!r.render().contains("fault:"), "clean runs carry no fault line");
        assert!(!r.render().contains("telemetry:"), "no telemetry line when off");
    }

    #[test]
    fn telemetry_sections_flip_the_schema_to_v3() {
        let mut r = ServingReport {
            encoders: 1,
            workload: "glue".into(),
            process: "poisson".into(),
            offered_seqs_per_s: 1000.0,
            seed: 7,
            requests: 1,
            completed: 1,
            total_tokens: 5,
            completed_tokens: 5,
            makespan_cycles: 1_000,
            latency: LatencySummary { p50: 10, p95: 10, p99: 10, mean: 10.0, max: 10 },
            latencies: vec![10],
            stages: vec![],
            eq1: None,
            dropped: 0,
            retransmits: 0,
            fault: None,
            events: 9,
            telemetry: None,
            sim_profile: None,
            decode: None,
            batching: None,
            tenants: None,
            fairness: None,
        };
        assert_eq!(r.schema(), "serving_report/v2");
        r.telemetry = Some(Json::obj(vec![
            ("requests_attributed", Json::Num(1.0)),
            (
                "attribution",
                Json::obj(vec![
                    ("totals_cycles", Json::obj(vec![("queue", Json::Num(3.0))])),
                    ("mean_cycles", Json::obj(vec![("queue", Json::Num(3.0))])),
                ]),
            ),
            ("wakes", Json::obj(vec![("total", Json::Num(4.0))])),
        ]));
        assert_eq!(r.schema(), "serving_report/v3");
        let j = r.to_json();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "serving_report/v3");
        assert_eq!(j.path("telemetry.requests_attributed").unwrap().as_i64().unwrap(), 1);
        validate_serving_report(&j).unwrap();
        // round-trip through the serializer preserves the sections
        let back = Json::parse(&j.pretty()).unwrap();
        validate_serving_report(&back).unwrap();
        assert_eq!(
            back.path("telemetry.wakes.total").unwrap().as_i64().unwrap(),
            4,
            "telemetry survives a serialize/parse round trip"
        );
        assert!(r.render().contains("telemetry: 1 requests attributed"));
    }

    #[test]
    fn decode_section_flips_the_schema_to_v4_and_round_trips() {
        let mut r = ServingReport {
            encoders: 1,
            workload: "glue".into(),
            process: "poisson".into(),
            offered_seqs_per_s: 1000.0,
            seed: 7,
            requests: 2,
            completed: 2,
            total_tokens: 10,
            completed_tokens: 10,
            makespan_cycles: 5_000,
            latency: LatencySummary { p50: 10, p95: 10, p99: 10, mean: 10.0, max: 10 },
            latencies: vec![10, 10],
            stages: vec![],
            eq1: None,
            dropped: 0,
            retransmits: 0,
            fault: None,
            events: 9,
            telemetry: None,
            sim_profile: None,
            decode: Some(DecodeReport {
                max_new_tokens: 4,
                generated_tokens: 8,
                ttft: LatencySummary { p50: 100, p95: 120, p99: 120, mean: 105.0, max: 120 },
                itl: LatencySummary { p50: 30, p95: 40, p99: 40, mean: 32.0, max: 40 },
                kv_occupancy: vec![0.5, 0.75],
            }),
            batching: None,
            tenants: None,
            fairness: None,
        };
        assert_eq!(r.schema(), "serving_report/v4");
        let j = r.to_json();
        assert_eq!(j.path("decode.max_new_tokens").unwrap().as_i64().unwrap(), 4);
        validate_serving_report(&j).unwrap();
        // serialize/parse round trip preserves the decode section
        let back = Json::parse(&j.pretty()).unwrap();
        validate_serving_report(&back).unwrap();
        assert_eq!(back.path("decode.ttft.p50_cycles").unwrap().as_i64().unwrap(), 100);
        assert_eq!(back.path("decode.itl.p99_cycles").unwrap().as_i64().unwrap(), 40);
        assert_eq!(back.path("decode.kv_occupancy").unwrap().as_arr().unwrap().len(), 2);
        assert!(r.render().contains("decode: 8 tokens generated"));
        // decode composes with telemetry: still v4, still valid
        r.telemetry = Some(Json::obj(vec![(
            "attribution",
            Json::obj(vec![("totals_cycles", Json::obj(vec![]))]),
        )]));
        assert_eq!(r.schema(), "serving_report/v4");
        validate_serving_report(&r.to_json()).unwrap();
        // a v2/v3 report smuggling a decode section is rejected
        let mut smuggled = back.clone();
        if let Json::Obj(pairs) = &mut smuggled {
            for (k, v) in pairs.iter_mut() {
                if k.as_str() == "schema" {
                    *v = Json::Str("serving_report/v3".into());
                }
            }
        }
        assert!(validate_serving_report(&smuggled).is_err());
    }

    #[test]
    fn batching_section_flips_the_schema_to_v5_and_round_trips() {
        let r = ServingReport {
            encoders: 1,
            workload: "glue".into(),
            process: "poisson".into(),
            offered_seqs_per_s: 4000.0,
            seed: 7,
            requests: 3,
            completed: 3,
            total_tokens: 24,
            completed_tokens: 24,
            makespan_cycles: 9_000,
            latency: LatencySummary { p50: 10, p95: 10, p99: 10, mean: 10.0, max: 10 },
            latencies: vec![10, 10, 10],
            stages: vec![],
            eq1: None,
            dropped: 0,
            retransmits: 0,
            fault: None,
            events: 9,
            telemetry: None,
            sim_profile: None,
            decode: Some(DecodeReport {
                max_new_tokens: 4,
                generated_tokens: 12,
                ttft: LatencySummary { p50: 100, p95: 120, p99: 120, mean: 105.0, max: 120 },
                itl: LatencySummary { p50: 30, p95: 40, p99: 40, mean: 32.0, max: 40 },
                kv_occupancy: vec![0.5, 0.75, 0.5],
            }),
            batching: Some(BatchingReport {
                batch_max: 8,
                batch_window: 256,
                batches: 3,
                histogram: vec![1, 0, 0, 0, 0, 0, 1, 1],
                assembly_wait: LatencySummary {
                    p50: 12,
                    p95: 40,
                    p99: 40,
                    mean: 18.0,
                    max: 40,
                },
                peak_active: 8,
                ttft_by_size: vec![
                    (1, LatencySummary { p50: 90, p95: 90, p99: 90, mean: 90.0, max: 90 }),
                    (8, LatencySummary { p50: 110, p95: 120, p99: 120, mean: 112.0, max: 120 }),
                ],
                itl_by_size: vec![(
                    8,
                    LatencySummary { p50: 30, p95: 40, p99: 40, mean: 32.0, max: 40 },
                )],
            }),
            tenants: None,
            fairness: None,
        };
        assert_eq!(r.schema(), "serving_report/v5");
        // 1 + 7 + 8 rows over 3 batches
        assert!((r.batching.as_ref().unwrap().mean_batch_size() - 16.0 / 3.0).abs() < 1e-12);
        let j = r.to_json();
        assert_eq!(j.path("batching.batch_max").unwrap().as_i64().unwrap(), 8);
        validate_serving_report(&j).unwrap();
        let back = Json::parse(&j.pretty()).unwrap();
        validate_serving_report(&back).unwrap();
        assert_eq!(back.path("batching.batches").unwrap().as_i64().unwrap(), 3);
        assert_eq!(
            back.path("batching.histogram").unwrap().as_arr().unwrap().len(),
            8,
            "histogram spans 1..=batch_max"
        );
        assert_eq!(
            back.path("batching.assembly_wait.p99_cycles").unwrap().as_i64().unwrap(),
            40
        );
        assert!(r.render().contains("batching: 3 iteration batches"));
        // a v4 report smuggling a batching section is rejected, as is a
        // v5 one missing it
        let mut smuggled = back.clone();
        if let Json::Obj(pairs) = &mut smuggled {
            for (k, v) in pairs.iter_mut() {
                if k.as_str() == "schema" {
                    *v = Json::Str("serving_report/v4".into());
                }
            }
        }
        assert!(validate_serving_report(&smuggled).is_err());
        let mut gutted = back.clone();
        if let Json::Obj(pairs) = &mut gutted {
            pairs.retain(|(k, _)| k.as_str() != "batching");
        }
        assert!(validate_serving_report(&gutted).is_err());
    }

    #[test]
    fn v2_fixture_still_validates() {
        // a pre-telemetry serving_report/v2 as PR 5 emitted it (pruned to
        // the schema skeleton): the v3 validator must keep accepting it
        let fixture = r#"{
            "schema": "serving_report/v2",
            "encoders": 2, "workload": "glue", "process": "poisson",
            "offered_seqs_per_s": 2000.0, "seed": 3, "requests": 12,
            "completed": 12, "total_tokens": 420, "completed_tokens": 420,
            "makespan_cycles": 1200000, "seqs_per_s": 2000.0,
            "tokens_per_s": 70000.0, "mean_inflight": 1.5,
            "latency": {"p50_cycles": 100, "p95_cycles": 200, "p99_cycles": 200,
                        "mean_cycles": 150.0, "max_cycles": 200,
                        "p50_us": 0.5, "p95_us": 1.0, "p99_us": 1.0},
            "stages": [], "eq1": null, "dropped": 0, "retransmits": 0,
            "fault": null, "events": 42
        }"#;
        let j = Json::parse(fixture).unwrap();
        validate_serving_report(&j).unwrap();
        // and an unknown schema is rejected
        let bad = Json::obj(vec![("schema", Json::Str("serving_report/v9".into()))]);
        assert!(validate_serving_report(&bad).is_err());
    }

    #[test]
    fn fault_section_shape() {
        let f = FaultReport {
            fpga: 8,
            cluster: 1,
            fail_cycle: 1_000,
            recover_cycle: 51_000,
            reconfig_cycles: 50_000,
            moved_kernels: 7,
            degraded_placement: true,
            recovered: true,
            input_buffer_bytes: 98_304,
            input_buffer_peak: 0.75,
            held_packets: 96,
            lost_events: 12,
            incomplete_requests: 2,
            recovery_window: Some(LatencySummary {
                p50: 60_000,
                p95: 70_000,
                p99: 70_000,
                mean: 61_000.0,
                max: 70_000,
            }),
        };
        assert_eq!(f.time_to_recover_cycles(), 50_000);
        let j = f.to_json();
        assert_eq!(j.get("time_to_recover_cycles").unwrap().as_i64().unwrap(), 50_000);
        assert_eq!(j.get("degraded_placement").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("recovered").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("input_buffer_bytes").unwrap().as_i64().unwrap(), 98_304);
        assert_eq!(j.path("recovery_window.p99_cycles").unwrap().as_i64().unwrap(), 70_000);
        // empty summaries render (degraded runs where nothing completed)
        assert_eq!(LatencySummary::empty().p99, 0);
    }

    fn tenant_report(name: &str, p99: u64, slo_p99_us: f64) -> TenantReport {
        TenantReport {
            name: name.into(),
            class: "guaranteed".into(),
            encoders: 3,
            offered: 10,
            admitted: 9,
            rejected_slo: 1,
            rejected_kv: 0,
            completed: 9,
            completed_tokens: 360,
            slo_p99_us,
            slo_met: p99 as f64 <= slo_p99_us * 1e-6 * FABRIC_CLOCK_HZ as f64,
            makespan_cycles: 400_000,
            latency: LatencySummary { p50: p99 / 2, p95: p99, p99, mean: p99 as f64 / 2.0, max: p99 },
            ttft: LatencySummary { p50: 50, p95: 60, p99: 60, mean: 52.0, max: 60 },
            latencies: vec![p99 / 2; 9],
        }
    }

    #[test]
    fn tenant_sections_flip_the_schema_to_v6_and_round_trip() {
        // 100k cycles = 500 us at 200 MHz: within a 900 us SLO,
        // outside a 400 us one
        let a = tenant_report("chat", 100_000, 900.0);
        let b = tenant_report("batch", 100_000, 400.0);
        assert!(a.slo_met && !b.slo_met);
        assert!((a.seqs_per_s() - 9.0 * FABRIC_CLOCK_HZ as f64 / 400_000.0).abs() < 1e-9);
        assert!((a.reject_rate() - 0.1).abs() < 1e-12);
        let fairness = FairnessReport::from_tenants(&[a.clone(), b.clone()]);
        // equal delivered fractions: perfectly fair
        assert!((fairness.jain_index - 1.0).abs() < 1e-12);
        // the 400 us tenant is the SLO-worst: 500/400 = 1.25
        assert_eq!(fairness.worst_tenant, "batch");
        assert!((fairness.max_p99_over_slo - 1.25).abs() < 1e-12);
        let r = ServingReport {
            encoders: 5,
            workload: "glue+glue".into(),
            process: "poisson+poisson".into(),
            offered_seqs_per_s: 6000.0,
            seed: 7,
            requests: 18,
            completed: 18,
            total_tokens: 720,
            completed_tokens: 720,
            makespan_cycles: 500_000,
            latency: LatencySummary { p50: 50_000, p95: 100_000, p99: 100_000, mean: 60_000.0, max: 100_000 },
            latencies: vec![50_000; 18],
            stages: vec![],
            eq1: None,
            dropped: 0,
            retransmits: 0,
            fault: None,
            events: 99,
            telemetry: None,
            sim_profile: None,
            decode: None,
            batching: None,
            tenants: Some(vec![a, b]),
            fairness: Some(fairness),
        };
        assert_eq!(r.schema(), "serving_report/v6");
        let j = r.to_json();
        validate_serving_report(&j).unwrap();
        let back = Json::parse(&j.pretty()).unwrap();
        validate_serving_report(&back).unwrap();
        let ts = back.get("tenants").unwrap().as_arr().unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].get("name").unwrap().as_str().unwrap(), "chat");
        assert_eq!(ts[1].get("slo_met").unwrap().as_bool().unwrap(), false);
        assert!((back.path("fairness.jain_index").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-12);
        let out = r.render();
        assert!(out.contains("per-tenant view") && out.contains("fairness: Jain index"));
        assert!(out.contains("chat") && out.contains("batch"));
        // a v2 report smuggling tenant sections is rejected ...
        let mut smuggled = back.clone();
        if let Json::Obj(pairs) = &mut smuggled {
            for (k, v) in pairs.iter_mut() {
                if k.as_str() == "schema" {
                    *v = Json::Str("serving_report/v2".into());
                }
            }
        }
        assert!(validate_serving_report(&smuggled).is_err());
        // ... as is a v6 one missing fairness, or carrying decode
        let mut gutted = back.clone();
        if let Json::Obj(pairs) = &mut gutted {
            pairs.retain(|(k, _)| k.as_str() != "fairness");
        }
        assert!(validate_serving_report(&gutted).is_err());
    }

    #[test]
    fn jain_index_detects_monopolization() {
        let mut starved = tenant_report("starved", 1_000, 900.0);
        starved.completed = 0;
        starved.latencies.clear();
        let fed = tenant_report("fed", 1_000, 900.0);
        let f = FairnessReport::from_tenants(&[fed, starved]);
        // fractions (0.9, 0.0): jain = 0.81 / (2 * 0.81) = 0.5
        assert!((f.jain_index - 0.5).abs() < 1e-12);
        // no tenants: the degenerate index is 1.0, not NaN
        assert_eq!(FairnessReport::from_tenants(&[]).jain_index, 1.0);
    }
}
