//! Serving metrics: per-request latency percentiles, sustained
//! throughput, and per-stage occupancy/backpressure distilled from the
//! DES trace and FIFO accounting.
//!
//! Latency is end-to-end as a user sees it: completion of the request's
//! last output row at the evaluation sink minus its *scheduled* arrival
//! — source-side queueing included. Percentiles use the nearest-rank
//! definition (`ceil(q·n)`-th smallest), so every reported number is an
//! actually-observed latency.

use crate::cycles_to_us;
use crate::eval::latency_model::LatencyComponents;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::FABRIC_CLOCK_HZ;

/// Nearest-rank percentile of a sorted sample: the smallest element with
/// at least `q` of the mass at or below it (q in (0, 1]).
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample");
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Latency distribution summary in cycles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub mean: f64,
    pub max: u64,
}

impl LatencySummary {
    pub fn from_unsorted(mut v: Vec<u64>) -> Option<LatencySummary> {
        if v.is_empty() {
            return None;
        }
        v.sort_unstable();
        let mean = v.iter().sum::<u64>() as f64 / v.len() as f64;
        Some(LatencySummary {
            p50: percentile(&v, 0.50),
            p95: percentile(&v, 0.95),
            p99: percentile(&v, 0.99),
            mean,
            max: *v.last().unwrap(),
        })
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("p50_cycles", Json::Num(self.p50 as f64)),
            ("p95_cycles", Json::Num(self.p95 as f64)),
            ("p99_cycles", Json::Num(self.p99 as f64)),
            ("mean_cycles", Json::Num(self.mean)),
            ("max_cycles", Json::Num(self.max as f64)),
            ("p50_us", Json::Num(cycles_to_us(self.p50))),
            ("p95_us", Json::Num(cycles_to_us(self.p95))),
            ("p99_us", Json::Num(cycles_to_us(self.p99))),
        ])
    }
}

/// Activity and backpressure of one encoder stage over the run.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReport {
    pub encoder: usize,
    /// fraction of the makespan during which the stage had work in
    /// flight (first gateway rx to last output tx)
    pub occupancy: f64,
    /// worst input-FIFO high-water mark across the stage's kernels, as a
    /// fraction of that FIFO's capacity (>1 means the §8.2.1 sizing rule
    /// was violated at this load)
    pub fifo_peak: f64,
    /// total FIFO overflow events across the stage's kernels
    pub fifo_overflows: u64,
    /// rows the stage ingested (gateway rx packets)
    pub rows_in: u64,
}

impl StageReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("encoder", Json::Num(self.encoder as f64)),
            ("occupancy", Json::Num(self.occupancy)),
            ("fifo_peak", Json::Num(self.fifo_peak)),
            ("fifo_overflows", Json::Num(self.fifo_overflows as f64)),
            ("rows_in", Json::Num(self.rows_in as f64)),
        ])
    }
}

/// Eq. 1 cross-check: the paper's analytic extrapolation against the
/// fully simulated N-encoder pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Eq1Check {
    pub encoders: usize,
    /// sequence length of the probe inference
    pub m: usize,
    /// single-encoder components the estimate is built from
    pub components: LatencyComponents,
    /// `T + (L-1)X + sum of per-boundary d` in cycles (reduces to Eq. 1's
    /// `T + (L-1)(X + d)` when every boundary has the same hop count)
    pub analytic: u64,
    /// simulated N-encoder last-output latency in cycles
    pub simulated: u64,
}

impl Eq1Check {
    /// Signed relative error of the analytic estimate vs the simulation.
    pub fn rel_err(&self) -> f64 {
        (self.analytic as f64 - self.simulated as f64) / self.simulated as f64
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("encoders", Json::Num(self.encoders as f64)),
            ("m", Json::Num(self.m as f64)),
            ("x_cycles", Json::Num(self.components.x as f64)),
            ("t_cycles", Json::Num(self.components.t as f64)),
            ("analytic_cycles", Json::Num(self.analytic as f64)),
            ("simulated_cycles", Json::Num(self.simulated as f64)),
            ("rel_err", Json::Num(self.rel_err())),
        ])
    }
}

/// Everything one serving run produced.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub encoders: usize,
    pub workload: String,
    pub process: String,
    pub offered_seqs_per_s: f64,
    pub seed: u64,
    pub requests: usize,
    /// requests whose full output matrix reached the sink
    pub completed: usize,
    pub total_tokens: u64,
    /// first scheduled arrival to last completion
    pub makespan_cycles: u64,
    pub latency: LatencySummary,
    /// per-request end-to-end latency in cycles, request order (the
    /// seed-determinism contract covers this vector verbatim)
    pub latencies: Vec<u64>,
    pub stages: Vec<StageReport>,
    pub eq1: Option<Eq1Check>,
    /// DES events the run took (simulator cost, not model time)
    pub events: u64,
}

impl ServingReport {
    /// Sustained sequences per second over the makespan.
    pub fn seqs_per_s(&self) -> f64 {
        self.completed as f64 * FABRIC_CLOCK_HZ as f64 / self.makespan_cycles.max(1) as f64
    }

    /// Sustained tokens per second over the makespan.
    pub fn tokens_per_s(&self) -> f64 {
        self.total_tokens as f64 * FABRIC_CLOCK_HZ as f64 / self.makespan_cycles.max(1) as f64
    }

    /// Mean requests in flight (Little's law: sum of latencies over the
    /// makespan) — the load metric that separates a saturated pipeline
    /// from a lightly loaded one when span-based occupancy cannot.
    pub fn mean_inflight(&self) -> f64 {
        self.latencies.iter().map(|&l| l as f64).sum::<f64>() / self.makespan_cycles.max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str("serving_report/v1".into())),
            ("encoders", Json::Num(self.encoders as f64)),
            ("workload", Json::Str(self.workload.clone())),
            ("process", Json::Str(self.process.clone())),
            ("offered_seqs_per_s", Json::Num(self.offered_seqs_per_s)),
            ("seed", Json::Num(self.seed as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("total_tokens", Json::Num(self.total_tokens as f64)),
            ("makespan_cycles", Json::Num(self.makespan_cycles as f64)),
            ("seqs_per_s", Json::Num(self.seqs_per_s())),
            ("tokens_per_s", Json::Num(self.tokens_per_s())),
            ("mean_inflight", Json::Num(self.mean_inflight())),
            ("latency", self.latency.to_json()),
            ("stages", Json::Arr(self.stages.iter().map(|s| s.to_json()).collect())),
            ("eq1", self.eq1.map(|e| e.to_json()).unwrap_or(Json::Null)),
            ("events", Json::Num(self.events as f64)),
        ])
    }

    /// Human-readable summary (the `serve` CLI's stdout).
    pub fn render(&self) -> String {
        let mut s = format!(
            "served {}/{} requests ({} tokens) through {} encoders \
             [{} arrivals, {} lengths, seed {}]\n",
            self.completed,
            self.requests,
            self.total_tokens,
            self.encoders,
            self.process,
            self.workload,
            self.seed
        );
        s.push_str(&format!(
            "offered {:.0} seqs/s -> sustained {:.0} seqs/s  ({:.0} tokens/s)  \
             over {:.2} ms of fabric time, {:.2} requests in flight on average\n",
            self.offered_seqs_per_s,
            self.seqs_per_s(),
            self.tokens_per_s(),
            cycles_to_us(self.makespan_cycles) / 1e3,
            self.mean_inflight(),
        ));
        s.push_str(&format!(
            "latency  p50 {:.1} us   p95 {:.1} us   p99 {:.1} us   mean {:.1} us   max {:.1} us\n",
            cycles_to_us(self.latency.p50),
            cycles_to_us(self.latency.p95),
            cycles_to_us(self.latency.p99),
            self.latency.mean * 1e6 / FABRIC_CLOCK_HZ as f64,
            cycles_to_us(self.latency.max),
        ));
        let mut t = Table::new(
            "per-stage pipeline view",
            &["encoder", "occupancy", "FIFO peak", "overflows", "rows in"],
        );
        for st in &self.stages {
            t.row(vec![
                st.encoder.to_string(),
                format!("{:.1}%", st.occupancy * 100.0),
                format!("{:.1}%", st.fifo_peak * 100.0),
                st.fifo_overflows.to_string(),
                st.rows_in.to_string(),
            ]);
        }
        s.push_str(&t.render());
        if let Some(e) = self.eq1 {
            s.push_str(&format!(
                "\nEq. 1 check @ m={}: analytic {} cycles vs simulated {} cycles \
                 ({:+.2}% error over {} encoders)\n",
                e.m,
                e.analytic,
                e.simulated,
                100.0 * e.rel_err(),
                e.encoders
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.95), 95);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        // small samples: every answer is an observed value
        let w = vec![10u64, 20, 30, 40];
        assert_eq!(percentile(&w, 0.50), 20);
        assert_eq!(percentile(&w, 0.99), 40);
        assert_eq!(percentile(&[7], 0.50), 7);
    }

    #[test]
    fn summary_from_unsorted() {
        let s = LatencySummary::from_unsorted(vec![30, 10, 20]).unwrap();
        assert_eq!(s.p50, 20);
        assert_eq!(s.max, 30);
        assert!((s.mean - 20.0).abs() < 1e-9);
        assert!(LatencySummary::from_unsorted(vec![]).is_none());
    }

    #[test]
    fn eq1_rel_err_signed() {
        let c = LatencyComponents { x: 100, t: 200, i: 5 };
        let e = Eq1Check { encoders: 12, m: 38, components: c, analytic: 105, simulated: 100 };
        assert!((e.rel_err() - 0.05).abs() < 1e-12);
        let e2 = Eq1Check { analytic: 95, ..e };
        assert!((e2.rel_err() + 0.05).abs() < 1e-12);
    }

    #[test]
    fn report_json_shape() {
        let r = ServingReport {
            encoders: 6,
            workload: "glue".into(),
            process: "poisson".into(),
            offered_seqs_per_s: 1000.0,
            seed: 7,
            requests: 2,
            completed: 2,
            total_tokens: 70,
            makespan_cycles: 200_000, // 1 ms at 200 MHz
            latency: LatencySummary { p50: 100, p95: 200, p99: 200, mean: 150.0, max: 200 },
            latencies: vec![100, 200],
            stages: vec![],
            eq1: None,
            events: 42,
        };
        assert!((r.seqs_per_s() - 2000.0).abs() < 1e-9);
        assert!((r.tokens_per_s() - 70_000.0).abs() < 1e-9);
        assert!((r.mean_inflight() - 300.0 / 200_000.0).abs() < 1e-12);
        let j = r.to_json();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "serving_report/v1");
        assert_eq!(j.path("latency.p50_cycles").unwrap().as_i64().unwrap(), 100);
        assert_eq!(j.get("eq1").unwrap(), &Json::Null);
        // render never panics and carries the headline numbers
        assert!(r.render().contains("p95"));
    }
}
