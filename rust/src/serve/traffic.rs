//! Open-loop traffic models: the serving workload generator.
//!
//! The paper's testbed streams back-to-back inferences of one fixed
//! length; a serving deployment sees *open-loop* traffic — requests
//! arrive on their own schedule whether or not the pipeline kept up, so
//! queueing delay is part of the latency a user observes. This module
//! turns an arrival process (Poisson or uniform) plus a benchmark
//! length distribution ([`GlueWorkload`]: GLUE, MRPC, SQuAD) into a
//! deterministic, seed-reproducible request schedule that the
//! evaluation-FPGA source kernel replays cycle-exactly.

use crate::eval::workload::GlueWorkload;
use crate::util::rng::Rng;
use crate::FABRIC_CLOCK_HZ;

/// One request of an open-loop schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Arrival cycle at the evaluation FPGA's ingress.
    pub arrival: u64,
    /// Actual (unpadded) sequence length in tokens.
    pub m: u32,
}

/// Inter-arrival process of the open-loop source.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival gaps with mean
    /// `1 / seqs_per_s` (the standard open-loop serving model).
    Poisson { seqs_per_s: f64 },
    /// Deterministic arrivals every `1 / seqs_per_s` seconds (isolates
    /// pipeline behavior from arrival burstiness).
    Uniform { seqs_per_s: f64 },
}

impl ArrivalProcess {
    pub fn seqs_per_s(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { seqs_per_s } | ArrivalProcess::Uniform { seqs_per_s } => {
                *seqs_per_s
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Uniform { .. } => "uniform",
        }
    }

    /// Next inter-arrival gap in fabric cycles.
    fn gap_cycles(&self, rng: &mut Rng) -> u64 {
        let mean = FABRIC_CLOCK_HZ as f64 / self.seqs_per_s();
        match self {
            ArrivalProcess::Uniform { .. } => mean.round() as u64,
            ArrivalProcess::Poisson { .. } => {
                // inverse-CDF sample; 1 - U in (0, 1] keeps ln() finite
                let u = 1.0 - rng.next_f64();
                (-u.ln() * mean).round() as u64
            }
        }
    }
}

/// Which benchmark's length distribution drives the workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LengthDist {
    /// GLUE suite, mean length 38 (the paper's §8.2.2 characterization).
    Glue,
    /// MRPC micro-benchmark, mean length 54 (§7.1).
    Mrpc,
    /// SQuAD-like long contexts (mean ~152, max 384); lengths are clamped
    /// to the hardware build point's `max_seq` at schedule generation.
    Squad,
}

impl LengthDist {
    pub fn from_name(s: &str) -> anyhow::Result<LengthDist> {
        match s {
            "glue" => Ok(LengthDist::Glue),
            "mrpc" => Ok(LengthDist::Mrpc),
            "squad" => Ok(LengthDist::Squad),
            _ => anyhow::bail!("unknown workload {s:?} (expected glue|mrpc|squad)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LengthDist::Glue => "glue",
            LengthDist::Mrpc => "mrpc",
            LengthDist::Squad => "squad",
        }
    }

    pub fn sampler(&self, seed: u64) -> GlueWorkload {
        match self {
            LengthDist::Glue => GlueWorkload::glue(seed),
            LengthDist::Mrpc => GlueWorkload::mrpc(seed),
            LengthDist::Squad => GlueWorkload::squad(seed),
        }
    }

    /// Published mean length of the distribution (tokens).
    pub fn mean(&self) -> f64 {
        match self {
            LengthDist::Glue => 38.0,
            LengthDist::Mrpc => 54.0,
            LengthDist::Squad => 152.0,
        }
    }
}

/// Autoregressive decode settings for a serving run.
///
/// A decode request is one prefill pass over the prompt followed by
/// `max_new_tokens` single-row decode passes, each re-entering the
/// pipeline under its own inference id. Inference ids are blocked per
/// request: request `r` owns ids `r * block() .. (r + 1) * block()`,
/// with the prefill at offset 0 and decode step `k` at offset `k`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeConfig {
    /// Number of single-token decode passes after the prefill. Zero is
    /// valid and means "pure prefill through the decode plumbing".
    pub max_new_tokens: u32,
}

impl DecodeConfig {
    /// Inference ids consumed per request (prefill + decode steps).
    pub fn block(&self) -> u32 {
        1 + self.max_new_tokens
    }
}

/// Continuous (iteration-level) batching settings for a serving run.
///
/// With batching enabled the evaluation FPGA's source becomes a batch
/// assembler: at most `max` sequences hold KV slots concurrently, and
/// generated-token rows are grouped into iteration batches — a batch
/// releases when every expected token has arrived, when it reaches
/// `max` rows, or when the oldest ready token has waited `window`
/// cycles (assembly wait is charged to request latency). Finished
/// sequences free their slot at the iteration boundary and queued
/// prefills join mid-stream (Orca-style continuous batching).
///
/// `max <= 1` normalizes to "batching disabled": the run takes the
/// exact legacy decode path and its report stays byte-identical v4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Maximum concurrent sequences (KV slots / rows per iteration).
    pub max: u32,
    /// Assembly window in cycles: the longest a ready token waits for
    /// batch-mates before the batch releases anyway.
    pub window: u64,
}

impl BatchConfig {
    /// Batching below 2 concurrent sequences is the legacy path.
    pub fn enabled(&self) -> bool {
        self.max >= 2
    }
}

/// Full specification of one open-loop traffic trace.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    pub process: ArrivalProcess,
    pub lengths: LengthDist,
    /// number of requests in the trace
    pub requests: usize,
    pub seed: u64,
    /// hardware build point: sampled lengths clamp here (the paper's
    /// testbed is built for 128 tokens)
    pub max_m: usize,
}

impl TrafficConfig {
    /// Generate the schedule: arrivals accumulate the process's gaps
    /// (first request at cycle 0), lengths come from the benchmark
    /// sampler. Deterministic in `seed`. A zero-request trace (tiny
    /// duration x low rate) is a valid, empty schedule — consumers
    /// (`run_serving`, the source kernel) handle it without panicking.
    pub fn generate(&self) -> Vec<Request> {
        if self.requests == 0 {
            return Vec::new();
        }
        let mut lens = self.lengths.sampler(self.seed);
        // independent stream for the arrival gaps so length and timing
        // draws never interleave (schedules stay stable if one sampler
        // changes its draw count)
        let mut gaps = Rng::new(self.seed ^ 0xA11A_57A7_5EED_0001);
        let mut t = 0u64;
        let mut out = Vec::with_capacity(self.requests);
        for _ in 0..self.requests {
            let m = lens.sample().clamp(1, self.max_m) as u32;
            out.push(Request { arrival: t, m });
            t += self.process.gap_cycles(&mut gaps);
        }
        out
    }
}

/// Total token count of a schedule.
pub fn total_tokens(requests: &[Request]) -> u64 {
    requests.iter().map(|r| r.m as u64).sum()
}

/// Derive an independent stream seed for sub-stream `index` of a base
/// seed (tenant traffic, fleet chains). One splitmix64 finalizer round
/// over a Weyl-sequenced input: cheap, stateless, and collision-free in
/// practice — two tenants sharing a base seed still draw unrelated
/// arrival processes, and the derivation never consumes draws from the
/// base stream itself (adding a tenant cannot shift another's schedule).
pub fn stream_seed(seed: u64, index: u64) -> u64 {
    let mut z = seed
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(index.wrapping_mul(0xD134_2543_DE82_EF95));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(process: ArrivalProcess) -> TrafficConfig {
        TrafficConfig {
            process,
            lengths: LengthDist::Glue,
            requests: 2000,
            seed: 11,
            max_m: 128,
        }
    }

    #[test]
    fn poisson_mean_gap_matches_rate() {
        let rate = 5_000.0; // seqs/s => mean gap 40_000 cycles
        let reqs = cfg(ArrivalProcess::Poisson { seqs_per_s: rate }).generate();
        let span = reqs.last().unwrap().arrival as f64;
        let mean_gap = span / (reqs.len() - 1) as f64;
        let want = FABRIC_CLOCK_HZ as f64 / rate;
        assert!(
            (mean_gap - want).abs() / want < 0.08,
            "mean gap {mean_gap} vs expected {want}"
        );
    }

    #[test]
    fn uniform_gaps_are_exact() {
        let reqs = cfg(ArrivalProcess::Uniform { seqs_per_s: 10_000.0 }).generate();
        let gap = FABRIC_CLOCK_HZ / 10_000;
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.arrival, i as u64 * gap);
        }
    }

    #[test]
    fn schedules_are_seed_deterministic() {
        let a = cfg(ArrivalProcess::Poisson { seqs_per_s: 1000.0 }).generate();
        let b = cfg(ArrivalProcess::Poisson { seqs_per_s: 1000.0 }).generate();
        assert_eq!(a, b);
        let mut c2 = cfg(ArrivalProcess::Poisson { seqs_per_s: 1000.0 });
        c2.seed = 12;
        assert_ne!(a, c2.generate());
    }

    #[test]
    fn lengths_clamp_to_the_build_point() {
        let mut c = cfg(ArrivalProcess::Uniform { seqs_per_s: 1000.0 });
        c.lengths = LengthDist::Squad; // mean 152, max 384 > the 128 build
        c.max_m = 128;
        let reqs = c.generate();
        assert!(reqs.iter().all(|r| (1..=128).contains(&r.m)));
        // the clamp must actually bind for a long-context workload
        assert!(reqs.iter().filter(|r| r.m == 128).count() > reqs.len() / 10);
    }

    #[test]
    fn empty_traces_are_graceful() {
        let mut c = cfg(ArrivalProcess::Poisson { seqs_per_s: 0.001 });
        c.requests = 0;
        let reqs = c.generate();
        assert!(reqs.is_empty());
        assert_eq!(total_tokens(&reqs), 0);
        // no `.last().unwrap()`-style assumption anywhere downstream:
        assert_eq!(reqs.last(), None);
    }

    #[test]
    fn batch_of_one_means_batching_disabled() {
        assert!(!BatchConfig { max: 0, window: 64 }.enabled());
        assert!(!BatchConfig { max: 1, window: 64 }.enabled());
        assert!(BatchConfig { max: 2, window: 0 }.enabled());
        assert!(BatchConfig { max: 16, window: 512 }.enabled());
    }

    #[test]
    fn stream_seeds_are_distinct_and_stateless() {
        let a: Vec<u64> = (0..16).map(|i| stream_seed(42, i)).collect();
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), a.len(), "derived seeds must not collide");
        // stateless: same (seed, index) always maps to the same stream
        assert_eq!(stream_seed(42, 3), a[3]);
        // distinct base seeds diverge even at index 0
        assert_ne!(stream_seed(42, 0), stream_seed(43, 0));
        // a derived stream is not the base stream: schedules differ
        let mut base = cfg(ArrivalProcess::Poisson { seqs_per_s: 2_000.0 });
        base.requests = 64;
        let mut derived = base.clone();
        derived.seed = stream_seed(base.seed, 0);
        assert_ne!(base.generate(), derived.generate());
    }

    #[test]
    fn arrivals_are_nondecreasing_and_positive_rate_required() {
        let reqs = cfg(ArrivalProcess::Poisson { seqs_per_s: 777.0 }).generate();
        assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert_eq!(total_tokens(&reqs), reqs.iter().map(|r| r.m as u64).sum::<u64>());
    }
}
