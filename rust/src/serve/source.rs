//! The serving source kernel: replays an open-loop request schedule into
//! the first encoder over the evaluation FPGA's 100G link.
//!
//! Emission is open-loop but the link is a real serial resource: row `r`
//! of request `i` leaves at `max(arrival_i, previous_emission + interval)`
//! — a request that arrives while an earlier one is still streaming
//! queues *at the source*, and that queueing delay is charged to its
//! end-to-end latency (completion − scheduled arrival), exactly like a
//! NIC transmit queue in a real deployment.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::gmi::Out;
use crate::sim::engine::{KernelBehavior, KernelIo, START_TAG};
use crate::sim::packet::{MsgMeta, Packet, Payload};

use super::traffic::Request;

/// Wake tag of the emission pump.
const PUMP: u64 = 1;

/// Stream tag of the decode feedback edge (last encoder -> eval gateway
/// -> source). Distinguishes fed-back token rows from anything else the
/// source might receive.
pub const FEEDBACK_STREAM: u8 = 1;

/// Streams the rows of each scheduled request at `interval` pacing,
/// tagging every row with the request index as its inference id so the
/// per-inference kernel state downstream keeps overlapping requests
/// separate.
pub struct RequestSourceKernel {
    dst: Out,
    /// cycles between consecutive row packets (12 = 100G line rate)
    interval: u64,
    requests: Arc<Vec<Request>>,
    /// golden input rows for functional runs (row `r` of a length-`m`
    /// request sends `data[r]`); None = timing payloads
    data: Option<Arc<Vec<Vec<i8>>>>,
    /// row size for timing payloads (one hidden row)
    row_bytes: usize,
    idx: usize,
    row: u32,
}

impl RequestSourceKernel {
    pub fn new(
        dst: Out,
        requests: Arc<Vec<Request>>,
        interval: u64,
        data: Option<Arc<Vec<Vec<i8>>>>,
        row_bytes: usize,
    ) -> Self {
        RequestSourceKernel { dst, interval, requests, data, row_bytes, idx: 0, row: 0 }
    }
}

impl KernelBehavior for RequestSourceKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag != START_TAG && tag != PUMP {
            return;
        }
        let Some(req) = self.requests.get(self.idx) else {
            return; // schedule drained
        };
        if self.row == 0 && io.now < req.arrival {
            // idle link: sleep until the next request arrives
            io.wake_in(req.arrival - io.now, PUMP);
            return;
        }
        let payload = match &self.data {
            Some(d) => Payload::row_i8(d[self.row as usize].clone()),
            None => Payload::Timing(self.row_bytes),
        };
        let meta = MsgMeta {
            stream: self.dst.stream.unwrap_or(0),
            row: self.row,
            rows: req.m,
            inference: self.idx as u32,
        };
        io.send(self.dst.dst, meta, payload);
        self.row += 1;
        if self.row == req.m {
            self.row = 0;
            self.idx += 1;
        }
        if self.idx < self.requests.len() {
            // the link stays serialized at `interval` even across request
            // boundaries; an early next-arrival waits in the PUMP branch
            io.wake_in(self.interval.max(1), PUMP);
        }
    }

    fn name(&self) -> String {
        "serve-source".to_string()
    }
}

/// Autoregressive serving source: each scheduled request is one prefill
/// pass (inference id `r * block`, `m` rows) followed by up to
/// `block - 1` single-row decode passes. A decode pass is triggered by
/// the feedback edge: the eval gateway broadcasts every pipeline output
/// row back here on [`FEEDBACK_STREAM`], and the *last* row of a pass —
/// the freshly generated token's representation — is re-emitted as the
/// next pass's input (inference id `+1`). The fed-back row stands in for
/// sampling+embedding, which keeps functional runs bit-exact against
/// the `ibert::encoder::decode_generate` reference.
///
/// Emissions share one serialized link: decode tokens and prefill rows
/// interleave at row granularity (queued tokens take priority — they
/// are single rows on the latency-critical path), each `interval`
/// cycles apart, exactly like [`RequestSourceKernel`]'s pacing.
pub struct DecodeSourceKernel {
    dst: Out,
    interval: u64,
    requests: Arc<Vec<Request>>,
    data: Option<Arc<Vec<Vec<i8>>>>,
    row_bytes: usize,
    /// passes per request: 1 prefill + max_new_tokens decode steps
    block: u32,
    idx: usize,
    row: u32,
    /// decode passes ready to emit: (inference id, input row payload)
    queue: VecDeque<(u32, Payload)>,
    /// pacing state: when the pump last emitted / whether it is armed
    last_emit: Option<u64>,
    armed: bool,
}

impl DecodeSourceKernel {
    pub fn new(
        dst: Out,
        requests: Arc<Vec<Request>>,
        interval: u64,
        data: Option<Arc<Vec<Vec<i8>>>>,
        row_bytes: usize,
        block: u32,
    ) -> Self {
        assert!(block >= 1, "decode block must include the prefill pass");
        DecodeSourceKernel {
            dst,
            interval,
            requests,
            data,
            row_bytes,
            block,
            idx: 0,
            row: 0,
            queue: VecDeque::new(),
            last_emit: None,
            armed: false,
        }
    }

    /// True while anything is left to emit (more feedback may still
    /// arm the pump later even when this is false).
    fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.idx < self.requests.len()
    }
}

impl KernelBehavior for DecodeSourceKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        // feedback rows from the eval gateway's broadcast
        let block = self.block;
        let interval = self.interval;
        let row_bytes = self.row_bytes;
        let functional = self.data.is_some();
        let queue = &mut self.queue;
        let armed = &mut self.armed;
        let last_emit = &self.last_emit;
        io.rows(pkt, |io2, meta, at, payload| {
            io2.consume(payload.bytes());
            if meta.stream != FEEDBACK_STREAM || meta.row + 1 != meta.rows {
                return; // only a pass's last row births the next token
            }
            let step = meta.inference % block;
            if step + 1 >= block {
                return; // request fully generated
            }
            let next = match (functional, payload) {
                (true, p @ Payload::RowI8(_)) => p,
                (true, p) => panic!("functional decode feedback carried {:?}", p.bytes()),
                (false, _) => Payload::Timing(row_bytes),
            };
            queue.push_back((meta.inference + 1, next));
            if !*armed {
                *armed = true;
                let due = last_emit.map_or(at, |le| (le + interval).max(at));
                io2.wake_in(due.saturating_sub(at).max(1), PUMP);
            }
        });
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag != START_TAG && tag != PUMP {
            return;
        }
        self.armed = false;
        // overlapping arms (feedback + schedule) may wake us early; the
        // serialized link re-imposes its pacing here
        if let Some(le) = self.last_emit {
            if io.now < le + self.interval {
                self.armed = true;
                io.wake_in(le + self.interval - io.now, PUMP);
                return;
            }
        }
        let stream = self.dst.stream.unwrap_or(0);
        if let Some((inference, payload)) = self.queue.pop_front() {
            let meta = MsgMeta { stream, row: 0, rows: 1, inference };
            io.send(self.dst.dst, meta, payload);
        } else {
            let Some(req) = self.requests.get(self.idx) else {
                return; // drained; feedback re-arms the pump
            };
            if self.row == 0 && io.now < req.arrival {
                // sleep unarmed: a fed-back token may claim the link first
                io.wake_in(req.arrival - io.now, PUMP);
                return;
            }
            let payload = match &self.data {
                Some(d) => Payload::row_i8(d[self.row as usize].clone()),
                None => Payload::Timing(self.row_bytes),
            };
            let meta = MsgMeta {
                stream,
                row: self.row,
                rows: req.m,
                inference: self.idx as u32 * self.block,
            };
            io.send(self.dst.dst, meta, payload);
            self.row += 1;
            if self.row == req.m {
                self.row = 0;
                self.idx += 1;
            }
        }
        self.last_emit = Some(io.now);
        if self.has_work() {
            self.armed = true;
            io.wake_in(self.interval.max(1), PUMP);
        }
    }

    fn name(&self) -> String {
        "serve-decode-source".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::{FpgaId, SwitchId};
    use crate::sim::fifo::Fifo;
    use crate::sim::packet::GlobalKernelId;
    use crate::sim::Sim;

    /// Records (arrival cycle, inference, row, rows) per packet.
    struct Recorder {
        seen: std::sync::Arc<std::sync::Mutex<Vec<(u64, u32, u32, u32)>>>,
    }
    impl KernelBehavior for Recorder {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            let log = self.seen.clone();
            io.rows(pkt, |io2, meta, at, payload| {
                io2.consume(payload.bytes());
                log.lock().unwrap().push((at, meta.inference, meta.row, meta.rows));
            });
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn run(requests: Vec<Request>, interval: u64) -> Vec<(u64, u32, u32, u32)> {
        let src = GlobalKernelId::new(0, 1);
        let dst = GlobalKernelId::new(0, 2);
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(
            src,
            FpgaId(0),
            Fifo::new(1 << 16),
            Box::new(RequestSourceKernel::new(
                Out::to(dst),
                Arc::new(requests),
                interval,
                None,
                768,
            )),
        )
        .unwrap();
        sim.add_kernel(dst, FpgaId(1), Fifo::new(1 << 20), Box::new(Recorder { seen: seen.clone() }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        let v = seen.lock().unwrap().clone();
        v
    }

    #[test]
    fn rows_follow_the_schedule_with_idle_gaps() {
        // request 1 arrives long after request 0 finished streaming
        let reqs =
            vec![Request { arrival: 0, m: 3 }, Request { arrival: 10_000, m: 2 }];
        let got = run(reqs, 12);
        assert_eq!(got.len(), 5);
        // rows of request 0 are spaced by the interval
        assert_eq!(got[1].0 - got[0].0, 12);
        assert_eq!(got[2].0 - got[1].0, 12);
        // request 1's first row leaves at its arrival, not before
        assert!(got[3].0 >= 10_000);
        assert_eq!(got[3].1, 1, "second request carries inference id 1");
        assert_eq!(got[3].3, 2, "rows metadata is the request's own length");
    }

    #[test]
    fn backlogged_arrivals_queue_at_the_source_link() {
        // request 1 arrives while request 0 (100 rows) still streams:
        // its rows must wait for the serialized link
        let reqs = vec![Request { arrival: 0, m: 100 }, Request { arrival: 60, m: 1 }];
        let got = run(reqs, 12);
        assert_eq!(got.len(), 101);
        let first_of_1 = got.iter().find(|e| e.1 == 1).unwrap();
        let last_of_0 = got.iter().filter(|e| e.1 == 0).map(|e| e.0).max().unwrap();
        assert!(
            first_of_1.0 > last_of_0,
            "queued request must start after the backlog drains"
        );
        assert_eq!(first_of_1.0 - last_of_0, 12, "and exactly one interval later");
    }

    #[test]
    fn empty_schedule_is_a_no_op() {
        assert!(run(Vec::new(), 12).is_empty());
    }

    /// Stands in for the whole pipeline + eval gateway: records every row
    /// and feeds each pass's last row back to the source on the
    /// feedback stream, like the gateway's broadcast would.
    struct Echo {
        src: GlobalKernelId,
        seen: std::sync::Arc<std::sync::Mutex<Vec<(u64, u32, u32, u32)>>>,
    }
    impl KernelBehavior for Echo {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            let log = self.seen.clone();
            let src = self.src;
            io.rows(pkt, |io2, meta, at, payload| {
                io2.consume(payload.bytes());
                log.lock().unwrap().push((at, meta.inference, meta.row, meta.rows));
                if meta.row + 1 == meta.rows {
                    let fb = MsgMeta { stream: FEEDBACK_STREAM, ..meta };
                    io2.send(src, fb, Payload::Timing(8));
                }
            });
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn run_decode(requests: Vec<Request>, block: u32) -> Vec<(u64, u32, u32, u32)> {
        let src = GlobalKernelId::new(0, 1);
        let dst = GlobalKernelId::new(0, 2);
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(
            src,
            FpgaId(0),
            Fifo::new(1 << 16),
            Box::new(DecodeSourceKernel::new(
                Out::to(dst),
                Arc::new(requests),
                12,
                None,
                768,
                block,
            )),
        )
        .unwrap();
        sim.add_kernel(
            dst,
            FpgaId(1),
            Fifo::new(1 << 20),
            Box::new(Echo { src, seen: seen.clone() }),
        )
        .unwrap();
        sim.start();
        sim.run().unwrap();
        let v = seen.lock().unwrap().clone();
        v
    }

    #[test]
    fn feedback_rows_trigger_per_token_passes() {
        // two requests, one decode token each: passes 0,1 and 2,3
        let reqs = vec![Request { arrival: 0, m: 3 }, Request { arrival: 0, m: 2 }];
        let got = run_decode(reqs, 2);
        assert_eq!(got.len(), 3 + 1 + 2 + 1);
        let of = |inf: u32| got.iter().filter(|e| e.1 == inf).collect::<Vec<_>>();
        assert_eq!(of(0).len(), 3, "request 0 prefill streams its prompt");
        assert_eq!(of(2).len(), 2, "request 1 prefill carries inference 2");
        for inf in [1, 3] {
            let tok = of(inf);
            assert_eq!(tok.len(), 1, "decode pass {inf} is a single row");
            assert_eq!((tok[0].2, tok[0].3), (0, 1));
        }
        // a token pass only starts after its previous pass finished
        let end0 = of(0).iter().map(|e| e.0).max().unwrap();
        assert!(of(1)[0].0 > end0);
    }

    #[test]
    fn block_one_means_pure_prefill() {
        let got = run_decode(vec![Request { arrival: 0, m: 4 }], 1);
        assert_eq!(got.len(), 4);
        assert!(got.iter().all(|e| e.1 == 0), "no decode passes at max_new_tokens = 0");
    }
}
