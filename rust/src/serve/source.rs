//! The serving source kernel: replays an open-loop request schedule into
//! the first encoder over the evaluation FPGA's 100G link.
//!
//! Emission is open-loop but the link is a real serial resource: row `r`
//! of request `i` leaves at `max(arrival_i, previous_emission + interval)`
//! — a request that arrives while an earlier one is still streaming
//! queues *at the source*, and that queueing delay is charged to its
//! end-to-end latency (completion − scheduled arrival), exactly like a
//! NIC transmit queue in a real deployment.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::gmi::Out;
use crate::sim::engine::{KernelBehavior, KernelIo, START_TAG};
use crate::sim::packet::{MsgMeta, Packet, Payload};

use super::traffic::{BatchConfig, Request};

/// Wake tag of the emission pump.
const PUMP: u64 = 1;

/// Wake tag of the batch-assembly window deadline.
const WINDOW: u64 = 2;

/// Stream tag of the decode feedback edge (last encoder -> eval gateway
/// -> source). Distinguishes fed-back token rows from anything else the
/// source might receive.
pub const FEEDBACK_STREAM: u8 = 1;

/// Streams the rows of each scheduled request at `interval` pacing,
/// tagging every row with the request index as its inference id so the
/// per-inference kernel state downstream keeps overlapping requests
/// separate.
pub struct RequestSourceKernel {
    dst: Out,
    /// cycles between consecutive row packets (12 = 100G line rate)
    interval: u64,
    requests: Arc<Vec<Request>>,
    /// golden input rows for functional runs (row `r` of a length-`m`
    /// request sends `data[r]`); None = timing payloads
    data: Option<Arc<Vec<Vec<i8>>>>,
    /// row size for timing payloads (one hidden row)
    row_bytes: usize,
    idx: usize,
    row: u32,
    /// tenant name in multi-tenant serving (shows up in trace output so
    /// per-tenant sources are tellable apart); None = the classic name
    label: Option<String>,
}

impl RequestSourceKernel {
    pub fn new(
        dst: Out,
        requests: Arc<Vec<Request>>,
        interval: u64,
        data: Option<Arc<Vec<Vec<i8>>>>,
        row_bytes: usize,
    ) -> Self {
        RequestSourceKernel { dst, interval, requests, data, row_bytes, idx: 0, row: 0, label: None }
    }

    /// Tag this source with a tenant name (multi-tenant serving).
    pub fn with_label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }
}

impl KernelBehavior for RequestSourceKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag != START_TAG && tag != PUMP {
            return;
        }
        let Some(req) = self.requests.get(self.idx) else {
            return; // schedule drained
        };
        if self.row == 0 && io.now < req.arrival {
            // idle link: sleep until the next request arrives
            io.wake_in(req.arrival - io.now, PUMP);
            return;
        }
        let payload = match &self.data {
            Some(d) => Payload::row_i8(d[self.row as usize].clone()),
            None => Payload::Timing(self.row_bytes),
        };
        let meta = MsgMeta {
            stream: self.dst.stream.unwrap_or(0),
            row: self.row,
            rows: req.m,
            inference: self.idx as u32,
        };
        io.send(self.dst.dst, meta, payload);
        self.row += 1;
        if self.row == req.m {
            self.row = 0;
            self.idx += 1;
        }
        if self.idx < self.requests.len() {
            // the link stays serialized at `interval` even across request
            // boundaries; an early next-arrival waits in the PUMP branch
            io.wake_in(self.interval.max(1), PUMP);
        }
    }

    fn name(&self) -> String {
        match &self.label {
            Some(l) => format!("serve-source/{l}"),
            None => "serve-source".to_string(),
        }
    }
}

/// Autoregressive serving source: each scheduled request is one prefill
/// pass (inference id `r * block`, `m` rows) followed by up to
/// `block - 1` single-row decode passes. A decode pass is triggered by
/// the feedback edge: the eval gateway broadcasts every pipeline output
/// row back here on [`FEEDBACK_STREAM`], and the *last* row of a pass —
/// the freshly generated token's representation — is re-emitted as the
/// next pass's input (inference id `+1`). The fed-back row stands in for
/// sampling+embedding, which keeps functional runs bit-exact against
/// the `ibert::encoder::decode_generate` reference.
///
/// Emissions share one serialized link: decode tokens and prefill rows
/// interleave at row granularity (queued tokens take priority — they
/// are single rows on the latency-critical path), each `interval`
/// cycles apart, exactly like [`RequestSourceKernel`]'s pacing.
pub struct DecodeSourceKernel {
    dst: Out,
    interval: u64,
    requests: Arc<Vec<Request>>,
    data: Option<Arc<Vec<Vec<i8>>>>,
    row_bytes: usize,
    /// passes per request: 1 prefill + max_new_tokens decode steps
    block: u32,
    idx: usize,
    row: u32,
    /// decode passes ready to emit: (inference id, input row payload)
    queue: VecDeque<(u32, Payload)>,
    /// pacing state: when the pump last emitted / whether it is armed
    last_emit: Option<u64>,
    armed: bool,
}

impl DecodeSourceKernel {
    pub fn new(
        dst: Out,
        requests: Arc<Vec<Request>>,
        interval: u64,
        data: Option<Arc<Vec<Vec<i8>>>>,
        row_bytes: usize,
        block: u32,
    ) -> Self {
        assert!(block >= 1, "decode block must include the prefill pass");
        DecodeSourceKernel {
            dst,
            interval,
            requests,
            data,
            row_bytes,
            block,
            idx: 0,
            row: 0,
            queue: VecDeque::new(),
            last_emit: None,
            armed: false,
        }
    }

    /// True while anything is left to emit (more feedback may still
    /// arm the pump later even when this is false).
    fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.idx < self.requests.len()
    }
}

impl KernelBehavior for DecodeSourceKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        // feedback rows from the eval gateway's broadcast
        let block = self.block;
        let interval = self.interval;
        let row_bytes = self.row_bytes;
        let functional = self.data.is_some();
        let queue = &mut self.queue;
        let armed = &mut self.armed;
        let last_emit = &self.last_emit;
        io.rows(pkt, |io2, meta, at, payload| {
            io2.consume(payload.bytes());
            if meta.stream != FEEDBACK_STREAM || meta.row + 1 != meta.rows {
                return; // only a pass's last row births the next token
            }
            let step = meta.inference % block;
            if step + 1 >= block {
                return; // request fully generated
            }
            let next = match (functional, payload) {
                (true, p @ Payload::RowI8(_)) => p,
                (true, p) => panic!("functional decode feedback carried {:?}", p.bytes()),
                (false, _) => Payload::Timing(row_bytes),
            };
            queue.push_back((meta.inference + 1, next));
            if !*armed {
                *armed = true;
                let due = last_emit.map_or(at, |le| (le + interval).max(at));
                io2.wake_in(due.saturating_sub(at).max(1), PUMP);
            }
        });
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag != START_TAG && tag != PUMP {
            return;
        }
        self.armed = false;
        // overlapping arms (feedback + schedule) may wake us early; the
        // serialized link re-imposes its pacing here
        if let Some(le) = self.last_emit {
            if io.now < le + self.interval {
                self.armed = true;
                io.wake_in(le + self.interval - io.now, PUMP);
                return;
            }
        }
        let stream = self.dst.stream.unwrap_or(0);
        if let Some((inference, payload)) = self.queue.pop_front() {
            let meta = MsgMeta { stream, row: 0, rows: 1, inference };
            io.send(self.dst.dst, meta, payload);
        } else {
            let Some(req) = self.requests.get(self.idx) else {
                return; // drained; feedback re-arms the pump
            };
            if self.row == 0 && io.now < req.arrival {
                // sleep unarmed: a fed-back token may claim the link first
                io.wake_in(req.arrival - io.now, PUMP);
                return;
            }
            let payload = match &self.data {
                Some(d) => Payload::row_i8(d[self.row as usize].clone()),
                None => Payload::Timing(self.row_bytes),
            };
            let meta = MsgMeta {
                stream,
                row: self.row,
                rows: req.m,
                inference: self.idx as u32 * self.block,
            };
            io.send(self.dst.dst, meta, payload);
            self.row += 1;
            if self.row == req.m {
                self.row = 0;
                self.idx += 1;
            }
        }
        self.last_emit = Some(io.now);
        if self.has_work() {
            self.armed = true;
            io.wake_in(self.interval.max(1), PUMP);
        }
    }

    fn name(&self) -> String {
        "serve-decode-source".to_string()
    }
}

/// Batching telemetry recorded by [`BatchSourceKernel`] for the serving
/// report's v5 section. Written only by the single source kernel during
/// the run and read after the simulation drains, so the mutex is
/// uncontended and the contents are deterministic regardless of the
/// engine's thread count.
#[derive(Debug, Default, Clone)]
pub struct BatchLog {
    /// every iteration-batch release: (release cycle, token rows in it)
    pub releases: Vec<(u64, u32)>,
    /// per released token row: cycles it waited in assembly
    pub waits: Vec<u64>,
    /// token pass inference id -> size of the batch it released in
    pub token_batch: HashMap<u32, u32>,
    /// peak concurrently admitted sequences (KV slots in use)
    pub peak_active: u32,
}

/// Drain the assembly buffer into the release queue as one iteration
/// batch, charging each token's assembly wait and recording the release.
/// Tokens whose pass will feed back yet another token count as
/// outstanding from release (not emission) so the "no batch-mate can
/// still join" test never fires during the short release-queue drain.
fn drain_ready(
    ready: &mut VecDeque<(u32, Payload, u64)>,
    release_q: &mut VecDeque<(u32, Payload)>,
    open_since: &mut Option<u64>,
    outstanding: &mut u32,
    block: u32,
    log: &Mutex<BatchLog>,
    now: u64,
) {
    let size = ready.len() as u32;
    debug_assert!(size > 0, "released an empty batch");
    let mut log = log.lock().unwrap();
    log.releases.push((now, size));
    while let Some((inference, payload, ready_at)) = ready.pop_front() {
        log.waits.push(now - ready_at);
        log.token_batch.insert(inference, size);
        if inference % block + 1 < block {
            *outstanding += 1;
        }
        release_q.push_back((inference, payload));
    }
    *open_since = None;
}

/// Continuous-batching serving source: the Orca-style iteration-level
/// scheduler. Extends [`DecodeSourceKernel`] three ways:
///
/// - **Admission**: at most `batch.max` sequences hold KV slots at
///   once. A scheduled prefill whose arrival has passed still waits at
///   the source until a slot frees (a finished sequence exits at its
///   iteration boundary), and that wait is charged to its latency.
/// - **Iteration batches**: fed-back token rows are not re-emitted
///   immediately — they collect in an assembly buffer that releases as
///   one back-to-back burst when no in-flight pass can add another
///   token, when the buffer holds `batch.max` rows, or when the oldest
///   token has waited `batch.window` cycles. Released rows chain down
///   the link at `interval` pacing, so the weight-stationary linear
///   kernels see an unbroken streak and charge the batched marginal
///   row cost instead of a full weight pass per token.
/// - **Telemetry**: every release, per-token assembly wait, and the
///   batch size each token rode in land in a shared [`BatchLog`].
///
/// Passes stay *in flight across iterations* — the assembler never
/// waits for the pipeline to drain (that would serialize iterations
/// and forfeit the batching win); it only groups tokens that are ready
/// now while other passes keep streaming. Prefills joining mid-stream
/// will contribute tokens a full pipeline latency later, so they do
/// not hold an open batch past its window.
pub struct BatchSourceKernel {
    dst: Out,
    interval: u64,
    requests: Arc<Vec<Request>>,
    data: Option<Arc<Vec<Vec<i8>>>>,
    row_bytes: usize,
    /// passes per request: 1 prefill + max_new_tokens decode steps
    block: u32,
    /// slot cap + assembly window
    batch: BatchConfig,
    idx: usize,
    row: u32,
    /// sequences currently holding a KV slot
    active: u32,
    /// in-flight passes whose feedback will yield another token
    outstanding: u32,
    /// assembly buffer: (inference id, payload, cycle it became ready)
    ready: VecDeque<(u32, Payload, u64)>,
    /// cycle the current assembly batch opened (first ready token)
    open_since: Option<u64>,
    /// deadline the WINDOW wake is armed for, if any
    window_armed: Option<u64>,
    /// released token rows awaiting the serialized link
    release_q: VecDeque<(u32, Payload)>,
    last_emit: Option<u64>,
    armed: bool,
    log: Arc<Mutex<BatchLog>>,
}

impl BatchSourceKernel {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        dst: Out,
        requests: Arc<Vec<Request>>,
        interval: u64,
        data: Option<Arc<Vec<Vec<i8>>>>,
        row_bytes: usize,
        block: u32,
        batch: BatchConfig,
        log: Arc<Mutex<BatchLog>>,
    ) -> Self {
        assert!(block >= 1, "decode block must include the prefill pass");
        assert!(batch.enabled(), "batch max < 2 is the legacy DecodeSourceKernel path");
        BatchSourceKernel {
            dst,
            interval,
            requests,
            data,
            row_bytes,
            block,
            batch,
            idx: 0,
            row: 0,
            active: 0,
            outstanding: 0,
            ready: VecDeque::new(),
            open_since: None,
            window_armed: None,
            release_q: VecDeque::new(),
            last_emit: None,
            armed: false,
            log,
        }
    }

    fn has_work(&self) -> bool {
        !self.release_q.is_empty() || self.idx < self.requests.len()
    }
}

impl KernelBehavior for BatchSourceKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        let block = self.block;
        let interval = self.interval;
        let row_bytes = self.row_bytes;
        let functional = self.data.is_some();
        let max = self.batch.max;
        let window = self.batch.window;
        let ready = &mut self.ready;
        let release_q = &mut self.release_q;
        let open_since = &mut self.open_since;
        let window_armed = &mut self.window_armed;
        let outstanding = &mut self.outstanding;
        let active = &mut self.active;
        let armed = &mut self.armed;
        let last_emit = &self.last_emit;
        let log = &self.log;
        io.rows(pkt, |io2, meta, at, payload| {
            io2.consume(payload.bytes());
            if meta.stream != FEEDBACK_STREAM || meta.row + 1 != meta.rows {
                return; // only a pass's last row births the next token
            }
            let step = meta.inference % block;
            if step + 1 >= block {
                // final pass: the sequence exits at this iteration
                // boundary and its KV slot frees for a queued prefill
                *active = active.saturating_sub(1);
                if !*armed {
                    *armed = true;
                    let due = last_emit.map_or(at, |le| (le + interval).max(at));
                    io2.wake_in(due.saturating_sub(at).max(1), PUMP);
                }
                return;
            }
            *outstanding = outstanding.saturating_sub(1);
            let next = match (functional, payload) {
                (true, p @ Payload::RowI8(_)) => p,
                (true, p) => panic!("functional batched feedback carried {:?}", p.bytes()),
                (false, _) => Payload::Timing(row_bytes),
            };
            ready.push_back((meta.inference + 1, next, at));
            if open_since.is_none() {
                *open_since = Some(at);
            }
            let deadline = open_since.unwrap() + window;
            if ready.len() >= max as usize || *outstanding == 0 || at >= deadline {
                // full batch / no batch-mate can still join / window lapsed
                drain_ready(ready, release_q, open_since, outstanding, block, log, at);
                if !*armed {
                    *armed = true;
                    let due = last_emit.map_or(at, |le| (le + interval).max(at));
                    io2.wake_in(due.saturating_sub(at).max(1), PUMP);
                }
            } else if *window_armed != Some(deadline) {
                *window_armed = Some(deadline);
                io2.wake_in(deadline.saturating_sub(at).max(1), WINDOW);
            }
        });
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == WINDOW {
            self.window_armed = None;
            if let Some(opened) = self.open_since {
                let deadline = opened + self.batch.window;
                if io.now >= deadline {
                    drain_ready(
                        &mut self.ready,
                        &mut self.release_q,
                        &mut self.open_since,
                        &mut self.outstanding,
                        self.block,
                        &self.log,
                        io.now,
                    );
                    if !self.armed {
                        self.armed = true;
                        let due = self
                            .last_emit
                            .map_or(io.now, |le| (le + self.interval).max(io.now));
                        io.wake_in(due.saturating_sub(io.now).max(1), PUMP);
                    }
                } else {
                    // a newer batch opened after this wake was armed
                    self.window_armed = Some(deadline);
                    io.wake_in(deadline - io.now, WINDOW);
                }
            }
            return;
        }
        if tag != START_TAG && tag != PUMP {
            return;
        }
        self.armed = false;
        // overlapping arms (feedback + schedule) may wake us early; the
        // serialized link re-imposes its pacing here
        if let Some(le) = self.last_emit {
            if io.now < le + self.interval {
                self.armed = true;
                io.wake_in(le + self.interval - io.now, PUMP);
                return;
            }
        }
        let stream = self.dst.stream.unwrap_or(0);
        if let Some((inference, payload)) = self.release_q.pop_front() {
            let meta = MsgMeta { stream, row: 0, rows: 1, inference };
            io.send(self.dst.dst, meta, payload);
        } else {
            let Some(req) = self.requests.get(self.idx) else {
                return; // drained; feedback re-arms the pump
            };
            if self.row == 0 {
                if io.now < req.arrival {
                    // sleep unarmed: a fed-back token may claim the link
                    io.wake_in(req.arrival - io.now, PUMP);
                    return;
                }
                if self.active >= self.batch.max {
                    // every KV slot is held: this prefill joins when a
                    // sequence finishes (the finish feedback re-arms us)
                    return;
                }
                self.active += 1;
                let mut log = self.log.lock().unwrap();
                log.peak_active = log.peak_active.max(self.active);
            }
            let payload = match &self.data {
                Some(d) => Payload::row_i8(d[self.row as usize].clone()),
                None => Payload::Timing(self.row_bytes),
            };
            let meta = MsgMeta {
                stream,
                row: self.row,
                rows: req.m,
                inference: self.idx as u32 * self.block,
            };
            io.send(self.dst.dst, meta, payload);
            self.row += 1;
            if self.row == req.m {
                self.row = 0;
                self.idx += 1;
                if self.block > 1 {
                    self.outstanding += 1; // prefill births the first token
                }
            }
        }
        self.last_emit = Some(io.now);
        if self.has_work() {
            self.armed = true;
            io.wake_in(self.interval.max(1), PUMP);
        }
    }

    fn name(&self) -> String {
        "serve-batch-source".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::{FpgaId, SwitchId};
    use crate::sim::fifo::Fifo;
    use crate::sim::packet::GlobalKernelId;
    use crate::sim::Sim;

    /// Records (arrival cycle, inference, row, rows) per packet.
    struct Recorder {
        seen: std::sync::Arc<std::sync::Mutex<Vec<(u64, u32, u32, u32)>>>,
    }
    impl KernelBehavior for Recorder {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            let log = self.seen.clone();
            io.rows(pkt, |io2, meta, at, payload| {
                io2.consume(payload.bytes());
                log.lock().unwrap().push((at, meta.inference, meta.row, meta.rows));
            });
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn run(requests: Vec<Request>, interval: u64) -> Vec<(u64, u32, u32, u32)> {
        let src = GlobalKernelId::new(0, 1);
        let dst = GlobalKernelId::new(0, 2);
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(
            src,
            FpgaId(0),
            Fifo::new(1 << 16),
            Box::new(RequestSourceKernel::new(
                Out::to(dst),
                Arc::new(requests),
                interval,
                None,
                768,
            )),
        )
        .unwrap();
        sim.add_kernel(dst, FpgaId(1), Fifo::new(1 << 20), Box::new(Recorder { seen: seen.clone() }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        let v = seen.lock().unwrap().clone();
        v
    }

    #[test]
    fn rows_follow_the_schedule_with_idle_gaps() {
        // request 1 arrives long after request 0 finished streaming
        let reqs =
            vec![Request { arrival: 0, m: 3 }, Request { arrival: 10_000, m: 2 }];
        let got = run(reqs, 12);
        assert_eq!(got.len(), 5);
        // rows of request 0 are spaced by the interval
        assert_eq!(got[1].0 - got[0].0, 12);
        assert_eq!(got[2].0 - got[1].0, 12);
        // request 1's first row leaves at its arrival, not before
        assert!(got[3].0 >= 10_000);
        assert_eq!(got[3].1, 1, "second request carries inference id 1");
        assert_eq!(got[3].3, 2, "rows metadata is the request's own length");
    }

    #[test]
    fn backlogged_arrivals_queue_at_the_source_link() {
        // request 1 arrives while request 0 (100 rows) still streams:
        // its rows must wait for the serialized link
        let reqs = vec![Request { arrival: 0, m: 100 }, Request { arrival: 60, m: 1 }];
        let got = run(reqs, 12);
        assert_eq!(got.len(), 101);
        let first_of_1 = got.iter().find(|e| e.1 == 1).unwrap();
        let last_of_0 = got.iter().filter(|e| e.1 == 0).map(|e| e.0).max().unwrap();
        assert!(
            first_of_1.0 > last_of_0,
            "queued request must start after the backlog drains"
        );
        assert_eq!(first_of_1.0 - last_of_0, 12, "and exactly one interval later");
    }

    #[test]
    fn empty_schedule_is_a_no_op() {
        assert!(run(Vec::new(), 12).is_empty());
    }

    /// Stands in for the whole pipeline + eval gateway: records every row
    /// and feeds each pass's last row back to the source on the
    /// feedback stream, like the gateway's broadcast would.
    struct Echo {
        src: GlobalKernelId,
        seen: std::sync::Arc<std::sync::Mutex<Vec<(u64, u32, u32, u32)>>>,
    }
    impl KernelBehavior for Echo {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            let log = self.seen.clone();
            let src = self.src;
            io.rows(pkt, |io2, meta, at, payload| {
                io2.consume(payload.bytes());
                log.lock().unwrap().push((at, meta.inference, meta.row, meta.rows));
                if meta.row + 1 == meta.rows {
                    let fb = MsgMeta { stream: FEEDBACK_STREAM, ..meta };
                    io2.send(src, fb, Payload::Timing(8));
                }
            });
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn run_decode(requests: Vec<Request>, block: u32) -> Vec<(u64, u32, u32, u32)> {
        let src = GlobalKernelId::new(0, 1);
        let dst = GlobalKernelId::new(0, 2);
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(
            src,
            FpgaId(0),
            Fifo::new(1 << 16),
            Box::new(DecodeSourceKernel::new(
                Out::to(dst),
                Arc::new(requests),
                12,
                None,
                768,
                block,
            )),
        )
        .unwrap();
        sim.add_kernel(
            dst,
            FpgaId(1),
            Fifo::new(1 << 20),
            Box::new(Echo { src, seen: seen.clone() }),
        )
        .unwrap();
        sim.start();
        sim.run().unwrap();
        let v = seen.lock().unwrap().clone();
        v
    }

    #[test]
    fn feedback_rows_trigger_per_token_passes() {
        // two requests, one decode token each: passes 0,1 and 2,3
        let reqs = vec![Request { arrival: 0, m: 3 }, Request { arrival: 0, m: 2 }];
        let got = run_decode(reqs, 2);
        assert_eq!(got.len(), 3 + 1 + 2 + 1);
        let of = |inf: u32| got.iter().filter(|e| e.1 == inf).collect::<Vec<_>>();
        assert_eq!(of(0).len(), 3, "request 0 prefill streams its prompt");
        assert_eq!(of(2).len(), 2, "request 1 prefill carries inference 2");
        for inf in [1, 3] {
            let tok = of(inf);
            assert_eq!(tok.len(), 1, "decode pass {inf} is a single row");
            assert_eq!((tok[0].2, tok[0].3), (0, 1));
        }
        // a token pass only starts after its previous pass finished
        let end0 = of(0).iter().map(|e| e.0).max().unwrap();
        assert!(of(1)[0].0 > end0);
    }

    #[test]
    fn block_one_means_pure_prefill() {
        let got = run_decode(vec![Request { arrival: 0, m: 4 }], 1);
        assert_eq!(got.len(), 4);
        assert!(got.iter().all(|e| e.1 == 0), "no decode passes at max_new_tokens = 0");
    }

    /// Echo with a fixed feedback latency (instant echo would serialize
    /// passes and no batch could ever form) plus an optional per-request
    /// stagger so tests can control feedback arrival order.
    struct DelayedEcho {
        src: GlobalKernelId,
        delay: u64,
        stagger: u64,
        block: u32,
        seen: std::sync::Arc<std::sync::Mutex<Vec<(u64, u32, u32, u32)>>>,
        pending: VecDeque<(u64, MsgMeta)>,
    }
    impl KernelBehavior for DelayedEcho {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            let log = self.seen.clone();
            let (delay, stagger, block) = (self.delay, self.stagger, self.block);
            let pending = &mut self.pending;
            io.rows(pkt, |io2, meta, at, payload| {
                io2.consume(payload.bytes());
                log.lock().unwrap().push((at, meta.inference, meta.row, meta.rows));
                if meta.row + 1 == meta.rows {
                    let due = at + delay + (meta.inference / block) as u64 * stagger;
                    pending.push_back((due, MsgMeta { stream: FEEDBACK_STREAM, ..meta }));
                    io2.wake_in(due.saturating_sub(at).max(1), PUMP);
                }
            });
        }
        fn on_wake(&mut self, _tag: u64, io: &mut KernelIo) {
            let now = io.now;
            let src = self.src;
            let mut rest = VecDeque::new();
            while let Some((due, meta)) = self.pending.pop_front() {
                if due <= now {
                    io.send(src, meta, Payload::Timing(8));
                } else {
                    rest.push_back((due, meta));
                }
            }
            self.pending = rest;
        }
    }

    fn run_batched(
        requests: Vec<Request>,
        block: u32,
        batch: BatchConfig,
        delay: u64,
        stagger: u64,
    ) -> (Vec<(u64, u32, u32, u32)>, BatchLog) {
        let src = GlobalKernelId::new(0, 1);
        let dst = GlobalKernelId::new(0, 2);
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let log = Arc::new(Mutex::new(BatchLog::default()));
        sim.add_kernel(
            src,
            FpgaId(0),
            Fifo::new(1 << 16),
            Box::new(BatchSourceKernel::new(
                Out::to(dst),
                Arc::new(requests),
                12,
                None,
                768,
                block,
                batch,
                log.clone(),
            )),
        )
        .unwrap();
        sim.add_kernel(
            dst,
            FpgaId(1),
            Fifo::new(1 << 20),
            Box::new(DelayedEcho { src, delay, stagger, block, seen: seen.clone(), pending: VecDeque::new() }),
        )
        .unwrap();
        sim.start();
        sim.run().unwrap();
        let v = seen.lock().unwrap().clone();
        let l = log.lock().unwrap().clone();
        (v, l)
    }

    #[test]
    fn tokens_group_into_full_batches_and_slots_gate_admission() {
        // three requests, two KV slots: r2's prefill must wait for a
        // finished sequence even though the link is idle from cycle ~60
        let reqs = vec![
            Request { arrival: 0, m: 2 },
            Request { arrival: 0, m: 2 },
            Request { arrival: 0, m: 2 },
        ];
        let (got, log) = run_batched(reqs, 2, BatchConfig { max: 2, window: 50 }, 600, 0);
        let of = |inf: u32| got.iter().filter(|e| e.1 == inf).copied().collect::<Vec<_>>();
        // r0/r1 tokens (inferences 1 and 3) release as one full batch
        // and chain down the link exactly one interval apart — the
        // streak the batched linear kernels price at marginal cost
        assert_eq!(log.releases.len(), 2, "releases: {:?}", log.releases);
        assert_eq!(log.releases[0].1, 2, "first batch holds both ready tokens");
        assert_eq!(log.releases[1].1, 1, "r2's token has no batch-mate left");
        assert_eq!(of(3)[0].0 - of(1)[0].0, 12, "batch rows chain at interval pacing");
        // the first-ready token waited for its batch-mate (prompts end
        // 24 cycles apart and feedback delay is uniform), the rest rode free
        assert_eq!(log.waits, vec![24, 0, 0]);
        assert_eq!(log.token_batch.get(&1), Some(&2));
        assert_eq!(log.token_batch.get(&3), Some(&2));
        assert_eq!(log.token_batch.get(&5), Some(&1));
        // admission: r2 (inference 4) only streams after a finish freed a slot
        assert_eq!(log.peak_active, 2);
        let first_of_r2 = of(4)[0].0;
        assert!(
            first_of_r2 > of(1)[0].0 + 600,
            "prefill admitted at {first_of_r2}, before r0's final pass finished"
        );
    }

    #[test]
    fn the_window_bounds_assembly_wait() {
        // staggered feedback: r1's token arrives 500 cycles after r0's,
        // far past the 100-cycle window — r0's token must not wait for it
        let reqs = vec![Request { arrival: 0, m: 2 }, Request { arrival: 0, m: 2 }];
        let (got, log) = run_batched(reqs, 2, BatchConfig { max: 4, window: 100 }, 600, 500);
        assert_eq!(log.releases.len(), 2);
        assert_eq!((log.releases[0].1, log.releases[1].1), (1, 1));
        assert_eq!(log.waits, vec![100, 0], "expired window charges exactly `window`");
        // the token really was held back by the window before emission
        let of = |inf: u32| got.iter().filter(|e| e.1 == inf).copied().collect::<Vec<_>>();
        let last_prefill_row = of(0).iter().map(|e| e.0).max().unwrap();
        assert!(of(1)[0].0 >= last_prefill_row + 600 + 100);
    }

    #[test]
    fn batched_source_with_no_requests_is_a_no_op() {
        let (got, log) =
            run_batched(Vec::new(), 3, BatchConfig { max: 4, window: 64 }, 600, 0);
        assert!(got.is_empty());
        assert!(log.releases.is_empty() && log.waits.is_empty());
        assert_eq!(log.peak_active, 0);
    }
}
