//! The serving source kernel: replays an open-loop request schedule into
//! the first encoder over the evaluation FPGA's 100G link.
//!
//! Emission is open-loop but the link is a real serial resource: row `r`
//! of request `i` leaves at `max(arrival_i, previous_emission + interval)`
//! — a request that arrives while an earlier one is still streaming
//! queues *at the source*, and that queueing delay is charged to its
//! end-to-end latency (completion − scheduled arrival), exactly like a
//! NIC transmit queue in a real deployment.

use std::sync::Arc;

use crate::gmi::Out;
use crate::sim::engine::{KernelBehavior, KernelIo, START_TAG};
use crate::sim::packet::{MsgMeta, Packet, Payload};

use super::traffic::Request;

/// Wake tag of the emission pump.
const PUMP: u64 = 1;

/// Streams the rows of each scheduled request at `interval` pacing,
/// tagging every row with the request index as its inference id so the
/// per-inference kernel state downstream keeps overlapping requests
/// separate.
pub struct RequestSourceKernel {
    dst: Out,
    /// cycles between consecutive row packets (12 = 100G line rate)
    interval: u64,
    requests: Arc<Vec<Request>>,
    /// golden input rows for functional runs (row `r` of a length-`m`
    /// request sends `data[r]`); None = timing payloads
    data: Option<Arc<Vec<Vec<i8>>>>,
    /// row size for timing payloads (one hidden row)
    row_bytes: usize,
    idx: usize,
    row: u32,
}

impl RequestSourceKernel {
    pub fn new(
        dst: Out,
        requests: Arc<Vec<Request>>,
        interval: u64,
        data: Option<Arc<Vec<Vec<i8>>>>,
        row_bytes: usize,
    ) -> Self {
        RequestSourceKernel { dst, interval, requests, data, row_bytes, idx: 0, row: 0 }
    }
}

impl KernelBehavior for RequestSourceKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag != START_TAG && tag != PUMP {
            return;
        }
        let Some(req) = self.requests.get(self.idx) else {
            return; // schedule drained
        };
        if self.row == 0 && io.now < req.arrival {
            // idle link: sleep until the next request arrives
            io.wake_in(req.arrival - io.now, PUMP);
            return;
        }
        let payload = match &self.data {
            Some(d) => Payload::row_i8(d[self.row as usize].clone()),
            None => Payload::Timing(self.row_bytes),
        };
        let meta = MsgMeta {
            stream: self.dst.stream.unwrap_or(0),
            row: self.row,
            rows: req.m,
            inference: self.idx as u32,
        };
        io.send(self.dst.dst, meta, payload);
        self.row += 1;
        if self.row == req.m {
            self.row = 0;
            self.idx += 1;
        }
        if self.idx < self.requests.len() {
            // the link stays serialized at `interval` even across request
            // boundaries; an early next-arrival waits in the PUMP branch
            io.wake_in(self.interval.max(1), PUMP);
        }
    }

    fn name(&self) -> String {
        "serve-source".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::{FpgaId, SwitchId};
    use crate::sim::fifo::Fifo;
    use crate::sim::packet::GlobalKernelId;
    use crate::sim::Sim;

    /// Records (arrival cycle, inference, row, rows) per packet.
    struct Recorder {
        seen: std::sync::Arc<std::sync::Mutex<Vec<(u64, u32, u32, u32)>>>,
    }
    impl KernelBehavior for Recorder {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            let log = self.seen.clone();
            io.rows(pkt, |io2, meta, at, payload| {
                io2.consume(payload.bytes());
                log.lock().unwrap().push((at, meta.inference, meta.row, meta.rows));
            });
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn run(requests: Vec<Request>, interval: u64) -> Vec<(u64, u32, u32, u32)> {
        let src = GlobalKernelId::new(0, 1);
        let dst = GlobalKernelId::new(0, 2);
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(
            src,
            FpgaId(0),
            Fifo::new(1 << 16),
            Box::new(RequestSourceKernel::new(
                Out::to(dst),
                Arc::new(requests),
                interval,
                None,
                768,
            )),
        )
        .unwrap();
        sim.add_kernel(dst, FpgaId(1), Fifo::new(1 << 20), Box::new(Recorder { seen: seen.clone() }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        let v = seen.lock().unwrap().clone();
        v
    }

    #[test]
    fn rows_follow_the_schedule_with_idle_gaps() {
        // request 1 arrives long after request 0 finished streaming
        let reqs =
            vec![Request { arrival: 0, m: 3 }, Request { arrival: 10_000, m: 2 }];
        let got = run(reqs, 12);
        assert_eq!(got.len(), 5);
        // rows of request 0 are spaced by the interval
        assert_eq!(got[1].0 - got[0].0, 12);
        assert_eq!(got[2].0 - got[1].0, 12);
        // request 1's first row leaves at its arrival, not before
        assert!(got[3].0 >= 10_000);
        assert_eq!(got[3].1, 1, "second request carries inference id 1");
        assert_eq!(got[3].3, 2, "rows metadata is the request's own length");
    }

    #[test]
    fn backlogged_arrivals_queue_at_the_source_link() {
        // request 1 arrives while request 0 (100 rows) still streams:
        // its rows must wait for the serialized link
        let reqs = vec![Request { arrival: 0, m: 100 }, Request { arrival: 60, m: 1 }];
        let got = run(reqs, 12);
        assert_eq!(got.len(), 101);
        let first_of_1 = got.iter().find(|e| e.1 == 1).unwrap();
        let last_of_0 = got.iter().filter(|e| e.1 == 0).map(|e| e.0).max().unwrap();
        assert!(
            first_of_1.0 > last_of_0,
            "queued request must start after the backlog drains"
        );
        assert_eq!(first_of_1.0 - last_of_0, 12, "and exactly one interval later");
    }

    #[test]
    fn empty_schedule_is_a_no_op() {
        assert!(run(Vec::new(), 12).is_empty());
    }
}
