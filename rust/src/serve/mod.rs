//! Streaming request serving over the simulated multi-FPGA pipeline
//! (the ROADMAP north-star: heavy traffic, not single-shot latency).
//!
//! The paper's headline numbers simulate ONE encoder and extrapolate the
//! 12-encoder model via Eq. 1 `T + (L-1)(X + d)`. This subsystem actually
//! builds the chain and serves it: [`traffic`] generates an open-loop
//! request schedule (Poisson/uniform arrivals over GLUE/MRPC/SQuAD
//! length distributions), [`source`] replays it into the first encoder
//! over the evaluation FPGA's serialized 100G link, and [`stats`]
//! distills per-request latency percentiles, sustained throughput, and
//! per-stage occupancy/backpressure out of the DES trace. Consecutive
//! sequences overlap inside the pipeline exactly as the paper's X-vs-T
//! analysis predicts — and [`validate_eq1`] turns that prediction into a
//! tested claim by comparing the analytic estimate against the fully
//! simulated N-encoder chain (inter-encoder `d` modeled as a real fabric
//! hop, not a constant).
//!
//! Entry points: [`ServeConfig`] + [`run_serving`] (the `serve` CLI
//! subcommand and `benches/serving_pipeline.rs` are thin wrappers).

pub mod source;
pub mod stats;
pub mod tenant;
pub mod traffic;

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::eval::latency_model::estimate_model_latency_cycles;
use crate::eval::testbed::{
    build_testbed, run_encoder_once, FailureSchedule, NetworkConfig, TestbedConfig,
    EVAL_CLUSTER, EVAL_SINK, EVAL_SOURCE,
};
use crate::obs::{render_chrome_trace, render_metrics_jsonl, telemetry_section};
use crate::obs::{ObsSettings, RequestOutcome, SpanRoles};
use crate::ibert::graph::{ids, KERNELS_PER_ENCODER};
use crate::ibert::kernels::Mode;
use crate::ibert::timing::PeConfig;
use crate::sim::packet::GlobalKernelId;
use crate::FABRIC_CLOCK_HZ;

pub use stats::{
    validate_serving_report, BatchingReport, DecodeReport, Eq1Check, FairnessReport, FaultReport,
    LatencySummary, ServingReport, StageReport, TenantReport,
};
pub use tenant::{AdmissionOutcome, TenantClass, TenantSpec, TenantsConfig};
pub use traffic::{ArrivalProcess, BatchConfig, DecodeConfig, LengthDist, Request, TrafficConfig};

/// One serving scenario: a pipeline shape plus an open-loop traffic trace.
#[derive(Clone)]
pub struct ServeConfig {
    /// chained encoders (12 = the full I-BERT of Fig. 17)
    pub encoders: usize,
    pub traffic: TrafficConfig,
    /// row packet interval on the source link (12 = 100G line rate)
    pub interval: u64,
    pub pe: PeConfig,
    pub mode: Mode,
    /// golden input rows for functional serving (>= max_m rows)
    pub input: Option<Arc<Vec<Vec<i8>>>>,
    /// per-encoder kernel -> slot map from the placer (None = Fig. 14)
    pub placement: Option<Vec<usize>>,
    pub fpgas_per_switch: usize,
    /// also run the Eq. 1 analytic-vs-simulated cross-check
    pub check_eq1: bool,
    /// DES worker threads (None = process default, 1 = sequential);
    /// serving reports are bit-identical at every thread count.
    pub threads: Option<usize>,
    /// shard cut for the parallel DES (None = simulator default,
    /// per-cluster); reports are granularity-invariant by contract.
    pub granularity: Option<crate::sim::ShardGranularity>,
    /// per-copy UDP loss probability on inter-FPGA hops (the drop
    /// pattern derives from `traffic.seed`, so lossy serving is
    /// seed-deterministic)
    pub drop_probability: f64,
    /// ack/retransmit reliable transport: lossy runs complete every
    /// inference instead of stalling on vanished rows
    pub reliable: bool,
    /// §6 failure injection: kill an FPGA mid-serving and recover via
    /// the placer's incremental re-place (fills the report's `fault`
    /// section)
    pub fail: Option<FailureSchedule>,
    /// cycle-domain telemetry (span traces + metrics + self-profile);
    /// off by default, and a telemetry-off report is byte-identical to
    /// the pre-telemetry `serving_report/v2`
    pub obs: ObsSettings,
    /// autoregressive decoding (`serve --decode`): each request becomes
    /// one prefill pass plus `max_new_tokens` single-token passes fed
    /// back through the pipeline, and the report gains the v4 `decode`
    /// section (TTFT / ITL percentiles, KV-cache occupancy)
    pub decode: Option<traffic::DecodeConfig>,
    /// continuous (iteration-level) batching for decode serving: token
    /// passes from different in-flight requests are grouped into one
    /// weight-stationary batch of up to `max` rows, waiting at most
    /// `window` cycles for stragglers; requires `decode`, and the report
    /// gains the v5 `batching` section. `max <= 1` (or None) is the
    /// legacy one-pass-at-a-time path, byte-identical to a v4 run.
    pub batching: Option<traffic::BatchConfig>,
}

impl ServeConfig {
    /// GLUE traffic at `seqs_per_s` Poisson arrivals through `encoders`
    /// chained encoders — the headline serving scenario.
    pub fn glue(encoders: usize, requests: usize, seqs_per_s: f64, seed: u64) -> ServeConfig {
        ServeConfig {
            encoders,
            traffic: TrafficConfig {
                process: ArrivalProcess::Poisson { seqs_per_s },
                lengths: LengthDist::Glue,
                requests,
                seed,
                max_m: 128,
            },
            interval: 12,
            pe: PeConfig::default(),
            mode: Mode::Timing,
            input: None,
            placement: None,
            fpgas_per_switch: 6,
            check_eq1: false,
            threads: None,
            granularity: None,
            drop_probability: 0.0,
            reliable: false,
            fail: None,
            obs: ObsSettings::default(),
            decode: None,
            batching: None,
        }
    }

    /// The build point's sequence capacity — what the KV caches and
    /// FIFOs are sized for.
    fn max_seq(&self) -> usize {
        match &self.mode {
            Mode::Functional(p) => p.cfg.max_seq,
            Mode::Timing => 128,
        }
    }

    /// Probe the pipeline's capacity at the workload's published mean
    /// length; returns `(mean_m, seqs_per_s)`. The single definition the
    /// CLI's `--util` and the serving bench's `load` both scale against.
    pub fn capacity_at_mean(&self) -> Result<(usize, f64)> {
        let mean_m = (self.traffic.lengths.mean().round() as usize).clamp(1, self.traffic.max_m);
        Ok((mean_m, pipeline_capacity_seqs_per_s(self, mean_m)?))
    }

    fn testbed_config(&self, schedule: Arc<Vec<Request>>) -> TestbedConfig {
        TestbedConfig {
            encoders: self.encoders,
            m: self.traffic.max_m,
            inferences: schedule.len() as u32,
            interval: self.interval,
            pe: self.pe,
            mode: self.mode.clone(),
            fpgas_per_switch: self.fpgas_per_switch,
            input: self.input.clone(),
            placement: self.placement.clone(),
            schedule: Some(schedule),
            threads: self.threads,
            granularity: self.granularity,
            net: NetworkConfig {
                drop_probability: self.drop_probability,
                reliable: self.reliable,
                // the traffic seed drives the drop pattern too: one seed
                // fully determines a lossy serving run
                seed: self.traffic.seed,
            },
            fail: self.fail,
            obs: self.obs.clone(),
            decode: self.decode,
            batching: self.batching,
        }
    }
}

/// Telemetry artifacts of one serving run (both None when telemetry is
/// off): the Chrome trace-event JSON behind `--trace-out` and the
/// `obs_metrics/v1` JSONL stream behind `--metrics-out`.
#[derive(Debug, Clone, Default)]
pub struct ObsOutput {
    pub trace_json: Option<String>,
    pub metrics_jsonl: Option<String>,
}

/// Measure the pipeline's sustainable sequence rate (seqs/s) at length
/// `m`: stream back-to-back inferences through one encoder and take the
/// median completion gap. Every stage of a homogeneous chain has the
/// same initiation interval, so one encoder's steady state is the whole
/// pipeline's capacity — this is what `--util` scales against.
pub fn pipeline_capacity_seqs_per_s(cfg: &ServeConfig, m: usize) -> Result<f64> {
    let mut tb_cfg = cfg.testbed_config(Arc::new(Vec::new()));
    tb_cfg.schedule = None;
    tb_cfg.encoders = 1;
    tb_cfg.m = m;
    tb_cfg.inferences = 6;
    // capacity is a property of the healthy pipeline: probe it without
    // the scenario's loss/failure injection, telemetry overhead, or
    // decode feedback loop
    tb_cfg.net = NetworkConfig::default();
    tb_cfg.fail = None;
    tb_cfg.obs = ObsSettings::default();
    tb_cfg.decode = None;
    tb_cfg.batching = None;
    let mut tb = build_testbed(&tb_cfg)?;
    tb.sim.start();
    tb.sim.run()?;
    let sink = tb.sink.lock().unwrap();
    let mut done: Vec<u64> = (0..tb_cfg.inferences)
        .filter_map(|i| sink.arrivals.get(&i).map(|&(_, t)| t))
        .collect();
    done.sort_unstable();
    ensure!(done.len() >= 2, "capacity probe needs >= 2 completed inferences");
    let mut gaps: Vec<u64> = done.windows(2).map(|w| w[1] - w[0]).collect();
    gaps.sort_unstable();
    let ii = gaps[gaps.len() / 2].max(1);
    Ok(FABRIC_CLOCK_HZ as f64 / ii as f64)
}

/// Validate Eq. 1 against the simulator: measure one encoder's (X, T)
/// at length `m`, extrapolate to `encoders` with `d` taken from the
/// platform's actual inter-encoder fabric hop, and compare against the
/// fully simulated chain's last-output latency.
pub fn validate_eq1(base: &TestbedConfig, encoders: usize, m: usize) -> Result<Eq1Check> {
    ensure!(encoders >= 1, "need at least one encoder");
    let mut one = base.clone();
    one.encoders = 1;
    one.m = m;
    one.inferences = 1;
    one.schedule = None;
    // Eq. 1 describes the healthy prefill pipeline: measure its
    // components without the serving scenario's loss/failure injection,
    // telemetry, or decode feedback loop
    one.net = NetworkConfig::default();
    one.fail = None;
    one.obs = ObsSettings::default();
    one.decode = None;
    one.batching = None;
    let single = run_encoder_once(&one)?;
    let components = single.components();

    let mut chain = one.clone();
    chain.encoders = encoders;
    let full = run_encoder_once(&chain)?;

    // Eq. 1 with d read off the topology. Hop counts can differ per
    // boundary when fpgas_per_switch does not divide the encoder width,
    // so sum the actual d of each boundary (reduces to the closed form
    // `T + (L-1)(X + d)` whenever d is uniform, e.g. the paper layout).
    let d_total: u64 = (0..encoders.saturating_sub(1))
        .map(|b| crate::eval::testbed::inter_encoder_hop_cycles(base, b))
        .sum();
    let analytic = estimate_model_latency_cycles(components, encoders, 0) + d_total;
    Ok(Eq1Check { encoders, m, components, analytic, simulated: full.t })
}

/// Run one serving scenario end to end and distill the report.
///
/// Degraded runs are reports, not errors: a lossy-unreliable or
/// fault-hit run that completes only some (or none) of its requests
/// still produces a `serving_report/v2` with `completed < requests`, a
/// zeroed latency summary when nothing finished, and — with a failure
/// injected — the fault section. An empty schedule (zero requests) is
/// likewise a valid, empty report.
pub fn run_serving(cfg: &ServeConfig) -> Result<ServingReport> {
    Ok(run_serving_with_obs(cfg)?.0)
}

/// [`run_serving`] plus the telemetry artifacts: the Chrome trace and
/// metrics stream of the run (both None unless `cfg.obs.enabled`), with
/// the report's `telemetry` / `sim_profile` sections filled in when
/// telemetry / profiling are on.
pub fn run_serving_with_obs(cfg: &ServeConfig) -> Result<(ServingReport, ObsOutput)> {
    ensure!(cfg.encoders >= 1, "need at least one encoder");
    ensure!(cfg.traffic.process.seqs_per_s() > 0.0, "offered rate must be positive");
    ensure!(
        (0.0..1.0).contains(&cfg.drop_probability),
        "drop probability must be in [0, 1)"
    );
    // decode-mode prompts must leave KV head-room for the generated
    // tokens: clamp the traffic's max length so prompt + max_new_tokens
    // fits the build point's sequence capacity. The clamp happens before
    // schedule generation, so it is deterministic at every thread count;
    // explicit over-long schedules still fail loudly in build_testbed.
    let clamped;
    let cfg = if let Some(dec) = cfg.decode {
        let cap = cfg.max_seq().saturating_sub(dec.max_new_tokens as usize).max(1);
        let mut c = cfg.clone();
        c.traffic.max_m = c.traffic.max_m.min(cap);
        clamped = c;
        &clamped
    } else {
        cfg
    };
    let max_seq = cfg.max_seq();
    let schedule = Arc::new(cfg.traffic.generate());
    let tb_cfg = cfg.testbed_config(schedule.clone());
    let mut tb = build_testbed(&tb_cfg)?;
    tb.sim.start();
    tb.sim.run()?;

    // per-request outcomes: completion of the last output row minus the
    // scheduled arrival (source queueing charged to the request). In
    // decode mode request r spans `block = 1 + max_new_tokens` pipeline
    // passes — the m-row prefill at inference id r*block, then one
    // single-row pass per generated token — and the request completes
    // when its last pass does.
    let block = cfg.decode.map_or(1u32, |d| d.block());
    let mut per_request: Vec<Option<u64>> = vec![None; schedule.len()];
    let (mut completed, mut completed_tokens, mut last_done) = (0usize, 0u64, 0u64);
    let mut decode_report = None;
    // continuous batching: snapshot the assembler's log (release sizes,
    // assembly waits, token-pass -> batch-size map) to distill the v5
    // batching section; a disabled config never builds the assembler, so
    // the report stays byte-identical to the v4 path
    let batching = cfg.batching.filter(|b| b.enabled());
    let batch_snapshot = tb.batch_log.as_ref().map(|l| l.lock().unwrap().clone());
    let mut batching_report = None;
    {
        let sink = tb.sink.lock().unwrap();
        let pass_done = |base: u32, p: u32, m: u32| -> Option<u64> {
            let need = if p == 0 { m } else { 1 };
            sink.arrivals.get(&(base + p)).and_then(|&(pkts, t)| (pkts == need).then_some(t))
        };
        let mut ttft = Vec::new();
        let mut itl = Vec::new();
        let mut kv_occupancy = Vec::with_capacity(schedule.len());
        let mut generated_tokens = 0u64;
        let mut ttft_by_size: std::collections::BTreeMap<u32, Vec<u64>> = Default::default();
        let mut itl_by_size: std::collections::BTreeMap<u32, Vec<u64>> = Default::default();
        for (i, req) in schedule.iter().enumerate() {
            let base = i as u32 * block;
            let passes: Vec<Option<u64>> =
                (0..block).map(|p| pass_done(base, p, req.m)).collect();
            // time-to-first-token: the prefill pass completing is the
            // moment the first generated token could be sampled
            if let Some(d0) = passes[0] {
                ttft.push(d0 - req.arrival);
                // keyed by the batch the first token pass rode in: the
                // contention level the request met when entering decode
                if let Some(log) = &batch_snapshot {
                    if let Some(&sz) = log.token_batch.get(&(base + 1)) {
                        ttft_by_size.entry(sz).or_default().push(d0 - req.arrival);
                    }
                }
            }
            let gen = passes[1..].iter().flatten().count() as u64;
            generated_tokens += gen;
            // inter-token latency: gaps between consecutive completed
            // passes (pass 0 -> 1 is the first post-prefill gap)
            for (p, w) in passes.windows(2).enumerate() {
                if let (Some(a), Some(b)) = (w[0], w[1]) {
                    itl.push(b.saturating_sub(a));
                    // keyed by the LATER token's batch: the gap a token
                    // paid depends on the batch it was grouped into
                    if let Some(log) = &batch_snapshot {
                        if let Some(&sz) = log.token_batch.get(&(base + p as u32 + 1)) {
                            itl_by_size.entry(sz).or_default().push(b.saturating_sub(a));
                        }
                    }
                }
            }
            kv_occupancy.push((req.m as u64 + gen) as f64 / max_seq as f64);
            if passes.iter().all(Option::is_some) {
                let done = passes.last().unwrap().unwrap();
                completed += 1;
                completed_tokens += req.m as u64 + (block - 1) as u64;
                per_request[i] = Some(done - req.arrival);
                last_done = last_done.max(done);
            }
        }
        if let Some(dec) = cfg.decode {
            decode_report = Some(stats::DecodeReport {
                max_new_tokens: dec.max_new_tokens,
                generated_tokens,
                ttft: LatencySummary::from_unsorted(ttft).unwrap_or_else(LatencySummary::empty),
                itl: LatencySummary::from_unsorted(itl).unwrap_or_else(LatencySummary::empty),
                kv_occupancy,
            });
        }
        if let (Some(bc), Some(log)) = (batching, &batch_snapshot) {
            let mut histogram = vec![0u64; bc.max as usize];
            for &(_, size) in &log.releases {
                histogram[(size.clamp(1, bc.max) - 1) as usize] += 1;
            }
            let summarize = |m: std::collections::BTreeMap<u32, Vec<u64>>| {
                m.into_iter()
                    .map(|(sz, v)| {
                        (sz, LatencySummary::from_unsorted(v).unwrap_or_else(LatencySummary::empty))
                    })
                    .collect()
            };
            batching_report = Some(stats::BatchingReport {
                batch_max: bc.max,
                batch_window: bc.window,
                batches: log.releases.len() as u64,
                histogram,
                assembly_wait: LatencySummary::from_unsorted(log.waits.clone())
                    .unwrap_or_else(LatencySummary::empty),
                peak_active: log.peak_active,
                ttft_by_size: summarize(ttft_by_size),
                itl_by_size: summarize(itl_by_size),
            });
        }
    }
    let latencies: Vec<u64> = per_request.iter().filter_map(|&l| l).collect();
    let latency =
        LatencySummary::from_unsorted(latencies.clone()).unwrap_or_else(LatencySummary::empty);
    let makespan_cycles =
        last_done.saturating_sub(schedule.first().map_or(0, |r| r.arrival));

    // §6 fault section: engine outcome + the planned recovery
    let fault = match (tb.recovery, tb.sim.failure_report()) {
        (Some(pr), Some(fr)) => {
            let window: Vec<u64> = schedule
                .iter()
                .zip(&per_request)
                .filter(|(req, _)| {
                    (fr.fail_cycle..fr.recover_cycle).contains(&req.arrival)
                })
                .filter_map(|(_, &lat)| lat)
                .collect();
            // the §6 cluster input buffer is the failed cluster's gateway
            // FIFO: report its capacity and how hard the backlog hit it
            let gw = GlobalKernelId::new(pr.cluster, ids::GATEWAY);
            let input_buffer_bytes = tb
                .spec
                .clusters
                .iter()
                .find(|c| c.id == pr.cluster)
                .map_or(0, |c| c.input_buffer_bytes());
            Some(FaultReport {
                fpga: pr.fpga,
                cluster: pr.cluster,
                fail_cycle: fr.fail_cycle,
                recover_cycle: fr.recover_cycle,
                reconfig_cycles: pr.reconfig_cycles,
                moved_kernels: pr.moved_kernels,
                degraded_placement: pr.degraded,
                recovered: fr.recovered,
                input_buffer_bytes,
                input_buffer_peak: tb.sim.fifo_of(gw).map_or(0.0, |f| f.peak_fraction()),
                held_packets: fr.held_packets,
                lost_events: fr.lost_events,
                incomplete_requests: schedule.len() - completed,
                recovery_window: LatencySummary::from_unsorted(window),
            })
        }
        _ => None,
    };

    // per-stage activity and backpressure
    let mut stages = Vec::with_capacity(cfg.encoders);
    for e in 0..cfg.encoders {
        let gw = GlobalKernelId::new(e as u8, ids::GATEWAY);
        let out = GlobalKernelId::new(e as u8, ids::LN2);
        let first_rx = tb.sim.trace.kernel(gw).and_then(|s| s.first_rx).unwrap_or(0);
        let last_tx = tb.sim.trace.kernel(out).and_then(|s| s.last_tx).unwrap_or(first_rx);
        let rows_in = tb.sim.trace.kernel(gw).map_or(0, |s| s.rx_packets);
        let (mut peak, mut overflows) = (0.0f64, 0u64);
        for k in 0..KERNELS_PER_ENCODER as u8 {
            if let Some(f) = tb.sim.fifo_of(GlobalKernelId::new(e as u8, k)) {
                peak = peak.max(f.peak_fraction());
                overflows += f.overflows;
            }
        }
        let span = last_tx.saturating_sub(first_rx) as f64;
        let occupancy = (span / makespan_cycles.max(1) as f64).min(1.0);
        stages.push(StageReport {
            encoder: e,
            occupancy,
            fifo_peak: peak,
            fifo_overflows: overflows,
            rows_in,
        });
    }

    // Eq. 1 cross-check at the workload's mean length
    let eq1 = if cfg.check_eq1 && !schedule.is_empty() {
        let mean_m = (traffic::total_tokens(&schedule) as f64 / schedule.len() as f64)
            .round()
            .clamp(1.0, cfg.traffic.max_m as f64) as usize;
        Some(validate_eq1(&tb_cfg, cfg.encoders, mean_m)?)
    } else {
        None
    };

    // telemetry exports: derive spans/metrics from the collectors the
    // run carried (all thread-invariant), then the report sections
    let mut obs_out = ObsOutput::default();
    let mut telemetry = None;
    if cfg.obs.enabled {
        if let Some(tobs) = tb.sim.trace.obs.as_deref() {
            let outcomes: Vec<RequestOutcome> = schedule
                .iter()
                .enumerate()
                .map(|(i, req)| RequestOutcome {
                    // in decode mode the request is identified by its
                    // prefill pass id, and `done` is the completion of
                    // the LAST pass (per_request already folds that in)
                    inference: i as u32 * block,
                    arrival: req.arrival,
                    m: req.m,
                    done: per_request[i].map(|lat| req.arrival + lat),
                })
                .collect();
            let roles = SpanRoles {
                source: Some(GlobalKernelId::new(EVAL_CLUSTER, EVAL_SOURCE).dense() as u32),
                stages: (0..cfg.encoders)
                    .map(|e| {
                        (
                            GlobalKernelId::new(e as u8, ids::GATEWAY).dense() as u32,
                            GlobalKernelId::new(e as u8, ids::LN2).dense() as u32,
                        )
                    })
                    .collect(),
                sink: Some(GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK).dense() as u32),
            };
            let fobs = tb.sim.fabric.obs.as_deref();
            obs_out.trace_json = Some(render_chrome_trace(&outcomes, &roles, tobs, fobs));
            obs_out.metrics_jsonl = Some(render_metrics_jsonl(
                &tb.sim.trace,
                tobs,
                fobs,
                &tb.sim.fifo_snapshots(),
                &tb.sim.fabric.stats,
                tb.sim.time,
            ));
            telemetry = Some(telemetry_section(&outcomes, &roles, &tb.sim.trace, tobs, fobs));
        }
    }
    let sim_profile = tb.sim.last_profile.as_ref().map(|p| p.to_json());

    let report = ServingReport {
        encoders: cfg.encoders,
        workload: cfg.traffic.lengths.name().to_string(),
        process: cfg.traffic.process.name().to_string(),
        offered_seqs_per_s: cfg.traffic.process.seqs_per_s(),
        seed: cfg.traffic.seed,
        requests: schedule.len(),
        completed,
        total_tokens: traffic::total_tokens(&schedule),
        completed_tokens,
        makespan_cycles,
        latency,
        latencies,
        stages,
        eq1,
        dropped: tb.sim.fabric.stats.dropped,
        retransmits: tb.sim.fabric.stats.retransmits,
        fault,
        events: tb.sim.trace.events_processed,
        telemetry,
        sim_profile,
        decode: decode_report,
        batching: batching_report,
        tenants: None,
        fairness: None,
    };
    Ok((report, obs_out))
}

/// One multi-tenant serving scenario: the tenant roster plus the
/// runtime knobs the whole fleet shares (`serve --tenants`).
#[derive(Clone)]
pub struct MultiTenantConfig {
    pub tenants: TenantsConfig,
    /// base RNG seed; tenant `t` draws its schedule from
    /// `traffic::stream_seed(seed, t)`, so sibling schedules never
    /// shift when the roster grows or shrinks
    pub seed: u64,
    pub pe: PeConfig,
    pub threads: Option<usize>,
    pub granularity: Option<crate::sim::ShardGranularity>,
    /// §6 failure injection: the failed FPGA belongs to exactly one
    /// tenant, and recovery re-places that tenant alone
    pub fail: Option<FailureSchedule>,
}

impl MultiTenantConfig {
    pub fn new(tenants: TenantsConfig, seed: u64) -> MultiTenantConfig {
        MultiTenantConfig {
            tenants,
            seed,
            pe: PeConfig::default(),
            threads: None,
            granularity: None,
            fail: None,
        }
    }
}

/// Serve N tenants on one fleet and distill the `serving_report/v6`.
///
/// The stages mirror a real multi-tenant control plane, and every one
/// of them is deterministic before the simulator even exists:
///
/// 1. **admission** — each tenant's offered schedule passes SLO-aware
///    admission control ([`TenantSpec::admit`]), a pure function of
///    that tenant's own schedule;
/// 2. **placement** — [`crate::placer::place_multi`] packs every
///    tenant's paper-shaped encoder onto one shared fleet (spatial
///    partitioning: contiguous per-tenant slot ranges);
/// 3. **serving** — one shared DES runs all chains at once; each
///    tenant has its own source, sink, and FIFOs, so the report
///    inherits the engine's thread/shard bit-identity contract;
/// 4. **reporting** — per-tenant TTFT/latency percentiles, throughput
///    over the tenant's own makespan, reject rates, and the
///    cross-tenant fairness section.
pub fn run_multi_tenant_serving(cfg: &MultiTenantConfig) -> Result<ServingReport> {
    use crate::eval::testbed::{build_tenant_testbed, TenantChain, TenantTestbedConfig};
    use crate::fpga::resources::Device;
    use crate::placer::{place_multi, Fleet, ModelShape, TenantGraphSpec};

    cfg.tenants.validate()?;
    let specs = &cfg.tenants.tenants;

    // 1) SLO-aware admission, per tenant, on independent seed streams
    let outcomes = cfg.tenants.admitted_schedules(cfg.seed);

    // 2) pack the roster onto one fleet (8 boards of headroom apiece)
    let graph_specs: Vec<TenantGraphSpec> = specs
        .iter()
        .map(|t| TenantGraphSpec {
            name: t.name.clone(),
            shape: ModelShape { max_seq: t.max_m, ..ModelShape::ibert_base() },
            m: t.max_m,
        })
        .collect();
    let fleet =
        Fleet::homogeneous(Device::Xczu19eg, 8 * specs.len(), cfg.tenants.fpgas_per_switch);
    let mp = place_multi(&graph_specs, &cfg.pe, &fleet)?;

    // 3) one shared testbed: per-tenant chains + a common eval FPGA
    let chains: Vec<TenantChain> = specs
        .iter()
        .zip(&mp.tenants)
        .zip(&outcomes)
        .map(|((t, tp), out)| {
            ensure!(
                tp.placement.slot_of.len() == KERNELS_PER_ENCODER,
                "tenant {:?}: the runtime encoder needs a {}-kernel (split-1) placement, \
                 the placer chose {}",
                t.name,
                KERNELS_PER_ENCODER,
                tp.placement.slot_of.len()
            );
            Ok(TenantChain {
                name: t.name.clone(),
                encoders: t.encoders,
                max_m: t.max_m,
                slots: tp.placement.slot_of.clone(),
                schedule: Arc::new(out.admitted.clone()),
            })
        })
        .collect::<Result<_>>()?;
    let tb_cfg = TenantTestbedConfig {
        tenants: chains,
        interval: cfg.tenants.interval,
        pe: cfg.pe,
        fpgas_per_switch: cfg.tenants.fpgas_per_switch,
        threads: cfg.threads,
        granularity: cfg.granularity,
        fail: cfg.fail,
    };
    let mut tb = build_tenant_testbed(&tb_cfg)?;
    tb.sim.start();
    tb.sim.run()?;

    // 4) distill each tenant's section off its OWN sink
    let mut tenant_reports = Vec::with_capacity(specs.len());
    let mut all_latencies: Vec<u64> = Vec::new();
    // (arrival, latency) of every admitted request, for the fault window
    let mut window_pairs: Vec<(u64, Option<u64>)> = Vec::new();
    let (mut completed_all, mut completed_tokens_all, mut total_tokens_all) =
        (0usize, 0u64, 0u64);
    let (mut first_arrival, mut last_done_all) = (u64::MAX, 0u64);
    for (t, (spec, out)) in specs.iter().zip(&outcomes).enumerate() {
        let sink = tb.sinks[t].lock().unwrap();
        let mut latencies = Vec::with_capacity(out.admitted.len());
        let mut ttfts = Vec::new();
        let (mut completed, mut completed_tokens, mut last_done) = (0u64, 0u64, 0u64);
        for (i, req) in out.admitted.iter().enumerate() {
            let id = i as u32;
            let done = sink
                .arrivals
                .get(&id)
                .and_then(|&(pkts, at)| (pkts == req.m).then_some(at));
            if let Some(d) = done {
                completed += 1;
                completed_tokens += req.m as u64;
                latencies.push(d - req.arrival);
                last_done = last_done.max(d);
            }
            // TTFT: the first output row reaching the tenant's sink
            if let Some(&f) = sink.first.get(&id) {
                ttfts.push(f.saturating_sub(req.arrival));
            }
            window_pairs.push((req.arrival, done.map(|d| d - req.arrival)));
        }
        let t_first = out.admitted.first().map_or(0, |r| r.arrival);
        if let Some(r) = out.admitted.first() {
            first_arrival = first_arrival.min(r.arrival);
        }
        last_done_all = last_done_all.max(last_done);
        let makespan_cycles = last_done.saturating_sub(t_first);
        let latency =
            LatencySummary::from_unsorted(latencies.clone()).unwrap_or_else(LatencySummary::empty);
        // the contract is met when every admitted request completed AND
        // the measured p99 landed inside the tenant's budget
        let slo_met =
            completed == out.admitted.len() as u64 && latency.p99 <= spec.slo_budget_cycles();
        completed_all += completed as usize;
        completed_tokens_all += completed_tokens;
        total_tokens_all += traffic::total_tokens(&out.admitted);
        tenant_reports.push(TenantReport {
            name: spec.name.clone(),
            class: spec.class.name().to_string(),
            encoders: spec.encoders,
            offered: out.offered(),
            admitted: out.admitted.len() as u64,
            rejected_slo: out.rejected_slo,
            rejected_kv: out.rejected_kv,
            completed,
            completed_tokens,
            slo_p99_us: spec.slo_p99_us,
            slo_met,
            makespan_cycles,
            latency,
            ttft: LatencySummary::from_unsorted(ttfts).unwrap_or_else(LatencySummary::empty),
            latencies: latencies.clone(),
        });
        all_latencies.extend(latencies);
    }
    let admitted_total: usize = outcomes.iter().map(|o| o.admitted.len()).sum();
    let makespan_cycles = if first_arrival == u64::MAX {
        0
    } else {
        last_done_all.saturating_sub(first_arrival)
    };

    // §6 fault section: same shape as the single-tenant path, but the
    // incomplete count spans every tenant's admitted schedule
    let fault = match (tb.recovery, tb.sim.failure_report()) {
        (Some(pr), Some(fr)) => {
            let window: Vec<u64> = window_pairs
                .iter()
                .filter(|(arr, _)| (fr.fail_cycle..fr.recover_cycle).contains(arr))
                .filter_map(|&(_, lat)| lat)
                .collect();
            let gw = GlobalKernelId::new(pr.cluster, ids::GATEWAY);
            let input_buffer_bytes = tb
                .spec
                .clusters
                .iter()
                .find(|c| c.id == pr.cluster)
                .map_or(0, |c| c.input_buffer_bytes());
            Some(FaultReport {
                fpga: pr.fpga,
                cluster: pr.cluster,
                fail_cycle: fr.fail_cycle,
                recover_cycle: fr.recover_cycle,
                reconfig_cycles: pr.reconfig_cycles,
                moved_kernels: pr.moved_kernels,
                degraded_placement: pr.degraded,
                recovered: fr.recovered,
                input_buffer_bytes,
                input_buffer_peak: tb.sim.fifo_of(gw).map_or(0.0, |f| f.peak_fraction()),
                held_packets: fr.held_packets,
                lost_events: fr.lost_events,
                incomplete_requests: admitted_total - completed_all,
                recovery_window: LatencySummary::from_unsorted(window),
            })
        }
        _ => None,
    };

    // per-stage activity, one entry per cluster across ALL chains (the
    // `encoder` field is the global cluster id)
    let total_clusters: usize = specs.iter().map(|t| t.encoders).sum();
    let mut stages = Vec::with_capacity(total_clusters);
    for e in 0..total_clusters {
        let gw = GlobalKernelId::new(e as u8, ids::GATEWAY);
        let out = GlobalKernelId::new(e as u8, ids::LN2);
        let first_rx = tb.sim.trace.kernel(gw).and_then(|s| s.first_rx).unwrap_or(0);
        let last_tx = tb.sim.trace.kernel(out).and_then(|s| s.last_tx).unwrap_or(first_rx);
        let rows_in = tb.sim.trace.kernel(gw).map_or(0, |s| s.rx_packets);
        let (mut peak, mut overflows) = (0.0f64, 0u64);
        for k in 0..KERNELS_PER_ENCODER as u8 {
            if let Some(f) = tb.sim.fifo_of(GlobalKernelId::new(e as u8, k)) {
                peak = peak.max(f.peak_fraction());
                overflows += f.overflows;
            }
        }
        let span = last_tx.saturating_sub(first_rx) as f64;
        let occupancy = (span / makespan_cycles.max(1) as f64).min(1.0);
        stages.push(StageReport {
            encoder: e,
            occupancy,
            fifo_peak: peak,
            fifo_overflows: overflows,
            rows_in,
        });
    }

    let fairness = FairnessReport::from_tenants(&tenant_reports);
    Ok(ServingReport {
        encoders: total_clusters,
        workload: specs.iter().map(|t| t.lengths.name()).collect::<Vec<_>>().join("+"),
        process: specs.iter().map(|t| t.process.name()).collect::<Vec<_>>().join("+"),
        offered_seqs_per_s: specs.iter().map(|t| t.process.seqs_per_s()).sum(),
        seed: cfg.seed,
        requests: admitted_total,
        completed: completed_all,
        total_tokens: total_tokens_all,
        completed_tokens: completed_tokens_all,
        makespan_cycles,
        latency: LatencySummary::from_unsorted(all_latencies.clone())
            .unwrap_or_else(LatencySummary::empty),
        latencies: all_latencies,
        stages,
        eq1: None,
        dropped: tb.sim.fabric.stats.dropped,
        retransmits: tb.sim.fabric.stats.retransmits,
        fault,
        events: tb.sim.trace.events_processed,
        telemetry: None,
        sim_profile: None,
        decode: None,
        batching: None,
        tenants: Some(tenant_reports),
        fairness: Some(fairness),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenants() -> TenantsConfig {
        TenantsConfig {
            interval: 12,
            fpgas_per_switch: 6,
            tenants: vec![
                TenantSpec {
                    name: "chat".into(),
                    encoders: 2,
                    class: TenantClass::Guaranteed,
                    slo_p99_us: 900.0,
                    kv_slots: 8,
                    requests: 8,
                    process: ArrivalProcess::Poisson { seqs_per_s: 2_000.0 },
                    lengths: LengthDist::Glue,
                    max_m: 128,
                },
                TenantSpec {
                    name: "batch".into(),
                    encoders: 1,
                    class: TenantClass::BestEffort,
                    slo_p99_us: 2_000.0,
                    kv_slots: 16,
                    requests: 6,
                    process: ArrivalProcess::Uniform { seqs_per_s: 4_000.0 },
                    lengths: LengthDist::Mrpc,
                    max_m: 64,
                },
            ],
        }
    }

    #[test]
    fn two_tenant_serving_reports_v6() {
        let cfg = MultiTenantConfig::new(two_tenants(), 11);
        let r = run_multi_tenant_serving(&cfg).unwrap();
        assert_eq!(r.schema(), "serving_report/v6");
        validate_serving_report(&r.to_json()).unwrap();
        let ts = r.tenants.as_ref().unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!((ts[0].name.as_str(), ts[1].name.as_str()), ("chat", "batch"));
        assert_eq!((ts[0].class.as_str(), ts[1].class.as_str()), ("guaranteed", "best-effort"));
        for t in ts {
            assert_eq!(t.offered, t.admitted + t.rejected_slo + t.rejected_kv);
            assert_eq!(t.completed, t.admitted, "light load: everything admitted completes");
            assert_eq!(t.latencies.len() as u64, t.completed);
            // the first output row lands strictly before the last one
            assert!(t.ttft.p50 > 0 && t.ttft.p50 <= t.latency.p50);
            assert!(t.makespan_cycles > 0 && t.seqs_per_s() > 0.0);
        }
        // aggregate view is the per-tenant view summed
        assert_eq!(r.requests as u64, ts.iter().map(|t| t.admitted).sum::<u64>());
        assert_eq!(r.completed as u64, ts.iter().map(|t| t.completed).sum::<u64>());
        assert_eq!(r.encoders, 3);
        assert_eq!(r.stages.len(), 3);
        assert_eq!((r.workload.as_str(), r.process.as_str()), ("glue+mrpc", "poisson+uniform"));
        // every chain saw exactly its own tenant's rows
        assert_eq!(r.stages[0].rows_in, ts[0].completed_tokens);
        assert_eq!(r.stages[1].rows_in, ts[0].completed_tokens);
        assert_eq!(r.stages[2].rows_in, ts[1].completed_tokens);
        let f = r.fairness.as_ref().unwrap();
        assert!((f.jain_index - 1.0).abs() < 1e-9, "both tenants fully served");
    }

    #[test]
    fn multi_tenant_reports_are_thread_and_shard_invariant() {
        let mut cfg = MultiTenantConfig::new(two_tenants(), 23);
        cfg.threads = Some(1);
        let a = run_multi_tenant_serving(&cfg).unwrap();
        for g in [crate::sim::ShardGranularity::PerCluster, crate::sim::ShardGranularity::PerFpga]
        {
            cfg.threads = Some(8);
            cfg.granularity = Some(g);
            let b = run_multi_tenant_serving(&cfg).unwrap();
            assert_eq!(a.to_json().pretty(), b.to_json().pretty(), "{g:?}");
        }
    }

    #[test]
    fn adding_a_tenant_never_shifts_a_sibling_schedule() {
        // seed streams are per-tenant-index: tenant 0's offered traffic
        // and admission outcome are identical whether or not tenant 1
        // exists. (Measured latencies may legitimately differ — the
        // roster changes the fleet topology and the shared ingress NIC —
        // but WHAT tenant 0 asked for and was granted never moves.)
        let solo = {
            let mut c = two_tenants();
            c.tenants.truncate(1);
            run_multi_tenant_serving(&MultiTenantConfig::new(c, 31)).unwrap()
        };
        let duo = run_multi_tenant_serving(&MultiTenantConfig::new(two_tenants(), 31)).unwrap();
        let a = &solo.tenants.as_ref().unwrap()[0];
        let b = &duo.tenants.as_ref().unwrap()[0];
        assert_eq!(
            (a.offered, a.admitted, a.rejected_slo, a.rejected_kv),
            (b.offered, b.admitted, b.rejected_slo, b.rejected_kv)
        );
        assert_eq!(a.completed, a.admitted);
        assert_eq!(b.completed, b.admitted);
    }

    #[test]
    fn glue_serving_completes_every_request() {
        let mut cfg = ServeConfig::glue(2, 12, 2_000.0, 3);
        cfg.check_eq1 = true;
        let r = run_serving(&cfg).unwrap();
        assert_eq!(r.completed, 12);
        assert_eq!(r.latencies.len(), 12);
        assert_eq!(r.stages.len(), 2);
        assert!(r.latency.p50 > 0 && r.latency.p99 >= r.latency.p50);
        assert!(r.seqs_per_s() > 0.0 && r.tokens_per_s() > r.seqs_per_s());
        // both stages saw every row of every request (one row per token)
        let rows = r.total_tokens;
        assert_eq!(r.stages[0].rows_in, rows);
        assert_eq!(r.stages[1].rows_in, rows);
        let e = r.eq1.unwrap();
        assert!(e.rel_err().abs() < 0.05, "Eq. 1 off by {:+.2}%", 100.0 * e.rel_err());
    }

    #[test]
    fn capacity_probe_is_positive_and_finite() {
        let cfg = ServeConfig::glue(1, 1, 1000.0, 1);
        let cap = pipeline_capacity_seqs_per_s(&cfg, 38).unwrap();
        assert!(cap > 100.0 && cap < 1e7, "capacity {cap} seqs/s");
    }

    #[test]
    fn zero_requests_yield_an_empty_report_gracefully() {
        // tiny duration x low rate can legitimately produce no traffic;
        // the serving path must report an empty run, not panic or error
        let mut cfg = ServeConfig::glue(1, 1, 1000.0, 1);
        cfg.traffic.requests = 0;
        let r = run_serving(&cfg).unwrap();
        assert_eq!((r.requests, r.completed, r.makespan_cycles), (0, 0, 0));
        assert_eq!(r.latency, LatencySummary::empty());
        assert!(r.latencies.is_empty());
        assert_eq!(r.seqs_per_s(), 0.0, "no infinite rate from an empty makespan");
        r.to_json(); // serializes without panicking
    }

    #[test]
    fn single_request_rates_are_finite() {
        // the makespan of a one-request run is its own service time; the
        // measured rates must come out finite and positive
        let r = run_serving(&ServeConfig::glue(1, 1, 1000.0, 1)).unwrap();
        assert_eq!((r.requests, r.completed), (1, 1));
        assert!(r.makespan_cycles > 0);
        assert!(r.seqs_per_s().is_finite() && r.seqs_per_s() > 0.0);
        assert!(r.tokens_per_s().is_finite());
        assert!(r.mean_inflight().is_finite());
    }

    #[test]
    fn telemetry_run_yields_artifacts_and_a_v3_report() {
        let mut cfg = ServeConfig::glue(2, 6, 2_000.0, 3);
        cfg.obs.enabled = true;
        cfg.obs.profile = true;
        let (r, obs) = run_serving_with_obs(&cfg).unwrap();
        assert_eq!(r.completed, 6);
        assert_eq!(r.schema(), "serving_report/v3");
        let j = r.to_json();
        validate_serving_report(&j).unwrap();
        assert_eq!(
            j.path("telemetry.requests_attributed").unwrap().as_i64().unwrap(),
            6,
            "every completed request is attributed"
        );
        // the attributed total is exactly the sum of reported latencies
        let total = j.path("telemetry.attribution.totals_cycles.total").unwrap().as_f64().unwrap();
        assert_eq!(total as u64, r.latencies.iter().sum::<u64>());
        // clean run: no retransmit or outage cycles to attribute
        for k in ["retransmit", "outage"] {
            let v = j.path(&format!("telemetry.attribution.totals_cycles.{k}")).unwrap();
            assert_eq!(v.as_f64().unwrap(), 0.0, "{k} must be zero on a clean run");
        }
        assert!(j.path("telemetry.wakes.total").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.path("sim_profile.events").unwrap().as_f64().unwrap() > 0.0);
        // the Chrome trace parses and carries request + stage spans
        let trace = obs.trace_json.unwrap();
        let doc = crate::util::json::Json::parse(&trace).unwrap();
        assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().len() > 6);
        assert!(trace.contains("\"id\":\"r0\"") && trace.contains("encoder1"));
        // the metrics stream parses line by line
        let metrics = obs.metrics_jsonl.unwrap();
        assert!(metrics.lines().next().unwrap().contains("\"schema\":\"obs_metrics/v1\""));
        for l in metrics.lines() {
            assert!(crate::util::json::Json::parse(l).is_ok(), "{l}");
        }

        // telemetry off: same scenario reports exactly v2, no artifacts
        cfg.obs = Default::default();
        let (r2, obs2) = run_serving_with_obs(&cfg).unwrap();
        assert_eq!(r2.schema(), "serving_report/v2");
        assert!(obs2.trace_json.is_none() && obs2.metrics_jsonl.is_none());
    }

    #[test]
    fn decode_serving_reports_v4_with_ttft_and_itl() {
        let mut cfg = ServeConfig::glue(2, 6, 2_000.0, 7);
        cfg.decode = Some(traffic::DecodeConfig { max_new_tokens: 3 });
        let r = run_serving(&cfg).unwrap();
        assert_eq!(r.completed, 6, "every request finishes prefill + 3 token passes");
        assert_eq!(r.schema(), "serving_report/v4");
        validate_serving_report(&r.to_json()).unwrap();
        assert_eq!(r.completed_tokens, r.total_tokens + 18, "prompt tokens + generated");
        let d = r.decode.as_ref().unwrap();
        assert_eq!((d.max_new_tokens, d.generated_tokens), (3, 18));
        assert_eq!(d.kv_occupancy.len(), 6);
        assert!(d.kv_occupancy.iter().all(|&o| o > 0.0 && o <= 1.0));
        assert!(d.ttft.p50 > 0 && d.itl.p50 > 0);
        // prefill completes strictly before the request does, pointwise,
        // so every TTFT percentile sits at or below the latency one
        assert!(d.ttft.p50 <= r.latency.p50 && d.ttft.p99 <= r.latency.p99);
    }

    #[test]
    fn decode_reports_are_thread_invariant() {
        let mut cfg = ServeConfig::glue(2, 5, 2_000.0, 13);
        cfg.decode = Some(traffic::DecodeConfig { max_new_tokens: 2 });
        cfg.threads = Some(1);
        let a = run_serving(&cfg).unwrap();
        cfg.threads = Some(8);
        let b = run_serving(&cfg).unwrap();
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    }

    #[test]
    fn zero_max_new_tokens_is_pure_prefill() {
        let mut cfg = ServeConfig::glue(2, 5, 2_000.0, 11);
        cfg.decode = Some(traffic::DecodeConfig { max_new_tokens: 0 });
        let r = run_serving(&cfg).unwrap();
        assert_eq!(r.completed, 5);
        assert_eq!(r.completed_tokens, r.total_tokens, "nothing generated");
        let d = r.decode.as_ref().unwrap();
        assert_eq!(d.generated_tokens, 0);
        assert_eq!(d.itl, LatencySummary::empty());
        // with no token passes, prefill IS the request: TTFT == latency
        assert_eq!(d.ttft, r.latency);
        assert_eq!(r.schema(), "serving_report/v4");
        validate_serving_report(&r.to_json()).unwrap();
    }

    #[test]
    fn zero_request_decode_yields_an_empty_v4_report() {
        let mut cfg = ServeConfig::glue(1, 1, 1_000.0, 1);
        cfg.traffic.requests = 0;
        cfg.decode = Some(traffic::DecodeConfig { max_new_tokens: 4 });
        let r = run_serving(&cfg).unwrap();
        assert_eq!((r.requests, r.completed), (0, 0));
        let d = r.decode.as_ref().unwrap();
        assert_eq!(d.generated_tokens, 0);
        assert!(d.kv_occupancy.is_empty());
        assert_eq!(d.ttft, LatencySummary::empty());
        assert_eq!(r.schema(), "serving_report/v4");
        validate_serving_report(&r.to_json()).unwrap();
    }

    #[test]
    fn oversized_prompt_plus_decode_overflows_loudly() {
        // an explicit schedule at the build point's max_seq must be
        // rejected with a clear KV-overflow error ...
        let mut cfg = ServeConfig::glue(1, 1, 1_000.0, 1);
        cfg.decode = Some(traffic::DecodeConfig { max_new_tokens: 4 });
        let tb_cfg = cfg.testbed_config(Arc::new(vec![Request { arrival: 0, m: 128 }]));
        let err = build_testbed(&tb_cfg).unwrap_err().to_string();
        assert!(err.contains("KV-cache overflow"), "{err}");
        // ... while the serving entry point clamps generated prompts
        // below the cap, so the same scenario runs to completion
        let r = run_serving(&cfg).unwrap();
        assert_eq!(r.completed, 1);
        assert!(r.decode.unwrap().kv_occupancy[0] <= 1.0);
    }

    #[test]
    fn functional_decode_matches_the_native_incremental_reference() {
        use crate::ibert::config::ModelConfig;
        use crate::ibert::encoder::decode_generate;
        use crate::ibert::weights::{synthetic_input, ModelParams};
        let cfg_m = ModelConfig { hidden: 96, heads: 12, ffn: 192, max_seq: 32, num_encoders: 2 };
        let p = Arc::new(ModelParams::synthetic(cfg_m, 0xFEED));
        let (prompt_m, max_new) = (5usize, 3usize);
        let input = Arc::new(synthetic_input(cfg_m.hidden, prompt_m, 21));
        let tb_cfg = TestbedConfig {
            encoders: 2,
            m: prompt_m,
            inferences: 1,
            interval: 12,
            pe: PeConfig::default(),
            mode: Mode::Functional(p.clone()),
            fpgas_per_switch: 6,
            input: Some(input.clone()),
            placement: None,
            schedule: Some(Arc::new(vec![Request { arrival: 0, m: prompt_m as u32 }])),
            decode: Some(traffic::DecodeConfig { max_new_tokens: max_new as u32 }),
            threads: Some(1),
            granularity: None,
            net: Default::default(),
            fail: None,
            obs: Default::default(),
            batching: None,
        };
        let mut tb = build_testbed(&tb_cfg).unwrap();
        tb.sim.start();
        tb.sim.run().unwrap();
        let sink = tb.sink.lock().unwrap();
        // the simulated pipeline's passes must be bit-identical to the
        // native incremental decoder (itself golden-tested against full
        // recompute): pass 0 = prefill matrix, pass 1+s = token row s
        let (pre, toks) = decode_generate(&p, &input, 2, max_new);
        assert_eq!(sink.matrix(0).unwrap(), pre, "prefill pass mismatch");
        assert_eq!(toks.len(), max_new);
        for (s, tok) in toks.iter().enumerate() {
            let got = sink.matrix(1 + s as u32).unwrap();
            assert_eq!(got.len(), 1, "token pass {} must be a single row", s + 1);
            assert_eq!(&got[0], tok, "token pass {} mismatch", s + 1);
        }
    }

    #[test]
    fn batch1_decode_serving_is_byte_identical_to_v4() {
        // `--batch-max 1` must normalize to the legacy one-pass-at-a-time
        // path: same kernels, same costs, byte-identical v4 report
        let mut cfg = ServeConfig::glue(2, 6, 2_000.0, 7);
        cfg.decode = Some(traffic::DecodeConfig { max_new_tokens: 3 });
        let v4 = run_serving(&cfg).unwrap();
        cfg.batching = Some(traffic::BatchConfig { max: 1, window: 4096 });
        let r = run_serving(&cfg).unwrap();
        assert_eq!(r.schema(), "serving_report/v4", "disabled batching keeps the v4 schema");
        assert_eq!(r.to_json().pretty(), v4.to_json().pretty());
    }

    #[test]
    fn batched_serving_reports_v5_and_conserves_work() {
        let mut cfg = ServeConfig::glue(1, 8, 40_000.0, 9);
        cfg.decode = Some(traffic::DecodeConfig { max_new_tokens: 6 });
        cfg.batching = Some(traffic::BatchConfig { max: 4, window: 512 });
        let r = run_serving(&cfg).unwrap();
        assert_eq!(r.completed, 8, "batching must not lose requests");
        assert_eq!(r.schema(), "serving_report/v5");
        validate_serving_report(&r.to_json()).unwrap();
        let d = r.decode.as_ref().unwrap();
        assert_eq!(d.generated_tokens, 48);
        let b = r.batching.as_ref().unwrap();
        assert_eq!((b.batch_max, b.batch_window), (4, 512));
        assert_eq!(b.histogram.len(), 4);
        assert_eq!(b.histogram.iter().sum::<u64>(), b.batches);
        // every generated token rode in exactly one released batch
        let token_rows: u64 =
            b.histogram.iter().enumerate().map(|(i, &n)| (i as u64 + 1) * n).sum();
        assert_eq!(token_rows, d.generated_tokens);
        assert!(b.peak_active >= 1 && b.peak_active <= 4, "admission respects the slot cap");
        assert!(b.mean_batch_size() >= 1.0);
        // grouped percentiles: ascending sizes, all within the cap
        let sizes: Vec<u32> = b.ttft_by_size.iter().map(|&(s, _)| s).collect();
        assert!(!sizes.is_empty() && sizes.windows(2).all(|w| w[0] < w[1]));
        for &(s, _) in b.ttft_by_size.iter().chain(&b.itl_by_size) {
            assert!((1..=4).contains(&s));
        }
    }

    #[test]
    fn batched_reports_are_thread_and_granularity_invariant() {
        let mut cfg = ServeConfig::glue(2, 6, 20_000.0, 17);
        cfg.decode = Some(traffic::DecodeConfig { max_new_tokens: 4 });
        cfg.batching = Some(traffic::BatchConfig { max: 4, window: 256 });
        cfg.threads = Some(1);
        let a = run_serving(&cfg).unwrap();
        assert_eq!(a.schema(), "serving_report/v5");
        for g in [crate::sim::ShardGranularity::PerCluster, crate::sim::ShardGranularity::PerFpga]
        {
            cfg.threads = Some(8);
            cfg.granularity = Some(g);
            let b = run_serving(&cfg).unwrap();
            assert_eq!(a.to_json().pretty(), b.to_json().pretty(), "{g:?}");
        }
    }

    #[test]
    fn batching_without_decode_is_rejected() {
        let mut cfg = ServeConfig::glue(1, 2, 2_000.0, 1);
        cfg.batching = Some(traffic::BatchConfig { max: 4, window: 64 });
        let err = run_serving(&cfg).unwrap_err().to_string();
        assert!(err.contains("needs decode"), "{err}");
    }

    #[test]
    fn functional_batched_decode_matches_independent_passes() {
        use crate::ibert::config::ModelConfig;
        use crate::ibert::encoder::decode_generate;
        use crate::ibert::weights::{synthetic_input, ModelParams};
        let cfg_m = ModelConfig { hidden: 96, heads: 12, ffn: 192, max_seq: 32, num_encoders: 2 };
        let p = Arc::new(ModelParams::synthetic(cfg_m, 0xFEED));
        let (prompt_m, max_new) = (4usize, 3usize);
        let input = Arc::new(synthetic_input(cfg_m.hidden, prompt_m, 33));
        let reqs = 3u32;
        let block = 1 + max_new as u32;
        let tb_cfg = TestbedConfig {
            encoders: 2,
            m: prompt_m,
            inferences: reqs,
            interval: 12,
            pe: PeConfig::default(),
            mode: Mode::Functional(p.clone()),
            fpgas_per_switch: 6,
            input: Some(input.clone()),
            placement: None,
            schedule: Some(Arc::new(
                (0..reqs)
                    .map(|i| Request { arrival: i as u64 * 40, m: prompt_m as u32 })
                    .collect(),
            )),
            decode: Some(traffic::DecodeConfig { max_new_tokens: max_new as u32 }),
            batching: Some(traffic::BatchConfig { max: reqs, window: 20_000 }),
            threads: Some(1),
            granularity: None,
            net: Default::default(),
            fail: None,
            obs: Default::default(),
        };
        let mut tb = build_testbed(&tb_cfg).unwrap();
        tb.sim.start();
        tb.sim.run().unwrap();
        let sink = tb.sink.lock().unwrap();
        // batching changes WHEN token passes run, never WHAT they
        // compute: every request's passes stay bit-identical to the
        // native incremental decoder run for that request alone
        let (pre, toks) = decode_generate(&p, &input, 2, max_new);
        for r in 0..reqs {
            let base = r * block;
            assert_eq!(sink.matrix(base).unwrap(), pre, "request {r} prefill mismatch");
            for (s, tok) in toks.iter().enumerate() {
                let got = sink.matrix(base + 1 + s as u32).unwrap();
                assert_eq!(got.len(), 1, "token pass must be a single row");
                assert_eq!(&got[0], tok, "request {r} token pass {} mismatch", s + 1);
            }
        }
        // and the assembler really grouped rows from different requests
        let log = tb.batch_log.as_ref().unwrap().lock().unwrap();
        assert!(log.releases.iter().any(|&(_, sz)| sz >= 2), "{:?}", log.releases);
    }

    #[test]
    fn lossy_reliable_serving_completes_every_request() {
        let mut cfg = ServeConfig::glue(2, 10, 2_000.0, 5);
        cfg.drop_probability = 0.02;
        cfg.reliable = true;
        let r = run_serving(&cfg).unwrap();
        assert_eq!(r.completed, 10, "reliable transport must complete every inference");
        assert!(r.dropped > 0, "2% loss over thousands of packets must drop some");
        assert_eq!(r.dropped, r.retransmits, "every lost copy was retransmitted");
        assert!(r.fault.is_none());
    }
}
