//! Multi-tenant serving: tenant specifications, traffic-class SLOs,
//! and deterministic admission control.
//!
//! A *tenant* is one model deployment sharing the fleet with others: a
//! chain depth (its model's encoder count), a build point (`max_m`), a
//! traffic class with a p99 latency target, a KV-slot budget bounding
//! its concurrent in-flight sequences, and its own open-loop arrival
//! process. The placer packs each tenant's kernel graph onto a disjoint
//! contiguous slot range ([`crate::placer::multi`]); this module owns
//! everything upstream of the simulator — parsing `--tenants` config
//! files, deriving per-tenant schedules from independent seed streams,
//! and deciding *before* the run which requests are admitted.
//!
//! Admission is a pure function of the schedule, evaluated against a
//! conservative source-link model (a request occupies its tenant's
//! ingress for `m * interval` cycles). Running it pre-simulation keeps
//! the decision identical across `--threads` and `--shards` cuts for
//! free: no simulator state feeds back into it, so thread-count can't
//! reorder accept/reject outcomes.

use std::collections::VecDeque;

use anyhow::{bail, ensure, Context, Result};

use super::traffic::{stream_seed, ArrivalProcess, LengthDist, Request, TrafficConfig};
use crate::util::json::Json;
use crate::FABRIC_CLOCK_HZ;

/// Traffic class: what happens to a tenant's requests under pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantClass {
    /// Queue rather than drop when the SLO budget is exhausted; only a
    /// full KV-slot backlog rejects (capacity, not latency, is the
    /// contract).
    Guaranteed,
    /// Shed load early: reject any request whose *predicted* queueing
    /// wait already exceeds the p99 budget, so admitted best-effort
    /// traffic cannot build an unbounded queue behind a burst.
    BestEffort,
}

impl TenantClass {
    pub fn from_name(s: &str) -> Result<TenantClass> {
        match s {
            "guaranteed" => Ok(TenantClass::Guaranteed),
            "best-effort" => Ok(TenantClass::BestEffort),
            _ => bail!("unknown tenant class {s:?} (expected guaranteed|best-effort)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TenantClass::Guaranteed => "guaranteed",
            TenantClass::BestEffort => "best-effort",
        }
    }
}

/// One tenant's deployment contract: model depth, build point, traffic
/// class + SLO, KV budget, and its open-loop arrival process.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Encoder-chain depth of this tenant's model.
    pub encoders: usize,
    pub class: TenantClass,
    /// p99 latency target in microseconds; the admission budget.
    pub slo_p99_us: f64,
    /// Maximum concurrent in-flight sequences (backlog depth cap).
    pub kv_slots: usize,
    /// Requests in this tenant's trace.
    pub requests: usize,
    pub process: ArrivalProcess,
    pub lengths: LengthDist,
    /// Hardware build point: sampled lengths clamp here.
    pub max_m: usize,
}

impl TenantSpec {
    /// SLO budget in fabric cycles.
    pub fn slo_budget_cycles(&self) -> u64 {
        (self.slo_p99_us * 1e-6 * FABRIC_CLOCK_HZ as f64).round() as u64
    }

    /// This tenant's schedule, drawn from its own derived seed stream
    /// (`stream_seed`) so sibling tenants never share or shift draws.
    pub fn schedule(&self, base_seed: u64, index: usize) -> Vec<Request> {
        TrafficConfig {
            process: self.process,
            lengths: self.lengths,
            requests: self.requests,
            seed: stream_seed(base_seed, index as u64),
            max_m: self.max_m,
        }
        .generate()
    }

    /// Deterministic pre-simulation admission control over a schedule.
    ///
    /// The source-link model: request `r` occupies the tenant's ingress
    /// for `r.m * interval` cycles starting no earlier than its arrival
    /// and no earlier than the previous admitted request's finish. A
    /// request is rejected when the tenant's backlog has consumed every
    /// KV slot (both classes — there is physically nowhere to put it),
    /// or, for best-effort tenants only, when its predicted wait
    /// already exceeds the p99 budget.
    pub fn admit(&self, schedule: &[Request], interval: u64) -> AdmissionOutcome {
        let budget = self.slo_budget_cycles();
        let mut busy_until = 0u64;
        // finish cycles of admitted requests still holding a KV slot
        let mut backlog: VecDeque<u64> = VecDeque::new();
        let mut out = AdmissionOutcome::default();
        for r in schedule {
            while let Some(&finish) = backlog.front() {
                if finish <= r.arrival {
                    backlog.pop_front();
                } else {
                    break;
                }
            }
            if backlog.len() >= self.kv_slots {
                out.rejected_kv += 1;
                continue;
            }
            let wait = busy_until.saturating_sub(r.arrival);
            if self.class == TenantClass::BestEffort && wait > budget {
                out.rejected_slo += 1;
                continue;
            }
            let start = r.arrival.max(busy_until);
            busy_until = start + r.m as u64 * interval;
            backlog.push_back(busy_until);
            out.admitted.push(*r);
        }
        out
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "tenant name must be non-empty");
        ensure!(self.encoders >= 1, "tenant {:?}: encoders must be >= 1", self.name);
        ensure!(
            self.slo_p99_us > 0.0,
            "tenant {:?}: slo_p99_us must be positive",
            self.name
        );
        ensure!(self.kv_slots >= 1, "tenant {:?}: kv_slots must be >= 1", self.name);
        ensure!(self.max_m >= 1, "tenant {:?}: max_m must be >= 1", self.name);
        ensure!(
            self.process.seqs_per_s() > 0.0,
            "tenant {:?}: arrival rate must be positive",
            self.name
        );
        Ok(())
    }
}

/// Admission decision for one tenant's schedule: the surviving
/// requests (original arrival cycles — admission shapes, it does not
/// re-time) plus per-reason reject counts.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AdmissionOutcome {
    pub admitted: Vec<Request>,
    /// Best-effort rejects: predicted wait exceeded the p99 budget.
    pub rejected_slo: u64,
    /// Capacity rejects: every KV slot held by the backlog.
    pub rejected_kv: u64,
}

impl AdmissionOutcome {
    pub fn offered(&self) -> u64 {
        self.admitted.len() as u64 + self.rejected_slo + self.rejected_kv
    }
}

/// Parsed `--tenants` configuration: the shared fabric settings plus
/// one [`TenantSpec`] per entry.
#[derive(Debug, Clone)]
pub struct TenantsConfig {
    /// Source row interval in cycles (shared fabric setting).
    pub interval: u64,
    pub fpgas_per_switch: usize,
    pub tenants: Vec<TenantSpec>,
}

impl TenantsConfig {
    /// Parse a tenants config file:
    ///
    /// ```json
    /// {
    ///   "interval": 12,
    ///   "fpgas_per_switch": 6,
    ///   "tenants": [
    ///     {"name": "chat", "encoders": 3, "class": "guaranteed",
    ///      "slo_p99_us": 900.0, "kv_slots": 8, "requests": 24,
    ///      "arrivals": "poisson", "rate": 2000.0,
    ///      "workload": "glue", "max_m": 128}
    ///   ]
    /// }
    /// ```
    ///
    /// `kv_slots` (16), `arrivals` ("poisson"), `workload` ("glue") and
    /// `max_m` (128) are optional; everything else is required. Unknown
    /// keys are rejected so a typo'd SLO field cannot silently fall
    /// back to a default.
    pub fn parse(text: &str) -> Result<TenantsConfig> {
        let j = Json::parse(text).context("tenants config is not valid JSON")?;
        for k in j.keys() {
            ensure!(
                matches!(k, "interval" | "fpgas_per_switch" | "tenants"),
                "tenants config: unknown top-level key {k:?}"
            );
        }
        let interval = match j.get("interval") {
            Some(v) => v.as_i64().context("interval must be an integer")? as u64,
            None => 12,
        };
        ensure!(interval >= 1, "interval must be >= 1");
        let fpgas_per_switch = match j.get("fpgas_per_switch") {
            Some(v) => v.as_i64().context("fpgas_per_switch must be an integer")? as usize,
            None => 6,
        };
        ensure!(fpgas_per_switch >= 1, "fpgas_per_switch must be >= 1");
        let list = j
            .get("tenants")
            .and_then(|v| v.as_arr())
            .context("tenants config needs a \"tenants\" array")?;
        let mut tenants = Vec::with_capacity(list.len());
        for (i, t) in list.iter().enumerate() {
            tenants.push(parse_tenant(t).with_context(|| format!("tenants[{i}]"))?);
        }
        let cfg = TenantsConfig { interval, fpgas_per_switch, tenants };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.tenants.is_empty(), "tenants config needs at least one tenant");
        for t in &self.tenants {
            t.validate()?;
        }
        for (i, a) in self.tenants.iter().enumerate() {
            for b in &self.tenants[i + 1..] {
                ensure!(a.name != b.name, "tenant names must be unique ({:?} repeats)", a.name);
            }
        }
        Ok(())
    }

    /// Per-tenant schedules + admission outcomes, in tenant order.
    pub fn admitted_schedules(&self, base_seed: u64) -> Vec<AdmissionOutcome> {
        self.tenants
            .iter()
            .enumerate()
            .map(|(i, t)| t.admit(&t.schedule(base_seed, i), self.interval))
            .collect()
    }
}

fn parse_tenant(j: &Json) -> Result<TenantSpec> {
    for k in j.keys() {
        ensure!(
            matches!(
                k,
                "name"
                    | "encoders"
                    | "class"
                    | "slo_p99_us"
                    | "kv_slots"
                    | "requests"
                    | "arrivals"
                    | "rate"
                    | "workload"
                    | "max_m"
            ),
            "unknown tenant key {k:?}"
        );
    }
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .context("tenant needs a \"name\" string")?
        .to_string();
    let encoders = j
        .get("encoders")
        .and_then(|v| v.as_i64())
        .context("tenant needs an integer \"encoders\"")? as usize;
    let class = TenantClass::from_name(
        j.get("class").and_then(|v| v.as_str()).context("tenant needs a \"class\"")?,
    )?;
    let slo_p99_us = j
        .get("slo_p99_us")
        .and_then(|v| v.as_f64())
        .context("tenant needs a numeric \"slo_p99_us\"")?;
    let kv_slots = match j.get("kv_slots") {
        Some(v) => v.as_i64().context("kv_slots must be an integer")? as usize,
        None => 16,
    };
    let requests = j
        .get("requests")
        .and_then(|v| v.as_i64())
        .context("tenant needs an integer \"requests\"")? as usize;
    let rate = j.get("rate").and_then(|v| v.as_f64()).context("tenant needs a numeric \"rate\"")?;
    let process = match j.get("arrivals").and_then(|v| v.as_str()).unwrap_or("poisson") {
        "poisson" => ArrivalProcess::Poisson { seqs_per_s: rate },
        "uniform" => ArrivalProcess::Uniform { seqs_per_s: rate },
        other => bail!("unknown arrivals {other:?} (expected poisson|uniform)"),
    };
    let lengths =
        LengthDist::from_name(j.get("workload").and_then(|v| v.as_str()).unwrap_or("glue"))?;
    let max_m = match j.get("max_m") {
        Some(v) => v.as_i64().context("max_m must be an integer")? as usize,
        None => 128,
    };
    Ok(TenantSpec {
        name,
        encoders,
        class,
        slo_p99_us,
        kv_slots,
        requests,
        process,
        lengths,
        max_m,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(class: TenantClass, slo_p99_us: f64, kv_slots: usize) -> TenantSpec {
        TenantSpec {
            name: "t".into(),
            encoders: 3,
            class,
            slo_p99_us,
            kv_slots,
            requests: 8,
            process: ArrivalProcess::Poisson { seqs_per_s: 2_000.0 },
            lengths: LengthDist::Glue,
            max_m: 128,
        }
    }

    const CFG: &str = r#"{
      "interval": 12,
      "fpgas_per_switch": 6,
      "tenants": [
        {"name": "chat", "encoders": 3, "class": "guaranteed",
         "slo_p99_us": 900.0, "kv_slots": 8, "requests": 24,
         "arrivals": "poisson", "rate": 2000.0, "workload": "glue",
         "max_m": 128},
        {"name": "batch", "encoders": 2, "class": "best-effort",
         "slo_p99_us": 400.0, "requests": 16, "rate": 4000.0}
      ]
    }"#;

    #[test]
    fn config_parses_with_defaults() {
        let cfg = TenantsConfig::parse(CFG).unwrap();
        assert_eq!(cfg.interval, 12);
        assert_eq!(cfg.tenants.len(), 2);
        let b = &cfg.tenants[1];
        assert_eq!(b.class, TenantClass::BestEffort);
        assert_eq!(b.kv_slots, 16); // default
        assert_eq!(b.lengths, LengthDist::Glue); // default
        assert_eq!(b.max_m, 128); // default
        assert_eq!(b.process, ArrivalProcess::Poisson { seqs_per_s: 4000.0 });
    }

    #[test]
    fn config_rejects_typos_and_duplicates() {
        let typo = CFG.replace("\"slo_p99_us\": 900.0", "\"slo_p99\": 900.0");
        let err = TenantsConfig::parse(&typo).unwrap_err().to_string();
        assert!(err.contains("tenants[0]"), "{err}");
        let dup = CFG.replace("\"name\": \"batch\"", "\"name\": \"chat\"");
        let err = format!("{:#}", TenantsConfig::parse(&dup).unwrap_err());
        assert!(err.contains("unique"), "{err}");
        assert!(TenantsConfig::parse(r#"{"tenants": []}"#).is_err());
        let bad_class = CFG.replace("best-effort", "spot");
        assert!(TenantsConfig::parse(&bad_class).is_err());
    }

    #[test]
    fn slo_budget_converts_microseconds_to_cycles() {
        // 6 us at the 200 MHz fabric clock = 1200 cycles
        assert_eq!(spec(TenantClass::BestEffort, 6.0, 4).slo_budget_cycles(), 1200);
    }

    #[test]
    fn kv_exhaustion_rejects_both_classes() {
        // 3 simultaneous arrivals, 2 KV slots: third is rejected no
        // matter the class — there is nowhere to put it.
        let sched = vec![
            Request { arrival: 0, m: 100 },
            Request { arrival: 0, m: 100 },
            Request { arrival: 0, m: 100 },
        ];
        for class in [TenantClass::Guaranteed, TenantClass::BestEffort] {
            let out = spec(class, 1_000_000.0, 2).admit(&sched, 12);
            assert_eq!(out.admitted.len(), 2, "{class:?}");
            assert_eq!(out.rejected_kv, 1, "{class:?}");
            assert_eq!(out.rejected_slo, 0, "{class:?}");
            assert_eq!(out.offered(), 3);
        }
    }

    #[test]
    fn best_effort_sheds_on_slo_pressure_guaranteed_queues() {
        // Two arrivals at cycle 0; the first occupies the link for
        // 100 * 12 = 1200 cycles, so the second predicts a 1200-cycle
        // wait against a 6 us = 1200-cycle budget: admitted (not >).
        // Against a 5 us = 1000-cycle budget a best-effort tenant sheds
        // it; a guaranteed tenant queues it.
        let sched = vec![Request { arrival: 0, m: 100 }, Request { arrival: 0, m: 100 }];
        let at_budget = spec(TenantClass::BestEffort, 6.0, 8).admit(&sched, 12);
        assert_eq!(at_budget.admitted.len(), 2);
        let shed = spec(TenantClass::BestEffort, 5.0, 8).admit(&sched, 12);
        assert_eq!(shed.admitted.len(), 1);
        assert_eq!(shed.rejected_slo, 1);
        let queued = spec(TenantClass::Guaranteed, 5.0, 8).admit(&sched, 12);
        assert_eq!(queued.admitted.len(), 2);
        assert_eq!(queued.rejected_slo, 0);
    }

    #[test]
    fn backlog_drains_as_requests_finish() {
        // 1 KV slot, arrivals spaced past each service time: all admit.
        let sched = vec![
            Request { arrival: 0, m: 10 },
            Request { arrival: 120, m: 10 }, // first finishes at 120
            Request { arrival: 240, m: 10 },
        ];
        let out = spec(TenantClass::Guaranteed, 1_000_000.0, 1).admit(&sched, 12);
        assert_eq!(out.admitted.len(), 3);
        // pull one arrival earlier and the single slot is still held
        let sched2 = vec![Request { arrival: 0, m: 10 }, Request { arrival: 119, m: 10 }];
        let out2 = spec(TenantClass::Guaranteed, 1_000_000.0, 1).admit(&sched2, 12);
        assert_eq!(out2.admitted.len(), 1);
        assert_eq!(out2.rejected_kv, 1);
    }

    #[test]
    fn admission_is_deterministic_and_preserves_arrivals() {
        let cfg = TenantsConfig::parse(CFG).unwrap();
        let a = cfg.admitted_schedules(7);
        let b = cfg.admitted_schedules(7);
        assert_eq!(a, b);
        // admitted requests keep their original open-loop arrival times
        let sched = cfg.tenants[0].schedule(7, 0);
        for r in &a[0].admitted {
            assert!(sched.contains(r));
        }
        // a different base seed yields different traffic
        assert_ne!(a, cfg.admitted_schedules(8));
    }

    #[test]
    fn sibling_tenants_draw_independent_streams() {
        let cfg = TenantsConfig::parse(CFG).unwrap();
        let solo = cfg.tenants[0].schedule(7, 0);
        // tenant 0's schedule does not depend on tenant 1 existing
        let mut fewer = cfg.clone();
        fewer.tenants.truncate(1);
        assert_eq!(solo, fewer.tenants[0].schedule(7, 0));
        // and the two tenants' streams differ even with equal specs
        let mut twin = cfg.tenants[0].clone();
        twin.name = "twin".into();
        assert_ne!(twin.schedule(7, 0), twin.schedule(7, 1));
    }
}
