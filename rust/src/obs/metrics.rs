//! Fabric-side telemetry collector and the streaming-metrics exporter.
//!
//! `FabricObs` rides inside [`crate::sim::fabric::Fabric`] and charges
//! link occupancy, drops and retransmit stalls into fixed-width cycle
//! buckets as `deliver` computes them — constant memory in the number
//! of requests, linear only in simulated time / bucket width.
//!
//! [`render_metrics_jsonl`] turns the collectors into the
//! `obs_metrics/v1` JSONL stream (`--metrics-out`): one header line,
//! one line per cycle bucket with fleet-level aggregates, then one
//! summary line per kernel / FIFO / link. Every line is hand-formatted
//! with a fixed key order so the output is byte-identical across
//! `--threads` counts.

use std::collections::BTreeMap;

use crate::obs::span::{add_buckets, bump, TraceObs};
use crate::sim::fabric::FabricStats;
use crate::sim::packet::GlobalKernelId;
use crate::sim::trace::Trace;

/// Occupancy charged into the bucket containing each transfer's *start*
/// cycle (a transfer crossing a bucket boundary is not split — the
/// approximation is documented in DESIGN.md "Observability").
#[derive(Debug, Clone)]
pub struct FabricObs {
    /// Bucket width in cycles.
    pub interval: u64,
    /// Kernel-egress busy flit-cycles per bucket, fleet-wide.
    pub bucket_egress_busy: Vec<u64>,
    /// NIC busy flit-cycles per bucket, fleet-wide.
    pub bucket_nic_busy: Vec<u64>,
    /// Dropped packet copies per bucket.
    pub bucket_drops: Vec<u64>,
    /// Retransmitted copies per bucket.
    pub bucket_retx: Vec<u64>,
    /// inference -> cycles spent waiting for a busy egress/NIC link.
    pub serialize_wait: BTreeMap<u32, u64>,
    /// inference -> extra cycles added by reliable-mode retransmits.
    pub retx_stall: BTreeMap<u32, u64>,
    /// dense kernel id -> total egress busy flit-cycles.
    pub egress_busy: BTreeMap<u32, u64>,
    /// src fpga -> total NIC busy flit-cycles.
    pub nic_busy: BTreeMap<u32, u64>,
    /// Retransmit stall spans: (start, dur, src_fpga, dst_fpga).
    pub retx_spans: Vec<(u64, u64, u32, u32)>,
}

impl FabricObs {
    pub fn new(interval: u64) -> FabricObs {
        FabricObs {
            interval: interval.max(1),
            bucket_egress_busy: Vec::new(),
            bucket_nic_busy: Vec::new(),
            bucket_drops: Vec::new(),
            bucket_retx: Vec::new(),
            serialize_wait: BTreeMap::new(),
            retx_stall: BTreeMap::new(),
            egress_busy: BTreeMap::new(),
            nic_busy: BTreeMap::new(),
            retx_spans: Vec::new(),
        }
    }

    #[inline]
    fn bucket(&self, t: u64) -> usize {
        (t / self.interval) as usize
    }

    /// A kernel-egress transfer: `flits` cycles of occupancy starting
    /// at `start`, after `wait` cycles of contention for the link.
    #[inline]
    pub fn on_egress(&mut self, dense: u32, inference: u32, start: u64, flits: u64, wait: u64) {
        let b = self.bucket(start);
        bump(&mut self.bucket_egress_busy, b, flits);
        *self.egress_busy.entry(dense).or_insert(0) += flits;
        if wait > 0 {
            *self.serialize_wait.entry(inference).or_insert(0) += wait;
        }
    }

    /// A NIC transfer on `src_fpga`'s 100G port.
    #[inline]
    pub fn on_nic(&mut self, src_fpga: u32, inference: u32, start: u64, flits: u64, wait: u64) {
        let b = self.bucket(start);
        bump(&mut self.bucket_nic_busy, b, flits);
        *self.nic_busy.entry(src_fpga).or_insert(0) += flits;
        if wait > 0 {
            *self.serialize_wait.entry(inference).or_insert(0) += wait;
        }
    }

    /// One dropped packet copy at send time `t`.
    #[inline]
    pub fn on_drop(&mut self, t: u64) {
        let b = self.bucket(t);
        bump(&mut self.bucket_drops, b, 1);
    }

    /// A reliable-mode retransmit episode: `copies` resends stretching
    /// the transfer by `stall` cycles starting at `start`.
    pub fn on_retx(
        &mut self,
        inference: u32,
        start: u64,
        stall: u64,
        copies: u64,
        src_fpga: u32,
        dst_fpga: u32,
    ) {
        let b = self.bucket(start);
        bump(&mut self.bucket_retx, b, copies);
        *self.retx_stall.entry(inference).or_insert(0) += stall;
        self.retx_spans.push((start, stall, src_fpga, dst_fpga));
    }

    /// Fold a per-shard collector back in (commutative).
    pub fn merge(&mut self, o: &FabricObs) {
        debug_assert_eq!(self.interval, o.interval);
        add_buckets(&mut self.bucket_egress_busy, &o.bucket_egress_busy);
        add_buckets(&mut self.bucket_nic_busy, &o.bucket_nic_busy);
        add_buckets(&mut self.bucket_drops, &o.bucket_drops);
        add_buckets(&mut self.bucket_retx, &o.bucket_retx);
        for (k, v) in &o.serialize_wait {
            *self.serialize_wait.entry(*k).or_insert(0) += v;
        }
        for (k, v) in &o.retx_stall {
            *self.retx_stall.entry(*k).or_insert(0) += v;
        }
        for (k, v) in &o.egress_busy {
            *self.egress_busy.entry(*k).or_insert(0) += v;
        }
        for (k, v) in &o.nic_busy {
            *self.nic_busy.entry(*k).or_insert(0) += v;
        }
        self.retx_spans.extend_from_slice(&o.retx_spans);
    }

    /// Retransmit spans in deterministic order for export.
    pub fn sorted_retx_spans(&self) -> Vec<(u64, u64, u32, u32)> {
        let mut v = self.retx_spans.clone();
        v.sort_unstable();
        v
    }
}

/// Point-in-time FIFO state collected from the kernel slots after a run.
#[derive(Debug, Clone, Copy)]
pub struct FifoSnapshot {
    pub occupancy: u64,
    pub high_water: u64,
    pub capacity_bytes: u64,
    pub overflows: u64,
}

fn kid(k: GlobalKernelId) -> String {
    format!("c{}k{}", k.cluster, k.kernel)
}

fn kid_dense(dense: u32) -> String {
    format!("c{}k{}", dense >> 8, dense & 0xff)
}

/// Render the `obs_metrics/v1` JSONL stream. Deterministic: fixed key
/// order, integer cycle counts, and `busy_frac` printed at fixed
/// precision from thread-invariant inputs.
pub fn render_metrics_jsonl(
    trace: &Trace,
    tobs: &TraceObs,
    fobs: Option<&FabricObs>,
    fifos: &[(GlobalKernelId, FifoSnapshot)],
    fleet: &FabricStats,
    makespan: u64,
) -> String {
    let at = |v: &[u64], i: usize| v.get(i).copied().unwrap_or(0);
    let mut buckets = tobs
        .bucket_events
        .len()
        .max(tobs.bucket_wakes.len())
        .max(tobs.bucket_fifo_peak.len());
    if let Some(f) = fobs {
        buckets = buckets
            .max(f.bucket_egress_busy.len())
            .max(f.bucket_nic_busy.len())
            .max(f.bucket_drops.len())
            .max(f.bucket_retx.len());
    }

    let mut out = String::new();
    out.push_str(&format!(
        "{{\"type\":\"header\",\"schema\":\"obs_metrics/v1\",\"interval_cycles\":{},\"makespan_cycles\":{},\"buckets\":{}}}\n",
        tobs.interval, makespan, buckets
    ));

    for b in 0..buckets {
        let (eb, nb, dr, rx) = match fobs {
            Some(f) => (
                at(&f.bucket_egress_busy, b),
                at(&f.bucket_nic_busy, b),
                at(&f.bucket_drops, b),
                at(&f.bucket_retx, b),
            ),
            None => (0, 0, 0, 0),
        };
        out.push_str(&format!(
            "{{\"type\":\"bucket\",\"start_cycle\":{},\"events\":{},\"wakes\":{},\"fifo_peak_bytes\":{},\"egress_busy_flit_cycles\":{},\"nic_busy_flit_cycles\":{},\"drops\":{},\"retransmits\":{}}}\n",
            b as u64 * tobs.interval,
            at(&tobs.bucket_events, b),
            at(&tobs.bucket_wakes, b),
            at(&tobs.bucket_fifo_peak, b),
            eb,
            nb,
            dr,
            rx
        ));
    }

    // Per-kernel activity, in (deterministic) registration order.
    for (id, st) in trace.kernels() {
        let lo = [st.first_rx, st.first_tx].iter().flatten().min().copied();
        let hi = [st.last_rx, st.last_tx].iter().flatten().max().copied();
        let busy_frac = match (lo, hi) {
            (Some(a), Some(z)) if makespan > 0 => (z - a) as f64 / makespan as f64,
            _ => 0.0,
        };
        out.push_str(&format!(
            "{{\"type\":\"kernel\",\"id\":\"{}\",\"rx_packets\":{},\"tx_packets\":{},\"wakes\":{},\"busy_frac\":{:.6}}}\n",
            kid(id),
            st.rx_packets,
            st.tx_packets,
            st.wakes,
            busy_frac
        ));
    }

    for (id, f) in fifos {
        out.push_str(&format!(
            "{{\"type\":\"fifo\",\"id\":\"{}\",\"high_water_bytes\":{},\"capacity_bytes\":{},\"overflows\":{}}}\n",
            kid(*id),
            f.high_water,
            f.capacity_bytes,
            f.overflows
        ));
    }

    if let Some(f) = fobs {
        for (dense, busy) in &f.egress_busy {
            out.push_str(&format!(
                "{{\"type\":\"link\",\"kind\":\"kernel_egress\",\"id\":\"{}\",\"busy_flit_cycles\":{}}}\n",
                kid_dense(*dense),
                busy
            ));
        }
        for (fpga, busy) in &f.nic_busy {
            out.push_str(&format!(
                "{{\"type\":\"link\",\"kind\":\"nic\",\"fpga\":{},\"busy_flit_cycles\":{}}}\n",
                fpga, busy
            ));
        }
    }

    let (ser, stall) = match fobs {
        Some(f) => (
            f.serialize_wait.values().sum::<u64>(),
            f.retx_stall.values().sum::<u64>(),
        ),
        None => (0, 0),
    };
    out.push_str(&format!(
        "{{\"type\":\"summary\",\"packets\":{},\"flits\":{},\"inter_fpga_packets\":{},\"dropped\":{},\"retransmits\":{},\"outage_holds\":{},\"serialize_wait_cycles\":{},\"retransmit_stall_cycles\":{},\"outage_hold_cycles\":{}}}\n",
        fleet.packets,
        fleet.flits,
        fleet.inter_fpga_packets,
        fleet.dropped,
        fleet.retransmits,
        tobs.outage_holds,
        ser,
        stall,
        tobs.outage_hold.values().sum::<u64>()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fabric_obs_buckets_and_merge() {
        let mut a = FabricObs::new(100);
        a.on_egress(5, 0, 10, 12, 3);
        a.on_nic(0, 0, 150, 12, 0);
        a.on_drop(150);
        a.on_retx(0, 200, 512, 1, 0, 1);
        let mut b = FabricObs::new(100);
        b.on_egress(5, 1, 110, 12, 0);
        a.merge(&b);
        assert_eq!(a.bucket_egress_busy, vec![12, 12]);
        assert_eq!(a.bucket_nic_busy, vec![0, 12]);
        assert_eq!(a.bucket_drops, vec![0, 1]);
        assert_eq!(a.bucket_retx, vec![0, 0, 1]);
        assert_eq!(a.egress_busy.get(&5), Some(&24));
        assert_eq!(a.serialize_wait.get(&0), Some(&3));
        assert_eq!(a.retx_stall.get(&0), Some(&512));
        assert_eq!(a.sorted_retx_spans(), vec![(200, 512, 0, 1)]);
    }

    #[test]
    fn metrics_jsonl_shape() {
        let mut trace = Trace::default();
        let k = GlobalKernelId::new(0, 3);
        let s = trace.register(k);
        trace.on_rx_slot(s, 10);
        trace.on_tx_slot(s, 90);
        trace.wake_slot(s);
        let mut tobs = TraceObs::new(50, vec![]);
        tobs.on_event(10);
        tobs.on_fifo_depth(60, 768);
        let fifos = vec![(
            k,
            FifoSnapshot { occupancy: 0, high_water: 768, capacity_bytes: 4096, overflows: 0 },
        )];
        let fleet = FabricStats::default();
        let text = render_metrics_jsonl(&trace, &tobs, None, &fifos, &fleet, 100);
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains("\"schema\":\"obs_metrics/v1\""));
        assert!(lines[0].contains("\"buckets\":2"));
        assert!(text.contains("\"type\":\"bucket\",\"start_cycle\":50"));
        assert!(text.contains("\"type\":\"kernel\",\"id\":\"c0k3\""));
        assert!(text.contains("\"wakes\":1"));
        assert!(text.contains("\"busy_frac\":0.800000"));
        assert!(text.contains("\"type\":\"fifo\",\"id\":\"c0k3\",\"high_water_bytes\":768"));
        assert!(text.ends_with("}\n"));
        // every line parses as JSON
        for l in lines {
            assert!(crate::util::json::Json::parse(l).is_ok(), "{l}");
        }
    }
}
