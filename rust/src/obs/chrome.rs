//! Chrome trace-event exporter (`--trace-out`).
//!
//! Spans are *derived at export time* from the per-request endpoint
//! stats the marked kernels collected (`TraceObs::per_inf`) — the hot
//! path never allocates span objects. Request lifecycle phases are
//! emitted as async begin/end pairs (`"ph":"b"` / `"ph":"e"`, one
//! async track per request id) because stage residencies of one
//! request overlap in time and would not nest as synchronous slices.
//! Retransmit stalls become `"X"` slices on the fabric process, and
//! failure / recovery instants become `"ph":"i"` events.
//!
//! The output is the standard JSON object form
//! (`{"traceEvents": [...]}`) and loads directly in Perfetto /
//! `chrome://tracing`.

use crate::cycles_to_us;
use crate::obs::metrics::FabricObs;
use crate::obs::span::TraceObs;

/// One request as the serving layer saw it: scheduled arrival,
/// sequence length and (if it completed) the cycle the sink finished.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub inference: u32,
    pub arrival: u64,
    pub m: u32,
    pub done: Option<u64>,
}

/// Which marked kernels play which role in the span model (dense ids).
#[derive(Debug, Clone, Default)]
pub struct SpanRoles {
    /// Traffic source (queue spans end at its first tx per request).
    pub source: Option<u32>,
    /// Per encoder: (gateway dense id, stage-output dense id).
    pub stages: Vec<(u32, u32)>,
    /// Evaluation sink (delivery spans).
    pub sink: Option<u32>,
}

fn push_async(
    out: &mut Vec<String>,
    ph: char,
    name: &str,
    inf: u32,
    t: u64,
    args: Option<String>,
) {
    let args = args.map_or(String::new(), |a| format!(",\"args\":{a}"));
    out.push(format!(
        "{{\"ph\":\"{ph}\",\"cat\":\"request\",\"id\":\"r{inf}\",\"pid\":1,\"tid\":{inf},\"name\":\"{name}\",\"ts\":{:.3}{args}}}",
        cycles_to_us(t)
    ));
}

/// Render the full Chrome trace JSON. Deterministic: requests in the
/// caller's (arrival) order, stages in pipeline order, instants and
/// retransmit spans sorted.
pub fn render_chrome_trace(
    requests: &[RequestOutcome],
    roles: &SpanRoles,
    tobs: &TraceObs,
    fobs: Option<&FabricObs>,
) -> String {
    let mut ev: Vec<String> = Vec::new();
    for (pid, name) in [(0, "fleet"), (1, "requests"), (2, "fabric")] {
        ev.push(format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }

    for r in requests {
        let inf = r.inference;
        let serialize = fobs.and_then(|f| f.serialize_wait.get(&inf)).copied().unwrap_or(0);
        let retx = fobs.and_then(|f| f.retx_stall.get(&inf)).copied().unwrap_or(0);
        let outage = tobs.outage_hold.get(&inf).copied().unwrap_or(0);
        if let Some(done) = r.done {
            let args = format!(
                "{{\"m\":{},\"total_cycles\":{},\"serialize_wait_cycles\":{serialize},\"retransmit_stall_cycles\":{retx},\"outage_hold_cycles\":{outage}}}",
                r.m,
                done - r.arrival
            );
            push_async(&mut ev, 'b', "request", inf, r.arrival, Some(args));
            push_async(&mut ev, 'e', "request", inf, done, None);
        }
        // Source queueing: scheduled arrival -> first packet injected.
        if let Some(first_tx) =
            roles.source.and_then(|s| tobs.mark(s, inf)).and_then(|m| m.first_tx)
        {
            if first_tx >= r.arrival {
                push_async(&mut ev, 'b', "queue", inf, r.arrival, None);
                push_async(&mut ev, 'e', "queue", inf, first_tx, None);
            }
        }
        // Stage residency: gateway first rx -> stage-output last tx.
        for (e, (gw, outk)) in roles.stages.iter().enumerate() {
            let enter = tobs.mark(*gw, inf).and_then(|m| m.first_rx);
            let leave = tobs.mark(*outk, inf).and_then(|m| m.last_tx);
            if let (Some(a), Some(z)) = (enter, leave) {
                if z >= a {
                    let name = format!("encoder{e}");
                    push_async(&mut ev, 'b', &name, inf, a, None);
                    push_async(&mut ev, 'e', &name, inf, z, None);
                }
            }
        }
        // Delivery at the evaluation sink.
        if let Some(m) = roles.sink.and_then(|s| tobs.mark(s, inf)) {
            if let (Some(a), Some(z)) = (m.first_rx, m.last_rx) {
                if z >= a {
                    push_async(&mut ev, 'b', "sink", inf, a, None);
                    push_async(&mut ev, 'e', "sink", inf, z, None);
                }
            }
        }
    }

    for i in tobs.sorted_instants() {
        ev.push(format!(
            "{{\"ph\":\"i\",\"pid\":0,\"tid\":{},\"name\":\"{}\",\"ts\":{:.3},\"s\":\"g\",\"args\":{{\"fpga\":{}}}}}",
            i.fpga,
            i.kind,
            cycles_to_us(i.t),
            i.fpga
        ));
    }

    if let Some(f) = fobs {
        for (start, dur, src, dst) in f.sorted_retx_spans() {
            ev.push(format!(
                "{{\"ph\":\"X\",\"pid\":2,\"tid\":{src},\"name\":\"retransmit\",\"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"dst_fpga\":{dst}}}}}",
                cycles_to_us(start),
                cycles_to_us(dur)
            ));
        }
    }

    let mut out = String::from("{\"traceEvents\":[\n");
    out.push_str(&ev.join(",\n"));
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn trace_is_valid_json_with_balanced_async_pairs() {
        let src = 0x0101u32;
        let gw = 0x0000u32;
        let outk = 0x0020u32;
        let mut tobs = TraceObs::new(100, vec![src, gw, outk]);
        tobs.on_tx_marked(src, 0, 120);
        tobs.on_rx_marked(gw, 0, 150);
        tobs.on_tx_marked(outk, 0, 900);
        tobs.on_instant(500, 3, "fail");
        tobs.on_instant(700, 3, "recover");
        let mut fobs = FabricObs::new(100);
        fobs.on_retx(0, 300, 512, 1, 0, 1);
        let reqs = vec![RequestOutcome { inference: 0, arrival: 100, m: 4, done: Some(1000) }];
        let roles =
            SpanRoles { source: Some(src), stages: vec![(gw, outk)], sink: None };
        let text = render_chrome_trace(&reqs, &roles, &tobs, Some(&fobs));
        let doc = Json::parse(&text).expect("valid JSON");
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let mut begins = 0i64;
        let mut ends = 0i64;
        for e in evs {
            let ph = e.get("ph").and_then(Json::as_str).unwrap();
            assert!(e.get("ts").is_some() || ph == "M");
            match ph {
                "b" => begins += 1,
                "e" => ends += 1,
                "X" => assert!(e.get("dur").is_some()),
                _ => {}
            }
        }
        assert_eq!(begins, ends);
        assert!(begins >= 3, "request + queue + encoder0 spans expected");
        assert!(text.contains("\"name\":\"fail\""));
        assert!(text.contains("\"name\":\"retransmit\""));
    }

    #[test]
    fn incomplete_requests_get_no_request_span() {
        let tobs = TraceObs::new(100, vec![]);
        let reqs = vec![RequestOutcome { inference: 7, arrival: 5, m: 1, done: None }];
        let text = render_chrome_trace(&reqs, &SpanRoles::default(), &tobs, None);
        assert!(!text.contains("\"id\":\"r7\""));
        assert!(Json::parse(&text).is_ok());
    }
}
