//! Simulator self-profiling (`--profile`, `bench --profile`).
//!
//! Answers "where does the *simulator's* wall clock go" — the question
//! the ROADMAP's fleet-scale item needs answered before thousand-FPGA
//! runs: events per conservative window, time spent parked on the
//! 3-barrier worker loop, and wall-ns per simulated cycle.
//!
//! Everything in here is wall-clock derived and therefore **not**
//! deterministic: the `sim_profile` section is only attached to a
//! report when profiling was explicitly requested, and the
//! thread-parity / golden-determinism suites never enable it.

use crate::util::json::Json;

/// Accumulated self-profile of one `Sim` across its `run_until` calls.
#[derive(Debug, Clone, Default)]
pub struct SimProfile {
    /// "sequential", "parallel", or "mixed" when both paths ran.
    pub engine: String,
    /// Worker threads used by the parallel path (0 for sequential).
    pub threads: usize,
    /// Shards in the last parallel partition.
    pub shards: usize,
    /// Conservative window width (cycles) of the last parallel run.
    pub window: u64,
    /// Barrier rounds executed by the windowed worker loop.
    pub rounds: u64,
    /// Events dispatched while profiling.
    pub events: u64,
    /// Simulated cycles advanced while profiling.
    pub sim_cycles: u64,
    /// Wall nanoseconds spent inside run_until.
    pub wall_ns: u64,
    /// Wall nanoseconds workers spent waiting on the round barriers.
    pub barrier_wait_ns: u64,
    /// Events dispatched by each shard (last parallel run).
    pub per_shard_events: Vec<u64>,
}

impl SimProfile {
    pub fn note_engine(&mut self, kind: &str) {
        if self.engine.is_empty() {
            self.engine = kind.to_string();
        } else if self.engine != kind {
            self.engine = "mixed".to_string();
        }
    }

    pub fn wall_ns_per_sim_cycle(&self) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        self.wall_ns as f64 / self.sim_cycles as f64
    }

    pub fn events_per_round(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.events as f64 / self.rounds as f64
    }

    pub fn barrier_frac(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        // Total park time across workers vs total worker wall time.
        self.barrier_wait_ns as f64 / (self.wall_ns as f64 * self.threads.max(1) as f64)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("engine", Json::Str(self.engine.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("window_cycles", Json::Num(self.window as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("events", Json::Num(self.events as f64)),
            ("sim_cycles", Json::Num(self.sim_cycles as f64)),
            ("wall_ns", Json::Num(self.wall_ns as f64)),
            ("wall_ns_per_sim_cycle", Json::Num(self.wall_ns_per_sim_cycle())),
            ("events_per_round", Json::Num(self.events_per_round())),
            ("barrier_wait_ns", Json::Num(self.barrier_wait_ns as f64)),
            ("barrier_wait_frac", Json::Num(self.barrier_frac())),
            (
                "per_shard_events",
                Json::Arr(self.per_shard_events.iter().map(|&e| Json::Num(e as f64)).collect()),
            ),
        ])
    }

    pub fn render(&self) -> String {
        format!(
            "sim profile: engine={} threads={} shards={} window={} rounds={} events={} \
             sim_cycles={} wall={:.2}ms ns/cycle={:.1} events/round={:.0} barrier={:.1}%",
            self.engine,
            self.threads,
            self.shards,
            self.window,
            self.rounds,
            self.events,
            self.sim_cycles,
            self.wall_ns as f64 / 1e6,
            self.wall_ns_per_sim_cycle(),
            self.events_per_round(),
            100.0 * self.barrier_frac()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_ratios_and_json_shape() {
        let mut p = SimProfile {
            threads: 4,
            shards: 6,
            window: 220,
            rounds: 10,
            events: 1000,
            sim_cycles: 2000,
            wall_ns: 4000,
            barrier_wait_ns: 800,
            per_shard_events: vec![250, 250, 500],
            ..Default::default()
        };
        p.note_engine("parallel");
        p.note_engine("parallel");
        assert_eq!(p.engine, "parallel");
        p.note_engine("sequential");
        assert_eq!(p.engine, "mixed");
        assert_eq!(p.wall_ns_per_sim_cycle(), 2.0);
        assert_eq!(p.events_per_round(), 100.0);
        assert!((p.barrier_frac() - 0.05).abs() < 1e-12);
        let j = p.to_json();
        assert_eq!(j.path("events").and_then(Json::as_i64), Some(1000));
        assert_eq!(j.get("per_shard_events").and_then(Json::as_arr).unwrap().len(), 3);
        assert!(p.render().contains("engine=mixed"));
    }

    #[test]
    fn empty_profile_divides_safely() {
        let p = SimProfile::default();
        assert_eq!(p.wall_ns_per_sim_cycle(), 0.0);
        assert_eq!(p.events_per_round(), 0.0);
        assert_eq!(p.barrier_frac(), 0.0);
    }
}
