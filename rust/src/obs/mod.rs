//! Cycle-domain telemetry: span traces, streaming fleet metrics, and
//! simulator self-profiling.
//!
//! The paper can only observe its platform end-to-end (Table 1's X/T/I
//! measured at the evaluation FPGA); the simulator can see everything.
//! This module turns that visibility into three artifacts:
//!
//! 1. **Span traces** ([`chrome`]) — per-request lifecycle spans
//!    (source queueing, per-encoder stage residency, retransmit
//!    stalls, outage holds) plus failure/recovery instants, exported
//!    as Chrome trace-event JSON (`--trace-out`, loads in Perfetto).
//! 2. **Streaming metrics** ([`metrics`]) — constant-memory,
//!    cycle-bucketed fleet series (`--metrics-out`): link utilization,
//!    FIFO depth, kernel busy fraction and wakes, drops/retransmits —
//!    and the bottleneck-attribution section of `serving_report/v3`.
//! 3. **Self-profile** ([`profile`]) — events per conservative window,
//!    barrier-wait time, wall-ns per simulated cycle (`--profile`,
//!    `bench --profile`).
//!
//! Collectors ([`span::TraceObs`], [`metrics::FabricObs`]) live as
//! `Option<Box<_>>` inside the structs the hot path already owns, so a
//! disabled run pays one predictable branch per event, and they merge
//! exactly across shards: all reported numbers are bit-identical at
//! every `--threads` count.

pub mod chrome;
pub mod metrics;
pub mod profile;
pub mod span;

pub use chrome::{render_chrome_trace, RequestOutcome, SpanRoles};
pub use metrics::{render_metrics_jsonl, FabricObs, FifoSnapshot};
pub use profile::SimProfile;
pub use span::{InstantEvent, MarkStats, TraceObs, DEFAULT_INTERVAL};

use crate::sim::trace::Trace;
use crate::util::json::Json;

/// Telemetry knobs threaded from the CLI down to the testbed.
#[derive(Debug, Clone, Default)]
pub struct ObsSettings {
    /// Collect spans + metrics (drives `--trace-out` / `--metrics-out`
    /// and the report's `telemetry` section).
    pub enabled: bool,
    /// Metrics bucket width in cycles; 0 = [`DEFAULT_INTERVAL`].
    pub metrics_interval: u64,
    /// Collect the (wall-clock, nondeterministic) simulator
    /// self-profile and attach a `sim_profile` report section.
    pub profile: bool,
}

impl ObsSettings {
    pub fn interval(&self) -> u64 {
        if self.metrics_interval == 0 {
            DEFAULT_INTERVAL
        } else {
            self.metrics_interval
        }
    }
}

/// Per-request cycle attribution: where one inference's end-to-end
/// latency went. `compute` is the residual (on-FPGA compute plus
/// uncontended flight time) after the measured components.
#[derive(Debug, Clone, Copy, Default)]
pub struct Attribution {
    pub total: u64,
    pub queue: u64,
    pub serialize: u64,
    pub retransmit: u64,
    pub outage: u64,
    pub compute: u64,
}

/// Attribute one completed request from the collectors.
pub fn attribute_request(
    r: &RequestOutcome,
    roles: &SpanRoles,
    tobs: &TraceObs,
    fobs: Option<&FabricObs>,
) -> Option<Attribution> {
    let done = r.done?;
    let total = done.saturating_sub(r.arrival);
    let queue = roles
        .source
        .and_then(|s| tobs.mark(s, r.inference))
        .and_then(|m| m.first_tx)
        .map_or(0, |t| t.saturating_sub(r.arrival));
    let serialize = fobs.and_then(|f| f.serialize_wait.get(&r.inference)).copied().unwrap_or(0);
    let retransmit = fobs.and_then(|f| f.retx_stall.get(&r.inference)).copied().unwrap_or(0);
    let outage = tobs.outage_hold.get(&r.inference).copied().unwrap_or(0);
    let compute = total
        .saturating_sub(queue)
        .saturating_sub(serialize)
        .saturating_sub(retransmit)
        .saturating_sub(outage);
    Some(Attribution { total, queue, serialize, retransmit, outage, compute })
}

/// Nearest-rank percentile over unsorted u64 samples.
fn pctl(samples: &mut [u64], p: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    samples.sort_unstable();
    let rank = ((p / 100.0) * samples.len() as f64).ceil() as usize;
    samples[rank.clamp(1, samples.len()) - 1]
}

/// Build the `telemetry` section of `serving_report/v3`: aggregate
/// bottleneck attribution across completed requests, per-kernel wake
/// telemetry (the previously dead `KernelStats::wakes` counter), and
/// fleet-level link totals. Everything here is thread-invariant.
pub fn telemetry_section(
    requests: &[RequestOutcome],
    roles: &SpanRoles,
    trace: &Trace,
    tobs: &TraceObs,
    fobs: Option<&FabricObs>,
) -> Json {
    let parts: Vec<Attribution> =
        requests.iter().filter_map(|r| attribute_request(r, roles, tobs, fobs)).collect();
    let comp = |f: fn(&Attribution) -> u64| -> (u64, f64, u64) {
        let total: u64 = parts.iter().map(f).sum();
        let mean = if parts.is_empty() { 0.0 } else { total as f64 / parts.len() as f64 };
        let mut v: Vec<u64> = parts.iter().map(f).collect();
        (total, mean, pctl(&mut v, 95.0))
    };
    let components: Vec<(&str, fn(&Attribution) -> u64)> = vec![
        ("queue", |a| a.queue),
        ("compute", |a| a.compute),
        ("serialize", |a| a.serialize),
        ("retransmit", |a| a.retransmit),
        ("outage", |a| a.outage),
        ("total", |a| a.total),
    ];
    let mut totals = Vec::new();
    let mut means = Vec::new();
    let mut p95s = Vec::new();
    for (name, f) in &components {
        let (t, m, p) = comp(*f);
        totals.push((*name, Json::Num(t as f64)));
        means.push((*name, Json::Num(m)));
        p95s.push((*name, Json::Num(p as f64)));
    }

    // Per-kernel wakes: fleet total plus the top wakers (ties broken
    // by kernel id for determinism).
    let mut wakes: Vec<(u64, u32, u64, u64)> = trace
        .kernels()
        .map(|(id, st)| (st.wakes, id.dense() as u32, st.rx_packets, st.tx_packets))
        .collect();
    let wakes_total: u64 = wakes.iter().map(|w| w.0).sum();
    wakes.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let top: Vec<Json> = wakes
        .iter()
        .take(8)
        .filter(|w| w.0 > 0)
        .map(|(w, dense, rx, tx)| {
            Json::obj(vec![
                ("id", Json::Str(format!("c{}k{}", dense >> 8, dense & 0xff))),
                ("wakes", Json::Num(*w as f64)),
                ("rx_packets", Json::Num(*rx as f64)),
                ("tx_packets", Json::Num(*tx as f64)),
            ])
        })
        .collect();

    let (egress, nic) = match fobs {
        Some(f) => (
            f.egress_busy.values().sum::<u64>(),
            f.nic_busy.values().sum::<u64>(),
        ),
        None => (0, 0),
    };

    Json::obj(vec![
        ("requests_attributed", Json::Num(parts.len() as f64)),
        (
            "attribution",
            Json::obj(vec![
                ("totals_cycles", Json::obj(totals)),
                ("mean_cycles", Json::obj(means)),
                ("p95_cycles", Json::obj(p95s)),
            ]),
        ),
        (
            "wakes",
            Json::obj(vec![
                ("total", Json::Num(wakes_total as f64)),
                ("top_kernels", Json::Arr(top)),
            ]),
        ),
        (
            "fleet",
            Json::obj(vec![
                ("egress_busy_flit_cycles", Json::Num(egress as f64)),
                ("nic_busy_flit_cycles", Json::Num(nic as f64)),
                ("outage_holds", Json::Num(tobs.outage_holds as f64)),
                (
                    "outage_hold_cycles",
                    Json::Num(tobs.outage_hold.values().sum::<u64>() as f64),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::packet::GlobalKernelId;

    #[test]
    fn attribution_splits_and_residual() {
        let src = GlobalKernelId::new(9, 1).dense() as u32;
        let mut tobs = TraceObs::new(100, vec![src]);
        tobs.on_tx_marked(src, 0, 150); // queued 100..150
        tobs.on_outage_hold(0, 20);
        let mut fobs = FabricObs::new(100);
        fobs.on_egress(3, 0, 200, 12, 30); // 30 cycles of serialize wait
        fobs.on_retx(0, 400, 512, 1, 0, 1);
        let r = RequestOutcome { inference: 0, arrival: 100, m: 2, done: Some(1100) };
        let roles = SpanRoles { source: Some(src), stages: vec![], sink: None };
        let a = attribute_request(&r, &roles, &tobs, Some(&fobs)).unwrap();
        assert_eq!(a.total, 1000);
        assert_eq!(a.queue, 50);
        assert_eq!(a.serialize, 30);
        assert_eq!(a.retransmit, 512);
        assert_eq!(a.outage, 20);
        assert_eq!(a.compute, 1000 - 50 - 30 - 512 - 20);
        // incomplete request attributes to None
        let r2 = RequestOutcome { done: None, ..r };
        assert!(attribute_request(&r2, &roles, &tobs, Some(&fobs)).is_none());
    }

    #[test]
    fn telemetry_section_reports_wakes() {
        let mut trace = Trace::default();
        let k = GlobalKernelId::new(0, 4);
        let s = trace.register(k);
        for _ in 0..3 {
            trace.wake_slot(s);
        }
        let tobs = TraceObs::new(100, vec![]);
        let j = telemetry_section(&[], &SpanRoles::default(), &trace, &tobs, None);
        assert_eq!(j.path("wakes.total").and_then(Json::as_i64), Some(3));
        let top = j.path("wakes.top_kernels").and_then(Json::as_arr).unwrap();
        assert_eq!(top.len(), 1);
        assert_eq!(top[0].get("id").and_then(Json::as_str), Some("c0k4"));
        assert_eq!(j.path("requests_attributed").and_then(Json::as_i64), Some(0));
    }

    #[test]
    fn pctl_nearest_rank() {
        let mut v = vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(pctl(&mut v, 95.0), 100);
        assert_eq!(pctl(&mut v.clone(), 50.0), 50);
        let mut empty: Vec<u64> = vec![];
        assert_eq!(pctl(&mut empty, 95.0), 0);
    }
}
