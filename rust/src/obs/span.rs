//! Cycle-domain collectors that ride inside [`crate::sim::trace::Trace`].
//!
//! `TraceObs` is the engine-side half of the telemetry subsystem: it
//! records (a) per-request endpoint stats at a small set of *marked*
//! kernels (source, sink, per-encoder gateway and output), from which
//! the exporter derives request lifecycle spans, (b) constant-memory
//! cycle-bucketed fleet series (events, wakes, FIFO peak depth), and
//! (c) outage bookkeeping from the §6 failure injector.
//!
//! Everything here is *exactly shard-mergeable*: counters add,
//! per-inference maps merge key-wise with commutative min/max, bucket
//! arrays add elementwise (peaks take max), and instants are sorted at
//! export. A run at `--threads 8` therefore renders byte-identical
//! traces and metrics to the same run at `--threads 1`.

use std::collections::BTreeMap;

/// Default metrics bucket width: the event-wheel horizon (8192 cycles
/// = 40.96 us of fabric time), a natural granularity for the engine.
pub const DEFAULT_INTERVAL: u64 = 8192;

/// First/last rx/tx of one inference at one marked kernel. The span
/// exporter turns these into queue / stage-residency spans.
#[derive(Debug, Clone, Default)]
pub struct MarkStats {
    pub rx_packets: u64,
    pub tx_packets: u64,
    pub first_rx: Option<u64>,
    pub last_rx: Option<u64>,
    pub first_tx: Option<u64>,
    pub last_tx: Option<u64>,
}

impl MarkStats {
    fn on_rx(&mut self, t: u64) {
        self.rx_packets += 1;
        self.first_rx = Some(self.first_rx.map_or(t, |f| f.min(t)));
        self.last_rx = Some(self.last_rx.map_or(t, |l| l.max(t)));
    }
    fn on_tx(&mut self, t: u64) {
        self.tx_packets += 1;
        self.first_tx = Some(self.first_tx.map_or(t, |f| f.min(t)));
        self.last_tx = Some(self.last_tx.map_or(t, |l| l.max(t)));
    }
    fn merge(&mut self, o: &MarkStats) {
        self.rx_packets += o.rx_packets;
        self.tx_packets += o.tx_packets;
        let min = |a: Option<u64>, b: Option<u64>| match (a, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            (x, y) => x.or(y),
        };
        let max = |a: Option<u64>, b: Option<u64>| match (a, b) {
            (Some(x), Some(y)) => Some(x.max(y)),
            (x, y) => x.or(y),
        };
        self.first_rx = min(self.first_rx, o.first_rx);
        self.last_rx = max(self.last_rx, o.last_rx);
        self.first_tx = min(self.first_tx, o.first_tx);
        self.last_tx = max(self.last_tx, o.last_tx);
    }
}

/// A cluster-level instant (failure injection / recovery) for the
/// Chrome trace's instant events.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct InstantEvent {
    pub t: u64,
    pub fpga: u32,
    /// "fail" | "recover"
    pub kind: &'static str,
}

/// Grow-and-add into a bucket vector.
#[inline]
pub(crate) fn bump(v: &mut Vec<u64>, b: usize, by: u64) {
    if v.len() <= b {
        v.resize(b + 1, 0);
    }
    v[b] += by;
}

/// Grow-and-max into a bucket vector.
#[inline]
pub(crate) fn bmax(v: &mut Vec<u64>, b: usize, x: u64) {
    if v.len() <= b {
        v.resize(b + 1, 0);
    }
    if v[b] < x {
        v[b] = x;
    }
}

/// Add `o` elementwise into `v`, growing as needed.
pub(crate) fn add_buckets(v: &mut Vec<u64>, o: &[u64]) {
    if v.len() < o.len() {
        v.resize(o.len(), 0);
    }
    for (a, b) in v.iter_mut().zip(o.iter()) {
        *a += b;
    }
}

/// Max `o` elementwise into `v`, growing as needed.
pub(crate) fn max_buckets(v: &mut Vec<u64>, o: &[u64]) {
    if v.len() < o.len() {
        v.resize(o.len(), 0);
    }
    for (a, b) in v.iter_mut().zip(o.iter()) {
        if *a < *b {
            *a = *b;
        }
    }
}

/// The trace-side telemetry collector. Lives as `Option<Box<TraceObs>>`
/// inside [`crate::sim::trace::Trace`]; every hot-path touch is behind
/// a single `Option` branch so a disabled run pays one predictable
/// not-taken test per event.
#[derive(Debug)]
pub struct TraceObs {
    /// Bucket width in cycles.
    pub interval: u64,
    /// Sorted dense kernel ids whose per-inference endpoints we track.
    pub mark_set: Vec<u32>,
    /// Per-trace-slot mark flag, parallel to the Trace slot vectors
    /// (maintained by `Trace::register`).
    pub marks: Vec<bool>,
    /// (dense kernel id, inference) -> endpoint stats.
    pub per_inf: BTreeMap<(u32, u32), MarkStats>,
    /// Delivered events per bucket (packets + wakes), fleet-wide.
    pub bucket_events: Vec<u64>,
    /// Kernel wakes per bucket, fleet-wide.
    pub bucket_wakes: Vec<u64>,
    /// Max FIFO occupancy (bytes) observed in each bucket, fleet-wide.
    pub bucket_fifo_peak: Vec<u64>,
    /// Cycles each inference spent held behind a failed FPGA
    /// (Hold::Buffer in the §6 injector): inference -> cycles.
    pub outage_hold: BTreeMap<u32, u64>,
    /// Total packet-holds across the run (all inferences).
    pub outage_holds: u64,
    /// Failure / recovery instants.
    pub instants: Vec<InstantEvent>,
}

impl TraceObs {
    pub fn new(interval: u64, mut mark_set: Vec<u32>) -> TraceObs {
        mark_set.sort_unstable();
        mark_set.dedup();
        TraceObs {
            interval: interval.max(1),
            mark_set,
            marks: Vec::new(),
            per_inf: BTreeMap::new(),
            bucket_events: Vec::new(),
            bucket_wakes: Vec::new(),
            bucket_fifo_peak: Vec::new(),
            outage_hold: BTreeMap::new(),
            outage_holds: 0,
            instants: Vec::new(),
        }
    }

    #[inline]
    pub fn is_marked_dense(&self, dense: u32) -> bool {
        self.mark_set.binary_search(&dense).is_ok()
    }

    #[inline]
    fn bucket(&self, t: u64) -> usize {
        (t / self.interval) as usize
    }

    #[inline]
    pub fn on_event(&mut self, t: u64) {
        let b = self.bucket(t);
        bump(&mut self.bucket_events, b, 1);
    }

    #[inline]
    pub fn on_wake_bucket(&mut self, t: u64) {
        let b = self.bucket(t);
        bump(&mut self.bucket_wakes, b, 1);
    }

    #[inline]
    pub fn on_fifo_depth(&mut self, t: u64, occupancy: u64) {
        let b = self.bucket(t);
        bmax(&mut self.bucket_fifo_peak, b, occupancy);
    }

    #[inline]
    pub fn on_rx_marked(&mut self, dense: u32, inference: u32, t: u64) {
        self.per_inf.entry((dense, inference)).or_default().on_rx(t);
    }

    #[inline]
    pub fn on_tx_marked(&mut self, dense: u32, inference: u32, t: u64) {
        self.per_inf.entry((dense, inference)).or_default().on_tx(t);
    }

    pub fn on_outage_hold(&mut self, inference: u32, cycles: u64) {
        *self.outage_hold.entry(inference).or_insert(0) += cycles;
        self.outage_holds += 1;
    }

    pub fn on_instant(&mut self, t: u64, fpga: u32, kind: &'static str) {
        self.instants.push(InstantEvent { t, fpga, kind });
    }

    /// Endpoint stats of `inference` at dense kernel id `dense`.
    pub fn mark(&self, dense: u32, inference: u32) -> Option<&MarkStats> {
        self.per_inf.get(&(dense, inference))
    }

    /// Fold a per-shard collector back in (commutative, so the merge
    /// order across shards cannot change the result).
    pub fn merge(&mut self, o: TraceObs) {
        debug_assert_eq!(self.interval, o.interval);
        for (k, s) in &o.per_inf {
            self.per_inf.entry(*k).or_default().merge(s);
        }
        add_buckets(&mut self.bucket_events, &o.bucket_events);
        add_buckets(&mut self.bucket_wakes, &o.bucket_wakes);
        max_buckets(&mut self.bucket_fifo_peak, &o.bucket_fifo_peak);
        for (inf, c) in &o.outage_hold {
            *self.outage_hold.entry(*inf).or_insert(0) += c;
        }
        self.outage_holds += o.outage_holds;
        self.instants.extend(o.instants);
    }

    /// Instants in deterministic (time, fpga, kind) order for export.
    pub fn sorted_instants(&self) -> Vec<InstantEvent> {
        let mut v = self.instants.clone();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mark_stats_track_extremes() {
        let mut o = TraceObs::new(100, vec![7]);
        assert!(o.is_marked_dense(7));
        assert!(!o.is_marked_dense(8));
        o.on_rx_marked(7, 3, 50);
        o.on_rx_marked(7, 3, 10);
        o.on_tx_marked(7, 3, 60);
        let m = o.mark(7, 3).unwrap();
        assert_eq!((m.first_rx, m.last_rx), (Some(10), Some(50)));
        assert_eq!(m.first_tx, Some(60));
        assert_eq!(m.rx_packets, 2);
    }

    #[test]
    fn buckets_grow_add_and_max() {
        let mut o = TraceObs::new(10, vec![]);
        o.on_event(5);
        o.on_event(25);
        o.on_wake_bucket(25);
        o.on_fifo_depth(25, 64);
        o.on_fifo_depth(29, 32);
        assert_eq!(o.bucket_events, vec![1, 0, 1]);
        assert_eq!(o.bucket_wakes, vec![0, 0, 1]);
        assert_eq!(o.bucket_fifo_peak, vec![0, 0, 64]);
    }

    #[test]
    fn merge_is_commutative_on_this_example() {
        let build = |times: &[u64]| {
            let mut o = TraceObs::new(10, vec![1]);
            for &t in times {
                o.on_event(t);
                o.on_rx_marked(1, 0, t);
            }
            o.on_outage_hold(0, 5);
            o.on_instant(times[0], 2, "fail");
            o
        };
        let mut ab = build(&[3, 14]);
        ab.merge(build(&[25]));
        let mut ba = build(&[25]);
        ba.merge(build(&[3, 14]));
        assert_eq!(ab.bucket_events, ba.bucket_events);
        assert_eq!(ab.outage_hold, ba.outage_hold);
        let (ma, mb) = (ab.mark(1, 0).unwrap(), ba.mark(1, 0).unwrap());
        assert_eq!(ma.first_rx, mb.first_rx);
        assert_eq!(ma.last_rx, mb.last_rx);
        assert_eq!(ab.sorted_instants(), ba.sorted_instants());
    }
}
