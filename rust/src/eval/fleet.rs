//! Synthetic fleet-scale scenarios: N independent encoder chains
//! replicated side by side until the platform reaches a thousand FPGAs.
//!
//! The paper's testbed tops out at one 6-FPGA encoder plus the
//! evaluation FPGA; the ROADMAP's "millions of users" north star needs
//! the simulator to answer questions at *fleet* scale — hundreds of
//! clusters serving in parallel, with the production-realism knobs
//! (lossy UDP, reliable transport, §6 failures) turned on. This module
//! generates that fleet: `chains` replicated encoder chains of
//! `encoders_per_chain` clusters (6 FPGAs each, the Fig. 14 mapping),
//! all fed from one evaluation FPGA, with **constant-memory streaming
//! stats** — the sink keeps running aggregates instead of per-inference
//! maps, so a thousand-FPGA run's memory does not grow with traffic.
//! With a `--tenants` config the fleet turns heterogeneous: each tenant
//! contributes chains of its *own* depth and build point, so mixed
//! model shapes share one fabric the way a multi-model deployment does.
//!
//! The default [`FleetConfig::thousand_fpga`] scenario is 28 chains x 6
//! encoders x 6 FPGAs = 1008 fabric FPGAs + 1 evaluation FPGA = 1009.
//! `benches/fleetscale.rs` runs it lossy at 1 and 8 threads and gates
//! the parallel-speedup headline; the `fleet` CLI subcommand exposes it
//! with an event-budget profile for bounded exploratory runs.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::eval::testbed::{NetworkConfig, EVAL_CLUSTER, EVAL_SINK};
use crate::galapagos::cluster::{ClusterSpec, KernelDecl, KernelType, PlatformSpec};
use crate::gmi::gateway::{Gateway, GatewayConfig};
use crate::gmi::Out;
use crate::ibert::graph::EncoderGraphParams;
use crate::ibert::kernels::Mode;
use crate::ibert::timing::PeConfig;
use crate::serve::source::RequestSourceKernel;
use crate::serve::tenant::TenantsConfig;
use crate::serve::traffic::{stream_seed, total_tokens, ArrivalProcess, LengthDist, Request, TrafficConfig};
use crate::sim::engine::{KernelBehavior, KernelIo, Sim};
use crate::sim::fabric::{FpgaId, SwitchId};
use crate::sim::packet::{GlobalKernelId, Packet};
use crate::sim::ShardGranularity;

/// First evaluation-cluster kernel id used for per-chain sources (one
/// source kernel per chain, ids `SOURCE_BASE..SOURCE_BASE + chains`).
pub const SOURCE_BASE: u8 = 3;

/// One chain's offered traffic in a homogeneous fleet: `inferences`
/// Poisson arrivals at `rate` seqs/s, every request `m` rows, drawn
/// from the chain's own seed stream (`stream_seed(net.seed, chain)` —
/// the same per-index derivation serving tenants use). The schedule
/// keeps the process's *leading* gap too (generate one extra request,
/// drop the head): a schedule that pinned its first arrival to cycle 0
/// would put every replica's opening request on the same cycle — the
/// exact lockstep the per-chain streams exist to remove. Chain `c`'s
/// schedule is a pure function of `(seed, c)`: adding or removing
/// chains never shifts a sibling's arrivals.
pub fn chain_schedule(cfg: &FleetConfig, chain: usize) -> Vec<Request> {
    let mut reqs = TrafficConfig {
        process: ArrivalProcess::Poisson { seqs_per_s: cfg.rate },
        // the fleet scenario streams fixed-length inferences; the
        // length distribution is overridden below
        lengths: LengthDist::Glue,
        requests: cfg.inferences as usize + 1,
        seed: stream_seed(cfg.net.seed, chain as u64),
        max_m: cfg.m,
    }
    .generate();
    reqs.remove(0);
    for r in &mut reqs {
        r.m = cfg.m as u32;
    }
    reqs
}

/// A fleet-scale scenario.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// independent replicated encoder chains
    pub chains: usize,
    /// encoder clusters per chain (6 FPGAs each)
    pub encoders_per_chain: usize,
    /// sequence length of every inference
    pub m: usize,
    /// pipelined inferences per chain
    pub inferences: u32,
    /// per-chain Poisson arrival rate (seqs/s) of the homogeneous
    /// scenario; tenant fleets use each tenant's own process instead
    pub rate: f64,
    /// input packet interval in cycles (12 = 100G line rate)
    pub interval: u64,
    /// FPGAs per 100G switch (switches chain serially)
    pub fpgas_per_switch: usize,
    /// lossy-UDP / reliable-transport behavior
    pub net: NetworkConfig,
    /// DES worker threads (None = process default)
    pub threads: Option<usize>,
    /// shard cut (None = simulator default, per-cluster)
    pub granularity: Option<ShardGranularity>,
    /// stop (with a truncated report, not an error) after this many
    /// events — the bounded "event-budget profile" for exploratory runs
    pub event_budget: Option<u64>,
    /// simulator self-profile (wall-ns/cycle, barrier wait, ...)
    pub profile: bool,
    /// heterogeneous fleet (`fleet --tenants`): each tenant contributes
    /// `chains_per_tenant` chains with its OWN depth, build point, and
    /// offered traffic (mixed model shapes on one fleet); overrides
    /// `chains`/`encoders_per_chain`/`m`/`rate`. Schedules come straight
    /// from each tenant's seed stream — the fleet measures fabric
    /// behavior under offered load, so no admission control applies.
    pub tenants: Option<TenantsConfig>,
    /// replicated chains per tenant when `tenants` is set
    pub chains_per_tenant: usize,
}

impl FleetConfig {
    /// The headline scenario: 28 chains x 6 encoders x 6 FPGAs = 1008
    /// fabric FPGAs + the evaluation FPGA = 1009 total.
    pub fn thousand_fpga() -> FleetConfig {
        FleetConfig {
            chains: 28,
            encoders_per_chain: 6,
            m: 16,
            inferences: 1,
            rate: 20_000.0,
            interval: 12,
            fpgas_per_switch: 6,
            net: NetworkConfig::default(),
            threads: None,
            granularity: None,
            event_budget: None,
            profile: false,
            tenants: None,
            chains_per_tenant: 1,
        }
    }

    /// Total FPGAs the scenario instantiates (fabric + evaluation).
    pub fn total_fpgas(&self) -> usize {
        match &self.tenants {
            None => self.chains * self.encoders_per_chain * 6 + 1,
            Some(tc) => {
                tc.tenants.iter().map(|t| t.encoders).sum::<usize>()
                    * self.chains_per_tenant
                    * 6
                    + 1
            }
        }
    }
}

/// One chain's identity in a (possibly heterogeneous) fleet: its depth,
/// hardware build point, and offered schedule.
#[derive(Clone)]
struct ChainPlan {
    label: String,
    encoders: usize,
    /// build point (KV/FIFO sizing); schedules never exceed it
    max_seq: usize,
    schedule: Arc<Vec<Request>>,
}

/// Expand the config into per-chain plans. Homogeneous fleets replicate
/// one plan shape with per-chain seed streams; tenant fleets lay out
/// `chains_per_tenant` chains per tenant in roster order, each drawing
/// from the tenant's schedule stream at its global chain index.
fn chain_plans(cfg: &FleetConfig) -> Result<Vec<ChainPlan>> {
    match &cfg.tenants {
        None => Ok((0..cfg.chains)
            .map(|chain| ChainPlan {
                label: format!("chain-{chain}"),
                encoders: cfg.encoders_per_chain,
                max_seq: 128,
                schedule: Arc::new(chain_schedule(cfg, chain)),
            })
            .collect()),
        Some(tc) => {
            tc.validate()?;
            ensure!(cfg.chains_per_tenant >= 1, "need at least one chain per tenant");
            let mut plans = Vec::new();
            for t in &tc.tenants {
                for k in 0..cfg.chains_per_tenant {
                    let idx = plans.len();
                    plans.push(ChainPlan {
                        label: format!("{}-{k}", t.name),
                        encoders: t.encoders,
                        max_seq: t.max_m,
                        schedule: Arc::new(t.schedule(cfg.net.seed, idx)),
                    });
                }
            }
            Ok(plans)
        }
    }
}

/// Constant-memory streaming aggregates of the fleet sink: running
/// counters only — nothing here grows with the number of inferences,
/// rows, or chains.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamStats {
    /// output rows received across all chains
    pub rows: u64,
    /// first / last output-row arrival cycles (0 until the first row)
    pub first_arrival: u64,
    pub last_arrival: u64,
    /// most output rows that ever landed on one cycle — the lockstep
    /// observable: desynchronized chains keep this near 1, phase-locked
    /// replicas pile up to `chains`
    pub coincident_rows_max: u64,
}

/// The fleet sink: every chain's final encoder output converges here.
/// Unlike the testbed's `SinkKernel` (per-inference arrival maps), it
/// keeps only [`StreamStats`] — O(1) memory at any fleet size.
struct StreamSinkKernel {
    stats: Arc<Mutex<StreamStats>>,
    /// streaming coincidence tracker: rows arrive in nondecreasing
    /// cycle order, so a (cycle, count) pair suffices for the max
    cur_cycle: u64,
    cur_count: u64,
}

impl KernelBehavior for StreamSinkKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        let stats = self.stats.clone();
        let (cur_cycle, cur_count) = (&mut self.cur_cycle, &mut self.cur_count);
        io.rows(pkt, |io2: &mut KernelIo, _meta, at, payload| {
            io2.consume(payload.bytes());
            let mut s = stats.lock().unwrap();
            if s.rows == 0 {
                s.first_arrival = at;
            }
            s.rows += 1;
            s.last_arrival = s.last_arrival.max(at);
            if *cur_count == 0 || at != *cur_cycle {
                *cur_cycle = at;
                *cur_count = 1;
            } else {
                *cur_count += 1;
            }
            s.coincident_rows_max = s.coincident_rows_max.max(*cur_count);
        });
    }

    fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}

    fn name(&self) -> String {
        "fleet-sink".to_string()
    }
}

/// A built fleet: the simulator plus the streaming-stats handle.
pub struct FleetSim {
    pub sim: Sim,
    pub stats: Arc<Mutex<StreamStats>>,
    /// rows the sink will have seen when every inference completes
    pub expected_rows: u64,
    pub fpgas: usize,
    pub clusters: usize,
    pub chains: usize,
}

/// Assemble the fleet: one encoder chain per [`ChainPlan`] (Fig. 14
/// mapping, 6 FPGAs per cluster) plus one evaluation FPGA hosting a
/// request source per chain and the shared streaming sink. Homogeneous
/// fleets replicate one plan shape; tenant fleets mix depths and build
/// points side by side on the same fabric.
pub fn build_fleet(cfg: &FleetConfig) -> Result<FleetSim> {
    if cfg.tenants.is_none() {
        ensure!(cfg.chains >= 1, "need at least one chain");
        ensure!(cfg.encoders_per_chain >= 1, "need at least one encoder per chain");
        ensure!((1..=128).contains(&cfg.m), "m must be in 1..=128");
        ensure!(cfg.rate > 0.0, "per-chain arrival rate must be positive");
    }
    ensure!(cfg.fpgas_per_switch >= 1, "need at least one FPGA per switch");
    ensure!(
        (0.0..1.0).contains(&cfg.net.drop_probability),
        "drop probability must be in [0, 1)"
    );
    let plans = chain_plans(cfg)?;
    let n_clusters: usize = plans.iter().map(|p| p.encoders).sum();
    ensure!(
        n_clusters < EVAL_CLUSTER as usize,
        "fleet needs {n_clusters} cluster ids; only {} fit under the evaluation cluster",
        EVAL_CLUSTER
    );
    ensure!(
        plans.len() <= (u8::MAX - SOURCE_BASE) as usize,
        "too many chains for the evaluation cluster's kernel-id space"
    );
    let (hidden, ffn) = (768usize, 3072usize);

    let slots = crate::ibert::graph::default_slots();
    let per = slots.iter().copied().max().map_or(1, |s| s + 1);
    let sink_global = GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK);

    let mut clusters = Vec::with_capacity(n_clusters + 1);
    let mut behaviors: HashMap<GlobalKernelId, Box<dyn KernelBehavior>> = HashMap::new();
    // first cluster id of each chain, in plan order
    let mut chain_head = Vec::with_capacity(plans.len());
    let mut next_cluster = 0usize;
    for plan in &plans {
        chain_head.push(next_cluster as u8);
        for e in 0..plan.encoders {
            let c = (next_cluster + e) as u8;
            let out_dst = if e + 1 < plan.encoders {
                Out::tagged(GlobalKernelId::new(c + 1, 0), 0)
            } else {
                Out::tagged(sink_global, 0)
            };
            let gp = EncoderGraphParams {
                cluster_id: c,
                fpga_base: per * (c as usize),
                pe: PeConfig::default(),
                mode: Mode::Timing,
                out_dst,
                max_seq: plan.max_seq,
                hidden,
                ffn,
                decode: None,
                batched: false,
            };
            let built = crate::ibert::graph::build_encoder_placed(&gp, &slots);
            for (id, b) in built.behaviors {
                behaviors.insert(GlobalKernelId::new(c, id), b);
            }
            clusters.push(built.cluster);
        }
        next_cluster += plan.encoders;
    }

    // evaluation cluster: gateway + shared streaming sink + one source
    // per chain, all on the last FPGA. The sink FIFO is sized for the
    // worst-case convergence of every chain's largest in-flight request.
    let sink_rows: usize = plans
        .iter()
        .map(|p| p.schedule.iter().map(|r| r.m as usize).max().unwrap_or(1))
        .sum();
    let eval_fpga = FpgaId(per * n_clusters);
    let mut kernels = vec![
        KernelDecl {
            id: 0,
            name: "fleet-gateway".into(),
            ktype: KernelType::Gateway,
            fpga: eval_fpga,
            dests: vec![sink_global],
            fifo_bytes: sink_rows * hidden,
        },
        KernelDecl {
            id: EVAL_SINK,
            name: "fleet-sink".into(),
            ktype: KernelType::Compute,
            fpga: eval_fpga,
            dests: vec![],
            fifo_bytes: sink_rows * hidden,
        },
    ];
    behaviors.insert(
        GlobalKernelId::new(EVAL_CLUSTER, 0),
        Box::new(Gateway::new(GatewayConfig { cluster: EVAL_CLUSTER, virtuals: HashMap::new() })),
    );
    let stats: Arc<Mutex<StreamStats>> = Arc::default();
    behaviors.insert(
        sink_global,
        Box::new(StreamSinkKernel { stats: stats.clone(), cur_cycle: 0, cur_count: 0 }),
    );
    for (chain, plan) in plans.iter().enumerate() {
        let sid = SOURCE_BASE + chain as u8;
        let head = GlobalKernelId::new(chain_head[chain], 0);
        kernels.push(KernelDecl {
            id: sid,
            name: format!("fleet-source-{}", plan.label),
            ktype: KernelType::Compute,
            fpga: eval_fpga,
            dests: vec![head],
            fifo_bytes: 4096,
        });
        // each chain replays its own seed-stream schedule — independent
        // open-loop arrivals, so the replicas never emit in lockstep
        behaviors.insert(
            GlobalKernelId::new(EVAL_CLUSTER, sid),
            Box::new(
                RequestSourceKernel::new(
                    Out::to(head),
                    plan.schedule.clone(),
                    cfg.interval,
                    None,
                    hidden,
                )
                .with_label(&plan.label),
            ),
        );
    }
    clusters.push(ClusterSpec { id: EVAL_CLUSTER, kernels });

    let mut switch_of = HashMap::new();
    for f in 0..=(per * n_clusters) {
        switch_of.insert(FpgaId(f), SwitchId(f / cfg.fpgas_per_switch));
    }
    let spec = PlatformSpec { clusters, switch_of };
    let fpgas = per * n_clusters + 1;
    let mut sim = spec.build_sim(|c, k| {
        behaviors
            .remove(&GlobalKernelId::new(c.id, k.id))
            .unwrap_or_else(|| panic!("no behavior for c{}k{}", c.id, k.id))
    })?;
    if let Some(t) = cfg.threads {
        sim.set_threads(t);
    }
    if let Some(g) = cfg.granularity {
        sim.granularity = g;
    }
    if let Some(b) = cfg.event_budget {
        sim.max_events = b;
    }
    if cfg.profile {
        sim.profile = true;
    }
    sim.fabric.drop_probability = cfg.net.drop_probability;
    sim.fabric.reliable = cfg.net.reliable;
    sim.fabric.seed_drop_rng(cfg.net.seed);

    Ok(FleetSim {
        sim,
        stats,
        expected_rows: plans.iter().map(|p| total_tokens(&p.schedule)).sum(),
        fpgas,
        clusters: n_clusters,
        chains: plans.len(),
    })
}

/// Outcome of one fleet run — everything is a running aggregate; the
/// report's size is independent of fleet size and traffic volume.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    pub fpgas: usize,
    pub clusters: usize,
    pub chains: usize,
    /// output rows the sink received / the count meaning "all done"
    pub rows: u64,
    pub expected_rows: u64,
    pub first_arrival: u64,
    pub last_arrival: u64,
    /// most output rows that landed on one cycle (lockstep observable)
    pub coincident_rows_max: u64,
    pub end_cycle: u64,
    pub events: u64,
    pub dropped: u64,
    pub retransmits: u64,
    /// the event budget stopped the run before quiescence
    pub truncated: bool,
}

impl FleetReport {
    pub fn completed(&self) -> bool {
        self.rows == self.expected_rows
    }
}

/// Build the fleet, run it to quiescence (or the event budget), and
/// distill the streaming aggregates. An exhausted event budget is a
/// truncated report, not an error — that is the point of the profile.
pub fn run_fleet(cfg: &FleetConfig) -> Result<(FleetReport, FleetSim)> {
    let mut fleet = build_fleet(cfg)?;
    fleet.sim.start();
    let truncated = match fleet.sim.run() {
        Ok(_) => false,
        Err(e) if e.to_string().contains("event budget exceeded") => true,
        Err(e) => return Err(e),
    };
    let s = *fleet.stats.lock().unwrap();
    let report = FleetReport {
        fpgas: fleet.fpgas,
        clusters: fleet.clusters,
        chains: fleet.chains,
        rows: s.rows,
        expected_rows: fleet.expected_rows,
        first_arrival: s.first_arrival,
        last_arrival: s.last_arrival,
        coincident_rows_max: s.coincident_rows_max,
        end_cycle: fleet.sim.time,
        events: fleet.sim.trace.events_processed,
        dropped: fleet.sim.fabric.stats.dropped,
        retransmits: fleet.sim.fabric.stats.retransmits,
        truncated,
    };
    Ok((report, fleet))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> FleetConfig {
        FleetConfig {
            chains: 2,
            encoders_per_chain: 1,
            m: 4,
            inferences: 1,
            rate: 20_000.0,
            interval: 12,
            fpgas_per_switch: 6,
            net: NetworkConfig::default(),
            threads: Some(1),
            granularity: None,
            event_budget: None,
            profile: false,
            tenants: None,
            chains_per_tenant: 1,
        }
    }

    #[test]
    fn thousand_fpga_scenario_reaches_1000() {
        let cfg = FleetConfig::thousand_fpga();
        assert!(cfg.total_fpgas() >= 1000, "got {}", cfg.total_fpgas());
        assert!(cfg.chains * cfg.encoders_per_chain < EVAL_CLUSTER as usize);
    }

    #[test]
    fn tiny_fleet_completes_every_row() {
        let (r, _) = run_fleet(&tiny()).unwrap();
        assert!(r.completed(), "{} of {} rows", r.rows, r.expected_rows);
        assert!(!r.truncated);
        assert!(r.last_arrival >= r.first_arrival && r.first_arrival > 0);
        assert_eq!(r.fpgas, 2 * 6 + 1);
    }

    #[test]
    fn fleet_is_thread_count_invariant_even_lossy() {
        let run = |threads: usize| {
            let mut cfg = tiny();
            cfg.chains = 3;
            cfg.threads = Some(threads);
            cfg.net = NetworkConfig { drop_probability: 0.05, reliable: true, seed: 11 };
            let (r, fleet) = run_fleet(&cfg).unwrap();
            (r, fleet.sim.fabric.drop_trace.clone())
        };
        let seq = run(1);
        assert!(seq.0.dropped > 0, "5% loss must drop something");
        assert!(seq.0.completed(), "reliable transport completes every row");
        for threads in [2, 8] {
            assert_eq!(run(threads), seq, "fleet run diverged at threads={threads}");
        }
    }

    #[test]
    fn chain_schedules_are_distinct_deterministic_and_independent() {
        // each chain's Poisson schedule is a pure function of
        // (net.seed, chain): deterministic on re-derivation, distinct
        // across chains, fixed-length rows at the configured m, and
        // never a function of how many chains the fleet has
        let mut cfg = tiny();
        cfg.inferences = 5;
        let scheds: Vec<Vec<Request>> = (0..6).map(|c| chain_schedule(&cfg, c)).collect();
        assert!(scheds.iter().flatten().all(|r| r.m == cfg.m as u32));
        assert!(scheds
            .iter()
            .all(|s| s.windows(2).all(|w| w[0].arrival <= w[1].arrival)));
        assert_eq!(scheds[3], chain_schedule(&cfg, 3), "re-derivation diverged");
        for i in 0..scheds.len() {
            for j in i + 1..scheds.len() {
                assert_ne!(
                    scheds[i], scheds[j],
                    "chains {i} and {j} drew phase-locked schedules"
                );
            }
        }
        // growing the fleet never shifts an existing chain's arrivals
        cfg.chains = 32;
        assert_eq!(chain_schedule(&cfg, 3), scheds[3]);
        // a different net seed re-draws every stream
        let mut reseeded = cfg.clone();
        reseeded.net.seed = 99;
        assert_ne!(chain_schedule(&reseeded, 0), scheds[0]);
    }

    #[test]
    fn chains_do_not_arrive_in_lockstep() {
        // single switch so every chain head sits at the same hop
        // distance from the shared evaluation FPGA: any spread in the
        // chains' first input arrivals is the sources' doing. Lockstep
        // sources (the pre-Poisson constant-interval behavior) would
        // collapse that spread to the shared source NIC's serialization
        // envelope — one row time (interval = 12 cycles at line rate)
        // per chain, i.e. at most 48 cycles across 4 chains — while
        // independent Poisson streams at 20k seqs/s space first
        // arrivals ~10_000 cycles apart on average.
        let mut cfg = tiny();
        cfg.chains = 4;
        cfg.fpgas_per_switch = 32;
        let (r, fleet) = run_fleet(&cfg).unwrap();
        assert!(r.completed());
        let first_rx: Vec<u64> = (0..cfg.chains)
            .map(|chain| {
                let gw = GlobalKernelId::new((chain * cfg.encoders_per_chain) as u8, 0);
                fleet.sim.trace.kernel(gw).and_then(|s| s.first_rx).expect("chain head fed")
            })
            .collect();
        let mut uniq = first_rx.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), cfg.chains, "chain inputs coincide: {first_rx:?}");
        let spread = uniq.last().unwrap() - uniq[0];
        assert!(
            spread > 12 * cfg.chains as u64,
            "chains still near-lockstep: first arrivals {first_rx:?} (spread {spread})"
        );
        // ...and the replicas' outputs no longer pile onto shared cycles
        assert!(r.coincident_rows_max >= 1);
        assert!(
            r.coincident_rows_max < cfg.chains as u64,
            "sink saw {} coincident rows from {} chains",
            r.coincident_rows_max,
            cfg.chains
        );
    }

    #[test]
    fn desynchronized_fleet_is_shard_plan_invariant() {
        // the stagger comes from per-chain pre-generated seed-stream
        // schedules, not from any cross-shard draw order — so the
        // report (including the coincidence stat) must not move with
        // the shard cut or thread count
        let run = |threads: usize, g: ShardGranularity| {
            let mut cfg = tiny();
            cfg.chains = 3;
            cfg.net.seed = 7;
            cfg.threads = Some(threads);
            cfg.granularity = Some(g);
            run_fleet(&cfg).unwrap().0
        };
        let base = run(1, ShardGranularity::PerCluster);
        assert!(base.completed());
        for threads in [1, 8] {
            for g in [ShardGranularity::PerCluster, ShardGranularity::PerFpga] {
                assert_eq!(run(threads, g), base, "diverged at threads={threads} ({g:?})");
            }
        }
    }

    #[test]
    fn tenant_fleet_mixes_shapes_and_completes() {
        use crate::serve::tenant::{TenantClass, TenantSpec, TenantsConfig};

        // two tenants with different chain depths AND build points,
        // replicated twice each: 2*(2+1) clusters on one fabric. The
        // fleet streams each tenant's *offered* schedule (no admission
        // — the fleet path measures fabric behavior under load).
        let tc = TenantsConfig {
            interval: 12,
            fpgas_per_switch: 6,
            tenants: vec![
                TenantSpec {
                    name: "chat".into(),
                    encoders: 2,
                    class: TenantClass::Guaranteed,
                    slo_p99_us: 900.0,
                    kv_slots: 8,
                    requests: 3,
                    process: ArrivalProcess::Poisson { seqs_per_s: 2_000.0 },
                    lengths: LengthDist::Glue,
                    max_m: 16,
                },
                TenantSpec {
                    name: "batch".into(),
                    encoders: 1,
                    class: TenantClass::BestEffort,
                    slo_p99_us: 2_000.0,
                    kv_slots: 16,
                    requests: 2,
                    process: ArrivalProcess::Uniform { seqs_per_s: 4_000.0 },
                    lengths: LengthDist::Mrpc,
                    max_m: 8,
                },
            ],
        };
        let mut cfg = tiny();
        cfg.tenants = Some(tc.clone());
        cfg.chains_per_tenant = 2;
        assert_eq!(cfg.total_fpgas(), 2 * 3 * 6 + 1);
        let (r, _) = run_fleet(&cfg).unwrap();
        assert_eq!(r.chains, 4);
        assert_eq!(r.clusters, 2 * (2 + 1));
        assert_eq!(r.fpgas, 2 * 3 * 6 + 1);
        // expected rows are each tenant's own offered tokens, which the
        // sink must fully receive
        let offered: u64 = (0..2)
            .flat_map(|k| {
                tc.tenants.iter().enumerate().map(move |(i, t)| {
                    total_tokens(&t.schedule(cfg.net.seed, i * cfg.chains_per_tenant + k))
                })
            })
            .sum();
        assert_eq!(r.expected_rows, offered);
        assert!(r.completed(), "{} of {} rows", r.rows, r.expected_rows);
        assert!(!r.truncated);
    }

    #[test]
    fn event_budget_truncates_instead_of_failing() {
        let mut cfg = tiny();
        cfg.event_budget = Some(200);
        let (r, _) = run_fleet(&cfg).unwrap();
        assert!(r.truncated, "200 events cannot finish the run");
        assert!(!r.completed());
    }
}
