//! Testbed assembly: N encoder clusters in a chain plus the evaluation
//! FPGA (§8.2: "one extra FPGA ... to provide inputs and receive outputs
//! for the encoder at 100 Gbps, which emulates how the encoder would be
//! connected in the full encoder chain").
//!
//! Two traffic modes drive the chain: the paper's fixed-length
//! back-to-back inferences ([`SourceKernel`]), or an open-loop request
//! schedule ([`TestbedConfig::schedule`], served by
//! `serve::source::RequestSourceKernel`) in which each request carries
//! its own sequence length and arrival cycle — the serving path of the
//! `serve` subsystem. Encoder-to-encoder edges are real fabric paths:
//! with six FPGAs per encoder and six per switch, LN2 of one encoder
//! reaches the next encoder's gateway across exactly one serial switch
//! hop, the paper's `d` ([`inter_encoder_hop_cycles`]).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::galapagos::cluster::{ClusterSpec, KernelDecl, KernelType, PlatformSpec};
use crate::gmi::gateway::{Gateway, GatewayConfig};
use crate::gmi::Out;
use crate::ibert::graph::EncoderGraphParams;
use crate::ibert::kernels::{Mode, SinkData, SinkKernel, SourceKernel};
use crate::ibert::timing::PeConfig;
use crate::sim::engine::KernelBehavior;
use crate::sim::fabric::{FpgaId, SwitchId};
use crate::sim::packet::GlobalKernelId;
use crate::sim::Sim;

/// Cluster id of the evaluation FPGA.
pub const EVAL_CLUSTER: u8 = 200;
pub const EVAL_SOURCE: u8 = 1;
pub const EVAL_SINK: u8 = 2;

/// Transport behavior of the modeled network (§2.1: Galapagos runs over
/// raw UDP) plus the seed its loss pattern derives from. The default is
/// the lossless happy path ("works well-enough in our testbed").
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct NetworkConfig {
    /// per-copy loss probability on inter-FPGA hops (0 = lossless)
    pub drop_probability: f64,
    /// ack/retransmit reliable transport: lossy runs still deliver every
    /// packet exactly once, each retry charged to the sender's NIC
    pub reliable: bool,
    /// run seed the drop pattern derives from — lossy runs are
    /// seed-deterministic, and different seeds drop differently
    pub seed: u64,
}

/// Kill one FPGA mid-run (§6): its whole cluster goes down for the
/// reconfiguration window while inbound packets buffer at the cluster
/// input; recovery re-places the cluster's kernels off the failed board
/// via `placer::recover` and drains the buffer in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailureSchedule {
    /// global FPGA index to kill (must host encoder-cluster kernels; the
    /// evaluation FPGA cannot fail — it is the measurement harness)
    pub fpga: usize,
    pub at_cycle: u64,
    /// outage length; None = the device's full-bitstream default from
    /// [`crate::placer::recover::ReconfigModel`] (~22.5M cycles on an
    /// XCZU19EG)
    pub recovery_cycles: Option<u64>,
}

/// What `build_testbed` pre-computed for a scheduled failure (the serve
/// report's fault section reads this alongside `Sim::failure_report`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedRecovery {
    pub fpga: usize,
    pub cluster: u8,
    pub moved_kernels: usize,
    pub reconfig_cycles: u64,
    /// the survivors had to overcommit their budgets (degraded mode)
    pub degraded: bool,
}

/// Testbed configuration.
#[derive(Clone)]
pub struct TestbedConfig {
    /// number of chained encoders (1 = the six-FPGA proof of concept;
    /// 12 = the estimated 72-FPGA full I-BERT of Fig. 17)
    pub encoders: usize,
    /// actual sequence length of each inference (no padding)
    pub m: usize,
    /// number of pipelined inferences
    pub inferences: u32,
    /// input packet interval in cycles (12 = line rate, §8.2.2)
    pub interval: u64,
    pub pe: PeConfig,
    pub mode: Mode,
    /// FPGAs per switch (Fig. 17 connects 6 Sidewinders per 100G switch;
    /// switches are chained serially)
    pub fpgas_per_switch: usize,
    /// golden input rows for functional runs
    pub input: Option<Arc<Vec<Vec<i8>>>>,
    /// kernel -> FPGA-slot override from the automatic placer (applied
    /// to every encoder cluster); None = the paper's Fig. 14 mapping
    pub placement: Option<Vec<usize>>,
    /// open-loop request schedule (serving mode): each request streams
    /// its own length at its own arrival cycle, tagged with its index as
    /// the inference id. Overrides `m`/`inferences` pacing; `interval`
    /// still paces rows on the source link.
    pub schedule: Option<Arc<Vec<crate::serve::traffic::Request>>>,
    /// autoregressive decoding (requires `schedule`): each request is a
    /// prefill pass plus `max_new_tokens` single-row decode passes. The
    /// attention/SMM heads switch to per-request KV caching, the last
    /// encoder's output is broadcast back to the source through the eval
    /// gateway, and inference ids advance in blocks of
    /// `1 + max_new_tokens` per request.
    pub decode: Option<crate::serve::traffic::DecodeConfig>,
    /// continuous (iteration-level) batching (requires `decode`): the
    /// eval source becomes the Orca-style batch assembler — at most
    /// `max` sequences hold KV slots, fed-back tokens group into
    /// iteration batches bounded by `window` cycles, and the encoder
    /// linears are built batched (weight-pass + marginal row pricing).
    /// A disabled config (`max <= 1`) is identical to `None`: the run
    /// takes the exact legacy decode path, byte for byte.
    pub batching: Option<crate::serve::traffic::BatchConfig>,
    /// worker threads for the sharded parallel DES (None = the process
    /// default: `--threads` / `PALLAS_SIM_THREADS` / auto; 1 = exact
    /// sequential engine). Results are thread-count-invariant by
    /// contract — this only changes wall-clock.
    pub threads: Option<usize>,
    /// shard cut for the parallel DES (None = the simulator default,
    /// per-cluster). Results are granularity-invariant by contract.
    pub granularity: Option<crate::sim::ShardGranularity>,
    /// lossy-UDP / reliable-transport behavior of the fabric
    pub net: NetworkConfig,
    /// optional §6 failure injection — runs on the sharded engine in
    /// phases around the outage window (`Sim::run_phased_failure`), so
    /// results stay thread-count-invariant without a sequential fallback
    pub fail: Option<FailureSchedule>,
    /// cycle-domain telemetry: span tracing + streaming metrics (off by
    /// default, zero-cost on the hot path when disabled) and the
    /// wall-clock self-profile
    pub obs: crate::obs::ObsSettings,
}

impl TestbedConfig {
    pub fn proof_of_concept(m: usize, mode: Mode) -> Self {
        TestbedConfig {
            encoders: 1,
            m,
            inferences: 1,
            interval: 12,
            pe: PeConfig::default(),
            mode,
            fpgas_per_switch: 6,
            input: None,
            placement: None,
            schedule: None,
            decode: None,
            batching: None,
            threads: None,
            granularity: None,
            net: NetworkConfig::default(),
            fail: None,
            obs: Default::default(),
        }
    }
}

/// The `d` of Eq. 1 as the platform actually implements it: the serial
/// switch-hop cycles between encoder `boundary`'s output kernel (LN2)
/// and encoder `boundary + 1`'s gateway, read off the topology
/// (placement + switch chaining) instead of assumed constant. The
/// paper's Fig. 17 layout (six FPGAs per encoder, six per switch) yields
/// exactly one hop = 220 cycles = 1.1 us at every boundary; when
/// `fpgas_per_switch` does not divide the FPGAs-per-encoder, the hop
/// count varies per boundary — sum this over boundaries rather than
/// multiplying one sample by `L - 1`.
pub fn inter_encoder_hop_cycles(cfg: &TestbedConfig, boundary: usize) -> u64 {
    use crate::ibert::graph::ids;
    let slots = match &cfg.placement {
        Some(s) => s.clone(),
        None => crate::ibert::graph::default_slots(),
    };
    let per = slots.iter().copied().max().map_or(1, |s| s + 1);
    let per_switch = cfg.fpgas_per_switch.max(1);
    let ln2_switch = (boundary * per + slots[ids::LN2 as usize]) / per_switch;
    let next_gw_switch = ((boundary + 1) * per + slots[ids::GATEWAY as usize]) / per_switch;
    next_gw_switch.abs_diff(ln2_switch) as u64 * crate::sim::params::INTER_SWITCH_LAT
}

/// A built testbed: the simulator plus handles into the evaluation FPGA.
pub struct EncoderTestbed {
    pub sim: Sim,
    pub sink: Arc<Mutex<SinkData>>,
    pub sink_id: GlobalKernelId,
    pub spec: PlatformSpec,
    /// the recovery `build_testbed` planned for `TestbedConfig::fail`
    pub recovery: Option<PlannedRecovery>,
    /// batching telemetry recorded by the batch assembler, when
    /// `TestbedConfig::batching` is enabled
    pub batch_log: Option<Arc<Mutex<crate::serve::source::BatchLog>>>,
}

/// Assemble the platform: `encoders` chained encoder clusters + the
/// evaluation cluster, six FPGAs per encoder, eval FPGA last.
pub fn build_testbed(cfg: &TestbedConfig) -> Result<EncoderTestbed> {
    anyhow::ensure!(
        (1..EVAL_CLUSTER as usize).contains(&cfg.encoders),
        "encoder count must be in 1..{EVAL_CLUSTER} (cluster id space)"
    );
    anyhow::ensure!(cfg.fpgas_per_switch >= 1, "need at least one FPGA per switch");
    anyhow::ensure!(
        (0.0..1.0).contains(&cfg.net.drop_probability),
        "drop probability must be in [0, 1) — at 1.0 a reliable link could never deliver"
    );
    let (hidden, ffn, max_seq) = match &cfg.mode {
        Mode::Functional(p) => (p.cfg.hidden, p.cfg.ffn, p.cfg.max_seq),
        Mode::Timing => (768, 3072, 128),
    };
    anyhow::ensure!(
        cfg.decode.is_none() || cfg.schedule.is_some(),
        "decode mode needs a request schedule (each request is one prefill + N token passes)"
    );
    // a disabled batch config (max <= 1) is the legacy decode path
    let batching = cfg.batching.filter(|b| b.enabled());
    anyhow::ensure!(
        batching.is_none() || cfg.decode.is_some(),
        "continuous batching needs decode mode (iteration batches are made of decode tokens)"
    );
    if let Some(sched) = &cfg.schedule {
        let longest = sched.iter().map(|r| r.m as usize).max().unwrap_or(0);
        anyhow::ensure!(longest <= max_seq, "scheduled request exceeds max_seq {max_seq}");
        if let Some(dec) = cfg.decode {
            // the KV caches are sized for max_seq positions at the build
            // point; a prompt that decodes past that would overflow them
            let need = longest + dec.max_new_tokens as usize;
            anyhow::ensure!(
                need <= max_seq,
                "KV-cache overflow: longest prompt ({longest}) + max_new_tokens ({}) = {need} \
                 exceeds the build point's max_seq ({max_seq}); shorten prompts or rebuild \
                 with a larger sequence capacity",
                dec.max_new_tokens
            );
        }
        // a zero-length request would pump the source forever (its
        // row counter can never reach m)
        anyhow::ensure!(
            sched.iter().all(|r| r.m >= 1),
            "scheduled requests must have at least one row"
        );
        if cfg.mode.is_functional() {
            let rows = cfg.input.as_ref().map_or(0, |d| d.len());
            anyhow::ensure!(
                rows >= longest,
                "functional serving needs input rows for the longest request ({longest})"
            );
        }
    }

    // the placer may use more or fewer FPGAs per encoder than Fig. 14's six
    let slots = match &cfg.placement {
        Some(s) => {
            anyhow::ensure!(
                s.len() == crate::ibert::graph::KERNELS_PER_ENCODER,
                "placement must cover all {} encoder kernels",
                crate::ibert::graph::KERNELS_PER_ENCODER
            );
            s.clone()
        }
        None => crate::ibert::graph::default_slots(),
    };
    let slots_per_encoder = slots.iter().copied().max().map_or(1, |s| s + 1);

    let mut clusters = Vec::new();
    let mut behaviors: HashMap<GlobalKernelId, Box<dyn KernelBehavior>> = HashMap::new();

    let sink_global = GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK);

    for e in 0..cfg.encoders {
        let out_dst = if e + 1 < cfg.encoders {
            // next encoder's gateway (its input-broadcast virtual kernel)
            Out::tagged(GlobalKernelId::new(e as u8 + 1, 0), 0)
        } else if cfg.decode.is_some() {
            // decode: route through the eval gateway's virtual module 0,
            // which fans the output back out to the sink AND the source
            // (the feedback edge that triggers the next token pass)
            Out::tagged(GlobalKernelId::new(EVAL_CLUSTER, 0), 0)
        } else {
            Out::tagged(sink_global, 0)
        };
        let gp = EncoderGraphParams {
            cluster_id: e as u8,
            fpga_base: slots_per_encoder * e,
            pe: cfg.pe,
            mode: cfg.mode.clone(),
            out_dst,
            max_seq,
            hidden,
            ffn,
            decode: cfg.decode.map(|d| d.block()),
            batched: batching.is_some(),
        };
        let built = crate::ibert::graph::build_encoder_placed(&gp, &slots);
        for (id, b) in built.behaviors {
            behaviors.insert(GlobalKernelId::new(e as u8, id), b);
        }
        clusters.push(built.cluster);
    }

    // evaluation cluster: gateway (forwarding) + source + sink on one FPGA
    let eval_fpga = FpgaId(slots_per_encoder * cfg.encoders);
    let source_global = GlobalKernelId::new(EVAL_CLUSTER, EVAL_SOURCE);
    let mut gateway_dests = vec![sink_global];
    if cfg.decode.is_some() {
        gateway_dests.push(source_global);
    }
    let eval_cluster = ClusterSpec {
        id: EVAL_CLUSTER,
        kernels: vec![
            KernelDecl {
                id: 0,
                name: "eval-gateway".into(),
                ktype: KernelType::Gateway,
                fpga: eval_fpga,
                dests: gateway_dests,
                fifo_bytes: max_seq * hidden,
            },
            KernelDecl {
                id: EVAL_SOURCE,
                name: "eval-source".into(),
                ktype: KernelType::Compute,
                fpga: eval_fpga,
                dests: vec![GlobalKernelId::new(0, 0)],
                // decode feeds whole output passes back to the source
                fifo_bytes: if cfg.decode.is_some() { max_seq * hidden } else { 4096 },
            },
            KernelDecl {
                id: EVAL_SINK,
                name: "eval-sink".into(),
                ktype: KernelType::Compute,
                fpga: eval_fpga,
                dests: vec![],
                fifo_bytes: max_seq * hidden,
            },
        ],
    };
    let mut virtuals = HashMap::new();
    if cfg.decode.is_some() {
        // virtual module 0: the last encoder's output fans out to the
        // sink (measurement) and back to the source (the feedback edge)
        virtuals.insert(
            0u8,
            crate::gmi::GmiOp::Broadcast {
                dsts: vec![
                    Out::tagged(sink_global, 0),
                    Out::tagged(source_global, crate::serve::source::FEEDBACK_STREAM),
                ],
            },
        );
    }
    behaviors.insert(
        GlobalKernelId::new(EVAL_CLUSTER, 0),
        Box::new(Gateway::new(GatewayConfig { cluster: EVAL_CLUSTER, virtuals })),
    );
    let mut batch_log = None;
    let source: Box<dyn KernelBehavior> = match (&cfg.schedule, cfg.decode) {
        (Some(sched), Some(dec)) if batching.is_some() => {
            let log = Arc::new(Mutex::new(crate::serve::source::BatchLog::default()));
            batch_log = Some(log.clone());
            Box::new(crate::serve::source::BatchSourceKernel::new(
                Out::to(GlobalKernelId::new(0, 0)),
                sched.clone(),
                cfg.interval,
                cfg.input.clone(),
                hidden,
                dec.block(),
                batching.unwrap(),
                log,
            ))
        }
        (Some(sched), Some(dec)) => Box::new(crate::serve::source::DecodeSourceKernel::new(
            Out::to(GlobalKernelId::new(0, 0)),
            sched.clone(),
            cfg.interval,
            cfg.input.clone(),
            hidden,
            dec.block(),
        )),
        (Some(sched), None) => Box::new(crate::serve::source::RequestSourceKernel::new(
            Out::to(GlobalKernelId::new(0, 0)),
            sched.clone(),
            cfg.interval,
            cfg.input.clone(),
            hidden,
        )),
        (None, _) => Box::new(SourceKernel::new(
            Out::to(GlobalKernelId::new(0, 0)),
            cfg.m as u32,
            cfg.inferences,
            cfg.interval,
            cfg.input.clone(),
        )),
    };
    behaviors.insert(source_global, source);
    let (sink, sink_data) = SinkKernel::new();
    behaviors.insert(GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK), Box::new(sink));
    clusters.push(eval_cluster);

    // switch topology: fpgas_per_switch per switch, chained serially
    let mut switch_of = HashMap::new();
    for f in 0..=(slots_per_encoder * cfg.encoders) {
        switch_of.insert(FpgaId(f), SwitchId(f / cfg.fpgas_per_switch));
    }

    let spec = PlatformSpec { clusters, switch_of };
    let mut sim = spec.build_sim(|c, k| {
        behaviors
            .remove(&GlobalKernelId::new(c.id, k.id))
            .unwrap_or_else(|| panic!("no behavior for c{}k{}", c.id, k.id))
    })?;
    if let Some(t) = cfg.threads {
        sim.set_threads(t);
    }
    if let Some(g) = cfg.granularity {
        sim.granularity = g;
    }
    sim.trace.add_probe(sink_global);

    if cfg.obs.enabled {
        // span-role kernels: the request boundary (eval source/sink) and
        // each encoder stage's ingress (gateway) and egress (LN2)
        use crate::ibert::graph::ids;
        let mut marked = vec![
            GlobalKernelId::new(EVAL_CLUSTER, EVAL_SOURCE),
            GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK),
        ];
        for e in 0..cfg.encoders {
            marked.push(GlobalKernelId::new(e as u8, ids::GATEWAY));
            marked.push(GlobalKernelId::new(e as u8, ids::LN2));
        }
        sim.enable_obs(cfg.obs.interval(), &marked);
    }
    if cfg.obs.profile {
        sim.profile = true;
    }

    // §2.1 transport: the drop pattern derives from the run seed, so
    // lossy runs are seed-deterministic (and differ across seeds)
    sim.fabric.drop_probability = cfg.net.drop_probability;
    sim.fabric.reliable = cfg.net.reliable;
    sim.fabric.seed_drop_rng(cfg.net.seed);

    let recovery = match cfg.fail {
        None => None,
        Some(f) => Some(plan_failure(
            cfg,
            &mut sim,
            &spec,
            &slots,
            slots_per_encoder,
            (hidden, ffn, max_seq),
            f,
        )?),
    };

    Ok(EncoderTestbed { sim, sink: sink_data, sink_id: sink_global, spec, recovery, batch_log })
}

/// Turn a [`FailureSchedule`] into an engine [`crate::sim::engine::FailurePlan`]:
/// identify the failed cluster, run the placer's incremental re-place to
/// get the recovery mapping (excluding the failed slot, minimally
/// perturbing the survivors), and arm the engine.
#[allow(clippy::too_many_arguments)]
fn plan_failure(
    cfg: &TestbedConfig,
    sim: &mut Sim,
    spec: &PlatformSpec,
    slots: &[usize],
    slots_per_encoder: usize,
    // build_testbed's already-resolved (hidden, ffn, max_seq) — the
    // recovery must plan against the exact shape the testbed runs
    (hidden, ffn, max_seq): (usize, usize, usize),
    f: FailureSchedule,
) -> Result<PlannedRecovery> {
    use crate::fpga::resources::Device;
    use crate::placer::{self, recover::ReconfigModel, Fleet, ModelShape, Placement};

    let cluster = spec
        .cluster_of(FpgaId(f.fpga))
        .ok_or_else(|| anyhow::anyhow!("--fail: FPGA {} hosts no kernels", f.fpga))?;
    anyhow::ensure!(
        (cluster as usize) < cfg.encoders,
        "--fail: FPGA {} belongs to the evaluation cluster, which is the measurement \
         harness and cannot fail",
        f.fpga
    );
    let base = slots_per_encoder * cluster as usize;
    let failed_slot = f.fpga - base;

    let shape = ModelShape {
        hidden,
        ffn,
        heads: crate::ibert::graph::HEADS as usize,
        max_seq,
        ffn_split: 1,
    };
    // recovery must re-place against the run's real budgets: decode
    // pins KV caches in BRAM, and continuous batching multiplies them
    // by the admission slot count
    let kv_slots = cfg.batching.filter(|b| b.enabled()).map_or(1, |b| b.max);
    let graph = placer::KernelGraph::encoder(shape, cfg.pe)?
        .with_decode(cfg.decode.is_some())
        .with_kv_slots(kv_slots);
    anyhow::ensure!(
        graph.n_kernels() == slots.len(),
        "failure recovery needs a paper-shaped encoder graph ({} kernels, placement has {})",
        graph.n_kernels(),
        slots.len()
    );
    let device = Device::Xczu19eg; // the testbed's Sidewinder fleet
    let fleet = Fleet::homogeneous(device, slots_per_encoder, cfg.fpgas_per_switch);
    let rec = placer::recover::replace_after_failure(
        &graph,
        &Placement { slot_of: slots.to_vec() },
        &fleet,
        failed_slot,
        cfg.m.clamp(1, max_seq),
    )?;

    let reconfig_cycles =
        f.recovery_cycles.unwrap_or_else(|| ReconfigModel::for_device(device).cycles());
    let remap = rec
        .moved
        .iter()
        .map(|mv| (GlobalKernelId::new(cluster, mv.kernel), FpgaId(base + mv.to)))
        .collect();
    sim.schedule_failure(crate::sim::engine::FailurePlan {
        fpga: FpgaId(f.fpga),
        at: f.at_cycle,
        recovery_cycles: reconfig_cycles,
        remap,
    })?;
    Ok(PlannedRecovery {
        fpga: f.fpga,
        cluster,
        moved_kernels: rec.moved.len(),
        reconfig_cycles,
        degraded: rec.degraded,
    })
}

/// One tenant's chain as the testbed builds it: depth, build point, the
/// placer's per-encoder kernel -> local-slot map, and the (already
/// admission-filtered) schedule its source replays.
#[derive(Clone)]
pub struct TenantChain {
    pub name: String,
    pub encoders: usize,
    /// hardware build point (KV/FIFO sizing and the schedule's clamp)
    pub max_m: usize,
    /// per-encoder kernel -> local FPGA slot map from the placer
    pub slots: Vec<usize>,
    /// admitted open-loop schedule (arrival cycles + lengths)
    pub schedule: Arc<Vec<crate::serve::traffic::Request>>,
}

/// Multi-tenant testbed configuration: N independent encoder chains
/// sharing one fleet and one evaluation FPGA.
#[derive(Clone)]
pub struct TenantTestbedConfig {
    pub tenants: Vec<TenantChain>,
    pub interval: u64,
    pub pe: PeConfig,
    pub fpgas_per_switch: usize,
    pub threads: Option<usize>,
    pub granularity: Option<crate::sim::ShardGranularity>,
    /// §6 failure injection: the failed FPGA maps to exactly one
    /// tenant's chain, and recovery re-places only that tenant
    pub fail: Option<FailureSchedule>,
}

/// Where each tenant landed: the slot/cluster arithmetic the serving
/// layer needs to read per-tenant stages back out of the shared trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantLayout {
    /// first cluster id of each tenant's chain (clusters are sequential)
    pub cluster_base: Vec<u8>,
    /// first global FPGA slot of each tenant's chain
    pub chain_base: Vec<usize>,
    /// FPGAs per encoder of each tenant
    pub width: Vec<usize>,
    /// total chain slots; the shared evaluation FPGA sits at this index
    pub total_slots: usize,
}

impl TenantLayout {
    /// Which tenant owns global FPGA slot `fpga` (None: the eval FPGA
    /// or out of range).
    pub fn tenant_of_fpga(&self, fpga: usize) -> Option<usize> {
        (0..self.chain_base.len()).find(|&t| {
            let lo = self.chain_base[t];
            let hi = lo + self.width[t] * self.chain_span(t);
            (lo..hi).contains(&fpga)
        })
    }

    fn chain_span(&self, t: usize) -> usize {
        let next = self
            .chain_base
            .get(t + 1)
            .copied()
            .unwrap_or(self.total_slots);
        (next - self.chain_base[t]) / self.width[t]
    }

    /// The evaluation-cluster kernel id of tenant `t`'s source.
    pub fn source_id(t: usize) -> u8 {
        1 + 2 * t as u8
    }

    /// The evaluation-cluster kernel id of tenant `t`'s sink.
    pub fn sink_id(t: usize) -> u8 {
        2 + 2 * t as u8
    }
}

/// A built multi-tenant testbed: the shared simulator plus per-tenant
/// sink handles (tenant order matches the config).
pub struct TenantTestbed {
    pub sim: Sim,
    pub sinks: Vec<Arc<Mutex<SinkData>>>,
    pub spec: PlatformSpec,
    pub layout: TenantLayout,
    pub recovery: Option<PlannedRecovery>,
    /// index of the tenant the scheduled failure lands on
    pub failed_tenant: Option<usize>,
}

/// Assemble a multi-tenant platform: each tenant's encoder chain on its
/// own contiguous slot range (clusters numbered sequentially across
/// tenants), plus one shared evaluation FPGA carrying a gateway and a
/// per-tenant source/sink pair (`TenantLayout::source_id` /
/// `TenantLayout::sink_id`). The chains share exactly two things: the
/// evaluation FPGA's egress NIC (sources contend there, so co-location
/// shapes timing, as on real hardware) and the analytic switch fabric
/// (fixed per-hop latency, no contention). Everything downstream of
/// ingress — encoder FPGAs, NICs, FIFOs, sinks — is per-tenant, which
/// is what makes one tenant's timeline bit-identical whether or not a
/// *neighbor's* FPGA fails (the failure-isolation contract): sources
/// are open-loop, so an outage never changes what enters the fabric.
pub fn build_tenant_testbed(cfg: &TenantTestbedConfig) -> Result<TenantTestbed> {
    anyhow::ensure!(!cfg.tenants.is_empty(), "need at least one tenant");
    anyhow::ensure!(cfg.fpgas_per_switch >= 1, "need at least one FPGA per switch");
    let total_encoders: usize = cfg.tenants.iter().map(|t| t.encoders).sum();
    anyhow::ensure!(
        (1..EVAL_CLUSTER as usize).contains(&total_encoders),
        "total encoder count must be in 1..{EVAL_CLUSTER} (cluster id space)"
    );
    // two kernel ids per tenant after the gateway must stay in u8 range
    anyhow::ensure!(
        cfg.tenants.len() <= 100,
        "at most 100 tenants (evaluation-FPGA kernel id space)"
    );
    let (hidden, ffn) = (768usize, 3072usize);

    let mut clusters = Vec::new();
    let mut behaviors: HashMap<GlobalKernelId, Box<dyn KernelBehavior>> = HashMap::new();
    let mut layout = TenantLayout {
        cluster_base: Vec::new(),
        chain_base: Vec::new(),
        width: Vec::new(),
        total_slots: 0,
    };
    let mut next_cluster = 0u8;
    let mut next_slot = 0usize;
    for (t, tc) in cfg.tenants.iter().enumerate() {
        anyhow::ensure!(tc.encoders >= 1, "tenant {:?} needs at least one encoder", tc.name);
        anyhow::ensure!(
            tc.slots.len() == crate::ibert::graph::KERNELS_PER_ENCODER,
            "tenant {:?}: placement must cover all {} encoder kernels",
            tc.name,
            crate::ibert::graph::KERNELS_PER_ENCODER
        );
        anyhow::ensure!(
            tc.schedule.iter().all(|r| (1..=tc.max_m as u32).contains(&r.m)),
            "tenant {:?}: scheduled lengths must be in 1..={}",
            tc.name,
            tc.max_m
        );
        let w = tc.slots.iter().copied().max().map_or(1, |s| s + 1);
        layout.cluster_base.push(next_cluster);
        layout.chain_base.push(next_slot);
        layout.width.push(w);
        let sink_global = GlobalKernelId::new(EVAL_CLUSTER, TenantLayout::sink_id(t));
        for e in 0..tc.encoders {
            let cid = next_cluster + e as u8;
            let out_dst = if e + 1 < tc.encoders {
                Out::tagged(GlobalKernelId::new(cid + 1, 0), 0)
            } else {
                Out::tagged(sink_global, 0)
            };
            let gp = EncoderGraphParams {
                cluster_id: cid,
                fpga_base: next_slot + w * e,
                pe: cfg.pe,
                mode: Mode::Timing,
                out_dst,
                max_seq: tc.max_m,
                hidden,
                ffn,
                decode: None,
                batched: false,
            };
            let built = crate::ibert::graph::build_encoder_placed(&gp, &tc.slots);
            for (id, b) in built.behaviors {
                behaviors.insert(GlobalKernelId::new(cid, id), b);
            }
            clusters.push(built.cluster);
        }
        next_cluster += tc.encoders as u8;
        next_slot += w * tc.encoders;
    }
    layout.total_slots = next_slot;

    // shared evaluation FPGA: one gateway + a source/sink pair per tenant
    let eval_fpga = FpgaId(layout.total_slots);
    let max_m_all = cfg.tenants.iter().map(|t| t.max_m).max().unwrap_or(1);
    let mut kernels = vec![KernelDecl {
        id: 0,
        name: "eval-gateway".into(),
        ktype: KernelType::Gateway,
        fpga: eval_fpga,
        dests: (0..cfg.tenants.len())
            .map(|t| GlobalKernelId::new(EVAL_CLUSTER, TenantLayout::sink_id(t)))
            .collect(),
        fifo_bytes: max_m_all * hidden,
    }];
    let mut sinks = Vec::with_capacity(cfg.tenants.len());
    for (t, tc) in cfg.tenants.iter().enumerate() {
        let first_gateway = GlobalKernelId::new(layout.cluster_base[t], 0);
        kernels.push(KernelDecl {
            id: TenantLayout::source_id(t),
            name: format!("eval-source-{}", tc.name),
            ktype: KernelType::Compute,
            fpga: eval_fpga,
            dests: vec![first_gateway],
            fifo_bytes: 4096,
        });
        kernels.push(KernelDecl {
            id: TenantLayout::sink_id(t),
            name: format!("eval-sink-{}", tc.name),
            ktype: KernelType::Compute,
            fpga: eval_fpga,
            dests: vec![],
            fifo_bytes: tc.max_m * hidden,
        });
        behaviors.insert(
            GlobalKernelId::new(EVAL_CLUSTER, TenantLayout::source_id(t)),
            Box::new(
                crate::serve::source::RequestSourceKernel::new(
                    Out::to(first_gateway),
                    tc.schedule.clone(),
                    cfg.interval,
                    None,
                    hidden,
                )
                .with_label(&tc.name),
            ),
        );
        let (sink, sink_data) = SinkKernel::new();
        behaviors.insert(
            GlobalKernelId::new(EVAL_CLUSTER, TenantLayout::sink_id(t)),
            Box::new(sink),
        );
        sinks.push(sink_data);
    }
    behaviors.insert(
        GlobalKernelId::new(EVAL_CLUSTER, 0),
        Box::new(Gateway::new(GatewayConfig { cluster: EVAL_CLUSTER, virtuals: HashMap::new() })),
    );
    clusters.push(ClusterSpec { id: EVAL_CLUSTER, kernels });

    let mut switch_of = HashMap::new();
    for f in 0..=layout.total_slots {
        switch_of.insert(FpgaId(f), SwitchId(f / cfg.fpgas_per_switch));
    }
    let spec = PlatformSpec { clusters, switch_of };
    let mut sim = spec.build_sim(|c, k| {
        behaviors
            .remove(&GlobalKernelId::new(c.id, k.id))
            .unwrap_or_else(|| panic!("no behavior for c{}k{}", c.id, k.id))
    })?;
    if let Some(t) = cfg.threads {
        sim.set_threads(t);
    }
    if let Some(g) = cfg.granularity {
        sim.granularity = g;
    }
    for t in 0..cfg.tenants.len() {
        sim.trace.add_probe(GlobalKernelId::new(EVAL_CLUSTER, TenantLayout::sink_id(t)));
    }

    let (recovery, failed_tenant) = match cfg.fail {
        None => (None, None),
        Some(f) => {
            let (pr, t) = plan_tenant_failure(cfg, &mut sim, &layout, f)?;
            (Some(pr), Some(t))
        }
    };
    Ok(TenantTestbed { sim, sinks, spec, layout, recovery, failed_tenant })
}

/// Tenant-aware failure planning: resolve the failed FPGA to the ONE
/// tenant whose chain hosts it, re-place that tenant's cluster against
/// its own sub-fleet (the placer never sees any other tenant's slots),
/// and arm the engine. Returns the plan plus the owning tenant's index.
fn plan_tenant_failure(
    cfg: &TenantTestbedConfig,
    sim: &mut Sim,
    layout: &TenantLayout,
    f: FailureSchedule,
) -> Result<(PlannedRecovery, usize)> {
    use crate::fpga::resources::Device;
    use crate::placer::{self, recover::ReconfigModel, Fleet, ModelShape, Placement};

    anyhow::ensure!(
        f.fpga != layout.total_slots,
        "--fail: FPGA {} is the shared evaluation FPGA, which is the measurement \
         harness and cannot fail",
        f.fpga
    );
    let t = layout
        .tenant_of_fpga(f.fpga)
        .ok_or_else(|| anyhow::anyhow!("--fail: FPGA {} hosts no kernels", f.fpga))?;
    let tc = &cfg.tenants[t];
    let w = layout.width[t];
    let local_e = (f.fpga - layout.chain_base[t]) / w;
    let cluster = layout.cluster_base[t] + local_e as u8;
    let base = layout.chain_base[t] + w * local_e;
    let failed_slot = f.fpga - base;

    let shape = ModelShape {
        hidden: 768,
        ffn: 3072,
        heads: crate::ibert::graph::HEADS as usize,
        max_seq: tc.max_m,
        ffn_split: 1,
    };
    let graph = placer::KernelGraph::encoder(shape, cfg.pe)?;
    anyhow::ensure!(
        graph.n_kernels() == tc.slots.len(),
        "failure recovery needs a paper-shaped encoder graph ({} kernels, placement has {})",
        graph.n_kernels(),
        tc.slots.len()
    );
    let device = Device::Xczu19eg;
    // the sub-fleet is exactly this tenant's allocation: recovery cannot
    // spill onto (or even observe) another tenant's boards
    let fleet = Fleet::homogeneous(device, w, cfg.fpgas_per_switch);
    let rec = placer::recover::replace_after_failure(
        &graph,
        &Placement { slot_of: tc.slots.clone() },
        &fleet,
        failed_slot,
        tc.max_m.max(1),
    )?;
    let reconfig_cycles =
        f.recovery_cycles.unwrap_or_else(|| ReconfigModel::for_device(device).cycles());
    let remap = rec
        .moved
        .iter()
        .map(|mv| (GlobalKernelId::new(cluster, mv.kernel), FpgaId(base + mv.to)))
        .collect();
    sim.schedule_failure(crate::sim::engine::FailurePlan {
        fpga: FpgaId(f.fpga),
        at: f.at_cycle,
        recovery_cycles: reconfig_cycles,
        remap,
    })?;
    Ok((
        PlannedRecovery {
            fpga: f.fpga,
            cluster,
            moved_kernels: rec.moved.len(),
            reconfig_cycles,
            degraded: rec.degraded,
        },
        t,
    ))
}

/// Measured result of one testbed run, decomposed the way §8.2.2 does.
pub struct EncoderRunResult {
    /// first-output latency at the evaluation sink (cycles)
    pub x: u64,
    /// last-output latency at the evaluation sink (cycles)
    pub t: u64,
    /// median interval between output packets (cycles)
    pub i: u64,
    /// cycle at which the simulation went quiescent (>= `t`; includes
    /// any post-output drain)
    pub end_cycle: u64,
    /// the testbed, for inspecting sink contents / trace / fabric stats
    pub testbed: EncoderTestbed,
}

impl EncoderRunResult {
    /// The (X, T, I) components Eq. 1 extrapolates from.
    pub fn components(&self) -> crate::eval::latency_model::LatencyComponents {
        crate::eval::latency_model::LatencyComponents { x: self.x, t: self.t, i: self.i }
    }
}

/// Convenience: build the testbed, run it to quiescence, and decompose
/// the sink's arrival series into [`EncoderRunResult`].
pub fn run_encoder_once(cfg: &TestbedConfig) -> Result<EncoderRunResult> {
    let mut tb = build_testbed(cfg)?;
    tb.sim.start();
    tb.sim.run()?;
    let (x, t, i) = tb
        .sim
        .trace
        .xti(tb.sink_id)
        .ok_or_else(|| anyhow::anyhow!("no packets reached the evaluation sink"))?;
    Ok(EncoderRunResult { x, t, i, end_cycle: tb.sim.time, testbed: tb })
}
