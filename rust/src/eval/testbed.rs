//! Testbed assembly: N encoder clusters in a chain plus the evaluation
//! FPGA (§8.2: "one extra FPGA ... to provide inputs and receive outputs
//! for the encoder at 100 Gbps, which emulates how the encoder would be
//! connected in the full encoder chain").

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::galapagos::cluster::{ClusterSpec, KernelDecl, KernelType, PlatformSpec};
use crate::gmi::gateway::{Gateway, GatewayConfig};
use crate::gmi::Out;
use crate::ibert::graph::EncoderGraphParams;
use crate::ibert::kernels::{Mode, SinkData, SinkKernel, SourceKernel};
use crate::ibert::timing::PeConfig;
use crate::sim::engine::KernelBehavior;
use crate::sim::fabric::{FpgaId, SwitchId};
use crate::sim::packet::GlobalKernelId;
use crate::sim::Sim;

/// Cluster id of the evaluation FPGA.
pub const EVAL_CLUSTER: u8 = 200;
pub const EVAL_SOURCE: u8 = 1;
pub const EVAL_SINK: u8 = 2;

/// Testbed configuration.
#[derive(Clone)]
pub struct TestbedConfig {
    /// number of chained encoders (1 = the six-FPGA proof of concept;
    /// 12 = the estimated 72-FPGA full I-BERT of Fig. 17)
    pub encoders: usize,
    /// actual sequence length of each inference (no padding)
    pub m: usize,
    /// number of pipelined inferences
    pub inferences: u32,
    /// input packet interval in cycles (12 = line rate, §8.2.2)
    pub interval: u64,
    pub pe: PeConfig,
    pub mode: Mode,
    /// FPGAs per switch (Fig. 17 connects 6 Sidewinders per 100G switch;
    /// switches are chained serially)
    pub fpgas_per_switch: usize,
    /// golden input rows for functional runs
    pub input: Option<Arc<Vec<Vec<i8>>>>,
    /// kernel -> FPGA-slot override from the automatic placer (applied
    /// to every encoder cluster); None = the paper's Fig. 14 mapping
    pub placement: Option<Vec<usize>>,
}

impl TestbedConfig {
    pub fn proof_of_concept(m: usize, mode: Mode) -> Self {
        TestbedConfig {
            encoders: 1,
            m,
            inferences: 1,
            interval: 12,
            pe: PeConfig::default(),
            mode,
            fpgas_per_switch: 6,
            input: None,
            placement: None,
        }
    }
}

/// A built testbed: the simulator plus handles into the evaluation FPGA.
pub struct EncoderTestbed {
    pub sim: Sim,
    pub sink: Arc<Mutex<SinkData>>,
    pub sink_id: GlobalKernelId,
    pub spec: PlatformSpec,
}

/// Assemble the platform: `encoders` chained encoder clusters + the
/// evaluation cluster, six FPGAs per encoder, eval FPGA last.
pub fn build_testbed(cfg: &TestbedConfig) -> Result<EncoderTestbed> {
    let (hidden, ffn, max_seq) = match &cfg.mode {
        Mode::Functional(p) => (p.cfg.hidden, p.cfg.ffn, p.cfg.max_seq),
        Mode::Timing => (768, 3072, 128),
    };

    // the placer may use more or fewer FPGAs per encoder than Fig. 14's six
    let slots = match &cfg.placement {
        Some(s) => {
            anyhow::ensure!(
                s.len() == crate::ibert::graph::KERNELS_PER_ENCODER,
                "placement must cover all {} encoder kernels",
                crate::ibert::graph::KERNELS_PER_ENCODER
            );
            s.clone()
        }
        None => crate::ibert::graph::default_slots(),
    };
    let slots_per_encoder = slots.iter().copied().max().map_or(1, |s| s + 1);

    let mut clusters = Vec::new();
    let mut behaviors: HashMap<GlobalKernelId, Box<dyn KernelBehavior>> = HashMap::new();

    let sink_global = GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK);

    for e in 0..cfg.encoders {
        let out_dst = if e + 1 < cfg.encoders {
            // next encoder's gateway (its input-broadcast virtual kernel)
            Out::tagged(GlobalKernelId::new(e as u8 + 1, 0), 0)
        } else {
            Out::tagged(sink_global, 0)
        };
        let gp = EncoderGraphParams {
            cluster_id: e as u8,
            fpga_base: slots_per_encoder * e,
            pe: cfg.pe,
            mode: cfg.mode.clone(),
            out_dst,
            max_seq,
            hidden,
            ffn,
        };
        let built = crate::ibert::graph::build_encoder_placed(&gp, &slots);
        for (id, b) in built.behaviors {
            behaviors.insert(GlobalKernelId::new(e as u8, id), b);
        }
        clusters.push(built.cluster);
    }

    // evaluation cluster: gateway (forwarding) + source + sink on one FPGA
    let eval_fpga = FpgaId(slots_per_encoder * cfg.encoders);
    let eval_cluster = ClusterSpec {
        id: EVAL_CLUSTER,
        kernels: vec![
            KernelDecl {
                id: 0,
                name: "eval-gateway".into(),
                ktype: KernelType::Gateway,
                fpga: eval_fpga,
                dests: vec![GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK)],
                fifo_bytes: max_seq * hidden,
            },
            KernelDecl {
                id: EVAL_SOURCE,
                name: "eval-source".into(),
                ktype: KernelType::Compute,
                fpga: eval_fpga,
                dests: vec![GlobalKernelId::new(0, 0)],
                fifo_bytes: 4096,
            },
            KernelDecl {
                id: EVAL_SINK,
                name: "eval-sink".into(),
                ktype: KernelType::Compute,
                fpga: eval_fpga,
                dests: vec![],
                fifo_bytes: max_seq * hidden,
            },
        ],
    };
    behaviors.insert(
        GlobalKernelId::new(EVAL_CLUSTER, 0),
        Box::new(Gateway::new(GatewayConfig { cluster: EVAL_CLUSTER, virtuals: HashMap::new() })),
    );
    behaviors.insert(
        GlobalKernelId::new(EVAL_CLUSTER, EVAL_SOURCE),
        Box::new(SourceKernel::new(
            Out::to(GlobalKernelId::new(0, 0)),
            cfg.m as u32,
            cfg.inferences,
            cfg.interval,
            cfg.input.clone(),
        )),
    );
    let (sink, sink_data) = SinkKernel::new();
    behaviors.insert(GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK), Box::new(sink));
    clusters.push(eval_cluster);

    // switch topology: fpgas_per_switch per switch, chained serially
    let mut switch_of = HashMap::new();
    for f in 0..=(slots_per_encoder * cfg.encoders) {
        switch_of.insert(FpgaId(f), SwitchId(f / cfg.fpgas_per_switch));
    }

    let spec = PlatformSpec { clusters, switch_of };
    let mut sim = spec.build_sim(|c, k| {
        behaviors
            .remove(&GlobalKernelId::new(c.id, k.id))
            .unwrap_or_else(|| panic!("no behavior for c{}k{}", c.id, k.id))
    })?;
    sim.trace.add_probe(sink_global);

    Ok(EncoderTestbed { sim, sink: sink_data, sink_id: sink_global, spec })
}

/// Convenience: run one inference through one encoder; returns
/// (X, T, I) in cycles at the evaluation sink plus the testbed.
pub fn run_encoder_once(cfg: &TestbedConfig) -> Result<(u64, u64, u64, EncoderTestbed)> {
    let mut tb = build_testbed(cfg)?;
    tb.sim.start();
    tb.sim.run()?;
    let (x, t, i) = tb
        .sim
        .trace
        .xti(tb.sink_id)
        .ok_or_else(|| anyhow::anyhow!("no packets reached the evaluation sink"))?;
    Ok((x, t, i, tb))
}
