//! Generators for every table and figure of the paper's evaluation
//! (DESIGN.md experiment index E1–E10). Each returns a rendered
//! [`Table`]; the bench targets print paper-vs-measured side by side.

use anyhow::Result;

use crate::baselines::{A100, FTRANS, NPE, T4};
use crate::cluster_builder::layer_builder::fpga_reports;
use crate::cycles_to_us;
use crate::eval::latency_model::{
    estimate_model_latency_us, paper_components, LatencyComponents, PAPER_TABLE2_MS,
};
use crate::eval::testbed::{build_testbed, run_encoder_once, TestbedConfig};
use crate::eval::workload::GlueWorkload;
use crate::fpga::resources::Device;
use crate::gmi::Out;
use crate::ibert::graph::{build_encoder, EncoderGraphParams};
use crate::ibert::kernels::Mode;
use crate::ibert::timing::PeConfig;
use crate::sim::packet::GlobalKernelId;
use crate::util::table::{f2, f3, i0, pct, Table};
use crate::versal::estimate_full_model;
use crate::FABRIC_CLOCK_HZ;

pub const SEQ_LENS: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

/// Run `measure_components` for every length on the worker pool —
/// each length is an independent simulator instance, so the sweeps
/// behind Tables 1/2 and Figs. 16/20 scale with cores.
fn components_sweep(lens: &[usize]) -> Result<Vec<LatencyComponents>> {
    crate::util::pool::parallel_map(lens, |&m| measure_components(m)).into_iter().collect()
}

/// Measure one encoder's X/T/I at sequence length m (timing mode).
pub fn measure_components(m: usize) -> Result<LatencyComponents> {
    let r = run_encoder_once(&TestbedConfig::proof_of_concept(m, Mode::Timing))?;
    Ok(r.components())
}

/// Measure pipelined throughput (inferences/s) at sequence length m by
/// streaming several inferences and taking the median completion gap.
pub fn measure_throughput(m: usize, inferences: u32) -> Result<f64> {
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
    cfg.inferences = inferences;
    let mut tb = build_testbed(&cfg)?;
    tb.sim.start();
    tb.sim.run()?;
    let sink = tb.sink.lock().unwrap();
    let mut completions: Vec<u64> = (0..inferences)
        .map(|i| sink.arrivals.get(&i).map(|&(_, t)| t).unwrap_or(0))
        .collect();
    completions.sort_unstable();
    anyhow::ensure!(completions.len() >= 2, "need >= 2 inferences");
    let mut gaps: Vec<u64> = completions.windows(2).map(|w| w[1] - w[0]).collect();
    gaps.sort_unstable();
    let ii = gaps[gaps.len() / 2];
    Ok(FABRIC_CLOCK_HZ as f64 / ii as f64)
}

/// E1 / Table 1: X, T, I vs sequence length (sim and paper).
pub fn table1() -> Result<Table> {
    let mut t = Table::new(
        "Table 1 — encoder latency components (cycles @200 MHz)",
        &["seq len", "X sim", "T sim", "I sim", "X paper", "T paper", "I paper"],
    );
    for (&m, c) in SEQ_LENS.iter().zip(components_sweep(&SEQ_LENS)?) {
        let p = paper_components(m).unwrap();
        t.row(vec![
            m.to_string(),
            i0(c.x),
            i0(c.t),
            i0(c.i),
            i0(p.x),
            i0(p.t),
            i0(p.i),
        ]);
    }
    Ok(t)
}

/// E2 / Table 2: estimated 12-encoder I-BERT latency (Eq. 1).
/// Reproduction note: the paper's published Table 2 equals Eq. 1 with
/// d = 0 (the 11 x 1.1 us switch term is missing from their own numbers);
/// we print both.
pub fn table2() -> Result<Table> {
    let mut t = Table::new(
        "Table 2 — estimated I-BERT latency (ms), L=12",
        &["seq len", "sim (d=1.1us)", "sim (d=0)", "paper"],
    );
    for (&m, c) in SEQ_LENS.iter().zip(components_sweep(&SEQ_LENS)?) {
        let with_d = estimate_model_latency_us(c, 12, 1.1) / 1e3;
        let no_d = estimate_model_latency_us(c, 12, 0.0) / 1e3;
        let paper = PAPER_TABLE2_MS.iter().find(|(len, _)| *len == m).unwrap().1;
        t.row(vec![m.to_string(), f3(with_d), f3(no_d), f3(paper)]);
    }
    Ok(t)
}

/// E3 / Table 3: batch-1 latency vs GPUs and NPE (ms), padding and
/// no-padding (GLUE average length 38).
pub fn table3() -> Result<Table> {
    let c128 = measure_components(128)?;
    let c38 = measure_components(38)?;
    let ours_padding = estimate_model_latency_us(c128, 12, 1.1) / 1e3;
    let ours_nopad = estimate_model_latency_us(c38, 12, 1.1) / 1e3;
    let npe = NPE.latency_ms_seq128.unwrap();

    let mut t = Table::new(
        "Table 3 — BERT-base INT8 batch-1 latency, max seq 128",
        &["design", "latency (ms)", "relative speedup vs NPE", "paper"],
    );
    let rows: Vec<(&str, f64, &str)> = vec![
        ("NVIDIA T4", T4.batch1_latency_ms, "1.66"),
        ("NVIDIA A100", A100.batch1_latency_ms, "0.77"),
        ("NPE (FPGA)", npe, "13.96"),
        ("ours (padding)", ours_padding, "7.19"),
        ("ours (no padding, avg len 38)", ours_nopad, "2.58"),
    ];
    for (name, ms, paper) in rows {
        t.row(vec![name.into(), f2(ms), f2(npe / ms), paper.into()]);
    }
    Ok(t)
}

/// E4 / Table 4: throughput vs FTRANS / NPE at max seq len 64.
pub fn table4() -> Result<Table> {
    let pad = measure_throughput(64, 4)?;
    let nopad = measure_throughput(38, 4)?;
    let npe = NPE.throughput_inf_s_seq64.unwrap();
    let mut t = Table::new(
        "Table 4 — throughput (inferences/s), max seq 64",
        &["design", "inf/s", "relative vs NPE", "paper"],
    );
    for (name, v, paper) in [
        ("FTRANS", FTRANS.throughput_inf_s_seq64.unwrap(), "101.79"),
        ("NPE", npe, "135.14"),
        ("ours (padding)", pad, "4120.6"),
        ("ours (no padding, avg 38)", nopad, "6802.26"),
    ] {
        t.row(vec![name.into(), f2(v), f2(v / npe), paper.into()]);
    }
    Ok(t)
}

/// E5 / Table 5: throughput vs T4 / A100 at max seq len 128 (GPUs at
/// their batch-128 optimum, the paper's derivation).
pub fn table5() -> Result<Table> {
    let pad = measure_throughput(128, 4)?;
    let nopad = measure_throughput(38, 4)?;
    let mut t = Table::new(
        "Table 5 — throughput (inferences/s), max seq 128",
        &["design", "inf/s", "relative vs T4", "paper"],
    );
    let t4 = T4.throughput_inf_s();
    for (name, v, paper) in [
        ("NVIDIA T4 (batch 128)", t4, "1581.2"),
        ("NVIDIA A100 (batch 128)", A100.throughput_inf_s(), "11962.6"),
        ("ours (padding)", pad, "2023.47"),
        ("ours (no padding, avg 38)", nopad, "6802.26"),
    ] {
        t.row(vec![name.into(), f2(v), f2(v / t4), paper.into()]);
    }
    Ok(t)
}

/// E6 / Fig. 15: per-FPGA resource utilisation of the six-FPGA encoder.
pub fn fig15() -> Result<Table> {
    let cluster = build_encoder(&EncoderGraphParams {
        cluster_id: 0,
        fpga_base: 0,
        pe: PeConfig::default(),
        mode: Mode::Timing,
        out_dst: Out::to(GlobalKernelId::new(200, 2)),
        max_seq: 128,
        hidden: 768,
        ffn: 3072,
        decode: None,
        batched: false,
    })
    .cluster;
    let mut t = Table::new(
        "Fig. 15 — resource utilisation per FPGA (XCZU19EG)",
        &["FPGA", "kernels", "LUT", "FF", "BRAM18", "DSP"],
    );
    for r in fpga_reports(&cluster, &PeConfig::default(), Device::Xczu19eg, 128, 768, 3072) {
        let (l, f, b, d) = r.utilisation();
        t.row(vec![
            format!("FPGA {}", r.fpga + 1),
            r.kernels.len().to_string(),
            pct(l),
            pct(f),
            pct(b),
            pct(d),
        ]);
    }
    Ok(t)
}

/// Standalone per-layer measurement (Fig. 16/20 basis): each layer gets
/// its own mini-testbed fed at line rate — the way the paper measured the
/// per-layer curves (layers 1-2 come out much faster than 0/3/4/5 because
/// they are not waiting behind the QKV linears).
/// Returns (layer name, latency cycles, output interval cycles).
pub fn layer_spans(m: usize) -> Result<Vec<(String, u64, u64)>> {
    use crate::galapagos::cluster::{ClusterSpec, KernelDecl, KernelType, PlatformSpec};
    use crate::ibert::kernels::{
        AttentionHeadKernel, LayerNormKernel, LinearKernel, LinearWhich, LnWhich, SinkKernel,
        SoftmaxMMKernel, SourceKernel,
    };
    use crate::sim::engine::KernelBehavior;
    use crate::sim::fabric::{FpgaId, SwitchId};

    let pe = PeConfig::default();
    let mm = m as u64;

    // run one layer standalone: sources feed each input stream at line
    // rate; the sink probes X/T/I.
    let run_layer = |mk: &dyn Fn(Out) -> Box<dyn KernelBehavior>,
                     srcs: Vec<(u8, usize)>| // (stream tag, row bytes)
     -> Result<(u64, u64, u64)> {
        let sink_id = GlobalKernelId::new(0, 3);
        let mut kernels = vec![KernelDecl {
            id: 0,
            name: "gw".into(),
            ktype: KernelType::Gateway,
            fpga: FpgaId(0),
            dests: vec![],
            fifo_bytes: 1 << 20,
        }];
        let mut behaviors: Vec<(u8, Box<dyn KernelBehavior>)> = Vec::new();
        behaviors.push((0, Box::new(crate::gmi::Gateway::new(Default::default()))));
        // layer under test = kernel 1; sources = 4.. ; sink = 3
        kernels.push(KernelDecl {
            id: 1,
            name: "dut".into(),
            ktype: KernelType::Compute,
            fpga: FpgaId(0),
            dests: vec![sink_id],
            fifo_bytes: 1 << 22,
        });
        behaviors.push((1, mk(Out::tagged(sink_id, 0))));
        kernels.push(KernelDecl {
            id: 3,
            name: "sink".into(),
            ktype: KernelType::Compute,
            fpga: FpgaId(1),
            dests: vec![],
            fifo_bytes: 1 << 22,
        });
        let (sink, _data) = SinkKernel::new();
        behaviors.push((3, Box::new(sink)));
        let mut next = 4u8;
        for (stream, bytes) in srcs {
            kernels.push(KernelDecl {
                id: next,
                name: format!("src{stream}"),
                ktype: KernelType::Compute,
                fpga: FpgaId(1),
                dests: vec![GlobalKernelId::new(0, 1)],
                fifo_bytes: 1 << 20,
            });
            behaviors.push((
                next,
                Box::new(
                    SourceKernel::new(
                        Out::tagged(GlobalKernelId::new(0, 1), stream),
                        m as u32,
                        1,
                        12,
                        None,
                    )
                    .with_row_bytes(bytes),
                ),
            ));
            next += 1;
        }
        // pad ids 2 (unused compute) to keep contiguity
        kernels.push(KernelDecl {
            id: 2,
            name: "unused".into(),
            ktype: KernelType::Compute,
            fpga: FpgaId(0),
            dests: vec![],
            fifo_bytes: 64,
        });
        struct Nop;
        impl KernelBehavior for Nop {
            fn on_packet(&mut self, _: crate::sim::Packet, _: &mut crate::sim::KernelIo) {}
            fn on_wake(&mut self, _: u64, _: &mut crate::sim::KernelIo) {}
        }
        behaviors.push((2, Box::new(Nop)));

        let mut bmap: std::collections::HashMap<u8, Box<dyn KernelBehavior>> =
            behaviors.into_iter().collect();
        let spec = PlatformSpec {
            clusters: vec![ClusterSpec { id: 0, kernels }],
            switch_of: [(FpgaId(0), SwitchId(0)), (FpgaId(1), SwitchId(0))].into_iter().collect(),
        };
        let mut sim = spec.build_sim(|_, k| bmap.remove(&k.id).unwrap())?;
        sim.trace.add_probe(sink_id);
        sim.start();
        sim.run()?;
        sim.trace.xti(sink_id).ok_or_else(|| anyhow::anyhow!("layer produced no output"))
    };

    let mode = Mode::Timing;
    let mut out: Vec<(String, u64, u64)> = Vec::new();

    // layer 0: one QKV linear (three run in parallel; latency identical)
    let (_, t0, i0) = run_layer(
        &|o| Box::new(LinearKernel::new(LinearWhich::Q, o, mode.clone(), &pe)),
        vec![(0, 768)],
    )?;
    out.push(("layer 0 (QKV linears)".into(), t0, i0));

    // layers 1+2 fused in hardware (Kern_4..15): split analytically
    let (_, t12, i12) = run_layer(
        &|o| Box::new(AttentionHeadKernel::new(0, o, mode.clone(), pe)),
        vec![(0, 64), (1, 64)],
    )?;
    let a = pe.attn_row_cycles(mm, 64) as f64;
    let s = pe.softmax_row_cycles(mm) as f64;
    let split = a / (a + s);
    out.push(("layer 1 (attn dot-product)".into(), (t12 as f64 * split) as u64, i12));
    out.push(("layer 2 (softmax)".into(), (t12 as f64 * (1.0 - split)) as u64, i12));

    // layer 3: softmax-MM head
    let (_, t3, i3) = run_layer(
        &|o| Box::new(SoftmaxMMKernel::new(0, o, mode.clone(), pe)),
        vec![(0, m.max(1)), (1, 64)],
    )?;
    out.push(("layer 3 (softmax-MM)".into(), t3, i3));

    // layer 4: projection linear (the Add&Norm streams behind it)
    let (_, t4p, _) = run_layer(
        &|o| Box::new(LinearKernel::new(LinearWhich::Proj, o, mode.clone(), &pe)),
        vec![(0, 768)],
    )?;
    let (_, t4n, i4) = run_layer(
        &|o| Box::new(LayerNormKernel::new(LnWhich::Ln1, o, mode.clone(), pe)),
        vec![(0, 3072), (1, 768)],
    )?;
    let _ = t4n;
    // layer 4's steady-state interval is paced by its slowest stage (proj)
    let i4 = i4.max(pe.qkv_row_cycles(768));
    out.push(("layer 4 (proj + LN)".into(), t4p + pe.ln_row_cycles(768) + pe.pipe_fill, i4));

    // layer 5: FFN1 -> FFN2 -> LN2; latency ~ ffn1 latency + per-row tails
    let (_, t5, i5) = run_layer(
        &|o| Box::new(LinearKernel::new(LinearWhich::Ffn1, o, mode.clone(), &pe)),
        vec![(0, 768)],
    )?;
    let tail = pe.ffn2_row_cycles(768, 3072) + pe.ln_row_cycles(768) + 2 * pe.pipe_fill;
    let i5 = i5.max(pe.ffn1_row_cycles(768, 3072)).max(pe.ffn2_row_cycles(768, 3072));
    out.push(("layer 5 (FFN + LN)".into(), t5 + tail, i5));

    // full encoder from the real six-FPGA testbed
    let c = measure_components(m)?;
    out.push(("full encoder".into(), c.t, c.i.max(1)));
    Ok(out)
}

/// E7 / Fig. 16: latency of the encoder and its six layers vs seq len.
pub fn fig16(lens: &[usize]) -> Result<Table> {
    let mut header = vec!["layer".to_string()];
    header.extend(lens.iter().map(|m| format!("m={m}")));
    let mut t = Table::new(
        "Fig. 16 — latency (us) per layer vs sequence length",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let all: Vec<Vec<(String, u64, u64)>> =
        crate::util::pool::parallel_map(lens, |&m| layer_spans(m))
            .into_iter()
            .collect::<Result<_>>()?;
    for li in 0..all[0].len() {
        let mut row = vec![all[0][li].0.clone()];
        for spans in &all {
            row.push(f2(cycles_to_us(spans[li].1)));
        }
        t.row(row);
    }
    Ok(t)
}

/// E8 / Fig. 20: throughput (inferences/s) of the encoder and its layers.
pub fn fig20(lens: &[usize]) -> Result<Table> {
    let mut header = vec!["layer".to_string()];
    header.extend(lens.iter().map(|m| format!("m={m}")));
    let mut t = Table::new(
        "Fig. 20 — throughput (inferences/s) per layer vs sequence length",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let all: Vec<Vec<(String, u64, u64)>> =
        crate::util::pool::parallel_map(lens, |&m| layer_spans(m))
            .into_iter()
            .collect::<Result<_>>()?;
    for li in 0..all[0].len() {
        let mut row = vec![all[0][li].0.clone()];
        for (j, spans) in all.iter().enumerate() {
            let m = lens[j] as u64;
            let (_, _, interval) = spans[li];
            // single-packet runs observe no interval; fall back to the
            // analytic per-row initiation interval of the layer
            let pe = PeConfig::default();
            let floor = match li {
                0 => pe.qkv_row_cycles(768),
                1 | 2 => pe.attn_row_cycles(m, 64) + pe.softmax_row_cycles(m),
                3 => pe.smm_row_cycles(m, 64),
                4 => pe.qkv_row_cycles(768),
                _ => pe.ffn1_row_cycles(768, 3072),
            };
            let ii = interval.max(floor).max(1) * m;
            row.push(f2(FABRIC_CLOCK_HZ as f64 / ii as f64));
        }
        t.row(row);
    }
    Ok(t)
}

/// E9 / §9.3: the Versal estimate table.
pub fn versal_table() -> Result<Table> {
    let e = estimate_full_model()?;
    let mut t = Table::new(
        "§9.3 — I-BERT on Versal VCK190 (estimate)",
        &["quantity", "ours", "paper"],
    );
    t.row(vec!["AIEs per encoder".into(), e.aies_used.to_string(), "312".into()]);
    t.row(vec!["QKV/proj matmul kernel (us)".into(), f2(e.kernels[0].1), "49".into()]);
    t.row(vec!["attention kernel per head (us)".into(), "16.38".into(), "16".into()]);
    t.row(vec!["FFN matmul kernel (us)".into(), f2(e.kernels[7].1), "49".into()]);
    t.row(vec!["one encoder (us)".into(), f2(e.encoder_us), "124.1".into()]);
    t.row(vec!["full I-BERT, 12 devices (us)".into(), f2(e.model_us), "860".into()]);
    t.row(vec![
        "A100 batch-1 (us)".into(),
        f2(A100.batch1_latency_ms * 1e3),
        "770".into(),
    ]);
    t.row(vec![
        "Versal/A100 latency ratio".into(),
        f2(e.model_us / (A100.batch1_latency_ms * 1e3)),
        "1.12".into(),
    ]);
    Ok(t)
}

/// E10 / §9.4: scalability & communication-overhead microbenchmarks.
pub fn scaling_table() -> Result<Table> {
    use crate::galapagos::router::{full_mesh_entries, hierarchical_entries};
    let mut t = Table::new("§9.4 — scalability and communication overhead", &["quantity", "value"]);
    // routing state scaling
    t.row(vec![
        "routing entries/FPGA, full mesh (256x256)".into(),
        full_mesh_entries(256, 256).to_string(),
    ]);
    t.row(vec![
        "routing entries/FPGA, gateways (2N-1)".into(),
        hierarchical_entries(256, 256).to_string(),
    ]);
    // FPGA-to-FPGA round trip through one switch
    let rtt = 2.0
        * cycles_to_us(
            crate::sim::params::NIC_LAT + crate::sim::params::SWITCH_LAT + crate::sim::params::NIC_LAT,
        );
    t.row(vec!["FPGA-FPGA RTT through one switch (us)".into(), f3(rtt)]);
    t.row(vec!["paper's measured RTT (us)".into(), "0.17".into()]);
    t.row(vec!["Catapult v2 LTL RTT (us, 40G)".into(), "2.88".into()]);
    t.row(vec!["switch-to-switch hop d (us)".into(), f3(cycles_to_us(crate::sim::params::INTER_SWITCH_LAT))]);
    // kernels per encoder / GMI kernels (§9.4)
    t.row(vec!["kernels per encoder cluster".into(), "38".into()]);
    t.row(vec!["GMI kernels per encoder (incl. virtual)".into(), "6".into()]);
    Ok(t)
}

/// GLUE average-length estimate used by Table 3 (the paper's 2.58 ms).
pub fn glue_average_latency_ms() -> Result<(f64, f64)> {
    // paper method: single estimate at the average length
    let c38 = measure_components(38)?;
    let at_mean = estimate_model_latency_us(c38, 12, 1.1) / 1e3;
    // our extension: expectation over the actual length distribution
    let mut w = GlueWorkload::glue(42);
    let lens = w.sample_n(64);
    let mut acc = 0.0;
    let mut cache: std::collections::HashMap<usize, f64> = Default::default();
    for m in lens.iter() {
        let ms = match cache.get(m) {
            Some(&v) => v,
            None => {
                let c = measure_components(*m)?;
                let v = estimate_model_latency_us(c, 12, 1.1) / 1e3;
                cache.insert(*m, v);
                v
            }
        };
        acc += ms;
    }
    Ok((at_mean, acc / lens.len() as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_holds() {
        let t = table1().unwrap();
        assert_eq!(t.rows.len(), 8);
        // X and T monotone increasing in m
        let xs: Vec<u64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        assert!(xs.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn table5_shape_holds() {
        let t = table5().unwrap();
        let vals: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        // A100 > ours(no padding) > ours(padding) > T4
        assert!(vals[1] > vals[3] && vals[3] > vals[2] && vals[2] > vals[0], "{vals:?}");
    }

    #[test]
    fn fig16_attention_layers_fastest() {
        let t = fig16(&[128]).unwrap();
        let get = |i: usize| -> f64 { t.rows[i][1].parse().unwrap() };
        // layers 1-3 faster than 0, 4, 5 (paper Fig. 16's shape)
        assert!(get(1) < get(0) && get(3) < get(0), "{:?}", t.rows);
        assert!(get(6) > get(0), "encoder total dominates");
    }
}
