//! Equation 1 (§8.2.2): full-model latency from one encoder's measured
//! components:  total = T + (L-1) * (X + d).

use crate::cycles_to_us;

/// Measured latency components of one encoder (Table 1), in cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyComponents {
    /// latency until the encoder emits its first output packet
    pub x: u64,
    /// latency until the encoder emits its last output packet
    pub t: u64,
    /// interval between output packets
    pub i: u64,
}

/// Eq. 1 in cycles: T + (L-1)(X + d). `encoders == 0` saturates to the
/// single-encoder term rather than wrapping `(L-1)` around u64::MAX
/// (which release builds would happily do).
pub fn estimate_model_latency_cycles(c: LatencyComponents, encoders: usize, d_cycles: u64) -> u64 {
    c.t + (encoders as u64).saturating_sub(1) * (c.x + d_cycles)
}

/// Eq. 1 in microseconds with d in us (the paper's d = 1.1 us).
pub fn estimate_model_latency_us(c: LatencyComponents, encoders: usize, d_us: f64) -> f64 {
    cycles_to_us(c.t) + encoders.saturating_sub(1) as f64 * (cycles_to_us(c.x) + d_us)
}

/// The paper's own Table 1 measurements (cycles), used to cross-check our
/// simulator's shape and to regenerate Table 2 exactly as published.
pub const PAPER_TABLE1: [(usize, u64, u64, u64); 8] = [
    // (seq len, X, T, I)
    (1, 6_936, 6_936, 0),
    (2, 10_455, 11_004, 275),
    (4, 13_769, 15_869, 525),
    (8, 17_122, 22_318, 650),
    (16, 23_393, 34_781, 712),
    (32, 35_828, 59_600, 743),
    (64, 61_121, 109_660, 759),
    (128, 111_708, 209_789, 767),
];

/// The paper's Table 2 (estimated I-BERT latency, ms).
pub const PAPER_TABLE2_MS: [(usize, f64); 8] = [
    (1, 0.416),
    (2, 0.630),
    (4, 0.837),
    (8, 1.053),
    (16, 1.461),
    (32, 2.269),
    (64, 3.910),
    (128, 7.193),
];

pub fn paper_components(m: usize) -> Option<LatencyComponents> {
    PAPER_TABLE1
        .iter()
        .find(|(len, ..)| *len == m)
        .map(|&(_, x, t, i)| LatencyComponents { x, t, i })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_reproduces_paper_table2() {
        // Reproduction finding (EXPERIMENTS.md E2): the paper's Table 2 is
        // exactly (T + (L-1)X) / 200 MHz — the published numbers do NOT
        // include the d = 1.1 us switch term that Eq. 1 itself includes
        // (a ~12 us constant, <0.2% at m=128 but 3% at m=1). We reproduce
        // the published table with d = 0 and report both in the bench.
        for &(m, want_ms) in &PAPER_TABLE2_MS {
            let c = paper_components(m).unwrap();
            let got_ms = estimate_model_latency_us(c, 12, 0.0) / 1000.0;
            let rel = (got_ms - want_ms).abs() / want_ms;
            assert!(rel < 0.005, "m={m}: got {got_ms:.3} ms want {want_ms} ms");
            // with d included, the difference is exactly 11 * 1.1 us
            let with_d = estimate_model_latency_us(c, 12, 1.1) / 1000.0;
            assert!((with_d - got_ms - 0.0121).abs() < 1e-9);
        }
    }

    #[test]
    fn single_encoder_latency_is_t() {
        let c = LatencyComponents { x: 100, t: 200, i: 5 };
        assert_eq!(estimate_model_latency_cycles(c, 1, 220), 200);
    }

    #[test]
    fn zero_encoders_saturates_instead_of_wrapping() {
        // regression: `encoders as u64 - 1` wrapped in release builds,
        // yielding a ~1.8e19-cycle "estimate" (or a debug panic)
        let c = LatencyComponents { x: 100, t: 200, i: 5 };
        assert_eq!(estimate_model_latency_cycles(c, 0, 220), 200);
        assert!(estimate_model_latency_us(c, 0, 1.1) >= 0.0);
        assert_eq!(estimate_model_latency_us(c, 0, 1.1), cycles_to_us(200));
    }

    #[test]
    fn x_scales_with_depth() {
        let c = LatencyComponents { x: 100, t: 200, i: 5 };
        assert_eq!(estimate_model_latency_cycles(c, 3, 10), 200 + 2 * 110);
    }
}
