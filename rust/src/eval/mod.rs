//! Evaluation harness (§8): testbeds, the Eq. 1 latency model, GLUE-like
//! workloads, and the generators for every table and figure in the paper.

pub mod fleet;
pub mod latency_model;
pub mod tables;
pub mod testbed;
pub mod workload;

pub use latency_model::{estimate_model_latency_us, LatencyComponents};
pub use testbed::{run_encoder_once, EncoderRunResult, EncoderTestbed, TestbedConfig};
