//! GLUE-like synthetic workload (§8.2.2): the paper's no-padding results
//! hinge on the sequence-length distribution of real benchmarks (GLUE
//! average 38, MRPC average 54, max 128).

use crate::util::rng::Rng;

/// A synthetic sequence-length sampler matching published GLUE statistics.
#[derive(Debug, Clone)]
pub struct GlueWorkload {
    pub max_len: usize,
    pub mean: f64,
    rng: Rng,
}

impl GlueWorkload {
    /// The GLUE suite as the paper characterises it: average length 38.
    pub fn glue(seed: u64) -> Self {
        GlueWorkload { max_len: 128, mean: 38.0, rng: Rng::new(seed) }
    }

    /// The MRPC micro-benchmark: average length 54 (§7.1).
    pub fn mrpc(seed: u64) -> Self {
        GlueWorkload { max_len: 128, mean: 54.0, rng: Rng::new(seed) }
    }

    /// A SQuAD-like reading-comprehension workload: long contexts
    /// (mean ~152 tokens, max 384) — well past the GLUE lengths the
    /// paper's 128-token build targets, to exercise placements of
    /// long-sequence encoder builds.
    pub fn squad(seed: u64) -> Self {
        GlueWorkload { max_len: 384, mean: 152.0, rng: Rng::new(seed) }
    }

    /// Sample one sequence length: log-normal-ish positive skew clipped to
    /// [1, max], rescaled so the empirical mean tracks `mean`.
    pub fn sample(&mut self) -> usize {
        // log-normal with sigma=0.55 has mean exp(mu + sigma^2/2)
        let sigma = 0.55f64;
        let mu = self.mean.ln() - sigma * sigma / 2.0;
        let g = self.rng.gauss();
        let len = (mu + sigma * g).exp().round() as i64;
        len.clamp(1, self.max_len as i64) as usize
    }

    pub fn sample_n(&mut self, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.sample()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glue_mean_is_about_38() {
        let mut w = GlueWorkload::glue(7);
        let lens = w.sample_n(20_000);
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!((mean - 38.0).abs() < 2.0, "mean={mean}");
        assert!(lens.iter().all(|&l| (1..=128).contains(&l)));
    }

    #[test]
    fn mrpc_mean_is_about_54() {
        let mut w = GlueWorkload::mrpc(8);
        let lens = w.sample_n(20_000);
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!((mean - 54.0).abs() < 3.0, "mean={mean}");
    }

    #[test]
    fn squad_mean_is_about_152_and_exceeds_glue_max() {
        let mut w = GlueWorkload::squad(9);
        let lens = w.sample_n(20_000);
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!((mean - 152.0).abs() < 8.0, "mean={mean}");
        assert!(lens.iter().all(|&l| (1..=384).contains(&l)));
        // a meaningful fraction of requests is longer than the paper's
        // 128-token build point — the reason long-seq builds exist
        let over = lens.iter().filter(|&&l| l > 128).count();
        assert!(over * 3 > lens.len(), "expected >1/3 of lengths over 128, got {over}");
    }

    #[test]
    fn deterministic_with_seed() {
        assert_eq!(GlueWorkload::glue(1).sample_n(10), GlueWorkload::glue(1).sample_n(10));
    }
}
