//! galapagos-llm — reproduction of *"The Feasibility of Implementing
//! Large-Scale Transformers on Multi-FPGA Platforms"* (Gao, Vega, Chow 2024).
//!
//! The crate is organised the way the paper is:
//!
//! * [`sim`] / [`fpga`] — the hardware substitute: a discrete-event
//!   simulator of streaming FPGA kernels, AXIS FIFOs, routers and a 100G
//!   switch fabric, plus device resource catalogs (XCZU19EG, VCK190).
//! * [`galapagos`] — the base platform (§2.1) and the clusters-of-clusters
//!   scaling scheme (§4): kernels, two-level routing tables, gateways.
//! * [`gmi`] — the Galapagos Messaging Interface (§5): Broadcast / Reduce /
//!   Scatter / Gather kernels, communicator groups, the one-byte
//!   inter-cluster header, and gateway virtual kernels.
//! * [`cluster_builder`] — the automation front-end (§6): JSON cluster /
//!   layer descriptions → kernel graph with GMI insertion, ID assignment
//!   and per-FPGA resource estimates.
//! * [`ibert`] — the test application (§7): bit-exact integer I-BERT
//!   compute (mirrors `python/compile/iops.py`), the 38-kernel encoder
//!   graph of Fig. 14, and the PE/tile timing models behind Table 1.
//! * [`placer`] — the automatic partitioner/placer: maps arbitrary
//!   encoder shapes onto heterogeneous multi-FPGA fleets (the tooling
//!   the paper argues is the missing piece), reproducing the manual
//!   Fig. 14 mapping for the paper's own configuration.
//! * [`runtime`] — PJRT: loads the AOT HLO artifacts produced by
//!   `python/compile/aot.py` and executes them on the request path.
//! * [`versal`] — the §9 analytical AIE model and latency estimator.
//! * [`baselines`] — published GPU/FPGA comparison points (§8 tables).
//! * [`eval`] — Eq. 1 latency model, GLUE-like workloads, and the
//!   generators for every table and figure in the paper's evaluation.
//! * [`obs`] — cycle-domain telemetry: per-request span traces
//!   (Chrome trace-event JSON), constant-memory streaming fleet
//!   metrics, and simulator self-profiling.
//! * [`serve`] — streaming request serving over the simulated pipeline:
//!   open-loop Poisson/uniform traffic through N chained encoders, with
//!   latency percentiles, throughput, per-stage backpressure, and the
//!   Eq. 1 analytic-vs-simulated cross-check.
//! * [`util`] — substrates the offline environment forced us to build:
//!   JSON, RNG, CLI, tables, bench harness, property testing, tensor I/O.

pub mod baselines;
pub mod cluster_builder;
pub mod eval;
pub mod obs;
pub mod fpga;
pub mod galapagos;
pub mod gmi;
pub mod ibert;
pub mod placer;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod versal;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Fabric clock of the simulated UltraScale+ platform, derived from the
/// paper's own numbers (DESIGN.md "Timing model calibration"): 200 MHz.
pub const FABRIC_CLOCK_HZ: u64 = 200_000_000;

/// Convert fabric cycles to microseconds.
pub fn cycles_to_us(cycles: u64) -> f64 {
    cycles as f64 * 1e6 / FABRIC_CLOCK_HZ as f64
}

/// Convert microseconds to fabric cycles (rounded).
pub fn us_to_cycles(us: f64) -> u64 {
    (us * FABRIC_CLOCK_HZ as f64 / 1e6).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_conversions_roundtrip() {
        assert_eq!(cycles_to_us(200), 1.0);
        assert_eq!(us_to_cycles(1.0), 200);
        assert_eq!(us_to_cycles(cycles_to_us(209_789)), 209_789);
    }
}
