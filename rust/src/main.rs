//! galapagos-llm — CLI launcher for the multi-FPGA transformer platform.
//!
//! Subcommands:
//!   tables    regenerate the paper's tables/figures (all or `--only <id>`)
//!   simulate  run the encoder-chain simulator with custom parameters
//!   plan      automatically place an encoder shape onto an FPGA fleet
//!             (prints the mapping, per-FPGA fit, predicted latency; can
//!             replay the placement through the simulator)
//!   build     run the Cluster Builder on a description file (emits Tcl +
//!             build manifest, validates resource fit)
//!   versal    print the §9 Versal estimate
//!   serve     stream open-loop request traffic through an N-encoder
//!             pipeline in the DES (latency percentiles, throughput,
//!             per-stage backpressure, Eq. 1 validation); `--backend
//!             pjrt` serves through the PJRT encoder artifact instead
//!   info      platform/calibration summary + device catalog

use std::sync::Arc;

use anyhow::{bail, Result};
use galapagos_llm::cluster_builder::description::BuildDescription;
use galapagos_llm::cluster_builder::{ip_generator, layer_builder};
use galapagos_llm::eval::tables;
use galapagos_llm::eval::testbed::{build_testbed, EVAL_CLUSTER, EVAL_SINK, EVAL_SOURCE};
use galapagos_llm::eval::workload::GlueWorkload;
use galapagos_llm::gmi::Out;
use galapagos_llm::ibert::encoder::rows_i8;
use galapagos_llm::ibert::graph::{build_encoder, ids, EncoderGraphParams};
use galapagos_llm::ibert::kernels::Mode;
use galapagos_llm::ibert::weights::{load_golden, ModelParams};
use galapagos_llm::obs::{
    render_chrome_trace, render_metrics_jsonl, ObsSettings, RequestOutcome, SpanRoles,
};
use galapagos_llm::placer;
use galapagos_llm::runtime::{EncoderEngine, PjrtRuntime};
use galapagos_llm::sim::packet::GlobalKernelId;
use galapagos_llm::util::cli::Args;
use galapagos_llm::{cycles_to_us, FABRIC_CLOCK_HZ};

const USAGE: &str = "\
galapagos-llm — multi-FPGA transformer feasibility platform (Gao/Vega/Chow 2024 reproduction)

USAGE: galapagos-llm <command> [options]

COMMANDS:
  tables    [--only table1|table2|table3|table4|table5|fig15|fig16|fig20|versal|scaling]
  simulate  [--m 128] [--encoders 1] [--inferences 1] [--functional] [--interval 12]
            [--reference]   (pre-optimization engine: heap queue, no coalescing)
            [--shards cluster|fpga]   (parallel-engine cut granularity)
            [--drop 0.02] [--reliable] [--net-seed 7]   (lossy UDP; --reliable
            adds the ack/retransmit layer: every packet delivered exactly once)
            [--fail <fpga>@<cycle>] [--recovery-cycles N]   (kill an FPGA at a
            cycle; its cluster buffers inbound traffic, recovers via the
            placer's incremental re-place, then drains in order — §6)
            [--trace-out t.json] [--metrics-out m.jsonl] [--metrics-interval N]
            (cycle-domain telemetry: Chrome trace-event spans for Perfetto,
            obs_metrics/v1 JSONL time series) [--profile]   (simulator
            self-profile: wall-ns/cycle, events/window, barrier wait)
  bench     [--quick] [--out BENCH_hotpath.json]
            [--profile]   (self-profile the 12-encoder chain at 1 and N
            threads instead of running the suite)
            [--check [--baseline BENCH_hotpath.json] [--tolerance 0.35]]
            hot-path suite: DES engine (reference vs coalesced vs sharded
            parallel), bit-exact encoder compute (reference vs packed GEMM),
            placer search; writes the perf-trajectory JSON. --check compares
            the fresh headlines against the committed baseline and exits
            nonzero on regression
  plan      [--config configs/ibert_poc.json] [--m <max_seq>] [--fleet N] [--out plan.json]
            [--replay]   (replay needs the ibert-base shape)
            [--tenants configs/tenants_3.json]   (multi-tenant packing:
            place every tenant's kernel graph onto one shared fleet in
            declaration order — prints the per-tenant packing table and
            leftover capacity; --fleet N sizes the shared fleet)
  fleet     [--chains 28] [--encoders 6] [--m 16] [--inferences 1] [--rate 20000]
            [--interval 12]
            [--drop 0.02] [--reliable] [--net-seed 7] [--shards cluster|fpga]
            [--event-budget N]   (stop after N events with a truncated
            report instead of running to quiescence) [--profile]
            synthetic fleet-scale scenario: chains x encoders x 6 FPGAs
            + 1 eval FPGA (defaults reach 1009), constant-memory
            streaming stats, per-chain Poisson arrival streams at --rate
            seqs/s — the thousand-FPGA lossy scenario behind
            benches/fleetscale.rs
            [--tenants configs/tenants_3.json [--chains-per-tenant 2]]
            (heterogeneous fleet: each tenant contributes chains of its
            own depth and build point, streaming its own offered
            schedule — mixed model shapes on one fabric)
  build     [--config configs/ibert_poc.json] [--out target/cluster_build]
  versal
  serve     [--encoders 6] [--requests 200] [--workload glue|mrpc|squad]
            [--arrivals poisson|uniform] [--rate <seqs/s> | --util 0.7]
            [--seed 7] [--interval 12] [--fpgas-per-switch 6] [--no-eq1]
            [--drop 0.02] [--reliable]   (lossy serving; reliable transport
            completes 100% of inferences and reports drop/retransmit counts)
            [--shards cluster|fpga]   (parallel-engine cut granularity —
            reports are identical across cuts and thread counts)
            [--fail <fpga>@<cycle>] [--recovery-cycles N]   (mid-serving
            failover: serving_report/v2 gains the fault section with
            time-to-recover and outage-window percentiles)
            [--place [--config configs/ibert_poc.json]]  (PR 1 placer placement)
            [--out report.json] [--quick]   (CI: writes BENCH_serving.json)
            [--trace-out t.json] [--metrics-out m.jsonl] [--metrics-interval N]
            [--profile]   (telemetry: the report upgrades to serving_report/v3
            with bottleneck attribution; artifacts as in simulate)
            [--decode [--max-new-tokens 8]]   (autoregressive serving: each
            request is one prefill pass + N single-token passes re-entering
            the same pipeline through the eval-gateway feedback edge; the
            report upgrades to serving_report/v4 with time-to-first-token
            and inter-token-latency percentiles + KV-cache occupancy)
            [--batch-max 8 [--batch-window 256]]   (continuous batching:
            ready decode tokens from different requests group into one
            weight-stationary pass of up to batch-max rows, waiting at most
            batch-window cycles for batch-mates; needs --decode, upgrades
            the report to serving_report/v5 with the batching section;
            --batch-max 1 is exactly the unbatched v4 run)
            [--tenants configs/tenants_3.json]   (multi-tenant serving:
            N model graphs packed onto one fleet, SLO-aware admission per
            traffic class, serving_report/v6 with per-tenant TTFT/latency
            percentiles + cross-tenant fairness; composes with --seed,
            --shards, --fail, --threads and --out)
            [--backend sim|pjrt]   (pjrt: [--requests 16] [--encoders 2])
  info

GLOBAL:
  --threads N    worker threads for the sharded DES engine, uniform across
                 simulate/serve/plan/bench (env fallback PALLAS_SIM_THREADS;
                 default = available parallelism; 1 = exact sequential
                 engine — results are identical at every thread count)
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    // --threads applies uniformly to every subcommand's simulator runs
    // (sim/serve/plan/bench); PALLAS_SIM_THREADS is the env fallback
    let threads = args.usize_or("threads", 0)?;
    if threads > 0 {
        galapagos_llm::util::pool::set_sim_threads(threads);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("tables") => cmd_tables(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("bench") => cmd_bench(&args),
        Some("plan") => cmd_plan(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("build") => cmd_build(&args),
        Some("versal") => cmd_versal(),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_tables(args: &Args) -> Result<()> {
    let only = args.str_opt("only");
    let all: Vec<(&str, fn() -> Result<galapagos_llm::util::table::Table>)> = vec![
        ("table1", tables::table1),
        ("table2", tables::table2),
        ("table3", tables::table3),
        ("table4", tables::table4),
        ("table5", tables::table5),
        ("fig15", tables::fig15),
        ("fig16", || tables::fig16(&tables::SEQ_LENS)),
        ("fig20", || tables::fig20(&tables::SEQ_LENS)),
        ("versal", tables::versal_table),
        ("scaling", tables::scaling_table),
    ];
    let mut hit = false;
    for (name, f) in all {
        if only.is_none_or(|o| o == name) {
            println!("{}", f()?.render());
            hit = true;
        }
    }
    if !hit {
        bail!("unknown table id {:?}", only.unwrap());
    }
    Ok(())
}

/// Parse the telemetry flags shared by `simulate` and `serve`:
/// span/metrics collection turns on when either artifact is requested,
/// and `--profile` independently enables the wall-clock self-profile.
fn parse_obs(args: &Args) -> Result<ObsSettings> {
    Ok(ObsSettings {
        enabled: args.str_opt("trace-out").is_some() || args.str_opt("metrics-out").is_some(),
        metrics_interval: args.u64_or("metrics-interval", 0)?,
        profile: args.bool_or("profile", false)?,
    })
}

/// Parse `--fail <fpga>@<cycle>` (+ optional `--recovery-cycles`) into a
/// testbed failure schedule.
fn parse_fail(args: &Args) -> Result<Option<galapagos_llm::eval::testbed::FailureSchedule>> {
    let Some(spec) = args.str_opt("fail") else { return Ok(None) };
    let (fpga, at) = spec
        .split_once('@')
        .ok_or_else(|| anyhow::anyhow!("--fail expects <fpga>@<cycle>, got {spec:?}"))?;
    let recovery_cycles =
        if args.has("recovery-cycles") { Some(args.u64_or("recovery-cycles", 0)?) } else { None };
    Ok(Some(galapagos_llm::eval::testbed::FailureSchedule {
        fpga: fpga.parse().map_err(|_| anyhow::anyhow!("--fail: bad FPGA index {fpga:?}"))?,
        at_cycle: at.parse().map_err(|_| anyhow::anyhow!("--fail: bad cycle {at:?}"))?,
        recovery_cycles,
    }))
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let m = args.usize_or("m", 128)?;
    let encoders = args.usize_or("encoders", 1)?;
    let inferences = args.u64_or("inferences", 1)? as u32;
    let interval = args.u64_or("interval", 12)?;
    let functional = args.bool_or("functional", false)?;
    let reference = args.bool_or("reference", false)?;

    let dir = ModelParams::default_dir();
    let (mode, input) = if functional {
        let p = Arc::new(ModelParams::load(&dir)?);
        let x = rows_i8(load_golden(&dir, "input_m128")?.as_i8()?)[..m].to_vec();
        (Mode::Functional(p), Some(Arc::new(x)))
    } else {
        (Mode::Timing, None)
    };

    let mut cfg = galapagos_llm::eval::testbed::TestbedConfig::proof_of_concept(m, mode);
    cfg.encoders = encoders;
    cfg.inferences = inferences;
    cfg.interval = interval;
    cfg.input = input;
    cfg.net.drop_probability = args.f64_or("drop", 0.0)?;
    cfg.net.reliable = args.bool_or("reliable", false)?;
    cfg.net.seed = args.u64_or("net-seed", 0)?;
    cfg.fail = parse_fail(args)?;
    cfg.obs = parse_obs(args)?;
    let mut tb = build_testbed(&cfg)?;
    tb.sim.granularity = match args.str_or("shards", "cluster").as_str() {
        "cluster" => galapagos_llm::sim::ShardGranularity::PerCluster,
        "fpga" => galapagos_llm::sim::ShardGranularity::PerFpga,
        other => bail!("unknown shard granularity {other:?} (expected cluster|fpga)"),
    };
    if reference {
        tb.sim.reference_mode();
    }
    println!(
        "platform: {} kernels / {} FPGAs / {} switches; mode={}",
        tb.sim.kernel_count(),
        tb.spec.switch_of.len(),
        tb.spec.switch_of.values().collect::<std::collections::HashSet<_>>().len(),
        if functional { "functional" } else { "timing" },
    );
    let t0 = std::time::Instant::now();
    tb.sim.start();
    tb.sim.run()?;
    let wall = t0.elapsed();
    let (x, t, i) = tb.sim.trace.xti(tb.sink_id).unwrap_or((0, 0, 0));
    println!(
        "X = {x} cycles ({:.2} us)   T = {t} cycles ({:.2} us)   I = {i} cycles",
        cycles_to_us(x),
        cycles_to_us(t)
    );
    println!(
        "events: {}   wakes: {}   packets: {}   flits: {}   wall: {:.1} ms ({:.2} M events/s)",
        tb.sim.trace.events_processed,
        tb.sim.trace.kernels().map(|(_, s)| s.wakes).sum::<u64>(),
        tb.sim.fabric.stats.packets,
        tb.sim.fabric.stats.flits,
        wall.as_secs_f64() * 1e3,
        tb.sim.trace.events_processed as f64 / wall.as_secs_f64() / 1e6
    );
    let fs = &tb.sim.fabric.stats;
    if fs.dropped > 0 || fs.retransmits > 0 {
        println!(
            "transport: {} copies dropped, {} retransmitted ({})",
            fs.dropped,
            fs.retransmits,
            if cfg.net.reliable { "reliable: delivered exactly once" } else { "unreliable" }
        );
    }
    if let (Some(pr), Some(fr)) = (tb.recovery, tb.sim.failure_report()) {
        println!(
            "fault: FPGA {} (cluster {}) down at {} for {} cycles ({:.2} ms); {} kernels \
             re-placed{}; {} packets buffered, {} events lost, recovered: {}",
            pr.fpga,
            pr.cluster,
            fr.fail_cycle,
            pr.reconfig_cycles,
            cycles_to_us(pr.reconfig_cycles) / 1e3,
            pr.moved_kernels,
            if pr.degraded { " (degraded: survivors overcommitted)" } else { "" },
            fr.held_packets,
            fr.lost_events,
            fr.recovered
        );
    }
    if inferences > 1 {
        let sink = tb.sink.lock().unwrap();
        let mut done: Vec<u64> =
            (0..inferences).filter_map(|i| sink.arrivals.get(&i).map(|&(_, t)| t)).collect();
        done.sort_unstable();
        if done.len() >= 2 {
            let ii = (done[done.len() - 1] - done[0]) / (done.len() as u64 - 1);
            println!("pipelined II = {ii} cycles  ->  {:.1} inferences/s",
                     FABRIC_CLOCK_HZ as f64 / ii as f64);
        }
    }

    // telemetry artifacts: derive the span trace / metrics stream from
    // the run's collectors (inference i "arrives" at its first source tx)
    if cfg.obs.enabled {
        if let Some(tobs) = tb.sim.trace.obs.as_deref() {
            let src_dense = GlobalKernelId::new(EVAL_CLUSTER, EVAL_SOURCE).dense() as u32;
            let outcomes: Vec<RequestOutcome> = {
                let sink = tb.sink.lock().unwrap();
                (0..inferences)
                    .map(|i| RequestOutcome {
                        inference: i,
                        arrival: tobs
                            .mark(src_dense, i)
                            .and_then(|mk| mk.first_tx)
                            .unwrap_or(0),
                        m: m as u32,
                        done: sink
                            .arrivals
                            .get(&i)
                            .and_then(|&(pkts, done)| (pkts == m as u32).then_some(done)),
                    })
                    .collect()
            };
            let roles = SpanRoles {
                source: Some(src_dense),
                stages: (0..encoders)
                    .map(|e| {
                        (
                            GlobalKernelId::new(e as u8, ids::GATEWAY).dense() as u32,
                            GlobalKernelId::new(e as u8, ids::LN2).dense() as u32,
                        )
                    })
                    .collect(),
                sink: Some(GlobalKernelId::new(EVAL_CLUSTER, EVAL_SINK).dense() as u32),
            };
            let fobs = tb.sim.fabric.obs.as_deref();
            if let Some(path) = args.str_opt("trace-out") {
                std::fs::write(path, render_chrome_trace(&outcomes, &roles, tobs, fobs))?;
                println!("trace written to {path}");
            }
            if let Some(path) = args.str_opt("metrics-out") {
                let text = render_metrics_jsonl(
                    &tb.sim.trace,
                    tobs,
                    fobs,
                    &tb.sim.fifo_snapshots(),
                    &tb.sim.fabric.stats,
                    tb.sim.time,
                );
                std::fs::write(path, text)?;
                println!("metrics written to {path}");
            }
        }
    }
    if let Some(p) = tb.sim.last_profile.as_ref() {
        println!("{}", p.render());
    }
    Ok(())
}

fn push_bench_case(
    cases: &mut Vec<galapagos_llm::util::json::Json>,
    name: &str,
    variant: &str,
    median_ns: f64,
    events: u64,
    rows: u64,
) {
    use galapagos_llm::util::json::Json;
    let per_s = |n: u64| if median_ns > 0.0 { n as f64 / (median_ns / 1e9) } else { 0.0 };
    cases.push(Json::obj(vec![
        ("name", Json::Str(name.into())),
        ("variant", Json::Str(variant.into())),
        ("median_ns", Json::Num(median_ns)),
        ("events", Json::Num(events as f64)),
        ("events_per_s", Json::Num(per_s(events))),
        ("rows_per_s", Json::Num(per_s(rows))),
    ]));
}

/// Benchmark one testbed configuration under one engine mode; returns
/// the median ns per full simulation run.
fn bench_sim_case(
    b: &mut galapagos_llm::util::bench::Bencher,
    cases: &mut Vec<galapagos_llm::util::json::Json>,
    label: &str,
    cfg: &galapagos_llm::eval::testbed::TestbedConfig,
    reference: bool,
) -> Result<f64> {
    use galapagos_llm::util::bench::black_box;
    let mut tb = build_testbed(cfg)?;
    if reference {
        tb.sim.reference_mode();
    }
    tb.sim.start();
    tb.sim.run()?;
    let events = tb.sim.trace.events_processed;
    let rows = tb.sim.fabric.stats.packets;
    let variant = if reference { "reference" } else { "coalesced" };
    let r = b.bench(&format!("{label} [{variant}] ({events} events)"), || {
        let mut tb = build_testbed(cfg).unwrap();
        if reference {
            tb.sim.reference_mode();
        }
        tb.sim.start();
        black_box(tb.sim.run().unwrap());
    });
    let med = r.median_ns();
    push_bench_case(cases, label, variant, med, events, rows);
    Ok(med)
}

/// The hot-path suite: DES engine (reference heap/per-row vs calendar
/// wheel + coalescing), native bit-exact encoder compute (row-at-a-time
/// vs blocked+parallel), and the placer search. Writes BENCH_hotpath.json
/// so the perf trajectory is tracked in-repo (ROADMAP "as fast as the
/// hardware allows"; CI uploads the quick run as an artifact).
fn cmd_bench(args: &Args) -> Result<()> {
    use galapagos_llm::eval::testbed::TestbedConfig;
    use galapagos_llm::ibert::config::ModelConfig;
    use galapagos_llm::ibert::encoder::{encoder_forward, encoder_forward_reference};
    use galapagos_llm::ibert::weights::synthetic_input;
    use galapagos_llm::util::bench::{black_box, Bencher};
    use galapagos_llm::util::json::Json;
    use galapagos_llm::util::pool;

    if args.bool_or("profile", false)? {
        return cmd_bench_profile(args);
    }
    let quick = args.bool_or("quick", false)?;
    let out_path = args.str_or("out", "BENCH_hotpath.json");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut cases: Vec<Json> = Vec::new();
    let mut headlines: std::collections::BTreeMap<String, Json> = Default::default();
    let headline = |headlines: &mut std::collections::BTreeMap<String, Json>,
                    key: &str,
                    reference_ns: f64,
                    optimized_ns: f64| {
        let speedup = reference_ns / optimized_ns.max(1.0);
        println!("    -> {key}: {speedup:.2}x");
        headlines.insert(key.to_string(), Json::Num(speedup));
    };

    // --- DES engine: timing-mode encoder runs ---
    for m in [38usize, 128] {
        let label = format!("sim timing m={m}");
        let cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
        let ref_ns = bench_sim_case(&mut b, &mut cases, &label, &cfg, true)?;
        let opt_ns = bench_sim_case(&mut b, &mut cases, &label, &cfg, false)?;
        headline(&mut headlines, &format!("sim_timing_m{m}_speedup"), ref_ns, opt_ns);
    }

    // --- DES engine: functional (bit-exact payloads), synthetic model ---
    {
        let cfg_small =
            ModelConfig { hidden: 96, heads: 12, ffn: 384, max_seq: 32, num_encoders: 1 };
        let params = Arc::new(galapagos_llm::ibert::weights::ModelParams::synthetic(
            cfg_small, 0xBE9C4,
        ));
        let m = 24;
        let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Functional(params));
        cfg.input = Some(Arc::new(synthetic_input(cfg_small.hidden, m, 7)));
        let label = format!("sim functional m={m} (synthetic h=96)");
        let ref_ns = bench_sim_case(&mut b, &mut cases, &label, &cfg, true)?;
        let opt_ns = bench_sim_case(&mut b, &mut cases, &label, &cfg, false)?;
        headline(&mut headlines, "sim_functional_m24_speedup", ref_ns, opt_ns);
    }

    // --- telemetry: the disabled path must stay free, the enabled path
    //     cheap (the `telemetry_on_efficiency` headline is off_ns/on_ns,
    //     ~1.0 when collection costs nothing) ---
    {
        let mut cfg = TestbedConfig::proof_of_concept(38, Mode::Timing);
        cfg.inferences = 4;
        let run_variant = |variant: &str,
                               cfg: &TestbedConfig,
                               b: &mut Bencher,
                               cases: &mut Vec<Json>|
         -> Result<f64> {
            let mut tb = build_testbed(cfg)?;
            tb.sim.start();
            tb.sim.run()?;
            let events = tb.sim.trace.events_processed;
            let r = b.bench(&format!("sim m=38 telemetry {variant} ({events} events)"), || {
                let mut tb = build_testbed(cfg).unwrap();
                tb.sim.start();
                black_box(tb.sim.run().unwrap());
            });
            push_bench_case(cases, "sim m=38 telemetry", variant, r.median_ns(), events, 0);
            Ok(r.median_ns())
        };
        let off_ns = run_variant("off", &cfg, &mut b, &mut cases)?;
        cfg.obs.enabled = true;
        let on_ns = run_variant("on", &cfg, &mut b, &mut cases)?;
        headline(&mut headlines, "telemetry_on_efficiency", off_ns, on_ns);
    }

    // --- native compute: bit-exact encoder forward ---
    {
        let dir = ModelParams::default_dir();
        let (params, x) = match ModelParams::load(&dir) {
            Ok(p) => {
                let x = rows_i8(load_golden(&dir, "input_m128")?.as_i8()?);
                (p, x)
            }
            Err(_) => {
                println!("(artifacts absent: benching the native path on a synthetic model)");
                let cfg = ModelConfig::default();
                let x = synthetic_input(cfg.hidden, 128, 11);
                (galapagos_llm::ibert::weights::ModelParams::synthetic(cfg, 0xF00D), x)
            }
        };
        for m in [38usize, 128] {
            let r = b.bench(&format!("native encoder_forward m={m} [reference]"), || {
                black_box(encoder_forward_reference(&params, &x[..m]));
            });
            let ref_ns = r.median_ns();
            push_bench_case(
                &mut cases,
                &format!("native encoder_forward m={m}"),
                "reference",
                ref_ns,
                0,
                m as u64,
            );
            let r = b.bench(&format!("native encoder_forward m={m} [blocked+parallel]"), || {
                black_box(encoder_forward(&params, &x[..m]));
            });
            let opt_ns = r.median_ns();
            push_bench_case(
                &mut cases,
                &format!("native encoder_forward m={m}"),
                "optimized",
                opt_ns,
                0,
                m as u64,
            );
            headline(&mut headlines, &format!("native_m{m}_speedup"), ref_ns, opt_ns);
        }
    }

    // --- placer search (sim-calibrated cost model + parallel candidates) ---
    {
        let r = b.bench("placer: ibert-base on the paper fleet", || {
            black_box(
                placer::place(
                    &placer::ModelShape::ibert_base(),
                    &galapagos_llm::ibert::timing::PeConfig::default(),
                    &placer::Fleet::paper(),
                    &placer::SearchParams::default(),
                )
                .unwrap(),
            );
        });
        let med = r.median_ns();
        push_bench_case(&mut cases, "placer search (paper fleet)", "optimized", med, 0, 0);
    }

    // --- sharded parallel DES: the 12-encoder serving-scale chain ---
    // (the acceptance scenario: >= 2x events/s at 8 threads vs threads=1)
    let sim_threads = pool::sim_threads();
    {
        let m = if quick { 38 } else { 128 };
        let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
        cfg.encoders = 12;
        cfg.inferences = if quick { 2 } else { 6 };
        let label = format!("sim 12-encoder chain m={m}");
        let bench_threads = |threads: usize,
                             b: &mut galapagos_llm::util::bench::Bencher,
                             cases: &mut Vec<Json>|
         -> Result<f64> {
            let mut cfg = cfg.clone();
            cfg.threads = Some(threads);
            let mut tb = build_testbed(&cfg)?;
            tb.sim.start();
            tb.sim.run()?;
            let events = tb.sim.trace.events_processed;
            let rows = tb.sim.fabric.stats.packets;
            let r = b.bench(&format!("{label} [threads={threads}] ({events} events)"), || {
                let mut tb = build_testbed(&cfg).unwrap();
                tb.sim.start();
                black_box(tb.sim.run().unwrap());
            });
            let variant = format!("threads={threads}");
            push_bench_case(cases, &label, &variant, r.median_ns(), events, rows);
            Ok(r.median_ns())
        };
        let seq_ns = bench_threads(1, &mut b, &mut cases)?;
        let par_ns = bench_threads(sim_threads.max(2), &mut b, &mut cases)?;
        headline(&mut headlines, "parallel_sim_12enc_speedup", seq_ns, par_ns);
    }

    let doc = Json::obj(vec![
        ("schema", Json::Str("bench_hotpath/v1".into())),
        ("mode", Json::Str(if quick { "quick" } else { "full" }.into())),
        ("threads", Json::Num(pool::num_threads() as f64)),
        ("sim_threads", Json::Num(sim_threads as f64)),
        ("cases", Json::Arr(cases)),
        ("headlines", Json::from_map(&headlines)),
    ]);

    // --check: read the committed baseline BEFORE overwriting the
    // trajectory file, then fail on any regressed headline
    let regressions = galapagos_llm::util::bench::load_check(args, &doc, &out_path)?;
    std::fs::write(&out_path, doc.pretty())?;
    println!("\nwrote {out_path} (speedup target: >= 3x sim/native, >= 2x parallel@8t)");
    galapagos_llm::util::bench::report_check(regressions)?;
    Ok(())
}

/// `bench --profile`: self-profile the 12-encoder serving-scale chain
/// instead of running the suite — sequential vs parallel engine, with
/// events/window, barrier-wait share, and wall-ns per simulated cycle.
fn cmd_bench_profile(args: &Args) -> Result<()> {
    use galapagos_llm::eval::testbed::TestbedConfig;
    use galapagos_llm::util::pool;

    let quick = args.bool_or("quick", false)?;
    let m = if quick { 38 } else { 128 };
    let mut cfg = TestbedConfig::proof_of_concept(m, Mode::Timing);
    cfg.encoders = 12;
    cfg.inferences = if quick { 2 } else { 6 };
    cfg.obs.profile = true;
    println!("self-profiling the 12-encoder chain @ m={m}, {} inference(s)", cfg.inferences);
    for threads in [1usize, pool::sim_threads().max(2)] {
        let mut c = cfg.clone();
        c.threads = Some(threads);
        let mut tb = build_testbed(&c)?;
        tb.sim.start();
        tb.sim.run()?;
        let p = tb.sim.last_profile.as_ref().expect("profiling was enabled");
        println!("[threads={threads}] {}", p.render());
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    if args.str_opt("tenants").is_some() {
        return cmd_plan_tenants(args);
    }
    let cfg_path = args.str_or("config", "configs/ibert_poc.json");
    let d = if std::path::Path::new(&cfg_path).exists() {
        BuildDescription::load(&cfg_path)?
    } else {
        println!("note: {cfg_path} not found, planning the default ibert-base description");
        BuildDescription::default()
    };
    let m = args.usize_or("m", d.max_seq)?;
    let shape = d.shape();
    let mut fleet = d.fleet();
    if args.has("fleet") {
        anyhow::ensure!(
            d.devices.is_none(),
            "--fleet would discard the config's explicit heterogeneous `devices` list; \
             edit the config (or drop --fleet) instead"
        );
        let n = args.usize_or("fleet", fleet.n_slots())?;
        fleet = placer::Fleet::homogeneous(d.device, n, d.fpgas_per_switch)
            .with_util_cap(d.util_cap);
    }
    println!(
        "placing {} (hidden={} ffn={} heads={} max_seq={}) onto {} FPGA(s), {} per switch",
        d.model,
        shape.hidden,
        shape.ffn,
        shape.heads,
        shape.max_seq,
        fleet.n_slots(),
        fleet.fpgas_per_switch
    );

    let sol = placer::place(&shape, &d.pe, &fleet, &placer::SearchParams::for_m(m))?;
    println!("{}", placer::report::placement_table(&sol.graph, &sol.placement, &fleet).render());
    let reports = placer::validate::check(&sol.graph, &sol.placement, &fleet)?;
    println!("{}", placer::report::utilisation_table(&reports).render());
    let d_cycles = galapagos_llm::sim::params::INTER_SWITCH_LAT;
    println!("{}", placer::report::latency_summary(&sol, m, d.encoders, d_cycles));
    match placer::cost::min_lookahead_cycles(&sol.placement, &fleet) {
        Some(la) => {
            println!(
                "parallel-sim lookahead: >= {la} cycles ({:.2} us) at the finest (per-FPGA) \
                 shard cut; the default per-encoder cut is at least this",
                cycles_to_us(la)
            );
            let retx = placer::cost::retx_aware_lookahead_cycles(&sol.placement, &fleet)
                .expect("same placement yielded a lookahead above");
            println!(
                "  with reliable lossy transport: >= {retx} cycles ({:.2} us){}",
                cycles_to_us(retx),
                if retx < la { " — clamped to RETX_TIMEOUT" } else { "" }
            );
            if retx < placer::cost::PROFITABLE_WINDOW_CYCLES {
                println!(
                    "  WARNING: the retransmit clamp shrinks the conservative window below \
                     {} cycles; parallel lossy runs on this placement will be \
                     barrier-dominated — consider --threads 1",
                    placer::cost::PROFITABLE_WINDOW_CYCLES
                );
            }
        }
        None => println!("parallel-sim lookahead: n/a (single-FPGA placement runs sequentially)"),
    }

    if let Some(out) = args.str_opt("out") {
        let plan = placer::Plan {
            shape: sol.graph.shape,
            fleet: fleet.clone(),
            placement: sol.placement.clone(),
            predicted: sol.predicted,
        };
        std::fs::write(out, plan.to_json().pretty())?;
        println!("plan written to {out}");
    }

    if args.bool_or("replay", false)? {
        let (x, t, i) =
            placer::validate::replay_in_simulator(&sol.graph, &sol.placement, &fleet, m)?;
        let (px, pt) = (sol.predicted.x, sol.predicted.t);
        println!(
            "simulator replay @ m={m}: X = {x} ({:.2} us)  T = {t} ({:.2} us)  I = {i}",
            cycles_to_us(x),
            cycles_to_us(t)
        );
        println!(
            "cost model error: X {:+.1}%  T {:+.1}%",
            100.0 * (px as f64 - x as f64) / x as f64,
            100.0 * (pt as f64 - t as f64) / t as f64
        );
    }
    Ok(())
}

/// `plan --tenants <config>`: pack every tenant's kernel graph onto one
/// shared fleet (declaration-order minimal-prefix packing) and print
/// the per-tenant table plus leftover capacity.
fn cmd_plan_tenants(args: &Args) -> Result<()> {
    use galapagos_llm::fpga::resources::Device;
    use galapagos_llm::serve::tenant::TenantsConfig;

    let path = args.str_or("tenants", "configs/tenants_3.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("--tenants {path}: {e}"))?;
    let tc = TenantsConfig::parse(&text)?;
    let specs: Vec<placer::TenantGraphSpec> = tc
        .tenants
        .iter()
        .map(|t| placer::TenantGraphSpec {
            name: t.name.clone(),
            shape: placer::ModelShape {
                max_seq: t.max_m,
                ..placer::ModelShape::ibert_base()
            },
            m: t.max_m,
        })
        .collect();
    let n = args.usize_or("fleet", 8 * specs.len())?;
    let fleet = placer::Fleet::homogeneous(Device::Xczu19eg, n, tc.fpgas_per_switch);
    println!(
        "packing {} tenant graph(s) onto {} FPGA slot(s), {} per switch",
        specs.len(),
        fleet.n_slots(),
        fleet.fpgas_per_switch
    );
    let pe = galapagos_llm::ibert::timing::PeConfig::default();
    let mp = placer::place_multi(&specs, &pe, &fleet)?;
    println!("{}", placer::report::multi_tenant_table(&mp).render());
    println!("free slots: {} of {}", mp.free_slots(), mp.fleet.n_slots());
    Ok(())
}

/// Run a synthetic fleet-scale scenario (N chains x M encoder clusters
/// x 6 FPGAs + the evaluation FPGA) with constant-memory streaming
/// stats and an optional event-budget profile.
fn cmd_fleet(args: &Args) -> Result<()> {
    use galapagos_llm::eval::fleet::{run_fleet, FleetConfig};

    let mut cfg = FleetConfig::thousand_fpga();
    cfg.chains = args.usize_or("chains", cfg.chains)?;
    cfg.encoders_per_chain = args.usize_or("encoders", cfg.encoders_per_chain)?;
    cfg.m = args.usize_or("m", cfg.m)?;
    cfg.inferences = args.u64_or("inferences", cfg.inferences as u64)? as u32;
    cfg.rate = args.f64_or("rate", cfg.rate)?;
    cfg.interval = args.u64_or("interval", cfg.interval)?;
    cfg.net.drop_probability = args.f64_or("drop", 0.0)?;
    cfg.net.reliable = args.bool_or("reliable", false)?;
    cfg.net.seed = args.u64_or("net-seed", 0)?;
    cfg.granularity = match args.str_or("shards", "cluster").as_str() {
        "cluster" => Some(galapagos_llm::sim::ShardGranularity::PerCluster),
        "fpga" => Some(galapagos_llm::sim::ShardGranularity::PerFpga),
        other => bail!("unknown shard granularity {other:?} (expected cluster|fpga)"),
    };
    if args.has("event-budget") {
        cfg.event_budget = Some(args.u64_or("event-budget", 0)?);
    }
    cfg.profile = args.bool_or("profile", false)?;
    if let Some(path) = args.str_opt("tenants") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--tenants {path}: {e}"))?;
        cfg.tenants = Some(galapagos_llm::serve::tenant::TenantsConfig::parse(&text)?);
        cfg.chains_per_tenant = args.usize_or("chains-per-tenant", 1)?;
    }

    let lossy = if cfg.net.drop_probability > 0.0 {
        format!(
            ", drop={}{}",
            cfg.net.drop_probability,
            if cfg.net.reliable { " (reliable)" } else { "" }
        )
    } else {
        String::new()
    };
    match &cfg.tenants {
        None => println!(
            "fleet: {} chains x {} encoders x 6 FPGAs + 1 eval = {} FPGAs ({} clusters); \
             m={}, {} inference(s)/chain at {:.0} seqs/s{}",
            cfg.chains,
            cfg.encoders_per_chain,
            cfg.total_fpgas(),
            cfg.chains * cfg.encoders_per_chain,
            cfg.m,
            cfg.inferences,
            cfg.rate,
            lossy
        ),
        Some(tc) => println!(
            "fleet: {} tenant(s) x {} chain(s) each = {} FPGAs; chain depths: {}{}",
            tc.tenants.len(),
            cfg.chains_per_tenant,
            cfg.total_fpgas(),
            tc.tenants
                .iter()
                .map(|t| format!("{}={}", t.name, t.encoders))
                .collect::<Vec<_>>()
                .join(", "),
            lossy
        ),
    }
    let t0 = std::time::Instant::now();
    let (r, fleet) = run_fleet(&cfg)?;
    let wall = t0.elapsed();
    println!(
        "rows: {}/{} ({}){}   end cycle: {} ({:.2} ms simulated)",
        r.rows,
        r.expected_rows,
        if r.completed() { "complete" } else { "incomplete" },
        if r.truncated { " [truncated by event budget]" } else { "" },
        r.end_cycle,
        cycles_to_us(r.end_cycle) / 1e3
    );
    println!(
        "events: {}   wall: {:.1} ms ({:.2} M events/s)",
        r.events,
        wall.as_secs_f64() * 1e3,
        r.events as f64 / wall.as_secs_f64() / 1e6
    );
    println!(
        "arrivals: first {}  last {}  max coincident rows/cycle {} \
         (per-chain arrival streams derived from net-seed {})",
        r.first_arrival, r.last_arrival, r.coincident_rows_max, cfg.net.seed
    );
    if r.dropped > 0 || r.retransmits > 0 {
        println!(
            "transport: {} copies dropped, {} retransmitted ({})",
            r.dropped,
            r.retransmits,
            if cfg.net.reliable { "reliable: delivered exactly once" } else { "unreliable" }
        );
    }
    if let Some(p) = fleet.sim.last_profile.as_ref() {
        println!("{}", p.render());
    }
    Ok(())
}

fn cmd_build(args: &Args) -> Result<()> {
    let cfg_path = args.str_or("config", "configs/ibert_poc.json");
    let out = args.str_or("out", "target/cluster_build");
    let d = BuildDescription::load(&cfg_path)?;
    anyhow::ensure!(
        d.heads == 12 && d.hidden == 768 && d.ffn == 3072,
        "the Cluster Builder emits the 12-head I-BERT HLS kernels; use `plan` for other shapes"
    );
    println!("cluster builder: {} encoder cluster(s), device {:?}", d.encoders, d.device);
    for e in 0..d.encoders {
        let built = build_encoder(&EncoderGraphParams {
            cluster_id: e as u8,
            fpga_base: 6 * e,
            pe: d.pe,
            mode: Mode::Timing,
            out_dst: Out::to(GlobalKernelId::new(200, 2)),
            max_seq: d.max_seq,
            hidden: d.hidden,
            ffn: d.ffn,
            decode: None,
            batched: false,
        });
        let dir = format!("{out}/cluster_{e}");
        let n = ip_generator::generate(
            &built.cluster,
            &d.pe,
            d.device,
            d.max_seq,
            d.hidden,
            d.ffn,
            &dir,
        )?;
        println!("  cluster {e}: {n} kernels -> {dir}/");
        for r in
            layer_builder::fpga_reports(&built.cluster, &d.pe, d.device, d.max_seq, d.hidden, d.ffn)
        {
            let (l, f, b, dsp) = r.utilisation();
            println!(
                "    FPGA {:>2}: LUT {:>5.1}%  FF {:>5.1}%  BRAM {:>5.1}%  DSP {:>5.1}%  {}",
                r.fpga,
                l * 100.0,
                f * 100.0,
                b * 100.0,
                dsp * 100.0,
                if r.fits() { "OK" } else { "OVER BUDGET" }
            );
        }
    }
    Ok(())
}

fn cmd_versal() -> Result<()> {
    println!("{}", tables::versal_table()?.render());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    match args.str_or("backend", "sim").as_str() {
        "sim" => cmd_serve_sim(args),
        "pjrt" => cmd_serve_pjrt(args),
        other => bail!("unknown serve backend {other:?} (expected sim|pjrt)"),
    }
}

/// Stream open-loop request traffic through an N-encoder pipeline in the
/// discrete-event simulator and report serving metrics + the Eq. 1 check.
fn cmd_serve_sim(args: &Args) -> Result<()> {
    use galapagos_llm::serve::{
        run_serving_with_obs, ArrivalProcess, DecodeConfig, LengthDist, ServeConfig,
    };

    if args.str_opt("tenants").is_some() {
        return cmd_serve_tenants(args);
    }
    let quick = args.bool_or("quick", false)?;
    let encoders = args.usize_or("encoders", 6)?;
    let requests = args.usize_or("requests", if quick { 32 } else { 200 })?;
    let lengths = LengthDist::from_name(&args.str_or("workload", "glue"))?;
    let seed = args.u64_or("seed", 7)?;

    let mut cfg = ServeConfig::glue(encoders, requests, 1.0, seed);
    cfg.traffic.lengths = lengths;
    cfg.interval = args.u64_or("interval", 12)?;
    cfg.fpgas_per_switch = args.usize_or("fpgas-per-switch", 6)?;
    cfg.check_eq1 = !args.bool_or("no-eq1", false)?;
    cfg.drop_probability = args.f64_or("drop", 0.0)?;
    cfg.reliable = args.bool_or("reliable", false)?;
    cfg.granularity = match args.str_or("shards", "cluster").as_str() {
        "cluster" => Some(galapagos_llm::sim::ShardGranularity::PerCluster),
        "fpga" => Some(galapagos_llm::sim::ShardGranularity::PerFpga),
        other => bail!("unknown shard granularity {other:?} (expected cluster|fpga)"),
    };
    cfg.fail = parse_fail(args)?;
    cfg.obs = parse_obs(args)?;
    if args.bool_or("decode", false)? || args.has("max-new-tokens") {
        cfg.decode =
            Some(DecodeConfig { max_new_tokens: args.u64_or("max-new-tokens", 8)? as u32 });
    }
    if args.has("batch-max") || args.has("batch-window") {
        anyhow::ensure!(
            cfg.decode.is_some(),
            "--batch-max/--batch-window need --decode (iteration batches are decode tokens)"
        );
        cfg.batching = Some(galapagos_llm::serve::BatchConfig {
            max: args.u64_or("batch-max", 8)? as u32,
            window: args.u64_or("batch-window", 256)?,
        });
    }

    if args.bool_or("place", false)? {
        // per-encoder placement from the PR 1 placer (possibly over the
        // heterogeneous fleet of a build description)
        let cfg_path = args.str_or("config", "configs/ibert_poc.json");
        let d = if std::path::Path::new(&cfg_path).exists() {
            BuildDescription::load(&cfg_path)?
        } else if args.has("config") {
            bail!("--config {cfg_path} does not exist");
        } else {
            println!("note: {cfg_path} not found, placing the default ibert-base description");
            BuildDescription::default()
        };
        let fleet = d.fleet();
        let sol = placer::place(
            &d.shape(),
            &d.pe,
            &fleet,
            &placer::SearchParams::for_m(d.max_seq.min(128)),
        )?;
        anyhow::ensure!(
            sol.placement.slot_of.len() == galapagos_llm::ibert::graph::KERNELS_PER_ENCODER,
            "serving needs a paper-shaped placement (38 kernels); use configs/ibert_poc.json"
        );
        println!(
            "placer: {} kernels over {} FPGA slot(s) ({} per switch)",
            sol.placement.slot_of.len(),
            sol.placement.used_slots().len(),
            fleet.fpgas_per_switch
        );
        cfg.pe = d.pe;
        cfg.fpgas_per_switch = fleet.fpgas_per_switch;
        cfg.placement = Some(sol.placement.slot_of.clone());
    }

    // offered load: explicit --rate, or --util x measured pipeline capacity
    let (mean_m, capacity) = cfg.capacity_at_mean()?;
    let rate = if args.has("rate") {
        args.f64_or("rate", capacity)?
    } else {
        capacity * args.f64_or("util", 0.7)?
    };
    anyhow::ensure!(rate > 0.0, "offered rate must be positive");
    cfg.traffic.process = match args.str_or("arrivals", "poisson").as_str() {
        "poisson" => ArrivalProcess::Poisson { seqs_per_s: rate },
        "uniform" => ArrivalProcess::Uniform { seqs_per_s: rate },
        other => bail!("unknown arrival process {other:?} (expected poisson|uniform)"),
    };
    println!(
        "pipeline capacity ~{capacity:.0} seqs/s at m={mean_m}; offering {rate:.0} seqs/s \
         ({:.0}% load)",
        100.0 * rate / capacity
    );
    if let Some(d) = cfg.decode {
        println!(
            "decode: prefill + {} token pass(es) per request (KV caches charged at the heads)",
            d.max_new_tokens
        );
    }
    if let Some(b) = cfg.batching.filter(|b| b.enabled()) {
        println!(
            "continuous batching: up to {} sequences per iteration, {}-cycle assembly window",
            b.max, b.window
        );
    }

    let t0 = std::time::Instant::now();
    let (report, obs_out) = run_serving_with_obs(&cfg)?;
    println!("{}", report.render());
    println!(
        "(DES: {} events in {:.1} ms wall)",
        report.events,
        t0.elapsed().as_secs_f64() * 1e3
    );
    let out = args
        .str_opt("out")
        .map(str::to_string)
        .or_else(|| quick.then(|| "BENCH_serving.json".to_string()));
    if let Some(path) = out {
        std::fs::write(&path, report.to_json().pretty())?;
        println!("report written to {path}");
    }
    if let (Some(path), Some(text)) = (args.str_opt("trace-out"), obs_out.trace_json.as_ref()) {
        std::fs::write(path, text)?;
        println!("trace written to {path}");
    }
    if let (Some(path), Some(text)) = (args.str_opt("metrics-out"), obs_out.metrics_jsonl.as_ref())
    {
        std::fs::write(path, text)?;
        println!("metrics written to {path}");
    }
    Ok(())
}

/// `serve --tenants <config>`: N model graphs on one fleet. Each tenant's
/// offered schedule passes SLO-aware admission, the multi-placer packs
/// the roster onto a shared fleet, one simulation serves the mixed
/// schedule, and the report upgrades to serving_report/v6 (per-tenant
/// percentiles, reject rates, cross-tenant fairness).
fn cmd_serve_tenants(args: &Args) -> Result<()> {
    use galapagos_llm::serve::tenant::TenantsConfig;
    use galapagos_llm::serve::{run_multi_tenant_serving, MultiTenantConfig};

    let path = args.str_or("tenants", "configs/tenants_3.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("--tenants {path}: {e}"))?;
    let tenants = TenantsConfig::parse(&text)?;
    let mut cfg = MultiTenantConfig::new(tenants, args.u64_or("seed", 7)?);
    cfg.granularity = match args.str_or("shards", "cluster").as_str() {
        "cluster" => Some(galapagos_llm::sim::ShardGranularity::PerCluster),
        "fpga" => Some(galapagos_llm::sim::ShardGranularity::PerFpga),
        other => bail!("unknown shard granularity {other:?} (expected cluster|fpga)"),
    };
    cfg.fail = parse_fail(args)?;
    for t in &cfg.tenants.tenants {
        println!(
            "tenant {:<12} {} encoder(s)  max_m {:>3}  {:<11} SLO p99 {:>6.0} us  \
             {:>2} kv slot(s)  {} request(s) ({} @ {:.0} seqs/s)",
            t.name,
            t.encoders,
            t.max_m,
            t.class.name(),
            t.slo_p99_us,
            t.kv_slots,
            t.requests,
            t.process.name(),
            t.process.seqs_per_s()
        );
    }
    let t0 = std::time::Instant::now();
    let report = run_multi_tenant_serving(&cfg)?;
    println!("{}", report.render());
    println!(
        "(DES: {} events in {:.1} ms wall)",
        report.events,
        t0.elapsed().as_secs_f64() * 1e3
    );
    if let Some(out) = args.str_opt("out") {
        std::fs::write(out, report.to_json().pretty())?;
        println!("report written to {out}");
    }
    Ok(())
}

/// Serve requests through the AOT-compiled PJRT encoder artifact (the
/// original `serve` path; needs `make artifacts`).
fn cmd_serve_pjrt(args: &Args) -> Result<()> {
    let n = args.usize_or("requests", 16)?;
    let encoders = args.usize_or("encoders", 2)?;
    let dir = ModelParams::default_dir();
    let rt = PjrtRuntime::cpu()?;
    let engine = EncoderEngine::load(&rt, &dir)?;
    let base = rows_i8(load_golden(&dir, "input_m128")?.as_i8()?);
    let mut wl = GlueWorkload::glue(3);
    let mut lat = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let m = wl.sample();
        let t = std::time::Instant::now();
        let out = engine.infer_model(&base[..m], encoders)?;
        lat.push(t.elapsed().as_secs_f64() * 1e3);
        anyhow::ensure!(out.len() == m);
        println!("request {i:>3}: len {m:>3} -> {:.1} ms", lat.last().unwrap());
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "p50 {:.1} ms  p95 {:.1} ms  throughput {:.2} req/s",
        lat[lat.len() / 2],
        lat[(lat.len() * 95) / 100],
        n as f64 / t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let mhz = FABRIC_CLOCK_HZ / 1_000_000;
    println!("fabric clock: {mhz} MHz (derived from the paper's Table 1/2)");
    println!("packet: one 768-byte row = 12 x 64-byte AXIS flits");
    println!("addressing: 256 clusters x 256 kernels (gateway-mediated inter-cluster)");
    println!("\ndevice catalog (placer fleets mix these freely):");
    for dev in galapagos_llm::fpga::resources::Device::ALL {
        let b = dev.budget();
        let shell = dev.shell_usage();
        println!(
            "  {:<9} LUT {:>9}  FF {:>9}  BRAM18 {:>5}  DSP {:>5}  \
             ({} int8 MAC/DSP, shell ~{:.0}% LUT)",
            dev.name(),
            b.lut,
            b.ff,
            b.bram18,
            b.dsp,
            dev.int8_macs_per_dsp(),
            100.0 * shell.lut as f64 / b.lut as f64
        );
    }
    println!();
    let dir = ModelParams::default_dir();
    match ModelParams::load(&dir) {
        Ok(p) => println!(
            "model FS: {:?} (hidden={}, heads={}, ffn={}, {} weight bytes)",
            dir, p.cfg.hidden, p.cfg.heads, p.cfg.ffn, p.weight_bytes()
        ),
        Err(_) => println!("model FS: not built — run `make artifacts`"),
    }
    Ok(())
}
