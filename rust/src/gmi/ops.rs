//! GMI collective kernels (§5.1): Broadcast, Scatter, Gather, Reduce —
//! the basic set from which Allreduce/Allgather compose (§5.1), plus a
//! point-to-point Forward relay.
//!
//! Each op is an ordinary streaming kernel: it consumes packets and emits
//! packets; compute kernels never see communication logic (Fig. 6b).
//! Multi-source ops (Gather/Reduce) identify the sender's rank by the
//! `meta.stream` tag, which the Cluster Builder configures on the sender
//! side — the GMI protocol itself carries no rank field (it is the
//! "extremely lightweight protocol" of §5.2).

use std::collections::HashMap;

use crate::sim::engine::{KernelBehavior, KernelIo};
use crate::sim::packet::{GlobalKernelId, MsgMeta, Packet, Payload};

/// An output edge of a GMI kernel: destination + optional stream retag
/// (multi-input compute kernels demux their logical ports by meta.stream,
/// which the Cluster Builder configures on the producing side).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Out {
    pub dst: GlobalKernelId,
    pub stream: Option<u8>,
}

impl Out {
    pub fn to(dst: GlobalKernelId) -> Self {
        Out { dst, stream: None }
    }
    pub fn tagged(dst: GlobalKernelId, stream: u8) -> Self {
        Out { dst, stream: Some(stream) }
    }
    fn retag(&self, meta: MsgMeta) -> MsgMeta {
        match self.stream {
            Some(s) => MsgMeta { stream: s, ..meta },
            None => meta,
        }
    }
}

/// Row distribution policy for Scatter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScatterPolicy {
    /// contiguous blocks of ceil(rows/n) rows per destination
    Block,
    /// row i goes to destination i mod n
    RoundRobin,
    /// each row is split column-wise into n equal segments, one per
    /// destination — the paper's head-wise Q/K/V distribution (§7.2):
    /// "Scatter" in the MPI sense of one vector scattered across PEs.
    ColumnSplit,
}

/// Element-wise combining function for Reduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceFn {
    Sum,
    Max,
}

impl ReduceFn {
    fn combine_i64(&self, a: i64, b: i64) -> i64 {
        match self {
            ReduceFn::Sum => a + b,
            ReduceFn::Max => a.max(b),
        }
    }
}

/// The collective operation a GMI kernel performs.
#[derive(Debug, Clone)]
pub enum GmiOp {
    Broadcast { dsts: Vec<Out> },
    Scatter { dsts: Vec<Out>, policy: ScatterPolicy },
    /// gather `n_srcs` row streams (ranked by meta.stream) into one message
    Gather { n_srcs: usize, dst: Out },
    /// gather `n_srcs` per-row column segments (ranked by meta.stream)
    /// into full rows — the inverse of ScatterPolicy::ColumnSplit (the
    /// paper's head-merge before the output projection, Fig. 14 Kern_37)
    GatherCols { n_srcs: usize, dst: Out },
    /// element-wise reduce `n_srcs` row streams into one
    Reduce { n_srcs: usize, dst: Out, f: ReduceFn },
    Forward { dst: Out },
}

impl GmiOp {
    pub fn kind(&self) -> &'static str {
        match self {
            GmiOp::Broadcast { .. } => "Broadcast",
            GmiOp::Scatter { .. } => "Scatter",
            GmiOp::Gather { .. } => "Gather",
            GmiOp::GatherCols { .. } => "GatherCols",
            GmiOp::Reduce { .. } => "Reduce",
            GmiOp::Forward { .. } => "Forward",
        }
    }
}

/// Split a payload into `n` equal column segments.
fn column_split(p: &Payload, n: usize) -> Vec<Payload> {
    match p {
        Payload::RowI8(v) => v.chunks(v.len() / n).map(|c| Payload::RowI8(c.to_vec())).collect(),
        Payload::RowI32(v) => v.chunks(v.len() / n).map(|c| Payload::RowI32(c.to_vec())).collect(),
        Payload::RowI64(v) => v.chunks(v.len() / n).map(|c| Payload::RowI64(c.to_vec())).collect(),
        Payload::Timing(b) => (0..n).map(|_| Payload::Timing(b / n)).collect(),
        Payload::Control(c) => (0..n).map(|_| Payload::Control(*c)).collect(),
    }
}

/// Concatenate column segments (same dtype) back into one row.
fn column_concat(parts: Vec<Payload>) -> Payload {
    let mut it = parts.into_iter();
    let mut acc = it.next().expect("concat of nothing");
    for p in it {
        acc = match (acc, p) {
            (Payload::RowI8(mut a), Payload::RowI8(b)) => {
                a.extend(b);
                Payload::RowI8(a)
            }
            (Payload::RowI32(mut a), Payload::RowI32(b)) => {
                a.extend(b);
                Payload::RowI32(a)
            }
            (Payload::RowI64(mut a), Payload::RowI64(b)) => {
                a.extend(b);
                Payload::RowI64(a)
            }
            (Payload::Timing(a), Payload::Timing(b)) => Payload::Timing(a + b),
            (a, _) => a,
        };
    }
    acc
}

#[derive(Default)]
struct GatherState {
    /// per (inference): per rank: (expected_rows, buffered rows by index)
    msgs: HashMap<u32, RankBuffers>,
}

#[derive(Default)]
struct RankBuffers {
    per_rank: HashMap<u8, (u32, HashMap<u32, Payload>)>,
    emitted: u32,
    next_rank: u8,
    next_row: u32,
}

/// A GMI kernel: one op instance, stateless for Broadcast/Scatter/Forward,
/// buffering for Gather/GatherCols/Reduce.
pub struct GmiKernel {
    pub op: GmiOp,
    gather: GatherState,
    /// (inference, row) -> per-rank column segments
    gather_cols: HashMap<(u32, u32), HashMap<u8, Payload>>,
    reduce: HashMap<(u32, u32), (usize, Payload)>, // (inference,row) -> (count, acc)
    reduce_meta: HashMap<u32, u32>,                // inference -> rows
}

impl GmiKernel {
    pub fn new(op: GmiOp) -> Self {
        GmiKernel {
            op,
            gather: GatherState::default(),
            gather_cols: HashMap::new(),
            reduce: HashMap::new(),
            reduce_meta: HashMap::new(),
        }
    }

    fn do_gather_cols(&mut self, pkt: Packet, io: &mut KernelIo) {
        let GmiOp::GatherCols { n_srcs, dst } = self.op else { unreachable!() };
        let key = (pkt.meta.inference, pkt.meta.row);
        let slot = self.gather_cols.entry(key).or_default();
        slot.insert(pkt.meta.stream, pkt.payload);
        if slot.len() == n_srcs {
            let parts = self.gather_cols.remove(&key).unwrap();
            let ordered: Vec<Payload> =
                (0..n_srcs as u8).map(|r| parts.get(&r).cloned().expect("missing rank")).collect();
            let meta = dst.retag(MsgMeta { stream: 0, ..pkt.meta });
            io.send(dst.dst, meta, column_concat(ordered));
        }
    }

    fn do_gather(&mut self, pkt: Packet, io: &mut KernelIo) {
        let GmiOp::Gather { n_srcs, dst } = self.op else { unreachable!() };
        let st = self.gather.msgs.entry(pkt.meta.inference).or_default();
        let rank = pkt.meta.stream;
        let entry = st.per_rank.entry(rank).or_insert_with(|| (pkt.meta.rows, HashMap::new()));
        entry.1.insert(pkt.meta.row, pkt.payload);

        // emit eagerly in (rank, row) order
        loop {
            if (st.next_rank as usize) >= n_srcs {
                break;
            }
            let Some((expect, buf)) = st.per_rank.get_mut(&st.next_rank) else { break };
            if st.next_row >= *expect {
                st.next_rank += 1;
                st.next_row = 0;
                continue;
            }
            let Some(payload) = buf.remove(&st.next_row) else { break };
            // total output rows unknown until all ranks announce; use the
            // running emitted counter for row numbering and patch `rows`
            // with the per-rank total sum when known (senders all use the
            // same per-message total in our graphs, so sum is fine).
            let total: u32 = st.per_rank.values().map(|(e, _)| *e).sum();
            let meta = dst.retag(MsgMeta {
                stream: 0,
                row: st.emitted,
                rows: total.max(st.emitted + 1),
                inference: pkt.meta.inference,
            });
            io.send(dst.dst, meta, payload);
            st.emitted += 1;
            st.next_row += 1;
        }
        if (st.next_rank as usize) >= n_srcs {
            self.gather.msgs.remove(&pkt.meta.inference);
        }
    }

    fn do_reduce(&mut self, pkt: Packet, io: &mut KernelIo) {
        let GmiOp::Reduce { n_srcs, dst, f } = self.op else { unreachable!() };
        self.reduce_meta.insert(pkt.meta.inference, pkt.meta.rows);
        let key = (pkt.meta.inference, pkt.meta.row);
        let slot = self.reduce.entry(key).or_insert_with(|| (0, zero_like(&pkt.payload)));
        slot.0 += 1;
        slot.1 = combine(&slot.1, &pkt.payload, f);
        if slot.0 == n_srcs {
            let (_, acc) = self.reduce.remove(&key).unwrap();
            let rows = *self.reduce_meta.get(&pkt.meta.inference).unwrap_or(&pkt.meta.rows);
            let meta = dst.retag(MsgMeta {
                stream: 0,
                row: pkt.meta.row,
                rows,
                inference: pkt.meta.inference,
            });
            io.send(dst.dst, meta, acc);
        }
    }
}

fn zero_like(p: &Payload) -> Payload {
    match p {
        Payload::Timing(b) => Payload::Timing(*b),
        Payload::RowI8(v) => Payload::RowI32(vec![0; v.len()]),
        Payload::RowI32(v) => Payload::RowI32(vec![0; v.len()]),
        Payload::RowI64(v) => Payload::RowI64(vec![0; v.len()]),
        Payload::Control(_) => Payload::Control(0),
    }
}

fn combine(acc: &Payload, new: &Payload, f: ReduceFn) -> Payload {
    match (acc, new) {
        (Payload::RowI32(a), Payload::RowI8(b)) => Payload::RowI32(
            a.iter().zip(b).map(|(&x, &y)| f.combine_i64(x as i64, y as i64) as i32).collect(),
        ),
        (Payload::RowI32(a), Payload::RowI32(b)) => Payload::RowI32(
            a.iter().zip(b).map(|(&x, &y)| f.combine_i64(x as i64, y as i64) as i32).collect(),
        ),
        (Payload::RowI64(a), Payload::RowI64(b)) => {
            Payload::RowI64(a.iter().zip(b).map(|(&x, &y)| f.combine_i64(x, y)).collect())
        }
        (Payload::Timing(b), _) => Payload::Timing(*b),
        (Payload::Control(a), Payload::Control(b)) => Payload::Control(a.wrapping_add(*b)),
        _ => acc.clone(),
    }
}

impl KernelBehavior for GmiKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
        match &self.op {
            GmiOp::Broadcast { dsts } => {
                for d in dsts.clone() {
                    io.send(d.dst, d.retag(pkt.meta), pkt.payload.clone());
                }
            }
            GmiOp::Scatter { dsts, policy } => {
                if *policy == ScatterPolicy::ColumnSplit {
                    let parts = column_split(&pkt.payload, dsts.len());
                    for (d, part) in dsts.clone().iter().zip(parts) {
                        io.send(d.dst, d.retag(pkt.meta), part);
                    }
                    return;
                }
                let n = dsts.len() as u32;
                let (idx, row, rows) = match policy {
                    ScatterPolicy::Block => {
                        let per = pkt.meta.rows.div_ceil(n);
                        let i = (pkt.meta.row / per).min(n - 1);
                        let start = i * per;
                        let count = per.min(pkt.meta.rows - start);
                        (i as usize, pkt.meta.row - start, count)
                    }
                    ScatterPolicy::RoundRobin => {
                        let i = pkt.meta.row % n;
                        let count =
                            (pkt.meta.rows + n - 1 - i) / n; // rows this lane receives
                        (i as usize, pkt.meta.row / n, count)
                    }
                    ScatterPolicy::ColumnSplit => unreachable!(),
                };
                let d = dsts[idx];
                let meta = d.retag(MsgMeta { row, rows, ..pkt.meta });
                io.send(d.dst, meta, pkt.payload);
            }
            GmiOp::Gather { .. } => self.do_gather(pkt, io),
            GmiOp::GatherCols { .. } => self.do_gather_cols(pkt, io),
            GmiOp::Reduce { .. } => self.do_reduce(pkt, io),
            GmiOp::Forward { dst } => {
                io.send(dst.dst, dst.retag(pkt.meta), pkt.payload);
            }
        }
    }

    fn on_wake(&mut self, _tag: u64, _io: &mut KernelIo) {}

    fn name(&self) -> String {
        format!("gmi-{}", self.op.kind().to_lowercase())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::START_TAG;
    use crate::sim::fabric::{FpgaId, SwitchId};
    use crate::sim::fifo::Fifo;
    use crate::sim::Sim;

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    /// Sends a fixed row stream at start.
    struct Tx {
        dst: GlobalKernelId,
        rows: Vec<Vec<i32>>,
        stream: u8,
    }
    impl KernelBehavior for Tx {
        fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
        fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
            if tag == START_TAG {
                let n = self.rows.len() as u32;
                for (i, r) in self.rows.iter().enumerate() {
                    let meta = MsgMeta {
                        stream: self.stream,
                        row: i as u32,
                        rows: n,
                        inference: 0,
                    };
                    io.send(self.dst, meta, Payload::RowI32(r.clone()));
                }
            }
        }
    }

    /// Records received rows in arrival order.
    #[derive(Default)]
    struct Rx;
    impl KernelBehavior for Rx {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            io.consume(pkt.wire_bytes());
            RECORDER.with(|r| r.borrow_mut().push((io.self_id, pkt.meta, pkt.payload)));
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    thread_local! {
        static RECORDER: std::cell::RefCell<Vec<(GlobalKernelId, MsgMeta, Payload)>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }

    fn recorded() -> Vec<(GlobalKernelId, MsgMeta, Payload)> {
        RECORDER.with(|r| r.borrow().clone())
    }
    fn reset_recorder() {
        RECORDER.with(|r| r.borrow_mut().clear());
    }

    fn base_sim() -> Sim {
        let mut sim = Sim::new();
        for f in 0..4 {
            sim.fabric.attach(FpgaId(f), SwitchId(0));
        }
        sim
    }

    #[test]
    fn broadcast_clones_to_all() {
        reset_recorder();
        let mut sim = base_sim();
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 2),
            rows: vec![vec![1, 2], vec![3, 4]],
            stream: 0,
        })).unwrap();
        sim.add_kernel(
            k(0, 2),
            FpgaId(1),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Broadcast { dsts: vec![Out::to(k(0, 3)), Out::to(k(0, 4))] })),
        )
        .unwrap();
        sim.add_kernel(k(0, 3), FpgaId(2), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.add_kernel(k(0, 4), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        let got = recorded();
        assert_eq!(got.len(), 4);
        let to3 = got.iter().filter(|(id, _, _)| *id == k(0, 3)).count();
        assert_eq!(to3, 2);
    }

    #[test]
    fn scatter_block_splits_rows() {
        reset_recorder();
        let mut sim = base_sim();
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 2),
            rows: (0..6).map(|i| vec![i]).collect(),
            stream: 0,
        })).unwrap();
        sim.add_kernel(
            k(0, 2),
            FpgaId(1),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Scatter {
                dsts: vec![Out::to(k(0, 3)), Out::to(k(0, 4))],
                policy: ScatterPolicy::Block,
            })),
        )
        .unwrap();
        sim.add_kernel(k(0, 3), FpgaId(2), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.add_kernel(k(0, 4), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        let got = recorded();
        // rows 0..2 -> kernel 3, rows 3..5 -> kernel 4, renumbered 0..2
        let to3: Vec<i32> = got
            .iter()
            .filter(|(id, _, _)| *id == k(0, 3))
            .map(|(_, _, p)| match p {
                Payload::RowI32(v) => v[0],
                _ => panic!(),
            })
            .collect();
        assert_eq!(to3, vec![0, 1, 2]);
        for (id, meta, _) in &got {
            if *id == k(0, 4) {
                assert!(meta.row < 3);
                assert_eq!(meta.rows, 3);
            }
        }
    }

    #[test]
    fn gather_orders_by_rank() {
        reset_recorder();
        let mut sim = base_sim();
        // rank 1 fires first but must be emitted after rank 0
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![10], vec![11]],
            stream: 1,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![0], vec![1]],
            stream: 0,
        })).unwrap();
        sim.add_kernel(
            k(0, 3),
            FpgaId(2),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Gather { n_srcs: 2, dst: Out::to(k(0, 4)) })),
        )
        .unwrap();
        sim.add_kernel(k(0, 4), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        let vals: Vec<i32> = recorded()
            .iter()
            .map(|(_, _, p)| match p {
                Payload::RowI32(v) => v[0],
                _ => panic!(),
            })
            .collect();
        assert_eq!(vals, vec![0, 1, 10, 11]);
        let rows: Vec<u32> = recorded().iter().map(|(_, m, _)| m.row).collect();
        assert_eq!(rows, vec![0, 1, 2, 3]);
    }

    #[test]
    fn reduce_sums_across_ranks() {
        reset_recorder();
        let mut sim = base_sim();
        for (kid, stream, base) in [(1u8, 0u8, 0), (2, 1, 100)] {
            sim.add_kernel(k(0, kid), FpgaId(kid as usize - 1), Fifo::new(1 << 16), Box::new(Tx {
                dst: k(0, 3),
                rows: vec![vec![base + 1, base + 2], vec![base + 3, base + 4]],
                stream,
            })).unwrap();
        }
        sim.add_kernel(
            k(0, 3),
            FpgaId(2),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Reduce {
                n_srcs: 2,
                dst: Out::to(k(0, 4)),
                f: ReduceFn::Sum,
            })),
        )
        .unwrap();
        sim.add_kernel(k(0, 4), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        let mut rows: Vec<(u32, Vec<i32>)> = recorded()
            .iter()
            .map(|(_, m, p)| match p {
                Payload::RowI32(v) => (m.row, v.clone()),
                _ => panic!(),
            })
            .collect();
        rows.sort();
        assert_eq!(rows, vec![(0, vec![102, 104]), (1, vec![106, 108])]);
    }

    #[test]
    fn allgather_composes_from_gather_plus_broadcast() {
        // §5.1: Allgather = Gather to a root, then Broadcast back out.
        reset_recorder();
        let mut sim = base_sim();
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![7]],
            stream: 0,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![8]],
            stream: 1,
        })).unwrap();
        sim.add_kernel(
            k(0, 3),
            FpgaId(2),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Gather { n_srcs: 2, dst: Out::to(k(0, 4)) })),
        )
        .unwrap();
        sim.add_kernel(
            k(0, 4),
            FpgaId(2),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Broadcast { dsts: vec![Out::to(k(0, 5)), Out::to(k(0, 6))] })),
        )
        .unwrap();
        sim.add_kernel(k(0, 5), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.add_kernel(k(0, 6), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        // both leaves see both rows
        for leaf in [k(0, 5), k(0, 6)] {
            let n = recorded().iter().filter(|(id, _, _)| *id == leaf).count();
            assert_eq!(n, 2, "leaf {leaf} sees the gathered set");
        }
    }
}
