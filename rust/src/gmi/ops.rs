//! GMI collective kernels (§5.1): Broadcast, Scatter, Gather, Reduce —
//! the basic set from which Allreduce/Allgather compose (§5.1), plus a
//! point-to-point Forward relay.
//!
//! Each op is an ordinary streaming kernel: it consumes packets and emits
//! packets; compute kernels never see communication logic (Fig. 6b).
//! Multi-source ops (Gather/Reduce) identify the sender's rank by the
//! `meta.stream` tag, which the Cluster Builder configures on the sender
//! side — the GMI protocol itself carries no rank field (it is the
//! "extremely lightweight protocol" of §5.2).
//!
//! Burst-aware: every op forwards a coalesced row run (see
//! `sim::packet::Burst`) at the rows' cycle-exact arrival times. Rows
//! pass through a per-destination `TxQueue`: coalescible destinations
//! (same FPGA) receive bursts immediately; everything else is emitted
//! row-by-row at the correct emission cycle via deferred wakes, so link
//! serialization order is identical to the uncoalesced engine.

use std::collections::{HashMap, VecDeque};

use crate::sim::engine::{KernelBehavior, KernelIo};
use crate::sim::packet::{GlobalKernelId, MsgMeta, Packet, Payload};

/// An output edge of a GMI kernel: destination + optional stream retag
/// (multi-input compute kernels demux their logical ports by meta.stream,
/// which the Cluster Builder configures on the producing side).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Out {
    pub dst: GlobalKernelId,
    pub stream: Option<u8>,
}

impl Out {
    pub fn to(dst: GlobalKernelId) -> Self {
        Out { dst, stream: None }
    }
    pub fn tagged(dst: GlobalKernelId, stream: u8) -> Self {
        Out { dst, stream: Some(stream) }
    }
    fn retag(&self, meta: MsgMeta) -> MsgMeta {
        match self.stream {
            Some(s) => MsgMeta { stream: s, ..meta },
            None => meta,
        }
    }
}

/// Row distribution policy for Scatter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScatterPolicy {
    /// contiguous blocks of ceil(rows/n) rows per destination
    Block,
    /// row i goes to destination i mod n
    RoundRobin,
    /// each row is split column-wise into n equal segments, one per
    /// destination — the paper's head-wise Q/K/V distribution (§7.2):
    /// "Scatter" in the MPI sense of one vector scattered across PEs.
    ColumnSplit,
}

/// Element-wise combining function for Reduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceFn {
    Sum,
    Max,
}

impl ReduceFn {
    fn combine_i64(&self, a: i64, b: i64) -> i64 {
        match self {
            ReduceFn::Sum => a + b,
            ReduceFn::Max => a.max(b),
        }
    }
}

/// The collective operation a GMI kernel performs.
#[derive(Debug, Clone)]
pub enum GmiOp {
    Broadcast { dsts: Vec<Out> },
    Scatter { dsts: Vec<Out>, policy: ScatterPolicy },
    /// gather `n_srcs` row streams (ranked by meta.stream) into one message
    Gather { n_srcs: usize, dst: Out },
    /// gather `n_srcs` per-row column segments (ranked by meta.stream)
    /// into full rows — the inverse of ScatterPolicy::ColumnSplit (the
    /// paper's head-merge before the output projection, Fig. 14 Kern_37)
    GatherCols { n_srcs: usize, dst: Out },
    /// element-wise reduce `n_srcs` row streams into one
    Reduce { n_srcs: usize, dst: Out, f: ReduceFn },
    Forward { dst: Out },
}

impl GmiOp {
    pub fn kind(&self) -> &'static str {
        match self {
            GmiOp::Broadcast { .. } => "Broadcast",
            GmiOp::Scatter { .. } => "Scatter",
            GmiOp::Gather { .. } => "Gather",
            GmiOp::GatherCols { .. } => "GatherCols",
            GmiOp::Reduce { .. } => "Reduce",
            GmiOp::Forward { .. } => "Forward",
        }
    }

    fn n_outputs(&self) -> usize {
        match self {
            GmiOp::Broadcast { dsts } => dsts.len(),
            GmiOp::Scatter { dsts, .. } => dsts.len(),
            _ => 1,
        }
    }

    fn out(&self, i: usize) -> Out {
        match self {
            GmiOp::Broadcast { dsts } => dsts[i],
            GmiOp::Scatter { dsts, .. } => dsts[i],
            GmiOp::Gather { dst, .. }
            | GmiOp::GatherCols { dst, .. }
            | GmiOp::Reduce { dst, .. }
            | GmiOp::Forward { dst } => *dst,
        }
    }
}

/// Split a payload into `n` equal column segments.
fn column_split(p: &Payload, n: usize) -> Vec<Payload> {
    match p {
        Payload::RowI8(v) => {
            v.chunks(v.len() / n).map(|c| Payload::row_i8(c.to_vec())).collect()
        }
        Payload::RowI32(v) => {
            v.chunks(v.len() / n).map(|c| Payload::row_i32(c.to_vec())).collect()
        }
        Payload::RowI64(v) => {
            v.chunks(v.len() / n).map(|c| Payload::row_i64(c.to_vec())).collect()
        }
        Payload::Timing(b) => (0..n).map(|_| Payload::Timing(b / n)).collect(),
        Payload::Control(c) => (0..n).map(|_| Payload::Control(*c)).collect(),
    }
}

/// Concatenate column segments (same dtype) back into one row.
fn column_concat(parts: Vec<Payload>) -> Payload {
    let mut it = parts.into_iter();
    match it.next().expect("concat of nothing") {
        Payload::RowI8(a) => {
            let mut out = std::sync::Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone());
            for p in it {
                if let Payload::RowI8(b) = p {
                    out.extend_from_slice(&b);
                }
            }
            Payload::row_i8(out)
        }
        Payload::RowI32(a) => {
            let mut out = std::sync::Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone());
            for p in it {
                if let Payload::RowI32(b) = p {
                    out.extend_from_slice(&b);
                }
            }
            Payload::row_i32(out)
        }
        Payload::RowI64(a) => {
            let mut out = std::sync::Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone());
            for p in it {
                if let Payload::RowI64(b) = p {
                    out.extend_from_slice(&b);
                }
            }
            Payload::row_i64(out)
        }
        Payload::Timing(first) => {
            let mut t = first;
            for p in it {
                if let Payload::Timing(b) = p {
                    t += b;
                }
            }
            Payload::Timing(t)
        }
        Payload::Control(c) => Payload::Control(c),
    }
}

/// Deferred per-destination emission queue. Entries carry their exact
/// emission cycle (nondecreasing). Coalescible destinations get the
/// whole backlog as bursts at once; others are emitted one row per wake
/// at precisely the scheduled cycle — identical link-serialization order
/// to the uncoalesced engine.
#[derive(Default)]
pub(crate) struct TxQueue {
    q: VecDeque<(MsgMeta, u64, Payload)>,
}

impl TxQueue {
    pub(crate) fn push(&mut self, meta: MsgMeta, at: u64, payload: Payload) {
        debug_assert!(self.q.back().is_none_or(|(_, t, _)| *t <= at));
        self.q.push_back((meta, at, payload));
    }

    /// Emission cycle of the next pending row.
    pub(crate) fn front_time(&self) -> Option<u64> {
        self.q.front().map(|&(_, t, _)| t)
    }

    /// Emit every row due at (or before) `io.now` as ordinary packets.
    pub(crate) fn emit_due(&mut self, d: Out, io: &mut KernelIo) {
        while let Some(&(_, at, _)) = self.q.front() {
            if at > io.now {
                break;
            }
            let (meta, _, payload) = self.q.pop_front().unwrap();
            io.send(d.dst, meta, payload);
        }
    }

    /// Ship the whole backlog as coalesced bursts. Only valid for a
    /// kernel's SOLE output queue on an intra-FPGA edge: a kernel with
    /// several queues serializes them row-major on its egress port, and
    /// shipping one queue's backlog at once would reorder that.
    pub(crate) fn ship_bursts(&mut self, d: Out, io: &mut KernelIo) {
        while !self.q.is_empty() {
            self.ship_run(d, io);
        }
    }

    /// Pop a maximal run of consecutive rows of one message and ship it
    /// as a single coalesced event.
    fn ship_run(&mut self, d: Out, io: &mut KernelIo) {
        let (meta, at0, head) = self.q.pop_front().unwrap();
        let mut times = vec![at0];
        let mut tail = Vec::new();
        while let Some((m2, _, p2)) = self.q.front() {
            let consecutive = m2.inference == meta.inference
                && m2.stream == meta.stream
                && m2.rows == meta.rows
                && m2.row == meta.row + times.len() as u32
                && p2.bytes() == head.bytes();
            if !consecutive {
                break;
            }
            let (_, at, p) = self.q.pop_front().unwrap();
            times.push(at);
            tail.push(p);
        }
        io.send_burst(d.dst, meta, times, head, tail);
    }
}

#[derive(Default)]
struct GatherState {
    /// per (inference): per rank: (expected_rows, buffered rows by index)
    msgs: HashMap<u32, RankBuffers>,
}

#[derive(Default)]
struct RankBuffers {
    per_rank: HashMap<u8, (u32, HashMap<u32, (Payload, u64)>)>,
    emitted: u32,
    next_rank: u8,
    next_row: u32,
    /// running max of emitted-row arrivals: the head-of-line emission time
    unblock: u64,
}

/// Wake tag used by the deferred-emission sweep (one wake services every
/// output queue of the kernel, so event count stays one per row).
const GMI_TX_WAKE: u64 = u64::MAX - 2;

/// A GMI kernel: one op instance, stateless for Broadcast/Scatter/Forward,
/// buffering for Gather/GatherCols/Reduce.
pub struct GmiKernel {
    pub op: GmiOp,
    gather: GatherState,
    /// (inference, row) -> (per-rank column segments, latest arrival)
    gather_cols: HashMap<(u32, u32), (HashMap<u8, Payload>, u64)>,
    /// (inference, row) -> (count, acc, latest arrival)
    reduce: HashMap<(u32, u32), (usize, Payload, u64)>,
    reduce_meta: HashMap<u32, u32>, // inference -> rows
    tx: Vec<TxQueue>,
    /// earliest armed sweep wake (None = nothing armed)
    wake_at: Option<u64>,
}

impl GmiKernel {
    pub fn new(op: GmiOp) -> Self {
        let tx = (0..op.n_outputs()).map(|_| TxQueue::default()).collect();
        GmiKernel {
            op,
            gather: GatherState::default(),
            gather_cols: HashMap::new(),
            reduce: HashMap::new(),
            reduce_meta: HashMap::new(),
            tx,
            wake_at: None,
        }
    }

    fn pump_all(&mut self, io: &mut KernelIo) {
        if self.tx.len() == 1 {
            let d = self.op.out(0);
            if io.can_burst(d.dst) {
                self.tx[0].ship_bursts(d, io);
                return;
            }
        }
        // row-major sweep: every queue's due rows, in destination order
        for i in 0..self.tx.len() {
            let d = self.op.out(i);
            self.tx[i].emit_due(d, io);
        }
        let next = self.tx.iter().filter_map(|q| q.front_time()).min();
        match next {
            None => self.wake_at = None,
            Some(t) => {
                // (re-)arm only when the horizon moved earlier; stale
                // later wakes fire as no-ops and re-arm themselves
                if self.wake_at.is_none_or(|w| t < w) {
                    io.wake_in(t - io.now, GMI_TX_WAKE);
                    self.wake_at = Some(t);
                }
            }
        }
    }
}

fn zero_like(p: &Payload) -> Payload {
    match p {
        Payload::Timing(b) => Payload::Timing(*b),
        Payload::RowI8(v) => Payload::row_i32(vec![0; v.len()]),
        Payload::RowI32(v) => Payload::row_i32(vec![0; v.len()]),
        Payload::RowI64(v) => Payload::row_i64(vec![0; v.len()]),
        Payload::Control(_) => Payload::Control(0),
    }
}

fn combine(acc: &Payload, new: &Payload, f: ReduceFn) -> Payload {
    match (acc, new) {
        (Payload::RowI32(a), Payload::RowI8(b)) => Payload::row_i32(
            a.iter()
                .zip(b.iter())
                .map(|(&x, &y)| f.combine_i64(x as i64, y as i64) as i32)
                .collect(),
        ),
        (Payload::RowI32(a), Payload::RowI32(b)) => Payload::row_i32(
            a.iter()
                .zip(b.iter())
                .map(|(&x, &y)| f.combine_i64(x as i64, y as i64) as i32)
                .collect(),
        ),
        (Payload::RowI64(a), Payload::RowI64(b)) => {
            Payload::row_i64(a.iter().zip(b.iter()).map(|(&x, &y)| f.combine_i64(x, y)).collect())
        }
        (Payload::Timing(b), _) => Payload::Timing(*b),
        (Payload::Control(a), Payload::Control(b)) => Payload::Control(a.wrapping_add(*b)),
        _ => acc.clone(),
    }
}

impl KernelBehavior for GmiKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        match &self.op {
            GmiOp::Broadcast { dsts } => {
                let dsts = dsts.clone();
                let tx = &mut self.tx;
                io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
                    io2.consume(payload.bytes());
                    for (i, d) in dsts.iter().enumerate() {
                        tx[i].push(d.retag(meta), at, payload.clone());
                    }
                });
            }
            GmiOp::Scatter { dsts, policy } => {
                let dsts = dsts.clone();
                let policy = *policy;
                let tx = &mut self.tx;
                io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
                    io2.consume(payload.bytes());
                    if policy == ScatterPolicy::ColumnSplit {
                        let parts = column_split(&payload, dsts.len());
                        for ((i, d), part) in dsts.iter().enumerate().zip(parts) {
                            tx[i].push(d.retag(meta), at, part);
                        }
                        return;
                    }
                    let n = dsts.len() as u32;
                    let (idx, row, rows) = match policy {
                        ScatterPolicy::Block => {
                            let per = meta.rows.div_ceil(n);
                            let i = (meta.row / per).min(n - 1);
                            let start = i * per;
                            let count = per.min(meta.rows - start);
                            (i as usize, meta.row - start, count)
                        }
                        ScatterPolicy::RoundRobin => {
                            let i = meta.row % n;
                            let count = (meta.rows + n - 1 - i) / n; // rows this lane receives
                            (i as usize, meta.row / n, count)
                        }
                        ScatterPolicy::ColumnSplit => unreachable!(),
                    };
                    let meta2 = dsts[idx].retag(MsgMeta { row, rows, ..meta });
                    tx[idx].push(meta2, at, payload);
                });
            }
            GmiOp::Gather { n_srcs, dst } => {
                let (n_srcs, dst) = (*n_srcs, *dst);
                let GmiKernel { gather, tx, .. } = self;
                io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
                    io2.consume(payload.bytes());
                    let st = gather.msgs.entry(meta.inference).or_default();
                    let rank = meta.stream;
                    let entry =
                        st.per_rank.entry(rank).or_insert_with(|| (meta.rows, HashMap::new()));
                    entry.1.insert(meta.row, (payload, at));

                    // emit eagerly in (rank, row) order; a buffered row
                    // leaves at the arrival that unblocked it (running
                    // max of arrivals along the emission order)
                    loop {
                        if (st.next_rank as usize) >= n_srcs {
                            break;
                        }
                        let Some((expect, buf)) = st.per_rank.get_mut(&st.next_rank) else {
                            break;
                        };
                        if st.next_row >= *expect {
                            st.next_rank += 1;
                            st.next_row = 0;
                            continue;
                        }
                        let Some((payload, arr)) = buf.remove(&st.next_row) else { break };
                        st.unblock = st.unblock.max(arr);
                        // total output rows unknown until all ranks
                        // announce; use the running emitted counter for
                        // row numbering and patch `rows` with the
                        // per-rank total sum when known (senders all use
                        // the same per-message total in our graphs)
                        let total: u32 = st.per_rank.values().map(|(e, _)| *e).sum();
                        let meta2 = dst.retag(MsgMeta {
                            stream: 0,
                            row: st.emitted,
                            rows: total.max(st.emitted + 1),
                            inference: meta.inference,
                        });
                        tx[0].push(meta2, st.unblock, payload);
                        st.emitted += 1;
                        st.next_row += 1;
                    }
                    if (st.next_rank as usize) >= n_srcs {
                        gather.msgs.remove(&meta.inference);
                    }
                });
            }
            GmiOp::GatherCols { n_srcs, dst } => {
                let (n_srcs, dst) = (*n_srcs, *dst);
                let GmiKernel { gather_cols, tx, .. } = self;
                io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
                    io2.consume(payload.bytes());
                    let key = (meta.inference, meta.row);
                    let slot = gather_cols.entry(key).or_default();
                    slot.0.insert(meta.stream, payload);
                    slot.1 = slot.1.max(at);
                    if slot.0.len() == n_srcs {
                        let (mut parts, done_at) = gather_cols.remove(&key).unwrap();
                        let ordered: Vec<Payload> = (0..n_srcs as u8)
                            .map(|r| parts.remove(&r).expect("missing rank"))
                            .collect();
                        let meta2 = dst.retag(MsgMeta { stream: 0, ..meta });
                        tx[0].push(meta2, done_at, column_concat(ordered));
                    }
                });
            }
            GmiOp::Reduce { n_srcs, dst, f } => {
                let (n_srcs, dst, fcomb) = (*n_srcs, *dst, *f);
                let GmiKernel { reduce, reduce_meta, tx, .. } = self;
                io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
                    io2.consume(payload.bytes());
                    reduce_meta.insert(meta.inference, meta.rows);
                    let key = (meta.inference, meta.row);
                    let slot =
                        reduce.entry(key).or_insert_with(|| (0, zero_like(&payload), 0));
                    slot.0 += 1;
                    slot.1 = combine(&slot.1, &payload, fcomb);
                    slot.2 = slot.2.max(at);
                    if slot.0 == n_srcs {
                        let (_, acc, done_at) = reduce.remove(&key).unwrap();
                        let rows = *reduce_meta.get(&meta.inference).unwrap_or(&meta.rows);
                        let meta2 = dst.retag(MsgMeta {
                            stream: 0,
                            row: meta.row,
                            rows,
                            inference: meta.inference,
                        });
                        tx[0].push(meta2, done_at, acc);
                    }
                });
            }
            GmiOp::Forward { dst } => {
                let dst = *dst;
                let tx = &mut self.tx;
                io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
                    io2.consume(payload.bytes());
                    tx[0].push(dst.retag(meta), at, payload);
                });
            }
        }
        self.pump_all(io);
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == GMI_TX_WAKE {
            self.wake_at = None;
            self.pump_all(io);
        }
    }

    fn name(&self) -> String {
        format!("gmi-{}", self.op.kind().to_lowercase())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::START_TAG;
    use crate::sim::fabric::{FpgaId, SwitchId};
    use crate::sim::fifo::Fifo;
    use crate::sim::Sim;

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    /// Sends a fixed row stream at start.
    struct Tx {
        dst: GlobalKernelId,
        rows: Vec<Vec<i32>>,
        stream: u8,
    }
    impl KernelBehavior for Tx {
        fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
        fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
            if tag == START_TAG {
                let n = self.rows.len() as u32;
                for (i, r) in self.rows.iter().enumerate() {
                    let meta = MsgMeta {
                        stream: self.stream,
                        row: i as u32,
                        rows: n,
                        inference: 0,
                    };
                    io.send(self.dst, meta, Payload::row_i32(r.clone()));
                }
            }
        }
    }

    /// Records received rows in arrival order (burst-aware).
    #[derive(Default)]
    struct Rx;
    impl KernelBehavior for Rx {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            io.rows(pkt, |io2: &mut KernelIo, meta, _at, payload| {
                io2.consume(payload.bytes());
                RECORDER.with(|r| r.borrow_mut().push((io2.self_id, meta, payload)));
            });
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    thread_local! {
        static RECORDER: std::cell::RefCell<Vec<(GlobalKernelId, MsgMeta, Payload)>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }

    fn recorded() -> Vec<(GlobalKernelId, MsgMeta, Payload)> {
        RECORDER.with(|r| r.borrow().clone())
    }
    fn reset_recorder() {
        RECORDER.with(|r| r.borrow_mut().clear());
    }

    fn base_sim() -> Sim {
        let mut sim = Sim::new();
        for f in 0..4 {
            sim.fabric.attach(FpgaId(f), SwitchId(0));
        }
        sim
    }

    fn i32_of(p: &Payload) -> i32 {
        match p {
            Payload::RowI32(v) => v[0],
            _ => panic!("expected RowI32"),
        }
    }

    #[test]
    fn broadcast_clones_to_all() {
        reset_recorder();
        let mut sim = base_sim();
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 2),
            rows: vec![vec![1, 2], vec![3, 4]],
            stream: 0,
        })).unwrap();
        sim.add_kernel(
            k(0, 2),
            FpgaId(1),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Broadcast { dsts: vec![Out::to(k(0, 3)), Out::to(k(0, 4))] })),
        )
        .unwrap();
        sim.add_kernel(k(0, 3), FpgaId(2), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.add_kernel(k(0, 4), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        let got = recorded();
        assert_eq!(got.len(), 4);
        let to3 = got.iter().filter(|(id, _, _)| *id == k(0, 3)).count();
        assert_eq!(to3, 2);
    }

    #[test]
    fn scatter_block_splits_rows() {
        reset_recorder();
        let mut sim = base_sim();
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 2),
            rows: (0..6).map(|i| vec![i]).collect(),
            stream: 0,
        })).unwrap();
        sim.add_kernel(
            k(0, 2),
            FpgaId(1),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Scatter {
                dsts: vec![Out::to(k(0, 3)), Out::to(k(0, 4))],
                policy: ScatterPolicy::Block,
            })),
        )
        .unwrap();
        sim.add_kernel(k(0, 3), FpgaId(2), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.add_kernel(k(0, 4), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        let got = recorded();
        // rows 0..2 -> kernel 3, rows 3..5 -> kernel 4, renumbered 0..2
        let to3: Vec<i32> = got
            .iter()
            .filter(|(id, _, _)| *id == k(0, 3))
            .map(|(_, _, p)| i32_of(p))
            .collect();
        assert_eq!(to3, vec![0, 1, 2]);
        for (id, meta, _) in &got {
            if *id == k(0, 4) {
                assert!(meta.row < 3);
                assert_eq!(meta.rows, 3);
            }
        }
    }

    #[test]
    fn gather_orders_by_rank() {
        reset_recorder();
        let mut sim = base_sim();
        // rank 1 fires first but must be emitted after rank 0
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![10], vec![11]],
            stream: 1,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![0], vec![1]],
            stream: 0,
        })).unwrap();
        sim.add_kernel(
            k(0, 3),
            FpgaId(2),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Gather { n_srcs: 2, dst: Out::to(k(0, 4)) })),
        )
        .unwrap();
        sim.add_kernel(k(0, 4), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        let vals: Vec<i32> = recorded().iter().map(|(_, _, p)| i32_of(p)).collect();
        assert_eq!(vals, vec![0, 1, 10, 11]);
        let rows: Vec<u32> = recorded().iter().map(|(_, m, _)| m.row).collect();
        assert_eq!(rows, vec![0, 1, 2, 3]);
    }

    #[test]
    fn reduce_sums_across_ranks() {
        reset_recorder();
        let mut sim = base_sim();
        for (kid, stream, base) in [(1u8, 0u8, 0), (2, 1, 100)] {
            sim.add_kernel(k(0, kid), FpgaId(kid as usize - 1), Fifo::new(1 << 16), Box::new(Tx {
                dst: k(0, 3),
                rows: vec![vec![base + 1, base + 2], vec![base + 3, base + 4]],
                stream,
            })).unwrap();
        }
        sim.add_kernel(
            k(0, 3),
            FpgaId(2),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Reduce {
                n_srcs: 2,
                dst: Out::to(k(0, 4)),
                f: ReduceFn::Sum,
            })),
        )
        .unwrap();
        sim.add_kernel(k(0, 4), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        let mut rows: Vec<(u32, Vec<i32>)> = recorded()
            .iter()
            .map(|(_, m, p)| match p {
                Payload::RowI32(v) => (m.row, (**v).clone()),
                _ => panic!(),
            })
            .collect();
        rows.sort();
        assert_eq!(rows, vec![(0, vec![102, 104]), (1, vec![106, 108])]);
    }

    #[test]
    fn allgather_composes_from_gather_plus_broadcast() {
        // §5.1: Allgather = Gather to a root, then Broadcast back out.
        // The gather and broadcast share FpgaId(2), so the hand-off
        // between them is a coalesced burst — results must be unchanged.
        reset_recorder();
        let mut sim = base_sim();
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![7]],
            stream: 0,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 16), Box::new(Tx {
            dst: k(0, 3),
            rows: vec![vec![8]],
            stream: 1,
        })).unwrap();
        sim.add_kernel(
            k(0, 3),
            FpgaId(2),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Gather { n_srcs: 2, dst: Out::to(k(0, 4)) })),
        )
        .unwrap();
        sim.add_kernel(
            k(0, 4),
            FpgaId(2),
            Fifo::new(1 << 16),
            Box::new(GmiKernel::new(GmiOp::Broadcast { dsts: vec![Out::to(k(0, 5)), Out::to(k(0, 6))] })),
        )
        .unwrap();
        sim.add_kernel(k(0, 5), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.add_kernel(k(0, 6), FpgaId(3), Fifo::new(1 << 16), Box::new(Rx)).unwrap();
        sim.start();
        sim.run().unwrap();
        // both leaves see both rows
        for leaf in [k(0, 5), k(0, 6)] {
            let n = recorded().iter().filter(|(id, _, _)| *id == leaf).count();
            assert_eq!(n, 2, "leaf {leaf} sees the gathered set");
        }
    }

    #[test]
    fn column_split_concat_roundtrip() {
        let row = Payload::row_i8((0..24).collect());
        let parts = column_split(&row, 4);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0].bytes(), 6);
        assert_eq!(column_concat(parts), row);
    }
}
