//! The Gateway kernel (§4 + §5.3, Fig. 8): the single entry point of a
//! cluster. Contains the Packet Decoder (GMI header), the Forwarding
//! module (point-to-point), and integrated GMI modules ("virtual kernels")
//! that reserve kernel ids without occupying the application region.

use std::collections::HashMap;

use crate::sim::engine::{KernelBehavior, KernelIo};
use crate::sim::packet::{GlobalKernelId, Packet};

use super::ops::{GmiKernel, GmiOp};
#[cfg(test)]
use super::ops::Out;

/// Static configuration of one cluster's gateway.
#[derive(Debug, Clone, Default)]
pub struct GatewayConfig {
    pub cluster: u8,
    /// virtual kernel id -> integrated GMI module. Id 0 designates the
    /// gateway's own ingress module (e.g. the encoder input Broadcast of
    /// Fig. 14's Kern_0).
    pub virtuals: HashMap<u8, GmiOp>,
}

/// The gateway behavior: decode -> (virtual GMI module | forwarding).
/// Virtual modules live in a BTreeMap so wake fan-out is deterministic.
pub struct Gateway {
    cfg: GatewayConfig,
    subs: std::collections::BTreeMap<u8, GmiKernel>,
}

impl Gateway {
    pub fn new(cfg: GatewayConfig) -> Self {
        let subs = cfg
            .virtuals
            .iter()
            .map(|(&id, op)| (id, GmiKernel::new(op.clone())))
            .collect();
        Gateway { cfg, subs }
    }
}

impl KernelBehavior for Gateway {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        // inter-cluster traffic is never coalesced (bursts are intra-FPGA,
        // intra-cluster by construction), so the gateway sees single rows
        debug_assert!(pkt.burst.is_none(), "gateway received a coalesced burst");
        io.consume(pkt.wire_bytes());
        // Packet Decoder: the one-byte GMI header names the final kernel.
        // Intra-cluster packets addressed to the gateway itself (no
        // header) go to module 0.
        let target = pkt.gmi_dst.unwrap_or(0);
        // strip the header before anything is re-sent
        let mut inner = pkt;
        inner.gmi_dst = None;
        inner.inter_cluster = false;
        inner.src = io.self_id;

        if let Some(sub) = self.subs.get_mut(&target) {
            // integrated GMI module (virtual kernel)
            sub.on_packet(inner, io);
        } else if target != 0 {
            // Forwarding module: plain point-to-point to the local kernel
            io.send(GlobalKernelId::new(self.cfg.cluster, target), inner.meta, inner.payload);
        } else {
            // no module configured and no forward target: drop (decoder
            // has nowhere to send it) — surfaced via trace counters.
        }
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        // deferred-emission sweeps of the integrated GMI modules fire as
        // wakes on the gateway kernel; relay them (no-op for the rest)
        for sub in self.subs.values_mut() {
            sub.on_wake(tag, io);
        }
    }

    fn name(&self) -> String {
        format!("gateway-c{}", self.cfg.cluster)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::START_TAG;
    use crate::sim::fabric::{FpgaId, SwitchId};
    use crate::sim::fifo::Fifo;
    use crate::sim::packet::{MsgMeta, Payload};
    use crate::sim::Sim;

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    struct Once {
        dst: GlobalKernelId,
        bytes: usize,
    }
    impl KernelBehavior for Once {
        fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
        fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
            if tag == START_TAG {
                io.send(
                    self.dst,
                    MsgMeta { rows: 1, ..Default::default() },
                    Payload::Timing(self.bytes),
                );
            }
        }
    }

    struct Sink;
    impl KernelBehavior for Sink {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            io.consume(pkt.wire_bytes());
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn two_cluster_sim(virtuals: HashMap<u8, GmiOp>, dst: GlobalKernelId) -> Sim {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(4096), Box::new(Once { dst, bytes: 768 }))
            .unwrap();
        sim.add_kernel(
            k(1, 0),
            FpgaId(1),
            Fifo::new(4096),
            Box::new(Gateway::new(GatewayConfig { cluster: 1, virtuals })),
        )
        .unwrap();
        for kid in [5u8, 6] {
            sim.add_kernel(k(1, kid), FpgaId(1), Fifo::new(4096), Box::new(Sink)).unwrap();
        }
        sim
    }

    #[test]
    fn forwards_point_to_point_by_header() {
        // sender targets c1k5; sender-side protocol rewrites to gateway+header
        let mut sim = two_cluster_sim(HashMap::new(), k(1, 5));
        sim.start();
        sim.run().unwrap();
        assert_eq!(sim.trace.kernel(k(1, 5)).unwrap().rx_packets, 1);
        assert!(sim.trace.kernel(k(1, 6)).is_none_or(|s| s.rx_packets == 0));
    }

    #[test]
    fn virtual_broadcast_module_at_gateway() {
        let mut virtuals = HashMap::new();
        virtuals.insert(0u8, GmiOp::Broadcast { dsts: vec![Out::to(k(1, 5)), Out::to(k(1, 6))] });
        // sender targets the gateway itself (kernel 0) => module 0 broadcast
        let mut sim = two_cluster_sim(virtuals, k(1, 0));
        sim.start();
        sim.run().unwrap();
        assert_eq!(sim.trace.kernel(k(1, 5)).unwrap().rx_packets, 1);
        assert_eq!(sim.trace.kernel(k(1, 6)).unwrap().rx_packets, 1);
    }

    #[test]
    fn header_is_stripped_on_forward() {
        let mut sim = two_cluster_sim(HashMap::new(), k(1, 5));
        sim.start();
        sim.run().unwrap();
        // 768-byte payload: 13 flits on the wire inter-cluster (header
        // byte), 12 after the gateway strips it. Verify via fabric flit
        // accounting: 13 (src->gw) + 12 (gw->k5) = 25.
        assert_eq!(sim.fabric.stats.flits, 25);
    }

    #[test]
    fn unroutable_header_is_dropped_quietly() {
        // no module at 0, sender targets gateway itself
        let mut sim = two_cluster_sim(HashMap::new(), k(1, 0));
        sim.start();
        sim.run().unwrap();
        assert_eq!(sim.trace.kernel(k(1, 0)).unwrap().rx_packets, 1);
        assert!(sim.trace.kernel(k(1, 5)).is_none_or(|s| s.rx_packets == 0));
    }
}
