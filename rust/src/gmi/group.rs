//! Communicators: MPI-style groups with integer ranks (§2.2, §5.1).

use anyhow::{bail, Result};

use crate::sim::packet::GlobalKernelId;

/// A group of kernels with dense ranks. Intra-communicators stay within
/// one cluster; inter-communicators span clusters (and therefore traverse
/// gateways).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Communicator {
    pub id: u32,
    pub members: Vec<GlobalKernelId>,
}

impl Communicator {
    pub fn new(id: u32, members: Vec<GlobalKernelId>) -> Result<Self> {
        if members.is_empty() {
            bail!("communicator {id} has no members");
        }
        let mut seen = std::collections::HashSet::new();
        for m in &members {
            if !seen.insert(*m) {
                bail!("communicator {id}: duplicate member {m}");
            }
        }
        Ok(Communicator { id, members })
    }

    pub fn size(&self) -> usize {
        self.members.len()
    }

    pub fn rank_of(&self, k: GlobalKernelId) -> Option<usize> {
        self.members.iter().position(|m| *m == k)
    }

    pub fn member(&self, rank: usize) -> Option<GlobalKernelId> {
        self.members.get(rank).copied()
    }

    /// True iff all members are in one cluster (intra-communicator).
    pub fn is_intra(&self) -> bool {
        self.members.windows(2).all(|w| w[0].cluster == w[1].cluster)
    }

    /// Subgroup by rank list (§5.1: "kernels [can] form subgroups and
    /// perform collective operations within subgroups").
    pub fn subgroup(&self, id: u32, ranks: &[usize]) -> Result<Communicator> {
        let mut members = Vec::with_capacity(ranks.len());
        for &r in ranks {
            match self.member(r) {
                Some(m) => members.push(m),
                None => bail!("subgroup rank {r} out of range (size {})", self.size()),
            }
        }
        Communicator::new(id, members)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    #[test]
    fn ranks_are_positions() {
        let comm = Communicator::new(1, vec![k(0, 3), k(0, 5), k(1, 2)]).unwrap();
        assert_eq!(comm.rank_of(k(0, 5)), Some(1));
        assert_eq!(comm.member(2), Some(k(1, 2)));
        assert_eq!(comm.rank_of(k(9, 9)), None);
        assert!(!comm.is_intra());
    }

    #[test]
    fn intra_detection() {
        let comm = Communicator::new(2, vec![k(4, 1), k(4, 2)]).unwrap();
        assert!(comm.is_intra());
    }

    #[test]
    fn duplicates_rejected() {
        assert!(Communicator::new(3, vec![k(0, 1), k(0, 1)]).is_err());
        assert!(Communicator::new(4, vec![]).is_err());
    }

    #[test]
    fn subgroups() {
        let comm = Communicator::new(5, vec![k(0, 1), k(0, 2), k(0, 3), k(0, 4)]).unwrap();
        let sub = comm.subgroup(6, &[0, 2]).unwrap();
        assert_eq!(sub.members, vec![k(0, 1), k(0, 3)]);
        assert!(comm.subgroup(7, &[9]).is_err());
    }
}
