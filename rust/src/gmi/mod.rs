//! The Galapagos Messaging Interface (§5): MPI-like collective
//! communication for Galapagos clusters, implemented as kernels in the
//! application region plus virtual kernels inside gateways.
//!
//! Design points reproduced from the paper:
//! * GMI kernels are ordinary Galapagos kernels inserted into the graph
//!   (Fig. 6) — compute kernels stay free of communication logic;
//! * the protocol is extremely lightweight: no header intra-cluster, one
//!   byte (destination kernel id) inter-cluster (§5.2);
//! * gateways integrate GMI modules as *virtual kernels* (§5.3, Fig. 8);
//! * communicators group kernels for intra-group and inter-group
//!   collectives, with subgroup support (§5.1).

pub mod gateway;
pub mod group;
pub mod ops;

pub use gateway::{Gateway, GatewayConfig};
pub use group::Communicator;
pub use ops::{GmiKernel, GmiOp, Out, ReduceFn, ScatterPolicy};
