//! FPGA device catalog and resource model (Fig. 15's axes).

pub mod resources;

pub use resources::{Device, ResourceBudget, ResourceUsage};
