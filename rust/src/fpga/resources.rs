//! Device resource catalogs and the utilisation model behind Fig. 15.
//!
//! The paper's limiting resource is BRAM (matrix-sized AXIS FIFOs + all
//! weights on-chip); DSP usage follows from the PE counts. We model the
//! four headline resources (LUT, FF, BRAM18, DSP) and let the Cluster
//! Builder estimate per-kernel usage from its tile/PE parameters.

use std::ops::{Add, AddAssign};

/// A device's total resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceBudget {
    pub lut: u64,
    pub ff: u64,
    pub bram18: u64,
    pub dsp: u64,
}

/// Resources consumed by a kernel / shell / FPGA build.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceUsage {
    pub lut: u64,
    pub ff: u64,
    pub bram18: u64,
    pub dsp: u64,
}

impl Add for ResourceUsage {
    type Output = ResourceUsage;
    fn add(self, o: ResourceUsage) -> ResourceUsage {
        ResourceUsage {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram18: self.bram18 + o.bram18,
            dsp: self.dsp + o.dsp,
        }
    }
}
impl AddAssign for ResourceUsage {
    fn add_assign(&mut self, o: ResourceUsage) {
        *self = *self + o;
    }
}

/// Rolling up usages is how multi-tenant accounting works: each
/// tenant's kernels sum into one ledger line, checked against the
/// budget of the sub-fleet that tenant was allocated.
impl std::iter::Sum for ResourceUsage {
    fn sum<I: Iterator<Item = ResourceUsage>>(iter: I) -> ResourceUsage {
        iter.fold(ResourceUsage::default(), |a, b| a + b)
    }
}

impl ResourceUsage {
    /// Utilisation fractions against a budget: (lut, ff, bram, dsp).
    pub fn utilisation(&self, b: &ResourceBudget) -> (f64, f64, f64, f64) {
        (
            self.lut as f64 / b.lut as f64,
            self.ff as f64 / b.ff as f64,
            self.bram18 as f64 / b.bram18 as f64,
            self.dsp as f64 / b.dsp as f64,
        )
    }

    pub fn fits(&self, b: &ResourceBudget) -> bool {
        self.lut <= b.lut && self.ff <= b.ff && self.bram18 <= b.bram18 && self.dsp <= b.dsp
    }

    pub fn max_utilisation(&self, b: &ResourceBudget) -> f64 {
        let (l, f, br, d) = self.utilisation(b);
        l.max(f).max(br).max(d)
    }
}

/// Device models the platform knows about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    /// XCZU19EG UltraScale+ (Fidus Sidewinder-100) — the paper's testbed.
    Xczu19eg,
    /// XCVC1902 Versal AI Core (VCK190) — §9's estimation target.
    Xcvc1902,
}

impl Device {
    /// All devices the platform knows about (the CLI `info` catalog and
    /// the placer's heterogeneous-fleet parsing iterate this).
    pub const ALL: [Device; 2] = [Device::Xczu19eg, Device::Xcvc1902];

    /// Stable lower-case name used in description files and plans.
    pub fn name(&self) -> &'static str {
        match self {
            Device::Xczu19eg => "xczu19eg",
            Device::Xcvc1902 => "xcvc1902",
        }
    }

    pub fn from_name(name: &str) -> Option<Device> {
        Device::ALL.into_iter().find(|d| d.name() == name)
    }

    pub fn budget(&self) -> ResourceBudget {
        match self {
            // XCZU19EG: 522,720 LUTs, 1,045,440 FFs, 1968 BRAM18, 1968 DSP48
            Device::Xczu19eg => ResourceBudget {
                lut: 522_720,
                ff: 1_045_440,
                bram18: 1_968,
                dsp: 1_968,
            },
            // XCVC1902: 899,840 LUTs, 1,799,680 FFs, 1934 BRAM18, 1968 DSP58
            // (+400 AIEs modeled separately in versal::aie)
            Device::Xcvc1902 => ResourceBudget {
                lut: 899_840,
                ff: 1_799_680,
                bram18: 1_934,
                dsp: 1_968,
            },
        }
    }

    /// Static shell ("hypervisor" layer §2.1): 100G MAC + Gulf-Stream UDP +
    /// bridges + router. Calibrated as a modest fraction of the device.
    pub fn shell_usage(&self) -> ResourceUsage {
        ResourceUsage { lut: 60_000, ff: 90_000, bram18: 120, dsp: 0 }
    }

    /// INT8 multiply-accumulate lanes per DSP slice (two int8 MACs pack
    /// into one DSP48E2 with the standard 27x18 trick).
    pub fn int8_macs_per_dsp(&self) -> u64 {
        match self {
            Device::Xczu19eg => 2,
            Device::Xcvc1902 => 3, // DSP58 INT8 packing
        }
    }

    /// Full-device configuration image size — what the §6 recovery path
    /// must stream to bring a replacement region up after a failure.
    /// XCZU19EG: ~45 MB bitstream; XCVC1902: ~82 MB PDI (Versal images
    /// carry NoC/AIE configuration on top of the fabric frames).
    pub fn bitstream_bytes(&self) -> u64 {
        match self {
            Device::Xczu19eg => 45 << 20,
            Device::Xcvc1902 => 82 << 20,
        }
    }
}

/// BRAM18 blocks needed to hold a KV-cache of `bytes` on-chip. A decode
/// kernel's cache is persistent state (unlike a FIFO it is never
/// drained), so it is charged block-granular against the device budget:
/// a BRAM18 holds 2304 bytes of int8 (the same geometry as
/// `sim::fifo::BRAM18_BYTES` — kept as a local constant because `fpga`
/// sits below `sim` in the module DAG; a placer test cross-checks the
/// two never drift). Any non-empty cache costs at least one block.
pub fn kv_cache_bram18(bytes: u64) -> u64 {
    const BRAM18_BYTES: u64 = 2304;
    bytes.div_ceil(BRAM18_BYTES).max(1)
}

/// BRAM18 blocks for a continuously batched decoder holding `slots`
/// concurrent sequences. Each slot is an independently addressed
/// block-granular region — rows of different requests land in the same
/// pipeline pass, so slots cannot pack into shared blocks — making the
/// charge `slots` times the single-sequence cost. `slots <= 1` reduces
/// to [`kv_cache_bram18`].
pub fn batched_kv_cache_bram18(bytes: u64, slots: u64) -> u64 {
    kv_cache_bram18(bytes) * slots.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_cache_is_block_granular() {
        assert_eq!(kv_cache_bram18(0), 1); // allocated, even if tiny
        assert_eq!(kv_cache_bram18(1), 1);
        assert_eq!(kv_cache_bram18(2304), 1);
        assert_eq!(kv_cache_bram18(2305), 2);
        // the paper build point: one head's K cache, 128 x 64 bytes
        assert_eq!(kv_cache_bram18(128 * 64), 4);
    }

    #[test]
    fn batched_kv_slots_multiply_block_granular() {
        // degenerate slot counts reduce to the single-sequence charge
        assert_eq!(batched_kv_cache_bram18(128 * 64, 0), kv_cache_bram18(128 * 64));
        assert_eq!(batched_kv_cache_bram18(128 * 64, 1), kv_cache_bram18(128 * 64));
        // 8 batch slots of the paper head cache: 8 independent regions,
        // each individually block-granular (no packing across slots)
        assert_eq!(batched_kv_cache_bram18(128 * 64, 8), 32);
        // a sub-block cache still costs one full block PER slot
        assert_eq!(batched_kv_cache_bram18(100, 4), 4);
    }

    #[test]
    fn usage_sums_per_component() {
        let a = ResourceUsage { lut: 1, ff: 2, bram18: 3, dsp: 4 };
        let b = ResourceUsage { lut: 10, ff: 20, bram18: 30, dsp: 40 };
        let total: ResourceUsage = [a, b].into_iter().sum();
        assert_eq!(total, ResourceUsage { lut: 11, ff: 22, bram18: 33, dsp: 44 });
        let empty: ResourceUsage = std::iter::empty().sum();
        assert_eq!(empty, ResourceUsage::default());
    }

    #[test]
    fn utilisation_math() {
        let b = Device::Xczu19eg.budget();
        let u = ResourceUsage { lut: b.lut / 2, ff: 0, bram18: b.bram18, dsp: 0 };
        let (l, _, br, _) = u.utilisation(&b);
        assert!((l - 0.5).abs() < 1e-12);
        assert!((br - 1.0).abs() < 1e-12);
        assert!(u.fits(&b));
        assert_eq!(u.max_utilisation(&b), 1.0);
    }

    #[test]
    fn overflow_detected() {
        let b = Device::Xczu19eg.budget();
        let u = ResourceUsage { bram18: b.bram18 + 1, ..Default::default() };
        assert!(!u.fits(&b));
    }

    #[test]
    fn device_names_roundtrip() {
        for d in Device::ALL {
            assert_eq!(Device::from_name(d.name()), Some(d));
        }
        assert_eq!(Device::from_name("stratix"), None);
    }

    #[test]
    fn shell_fits_comfortably() {
        for d in [Device::Xczu19eg, Device::Xcvc1902] {
            let u = d.shell_usage();
            assert!(u.max_utilisation(&d.budget()) < 0.2);
        }
    }

    #[test]
    fn paper_dsp_budget_supports_pe_counts() {
        // DESIGN.md calibration: one 768-MAC linear kernel needs <= 384 DSPs
        // on the XCZU19EG (2 int8 MACs/DSP) — three fit alongside headroom.
        let d = Device::Xczu19eg;
        let dsp_per_linear = 768 / d.int8_macs_per_dsp();
        assert_eq!(dsp_per_linear, 384);
        assert!(3 * dsp_per_linear < d.budget().dsp);
    }
}
