//! The Cluster Builder (§6): the automation front-end that turns a model
//! + description files into Galapagos clusters.
//!
//! Paper flow (Fig. 9) → our substitution:
//!   Model File System Generator  → python/compile/weights.py (build time)
//!   Cluster Information Extractor → [`extractor`] (kernel id/src/dst/type)
//!   Layer Builder + handlers      → [`layer_builder`] (behaviors + resources)
//!   GMI Builder                   → GMI kernel configs in ibert::graph
//!   IP Generator (Vivado HLS Tcl) → [`ip_generator`] (Tcl + build manifest)

pub mod description;
pub mod extractor;
pub mod ip_generator;
pub mod layer_builder;

pub use description::BuildDescription;
pub use extractor::{extract_cluster_info, KernelInfo};
pub use layer_builder::{fpga_reports, kernel_usage, FpgaReport};
