//! Description files (§6.1): the Cluster Description File (how many
//! clusters, partitioning) and Layer Description File (module configs,
//! parallelisation / resource knobs) as one JSON document.

use anyhow::{bail, Context, Result};

use crate::eval::testbed::TestbedConfig;
use crate::fpga::resources::Device;
use crate::ibert::kernels::Mode;
use crate::ibert::timing::PeConfig;
use crate::util::json::Json;

/// Parsed build description.
#[derive(Debug, Clone)]
pub struct BuildDescription {
    pub model: String,
    /// number of encoder clusters to build
    pub encoders: usize,
    pub max_seq: usize,
    pub fpgas_per_switch: usize,
    pub device: Device,
    pub pe: PeConfig,
}

impl Default for BuildDescription {
    fn default() -> Self {
        BuildDescription {
            model: "ibert-base".into(),
            encoders: 1,
            max_seq: 128,
            fpgas_per_switch: 6,
            device: Device::Xczu19eg,
            pe: PeConfig::default(),
        }
    }
}

impl BuildDescription {
    pub fn parse(text: &str) -> Result<BuildDescription> {
        let j = Json::parse(text).context("build description")?;
        let mut d = BuildDescription::default();
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            if m != "ibert-base" {
                bail!("unknown model {m:?} (this reproduction builds ibert-base)");
            }
            d.model = m.to_string();
        }
        let geti = |name: &str, dflt: usize| -> Result<usize> {
            match j.get(name) {
                None => Ok(dflt),
                Some(v) => v
                    .as_i64()
                    .map(|x| x as usize)
                    .with_context(|| format!("{name} must be an integer")),
            }
        };
        d.encoders = geti("encoders", d.encoders)?;
        d.max_seq = geti("max_seq", d.max_seq)?;
        d.fpgas_per_switch = geti("fpgas_per_switch", d.fpgas_per_switch)?;
        if d.encoders == 0 || d.encoders > 42 {
            bail!("encoders must be 1..=42 (256-cluster limit minus eval)");
        }
        match j.get("device").and_then(Json::as_str) {
            None => {}
            Some("xczu19eg") => d.device = Device::Xczu19eg,
            Some("xcvc1902") => d.device = Device::Xcvc1902,
            Some(other) => bail!("unknown device {other:?}"),
        }
        if let Some(pe) = j.get("pe") {
            let getu = |name: &str, dflt: u64| -> Result<u64> {
                match pe.get(name) {
                    None => Ok(dflt),
                    Some(v) => v.as_i64().map(|x| x as u64)
                        .with_context(|| format!("pe.{name} must be an integer")),
                }
            };
            d.pe = PeConfig {
                linear_macs: getu("linear_macs", d.pe.linear_macs)?,
                ffn_macs: getu("ffn_macs", d.pe.ffn_macs)?,
                attn_pes: getu("attn_pes", d.pe.attn_pes)?,
                smm_pes: getu("smm_pes", d.pe.smm_pes)?,
                sm_simd: getu("sm_simd", d.pe.sm_simd)?,
                ln_simd: getu("ln_simd", d.pe.ln_simd)?,
                pipe_fill: getu("pipe_fill", d.pe.pipe_fill)?,
            };
        }
        Ok(d)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<BuildDescription> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    /// Convert into a simulator testbed configuration.
    pub fn testbed(&self, m: usize, inferences: u32, interval: u64, mode: Mode) -> TestbedConfig {
        TestbedConfig {
            encoders: self.encoders,
            m,
            inferences,
            interval,
            pe: self.pe,
            mode,
            fpgas_per_switch: self.fpgas_per_switch,
            input: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_description() {
        let d = BuildDescription::parse(
            r#"{"model": "ibert-base", "encoders": 12, "max_seq": 128,
                "fpgas_per_switch": 6, "device": "xczu19eg",
                "pe": {"linear_macs": 768, "attn_pes": 16}}"#,
        )
        .unwrap();
        assert_eq!(d.encoders, 12);
        assert_eq!(d.pe.attn_pes, 16);
        assert_eq!(d.pe.ffn_macs, 3072); // default preserved
    }

    #[test]
    fn defaults_on_empty() {
        let d = BuildDescription::parse("{}").unwrap();
        assert_eq!(d.encoders, 1);
        assert_eq!(d.device, Device::Xczu19eg);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(BuildDescription::parse(r#"{"model": "gpt-3"}"#).is_err());
        assert!(BuildDescription::parse(r#"{"encoders": 0}"#).is_err());
        assert!(BuildDescription::parse(r#"{"encoders": 100}"#).is_err());
        assert!(BuildDescription::parse(r#"{"device": "stratix"}"#).is_err());
        assert!(BuildDescription::parse(r#"{"pe": {"attn_pes": "lots"}}"#).is_err());
    }
}
