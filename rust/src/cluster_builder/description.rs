//! Description files (§6.1): the Cluster Description File (how many
//! clusters, partitioning) and Layer Description File (module configs,
//! parallelisation / resource knobs) as one JSON document.
//!
//! Since the automatic placer landed, a description also names the model
//! *shape* (hidden / ffn / heads — presets for `ibert-base` and
//! `bert-large`, overridable field by field) and the *fleet* it should
//! be mapped onto (`fleet_size` homogeneous FPGAs of `device`, or an
//! explicit heterogeneous `devices` list, plus the `util_cap`
//! place-and-route headroom).

use anyhow::{bail, Context, Result};

use crate::eval::testbed::TestbedConfig;
use crate::fpga::resources::Device;
use crate::ibert::kernels::Mode;
use crate::ibert::timing::PeConfig;
use crate::util::json::Json;

/// Parsed build description.
#[derive(Debug, Clone, PartialEq)]
pub struct BuildDescription {
    pub model: String,
    /// number of encoder clusters to build
    pub encoders: usize,
    pub max_seq: usize,
    pub fpgas_per_switch: usize,
    pub device: Device,
    pub pe: PeConfig,
    // -- model shape (placer input) -------------------------------------
    pub hidden: usize,
    pub ffn: usize,
    pub heads: usize,
    // -- fleet (placer input) -------------------------------------------
    /// explicit heterogeneous fleet; overrides device x fleet_size
    pub devices: Option<Vec<Device>>,
    /// homogeneous fleet size per encoder when `devices` is absent
    pub fleet_size: usize,
    /// utilisation headroom the packer targets (place-and-route margin)
    pub util_cap: f64,
}

impl Default for BuildDescription {
    fn default() -> Self {
        BuildDescription {
            model: "ibert-base".into(),
            encoders: 1,
            max_seq: 128,
            fpgas_per_switch: 6,
            device: Device::Xczu19eg,
            pe: PeConfig::default(),
            hidden: 768,
            ffn: 3072,
            heads: 12,
            devices: None,
            fleet_size: 6,
            util_cap: 0.85,
        }
    }
}

impl BuildDescription {
    pub fn parse(text: &str) -> Result<BuildDescription> {
        let j = Json::parse(text).context("build description")?;
        let mut d = BuildDescription::default();
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            match m {
                "ibert-base" => {}
                "bert-large" => {
                    d.hidden = 1024;
                    d.ffn = 4096;
                    d.heads = 16;
                    d.fleet_size = 12;
                }
                _ => bail!("unknown model {m:?} (presets: ibert-base, bert-large)"),
            }
            d.model = m.to_string();
        }
        let geti = |name: &str, dflt: usize| -> Result<usize> {
            match j.get(name) {
                None => Ok(dflt),
                Some(v) => v
                    .as_i64()
                    .map(|x| x as usize)
                    .with_context(|| format!("{name} must be an integer")),
            }
        };
        d.encoders = geti("encoders", d.encoders)?;
        d.max_seq = geti("max_seq", d.max_seq)?;
        d.fpgas_per_switch = geti("fpgas_per_switch", d.fpgas_per_switch)?;
        d.hidden = geti("hidden", d.hidden)?;
        d.ffn = geti("ffn", d.ffn)?;
        d.heads = geti("heads", d.heads)?;
        d.fleet_size = geti("fleet_size", d.fleet_size)?;
        if d.encoders == 0 || d.encoders > 42 {
            bail!("encoders must be 1..=42 (256-cluster limit minus eval)");
        }
        if d.heads == 0 || d.hidden == 0 || d.hidden % d.heads != 0 {
            bail!("hidden ({}) must be a positive multiple of heads ({})", d.hidden, d.heads);
        }
        match j.get("device").and_then(Json::as_str) {
            None => {}
            Some(name) => match Device::from_name(name) {
                Some(dev) => d.device = dev,
                None => bail!("unknown device {name:?}"),
            },
        }
        if let Some(list) = j.get("devices") {
            let arr = list.as_arr().context("devices must be an array of device names")?;
            let mut devs = Vec::new();
            for v in arr {
                let name = v.as_str().context("devices entries must be strings")?;
                match Device::from_name(name) {
                    Some(dev) => devs.push(dev),
                    None => bail!("unknown device {name:?} in devices list"),
                }
            }
            if devs.is_empty() {
                bail!("devices list must not be empty");
            }
            d.devices = Some(devs);
        }
        if let Some(v) = j.get("util_cap") {
            let cap = v.as_f64().context("util_cap must be a number")?;
            if !(0.1..=1.0).contains(&cap) {
                bail!("util_cap must be in [0.1, 1.0], got {cap}");
            }
            d.util_cap = cap;
        }
        if let Some(pe) = j.get("pe") {
            let getu = |name: &str, dflt: u64| -> Result<u64> {
                match pe.get(name) {
                    None => Ok(dflt),
                    Some(v) => v.as_i64().map(|x| x as u64)
                        .with_context(|| format!("pe.{name} must be an integer")),
                }
            };
            d.pe = PeConfig {
                linear_macs: getu("linear_macs", d.pe.linear_macs)?,
                ffn_macs: getu("ffn_macs", d.pe.ffn_macs)?,
                attn_pes: getu("attn_pes", d.pe.attn_pes)?,
                smm_pes: getu("smm_pes", d.pe.smm_pes)?,
                sm_simd: getu("sm_simd", d.pe.sm_simd)?,
                ln_simd: getu("ln_simd", d.pe.ln_simd)?,
                pipe_fill: getu("pipe_fill", d.pe.pipe_fill)?,
            };
        }
        Ok(d)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<BuildDescription> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    /// Serialize back to description JSON (placements round-trip through
    /// this form: `parse(d.to_json().pretty()) == d`).
    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(&str, Json)> = vec![
            ("model", self.model.as_str().into()),
            ("encoders", self.encoders.into()),
            ("max_seq", self.max_seq.into()),
            ("fpgas_per_switch", self.fpgas_per_switch.into()),
            ("device", self.device.name().into()),
            ("hidden", self.hidden.into()),
            ("ffn", self.ffn.into()),
            ("heads", self.heads.into()),
            ("fleet_size", self.fleet_size.into()),
            ("util_cap", self.util_cap.into()),
        ];
        if let Some(devs) = &self.devices {
            kv.push(("devices", Json::Arr(devs.iter().map(|d| d.name().into()).collect())));
        }
        kv.push((
            "pe",
            Json::obj(vec![
                ("linear_macs", (self.pe.linear_macs as i64).into()),
                ("ffn_macs", (self.pe.ffn_macs as i64).into()),
                ("attn_pes", (self.pe.attn_pes as i64).into()),
                ("smm_pes", (self.pe.smm_pes as i64).into()),
                ("sm_simd", (self.pe.sm_simd as i64).into()),
                ("ln_simd", (self.pe.ln_simd as i64).into()),
                ("pipe_fill", (self.pe.pipe_fill as i64).into()),
            ]),
        ));
        Json::obj(kv)
    }

    /// The model shape this description asks the placer to map.
    pub fn shape(&self) -> crate::placer::ModelShape {
        crate::placer::ModelShape {
            hidden: self.hidden,
            ffn: self.ffn,
            heads: self.heads,
            max_seq: self.max_seq,
            ffn_split: 1,
        }
    }

    /// The fleet available to one encoder cluster.
    pub fn fleet(&self) -> crate::placer::Fleet {
        let devices = match &self.devices {
            Some(v) => v.clone(),
            None => vec![self.device; self.fleet_size],
        };
        crate::placer::Fleet {
            devices,
            fpgas_per_switch: self.fpgas_per_switch,
            util_cap: self.util_cap,
        }
    }

    /// Convert into a simulator testbed configuration.
    pub fn testbed(&self, m: usize, inferences: u32, interval: u64, mode: Mode) -> TestbedConfig {
        TestbedConfig {
            encoders: self.encoders,
            m,
            inferences,
            interval,
            pe: self.pe,
            mode,
            fpgas_per_switch: self.fpgas_per_switch,
            input: None,
            placement: None,
            schedule: None,
            decode: None,
            batching: None,
            threads: None,
            granularity: None,
            net: Default::default(),
            fail: None,
            obs: Default::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_description() {
        let d = BuildDescription::parse(
            r#"{"model": "ibert-base", "encoders": 12, "max_seq": 128,
                "fpgas_per_switch": 6, "device": "xczu19eg",
                "pe": {"linear_macs": 768, "attn_pes": 16}}"#,
        )
        .unwrap();
        assert_eq!(d.encoders, 12);
        assert_eq!(d.pe.attn_pes, 16);
        assert_eq!(d.pe.ffn_macs, 3072); // default preserved
    }

    #[test]
    fn defaults_on_empty() {
        let d = BuildDescription::parse("{}").unwrap();
        assert_eq!(d.encoders, 1);
        assert_eq!(d.device, Device::Xczu19eg);
        assert_eq!((d.hidden, d.ffn, d.heads), (768, 3072, 12));
        assert_eq!(d.fleet().n_slots(), 6);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(BuildDescription::parse(r#"{"model": "gpt-3"}"#).is_err());
        assert!(BuildDescription::parse(r#"{"encoders": 0}"#).is_err());
        assert!(BuildDescription::parse(r#"{"encoders": 100}"#).is_err());
        assert!(BuildDescription::parse(r#"{"device": "stratix"}"#).is_err());
        assert!(BuildDescription::parse(r#"{"pe": {"attn_pes": "lots"}}"#).is_err());
        assert!(BuildDescription::parse(r#"{"hidden": 770}"#).is_err()); // 770 % 12 != 0
        assert!(BuildDescription::parse(r#"{"devices": []}"#).is_err());
        assert!(BuildDescription::parse(r#"{"devices": ["stratix"]}"#).is_err());
        assert!(BuildDescription::parse(r#"{"util_cap": 3.0}"#).is_err());
    }

    #[test]
    fn bert_large_preset() {
        let d = BuildDescription::parse(r#"{"model": "bert-large"}"#).unwrap();
        assert_eq!((d.hidden, d.ffn, d.heads), (1024, 4096, 16));
        assert_eq!(d.fleet_size, 12);
        let shape = d.shape();
        assert_eq!(shape.head_dim(), 64);
    }

    #[test]
    fn heterogeneous_fleet_parses() {
        let d = BuildDescription::parse(
            r#"{"devices": ["xcvc1902", "xcvc1902", "xczu19eg", "xczu19eg",
                           "xczu19eg", "xczu19eg"], "util_cap": 0.9}"#,
        )
        .unwrap();
        let f = d.fleet();
        assert_eq!(f.n_slots(), 6);
        assert_eq!(f.device(0), Device::Xcvc1902);
        assert_eq!(f.device(5), Device::Xczu19eg);
        assert!((f.util_cap - 0.9).abs() < 1e-12);
    }

    #[test]
    fn description_json_roundtrip() {
        for src in [
            "{}",
            r#"{"model": "bert-large", "encoders": 3}"#,
            r#"{"devices": ["xcvc1902", "xczu19eg"], "util_cap": 0.75,
                "pe": {"linear_macs": 384}}"#,
        ] {
            let d = BuildDescription::parse(src).unwrap();
            let back = BuildDescription::parse(&d.to_json().pretty()).unwrap();
            assert_eq!(back, d, "round-trip failed for {src}");
        }
    }
}
