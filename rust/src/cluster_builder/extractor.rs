//! Cluster Information Extractor (§6.1): derives Kernel IDs, Kernel
//! Sources, Kernel Destinations and Kernel Types from the cluster graph —
//! the intermediate the Layer Builder and GMI Builder consume.

use std::collections::HashMap;

use crate::galapagos::cluster::{ClusterSpec, KernelType};
use crate::sim::packet::GlobalKernelId;

/// Extracted information for one kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelInfo {
    pub id: u8,
    pub name: String,
    pub ktype: KernelType,
    pub fpga: usize,
    pub sources: Vec<GlobalKernelId>,
    pub destinations: Vec<GlobalKernelId>,
}

/// Extract per-kernel info (including reverse edges) from a cluster spec.
pub fn extract_cluster_info(c: &ClusterSpec) -> Vec<KernelInfo> {
    let mut sources: HashMap<u8, Vec<GlobalKernelId>> = HashMap::new();
    for k in &c.kernels {
        for d in &k.dests {
            if d.cluster == c.id {
                sources.entry(d.kernel).or_default().push(GlobalKernelId::new(c.id, k.id));
            }
        }
    }
    let mut out: Vec<KernelInfo> = c
        .kernels
        .iter()
        .map(|k| KernelInfo {
            id: k.id,
            name: k.name.clone(),
            ktype: k.ktype,
            fpga: k.fpga.0,
            sources: sources.remove(&k.id).unwrap_or_default(),
            destinations: k.dests.clone(),
        })
        .collect();
    out.sort_by_key(|k| k.id);
    out
}

/// The three id classes of §6.1 (compute / GMI / virtual) as counts.
pub fn id_class_counts(infos: &[KernelInfo]) -> (usize, usize, usize) {
    let mut compute = 0;
    let mut gmi = 0;
    let mut virt = 0;
    for i in infos {
        match i.ktype {
            KernelType::Compute => compute += 1,
            KernelType::Gmi => gmi += 1,
            KernelType::Virtual => virt += 1,
            KernelType::Gateway => {}
        }
    }
    (compute, gmi, virt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmi::Out;
    use crate::ibert::graph::{build_encoder, ids, EncoderGraphParams};
    use crate::ibert::kernels::Mode;
    use crate::ibert::timing::PeConfig;

    fn encoder_infos() -> Vec<KernelInfo> {
        let gp = EncoderGraphParams {
            cluster_id: 0,
            fpga_base: 0,
            pe: PeConfig::default(),
            mode: Mode::Timing,
            out_dst: Out::to(GlobalKernelId::new(200, 2)),
            max_seq: 128,
            hidden: 768,
            ffn: 3072,
            decode: None,
            batched: false,
        };
        extract_cluster_info(&build_encoder(&gp).cluster)
    }

    #[test]
    fn ids_are_contiguous_and_complete() {
        let infos = encoder_infos();
        assert_eq!(infos.len(), 38);
        for (i, info) in infos.iter().enumerate() {
            assert_eq!(info.id as usize, i);
        }
    }

    #[test]
    fn reverse_edges_derived() {
        let infos = encoder_infos();
        // the gather kernel receives from all 12 smm heads
        let gather = &infos[ids::GATHER as usize];
        assert_eq!(gather.sources.len(), 12);
        // LN1 receives from the gateway (residual) and proj
        let ln1 = &infos[ids::LN1 as usize];
        assert_eq!(ln1.sources.len(), 2);
    }

    #[test]
    fn class_counts_match_fig14() {
        let infos = encoder_infos();
        let (compute, gmi, virt) = id_class_counts(&infos);
        assert_eq!(compute, 32);
        assert_eq!(gmi, 5);
        assert_eq!(virt, 0); // the input broadcast lives inside the gateway
    }
}
