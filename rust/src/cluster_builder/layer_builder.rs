//! Layer Builder (§6.1): per-module handlers producing resource estimates
//! — the Fig. 15 model. (Behaviors themselves are built by ibert::graph;
//! this module owns the hardware-cost side the paper's handlers computed
//! from HLS reports.)

use crate::fpga::resources::{Device, ResourceBudget, ResourceUsage};
use crate::galapagos::cluster::{ClusterSpec, KernelType, PlatformSpec};
use crate::ibert::timing::PeConfig;
use crate::placer::{fig14_role, role_usage, ModelShape};

/// Resource estimate of one encoder kernel (by id), including its input
/// and output FIFOs (§8.2.1) and held weights. The formulas live in the
/// placer's role-based model (`placer::role_usage`); this keeps the
/// Fig. 15 id-based view as a thin adapter over the 12-head layout.
pub fn kernel_usage(
    id: u8,
    pe: &PeConfig,
    dev: Device,
    max_seq: usize,
    hidden: usize,
    ffn: usize,
) -> ResourceUsage {
    let shape = ModelShape { hidden, ffn, heads: 12, max_seq, ffn_split: 1 };
    role_usage(fig14_role(id), &shape, pe, dev)
}

/// Per-FPGA aggregate report (one Fig. 15 bar group).
#[derive(Debug, Clone)]
pub struct FpgaReport {
    pub fpga: usize,
    pub kernels: Vec<u8>,
    pub usage: ResourceUsage,
    pub budget: ResourceBudget,
}

impl FpgaReport {
    pub fn utilisation(&self) -> (f64, f64, f64, f64) {
        self.usage.utilisation(&self.budget)
    }
    pub fn fits(&self) -> bool {
        self.usage.fits(&self.budget)
    }
}

/// Aggregate kernel estimates per FPGA for one encoder cluster: kernels +
/// shell (the static "hypervisor" region) + the two routing tables.
pub fn fpga_reports(
    cluster: &ClusterSpec,
    pe: &PeConfig,
    dev: Device,
    max_seq: usize,
    hidden: usize,
    ffn: usize,
) -> Vec<FpgaReport> {
    let routing_bram = crate::galapagos::RoutingTables::new(cluster.id).bram18() as u64;
    let mut by_fpga: std::collections::BTreeMap<usize, FpgaReport> = Default::default();
    for k in &cluster.kernels {
        if k.ktype == KernelType::Virtual {
            continue;
        }
        let r = by_fpga.entry(k.fpga.0).or_insert_with(|| FpgaReport {
            fpga: k.fpga.0,
            kernels: vec![],
            usage: dev.shell_usage()
                + ResourceUsage { bram18: routing_bram, ..Default::default() },
            budget: dev.budget(),
        });
        r.kernels.push(k.id);
        r.usage += kernel_usage(k.id, pe, dev, max_seq, hidden, ffn);
    }
    by_fpga.into_values().collect()
}

/// Validate that every FPGA of a platform fits its device (the check the
/// paper's flow gets from Vivado place-and-route).
pub fn validate_fit(
    spec: &PlatformSpec,
    pe: &PeConfig,
    dev: Device,
    max_seq: usize,
    hidden: usize,
    ffn: usize,
) -> anyhow::Result<()> {
    for c in &spec.clusters {
        for r in fpga_reports(c, pe, dev, max_seq, hidden, ffn) {
            if !r.fits() {
                anyhow::bail!(
                    "FPGA {} over budget: LUT {:.0}% FF {:.0}% BRAM {:.0}% DSP {:.0}%",
                    r.fpga,
                    r.utilisation().0 * 100.0,
                    r.utilisation().1 * 100.0,
                    r.utilisation().2 * 100.0,
                    r.utilisation().3 * 100.0
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmi::Out;
    use crate::ibert::graph::{build_encoder, EncoderGraphParams};
    use crate::ibert::kernels::Mode;
    use crate::sim::packet::GlobalKernelId;

    fn cluster() -> ClusterSpec {
        build_encoder(&EncoderGraphParams {
            cluster_id: 0,
            fpga_base: 0,
            pe: PeConfig::default(),
            mode: Mode::Timing,
            out_dst: Out::to(GlobalKernelId::new(200, 2)),
            max_seq: 128,
            hidden: 768,
            ffn: 3072,
            decode: None,
            batched: false,
        })
        .cluster
    }

    #[test]
    fn six_fpga_reports_and_all_fit() {
        let reports =
            fpga_reports(&cluster(), &PeConfig::default(), Device::Xczu19eg, 128, 768, 3072);
        assert_eq!(reports.len(), 6);
        for r in &reports {
            assert!(r.fits(), "FPGA {} over budget: {:?}", r.fpga, r.utilisation());
        }
    }

    #[test]
    fn bram_is_the_limiting_resource_on_weight_fpgas() {
        // Fig. 15: BRAM dominates (weights + matrix FIFOs on-chip)
        let reports =
            fpga_reports(&cluster(), &PeConfig::default(), Device::Xczu19eg, 128, 768, 3072);
        // FPGA 4 (FFN1) and FPGA 5 (FFN2 + LN2) hold the 768x3072 weights
        for r in reports.iter().filter(|r| r.fpga >= 4) {
            let (lut, ff, bram, _dsp) = r.utilisation();
            assert!(bram > lut && bram > ff, "bram should dominate on FPGA {}", r.fpga);
            assert!(bram > 0.5, "weight FPGAs should be BRAM-heavy: {bram:.2}");
        }
    }

    #[test]
    fn dsp_pattern_matches_paper_shape() {
        // §8.2.1: linear/FFN FPGAs use much more DSP than the head FPGAs
        let reports =
            fpga_reports(&cluster(), &PeConfig::default(), Device::Xczu19eg, 128, 768, 3072);
        let dsp: Vec<f64> = reports.iter().map(|r| r.utilisation().3).collect();
        assert!(dsp[4] > 0.5 && dsp[5] > 0.5, "FFN FPGAs DSP-heavy: {dsp:?}");
        assert!(dsp[1] < dsp[4], "head FPGA lighter than FFN: {dsp:?}");
        assert!(dsp[0] > 0.4, "QKV FPGA uses substantial DSP: {dsp:?}");
    }

    #[test]
    fn oversized_pe_config_fails_validation() {
        let pe = PeConfig { linear_macs: 100_000, ..Default::default() };
        let reports = fpga_reports(&cluster(), &pe, Device::Xczu19eg, 128, 768, 3072);
        assert!(reports.iter().any(|r| !r.fits()));
    }
}
