//! Prior single-FPGA transformer accelerators the paper compares against.

/// A published FPGA accelerator datapoint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpgaBaseline {
    pub name: &'static str,
    /// batch-1 latency (ms) at max seq len 128 (None if not reported)
    pub latency_ms_seq128: Option<f64>,
    /// throughput (inferences/s) at max seq len 64
    pub throughput_inf_s_seq64: Option<f64>,
    pub notes: &'static str,
}

/// NPE (Khan et al., FPGA'21): overlay NLP processor, 8-bit matmuls.
pub const NPE: FpgaBaseline = FpgaBaseline {
    name: "NPE (FPGA)",
    latency_ms_seq128: Some(13.96),
    throughput_inf_s_seq64: Some(135.14),
    notes: "overlay processor, layer-by-layer reuse — low throughput",
};

/// FTRANS (Li et al., ISLPED'20): BCM-compressed transformer.
pub const FTRANS: FpgaBaseline = FpgaBaseline {
    name: "FTRANS",
    latency_ms_seq128: None,
    throughput_inf_s_seq64: Some(101.79),
    notes: "block-circulant compression; ~4.3% accuracy drop on BERT",
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_and_4_baselines() {
        assert_eq!(NPE.latency_ms_seq128, Some(13.96));
        assert_eq!(NPE.throughput_inf_s_seq64, Some(135.14));
        assert_eq!(FTRANS.throughput_inf_s_seq64, Some(101.79));
    }
}
