//! GPU baselines: the NVIDIA TensorRT BERT-base INT8 numbers the paper
//! compares against (max seq len 128), plus a roofline cross-check.

/// One GPU comparison point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuBaseline {
    pub name: &'static str,
    /// batch-1 latency (ms), BERT-base INT8, seq len 128 (TensorRT report)
    pub batch1_latency_ms: f64,
    /// batch-128 latency (ms) — the throughput-optimal point
    pub batch128_latency_ms: f64,
    /// peak INT8 throughput (TOPS)
    pub int8_tops: f64,
    /// board power (W), for the efficiency discussion
    pub tdp_w: f64,
}

impl GpuBaseline {
    /// Throughput derived the way the paper does it (§8.2.3): batch-128
    /// latency divided across the batch.
    pub fn throughput_inf_s(&self) -> f64 {
        128.0 / (self.batch128_latency_ms / 1e3)
    }

    /// Batch-1 "effective" latency the batched run imposes on each request
    /// (the §8.2.3 nuance: all results arrive when the batch completes).
    pub fn batched_request_latency_ms(&self) -> f64 {
        self.batch128_latency_ms
    }

    /// Roofline sanity: BERT-base forward is ~22.4 GFLOPs (INT8 ops) at
    /// seq 128; utilisation = achieved / peak.
    pub fn batch1_utilisation(&self) -> f64 {
        let ops = 22.4e9; // 2 * 11.2e9 MACs
        let achieved_tops = ops / (self.batch1_latency_ms / 1e3) / 1e12;
        achieved_tops / self.int8_tops
    }
}

/// NVIDIA T4 (TensorRT report, BERT-base INT8, seq 128).
pub const T4: GpuBaseline = GpuBaseline {
    name: "NVIDIA T4",
    batch1_latency_ms: 1.66,
    batch128_latency_ms: 80.95, // §8.2.3: "latency of 80.95 ms for a batch size of 128"
    int8_tops: 130.0,
    tdp_w: 70.0,
};

/// NVIDIA A100 (TensorRT report, BERT-base INT8, seq 128).
pub const A100: GpuBaseline = GpuBaseline {
    name: "NVIDIA A100",
    batch1_latency_ms: 0.77,
    // derived from the paper's 11962.6 inf/s: 128 / 11962.6 = 10.70 ms
    batch128_latency_ms: 10.70,
    int8_tops: 1248.0,
    tdp_w: 400.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughputs_match_paper_table5() {
        // Table 5: T4 = 1581.2 inf/s, A100 = 11962.6 inf/s
        assert!((T4.throughput_inf_s() - 1581.2).abs() < 1.0, "{}", T4.throughput_inf_s());
        assert!((A100.throughput_inf_s() - 11962.6).abs() < 25.0, "{}", A100.throughput_inf_s());
    }

    #[test]
    fn batch1_utilisation_is_low() {
        // the low-batch inefficiency that motivates FPGAs (§1): batch-1
        // achieves a small fraction of peak INT8 throughput
        assert!(T4.batch1_utilisation() < 0.25);
        assert!(A100.batch1_utilisation() < 0.05);
    }

    #[test]
    fn batched_latency_dwarfs_batch1() {
        // §8.2.3's nuance: batched requests wait for the whole batch
        assert!(T4.batched_request_latency_ms() > 40.0 * T4.batch1_latency_ms);
    }
}
