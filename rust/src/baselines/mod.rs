//! Published comparison points (§8 Tables 3-5, §9.3) + an analytic GPU
//! roofline used as a sanity check on the published numbers.

pub mod fpga_prior;
pub mod gpu;

pub use fpga_prior::{FTRANS, NPE};
pub use gpu::{GpuBaseline, A100, T4};
