//! ASCII table renderer for reproducing the paper's tables on stdout.

/// A simple right-padded text table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<1$} |", c, w[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as machine-readable CSV (used by EXPERIMENTS.md capture).
    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }
}

/// Convenience formatters used by the table generators.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn i0(x: u64) -> String {
    format!("{x}")
}
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("| name      | value |"));
        assert!(s.contains("| long-name | 12345 |"));
        let lines: Vec<&str> = s.lines().collect();
        // title + 4 separators/rows + 2 data rows
        assert_eq!(lines.len(), 7);
        let width = lines[1].len();
        assert!(lines[1..].iter().all(|l| l.len() == width));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new("", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.005), "1.00");
        assert_eq!(f3(0.12345), "0.123");
        assert_eq!(pct(0.905), "90.5%");
        assert_eq!(i0(42), "42");
    }
}
