//! Substrate utilities built from scratch for the offline environment
//! (no serde / clap / criterion / proptest / rand in the vendored set).

pub mod bench;
pub mod fxhash;
pub mod cli;
pub mod json;
pub mod pool;
pub mod quickcheck;
pub mod rng;
pub mod table;
pub mod tensorfile;
