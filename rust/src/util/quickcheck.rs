//! Mini property-testing framework (proptest is not in the vendored set).
//!
//! A property is a closure over a [`Gen`] (seeded value source). `check`
//! runs it across many seeds; on failure it reports the seed so the case
//! can be replayed deterministically. Used by the coordinator invariant
//! tests (routing, batching, GMI state machines).

use super::rng::Rng;

/// A seeded generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.bool_with_p(0.5)
    }
    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }
    /// A vector whose length scales with the generation `size`.
    pub fn vec<T>(&mut self, mut item: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.rng.range_usize(0, self.size);
        (0..n).map(|_| item(self)).collect()
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range_usize(0, xs.len() - 1)]
    }
}

/// Outcome of a property: Ok or a failure description.
pub type PropResult = Result<(), String>;

pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, base_seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Run `prop` across `cfg.cases` seeds; panics with the failing seed.
pub fn check_with(cfg: &Config, name: &str, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // grow the size with the case index so early failures are small
        let size = 1 + (cfg.max_size * (case + 1)) / cfg.cases;
        let mut g = Gen { rng: Rng::new(seed), size };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {size}): {msg}"
            );
        }
    }
}

/// Run with default config.
pub fn check(name: &str, prop: impl FnMut(&mut Gen) -> PropResult) {
    check_with(&Config::default(), name, prop)
}

/// Assertion helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-reverse-id", |g| {
            let v = g.vec(|g| g.i64_in(-100, 100));
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            prop_assert!(r == v, "{v:?} != {r:?}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", |g| {
            let x = g.usize_in(0, 10);
            prop_assert!(x > 100, "x={x} not > 100");
            Ok(())
        });
    }

    #[test]
    fn sizes_grow() {
        let mut max_len = 0;
        check("sizes", |g| {
            max_len = max_len.max(g.size);
            Ok(())
        });
        assert!(max_len >= 32);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<i64> = vec![];
        check_with(&Config { cases: 10, ..Default::default() }, "det-a", |g| {
            first.push(g.i64_in(0, 1000));
            Ok(())
        });
        let mut second: Vec<i64> = vec![];
        check_with(&Config { cases: 10, ..Default::default() }, "det-b", |g| {
            second.push(g.i64_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
