//! FxHash-style fast hasher (the rustc-internal multiply-rotate hash) for
//! the simulator's hot-path maps — SipHash (std default) dominated the
//! event-dispatch profile (EXPERIMENTS.md §Perf L3.2).

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default, Clone, Copy)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }
    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }
    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(i, i * 2);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&500], 1000);
    }

    #[test]
    fn distributes() {
        // crude avalanche check: nearby keys hash far apart
        let h = |x: u64| {
            let mut f = FxHasher::default();
            f.write_u64(x);
            f.finish()
        };
        let a = h(1);
        let b = h(2);
        assert_ne!(a, b);
        assert!((a ^ b).count_ones() > 8, "poor diffusion: {a:x} vs {b:x}");
    }

    #[test]
    fn byte_slices() {
        let h = |x: &[u8]| {
            let mut f = FxHasher::default();
            f.write(x);
            f.finish()
        };
        assert_ne!(h(b"hello"), h(b"hellp"));
        assert_ne!(h(b"12345678"), h(b"123456789"));
    }
}
