//! Tiny scoped worker pool (rayon is not in the vendored crate set).
//!
//! Deterministic data-parallel helpers built on `std::thread::scope`:
//! outputs are written into pre-split disjoint chunks, so results are
//! bit-identical to the serial loop regardless of thread count. Thread
//! count comes from `GALAPAGOS_THREADS` (0/1 disables) or the machine's
//! available parallelism.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Worker threads to use for data-parallel sections.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("GALAPAGOS_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Process-wide default for the sharded DES engine (`--threads` CLI
/// flag); 0 = unset.
static SIM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Pin the process-wide default simulator thread count (the `--threads`
/// flag; 0 clears back to env/auto). Per-`Sim` settings override this.
pub fn set_sim_threads(n: usize) {
    SIM_THREADS.store(n, Ordering::SeqCst);
}

/// Worker threads for the sharded DES engine: the `--threads` override
/// if set, else `PALLAS_SIM_THREADS`, else the machine's available
/// parallelism. Deliberately NOT cached: tests and benches flip it.
pub fn sim_threads() -> usize {
    let over = SIM_THREADS.load(Ordering::SeqCst);
    if over > 0 {
        return over;
    }
    if let Ok(v) = std::env::var("PALLAS_SIM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(0) .. f(n-1)` on `n` scoped worker threads and return the
/// results in index order — the long-lived-worker primitive the sharded
/// DES engine builds its barrier rounds on (one spawn per run, not per
/// window). `n == 1` runs inline.
pub fn run_workers<R: Send>(n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    if n <= 1 {
        return vec![f(0)];
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (i, slot) in out.iter_mut().enumerate() {
            let fr = &f;
            s.spawn(move || {
                *slot = Some(fr(i));
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker completed")).collect()
}

/// Fill `out` by calling `f(start_index, chunk)` for consecutive chunks
/// of `chunk` elements, distributing chunks round-robin over the worker
/// threads. Serial when one thread suffices or the input is small.
pub fn parallel_chunks<T, F>(out: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk.max(1));
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (ci, sl) in out.chunks_mut(chunk).enumerate() {
            f(ci * chunk, sl);
        }
        return;
    }
    // deal chunks round-robin so uneven per-row cost still balances
    let mut lists: Vec<Vec<(usize, &mut [T])>> = (0..threads).map(|_| Vec::new()).collect();
    for (ci, sl) in out.chunks_mut(chunk).enumerate() {
        lists[ci % threads].push((ci * chunk, sl));
    }
    let fr = &f;
    std::thread::scope(|s| {
        for list in lists {
            s.spawn(move || {
                for (start, sl) in list {
                    fr(start, sl);
                }
            });
        }
    });
}

/// Parallel map over a slice; result order matches input order and every
/// element is computed exactly as in the serial loop.
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    parallel_chunks(&mut out, 1, |start, sl| {
        sl[0] = Some(f(&items[start]));
    });
    out.into_iter().map(|o| o.expect("parallel_map: unfilled slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_every_index_once() {
        let mut out = vec![0usize; 103];
        parallel_chunks(&mut out, 8, |start, sl| {
            for (j, o) in sl.iter_mut().enumerate() {
                *o = start + j + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i + 1);
        }
    }

    #[test]
    fn map_preserves_order() {
        let xs: Vec<u64> = (0..57).collect();
        let ys = parallel_map(&xs, |&x| x * x);
        assert_eq!(ys, xs.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn run_workers_indexes_and_joins() {
        let hits = std::sync::Mutex::new(Vec::new());
        let out = run_workers(4, |i| {
            hits.lock().unwrap().push(i);
            i * 10
        });
        assert_eq!(out, vec![0, 10, 20, 30]);
        let mut h = hits.into_inner().unwrap();
        h.sort_unstable();
        assert_eq!(h, vec![0, 1, 2, 3]);
        assert_eq!(run_workers(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn sim_threads_is_always_positive() {
        // the override itself is NOT exercised here: lib tests share one
        // process, and flipping the global would transiently change
        // which engine concurrently-running default-threads Sims select
        // (results are identical by contract, but engine selection
        // should not be racy in the suite). The override path is covered
        // end-to-end by the CI thread-parity job's --threads flag.
        assert!(sim_threads() >= 1);
        set_sim_threads(0); // clearing an unset override is a no-op
        assert!(sim_threads() >= 1);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut out: Vec<u8> = vec![];
        parallel_chunks(&mut out, 4, |_, _| panic!("no chunks expected"));
        let ys = parallel_map(&[5u8], |&x| x + 1);
        assert_eq!(ys, vec![6]);
    }
}
