//! Deterministic PRNG (the vendored set has no `rand`): splitmix64 seeding
//! into xoshiro256** — the standard small-state generator with good
//! statistical properties, plus the few distributions the workloads need.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Default for Rng {
    fn default() -> Self {
        Rng::new(0)
    }
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Uses rejection-free Lemire
    /// reduction; bias is negligible for our range sizes.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        lo + (self.next_u64() % span)
    }

    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool_with_p(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Rng::new(5);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            seen_lo |= v == -2;
            seen_hi |= v == 2;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(8);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f64 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2={p2}");
    }
}
