//! Benchmark harness (criterion is not in the vendored crate set).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! multiple timed samples, median/mean/p95 reporting, and a `black_box`
//! to defeat the optimiser. Table-generating benches also use it to time
//! the end-to-end experiment regeneration.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    fn per_iter_ns(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self
            .samples
            .iter()
            .map(|d| d.as_nanos() as f64 / self.iters_per_sample as f64)
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }
    pub fn median_ns(&self) -> f64 {
        let v = self.per_iter_ns();
        v[v.len() / 2]
    }
    pub fn mean_ns(&self) -> f64 {
        let v = self.per_iter_ns();
        v.iter().sum::<f64>() / v.len() as f64
    }
    pub fn p95_ns(&self) -> f64 {
        let v = self.per_iter_ns();
        v[((v.len() as f64 * 0.95) as usize).min(v.len() - 1)]
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  p95 {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns()),
            self.samples.len(),
            self.iters_per_sample
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner with warmup and automatic iteration calibration.
pub struct Bencher {
    pub warmup: Duration,
    pub samples: usize,
    pub target_sample_time: Duration,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            samples: 12,
            target_sample_time: Duration::from_millis(120),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            samples: 5,
            target_sample_time: Duration::from_millis(40),
            results: Vec::new(),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup + calibration
        let t0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.target_sample_time.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let s = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(s.elapsed());
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            samples,
            iters_per_sample: iters,
        });
        let r = self.results.last().unwrap();
        println!("{}", r.report());
        r
    }

    /// Run once and report wall-clock (for heavyweight end-to-end drivers).
    pub fn once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> T {
        let s = Instant::now();
        let out = f();
        let d = s.elapsed();
        println!("{:<44} once   {:>12}", name, fmt_ns(d.as_nanos() as f64));
        self.results.push(BenchResult {
            name: name.to_string(),
            samples: vec![d],
            iters_per_sample: 1,
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            samples: 3,
            target_sample_time: Duration::from_millis(2),
            results: vec![],
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let r = &b.results[0];
        assert!(r.median_ns() > 0.0);
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 us");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }

    #[test]
    fn once_returns_value() {
        let mut b = Bencher::quick();
        let v = b.once("ret", || 42);
        assert_eq!(v, 42);
    }
}
