//! Benchmark harness (criterion is not in the vendored crate set).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! multiple timed samples, median/mean/p95 reporting, and a `black_box`
//! to defeat the optimiser. Table-generating benches also use it to time
//! the end-to-end experiment regeneration.
//!
//! [`check_headlines`] backs the `--check` regression gate: committed
//! BENCH_*.json baselines carry a `headlines` object of speedup ratios,
//! and a fresh run must stay within a tolerance of each one.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    fn per_iter_ns(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self
            .samples
            .iter()
            .map(|d| d.as_nanos() as f64 / self.iters_per_sample as f64)
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }
    pub fn median_ns(&self) -> f64 {
        let v = self.per_iter_ns();
        v[v.len() / 2]
    }
    pub fn mean_ns(&self) -> f64 {
        let v = self.per_iter_ns();
        v.iter().sum::<f64>() / v.len() as f64
    }
    pub fn p95_ns(&self) -> f64 {
        let v = self.per_iter_ns();
        v[((v.len() as f64 * 0.95) as usize).min(v.len() - 1)]
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  p95 {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns()),
            self.samples.len(),
            self.iters_per_sample
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner with warmup and automatic iteration calibration.
pub struct Bencher {
    pub warmup: Duration,
    pub samples: usize,
    pub target_sample_time: Duration,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            samples: 12,
            target_sample_time: Duration::from_millis(120),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            samples: 5,
            target_sample_time: Duration::from_millis(40),
            results: Vec::new(),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup + calibration
        let t0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.target_sample_time.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let s = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(s.elapsed());
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            samples,
            iters_per_sample: iters,
        });
        let r = self.results.last().unwrap();
        println!("{}", r.report());
        r
    }

    /// Run once and report wall-clock (for heavyweight end-to-end drivers).
    pub fn once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> T {
        let s = Instant::now();
        let out = f();
        let d = s.elapsed();
        println!("{:<44} once   {:>12}", name, fmt_ns(d.as_nanos() as f64));
        self.results.push(BenchResult {
            name: name.to_string(),
            samples: vec![d],
            iters_per_sample: 1,
        });
        out
    }
}

/// Compare a fresh run's `headlines` object against a committed
/// baseline's: every baseline headline must be present and reach at
/// least `baseline * (1 - tolerance)` (headlines are "bigger is better"
/// ratios — speedups, events/s). Returns the regression descriptions
/// (empty = pass). Headlines present only in the current run are new
/// coverage, never a failure.
pub fn check_headlines(current: &Json, baseline: &Json, tolerance: f64) -> Vec<String> {
    let mut regressions = Vec::new();
    let Some(base) = baseline.get("headlines") else {
        regressions.push("baseline has no `headlines` object".to_string());
        return regressions;
    };
    let cur = current.get("headlines");
    for key in base.keys() {
        let want = base.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN);
        if !want.is_finite() {
            continue;
        }
        match cur.and_then(|c| c.get(key)).and_then(Json::as_f64) {
            None => regressions.push(format!("headline {key:?} missing from the current run")),
            Some(got) => {
                let floor = want * (1.0 - tolerance);
                if got < floor {
                    regressions.push(format!(
                        "{key}: {got:.2} < {floor:.2} (baseline {want:.2} - {:.0}%)",
                        100.0 * tolerance
                    ));
                }
            }
        }
    }
    regressions
}

/// Headline keys present in the current run but absent from the
/// baseline — newly added coverage a stale committed baseline does not
/// know about yet. These must never fail `--check` (the baseline
/// catches up when the fresh trajectory is committed); `load_check`
/// surfaces them as warnings so the gap is visible, not silent.
pub fn new_headline_keys(current: &Json, baseline: &Json) -> Vec<String> {
    let base = baseline.get("headlines");
    let Some(cur) = current.get("headlines") else { return Vec::new() };
    cur.keys()
        .into_iter()
        .filter(|k| base.and_then(|b| b.get(k)).is_none())
        .map(str::to_string)
        .collect()
}

/// Provenance of a committed baseline's headline floors. Baselines
/// measured on real hardware record the machine in a `machine` field;
/// baselines committed as conservative promises (no native toolchain on
/// the build container — see DESIGN.md "Perf baselines") mark
/// themselves "unmeasured-floor". `--check` prints which kind gates the
/// run so a pass against a promised floor is never mistaken for a pass
/// against a measurement.
pub fn baseline_provenance(baseline: &Json) -> &'static str {
    match baseline.get("machine").and_then(Json::as_str) {
        Some(m) if m.contains("unmeasured-floor") => "unmeasured-floor",
        Some(_) => "measured",
        None => "measured (machine unrecorded)",
    }
}

/// Structural gaps in a committed baseline that `--check` should call
/// out loudly: an empty or missing `cases` array means the gate holds
/// only the headline floors — there is no recorded trajectory to eyeball
/// a regression against, which is easy to miss when the check passes.
pub fn baseline_warnings(baseline: &Json) -> Vec<String> {
    match baseline.get("cases").and_then(Json::as_arr) {
        Some(cases) if !cases.is_empty() => Vec::new(),
        Some(_) => vec!["baseline `cases` is empty — gating on headline floors only".into()],
        None => vec!["baseline has no `cases` array — gating on headline floors only".into()],
    }
}

/// Shared `--check` front half for the bench CLIs: when `--check` is
/// set, read the baseline (`--baseline`, defaulting to the out path
/// itself — call this BEFORE overwriting the trajectory file) and
/// compare `doc`'s headlines at `--tolerance` (default 0.35). Headlines
/// the baseline does not carry yet are warned about, never failed.
/// `None` when `--check` is absent.
pub fn load_check(
    args: &crate::util::cli::Args,
    doc: &Json,
    out_path: &str,
) -> anyhow::Result<Option<Vec<String>>> {
    if !args.bool_or("check", false)? {
        return Ok(None);
    }
    let base_path = args.str_or("baseline", out_path);
    let text = std::fs::read_to_string(&base_path)
        .map_err(|e| anyhow::anyhow!("--check: cannot read baseline {base_path}: {e}"))?;
    let baseline = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("--check: bad baseline JSON: {e:?}"))?;
    let tol = args.f64_or("tolerance", 0.35)?;
    for w in baseline_warnings(&baseline) {
        println!("--check: warning: {w} ({base_path})");
    }
    // per-headline provenance: say whether each gating floor came from a
    // real measurement or from a committed unmeasured promise
    let prov = baseline_provenance(&baseline);
    if let Some(hl) = baseline.get("headlines") {
        for key in hl.keys() {
            if let Some(want) = hl.get(key).and_then(Json::as_f64) {
                println!(
                    "--check: baseline {key} = {want:.3} [{prov}] \
                     (floor {:.3} at -{:.0}%)",
                    want * (1.0 - tol),
                    100.0 * tol
                );
            }
        }
    }
    for key in new_headline_keys(doc, &baseline) {
        println!(
            "--check: headline {key:?} is new (absent from baseline {base_path}) — \
             informational until the refreshed trajectory is committed"
        );
    }
    Ok(Some(check_headlines(doc, &baseline, tol)))
}

/// Back half of the `--check` gate: print the outcome and fail when any
/// headline regressed (callers invoke this AFTER writing the fresh
/// trajectory, so the regression is recorded either way).
pub fn report_check(regressions: Option<Vec<String>>) -> anyhow::Result<()> {
    let Some(regs) = regressions else {
        return Ok(());
    };
    if regs.is_empty() {
        println!("--check: all baseline headlines hold");
        return Ok(());
    }
    for r in &regs {
        eprintln!("--check REGRESSION: {r}");
    }
    anyhow::bail!("--check: {} headline(s) regressed vs the committed baseline", regs.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            samples: 3,
            target_sample_time: Duration::from_millis(2),
            results: vec![],
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let r = &b.results[0];
        assert!(r.median_ns() > 0.0);
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 us");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }

    #[test]
    fn once_returns_value() {
        let mut b = Bencher::quick();
        let v = b.once("ret", || 42);
        assert_eq!(v, 42);
    }

    #[test]
    fn headline_check_flags_regressions_and_misses() {
        let doc = |pairs: Vec<(&str, f64)>| {
            Json::obj(vec![(
                "headlines",
                Json::obj(pairs.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
            )])
        };
        let base = doc(vec![("a_speedup", 3.0), ("b_speedup", 2.0)]);
        // within tolerance: pass (even with a's dip and an extra key)
        let ok = doc(vec![("a_speedup", 2.2), ("b_speedup", 2.5), ("new_one", 9.0)]);
        assert!(check_headlines(&ok, &base, 0.35).is_empty());
        // a real regression and a missing headline both fail
        let bad = doc(vec![("a_speedup", 1.0)]);
        let regs = check_headlines(&bad, &base, 0.35);
        assert_eq!(regs.len(), 2, "{regs:?}");
        // no headlines in the baseline at all
        assert!(!check_headlines(&ok, &Json::obj(vec![]), 0.35).is_empty());
    }

    #[test]
    fn empty_case_baselines_warn_but_still_gate() {
        // headline floors still apply, but the hole in the trajectory
        // record is surfaced instead of silently gating on floors alone
        let with_cases = Json::obj(vec![(
            "cases",
            Json::Arr(vec![Json::obj(vec![("scenario", Json::Str("x".into()))])]),
        )]);
        assert!(baseline_warnings(&with_cases).is_empty());
        let empty = Json::obj(vec![("cases", Json::Arr(vec![]))]);
        let w = baseline_warnings(&empty);
        assert_eq!(w.len(), 1);
        assert!(w[0].contains("empty"), "{w:?}");
        let missing = Json::obj(vec![("headlines", Json::obj(vec![]))]);
        let w = baseline_warnings(&missing);
        assert_eq!(w.len(), 1);
        assert!(w[0].contains("no `cases`"), "{w:?}");
    }

    #[test]
    fn baseline_provenance_distinguishes_floors_from_measurements() {
        let floor = Json::obj(vec![(
            "machine",
            Json::Str("unmeasured-floor (build container has no native toolchain)".into()),
        )]);
        assert_eq!(baseline_provenance(&floor), "unmeasured-floor");
        let measured = Json::obj(vec![("machine", Json::Str("ryzen-7950x / 32G".into()))]);
        assert_eq!(baseline_provenance(&measured), "measured");
        assert_eq!(baseline_provenance(&Json::obj(vec![])), "measured (machine unrecorded)");
    }

    #[test]
    fn new_headlines_warn_but_never_fail() {
        let doc = |pairs: Vec<(&str, f64)>| {
            Json::obj(vec![(
                "headlines",
                Json::obj(pairs.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
            )])
        };
        // A stale baseline that predates the fleetscale bench: the fresh
        // run's extra headline is surfaced by name but is not a regression.
        let base = doc(vec![("a_speedup", 3.0)]);
        let cur = doc(vec![("a_speedup", 3.1), ("fleetscale_lossy_1000fpga_parallel_speedup", 2.4)]);
        assert_eq!(
            new_headline_keys(&cur, &base),
            vec!["fleetscale_lossy_1000fpga_parallel_speedup".to_string()]
        );
        assert!(check_headlines(&cur, &base, 0.35).is_empty());
        // identical key sets -> nothing to warn about
        assert!(new_headline_keys(&base, &base).is_empty());
    }
}
