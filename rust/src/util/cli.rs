//! Tiny CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    spec: Vec<(String, String, String)>, // (name, default, help)
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates flag parsing
                    a.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    a.flags.insert(body.to_string(), v);
                } else {
                    a.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(arg);
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Declare a flag for the usage string (purely documentation).
    pub fn declare(&mut self, name: &str, default: &str, help: &str) -> &mut Self {
        self.spec.push((name.into(), default.into(), help.into()));
        self
    }

    pub fn usage(&self, prog: &str, about: &str) -> String {
        let mut s = format!("{prog} — {about}\n\nOptions:\n");
        for (n, d, h) in &self.spec {
            s.push_str(&format!("  --{n:<18} {h} [default: {d}]\n"));
        }
        s
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v} is not an integer")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v} is not a number")),
        }
    }

    pub fn bool_or(&self, name: &str, default: bool) -> Result<bool> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => match v.as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                _ => bail!("--{name}={v} is not a bool"),
            },
        }
    }

    /// Parse a comma-separated list of integers, e.g. `--lens 1,2,4,128`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().with_context(|| format!("bad list item {p:?} in --{name}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_kinds() {
        // NB: a bare `--flag` followed by a non-flag token consumes it as the
        // value, so boolean flags go last or use `--flag=true`.
        let a = parse(&["run", "--n", "5", "--mode=fast", "extra", "--verbose"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.u64_or("n", 0).unwrap(), 5);
        assert_eq!(a.str_or("mode", ""), "fast");
        assert!(a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.u64_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("x", 1.5).unwrap(), 1.5);
        assert!(!a.has("anything"));
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.u64_or("n", 0).is_err());
        let b = parse(&["--flag=maybe"]);
        assert!(b.bool_or("flag", false).is_err());
    }

    #[test]
    fn double_dash_stops_flags() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn lists() {
        let a = parse(&["--lens", "1,2, 4"]);
        assert_eq!(a.usize_list_or("lens", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(parse(&[]).usize_list_or("lens", &[9]).unwrap(), vec![9]);
    }
}
