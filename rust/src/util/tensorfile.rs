//! GTF1 tensor file format — the rust twin of `python/compile/tensorfile.py`.
//!
//! Little-endian: magic "GTF1", dtype u8 (0=i8, 1=i32, 2=i64, 3=f32),
//! ndim u8, 2 pad bytes, ndim*u32 dims, raw C-order data.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 4] = b"GTF1";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I8 = 0,
    I32 = 1,
    I64 = 2,
    F32 = 3,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 => 4,
            DType::I64 => 8,
            DType::F32 => 4,
        }
    }
    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::I8,
            1 => DType::I32,
            2 => DType::I64,
            3 => DType::F32,
            _ => bail!("unknown dtype code {c}"),
        })
    }
}

/// A dense tensor with one of the four supported element types.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    I8(TensorData<i8>),
    I32(TensorData<i32>),
    I64(TensorData<i64>),
    F32(TensorData<f32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorData<T> {
    pub dims: Vec<usize>,
    pub data: Vec<T>,
}

impl<T: Copy> TensorData<T> {
    pub fn new(dims: Vec<usize>, data: Vec<T>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorData { dims, data }
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    /// Row-major 2-D accessor.
    pub fn at2(&self, i: usize, j: usize) -> T {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }
}

impl Tensor {
    pub fn dtype(&self) -> DType {
        match self {
            Tensor::I8(_) => DType::I8,
            Tensor::I32(_) => DType::I32,
            Tensor::I64(_) => DType::I64,
            Tensor::F32(_) => DType::F32,
        }
    }
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::I8(t) => &t.dims,
            Tensor::I32(t) => &t.dims,
            Tensor::I64(t) => &t.dims,
            Tensor::F32(t) => &t.dims,
        }
    }
    pub fn as_i8(&self) -> Result<&TensorData<i8>> {
        match self {
            Tensor::I8(t) => Ok(t),
            _ => bail!("expected i8 tensor, got {:?}", self.dtype()),
        }
    }
    pub fn as_i32(&self) -> Result<&TensorData<i32>> {
        match self {
            Tensor::I32(t) => Ok(t),
            _ => bail!("expected i32 tensor, got {:?}", self.dtype()),
        }
    }
    pub fn as_i64(&self) -> Result<&TensorData<i64>> {
        match self {
            Tensor::I64(t) => Ok(t),
            _ => bail!("expected i64 tensor, got {:?}", self.dtype()),
        }
    }
    pub fn as_f32(&self) -> Result<&TensorData<f32>> {
        match self {
            Tensor::F32(t) => Ok(t),
            _ => bail!("expected f32 tensor, got {:?}", self.dtype()),
        }
    }
}

pub fn read_tensor(path: impl AsRef<Path>) -> Result<Tensor> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut head = [0u8; 8];
    f.read_exact(&mut head)?;
    if &head[0..4] != MAGIC {
        bail!("{path:?}: bad magic {:?}", &head[0..4]);
    }
    let dtype = DType::from_code(head[4])?;
    let ndim = head[5] as usize;
    let mut dim_bytes = vec![0u8; 4 * ndim];
    f.read_exact(&mut dim_bytes)?;
    let dims: Vec<usize> = dim_bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize)
        .collect();
    let n: usize = dims.iter().product();
    let mut raw = vec![0u8; n * dtype.size()];
    f.read_exact(&mut raw).with_context(|| format!("{path:?}: truncated data"))?;

    Ok(match dtype {
        DType::I8 => Tensor::I8(TensorData::new(
            dims,
            raw.iter().map(|&b| b as i8).collect(),
        )),
        DType::I32 => Tensor::I32(TensorData::new(
            dims,
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        )),
        DType::I64 => Tensor::I64(TensorData::new(
            dims,
            raw.chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        )),
        DType::F32 => Tensor::F32(TensorData::new(
            dims,
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        )),
    })
}

pub fn write_tensor(path: impl AsRef<Path>, t: &Tensor) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())?;
    let dims = t.dims();
    f.write_all(MAGIC)?;
    f.write_all(&[t.dtype() as u8, dims.len() as u8, 0, 0])?;
    for &d in dims {
        f.write_all(&(d as u32).to_le_bytes())?;
    }
    match t {
        Tensor::I8(td) => {
            let bytes: Vec<u8> = td.data.iter().map(|&v| v as u8).collect();
            f.write_all(&bytes)?;
        }
        Tensor::I32(td) => {
            for v in &td.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Tensor::I64(td) => {
            for v in &td.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Tensor::F32(td) => {
            for v in &td.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gtf_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_i8() {
        let t = Tensor::I8(TensorData::new(vec![2, 3], vec![1, -2, 3, -4, 5, -128]));
        let p = tmp("i8.bin");
        write_tensor(&p, &t).unwrap();
        assert_eq!(read_tensor(&p).unwrap(), t);
    }

    #[test]
    fn roundtrip_i32_i64_f32() {
        for t in [
            Tensor::I32(TensorData::new(vec![4], vec![i32::MIN, -1, 0, i32::MAX])),
            Tensor::I64(TensorData::new(vec![2, 2], vec![i64::MIN, -1, 0, i64::MAX])),
            Tensor::F32(TensorData::new(vec![3], vec![-1.5, 0.0, 3.25])),
        ] {
            let p = tmp("x.bin");
            write_tensor(&p, &t).unwrap();
            assert_eq!(read_tensor(&p).unwrap(), t);
        }
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::I64(TensorData::new(vec![], vec![42]));
        let p = tmp("scalar.bin");
        write_tensor(&p, &t).unwrap();
        let back = read_tensor(&p).unwrap();
        assert_eq!(back.dims(), &[] as &[usize]);
        assert_eq!(back.as_i64().unwrap().data, vec![42]);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"XXXX0000").unwrap();
        assert!(read_tensor(&p).is_err());
    }

    #[test]
    fn at2_indexing() {
        let t = TensorData::new(vec![2, 3], vec![0i32, 1, 2, 10, 11, 12]);
        assert_eq!(t.at2(1, 2), 12);
        assert_eq!(t.at2(0, 0), 0);
    }
}
