//! Minimal JSON parser / writer (serde is not in the vendored crate set).
//!
//! Supports the full JSON grammar; numbers are kept as f64 with an i64
//! fast-path accessor (quantparams and manifests only use integers and
//! plain decimals). Object key order is preserved (the Cluster Builder
//! emits deterministic descriptions that are diffed in tests).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// `get` chained through a dotted path: `j.path("encoder.rq_q.m")`.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(kv) => kv.iter().map(|(k, _)| k.as_str()).collect(),
            _ => vec![],
        }
    }

    // -- builders ---------------------------------------------------------
    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn from_map(m: &BTreeMap<String, Json>) -> Json {
        Json::Obj(m.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(kv)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hex = self
                            .b
                            .get(self.pos..self.pos + 4)
                            .ok_or_else(|| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                            16,
                        )
                        .map_err(|_| self.err("bad \\u"))?;
                        self.pos += 4;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self, f, None, 0)
    }
}

impl Json {
    /// Pretty-print with 1-space indent (matches python `json.dumps(indent=1)`).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        use fmt::Write;
        struct W<'a>(&'a mut String);
        impl fmt::Write for W<'_> {
            fn write_str(&mut self, x: &str) -> fmt::Result {
                self.0.push_str(x);
                Ok(())
            }
        }
        let mut w = W(&mut s);
        write!(w, "{}", PrettyJson(self)).unwrap();
        s
    }
}

struct PrettyJson<'a>(&'a Json);
impl fmt::Display for PrettyJson<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self.0, f, Some(1), 0)
    }
}

fn write_json(
    v: &Json,
    f: &mut fmt::Formatter<'_>,
    indent: Option<usize>,
    depth: usize,
) -> fmt::Result {
    let (nl, pad, pad_in) = match indent {
        Some(n) => (
            "\n",
            " ".repeat(n * depth),
            " ".repeat(n * (depth + 1)),
        ),
        None => ("", String::new(), String::new()),
    };
    match v {
        Json::Null => write!(f, "null"),
        Json::Bool(b) => write!(f, "{b}"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Json::Str(s) => write_escaped(s, f),
        Json::Arr(a) => {
            if a.is_empty() {
                return write!(f, "[]");
            }
            write!(f, "[{nl}")?;
            for (i, x) in a.iter().enumerate() {
                write!(f, "{pad_in}")?;
                write_json(x, f, indent, depth + 1)?;
                if i + 1 < a.len() {
                    write!(f, ",")?;
                }
                write!(f, "{nl}")?;
            }
            write!(f, "{pad}]")
        }
        Json::Obj(kv) => {
            if kv.is_empty() {
                return write!(f, "{{}}");
            }
            write!(f, "{{{nl}")?;
            for (i, (k, x)) in kv.iter().enumerate() {
                write!(f, "{pad_in}")?;
                write_escaped(k, f)?;
                write!(f, ":")?;
                if indent.is_some() {
                    write!(f, " ")?;
                }
                write_json(x, f, indent, depth + 1)?;
                if i + 1 < kv.len() {
                    write!(f, ",")?;
                }
                write!(f, "{nl}")?;
            }
            write!(f, "{pad}}}")
        }
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("-3.5").unwrap().as_f64(), Some(-3.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap().as_str(), Some("hi\n"));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": -1.5e-2}}"#).unwrap();
        assert_eq!(j.path("d.e").unwrap().as_f64(), Some(-0.015));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.path("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("07a").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"m": 16384, "n": 4, "s": "x\"y", "a": [true, false, null], "f": 0.5}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn parses_python_manifest_style() {
        // exactly what python json.dumps(indent=1) produces
        let src = "{\n \"seed\": 20240601,\n \"weights\": {\n  \"wq\": {\n   \"file\": \"weights/wq.bin\"\n  }\n }\n}";
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path("weights.wq.file").unwrap().as_str(), Some("weights/wq.bin"));
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"\\u00e9\\u0041 caf\u{e9}\"").unwrap();
        assert_eq!(j.as_str(), Some("\u{e9}A caf\u{e9}"));
    }
}
