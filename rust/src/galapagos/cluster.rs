//! Cluster and platform specifications: the validated form of the user's
//! kernel graph, from which routing tables and the simulator are built.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::router::{RoutingTables, MAX_CLUSTERS, MAX_KERNELS_PER_CLUSTER};
use crate::sim::engine::KernelBehavior;
use crate::sim::fabric::{FpgaId, SwitchId};
use crate::sim::fifo::Fifo;
use crate::sim::packet::GlobalKernelId;
use crate::sim::Sim;

/// §6.1: kernel ids are one of three types forming a contiguous id space
/// (gateway is id 0 by the §4 convention).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelType {
    /// kernel 0: cluster entry point; hosts virtual GMI kernels.
    Gateway,
    /// a computation kernel (Layer Builder output).
    Compute,
    /// a physically-placed GMI kernel (GMI Builder output).
    Gmi,
    /// a GMI kernel integrated into the gateway — reserves an id but is
    /// not physically placed in the application region (§5.3).
    Virtual,
}

/// One kernel declaration in a cluster.
#[derive(Debug, Clone)]
pub struct KernelDecl {
    pub id: u8,
    pub name: String,
    pub ktype: KernelType,
    pub fpga: FpgaId,
    /// outgoing edges of the connection graph (graph input to Galapagos).
    pub dests: Vec<GlobalKernelId>,
    /// input FIFO capacity in bytes (sized by the Cluster Builder).
    pub fifo_bytes: usize,
}

/// A Galapagos cluster: up to 256 kernels with a contiguous id space.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub id: u8,
    pub kernels: Vec<KernelDecl>,
}

impl ClusterSpec {
    pub fn kernel(&self, id: u8) -> Option<&KernelDecl> {
        self.kernels.iter().find(|k| k.id == id)
    }

    /// Capacity of the §6 **cluster input buffer**: the gateway's input
    /// FIFO, where packets addressed to this cluster wait while the
    /// cluster is being re-configured after an FPGA failure. The paper's
    /// sizing rule ("one input buffer per cluster", large enough for a
    /// full matrix) is what bounds how long an outage the cluster can
    /// absorb without loss at a given inbound rate.
    pub fn input_buffer_bytes(&self) -> usize {
        self.kernel(0).map_or(0, |g| g.fifo_bytes)
    }

    /// Distinct FPGAs hosting this cluster's physical kernels, ascending
    /// (virtual kernels live inside the gateway and are skipped).
    pub fn fpgas(&self) -> Vec<FpgaId> {
        let mut v: Vec<FpgaId> = self
            .kernels
            .iter()
            .filter(|k| k.ktype != KernelType::Virtual)
            .map(|k| k.fpga)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn validate(&self) -> Result<()> {
        if self.kernels.len() > MAX_KERNELS_PER_CLUSTER {
            bail!(
                "cluster {}: {} kernels exceeds the 256-kernel Galapagos limit",
                self.id,
                self.kernels.len()
            );
        }
        // contiguous id space 0..N-1 (§6.1)
        let mut ids: Vec<u8> = self.kernels.iter().map(|k| k.id).collect();
        ids.sort_unstable();
        for (i, id) in ids.iter().enumerate() {
            if *id as usize != i {
                bail!(
                    "cluster {}: kernel ids are not contiguous 0..N-1 (saw {id} at {i})",
                    self.id
                );
            }
        }
        // gateway convention
        if let Some(k0) = self.kernel(0) {
            if k0.ktype != KernelType::Gateway {
                bail!("cluster {}: kernel 0 must be the gateway", self.id);
            }
        }
        for k in &self.kernels {
            if k.ktype == KernelType::Gateway && k.id != 0 {
                bail!("cluster {}: gateway must be kernel 0, found at {}", self.id, k.id);
            }
        }
        Ok(())
    }
}

/// The whole deployment: clusters of clusters + the switch topology.
#[derive(Debug, Clone, Default)]
pub struct PlatformSpec {
    pub clusters: Vec<ClusterSpec>,
    pub switch_of: HashMap<FpgaId, SwitchId>,
}

impl PlatformSpec {
    pub fn validate(&self) -> Result<()> {
        if self.clusters.len() > MAX_CLUSTERS {
            bail!("{} clusters exceeds the 256-cluster limit", self.clusters.len());
        }
        let mut seen = std::collections::HashSet::new();
        for c in &self.clusters {
            c.validate()?;
            if !seen.insert(c.id) {
                bail!("duplicate cluster id {}", c.id);
            }
        }
        // an FPGA hosts kernels of exactly one cluster (paper's deployment
        // model: clusters are the unit of reconfiguration, §6)
        let mut fpga_cluster: HashMap<FpgaId, u8> = HashMap::new();
        for c in &self.clusters {
            for k in &c.kernels {
                if k.ktype == KernelType::Virtual {
                    continue;
                }
                if let Some(prev) = fpga_cluster.insert(k.fpga, c.id) {
                    if prev != c.id {
                        bail!(
                            "FPGA {:?} hosts kernels of clusters {prev} and {} — clusters must \
                             not share FPGAs",
                            k.fpga,
                            c.id
                        );
                    }
                }
                if !self.switch_of.contains_key(&k.fpga) {
                    bail!("FPGA {:?} is not attached to any switch", k.fpga);
                }
            }
        }
        self.validate_edges()?;
        Ok(())
    }

    /// Every connection-graph edge must be routable: intra-cluster edges
    /// resolve in table 1; inter-cluster edges require the destination
    /// cluster to exist and have a gateway.
    fn validate_edges(&self) -> Result<()> {
        let by_id: HashMap<u8, &ClusterSpec> = self.clusters.iter().map(|c| (c.id, c)).collect();
        for c in &self.clusters {
            for k in &c.kernels {
                for d in &k.dests {
                    let dc = by_id
                        .get(&d.cluster)
                        .with_context(|| format!("edge {}->{} targets unknown cluster", k.id, d))?;
                    if dc.kernel(d.kernel).is_none() {
                        bail!("edge c{}k{} -> {} targets unknown kernel", c.id, k.id, d);
                    }
                    let gw = dc.kernel(0).map(|g| g.ktype);
                    if d.cluster != c.id && gw != Some(KernelType::Gateway) {
                        bail!(
                            "edge c{}k{} -> {} crosses clusters but cluster {} has no gateway",
                            c.id,
                            k.id,
                            d,
                            d.cluster
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Construct each FPGA's routing tables (what the Network layer would
    /// burn into BRAM). Gateways of *all* clusters are installed in table 2
    /// of every FPGA, mirroring the 2N-1 scheme.
    pub fn routing_tables(&self) -> Result<HashMap<FpgaId, RoutingTables>> {
        self.validate()?;
        let mut out: HashMap<FpgaId, RoutingTables> = HashMap::new();
        for c in &self.clusters {
            // collect this cluster's kernel placements
            for k in &c.kernels {
                if k.ktype == KernelType::Virtual {
                    continue;
                }
                let rt = out.entry(k.fpga).or_insert_with(|| RoutingTables::new(c.id));
                rt.cluster = c.id;
            }
        }
        for c in &self.clusters {
            let gateway_fpga = c.kernel(0).map(|g| g.fpga);
            for (fpga, rt) in out.iter_mut() {
                if rt.cluster == c.id {
                    // table 1: all kernels of own cluster
                    for k in &c.kernels {
                        if k.ktype != KernelType::Virtual {
                            rt.set_kernel(k.id, k.fpga);
                        } else {
                            // virtual kernels live inside the gateway
                            if let Some(gf) = gateway_fpga {
                                rt.set_kernel(k.id, gf);
                            }
                        }
                    }
                } else if let Some(gf) = gateway_fpga {
                    // table 2: gateway of every other cluster
                    let _ = fpga;
                    rt.set_gateway(c.id, gf);
                }
            }
        }
        Ok(out)
    }

    /// Instantiate the platform into a simulator. `factory` supplies the
    /// behavior for each non-virtual kernel.
    pub fn build_sim(
        &self,
        mut factory: impl FnMut(&ClusterSpec, &KernelDecl) -> Box<dyn KernelBehavior>,
    ) -> Result<Sim> {
        self.validate()?;
        let mut sim = Sim::new();
        for (&f, &s) in &self.switch_of {
            sim.fabric.attach(f, s);
        }
        for c in &self.clusters {
            for k in &c.kernels {
                if k.ktype == KernelType::Virtual {
                    continue;
                }
                let id = GlobalKernelId::new(c.id, k.id);
                let behavior = factory(c, k);
                sim.add_kernel(id, k.fpga, Fifo::new(k.fifo_bytes), behavior)?;
            }
        }
        Ok(sim)
    }

    pub fn total_kernels(&self) -> usize {
        self.clusters.iter().map(|c| c.kernels.len()).sum()
    }

    /// The cluster whose kernels an FPGA hosts (None for an FPGA hosting
    /// nothing). Well-defined because validation enforces the paper's
    /// deployment rule that clusters — the unit of reconfiguration, §6 —
    /// never share FPGAs; this is what maps a failed FPGA to the cluster
    /// that must be re-configured.
    pub fn cluster_of(&self, fpga: FpgaId) -> Option<u8> {
        self.clusters.iter().find_map(|c| {
            c.kernels
                .iter()
                .any(|k| k.ktype != KernelType::Virtual && k.fpga == fpga)
                .then_some(c.id)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decl(id: u8, ktype: KernelType, fpga: usize) -> KernelDecl {
        KernelDecl {
            id,
            name: format!("k{id}"),
            ktype,
            fpga: FpgaId(fpga),
            dests: vec![],
            fifo_bytes: 1024,
        }
    }

    fn one_cluster() -> PlatformSpec {
        let c = ClusterSpec {
            id: 0,
            kernels: vec![
                decl(0, KernelType::Gateway, 0),
                decl(1, KernelType::Compute, 0),
                decl(2, KernelType::Gmi, 1),
            ],
        };
        let mut p = PlatformSpec { clusters: vec![c], switch_of: HashMap::new() };
        p.switch_of.insert(FpgaId(0), SwitchId(0));
        p.switch_of.insert(FpgaId(1), SwitchId(0));
        p
    }

    #[test]
    fn valid_platform_passes() {
        one_cluster().validate().unwrap();
    }

    #[test]
    fn cluster_fpgas_are_distinct_and_sorted() {
        let p = one_cluster();
        assert_eq!(p.clusters[0].fpgas(), vec![FpgaId(0), FpgaId(1)]);
    }

    #[test]
    fn cluster_of_fpga_and_input_buffer() {
        let p = one_cluster();
        assert_eq!(p.cluster_of(FpgaId(0)), Some(0));
        assert_eq!(p.cluster_of(FpgaId(1)), Some(0));
        assert_eq!(p.cluster_of(FpgaId(9)), None);
        assert_eq!(p.clusters[0].input_buffer_bytes(), 1024);
    }

    #[test]
    fn non_contiguous_ids_rejected() {
        let mut p = one_cluster();
        p.clusters[0].kernels[2].id = 7;
        assert!(p.validate().is_err());
    }

    #[test]
    fn gateway_not_zero_rejected() {
        let mut p = one_cluster();
        p.clusters[0].kernels[0].ktype = KernelType::Compute;
        p.clusters[0].kernels[1].ktype = KernelType::Gateway;
        assert!(p.validate().is_err());
    }

    #[test]
    fn fpga_shared_across_clusters_rejected() {
        let mut p = one_cluster();
        let c1 = ClusterSpec {
            id: 1,
            kernels: vec![decl(0, KernelType::Gateway, 0)], // reuses FPGA 0
        };
        p.clusters.push(c1);
        assert!(p.validate().is_err());
    }

    #[test]
    fn edge_to_unknown_kernel_rejected() {
        let mut p = one_cluster();
        p.clusters[0].kernels[1].dests.push(GlobalKernelId::new(0, 99));
        assert!(p.validate().is_err());
    }

    #[test]
    fn routing_tables_have_own_kernels_and_other_gateways() {
        let mut p = one_cluster();
        let c1 = ClusterSpec {
            id: 1,
            kernels: vec![decl(0, KernelType::Gateway, 2), decl(1, KernelType::Compute, 2)],
        };
        p.clusters.push(c1);
        p.switch_of.insert(FpgaId(2), SwitchId(0));
        let tables = p.routing_tables().unwrap();
        let rt0 = &tables[&FpgaId(0)];
        assert_eq!(rt0.cluster, 0);
        // 3 own kernels + 1 foreign gateway
        assert_eq!(rt0.entries(), 4);
        let rt2 = &tables[&FpgaId(2)];
        assert_eq!(rt2.cluster, 1);
        assert_eq!(rt2.entries(), 3); // 2 own + 1 foreign gateway
    }

    #[test]
    fn virtual_kernels_not_instantiated() {
        let mut p = one_cluster();
        p.clusters[0].kernels.push(decl(3, KernelType::Virtual, 0));
        struct Nop;
        impl KernelBehavior for Nop {
            fn on_packet(&mut self, _: crate::sim::Packet, _: &mut crate::sim::KernelIo) {}
            fn on_wake(&mut self, _: u64, _: &mut crate::sim::KernelIo) {}
        }
        let sim = p.build_sim(|_, _| Box::new(Nop)).unwrap();
        assert_eq!(sim.kernel_count(), 3); // virtual kernel excluded
        assert_eq!(p.total_kernels(), 4); // but reserves an id
    }

    #[test]
    fn cluster_size_limit_enforced() {
        let mut kernels = vec![decl(0, KernelType::Gateway, 0)];
        for i in 1..=256 {
            // 257 total
            let mut d = decl((i % 256) as u8, KernelType::Compute, 0);
            d.id = (i % 256) as u8;
            kernels.push(d);
        }
        let c = ClusterSpec { id: 0, kernels };
        assert!(c.validate().is_err());
    }
}
