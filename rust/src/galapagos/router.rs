//! Two-table router model (§4, Fig. 4).
//!
//! The original Galapagos router holds one 256-entry table mapping kernel
//! ids to FPGA addresses. The enhanced router adds TUSER bit16: 0 =>
//! consult table 1 (intra-cluster kernel -> FPGA IP), 1 => consult table 2
//! (cluster -> gateway FPGA IP). Restricting inter-cluster traffic to
//! gateways shrinks state from N^2 to 2N-1 addresses per FPGA.

use anyhow::{bail, Result};

use crate::sim::fabric::FpgaId;
use crate::sim::packet::Packet;
#[cfg(test)]
use crate::sim::packet::GlobalKernelId;

pub const MAX_KERNELS_PER_CLUSTER: usize = 256;
pub const MAX_CLUSTERS: usize = 256;

/// TUSER sideband width: kernel id bits [7:0], dest cluster bits [15:8],
/// inter-cluster flag at bit 16 (§4 "one additional bit in the TUSER
/// channel (bit16)").
pub const TUSER_INTER_CLUSTER_BIT: u32 = 16;

/// Encode the routing sideband for a packet.
pub fn encode_tuser(pkt: &Packet) -> u32 {
    let mut t = pkt.dst.kernel as u32;
    t |= (pkt.dst.cluster as u32) << 8;
    if pkt.inter_cluster {
        t |= 1 << TUSER_INTER_CLUSTER_BIT;
    }
    t
}

/// Decode (kernel, cluster, inter_cluster) from TUSER.
pub fn decode_tuser(t: u32) -> (u8, u8, bool) {
    ((t & 0xFF) as u8, ((t >> 8) & 0xFF) as u8, t & (1 << TUSER_INTER_CLUSTER_BIT) != 0)
}

/// The BRAM-resident routing state of one FPGA.
#[derive(Debug, Clone)]
pub struct RoutingTables {
    /// our cluster id
    pub cluster: u8,
    /// table 1: kernel id within this cluster -> FPGA
    intra: Vec<Option<FpgaId>>,
    /// table 2: other cluster id -> gateway FPGA
    inter: Vec<Option<FpgaId>>,
}

impl RoutingTables {
    pub fn new(cluster: u8) -> Self {
        RoutingTables {
            cluster,
            intra: vec![None; MAX_KERNELS_PER_CLUSTER],
            inter: vec![None; MAX_CLUSTERS],
        }
    }

    pub fn set_kernel(&mut self, kernel: u8, fpga: FpgaId) {
        self.intra[kernel as usize] = Some(fpga);
    }

    pub fn set_gateway(&mut self, cluster: u8, fpga: FpgaId) {
        self.inter[cluster as usize] = Some(fpga);
    }

    /// Route a packet: TUSER bit16 selects the table (Fig. 4).
    pub fn route(&self, pkt: &Packet) -> Result<FpgaId> {
        let (kernel, cluster, inter) = decode_tuser(encode_tuser(pkt));
        if inter {
            match self.inter[cluster as usize] {
                Some(f) => Ok(f),
                None => bail!("cluster {cluster} not in routing table 2 of cluster {}", self.cluster),
            }
        } else {
            if cluster != self.cluster {
                bail!(
                    "intra-cluster packet for cluster {cluster} routed inside cluster {}",
                    self.cluster
                );
            }
            match self.intra[kernel as usize] {
                Some(f) => Ok(f),
                None => bail!("kernel {kernel} not in routing table 1 of cluster {}", self.cluster),
            }
        }
    }

    /// Entries actually populated (the 2N-1 quantity of §4).
    pub fn entries(&self) -> usize {
        self.intra.iter().flatten().count() + self.inter.iter().flatten().count()
    }

    /// BRAM18 blocks needed for both tables (4-byte IPv4 per entry).
    pub fn bram18(&self) -> usize {
        let bytes = 4 * (MAX_KERNELS_PER_CLUSTER + MAX_CLUSTERS);
        bytes.div_ceil(crate::sim::fifo::BRAM18_BYTES)
    }
}

/// §4's scaling argument: addresses stored per FPGA if any kernel may talk
/// to any kernel in any cluster directly (full mesh) ...
pub fn full_mesh_entries(n_clusters: usize, kernels_per_cluster: usize) -> usize {
    n_clusters * kernels_per_cluster
}

/// ... versus gateway-restricted routing (intra table + one gateway per
/// other cluster).
pub fn hierarchical_entries(n_clusters: usize, kernels_per_cluster: usize) -> usize {
    kernels_per_cluster + (n_clusters - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::packet::{MsgMeta, Payload};

    fn pkt(src: GlobalKernelId, dst: GlobalKernelId) -> Packet {
        let mut p = Packet::new(src, dst, MsgMeta::default(), Payload::Timing(8));
        if p.inter_cluster {
            p.gmi_dst = Some(dst.kernel);
            p.dst = GlobalKernelId::gateway_of(dst.cluster);
        }
        p
    }

    #[test]
    fn tuser_roundtrip() {
        let p = pkt(GlobalKernelId::new(0, 1), GlobalKernelId::new(3, 7));
        let (k, c, inter) = decode_tuser(encode_tuser(&p));
        assert_eq!((k, c, inter), (0, 3, true)); // rewritten to gateway 0 of cluster 3
        let q = pkt(GlobalKernelId::new(0, 1), GlobalKernelId::new(0, 9));
        assert_eq!(decode_tuser(encode_tuser(&q)), (9, 0, false));
    }

    #[test]
    fn routes_by_table() {
        let mut rt = RoutingTables::new(0);
        rt.set_kernel(9, FpgaId(2));
        rt.set_gateway(3, FpgaId(5));
        let local = pkt(GlobalKernelId::new(0, 1), GlobalKernelId::new(0, 9));
        assert_eq!(rt.route(&local).unwrap(), FpgaId(2));
        let remote = pkt(GlobalKernelId::new(0, 1), GlobalKernelId::new(3, 7));
        assert_eq!(rt.route(&remote).unwrap(), FpgaId(5));
    }

    #[test]
    fn missing_entries_error() {
        let rt = RoutingTables::new(0);
        assert!(rt.route(&pkt(GlobalKernelId::new(0, 1), GlobalKernelId::new(0, 9))).is_err());
        assert!(rt.route(&pkt(GlobalKernelId::new(0, 1), GlobalKernelId::new(2, 2))).is_err());
    }

    #[test]
    fn paper_scaling_claim() {
        // §4: N clusters of N kernels => N^2 addresses full mesh, 2N-1 with
        // gateways; 256x256 = 65536 kernels total.
        assert_eq!(full_mesh_entries(256, 256), 65_536);
        assert_eq!(hierarchical_entries(256, 256), 511); // 2N - 1
        assert_eq!(
            MAX_CLUSTERS * MAX_KERNELS_PER_CLUSTER,
            65_536,
            "enhanced Galapagos accommodates 65536 kernels"
        );
    }

    #[test]
    fn table_fits_in_one_bram_pair() {
        let rt = RoutingTables::new(0);
        assert!(rt.bram18() <= 2);
    }
}
