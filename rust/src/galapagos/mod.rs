//! The enhanced Galapagos platform (§2.1 base stack + §4 scaling).
//!
//! Galapagos abstracts a group of network-attached FPGAs as "one large
//! FPGA fabric" hosting streaming kernels. The enhancement this paper
//! contributes is clusters-of-clusters: hierarchical 256x256 addressing
//! with gateway kernels and a second routing table.

pub mod cluster;
pub mod router;

pub use cluster::{ClusterSpec, KernelDecl, KernelType, PlatformSpec};
pub use router::{RoutingTables, MAX_CLUSTERS, MAX_KERNELS_PER_CLUSTER};
