//! §9: estimating I-BERT on AMD Versal ACAP devices.
//!
//! The paper itself does no Versal implementation — §9 is an analytical
//! estimate validated with AMD engineers. We implement that estimator
//! with every assumption exposed as a parameter, plus the modified-
//! Galapagos mapping of Fig. 23 (kernel → AIE assignment with dmem and
//! PLIO budget checks).

pub mod aie;
pub mod estimate;
pub mod mapping;

pub use aie::AieArray;
pub use estimate::{estimate_encoder, estimate_full_model, VersalEstimate};
pub use mapping::{versal_encoder_mapping, VersalKernel};
