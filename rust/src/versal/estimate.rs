//! §9.3's latency estimate for I-BERT on Versal devices, with every
//! assumption a parameter.

use super::aie::AieArray;
use super::mapping::{validate_mapping, versal_encoder_mapping, VersalKernel};
use crate::eval::latency_model::LatencyComponents;

/// The §9.3 assumptions.
#[derive(Debug, Clone, Copy)]
pub struct VersalAssumptions {
    /// latency the nonlinear modules add per encoder (paper: 26.1 us)
    pub nonlinear_overhead_us: f64,
    /// X/T ratio carried over from the UltraScale+ measurement (0.53)
    pub x_over_t: f64,
    /// switch-to-switch latency (1.1 us)
    pub d_us: f64,
    pub encoders: usize,
}

impl Default for VersalAssumptions {
    fn default() -> Self {
        VersalAssumptions { nonlinear_overhead_us: 26.1, x_over_t: 0.53, d_us: 1.1, encoders: 12 }
    }
}

/// The estimate output.
#[derive(Debug, Clone)]
pub struct VersalEstimate {
    pub kernels: Vec<(String, f64)>,
    pub aies_used: usize,
    /// critical-path matmul latency of one encoder (us)
    pub matmul_us: f64,
    /// one-encoder latency including nonlinear overhead (us)
    pub encoder_us: f64,
    /// full-model latency (us)
    pub model_us: f64,
    pub devices: usize,
}

/// One encoder on one Versal device (Fig. 23): the critical path is
/// QKV (parallel, 49 us) -> attention (16+16 us, overlapped w/ proj) ->
/// FFN (49 us); the paper sums the two 49 us stages ("the overall latency
/// for one encoder is 98 + 26.1 us").
pub fn estimate_encoder(a: &AieArray, m: usize, hidden: usize, ffn: usize,
                        asm: &VersalAssumptions) -> anyhow::Result<VersalEstimate> {
    let ks = versal_encoder_mapping(m, hidden, ffn);
    validate_mapping(&ks, a)?;

    let lat = |name: &str| -> f64 {
        ks.iter()
            .find(|k| k.name.starts_with(name))
            .map(|k: &VersalKernel| match k.name.contains("(x12)") {
                // per-head kernels: one head per AIE, heads run in parallel
                true => {
                    let (mm, kk, nn) = k.matmul.unwrap();
                    a.matmul_latency_us(mm, kk, nn, 1)
                }
                false => k.latency_us(a),
            })
            .unwrap_or(0.0)
    };

    // paper's critical path: the QKV stage and the FFN stage at 49 us each
    let matmul_us = lat("k1") + lat("k8");
    let encoder_us = matmul_us + asm.nonlinear_overhead_us;

    let t_cycles = (encoder_us * 1e3).round() as u64; // placeholder domain: us*1000
    let x_cycles = (encoder_us * asm.x_over_t * 1e3).round() as u64;
    let c = LatencyComponents { x: x_cycles, t: t_cycles, i: 0 };
    // Eq. 1 in us directly (we keep the us domain; cycles field is x1000)
    let model_us = (c.t as f64 / 1e3)
        + (asm.encoders as f64 - 1.0) * (c.x as f64 / 1e3 + asm.d_us);

    Ok(VersalEstimate {
        kernels: ks.iter().map(|k| (k.name.to_string(), k.latency_us(a))).collect(),
        aies_used: ks.iter().map(|k| k.aies).sum(),
        matmul_us,
        encoder_us,
        model_us,
        devices: asm.encoders,
    })
}

/// Full-model estimate with the paper's defaults (→ ~860 us).
pub fn estimate_full_model() -> anyhow::Result<VersalEstimate> {
    estimate_encoder(&AieArray::vck190(), 128, 768, 3072, &VersalAssumptions::default())
}

/// §9.3's weight-reconfiguration argument: with two cards ping-ponging
/// (one computing while the other loads the next encoder's weights),
/// the whole model needs only `2` devices if reconfiguration fits in the
/// compute shadow. Returns (devices, reconfig_us, compute_us).
pub fn reconfig_device_estimate(a: &AieArray, encoder_weight_bytes: usize,
                                encoder_us: f64) -> (usize, f64, f64) {
    // weight load is DRAM-bandwidth bound
    let reconfig_us = encoder_weight_bytes as f64 / a.dram_bw as f64 * 1e6;
    let devices = if reconfig_us <= encoder_us { 2 } else {
        // need enough cards that the pipeline hides reconfiguration
        1 + (reconfig_us / encoder_us).ceil() as usize
    };
    (devices, reconfig_us, encoder_us)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_860us() {
        let e = estimate_full_model().unwrap();
        assert_eq!(e.aies_used, 312);
        assert!((e.matmul_us - 98.3).abs() < 0.5, "matmul {:.1}", e.matmul_us);
        assert!((e.encoder_us - 124.4).abs() < 0.5, "encoder {:.1}", e.encoder_us);
        // paper: 860 us overall
        assert!((e.model_us - 860.0).abs() < 10.0, "model {:.1}", e.model_us);
    }

    #[test]
    fn versal_is_comparable_to_a100() {
        // §9.3's headline: 860 us vs the A100's 770 us batch-1 => within ~12%
        let e = estimate_full_model().unwrap();
        let a100_us = crate::baselines::gpu::A100.batch1_latency_ms * 1e3;
        let ratio = e.model_us / a100_us;
        assert!(ratio < 1.2, "Versal/A100 = {ratio:.2} should be ~1.12");
        assert!(ratio > 0.9, "the estimate should not beat the A100 either");
    }

    #[test]
    fn reconfig_two_cards_suffice() {
        // one encoder's weights: ~7.1 MB int8 -> ~0.28 ms from DRAM; an
        // encoder computes in 124 us, so reconfiguration does NOT hide in
        // one encoder's shadow -> more than 2 cards by the strict model.
        // The paper's "two cards suffice" assumes overlapping across the
        // pipeline; we surface both numbers.
        let a = AieArray::vck190();
        let weights = 4 * 768 * 768 + 2 * 768 * 3072;
        let (devices, reconfig_us, compute_us) = reconfig_device_estimate(&a, weights, 124.1);
        assert!(reconfig_us > compute_us, "DRAM load slower than one encoder");
        assert!(devices >= 2 && devices <= 4, "devices={devices}");
    }
}
