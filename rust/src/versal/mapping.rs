//! Fig. 23: mapping one I-BERT encoder onto one VCK190 (modified
//! Galapagos: each kernel has a PL part and an AIE part; PLIOs are the
//! scarce interface resource, which is why attention heads fuse into one
//! kernel each for dot-product and softmax-MM).

use anyhow::{bail, Result};

use super::aie::AieArray;

/// One kernel of the Versal encoder mapping.
#[derive(Debug, Clone, PartialEq)]
pub struct VersalKernel {
    pub name: &'static str,
    /// matmul dims (m, k, n); None for PL-only kernels (LayerNorm)
    pub matmul: Option<(usize, usize, usize)>,
    pub aies: usize,
    /// PLIO connections this kernel needs (in + out)
    pub plios: usize,
}

impl VersalKernel {
    pub fn latency_us(&self, a: &AieArray) -> f64 {
        match self.matmul {
            Some((m, k, n)) => a.matmul_latency_us(m, k, n, self.aies.max(1)),
            None => 0.0, // PL-side pipeline, overlapped
        }
    }
}

/// The §9.3 mapping: kernels 1,2,3,6 = 128x768x768 on 24 AIEs each;
/// kernel 4 = 12 attention dot-products (+softmax on PL) on 12 AIEs;
/// kernel 5 = 12 softmax-MMs on 12 AIEs; kernels 8,9 = 128x768x3072 on
/// 96 AIEs each; kernels 7,10 = LayerNorm on the PL only.
pub fn versal_encoder_mapping(m: usize, hidden: usize, ffn: usize) -> Vec<VersalKernel> {
    let heads = 12;
    let d = hidden / heads;
    vec![
        VersalKernel { name: "k1-linear-q", matmul: Some((m, hidden, hidden)), aies: 24, plios: 2 },
        VersalKernel { name: "k2-linear-k", matmul: Some((m, hidden, hidden)), aies: 24, plios: 2 },
        VersalKernel { name: "k3-linear-v", matmul: Some((m, hidden, hidden)), aies: 24, plios: 2 },
        VersalKernel {
            name: "k4-attn-dot-product(x12)+softmax",
            matmul: Some((m, d, m)), // per head, one AIE each
            aies: heads,
            plios: 3,
        },
        VersalKernel {
            name: "k5-softmax-mm(x12)",
            matmul: Some((m, m, d)),
            aies: heads,
            plios: 3,
        },
        VersalKernel { name: "k6-linear-proj", matmul: Some((m, hidden, hidden)), aies: 24, plios: 2 },
        VersalKernel { name: "k7-layernorm1", matmul: None, aies: 0, plios: 2 },
        VersalKernel { name: "k8-ffn1", matmul: Some((m, hidden, ffn)), aies: 96, plios: 2 },
        VersalKernel { name: "k9-ffn2", matmul: Some((m, ffn, hidden)), aies: 96, plios: 2 },
        VersalKernel { name: "k10-layernorm2", matmul: None, aies: 0, plios: 2 },
    ]
}

/// Validate a mapping against the device: AIE count, PLIO budget, and
/// per-AIE weight residency.
pub fn validate_mapping(kernels: &[VersalKernel], a: &AieArray) -> Result<()> {
    let aies: usize = kernels.iter().map(|k| k.aies).sum();
    if aies > a.total_aies() {
        bail!("mapping needs {aies} AIEs > {} available", a.total_aies());
    }
    let plios: usize = kernels.iter().map(|k| k.plios).sum();
    if plios > a.plio_tiles {
        bail!("mapping needs {plios} PLIOs > {} available", a.plio_tiles);
    }
    for k in kernels {
        if let Some((_, kk, nn)) = k.matmul {
            if k.aies == 0 {
                bail!("{}: matmul kernel with no AIEs", k.name);
            }
            // per-head kernels replicate weights per AIE; weight slab must fit
            let slab = (kk * nn).div_ceil(k.aies);
            if slab > a.dmem_bytes {
                bail!("{}: weight slab {} B exceeds {} B dmem", k.name, slab, a.dmem_bytes);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mapping_uses_312_aies() {
        // §9.3: 24*4 + 12 + 12 + 96*2 = 312 AIEs for one encoder
        let ks = versal_encoder_mapping(128, 768, 3072);
        let total: usize = ks.iter().map(|k| k.aies).sum();
        assert_eq!(total, 312);
    }

    #[test]
    fn mapping_fits_vck190() {
        let a = AieArray::vck190();
        validate_mapping(&versal_encoder_mapping(128, 768, 3072), &a).unwrap();
    }

    #[test]
    fn plio_budget_is_tight_but_sufficient() {
        // §9.3: "there are only 39 PLIOs ... important to limit the number
        // of kernels"
        let ks = versal_encoder_mapping(128, 768, 3072);
        let plios: usize = ks.iter().map(|k| k.plios).sum();
        assert!(plios <= 39, "plios={plios}");
        assert!(plios >= 20, "the budget should be visibly consumed");
    }

    #[test]
    fn per_head_kernels_are_16us() {
        let a = AieArray::vck190();
        let ks = versal_encoder_mapping(128, 768, 3072);
        let k4 = ks.iter().find(|k| k.name.starts_with("k4")).unwrap();
        // one head on one AIE: 128*64*128 / 64 = 16,384 cycles
        let per_head = a.matmul_latency_us(128, 64, 128, 1);
        assert!((per_head - 16.384).abs() < 0.01);
        assert_eq!(k4.aies, 12);
    }

    #[test]
    fn oversubscription_rejected() {
        let a = AieArray::vck190();
        let mut ks = versal_encoder_mapping(128, 768, 3072);
        ks[0].aies = 400;
        assert!(validate_mapping(&ks, &a).is_err());
    }
}
