//! The AIE array model (§9.1): VCK190 / XCVC1902 parameters.

/// AIE array of one Versal device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AieArray {
    pub rows: usize,
    pub cols: usize,
    /// AIE clock (Hz)
    pub clock_hz: u64,
    /// per-AIE data memory (bytes)
    pub dmem_bytes: usize,
    /// per-AIE vector register file (bytes)
    pub regfile_bytes: usize,
    /// INT8 MACs per AIE per cycle: the paper's estimate fetches 512 bits
    /// = 64 int8 weights per cycle from data memory (§9.3)
    pub int8_macs_per_cycle: u64,
    /// PL<->AIE interface tiles (§9.1: 39 PLIOs on the VCK190)
    pub plio_tiles: usize,
    /// PL -> AIE bandwidth (bytes/s)
    pub pl_to_aie_bw: u64,
    /// AIE -> PL bandwidth (bytes/s)
    pub aie_to_pl_bw: u64,
    /// DRAM peak bandwidth (bytes/s)
    pub dram_bw: u64,
}

impl AieArray {
    /// XCVC1902 on the VCK190 evaluation board (§9.1 figures).
    pub fn vck190() -> Self {
        AieArray {
            rows: 8,
            cols: 50,
            clock_hz: 1_000_000_000,
            dmem_bytes: 32 * 1024,
            regfile_bytes: 2 * 1024,
            int8_macs_per_cycle: 64,
            plio_tiles: 39,
            pl_to_aie_bw: 1_200_000_000_000, // 1.2 TB/s
            aie_to_pl_bw: 900_000_000_000,   // 0.9 TB/s
            dram_bw: 25_600_000_000,         // 25.6 GB/s
        }
    }

    pub fn total_aies(&self) -> usize {
        self.rows * self.cols
    }

    /// Peak INT8 throughput of the array (ops/s, MAC = 2 ops).
    pub fn peak_int8_tops(&self) -> f64 {
        2.0 * self.total_aies() as f64 * self.int8_macs_per_cycle as f64 * self.clock_hz as f64
            / 1e12
    }

    /// AIEs needed to hold a K x N int8 weight matrix in data memory
    /// (weight-stationary, §9.3: "the weight matrix needs to be stored in
    /// the data memory").
    pub fn aies_for_weights(&self, k: usize, n: usize) -> usize {
        (k * n).div_ceil(self.dmem_bytes)
    }

    /// Latency (us) of an M x K x N int8 matmul spread over `aies` AIEs,
    /// each fetching 64 weights/cycle (the §9.3 estimation method).
    pub fn matmul_latency_us(&self, m: usize, k: usize, n: usize, aies: usize) -> f64 {
        let macs_total = (m * k * n) as u64;
        let macs_per_aie = macs_total.div_ceil(aies as u64);
        let cycles = macs_per_aie.div_ceil(self.int8_macs_per_cycle);
        cycles as f64 * 1e6 / self.clock_hz as f64
    }

    /// The §9.3 alternative partitioning (Fig. 24): a `rows x cols` grid
    /// of (K/rows) x (N/cols) partial weight matrices — e.g. 3x8 grid of
    /// 256x96 for the 768x768 linears. Input-row segments are packet-
    /// switched to the grid rows and broadcast along each row; partial
    /// sums reduce down the columns. Returns (latency_us, slab_bytes).
    pub fn grid_matmul(&self, m: usize, k: usize, n: usize, rows: usize, cols: usize) -> (f64, usize) {
        let slab = k.div_ceil(rows) * n.div_ceil(cols);
        let lat = self.matmul_latency_us(m, k, n, rows * cols);
        (lat, slab)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vck190_has_400_aies() {
        let a = AieArray::vck190();
        assert_eq!(a.total_aies(), 400);
    }

    #[test]
    fn weight_partitioning_matches_paper() {
        // §9.3: a 768x768 int8 matrix needs 576 KB => at least 18 AIEs;
        // the paper picks 24 (768x32 slabs).
        let a = AieArray::vck190();
        assert_eq!(a.aies_for_weights(768, 768), 18);
        // 768x32 slab = 24 KB fits one AIE's 32 KB dmem
        assert!(768 * 32 <= a.dmem_bytes);
    }

    #[test]
    fn qkv_latency_is_49us_on_24_aies() {
        // §9.3: 128x768x32 = 3,145,728 MACs per AIE / 64 = 49,152 cycles
        // = 49 us at 1 GHz.
        let a = AieArray::vck190();
        let us = a.matmul_latency_us(128, 768, 768, 24);
        assert!((us - 49.152).abs() < 0.01, "{us}");
    }

    #[test]
    fn attention_latency_is_16us_on_1_aie() {
        // §9.3: 128x64x128 = 1,048,576 MACs / 64 = 16,384 cycles = 16 us.
        let a = AieArray::vck190();
        let us = a.matmul_latency_us(128, 64, 128, 1);
        assert!((us - 16.384).abs() < 0.01, "{us}");
    }

    #[test]
    fn ffn_latency_matches_qkv_with_96_aies() {
        // §9.3: kernels 8/9 are 4x the work; 96 AIEs keep 49 us.
        let a = AieArray::vck190();
        let us = a.matmul_latency_us(128, 768, 3072, 96);
        assert!((us - 49.152).abs() < 0.01, "{us}");
    }

    #[test]
    fn grid_partitioning_alternative_matches_slab_scheme() {
        // §9.3: "we can partition the matrix into a grid of 3 x 8 partial
        // matrices with a dimension of 256 x 96" — same 24 AIEs, same
        // latency, and the 24 KB slab still fits the 32 KB data memory.
        let a = AieArray::vck190();
        let (lat_grid, slab_grid) = a.grid_matmul(128, 768, 768, 3, 8);
        let lat_cols = a.matmul_latency_us(128, 768, 768, 24);
        assert!((lat_grid - lat_cols).abs() < 1e-9);
        assert_eq!(slab_grid, 256 * 96);
        assert!(slab_grid <= a.dmem_bytes);
    }

    #[test]
    fn peak_tops_close_to_datasheet() {
        // §9.3 cites 133 INT8 TOPs for the VCK190; our first-principles
        // peak (2*400*64*1GHz = 51.2 TOPS via plain MAC counting) shows
        // the datasheet number assumes the AIE-ML style packing; keep the
        // model's number and compare against the paper's cited figure in
        // the estimate module instead.
        let a = AieArray::vck190();
        assert!(a.peak_int8_tops() > 50.0);
    }
}
