//! PE/Tile cycle models (§7.1) — the timing side of the HLS kernels,
//! calibrated against the paper's own measurements (DESIGN.md):
//!   * one 768-wide INT8 MAC array produces a 768x768 linear output row
//!     every 768 cycles => the measured packet interval I = 767 +- 1;
//!   * layer-0 compute = M*768 cycles => T(128) ~ 2x layer 0 ~ 210k cycles.

use crate::fpga::resources::{Device, ResourceUsage};
use crate::sim::fifo::BRAM18_BYTES;

/// PE configuration of the six-FPGA encoder build (the knobs the Layer
/// Description File exposes, §6.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeConfig {
    /// MAC lanes of each QKV/projection linear kernel (768x768).
    pub linear_macs: u64,
    /// MAC lanes of the FFN linear kernels (768x3072 / 3072x768).
    pub ffn_macs: u64,
    /// PEs per attention dot-product head kernel (§7.1.2 NUM_PE).
    pub attn_pes: u64,
    /// PEs per softmax matrix-multiply head kernel (§7.1.3 NUM_PE).
    pub smm_pes: u64,
    /// SIMD lanes of the softmax unit.
    pub sm_simd: u64,
    /// SIMD lanes of the LayerNorm unit.
    pub ln_simd: u64,
    /// pipeline fill of a streaming kernel (HLS dataflow region depth).
    pub pipe_fill: u64,
}

impl Default for PeConfig {
    fn default() -> Self {
        PeConfig {
            linear_macs: 768,
            ffn_macs: 3072,
            attn_pes: 32,
            // 11 PEs make the softmax-MM row time ~745 cycles at m=128 —
            // the paper's Fig. 16/20: layer 3 paces like layers 0/4/5,
            // only layers 1-2 are faster.
            smm_pes: 11,
            sm_simd: 8,
            ln_simd: 8,
            pipe_fill: 24,
        }
    }
}

impl PeConfig {
    /// Cycles to produce one output row of a K x N linear.
    pub fn linear_row_cycles(&self, k: u64, n: u64, macs: u64) -> u64 {
        (k * n).div_ceil(macs)
    }

    pub fn qkv_row_cycles(&self, hidden: u64) -> u64 {
        self.linear_row_cycles(hidden, hidden, self.linear_macs)
    }

    pub fn ffn1_row_cycles(&self, hidden: u64, ffn: u64) -> u64 {
        self.linear_row_cycles(hidden, ffn, self.ffn_macs)
    }

    pub fn ffn2_row_cycles(&self, hidden: u64, ffn: u64) -> u64 {
        self.linear_row_cycles(ffn, hidden, self.ffn_macs)
    }

    /// Attention dot-product: one score row against an M-row K matrix with
    /// the paper's minimum padding NUM_PE * ceil(M / NUM_PE) (§7.1.2),
    /// d MACs per score, NUM_PE scores in parallel.
    pub fn attn_row_cycles(&self, m: u64, d: u64) -> u64 {
        let padded = self.attn_pes * m.div_ceil(self.attn_pes);
        d * padded / self.attn_pes
    }

    /// Fused i-Softmax over an M-wide score row.
    pub fn softmax_row_cycles(&self, m: u64) -> u64 {
        m.div_ceil(self.sm_simd) + 20
    }

    /// Softmax matrix-multiply: prob row [M] x V [M, d]; each PE iterates
    /// the actual M (the no-padding benefit of §7.1.3).
    pub fn smm_row_cycles(&self, m: u64, d: u64) -> u64 {
        (m * d).div_ceil(self.smm_pes)
    }

    /// i-LayerNorm row: two passes over H plus the integer sqrt.
    pub fn ln_row_cycles(&self, hidden: u64) -> u64 {
        2 * hidden.div_ceil(self.ln_simd) + 45
    }

    // ---- continuous batching (weight-stationary token passes) ----
    //
    // A single-token pass through a K x N linear streams the full weight
    // matrix past the MAC array — the same K*N/macs cycles as a prefill
    // row, but now the stream serves only one activation row. When the
    // batch assembler releases several token rows back to back, the
    // weight stream stays live and each additional row rides it at the
    // dual-int8 DSP packing rate (two activation rows share one streamed
    // weight beat on the XCZU19EG), halving the per-row marginal cost.
    // A batch of B token rows therefore costs
    //   weight_pass + B * marginal  =  K*N/macs + B * K*N/(2*macs)
    // at the kernel, versus B * K*N/macs unbatched. Prefill rows
    // (rows > 1 per pass) keep the calibrated full-row cost — the
    // paper's measured I = 767 +- 1 anchor is a prefill measurement.

    /// Fixed per-pass cost of streaming a K x N weight matrix once
    /// (charged when a token row starts a fresh weight stream).
    pub fn linear_weight_pass_cycles(&self, k: u64, n: u64, macs: u64) -> u64 {
        self.linear_row_cycles(k, n, macs)
    }

    /// Marginal per-row cost of a token row riding an already-live
    /// weight stream: dual-int8 packing shares each weight beat across
    /// two activation rows.
    pub fn batched_linear_row_cycles(&self, k: u64, n: u64, macs: u64) -> u64 {
        (k * n).div_ceil(macs * 2)
    }

    // ---- decode (variable trip count) ----
    //
    // Under the causal mask a query at global position p attends
    // `attended = p + 1` cached positions, so an attention/SMM kernel's
    // per-row trip count varies row to row within a prefill pass and
    // grows token by token across decode steps. The cycle models are the
    // same hardware loops as above — only the loop bound changes from
    // the fixed pass length `m` to the row's attended length.

    /// Masked-attention score row + fused softmax over `attended` cached
    /// K positions (decode trip count).
    pub fn attn_decode_row_cycles(&self, attended: u64, d: u64) -> u64 {
        self.attn_row_cycles(attended, d) + self.softmax_row_cycles(attended)
    }

    /// Softmax matrix-multiply over `attended` cached V positions.
    pub fn smm_decode_row_cycles(&self, attended: u64, d: u64) -> u64 {
        self.smm_row_cycles(attended, d)
    }

    // ---- resource estimation (Fig. 15's model) ----

    /// DSP cost of a MAC array on a device.
    pub fn macs_dsp(&self, macs: u64, dev: Device) -> u64 {
        macs.div_ceil(dev.int8_macs_per_dsp())
    }

    /// Resource estimate of a linear kernel holding a K x N int8 weight
    /// matrix in BRAM plus its MAC array and control.
    pub fn linear_usage(&self, k: u64, n: u64, macs: u64, dev: Device) -> ResourceUsage {
        let weight_bram = ((k * n) as usize).div_ceil(BRAM18_BYTES) as u64;
        ResourceUsage {
            lut: 6_000 + macs * 24,
            ff: 9_000 + macs * 40,
            bram18: weight_bram,
            dsp: self.macs_dsp(macs, dev),
        }
    }

    /// Resource estimate of an attention / smm head kernel (buffers one
    /// [M, d] int8 matrix on-chip).
    pub fn head_usage(&self, max_m: u64, d: u64, pes: u64, dev: Device) -> ResourceUsage {
        let buf_bram = ((max_m * d) as usize).div_ceil(BRAM18_BYTES) as u64;
        ResourceUsage {
            lut: 3_000 + pes * 60,
            ff: 4_500 + pes * 90,
            bram18: buf_bram.max(1),
            dsp: self.macs_dsp(pes, dev),
        }
    }

    /// LayerNorm / softmax style scalar-pipeline kernel.
    pub fn pipe_usage(&self, simd: u64) -> ResourceUsage {
        ResourceUsage { lut: 8_000 + simd * 400, ff: 12_000 + simd * 600, bram18: 4, dsp: 8 * simd }
    }

    /// GMI kernel (switching/buffering only).
    pub fn gmi_usage(&self) -> ResourceUsage {
        ResourceUsage { lut: 2_500, ff: 4_000, bram18: 2, dsp: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_anchors() {
        let pe = PeConfig::default();
        // I = 767+-1: one row every ~768 cycles from the 768x768 linears
        assert_eq!(pe.qkv_row_cycles(768), 768);
        // FFN kernels keep the same initiation interval
        assert_eq!(pe.ffn1_row_cycles(768, 3072), 768);
        assert_eq!(pe.ffn2_row_cycles(768, 3072), 768);
        // layer-0 compute at M=128 is ~98k cycles (DESIGN.md)
        assert_eq!(128 * pe.qkv_row_cycles(768), 98_304);
    }

    #[test]
    fn attention_is_faster_but_smm_paces_like_linears() {
        // Fig. 16: layers 1-2 have lower latency than 0, 3, 4, 5; layer 3
        // paces with the linears.
        let pe = PeConfig::default();
        let m = 128;
        assert!(pe.attn_row_cycles(m, 64) + pe.softmax_row_cycles(m) < pe.qkv_row_cycles(768));
        let smm = pe.smm_row_cycles(m, 64);
        assert!(smm <= pe.qkv_row_cycles(768) && smm > pe.qkv_row_cycles(768) * 9 / 10, "{smm}");
    }

    #[test]
    fn padding_formula_matches_paper() {
        // NUM_PE * ceil(M / NUM_PE) for M=54 (MRPC average), NUM_PE=32 => 64
        let pe = PeConfig { attn_pes: 32, ..Default::default() };
        assert_eq!(pe.attn_row_cycles(54, 64), 64 * 64 / 32);
    }

    #[test]
    fn no_padding_scales_with_actual_m() {
        let pe = PeConfig::default();
        // smm iterates actual M: 38-token sequences cost ~38/128 of max
        let full = pe.smm_row_cycles(128, 64);
        let short = pe.smm_row_cycles(38, 64);
        assert!(short * 3 < full);
    }

    #[test]
    fn ln_keeps_line_rate() {
        let pe = PeConfig::default();
        assert!(pe.ln_row_cycles(768) < pe.qkv_row_cycles(768));
    }

    #[test]
    fn decode_trip_counts_grow_with_attended_length() {
        let pe = PeConfig::default();
        // a single-token decode step against a short cache is far
        // cheaper than a full-length row...
        assert!(pe.attn_decode_row_cycles(8, 64) < pe.attn_decode_row_cycles(128, 64) / 4);
        assert!(pe.smm_decode_row_cycles(8, 64) * 4 < pe.smm_decode_row_cycles(128, 64));
        // ...and at full length the decode model degenerates to the
        // fixed-m encoder model (same hardware loops)
        assert_eq!(
            pe.attn_decode_row_cycles(128, 64),
            pe.attn_row_cycles(128, 64) + pe.softmax_row_cycles(128)
        );
        assert_eq!(pe.smm_decode_row_cycles(128, 64), pe.smm_row_cycles(128, 64));
    }

    #[test]
    fn batched_token_rows_amortize_the_weight_pass() {
        let pe = PeConfig::default();
        // 768x768 linears: the weight pass is the calibrated 768-cycle
        // row time; a token row riding the live stream costs half
        assert_eq!(pe.linear_weight_pass_cycles(768, 768, pe.linear_macs), 768);
        assert_eq!(pe.batched_linear_row_cycles(768, 768, pe.linear_macs), 384);
        // FFN kernels amortize identically (same ii, wider matrices)
        assert_eq!(pe.batched_linear_row_cycles(768, 3072, pe.ffn_macs), 384);
        assert_eq!(pe.batched_linear_row_cycles(3072, 768, pe.ffn_macs), 384);
        // a batch of 8 token rows beats 8 independent single-row passes
        let batched = pe.linear_weight_pass_cycles(768, 768, 768)
            + 8 * pe.batched_linear_row_cycles(768, 768, 768);
        assert_eq!(batched, 3840);
        assert!(batched * 16 == 8 * 768 * 10, "1.6x at B=8: {batched} vs {}", 8 * 768);
    }

    #[test]
    fn dsp_estimates() {
        let pe = PeConfig::default();
        assert_eq!(pe.macs_dsp(768, Device::Xczu19eg), 384);
        assert_eq!(pe.macs_dsp(3072, Device::Xczu19eg), 1536);
        let u = pe.linear_usage(768, 768, 768, Device::Xczu19eg);
        assert_eq!(u.bram18, (768u64 * 768).div_ceil(2304));
    }
}
