//! Quantisation constants, parsed from artifacts/quantparams.json.
//!
//! These are the integer constants derived ONCE in python/compile/quantize.py;
//! the rust side only reads them (bit-exactness contract — DESIGN.md).

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequantSite {
    pub m: i64,
    pub n: u32,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SoftmaxParams {
    pub q_ln2: i64,
    pub q_b: i64,
    pub q_c: i64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeluParams {
    pub q_b: i64,
    pub q_c: i64,
    pub q_one: i64,
    pub out: RequantSite,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerNormParams {
    pub kg: u32,
}

/// All integer constants of one encoder (mirror of quantize.EncoderQuant).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EncoderQuant {
    pub rq_q: RequantSite,
    pub rq_k: RequantSite,
    pub rq_v: RequantSite,
    pub rq_att: RequantSite,
    pub rq_proj: RequantSite,
    pub rq_resin: RequantSite,
    pub rq_gelu_in: RequantSite,
    pub rq_ffn2: RequantSite,
    pub rq_res2in: RequantSite,
    pub softmax: SoftmaxParams,
    pub gelu: GeluParams,
    pub ln1: LayerNormParams,
    pub ln2: LayerNormParams,
}

impl EncoderQuant {
    /// The integer constants `python/compile/quantize.py` exports for the
    /// I-BERT base checkpoint. Used to build synthetic models when the
    /// artifacts directory is absent (benches, property tests) — the
    /// operators behave identically, only the weights differ.
    pub fn ibert_base_sample() -> EncoderQuant {
        EncoderQuant {
            rq_q: RequantSite { m: 25412, n: 24 },
            rq_k: RequantSite { m: 21090, n: 24 },
            rq_v: RequantSite { m: 22878, n: 24 },
            rq_att: RequantSite { m: 20365, n: 21 },
            rq_proj: RequantSite { m: 30599, n: 15 },
            rq_resin: RequantSite { m: 25999, n: 5 },
            rq_gelu_in: RequantSite { m: 27916, n: 24 },
            rq_ffn2: RequantSite { m: 23137, n: 15 },
            rq_res2in: RequantSite { m: 32264, n: 5 },
            softmax: SoftmaxParams { q_ln2: 1051, q_b: 2052, q_c: 2_209_112 },
            gelu: GeluParams {
                q_b: -70,
                q_c: -5272,
                q_one: -5272,
                out: RequantSite { m: 25463, n: 28 },
            },
            ln1: LayerNormParams { kg: 10 },
            ln2: LayerNormParams { kg: 10 },
        }
    }
}

/// Model geometry (BERT-base / I-BERT base).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub hidden: usize,
    pub heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub num_encoders: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { hidden: 768, heads: 12, ffn: 3072, max_seq: 128, num_encoders: 12 }
    }
}

fn site(j: &Json, path: &str) -> Result<RequantSite> {
    let s = j.path(path).with_context(|| format!("quantparams missing {path}"))?;
    Ok(RequantSite {
        m: s.get("m").and_then(Json::as_i64).context("requant m")?,
        n: s.get("n").and_then(Json::as_i64).context("requant n")? as u32,
    })
}

fn int(j: &Json, path: &str) -> Result<i64> {
    j.path(path).and_then(Json::as_i64).with_context(|| format!("quantparams missing {path}"))
}

/// Parse quantparams.json text into (geometry, constants).
pub fn parse_quantparams(text: &str) -> Result<(ModelConfig, EncoderQuant)> {
    let j = Json::parse(text).context("quantparams.json")?;
    let cfg = ModelConfig {
        hidden: int(&j, "hidden")? as usize,
        heads: int(&j, "heads")? as usize,
        ffn: int(&j, "ffn")? as usize,
        max_seq: int(&j, "max_seq")? as usize,
        num_encoders: int(&j, "num_encoders")? as usize,
    };
    let e = "encoder";
    let eq = EncoderQuant {
        rq_q: site(&j, &format!("{e}.rq_q"))?,
        rq_k: site(&j, &format!("{e}.rq_k"))?,
        rq_v: site(&j, &format!("{e}.rq_v"))?,
        rq_att: site(&j, &format!("{e}.rq_att"))?,
        rq_proj: site(&j, &format!("{e}.rq_proj"))?,
        rq_resin: site(&j, &format!("{e}.rq_resin"))?,
        rq_gelu_in: site(&j, &format!("{e}.rq_gelu_in"))?,
        rq_ffn2: site(&j, &format!("{e}.rq_ffn2"))?,
        rq_res2in: site(&j, &format!("{e}.rq_res2in"))?,
        softmax: SoftmaxParams {
            q_ln2: int(&j, &format!("{e}.softmax.q_ln2"))?,
            q_b: int(&j, &format!("{e}.softmax.q_b"))?,
            q_c: int(&j, &format!("{e}.softmax.q_c"))?,
        },
        gelu: GeluParams {
            q_b: int(&j, &format!("{e}.gelu.q_b"))?,
            q_c: int(&j, &format!("{e}.gelu.q_c"))?,
            q_one: int(&j, &format!("{e}.gelu.q_one"))?,
            out: site(&j, &format!("{e}.gelu.out"))?,
        },
        ln1: LayerNormParams { kg: int(&j, &format!("{e}.ln1.kg"))? as u32 },
        ln2: LayerNormParams { kg: int(&j, &format!("{e}.ln2.kg"))? as u32 },
    };
    Ok((cfg, eq))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "encoder": {
        "rq_q": {"m": 25412, "n": 24}, "rq_k": {"m": 21090, "n": 24},
        "rq_v": {"m": 22878, "n": 24}, "rq_att": {"m": 20365, "n": 21},
        "rq_proj": {"m": 30599, "n": 15}, "rq_resin": {"m": 25999, "n": 5},
        "rq_gelu_in": {"m": 27916, "n": 24}, "rq_ffn2": {"m": 23137, "n": 15},
        "rq_res2in": {"m": 32264, "n": 5},
        "softmax": {"q_ln2": 1051, "q_b": 2052, "q_c": 2209112},
        "gelu": {"q_b": -70, "q_c": -5272, "q_one": -5272,
                 "out": {"m": 25463, "n": 28}},
        "ln1": {"kg": 10}, "ln2": {"kg": 10}
      },
      "hidden": 768, "heads": 12, "ffn": 3072, "max_seq": 128, "num_encoders": 12
    }"#;

    #[test]
    fn parses_sample() {
        let (cfg, eq) = parse_quantparams(SAMPLE).unwrap();
        assert_eq!(cfg.hidden, 768);
        assert_eq!(cfg.head_dim(), 64);
        assert_eq!(eq.rq_q.m, 25412);
        assert_eq!(eq.softmax.q_c, 2_209_112);
        assert_eq!(eq.gelu.q_b, -70);
        assert_eq!(eq.ln2.kg, 10);
    }

    #[test]
    fn missing_field_is_error() {
        assert!(parse_quantparams("{\"hidden\": 768}").is_err());
    }
}
