//! The Fig. 14 encoder graph: 38 kernels over six FPGAs, one Galapagos
//! cluster per encoder (§7.2).
//!
//! Kernel numbering follows the paper with one fix: the paper's own
//! listing skips id 33 (enumerating 0–32 and 34–38, which is 38 kernels);
//! Galapagos requires a contiguous id space, so our GMI kernels are
//! 33–37 — still 38 kernels total (see DESIGN.md "Known deviations").
//!
//!   0        Gateway (+ virtual Broadcast of the encoder input)
//!   1..3     Linear+Quant (Q, K, V)            — layer 0
//!   4..15    Attention Dot-Product + Softmax   — layers 1-2 (per head)
//!   16..27   Softmax Matrix-Multiply + Quant   — layer 3 (per head)
//!   28       Linear+Quant (output projection)  — layer 4
//!   29       Add & LayerNorm 1                 — layer 4
//!   30       Linear+GELU (FFN 1)               — layer 5
//!   31       Linear+Quant (FFN 2)              — layer 5
//!   32       Add & LayerNorm 2                 — layer 5
//!   33,34,35 GMI Scatter (head-split Q, K, V)
//!   36       GMI GatherCols (head merge)
//!   37       GMI Broadcast (LN1 -> FFN + residual)

use std::collections::HashMap;

use crate::galapagos::cluster::{ClusterSpec, KernelDecl, KernelType};
use crate::gmi::gateway::{Gateway, GatewayConfig};
use crate::gmi::{GmiKernel, GmiOp, Out, ScatterPolicy};
use crate::sim::engine::KernelBehavior;
use crate::sim::fabric::FpgaId;
use crate::sim::packet::GlobalKernelId;

use super::kernels::{
    AttentionHeadKernel, LayerNormKernel, LinearKernel, LinearWhich, LnWhich, Mode, SoftmaxMMKernel,
};
use super::timing::PeConfig;

pub const HEADS: u8 = 12;
pub const KERNELS_PER_ENCODER: usize = 38;

/// Ids of the encoder kernels (paper Fig. 14, contiguous renumbering).
pub mod ids {
    pub const GATEWAY: u8 = 0;
    pub const LINEAR_Q: u8 = 1;
    pub const LINEAR_K: u8 = 2;
    pub const LINEAR_V: u8 = 3;
    pub const ATTN_BASE: u8 = 4; // ..15
    pub const SMM_BASE: u8 = 16; // ..27
    pub const PROJ: u8 = 28;
    pub const LN1: u8 = 29;
    pub const FFN1: u8 = 30;
    pub const FFN2: u8 = 31;
    pub const LN2: u8 = 32;
    pub const SCATTER_Q: u8 = 33;
    pub const SCATTER_K: u8 = 34;
    pub const SCATTER_V: u8 = 35;
    pub const GATHER: u8 = 36;
    pub const BCAST_LN1: u8 = 37;
}

/// Configuration of one encoder cluster build.
#[derive(Clone)]
pub struct EncoderGraphParams {
    pub cluster_id: u8,
    /// six consecutive FPGAs starting here
    pub fpga_base: usize,
    pub pe: PeConfig,
    pub mode: Mode,
    /// where LN2 sends the encoder output (next encoder's gateway, or the
    /// evaluation sink)
    pub out_dst: Out,
    /// sequence capacity used for FIFO sizing (the hardware build point)
    pub max_seq: usize,
    pub hidden: usize,
    pub ffn: usize,
    /// `Some(block)` switches the attention/SMM heads into decode mode
    /// (per-request KV caches, causal masking, variable trip counts);
    /// `block` = inference ids per request (`DecodeConfig::block`).
    pub decode: Option<u32>,
    /// Continuous-batching build: the six linear kernels price
    /// single-token rows with the weight-stationary split (full weight
    /// pass only when the token opens a streak, marginal cost inside
    /// one). Requires `decode` — only decode runs emit token rows.
    pub batched: bool,
}

/// A built encoder: the validated cluster spec plus kernel behaviors.
pub struct EncoderBuild {
    pub cluster: ClusterSpec,
    pub behaviors: HashMap<u8, Box<dyn KernelBehavior>>,
}

/// The paper's manual placement as a slot vector (what `build_encoder`
/// uses; the placer subsystem produces alternatives for
/// [`build_encoder_placed`]).
pub fn default_slots() -> Vec<usize> {
    (0..KERNELS_PER_ENCODER as u8).map(fpga_slot).collect()
}

/// FPGA placement of a kernel id within the 6-FPGA encoder (Fig. 18).
pub fn fpga_slot(id: u8) -> usize {
    use ids::*;
    match id {
        GATEWAY | LINEAR_Q | LINEAR_K | LINEAR_V | SCATTER_Q | SCATTER_K | SCATTER_V => 0,
        x if (ATTN_BASE..ATTN_BASE + HEADS).contains(&x) => 1,
        x if (SMM_BASE..SMM_BASE + HEADS).contains(&x) => 2,
        GATHER => 2,
        PROJ | LN1 | BCAST_LN1 => 3,
        FFN1 => 4,
        FFN2 | LN2 => 5,
        _ => panic!("unknown encoder kernel id {id}"),
    }
}

fn kind_of(id: u8) -> KernelType {
    use ids::*;
    match id {
        GATEWAY => KernelType::Gateway,
        SCATTER_Q | SCATTER_K | SCATTER_V | GATHER | BCAST_LN1 => KernelType::Gmi,
        _ => KernelType::Compute,
    }
}

/// Input FIFO capacity of each kernel, per the paper's sizing rule
/// ("large enough to hold at least one matrix", §8.2.1).
pub fn fifo_bytes(id: u8, max_seq: usize, hidden: usize, ffn: usize) -> usize {
    use ids::*;
    let d = hidden / HEADS as usize;
    match id {
        GATEWAY => max_seq * hidden,
        LINEAR_Q | LINEAR_K | LINEAR_V => max_seq * hidden,
        x if (ATTN_BASE..ATTN_BASE + HEADS).contains(&x) => 2 * max_seq * d,
        x if (SMM_BASE..SMM_BASE + HEADS).contains(&x) => max_seq * (max_seq + d),
        PROJ => max_seq * hidden,
        // LN1 holds the residual input matrix while the attention path drains
        LN1 => max_seq * hidden + 16 * 4 * hidden,
        FFN1 => max_seq * hidden,
        FFN2 => max_seq * ffn,
        LN2 => max_seq * hidden + 16 * 4 * hidden,
        SCATTER_Q | SCATTER_K | SCATTER_V => 8 * hidden,
        GATHER => max_seq * hidden,
        BCAST_LN1 => 8 * hidden,
        _ => panic!("unknown encoder kernel id {id}"),
    }
}

/// Connection-graph edges of kernel `id` (the graph input to Galapagos).
pub fn dests_of(id: u8, cluster: u8, out_dst: Out) -> Vec<GlobalKernelId> {
    use ids::*;
    let k = |n: u8| GlobalKernelId::new(cluster, n);
    match id {
        GATEWAY => vec![k(LINEAR_Q), k(LINEAR_K), k(LINEAR_V), k(LN1)],
        LINEAR_Q => vec![k(SCATTER_Q)],
        LINEAR_K => vec![k(SCATTER_K)],
        LINEAR_V => vec![k(SCATTER_V)],
        x if (ATTN_BASE..ATTN_BASE + HEADS).contains(&x) => vec![k(SMM_BASE + (x - ATTN_BASE))],
        x if (SMM_BASE..SMM_BASE + HEADS).contains(&x) => vec![k(GATHER)],
        PROJ => vec![k(LN1)],
        LN1 => vec![k(BCAST_LN1)],
        FFN1 => vec![k(FFN2)],
        FFN2 => vec![k(LN2)],
        LN2 => vec![out_dst.dst],
        SCATTER_Q | SCATTER_K => (0..HEADS).map(|h| k(ATTN_BASE + h)).collect(),
        SCATTER_V => (0..HEADS).map(|h| k(SMM_BASE + h)).collect(),
        GATHER => vec![k(PROJ)],
        BCAST_LN1 => vec![k(FFN1), k(LN2)],
        _ => panic!("unknown encoder kernel id {id}"),
    }
}

/// Build one encoder cluster with the paper's Fig. 14/18 placement.
pub fn build_encoder(gp: &EncoderGraphParams) -> EncoderBuild {
    build_encoder_placed(gp, &default_slots())
}

/// Build one encoder cluster: spec + behaviors (§7.2's Cluster Builder
/// output for the I-BERT layer description). `slots[id]` gives each
/// kernel's FPGA slot relative to `gp.fpga_base` — the hook through
/// which the automatic placer drives the Cluster Builder and the
/// simulator instead of the hard-coded paper mapping.
pub fn build_encoder_placed(gp: &EncoderGraphParams, slots: &[usize]) -> EncoderBuild {
    use ids::*;
    assert_eq!(slots.len(), KERNELS_PER_ENCODER, "placement must cover all 38 kernels");
    let c = gp.cluster_id;
    let k = |n: u8| GlobalKernelId::new(c, n);

    let mut behaviors: HashMap<u8, Box<dyn KernelBehavior>> = HashMap::new();

    // gateway with the virtual input-broadcast module (Kern_0)
    let mut virtuals = HashMap::new();
    virtuals.insert(
        0u8,
        GmiOp::Broadcast {
            dsts: vec![
                Out::tagged(k(LINEAR_Q), 0),
                Out::tagged(k(LINEAR_K), 0),
                Out::tagged(k(LINEAR_V), 0),
                Out::tagged(k(LN1), 1), // residual path
            ],
        },
    );
    behaviors.insert(GATEWAY, Box::new(Gateway::new(GatewayConfig { cluster: c, virtuals })));

    // all six weight-stationary linears share the batched-build switch
    let lin = |which: LinearWhich, out: Out| {
        let kern = LinearKernel::new(which, out, gp.mode.clone(), &gp.pe);
        if gp.batched { kern.with_batched(&gp.pe) } else { kern }
    };

    // layer 0: Q/K/V linears
    behaviors.insert(LINEAR_Q, Box::new(lin(LinearWhich::Q, Out::to(k(SCATTER_Q)))));
    behaviors.insert(LINEAR_K, Box::new(lin(LinearWhich::K, Out::to(k(SCATTER_K)))));
    behaviors.insert(LINEAR_V, Box::new(lin(LinearWhich::V, Out::to(k(SCATTER_V)))));

    // head-split scatters
    behaviors.insert(
        SCATTER_Q,
        Box::new(GmiKernel::new(GmiOp::Scatter {
            dsts: (0..HEADS).map(|h| Out::tagged(k(ATTN_BASE + h), 0)).collect(),
            policy: ScatterPolicy::ColumnSplit,
        })),
    );
    behaviors.insert(
        SCATTER_K,
        Box::new(GmiKernel::new(GmiOp::Scatter {
            dsts: (0..HEADS).map(|h| Out::tagged(k(ATTN_BASE + h), 1)).collect(),
            policy: ScatterPolicy::ColumnSplit,
        })),
    );
    behaviors.insert(
        SCATTER_V,
        Box::new(GmiKernel::new(GmiOp::Scatter {
            dsts: (0..HEADS).map(|h| Out::tagged(k(SMM_BASE + h), 1)).collect(),
            policy: ScatterPolicy::ColumnSplit,
        })),
    );

    // layers 1-3: attention heads (KV-caching causal variants in decode
    // mode — same graph, same edges, stateful behaviors)
    for h in 0..HEADS {
        let mut attn = AttentionHeadKernel::new(
            h as usize,
            Out::tagged(k(SMM_BASE + h), 0),
            gp.mode.clone(),
            gp.pe,
        );
        let mut smm = SoftmaxMMKernel::new(
            h as usize,
            Out::tagged(k(GATHER), h), // stream tag = gather rank
            gp.mode.clone(),
            gp.pe,
        );
        if let Some(block) = gp.decode {
            attn = attn.with_decode(block);
            smm = smm.with_decode(block);
        }
        behaviors.insert(ATTN_BASE + h, Box::new(attn));
        behaviors.insert(SMM_BASE + h, Box::new(smm));
    }

    // head merge
    behaviors.insert(
        GATHER,
        Box::new(GmiKernel::new(GmiOp::GatherCols {
            n_srcs: HEADS as usize,
            dst: Out::tagged(k(PROJ), 0),
        })),
    );

    // layer 4
    behaviors.insert(PROJ, Box::new(lin(LinearWhich::Proj, Out::tagged(k(LN1), 0))));
    behaviors.insert(
        LN1,
        Box::new(LayerNormKernel::new(LnWhich::Ln1, Out::to(k(BCAST_LN1)), gp.mode.clone(), gp.pe)),
    );
    behaviors.insert(
        BCAST_LN1,
        Box::new(GmiKernel::new(GmiOp::Broadcast {
            dsts: vec![Out::tagged(k(FFN1), 0), Out::tagged(k(LN2), 1)],
        })),
    );

    // layer 5
    behaviors.insert(FFN1, Box::new(lin(LinearWhich::Ffn1, Out::tagged(k(FFN2), 0))));
    behaviors.insert(FFN2, Box::new(lin(LinearWhich::Ffn2, Out::tagged(k(LN2), 0))));
    behaviors.insert(
        LN2,
        Box::new(LayerNormKernel::new(LnWhich::Ln2, gp.out_dst, gp.mode.clone(), gp.pe)),
    );

    // the cluster spec
    let mut kernels = Vec::new();
    for id in 0..KERNELS_PER_ENCODER as u8 {
        kernels.push(KernelDecl {
            id,
            name: kernel_name(id),
            ktype: kind_of(id),
            fpga: FpgaId(gp.fpga_base + slots[id as usize]),
            dests: dests_of(id, c, gp.out_dst),
            fifo_bytes: fifo_bytes(id, gp.max_seq, gp.hidden, gp.ffn),
        });
    }

    EncoderBuild { cluster: ClusterSpec { id: c, kernels }, behaviors }
}

/// Human-readable kernel name (Fig. 14 labels).
pub fn kernel_name(id: u8) -> String {
    use ids::*;
    match id {
        GATEWAY => "gateway+broadcast".into(),
        LINEAR_Q => "linear-q+quant".into(),
        LINEAR_K => "linear-k+quant".into(),
        LINEAR_V => "linear-v+quant".into(),
        x if (ATTN_BASE..ATTN_BASE + HEADS).contains(&x) => {
            format!("dot-product+softmax-h{}", x - ATTN_BASE)
        }
        x if (SMM_BASE..SMM_BASE + HEADS).contains(&x) => {
            format!("softmax-mm+quant-h{}", x - SMM_BASE)
        }
        PROJ => "linear-proj+quant".into(),
        LN1 => "add+layernorm-1".into(),
        FFN1 => "linear-ffn1+gelu".into(),
        FFN2 => "linear-ffn2+quant".into(),
        LN2 => "add+layernorm-2".into(),
        SCATTER_Q => "gmi-scatter-q".into(),
        SCATTER_K => "gmi-scatter-k".into(),
        SCATTER_V => "gmi-scatter-v".into(),
        GATHER => "gmi-gather-heads".into(),
        BCAST_LN1 => "gmi-broadcast-ln1".into(),
        _ => format!("kern_{id}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> EncoderGraphParams {
        EncoderGraphParams {
            cluster_id: 0,
            fpga_base: 0,
            pe: PeConfig::default(),
            mode: Mode::Timing,
            out_dst: Out::to(GlobalKernelId::new(0, ids::GATEWAY)), // placeholder
            max_seq: 128,
            hidden: 768,
            ffn: 3072,
            decode: None,
            batched: false,
        }
    }

    #[test]
    fn decode_graph_builds_with_caching_heads() {
        let gp = EncoderGraphParams { decode: Some(5), ..params() };
        let b = build_encoder(&gp);
        assert_eq!(b.cluster.kernels.len(), 38);
        b.cluster.validate().unwrap();
    }

    #[test]
    fn batched_graph_builds_with_batched_linears() {
        let gp = EncoderGraphParams { decode: Some(5), batched: true, ..params() };
        let b = build_encoder(&gp);
        assert_eq!(b.cluster.kernels.len(), 38);
        assert_eq!(b.behaviors.len(), 38);
        b.cluster.validate().unwrap();
    }

    #[test]
    fn encoder_has_38_kernels_like_fig14() {
        let b = build_encoder(&params());
        assert_eq!(b.cluster.kernels.len(), 38);
        assert_eq!(b.behaviors.len(), 38);
        b.cluster.validate().unwrap();
    }

    #[test]
    fn six_fpgas_used() {
        let b = build_encoder(&params());
        let fpgas: Vec<usize> = b.cluster.fpgas().iter().map(|f| f.0).collect();
        assert_eq!(fpgas, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn custom_placement_overrides_the_paper_slots() {
        // the placer's hook: same graph, arbitrary kernel -> slot map
        let mut slots = default_slots();
        slots[ids::FFN1 as usize] = 3; // co-locate FFN1 with layer 4
        let b = build_encoder_placed(&params(), &slots);
        let ffn1 = b.cluster.kernel(ids::FFN1).unwrap();
        assert_eq!(ffn1.fpga.0, 3);
        b.cluster.validate().unwrap();
        // default build still follows Fig. 18
        let d = build_encoder(&params());
        assert_eq!(d.cluster.kernel(ids::FFN1).unwrap().fpga.0, 4);
    }

    #[test]
    fn gmi_kernel_count_matches_paper() {
        // §9.4: "we have 38 kernels, including six GMI kernels" — five
        // physical (scatters, gather, broadcast) + the gateway's virtual
        // broadcast module.
        let b = build_encoder(&params());
        let gmi = b.cluster.kernels.iter().filter(|k| k.ktype == KernelType::Gmi).count();
        assert_eq!(gmi, 5);
        let gw = b.cluster.kernels.iter().filter(|k| k.ktype == KernelType::Gateway).count();
        assert_eq!(gw, 1);
    }

    #[test]
    fn paper_fifo_rule_43_brams() {
        // one [128, 768] int8 matrix => 43 BRAM18 (§8.2.1)
        let bytes = fifo_bytes(ids::LINEAR_Q, 128, 768, 3072);
        assert_eq!(bytes.div_ceil(crate::sim::fifo::BRAM18_BYTES), 43);
    }

    #[test]
    fn edges_form_a_dag_reaching_ln2() {
        // BFS from the gateway must reach LN2 (the encoder output)
        let _b = build_encoder(&params());
        let mut seen = std::collections::HashSet::new();
        let mut queue = vec![ids::GATEWAY];
        while let Some(id) = queue.pop() {
            if !seen.insert(id) {
                continue;
            }
            for d in dests_of(id, 0, Out::to(GlobalKernelId::new(0, 0))) {
                if d.cluster == 0 && d.kernel != ids::GATEWAY && !seen.contains(&d.kernel) {
                    queue.push(d.kernel);
                }
            }
        }
        assert!(seen.contains(&ids::LN2));
        assert_eq!(seen.len(), 38, "all kernels reachable");
    }
}
