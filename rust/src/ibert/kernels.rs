//! Streaming kernel behaviors of the I-BERT encoder (§7.1, Fig. 14).
//!
//! Each kernel is the HLS module of the paper as a discrete-event actor:
//! rows stream in, a PE/tile timing model (timing.rs) paces the output,
//! and in Functional mode the emitted rows carry real integers computed
//! with the bit-exact operators of compute.rs — so a simulated six-FPGA
//! cluster produces the same bytes as the JAX reference.
//!
//! Burst-aware pacing: every input row carries an explicit (possibly
//! virtual) arrival time — `KernelIo::rows` supplies it for both single
//! packets and coalesced runs — and every pacer decision is a pure
//! function of those times (`ready = max(arrival, gate)`), never of the
//! dispatch instant. That is what makes the coalesced engine emit each
//! row at exactly the cycle the uncoalesced engine would (the
//! golden-determinism contract in rust/tests/proptests.rs). Emission
//! goes through an `OutStream`: whole backlogs ship as one burst on
//! intra-FPGA edges, or row-by-row at the exact scheduled cycle via
//! deferred wakes everywhere else.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::gmi::ops::TxQueue;
use crate::sim::engine::{KernelBehavior, KernelIo, START_TAG};
use crate::sim::packet::{MsgMeta, Packet, Payload};

use super::compute;
use super::timing::PeConfig;
use super::weights::ModelParams;
use crate::gmi::Out;

/// Wake tag used by every kernel's output stream (START_TAG is u64::MAX).
const OUT_WAKE: u64 = u64::MAX - 1;

/// Simulation mode: pure timing (Timing payloads) or functional
/// (real integer rows, bit-exact vs the reference).
#[derive(Clone)]
pub enum Mode {
    Timing,
    Functional(Arc<ModelParams>),
}

impl Mode {
    pub fn is_functional(&self) -> bool {
        matches!(self, Mode::Functional(_))
    }
    fn params(&self) -> Option<&Arc<ModelParams>> {
        match self {
            Mode::Functional(p) => Some(p),
            Mode::Timing => None,
        }
    }
}

/// Serialize row emissions: a pipelined unit with a one-time fill depth
/// and a per-row initiation interval. A row whose inputs are ready at
/// `t` emits at max(t + fill + ii, last_emit + ii) — steady-state output
/// interval is exactly `ii` (the paper's measured I = 767 for the
/// 768-wide linears).
#[derive(Debug, Default, Clone, Copy)]
struct EmitPacer {
    last_emit: Option<u64>,
}

impl EmitPacer {
    fn schedule(&mut self, now: u64, fill: u64, ii: u64) -> u64 {
        let emit = (now + fill + ii).max(self.last_emit.map_or(0, |e| e + ii));
        self.last_emit = Some(emit);
        emit
    }
}

/// The output side of a compute kernel: pacer + emission queue. Rows are
/// queued with their exact emission cycle; the queue ships them as
/// coalesced bursts (intra-FPGA destination) or row-by-row wakes.
struct OutStream {
    out: Out,
    fill: u64,
    pacer: EmitPacer,
    tx: TxQueue,
    wake_at: Option<u64>,
}

impl OutStream {
    fn new(out: Out, fill: u64) -> OutStream {
        OutStream {
            out,
            fill,
            pacer: EmitPacer::default(),
            tx: TxQueue::default(),
            wake_at: None,
        }
    }

    /// Pace one output row whose inputs became ready at `ready_t`.
    fn push(&mut self, ready_t: u64, ii: u64, meta: MsgMeta, payload: Payload) {
        let at = self.pacer.schedule(ready_t, self.fill, ii);
        self.tx.push(meta, at, payload);
    }

    fn pump(&mut self, io: &mut KernelIo) {
        if io.can_burst(self.out.dst) {
            // a compute kernel has exactly one output stream, so the
            // whole backlog may ship as coalesced bursts
            self.tx.ship_bursts(self.out, io);
            return;
        }
        self.tx.emit_due(self.out, io);
        match self.tx.front_time() {
            None => self.wake_at = None,
            Some(t) => {
                if self.wake_at.is_none_or(|w| t < w) {
                    io.wake_in(t - io.now, OUT_WAKE);
                    self.wake_at = Some(t);
                }
            }
        }
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == OUT_WAKE {
            self.wake_at = None;
            self.pump(io);
        }
    }
}

fn row_i8(p: Payload) -> Option<Arc<Vec<i8>>> {
    match p {
        Payload::RowI8(v) => Some(v),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Linear kernels (Kern_1..3, 28, 30, 31)
// ---------------------------------------------------------------------------

/// Which linear module this kernel instantiates; selects weights, the
/// requantiser, the fused post-op, and the output payload kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearWhich {
    Q,
    K,
    V,
    /// attention output projection; emits wide rows for the residual add
    Proj,
    /// FFN first linear with fused i-GELU (Kern_30)
    Ffn1,
    /// FFN second linear; emits wide rows (Kern_31)
    Ffn2,
}

fn linear_out_bytes(which: LinearWhich, hidden: usize, ffn: usize) -> usize {
    match which {
        LinearWhich::Q | LinearWhich::K | LinearWhich::V => hidden,
        LinearWhich::Proj | LinearWhich::Ffn2 => 4 * hidden,
        LinearWhich::Ffn1 => ffn,
    }
}

fn linear_compute_row(which: LinearWhich, p: &ModelParams, x: &[i8]) -> Payload {
    let (h, f) = (p.cfg.hidden, p.cfg.ffn);
    let eq = &p.eq;
    match which {
        LinearWhich::Q => Payload::row_i8(
            compute::linear_row(x, &p.wq.data, h, h, &p.bq)
                .into_iter()
                .map(|a| compute::requant8(a as i64, eq.rq_q))
                .collect(),
        ),
        LinearWhich::K => Payload::row_i8(
            compute::linear_row(x, &p.wk.data, h, h, &p.bk)
                .into_iter()
                .map(|a| compute::requant8(a as i64, eq.rq_k))
                .collect(),
        ),
        LinearWhich::V => Payload::row_i8(
            compute::linear_row(x, &p.wv.data, h, h, &p.bv)
                .into_iter()
                .map(|a| compute::requant8(a as i64, eq.rq_v))
                .collect(),
        ),
        LinearWhich::Proj => Payload::row_i32(
            compute::linear_row(x, &p.wo.data, h, h, &p.bo)
                .into_iter()
                .map(|a| compute::requant32(a as i64, eq.rq_proj) as i32)
                .collect(),
        ),
        LinearWhich::Ffn1 => Payload::row_i8(
            compute::linear_row(x, &p.w1.data, h, f, &p.b1)
                .into_iter()
                .map(|a| compute::gelu_i8(compute::requant8(a as i64, eq.rq_gelu_in), eq.gelu))
                .collect(),
        ),
        LinearWhich::Ffn2 => Payload::row_i32(
            compute::linear_row(x, &p.w2.data, f, h, &p.b2)
                .into_iter()
                .map(|a| compute::requant32(a as i64, eq.rq_ffn2) as i32)
                .collect(),
        ),
    }
}

/// Linear (+Quant / +GELU) kernel: consumes one int8 row, emits one row.
pub struct LinearKernel {
    pub which: LinearWhich,
    pub mode: Mode,
    pub row_cycles: u64,
    /// `Some((weight_pass, marginal))` = continuous-batching mode. A
    /// single-token row (`meta.rows == 1`) that arrives while the weight
    /// stream is still live — i.e. before the previous output row has
    /// finished emitting — rides the stream at the dual-int8 `marginal`
    /// rate; a token row that finds the kernel idle restarts the stream
    /// and pays `weight_pass + marginal`. Prefill rows (`rows > 1`) keep
    /// the calibrated `row_cycles` either way: the paper's I = 767
    /// anchor is a prefill measurement. The decision is a pure function
    /// of deterministic event times (row arrival vs the pacer's last
    /// emission), so batched runs inherit the engine's thread- and
    /// shard-invariance unchanged.
    batched: Option<(u64, u64)>,
    out: OutStream,
}

impl LinearKernel {
    pub fn new(which: LinearWhich, out: Out, mode: Mode, pe: &PeConfig) -> Self {
        let (h, f) = match mode.params() {
            Some(p) => (p.cfg.hidden as u64, p.cfg.ffn as u64),
            None => (768, 3072),
        };
        let row_cycles = match which {
            LinearWhich::Q | LinearWhich::K | LinearWhich::V | LinearWhich::Proj => {
                pe.qkv_row_cycles(h)
            }
            LinearWhich::Ffn1 => pe.ffn1_row_cycles(h, f),
            LinearWhich::Ffn2 => pe.ffn2_row_cycles(h, f),
        };
        LinearKernel {
            which,
            mode,
            row_cycles,
            batched: None,
            out: OutStream::new(out, pe.pipe_fill),
        }
    }

    /// Switch into continuous-batching (weight-stationary) timing: token
    /// rows amortize the weight pass across an emission streak.
    pub fn with_batched(mut self, pe: &PeConfig) -> Self {
        let (h, f) = match self.mode.params() {
            Some(p) => (p.cfg.hidden as u64, p.cfg.ffn as u64),
            None => (768, 3072),
        };
        let (k, n, macs) = match self.which {
            LinearWhich::Q | LinearWhich::K | LinearWhich::V | LinearWhich::Proj => {
                (h, h, pe.linear_macs)
            }
            LinearWhich::Ffn1 => (h, f, pe.ffn_macs),
            LinearWhich::Ffn2 => (f, h, pe.ffn_macs),
        };
        self.batched = Some((
            pe.linear_weight_pass_cycles(k, n, macs),
            pe.batched_linear_row_cycles(k, n, macs),
        ));
        self
    }
}

impl KernelBehavior for LinearKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        let LinearKernel { which, mode, row_cycles, batched, out } = self;
        let (which, row_cycles, batched) = (*which, *row_cycles, *batched);
        let dims = match mode.params() {
            Some(p) => (p.cfg.hidden, p.cfg.ffn),
            None => (768, 3072),
        };
        let stream = out.out.stream.unwrap_or(0);
        io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
            io2.consume(payload.bytes());
            let pl = match (mode.params(), row_i8(payload)) {
                (Some(p), Some(x)) => linear_compute_row(which, p, &x),
                _ => Payload::Timing(linear_out_bytes(which, dims.0, dims.1)),
            };
            let ii = match batched {
                Some((weight_pass, marginal)) if meta.rows == 1 => {
                    if out.pacer.last_emit.is_some_and(|le| at <= le) {
                        marginal
                    } else {
                        weight_pass + marginal
                    }
                }
                _ => row_cycles,
            };
            out.push(at, ii, MsgMeta { stream, ..meta }, pl);
        });
        self.out.pump(io);
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            return;
        }
        self.out.on_wake(tag, io);
    }

    fn name(&self) -> String {
        format!("linear-{:?}", self.which)
    }
}

// ---------------------------------------------------------------------------
// Decode mode: per-request KV caching for the head kernels
// ---------------------------------------------------------------------------

/// Per-request decoder state of a cache-holding head kernel (the
/// attention head caches its K slices, the SMM head its V slices).
/// Inference ids are blocked per request (`DecodeConfig::block`): the
/// prefill at offset 0 appends `m` cache rows, each decode step appends
/// one, and the state retires when the final step's outputs are queued.
/// The feedback loop serializes a request's passes — a step's input row
/// cannot re-enter the pipeline before the previous pass fully drained
/// through every kernel — so one transient pass context suffices.
#[derive(Default)]
struct DecodeReq {
    /// cached rows across passes, in position order (functional mode;
    /// Timing mode tracks only `len`)
    cache: Vec<Arc<Vec<i8>>>,
    /// cached positions so far
    len: u32,
    /// latest cache-row arrival over the whole request: the decode gate
    done: u64,
    /// active pass id
    inference: u32,
    /// cache length at active-pass start
    base: u32,
    /// stream-1 (cache) rows received this pass
    got: u32,
    pass_rows: u32,
    /// this pass's cache rows, staged until the block is complete so
    /// out-of-order arrivals still append in position order
    staged: BTreeMap<u32, Arc<Vec<i8>>>,
    /// stream-0 rows waiting on the pass's cache block: row -> (arrival, data)
    pending: BTreeMap<u32, (u64, Option<Arc<Vec<i8>>>)>,
    queued: u32,
}

impl DecodeReq {
    fn new(inference: u32) -> DecodeReq {
        DecodeReq { inference, ..Default::default() }
    }
}

/// One input row of a decode-mode pass. Stream 1 rows append to the KV
/// cache; other streams are compute rows (Q for attention, probability
/// rows for SMM) gated until the pass's cache block is complete. A row
/// at in-pass index `j` attends `base + j + 1` cached positions — the
/// causal mask — and `emit(cache, attended, data)` turns that into the
/// row's (cycles, payload) under the variable-trip-count timing model.
#[allow(clippy::too_many_arguments)]
fn decode_on_row(
    reqs: &mut HashMap<u32, DecodeReq>,
    block: u32,
    functional: bool,
    out: &mut OutStream,
    stream_tag: u8,
    meta: MsgMeta,
    at: u64,
    payload: Payload,
    emit: &mut dyn FnMut(&[Arc<Vec<i8>>], u32, Option<&Arc<Vec<i8>>>) -> (u64, Payload),
) {
    let inference = meta.inference;
    let request = inference / block;
    let step = inference % block;
    let st = reqs.entry(request).or_insert_with(|| DecodeReq::new(inference));
    if st.inference != inference {
        // next pass of this request (pass serialization guarantees the
        // previous one drained)
        debug_assert!(st.staged.is_empty() && st.pending.is_empty());
        st.inference = inference;
        st.base = st.len;
        st.got = 0;
        st.pass_rows = 0;
        st.queued = 0;
    }
    st.pass_rows = st.pass_rows.max(meta.rows);
    match meta.stream {
        1 => {
            if functional {
                if let Some(v) = row_i8(payload) {
                    st.staged.insert(meta.row, v);
                }
            }
            st.got += 1;
            st.done = st.done.max(at);
            if st.got == st.pass_rows {
                // cache block complete: append in position order, then
                // drain the compute rows buffered behind it
                let staged = std::mem::take(&mut st.staged);
                st.cache.extend(staged.into_values());
                st.len += st.pass_rows;
                let pending = std::mem::take(&mut st.pending);
                for (row, (arr, data)) in pending {
                    let ready = arr.max(st.done);
                    let attended = st.base + row + 1;
                    let (cycles, pl) = emit(&st.cache, attended, data.as_ref());
                    let meta2 =
                        MsgMeta { stream: stream_tag, row, rows: st.pass_rows, inference };
                    out.push(ready, cycles, meta2, pl);
                    st.queued += 1;
                }
            }
        }
        _ => {
            let data = if functional { row_i8(payload) } else { None };
            if st.pass_rows > 0 && st.got == st.pass_rows {
                let ready = at.max(st.done);
                let attended = st.base + meta.row + 1;
                let (cycles, pl) = emit(&st.cache, attended, data.as_ref());
                let meta2 =
                    MsgMeta { stream: stream_tag, row: meta.row, rows: st.pass_rows, inference };
                out.push(ready, cycles, meta2, pl);
                st.queued += 1;
            } else {
                st.pending.insert(meta.row, (at, data));
            }
        }
    }
    if st.pass_rows > 0 && st.queued == st.pass_rows && step + 1 == block {
        // final pass fully queued: the request's KV cache retires
        reqs.remove(&request);
    }
}

// ---------------------------------------------------------------------------
// Attention dot-product + softmax head kernel (Kern_4..15)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct AttnInf {
    m: u32,
    k_rows: BTreeMap<u32, Arc<Vec<i8>>>,
    k_got: u32,
    /// latest K-row arrival: the gate time once k_got == m
    k_done: u64,
    /// Q rows waiting for the K matrix: row -> (arrival, data)
    q_pending: BTreeMap<u32, (u64, Option<Arc<Vec<i8>>>)>,
    queued: u32,
}

/// One attention head: buffers K (stream 1), streams Q rows (stream 0)
/// into score rows, applies i-Softmax, emits int8 probability rows.
pub struct AttentionHeadKernel {
    pub head: usize,
    pub mode: Mode,
    pub pe: PeConfig,
    /// `Some(block)` = decode mode: per-request K caching, causal
    /// masking, inference ids blocked per request.
    pub decode: Option<u32>,
    out: OutStream,
    inf: HashMap<u32, AttnInf>,
    reqs: HashMap<u32, DecodeReq>,
}

impl AttentionHeadKernel {
    pub fn new(head: usize, out: Out, mode: Mode, pe: PeConfig) -> Self {
        AttentionHeadKernel {
            head,
            mode,
            pe,
            decode: None,
            out: OutStream::new(out, pe.pipe_fill),
            inf: HashMap::new(),
            reqs: HashMap::new(),
        }
    }

    /// Switch the head into decode mode with `block` inference ids per
    /// request (1 prefill + `block - 1` decode steps).
    pub fn with_decode(mut self, block: u32) -> Self {
        self.decode = Some(block);
        self
    }
}

fn attn_score_row(st: &AttnInf, q: &[i8], m: u32, p: &ModelParams) -> Payload {
    let scores: Vec<i32> = (0..m)
        .map(|c| {
            let krow = &st.k_rows[&c];
            let mut acc = 0i32;
            for (qq, kk) in q.iter().zip(krow.iter()) {
                acc += *qq as i32 * *kk as i32;
            }
            acc
        })
        .collect();
    Payload::row_i8(compute::softmax_row(&scores, p.eq.softmax))
}

impl KernelBehavior for AttentionHeadKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        if let Some(block) = self.decode {
            let AttentionHeadKernel { mode, pe, out, reqs, .. } = self;
            let pe = *pe;
            let d = mode.params().map(|p| p.cfg.head_dim()).unwrap_or(64);
            let stream_tag = out.out.stream.unwrap_or(0);
            let functional = mode.is_functional();
            let params = mode.params().cloned();
            io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
                io2.consume(payload.bytes());
                decode_on_row(
                    reqs,
                    block,
                    functional,
                    out,
                    stream_tag,
                    meta,
                    at,
                    payload,
                    &mut |cache, attended, data| {
                        let cycles = pe.attn_decode_row_cycles(attended as u64, d as u64);
                        let pl = match (&params, data) {
                            (Some(p), Some(q)) => {
                                let ks: Vec<&[i8]> = cache[..attended as usize]
                                    .iter()
                                    .map(|a| a.as_slice())
                                    .collect();
                                let scores = compute::causal_head_scores(q, &ks, 0, d);
                                Payload::row_i8(compute::softmax_row(&scores, p.eq.softmax))
                            }
                            _ => Payload::Timing(attended as usize),
                        };
                        (cycles, pl)
                    },
                );
            });
            self.out.pump(io);
            return;
        }
        let AttentionHeadKernel { mode, pe, out, inf, .. } = self;
        let pe = *pe;
        let d = mode.params().map(|p| p.cfg.head_dim()).unwrap_or(64) as u64;
        let stream_tag = out.out.stream.unwrap_or(0);
        io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
            io2.consume(payload.bytes());
            let inference = meta.inference;
            let st = inf.entry(inference).or_default();
            st.m = st.m.max(meta.rows);
            let m = st.m;
            let cycles = pe.attn_row_cycles(m as u64, d) + pe.softmax_row_cycles(m as u64);
            match meta.stream {
                1 => {
                    if mode.is_functional() {
                        if let Some(v) = row_i8(payload) {
                            st.k_rows.insert(meta.row, v);
                        }
                    }
                    st.k_got += 1;
                    st.k_done = st.k_done.max(at);
                    if st.k_got == m && m > 0 {
                        // drain Q rows buffered behind the K matrix, in
                        // row order, gated at the K completion time
                        let pending = std::mem::take(&mut st.q_pending);
                        for (row, (arr_q, data)) in pending {
                            let ready = arr_q.max(st.k_done);
                            let pl = match (mode.params(), data) {
                                (Some(p), Some(q)) => attn_score_row(st, &q, m, p),
                                _ => Payload::Timing(m as usize),
                            };
                            let meta2 =
                                MsgMeta { stream: stream_tag, row, rows: m, inference };
                            out.push(ready, cycles, meta2, pl);
                            st.queued += 1;
                        }
                    }
                }
                _ => {
                    let data = if mode.is_functional() { row_i8(payload) } else { None };
                    if st.k_got == m && m > 0 {
                        let ready = at.max(st.k_done);
                        let pl = match (mode.params(), data) {
                            (Some(p), Some(q)) => attn_score_row(st, &q, m, p),
                            _ => Payload::Timing(m as usize),
                        };
                        let meta2 = MsgMeta {
                            stream: stream_tag,
                            row: meta.row,
                            rows: m,
                            inference,
                        };
                        out.push(ready, cycles, meta2, pl);
                        st.queued += 1;
                    } else {
                        st.q_pending.insert(meta.row, (at, data));
                    }
                }
            }
            if st.m > 0 && st.queued == st.m {
                inf.remove(&inference);
            }
        });
        self.out.pump(io);
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            return;
        }
        self.out.on_wake(tag, io);
    }

    fn name(&self) -> String {
        format!("attn-head{}", self.head)
    }
}

// ---------------------------------------------------------------------------
// Softmax matrix-multiply + Quant head kernel (Kern_16..27)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct SmmInf {
    m: u32,
    v_rows: BTreeMap<u32, Arc<Vec<i8>>>,
    v_got: u32,
    v_done: u64,
    p_pending: BTreeMap<u32, (u64, Option<Arc<Vec<i8>>>)>,
    queued: u32,
}

/// One head of the Softmax Matrix Multiply (§7.1.3): prob rows (stream 0)
/// x buffered V slice (stream 1) -> requantised int8 attention segments.
pub struct SoftmaxMMKernel {
    pub head: usize,
    pub mode: Mode,
    pub pe: PeConfig,
    /// `Some(block)` = decode mode: per-request V caching (see
    /// [`AttentionHeadKernel::decode`]).
    pub decode: Option<u32>,
    out: OutStream,
    inf: HashMap<u32, SmmInf>,
    reqs: HashMap<u32, DecodeReq>,
}

impl SoftmaxMMKernel {
    pub fn new(head: usize, out: Out, mode: Mode, pe: PeConfig) -> Self {
        SoftmaxMMKernel {
            head,
            mode,
            pe,
            decode: None,
            out: OutStream::new(out, pe.pipe_fill),
            inf: HashMap::new(),
            reqs: HashMap::new(),
        }
    }

    /// Switch the head into decode mode with `block` inference ids per
    /// request.
    pub fn with_decode(mut self, block: u32) -> Self {
        self.decode = Some(block);
        self
    }
}

fn smm_row(st: &SmmInf, probs: &[i8], m: u32, p: &ModelParams) -> Payload {
    let d = p.cfg.head_dim();
    let mut seg = vec![0i8; d];
    for (j, s) in seg.iter_mut().enumerate() {
        let mut acc = 0i32;
        for c in 0..m {
            acc += probs[c as usize] as i32 * st.v_rows[&c][j] as i32;
        }
        *s = compute::requant8(acc as i64, p.eq.rq_att);
    }
    Payload::row_i8(seg)
}

impl KernelBehavior for SoftmaxMMKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        if let Some(block) = self.decode {
            let SoftmaxMMKernel { head, mode, pe, out, reqs, .. } = self;
            let pe = *pe;
            let d = mode.params().map(|p| p.cfg.head_dim()).unwrap_or(64);
            let stream_tag = out.out.stream.unwrap_or(*head as u8);
            let functional = mode.is_functional();
            let params = mode.params().cloned();
            io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
                io2.consume(payload.bytes());
                decode_on_row(
                    reqs,
                    block,
                    functional,
                    out,
                    stream_tag,
                    meta,
                    at,
                    payload,
                    &mut |cache, attended, data| {
                        let cycles = pe.smm_decode_row_cycles(attended as u64, d as u64);
                        let pl = match (&params, data) {
                            (Some(p), Some(pr)) => {
                                let vs: Vec<&[i8]> = cache[..attended as usize]
                                    .iter()
                                    .map(|a| a.as_slice())
                                    .collect();
                                Payload::row_i8(compute::head_context_row(
                                    pr, &vs, 0, d, p.eq.rq_att,
                                ))
                            }
                            _ => Payload::Timing(d),
                        };
                        (cycles, pl)
                    },
                );
            });
            self.out.pump(io);
            return;
        }
        let SoftmaxMMKernel { head, mode, pe, out, inf, .. } = self;
        let pe = *pe;
        let d = mode.params().map(|p| p.cfg.head_dim()).unwrap_or(64) as u64;
        let default_stream = *head as u8;
        let stream_tag = out.out.stream.unwrap_or(default_stream);
        io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
            io2.consume(payload.bytes());
            let inference = meta.inference;
            let st = inf.entry(inference).or_default();
            st.m = st.m.max(meta.rows);
            let m = st.m;
            let cycles = pe.smm_row_cycles(m as u64, d);
            match meta.stream {
                1 => {
                    if mode.is_functional() {
                        if let Some(v) = row_i8(payload) {
                            st.v_rows.insert(meta.row, v);
                        }
                    }
                    st.v_got += 1;
                    st.v_done = st.v_done.max(at);
                    if st.v_got == m && m > 0 {
                        let pending = std::mem::take(&mut st.p_pending);
                        for (row, (arr_p, data)) in pending {
                            let ready = arr_p.max(st.v_done);
                            let pl = match (mode.params(), data) {
                                (Some(p), Some(pr)) => smm_row(st, &pr, m, p),
                                _ => Payload::Timing(64),
                            };
                            let meta2 =
                                MsgMeta { stream: stream_tag, row, rows: m, inference };
                            out.push(ready, cycles, meta2, pl);
                            st.queued += 1;
                        }
                    }
                }
                _ => {
                    let data = if mode.is_functional() { row_i8(payload) } else { None };
                    if st.v_got == m && m > 0 {
                        let ready = at.max(st.v_done);
                        let pl = match (mode.params(), data) {
                            (Some(p), Some(pr)) => smm_row(st, &pr, m, p),
                            _ => Payload::Timing(64),
                        };
                        let meta2 = MsgMeta {
                            stream: stream_tag,
                            row: meta.row,
                            rows: m,
                            inference,
                        };
                        out.push(ready, cycles, meta2, pl);
                        st.queued += 1;
                    } else {
                        st.p_pending.insert(meta.row, (at, data));
                    }
                }
            }
            if st.m > 0 && st.queued == st.m {
                inf.remove(&inference);
            }
        });
        self.out.pump(io);
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            return;
        }
        self.out.on_wake(tag, io);
    }

    fn name(&self) -> String {
        format!("smm-head{}", self.head)
    }
}

// ---------------------------------------------------------------------------
// LayerNorm (+ residual requant-add) kernel (Kern_29, 32)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LnWhich {
    Ln1,
    Ln2,
}

#[derive(Default)]
struct LnInf {
    main: BTreeMap<u32, (u64, Option<Arc<Vec<i32>>>)>,
    resid: BTreeMap<u32, (u64, Option<Arc<Vec<i8>>>)>,
    /// wire bytes still sitting in the input FIFO per row (the residual
    /// matrix genuinely occupies the FIFO until the attention path
    /// catches up — the paper's §8.2.1 sizing rule)
    fifo_bytes: BTreeMap<u32, usize>,
    queued: u32,
    rows: u32,
}

fn ln_row(which: LnWhich, p: &ModelParams, main: &[i32], resid: &[i8]) -> Payload {
    let eq = &p.eq;
    let (site, gamma, beta, ln) = match which {
        LnWhich::Ln1 => (eq.rq_resin, &p.ln1_gamma, &p.ln1_beta, eq.ln1),
        LnWhich::Ln2 => (eq.rq_res2in, &p.ln2_gamma, &p.ln2_beta, eq.ln2),
    };
    let wide: Vec<i64> = main
        .iter()
        .zip(resid.iter())
        .map(|(&mv, &rv)| mv as i64 + compute::requant32(rv as i64, site))
        .collect();
    Payload::row_i8(compute::layernorm_row(&wide, gamma, beta, ln))
}

/// Add & Norm: wide rows (stream 0) + int8 residual rows (stream 1) ->
/// requant-add -> i-LayerNorm -> int8 rows.
pub struct LayerNormKernel {
    pub which: LnWhich,
    pub mode: Mode,
    pub pe: PeConfig,
    out: OutStream,
    inf: HashMap<u32, LnInf>,
}

impl LayerNormKernel {
    pub fn new(which: LnWhich, out: Out, mode: Mode, pe: PeConfig) -> Self {
        LayerNormKernel {
            which,
            mode,
            pe,
            out: OutStream::new(out, pe.pipe_fill),
            inf: HashMap::new(),
        }
    }
}

impl KernelBehavior for LayerNormKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        let LayerNormKernel { which, mode, pe, out, inf } = self;
        let (which, pe) = (*which, *pe);
        let h = mode.params().map(|p| p.cfg.hidden).unwrap_or(768);
        let cycles = pe.ln_row_cycles(h as u64);
        let stream_tag = out.out.stream.unwrap_or(0);
        io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
            // NOT consumed yet: rows wait in the input FIFO until both the
            // wide row and its residual partner arrive
            let inference = meta.inference;
            let row = meta.row;
            let functional = mode.is_functional();
            let st = inf.entry(inference).or_default();
            st.rows = st.rows.max(meta.rows);
            *st.fifo_bytes.entry(row).or_insert(0) += payload.bytes();
            match meta.stream {
                1 => {
                    let data = if functional { row_i8(payload) } else { None };
                    st.resid.insert(row, (at, data));
                }
                _ => {
                    let data = if functional {
                        match payload {
                            Payload::RowI32(v) => Some(v),
                            _ => None,
                        }
                    } else {
                        None
                    };
                    st.main.insert(row, (at, data));
                }
            }
            if st.main.contains_key(&row) && st.resid.contains_key(&row) {
                let (arr_m, main) = st.main.remove(&row).unwrap();
                let (arr_r, resid) = st.resid.remove(&row).unwrap();
                // both rows leave the input FIFO now
                io2.consume(st.fifo_bytes.remove(&row).unwrap_or(0));
                let ready = arr_m.max(arr_r);
                let pl = match (mode.params(), main, resid) {
                    (Some(p), Some(mn), Some(rs)) => ln_row(which, p, &mn, &rs),
                    _ => Payload::Timing(h),
                };
                let meta2 =
                    MsgMeta { stream: stream_tag, row, rows: st.rows, inference };
                out.push(ready, cycles, meta2, pl);
                st.queued += 1;
                if st.queued == st.rows {
                    inf.remove(&inference);
                }
            }
        });
        self.out.pump(io);
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            return;
        }
        self.out.on_wake(tag, io);
    }

    fn name(&self) -> String {
        format!("layernorm-{:?}", self.which)
    }
}

// ---------------------------------------------------------------------------
// Evaluation FPGA: source + sink (§8.2)
// ---------------------------------------------------------------------------

/// The evaluation FPGA's generator: streams input rows at a configurable
/// packet interval, emulating the previous encoder in the chain.
pub struct SourceKernel {
    pub dst: Out,
    pub m: u32,
    pub inferences: u32,
    /// cycles between consecutive row packets (the paper sweeps this: 12 =
    /// line rate, then the measured I).
    pub interval: u64,
    /// extra cycles between inferences.
    pub gap: u64,
    pub data: Option<Arc<Vec<Vec<i8>>>>,
    /// row size for Timing payloads (default 768 = one hidden row)
    pub row_bytes: usize,
    /// cycles to hold before the first row (per-chain arrival phase in
    /// fleet scenarios — replicated chains must not emit in lockstep)
    start_offset: u64,
    sent_inf: u32,
    sent_row: u32,
}

impl SourceKernel {
    pub fn new(dst: Out, m: u32, inferences: u32, interval: u64, data: Option<Arc<Vec<Vec<i8>>>>) -> Self {
        SourceKernel {
            dst,
            m,
            inferences,
            interval,
            gap: 0,
            data,
            row_bytes: 768,
            start_offset: 0,
            sent_inf: 0,
            sent_row: 0,
        }
    }

    pub fn with_row_bytes(mut self, bytes: usize) -> Self {
        self.row_bytes = bytes;
        self
    }

    /// Delay the first emitted row by `cycles` (arrival phase).
    pub fn with_start_offset(mut self, cycles: u64) -> Self {
        self.start_offset = cycles;
        self
    }
}

impl KernelBehavior for SourceKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
    }

    fn on_wake(&mut self, _tag: u64, io: &mut KernelIo) {
        if self.sent_inf >= self.inferences {
            return;
        }
        if self.start_offset > 0 {
            let hold = self.start_offset;
            self.start_offset = 0;
            io.wake_in(hold, 1);
            return;
        }
        let payload = match &self.data {
            Some(d) => Payload::row_i8(d[self.sent_row as usize].clone()),
            None => Payload::Timing(self.row_bytes),
        };
        let meta = MsgMeta {
            stream: self.dst.stream.unwrap_or(0),
            row: self.sent_row,
            rows: self.m,
            inference: self.sent_inf,
        };
        io.send(self.dst.dst, meta, payload);
        self.sent_row += 1;
        let mut delay = self.interval;
        if self.sent_row == self.m {
            self.sent_row = 0;
            self.sent_inf += 1;
            delay += self.gap;
        }
        if self.sent_inf < self.inferences {
            io.wake_in(delay, 1);
        }
    }

    fn name(&self) -> String {
        "eval-source".to_string()
    }
}

/// Collected sink output, shared with the harness.
#[derive(Debug, Default)]
pub struct SinkData {
    /// inference -> collected rows
    pub rows: HashMap<u32, BTreeMap<u32, Vec<i8>>>,
    pub packets: u64,
    /// inference -> (packets received, time of last arrival) — works in
    /// Timing mode too (drives the throughput measurements of Fig. 20)
    pub arrivals: HashMap<u32, (u32, u64)>,
    /// inference -> time of FIRST arrival: the prefill TTFT signal of
    /// the multi-tenant serving report (first output row at the sink)
    pub first: HashMap<u32, u64>,
}

impl SinkData {
    /// Assemble inference `i` as a dense matrix if complete.
    pub fn matrix(&self, inference: u32) -> Option<Vec<Vec<i8>>> {
        let rows = self.rows.get(&inference)?;
        let m = rows.values().len();
        let expect = *rows.keys().max()? as usize + 1;
        if m != expect {
            return None;
        }
        Some(rows.values().cloned().collect())
    }
}

/// The evaluation FPGA's receiver: add as a probe to measure X/T/I.
pub struct SinkKernel {
    pub data: Arc<Mutex<SinkData>>,
}

impl SinkKernel {
    pub fn new() -> (Self, Arc<Mutex<SinkData>>) {
        let data = Arc::new(Mutex::new(SinkData::default()));
        (SinkKernel { data: data.clone() }, data)
    }
}

impl KernelBehavior for SinkKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        let data = self.data.clone();
        io.rows(pkt, |io2: &mut KernelIo, meta, at, payload| {
            io2.consume(payload.bytes());
            let mut d = data.lock().unwrap();
            d.packets += 1;
            let a = d.arrivals.entry(meta.inference).or_insert((0, 0));
            a.0 += 1;
            a.1 = a.1.max(at);
            d.first.entry(meta.inference).and_modify(|t| *t = (*t).min(at)).or_insert(at);
            if let Payload::RowI8(v) = payload {
                let row = Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone());
                d.rows.entry(meta.inference).or_default().insert(meta.row, row);
            }
        });
    }

    fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}

    fn name(&self) -> String {
        "eval-sink".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pacer_enforces_initiation_interval() {
        let mut p = EmitPacer::default();
        // first row pays fill + ii
        assert_eq!(p.schedule(100, 10, 50), 160);
        // back-to-back rows emit ii apart (fill amortised)
        assert_eq!(p.schedule(100, 10, 50), 210);
        assert_eq!(p.schedule(101, 10, 50), 260);
        // idle gap: next row pays fill again
        assert_eq!(p.schedule(900, 10, 50), 960);
    }

    #[test]
    fn decode_rows_attend_causally_and_state_retires() {
        use crate::sim::packet::GlobalKernelId;
        let mut reqs: HashMap<u32, DecodeReq> = HashMap::new();
        let mut out = OutStream::new(Out::tagged(GlobalKernelId::new(0, 9), 0), 0);
        let block = 2; // prefill + 1 decode step per request
        let mut seen: Vec<u32> = Vec::new();
        let mut emit = |_cache: &[Arc<Vec<i8>>], attended: u32, _d: Option<&Arc<Vec<i8>>>| {
            seen.push(attended);
            (10u64, Payload::Timing(attended as usize))
        };
        // prefill (inference 0, request 0): K rows land, then Q rows
        for row in 0..2u32 {
            let meta = MsgMeta { stream: 1, row, rows: 2, inference: 0 };
            decode_on_row(
                &mut reqs, block, false, &mut out, 0, meta, 100 + row as u64,
                Payload::Timing(64), &mut emit,
            );
        }
        for row in 0..2u32 {
            let meta = MsgMeta { stream: 0, row, rows: 2, inference: 0 };
            decode_on_row(
                &mut reqs, block, false, &mut out, 0, meta, 200 + row as u64,
                Payload::Timing(64), &mut emit,
            );
        }
        assert_eq!(reqs[&0].len, 2, "prefill cached both positions");
        assert_eq!(reqs[&0].queued, 2);
        // decode step (inference 1): one cache row + one query row, and
        // the final pass retires the request state
        let meta = MsgMeta { stream: 1, row: 0, rows: 1, inference: 1 };
        decode_on_row(
            &mut reqs, block, false, &mut out, 0, meta, 300, Payload::Timing(64), &mut emit,
        );
        let meta = MsgMeta { stream: 0, row: 0, rows: 1, inference: 1 };
        decode_on_row(
            &mut reqs, block, false, &mut out, 0, meta, 301, Payload::Timing(64), &mut emit,
        );
        assert!(reqs.is_empty(), "KV cache retires after the final pass");
        // causal attended lengths: prefill rows see 1 then 2 positions,
        // the decode step sees all 3
        assert_eq!(seen, vec![1, 2, 3]);
    }

    #[test]
    fn batched_linear_costs_derive_from_the_pe_model() {
        use crate::sim::packet::GlobalKernelId;
        let pe = PeConfig::default();
        let mk = |which| {
            LinearKernel::new(which, Out::tagged(GlobalKernelId::new(0, 9), 0), Mode::Timing, &pe)
                .with_batched(&pe)
        };
        // every linear stage: 768-cycle weight pass, 384-cycle marginal
        for which in [
            LinearWhich::Q,
            LinearWhich::K,
            LinearWhich::V,
            LinearWhich::Proj,
            LinearWhich::Ffn1,
            LinearWhich::Ffn2,
        ] {
            let k = mk(which);
            assert_eq!(k.batched, Some((768, 384)), "{which:?}");
            assert_eq!(k.row_cycles, 768, "{which:?}: prefill rows keep the calibrated ii");
        }
        // without the builder the kernel stays on the legacy path
        let plain =
            LinearKernel::new(LinearWhich::Q, Out::tagged(GlobalKernelId::new(0, 9), 0), Mode::Timing, &pe);
        assert_eq!(plain.batched, None);
    }

    #[test]
    fn sink_matrix_assembly() {
        let (_k, data) = SinkKernel::new();
        {
            let mut d = data.lock().unwrap();
            d.rows.entry(0).or_default().insert(1, vec![2]);
            assert!(d.matrix(0).is_none()); // row 0 missing
            d.rows.entry(0).or_default().insert(0, vec![1]);
        }
        let m = data.lock().unwrap().matrix(0).unwrap();
        assert_eq!(m, vec![vec![1], vec![2]]);
    }
}
