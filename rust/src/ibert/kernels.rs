//! Streaming kernel behaviors of the I-BERT encoder (§7.1, Fig. 14).
//!
//! Each kernel is the HLS module of the paper as a discrete-event actor:
//! rows stream in, a PE/tile timing model (timing.rs) paces the output,
//! and in Functional mode the emitted rows carry real integers computed
//! with the bit-exact operators of compute.rs — so a simulated six-FPGA
//! cluster produces the same bytes as the JAX reference.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::sim::engine::{KernelBehavior, KernelIo, START_TAG};
use crate::sim::packet::{MsgMeta, Packet, Payload};

use super::compute;
use super::timing::PeConfig;
use super::weights::ModelParams;
use crate::gmi::Out;

/// Simulation mode: pure timing (Timing payloads) or functional
/// (real integer rows, bit-exact vs the reference).
#[derive(Clone)]
pub enum Mode {
    Timing,
    Functional(Arc<ModelParams>),
}

impl Mode {
    pub fn is_functional(&self) -> bool {
        matches!(self, Mode::Functional(_))
    }
    fn params(&self) -> Option<&Arc<ModelParams>> {
        match self {
            Mode::Functional(p) => Some(p),
            Mode::Timing => None,
        }
    }
}

#[inline]
fn tag_of(inference: u32, row: u32) -> u64 {
    ((inference as u64) << 32) | row as u64
}
#[inline]
fn untag(t: u64) -> (u32, u32) {
    ((t >> 32) as u32, t as u32)
}

/// Serialize row emissions: a pipelined unit with a one-time fill depth
/// and a per-row initiation interval. A row arriving at `now` emits at
/// max(now + fill + ii, last_emit + ii) — steady-state output interval is
/// exactly `ii` (the paper's measured I = 767 for the 768-wide linears).
#[derive(Debug, Default, Clone, Copy)]
struct EmitPacer {
    last_emit: Option<u64>,
}

impl EmitPacer {
    fn schedule(&mut self, now: u64, fill: u64, ii: u64) -> u64 {
        let emit = (now + fill + ii).max(self.last_emit.map_or(0, |e| e + ii));
        self.last_emit = Some(emit);
        emit
    }
}

fn row_i8(p: Payload) -> Option<Vec<i8>> {
    match p {
        Payload::RowI8(v) => Some(v),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Linear kernels (Kern_1..3, 28, 30, 31)
// ---------------------------------------------------------------------------

/// Which linear module this kernel instantiates; selects weights, the
/// requantiser, the fused post-op, and the output payload kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearWhich {
    Q,
    K,
    V,
    /// attention output projection; emits wide rows for the residual add
    Proj,
    /// FFN first linear with fused i-GELU (Kern_30)
    Ffn1,
    /// FFN second linear; emits wide rows (Kern_31)
    Ffn2,
}

/// Linear (+Quant / +GELU) kernel: consumes one int8 row, emits one row.
pub struct LinearKernel {
    pub which: LinearWhich,
    pub out: Out,
    pub mode: Mode,
    pub row_cycles: u64,
    pub fill: u64,
    pacer: EmitPacer,
    pending: HashMap<u64, (MsgMeta, Option<Vec<i8>>)>,
}

impl LinearKernel {
    pub fn new(which: LinearWhich, out: Out, mode: Mode, pe: &PeConfig) -> Self {
        let (h, f) = match mode.params() {
            Some(p) => (p.cfg.hidden as u64, p.cfg.ffn as u64),
            None => (768, 3072),
        };
        let row_cycles = match which {
            LinearWhich::Q | LinearWhich::K | LinearWhich::V | LinearWhich::Proj => {
                pe.qkv_row_cycles(h)
            }
            LinearWhich::Ffn1 => pe.ffn1_row_cycles(h, f),
            LinearWhich::Ffn2 => pe.ffn2_row_cycles(h, f),
        };
        LinearKernel {
            which,
            out,
            mode,
            row_cycles,
            fill: pe.pipe_fill,
            pacer: EmitPacer::default(),
            pending: HashMap::new(),
        }
    }

    fn out_bytes(&self, p: &ModelParamsDims) -> usize {
        match self.which {
            LinearWhich::Q | LinearWhich::K | LinearWhich::V => p.hidden,
            LinearWhich::Proj | LinearWhich::Ffn2 => 4 * p.hidden,
            LinearWhich::Ffn1 => p.ffn,
        }
    }

    fn compute_row(&self, p: &ModelParams, x: &[i8]) -> Payload {
        let (h, f) = (p.cfg.hidden, p.cfg.ffn);
        let eq = &p.eq;
        match self.which {
            LinearWhich::Q => Payload::RowI8(
                compute::linear_row(x, &p.wq.data, h, h, &p.bq)
                    .into_iter()
                    .map(|a| compute::requant8(a as i64, eq.rq_q))
                    .collect(),
            ),
            LinearWhich::K => Payload::RowI8(
                compute::linear_row(x, &p.wk.data, h, h, &p.bk)
                    .into_iter()
                    .map(|a| compute::requant8(a as i64, eq.rq_k))
                    .collect(),
            ),
            LinearWhich::V => Payload::RowI8(
                compute::linear_row(x, &p.wv.data, h, h, &p.bv)
                    .into_iter()
                    .map(|a| compute::requant8(a as i64, eq.rq_v))
                    .collect(),
            ),
            LinearWhich::Proj => Payload::RowI32(
                compute::linear_row(x, &p.wo.data, h, h, &p.bo)
                    .into_iter()
                    .map(|a| compute::requant32(a as i64, eq.rq_proj) as i32)
                    .collect(),
            ),
            LinearWhich::Ffn1 => Payload::RowI8(
                compute::linear_row(x, &p.w1.data, h, f, &p.b1)
                    .into_iter()
                    .map(|a| compute::gelu_i8(compute::requant8(a as i64, eq.rq_gelu_in), eq.gelu))
                    .collect(),
            ),
            LinearWhich::Ffn2 => Payload::RowI32(
                compute::linear_row(x, &p.w2.data, f, h, &p.b2)
                    .into_iter()
                    .map(|a| compute::requant32(a as i64, eq.rq_ffn2) as i32)
                    .collect(),
            ),
        }
    }
}

struct ModelParamsDims {
    hidden: usize,
    ffn: usize,
}

impl KernelBehavior for LinearKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
        let t = tag_of(pkt.meta.inference, pkt.meta.row);
        let data = if self.mode.is_functional() { row_i8(pkt.payload) } else { None };
        self.pending.insert(t, (pkt.meta, data));
        let emit_at = self.pacer.schedule(io.now, self.fill, self.row_cycles);
        io.wake_in(emit_at - io.now, t);
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            return;
        }
        let Some((meta, data)) = self.pending.remove(&tag) else { return };
        let dims = match self.mode.params() {
            Some(p) => ModelParamsDims { hidden: p.cfg.hidden, ffn: p.cfg.ffn },
            None => ModelParamsDims { hidden: 768, ffn: 3072 },
        };
        let payload = match (&self.mode, data) {
            (Mode::Functional(p), Some(x)) => self.compute_row(p, &x),
            _ => Payload::Timing(self.out_bytes(&dims)),
        };
        let meta = MsgMeta { stream: self.out.stream.unwrap_or(0), ..meta };
        io.send(self.out.dst, meta, payload);
    }

    fn name(&self) -> String {
        format!("linear-{:?}", self.which)
    }
}

// ---------------------------------------------------------------------------
// Attention dot-product + softmax head kernel (Kern_4..15)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct AttnInf {
    m: u32,
    k_rows: BTreeMap<u32, Vec<i8>>,
    k_got: u32,
    q_pending: BTreeMap<u32, Option<Vec<i8>>>,
    emitted: u32,
}

/// One attention head: buffers K (stream 1), streams Q rows (stream 0)
/// into score rows, applies i-Softmax, emits int8 probability rows.
pub struct AttentionHeadKernel {
    pub head: usize,
    pub out: Out,
    pub mode: Mode,
    pub pe: PeConfig,
    pacer: EmitPacer,
    inf: HashMap<u32, AttnInf>,
}

impl AttentionHeadKernel {
    pub fn new(head: usize, out: Out, mode: Mode, pe: PeConfig) -> Self {
        AttentionHeadKernel { head, out, mode, pe, pacer: EmitPacer::default(), inf: HashMap::new() }
    }

    fn drain_ready(&mut self, inference: u32, io: &mut KernelIo) {
        let d = self.mode.params().map(|p| p.cfg.head_dim()).unwrap_or(64) as u64;
        let Some(st) = self.inf.get_mut(&inference) else { return };
        if st.m == 0 || st.k_got < st.m {
            return;
        }
        let m = st.m as u64;
        let cycles = self.pe.attn_row_cycles(m, d) + self.pe.softmax_row_cycles(m);
        let fill = self.pe.pipe_fill;
        let rows: Vec<u32> = st.q_pending.keys().copied().collect();
        for r in rows {
            let emit_at = self.pacer.schedule(io.now, fill, cycles);
            io.wake_in(emit_at - io.now, tag_of(inference, r));
        }
    }
}

impl KernelBehavior for AttentionHeadKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
        let inference = pkt.meta.inference;
        let functional = self.mode.is_functional();
        {
            let st = self.inf.entry(inference).or_default();
            st.m = st.m.max(pkt.meta.rows);
            match pkt.meta.stream {
                1 => {
                    if functional {
                        if let Payload::RowI8(v) = pkt.payload {
                            st.k_rows.insert(pkt.meta.row, v);
                        }
                    }
                    st.k_got += 1;
                    if st.k_got == st.m {
                        self.drain_ready(inference, io);
                    }
                }
                _ => {
                    let data = if functional { row_i8(pkt.payload) } else { None };
                    let d = self.mode.params().map(|p| p.cfg.head_dim()).unwrap_or(64) as u64;
                    let st = self.inf.get_mut(&inference).unwrap();
                    st.q_pending.insert(pkt.meta.row, data);
                    if st.k_got == st.m && st.m > 0 {
                        // schedule just this row
                        let m = st.m as u64;
                        let cycles =
                            self.pe.attn_row_cycles(m, d) + self.pe.softmax_row_cycles(m);
                        let emit_at = self.pacer.schedule(io.now, self.pe.pipe_fill, cycles);
                        io.wake_in(emit_at - io.now, tag_of(inference, pkt.meta.row));
                    }
                }
            }
        }
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            return;
        }
        let (inference, row) = untag(tag);
        let Some(st) = self.inf.get_mut(&inference) else { return };
        let Some(q) = st.q_pending.remove(&row) else { return };
        let m = st.m;
        let payload = match (&self.mode, q) {
            (Mode::Functional(p), Some(qrow)) => {
                let scores: Vec<i32> = (0..m)
                    .map(|c| {
                        let krow = &st.k_rows[&c];
                        let mut acc = 0i32;
                        for (qq, kk) in qrow.iter().zip(krow) {
                            acc += *qq as i32 * *kk as i32;
                        }
                        acc
                    })
                    .collect();
                Payload::RowI8(compute::softmax_row(&scores, p.eq.softmax))
            }
            _ => Payload::Timing(m as usize),
        };
        st.emitted += 1;
        let done = st.emitted == m;
        let meta = MsgMeta {
            stream: self.out.stream.unwrap_or(0),
            row,
            rows: m,
            inference,
        };
        io.send(self.out.dst, meta, payload);
        if done {
            self.inf.remove(&inference);
        }
    }

    fn name(&self) -> String {
        format!("attn-head{}", self.head)
    }
}

// ---------------------------------------------------------------------------
// Softmax matrix-multiply + Quant head kernel (Kern_16..27)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct SmmInf {
    m: u32,
    v_rows: BTreeMap<u32, Vec<i8>>,
    v_got: u32,
    p_pending: BTreeMap<u32, Option<Vec<i8>>>,
    emitted: u32,
}

/// One head of the Softmax Matrix Multiply (§7.1.3): prob rows (stream 0)
/// x buffered V slice (stream 1) -> requantised int8 attention segments.
pub struct SoftmaxMMKernel {
    pub head: usize,
    pub out: Out,
    pub mode: Mode,
    pub pe: PeConfig,
    pacer: EmitPacer,
    inf: HashMap<u32, SmmInf>,
}

impl SoftmaxMMKernel {
    pub fn new(head: usize, out: Out, mode: Mode, pe: PeConfig) -> Self {
        SoftmaxMMKernel { head, out, mode, pe, pacer: EmitPacer::default(), inf: HashMap::new() }
    }

    fn schedule_row(&mut self, inference: u32, row: u32, m: u64, io: &mut KernelIo) {
        let d = self.mode.params().map(|p| p.cfg.head_dim()).unwrap_or(64) as u64;
        let cycles = self.pe.smm_row_cycles(m, d);
        let emit_at = self.pacer.schedule(io.now, self.pe.pipe_fill, cycles);
        io.wake_in(emit_at - io.now, tag_of(inference, row));
    }
}

impl KernelBehavior for SoftmaxMMKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
        let inference = pkt.meta.inference;
        let functional = self.mode.is_functional();
        let st = self.inf.entry(inference).or_default();
        st.m = st.m.max(pkt.meta.rows);
        match pkt.meta.stream {
            1 => {
                if functional {
                    if let Payload::RowI8(v) = pkt.payload {
                        st.v_rows.insert(pkt.meta.row, v);
                    }
                }
                st.v_got += 1;
                if st.v_got == st.m {
                    let m = st.m as u64;
                    let rows: Vec<u32> = st.p_pending.keys().copied().collect();
                    for r in rows {
                        self.schedule_row(inference, r, m, io);
                    }
                }
            }
            _ => {
                let data = if functional { row_i8(pkt.payload) } else { None };
                st.p_pending.insert(pkt.meta.row, data);
                let (m, ready) = (st.m as u64, st.v_got == st.m && st.m > 0);
                if ready {
                    self.schedule_row(inference, pkt.meta.row, m, io);
                }
            }
        }
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            return;
        }
        let (inference, row) = untag(tag);
        let Some(st) = self.inf.get_mut(&inference) else { return };
        let Some(probs) = st.p_pending.remove(&row) else { return };
        let m = st.m;
        let payload = match (&self.mode, probs) {
            (Mode::Functional(p), Some(prow)) => {
                let d = p.cfg.head_dim();
                let mut seg = vec![0i8; d];
                for (j, s) in seg.iter_mut().enumerate() {
                    let mut acc = 0i32;
                    for c in 0..m {
                        acc += prow[c as usize] as i32 * st.v_rows[&c][j] as i32;
                    }
                    *s = compute::requant8(acc as i64, p.eq.rq_att);
                }
                Payload::RowI8(seg)
            }
            _ => Payload::Timing(64),
        };
        st.emitted += 1;
        let done = st.emitted == m;
        let meta = MsgMeta {
            stream: self.out.stream.unwrap_or(self.head as u8),
            row,
            rows: m,
            inference,
        };
        io.send(self.out.dst, meta, payload);
        if done {
            self.inf.remove(&inference);
        }
    }

    fn name(&self) -> String {
        format!("smm-head{}", self.head)
    }
}

// ---------------------------------------------------------------------------
// LayerNorm (+ residual requant-add) kernel (Kern_29, 32)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LnWhich {
    Ln1,
    Ln2,
}

#[derive(Default)]
struct LnInf {
    main: BTreeMap<u32, Option<Vec<i32>>>,
    resid: BTreeMap<u32, Option<Vec<i8>>>,
    /// wire bytes still sitting in the input FIFO per row (the residual
    /// matrix genuinely occupies the FIFO until the attention path
    /// catches up — the paper's §8.2.1 sizing rule)
    fifo_bytes: BTreeMap<u32, usize>,
    emitted: u32,
    rows: u32,
}

/// Add & Norm: wide rows (stream 0) + int8 residual rows (stream 1) ->
/// requant-add -> i-LayerNorm -> int8 rows.
pub struct LayerNormKernel {
    pub which: LnWhich,
    pub out: Out,
    pub mode: Mode,
    pub pe: PeConfig,
    pacer: EmitPacer,
    inf: HashMap<u32, LnInf>,
}

impl LayerNormKernel {
    pub fn new(which: LnWhich, out: Out, mode: Mode, pe: PeConfig) -> Self {
        LayerNormKernel { which, out, mode, pe, pacer: EmitPacer::default(), inf: HashMap::new() }
    }
}

impl KernelBehavior for LayerNormKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        // NOT consumed yet: rows wait in the input FIFO until both the
        // wide row and its residual partner arrive (consume on emission)
        let _ = &io;
        let inference = pkt.meta.inference;
        let row = pkt.meta.row;
        let functional = self.mode.is_functional();
        let st = self.inf.entry(inference).or_default();
        st.rows = st.rows.max(pkt.meta.rows);
        *st.fifo_bytes.entry(row).or_insert(0) += pkt.wire_bytes();
        match pkt.meta.stream {
            1 => {
                let data = if functional {
                    match pkt.payload {
                        Payload::RowI8(v) => Some(v),
                        _ => None,
                    }
                } else {
                    None
                };
                st.resid.insert(row, data);
            }
            _ => {
                let data = if functional {
                    match pkt.payload {
                        Payload::RowI32(v) => Some(v),
                        _ => None,
                    }
                } else {
                    None
                };
                st.main.insert(row, data);
            }
        }
        if st.main.contains_key(&row) && st.resid.contains_key(&row) {
            let h = self.mode.params().map(|p| p.cfg.hidden).unwrap_or(768) as u64;
            let cycles = self.pe.ln_row_cycles(h);
            let emit_at = self.pacer.schedule(io.now, self.pe.pipe_fill, cycles);
            io.wake_in(emit_at - io.now, tag_of(inference, row));
        }
    }

    fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
        if tag == START_TAG {
            return;
        }
        let (inference, row) = untag(tag);
        let Some(st) = self.inf.get_mut(&inference) else { return };
        let (Some(main), Some(resid)) = (st.main.remove(&row), st.resid.remove(&row)) else {
            return;
        };
        // both rows leave the input FIFO now
        io.consume(st.fifo_bytes.remove(&row).unwrap_or(0));
        let payload = match (&self.mode, main, resid) {
            (Mode::Functional(p), Some(main), Some(resid)) => {
                let eq = &p.eq;
                let (site, gamma, beta, ln) = match self.which {
                    LnWhich::Ln1 => (eq.rq_resin, &p.ln1_gamma, &p.ln1_beta, eq.ln1),
                    LnWhich::Ln2 => (eq.rq_res2in, &p.ln2_gamma, &p.ln2_beta, eq.ln2),
                };
                let wide: Vec<i64> = main
                    .iter()
                    .zip(&resid)
                    .map(|(&mv, &rv)| mv as i64 + compute::requant32(rv as i64, site))
                    .collect();
                Payload::RowI8(compute::layernorm_row(&wide, gamma, beta, ln))
            }
            _ => Payload::Timing(self.mode.params().map(|p| p.cfg.hidden).unwrap_or(768)),
        };
        st.emitted += 1;
        let done = st.emitted == st.rows;
        let meta = MsgMeta {
            stream: self.out.stream.unwrap_or(0),
            row,
            rows: st.rows,
            inference,
        };
        io.send(self.out.dst, meta, payload);
        if done {
            self.inf.remove(&inference);
        }
    }

    fn name(&self) -> String {
        format!("layernorm-{:?}", self.which)
    }
}

// ---------------------------------------------------------------------------
// Evaluation FPGA: source + sink (§8.2)
// ---------------------------------------------------------------------------

/// The evaluation FPGA's generator: streams input rows at a configurable
/// packet interval, emulating the previous encoder in the chain.
pub struct SourceKernel {
    pub dst: Out,
    pub m: u32,
    pub inferences: u32,
    /// cycles between consecutive row packets (the paper sweeps this: 12 =
    /// line rate, then the measured I).
    pub interval: u64,
    /// extra cycles between inferences.
    pub gap: u64,
    pub data: Option<Arc<Vec<Vec<i8>>>>,
    /// row size for Timing payloads (default 768 = one hidden row)
    pub row_bytes: usize,
    sent_inf: u32,
    sent_row: u32,
}

impl SourceKernel {
    pub fn new(dst: Out, m: u32, inferences: u32, interval: u64, data: Option<Arc<Vec<Vec<i8>>>>) -> Self {
        SourceKernel {
            dst,
            m,
            inferences,
            interval,
            gap: 0,
            data,
            row_bytes: 768,
            sent_inf: 0,
            sent_row: 0,
        }
    }

    pub fn with_row_bytes(mut self, bytes: usize) -> Self {
        self.row_bytes = bytes;
        self
    }
}

impl KernelBehavior for SourceKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
    }

    fn on_wake(&mut self, _tag: u64, io: &mut KernelIo) {
        if self.sent_inf >= self.inferences {
            return;
        }
        let payload = match &self.data {
            Some(d) => Payload::RowI8(d[self.sent_row as usize].clone()),
            None => Payload::Timing(self.row_bytes),
        };
        let meta = MsgMeta {
            stream: self.dst.stream.unwrap_or(0),
            row: self.sent_row,
            rows: self.m,
            inference: self.sent_inf,
        };
        io.send(self.dst.dst, meta, payload);
        self.sent_row += 1;
        let mut delay = self.interval;
        if self.sent_row == self.m {
            self.sent_row = 0;
            self.sent_inf += 1;
            delay += self.gap;
        }
        if self.sent_inf < self.inferences {
            io.wake_in(delay, 1);
        }
    }

    fn name(&self) -> String {
        "eval-source".to_string()
    }
}

/// Collected sink output, shared with the harness.
#[derive(Debug, Default)]
pub struct SinkData {
    /// inference -> collected rows
    pub rows: HashMap<u32, BTreeMap<u32, Vec<i8>>>,
    pub packets: u64,
    /// inference -> (packets received, time of last arrival) — works in
    /// Timing mode too (drives the throughput measurements of Fig. 20)
    pub arrivals: HashMap<u32, (u32, u64)>,
}

impl SinkData {
    /// Assemble inference `i` as a dense matrix if complete.
    pub fn matrix(&self, inference: u32) -> Option<Vec<Vec<i8>>> {
        let rows = self.rows.get(&inference)?;
        let m = rows.values().len();
        let expect = *rows.keys().max()? as usize + 1;
        if m != expect {
            return None;
        }
        Some(rows.values().cloned().collect())
    }
}

/// The evaluation FPGA's receiver: add as a probe to measure X/T/I.
pub struct SinkKernel {
    pub data: Arc<Mutex<SinkData>>,
}

impl SinkKernel {
    pub fn new() -> (Self, Arc<Mutex<SinkData>>) {
        let data = Arc::new(Mutex::new(SinkData::default()));
        (SinkKernel { data: data.clone() }, data)
    }
}

impl KernelBehavior for SinkKernel {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
        io.consume(pkt.wire_bytes());
        let mut d = self.data.lock().unwrap();
        d.packets += 1;
        let a = d.arrivals.entry(pkt.meta.inference).or_insert((0, 0));
        a.0 += 1;
        a.1 = io.now;
        if let Payload::RowI8(v) = pkt.payload {
            d.rows.entry(pkt.meta.inference).or_default().insert(pkt.meta.row, v);
        }
    }

    fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}

    fn name(&self) -> String {
        "eval-sink".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip() {
        let t = tag_of(7, 123);
        assert_eq!(untag(t), (7, 123));
        let t = tag_of(u32::MAX - 1, u32::MAX - 2);
        assert_eq!(untag(t), (u32::MAX - 1, u32::MAX - 2));
    }

    #[test]
    fn pacer_enforces_initiation_interval() {
        let mut p = EmitPacer::default();
        // first row pays fill + ii
        assert_eq!(p.schedule(100, 10, 50), 160);
        // back-to-back rows emit ii apart (fill amortised)
        assert_eq!(p.schedule(100, 10, 50), 210);
        assert_eq!(p.schedule(101, 10, 50), 260);
        // idle gap: next row pays fill again
        assert_eq!(p.schedule(900, 10, 50), 960);
    }

    #[test]
    fn sink_matrix_assembly() {
        let (_k, data) = SinkKernel::new();
        {
            let mut d = data.lock().unwrap();
            d.rows.entry(0).or_default().insert(1, vec![2]);
            assert!(d.matrix(0).is_none()); // row 0 missing
            d.rows.entry(0).or_default().insert(0, vec![1]);
        }
        let m = data.lock().unwrap().matrix(0).unwrap();
        assert_eq!(m, vec![vec![1], vec![2]]);
    }
}
