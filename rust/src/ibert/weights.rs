//! Model File System loader (§6.1): quantised weights + integer constants
//! exported by `python/compile/weights.py` into artifacts/.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::config::{parse_quantparams, EncoderQuant, ModelConfig};
use crate::util::tensorfile::{read_tensor, TensorData};

/// All integer parameters of one encoder, loaded from the model FS.
#[derive(Debug, Clone)]
pub struct ModelParams {
    pub cfg: ModelConfig,
    pub eq: EncoderQuant,
    /// [H, H] row-major int8
    pub wq: TensorData<i8>,
    pub wk: TensorData<i8>,
    pub wv: TensorData<i8>,
    pub wo: TensorData<i8>,
    /// [H, F]
    pub w1: TensorData<i8>,
    /// [F, H]
    pub w2: TensorData<i8>,
    pub bq: Vec<i32>,
    pub bk: Vec<i32>,
    pub bv: Vec<i32>,
    pub bo: Vec<i32>,
    pub b1: Vec<i32>,
    pub b2: Vec<i32>,
    pub ln1_gamma: Vec<i64>,
    pub ln1_beta: Vec<i64>,
    pub ln2_gamma: Vec<i64>,
    pub ln2_beta: Vec<i64>,
}

fn load_i8(dir: &Path, name: &str, dims: &[usize]) -> Result<TensorData<i8>> {
    let t = read_tensor(dir.join(format!("weights/{name}.bin")))?;
    let td = t.as_i8().with_context(|| name.to_string())?;
    if td.dims != dims {
        bail!("{name}: expected dims {dims:?}, got {:?}", td.dims);
    }
    Ok(td.clone())
}

fn load_i32(dir: &Path, name: &str, len: usize) -> Result<Vec<i32>> {
    let t = read_tensor(dir.join(format!("weights/{name}.bin")))?;
    let td = t.as_i32().with_context(|| name.to_string())?;
    if td.len() != len {
        bail!("{name}: expected {len} elements, got {}", td.len());
    }
    Ok(td.data.clone())
}

fn load_i64(dir: &Path, name: &str, len: usize) -> Result<Vec<i64>> {
    let t = read_tensor(dir.join(format!("weights/{name}.bin")))?;
    let td = t.as_i64().with_context(|| name.to_string())?;
    if td.len() != len {
        bail!("{name}: expected {len} elements, got {}", td.len());
    }
    Ok(td.data.clone())
}

impl ModelParams {
    /// Load from the artifacts directory (quantparams.json + weights/).
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<ModelParams> {
        let dir = artifacts_dir.as_ref();
        let qp = std::fs::read_to_string(dir.join("quantparams.json"))
            .with_context(|| format!("read {dir:?}/quantparams.json — run `make artifacts`"))?;
        let (cfg, eq) = parse_quantparams(&qp)?;
        let (h, f) = (cfg.hidden, cfg.ffn);
        Ok(ModelParams {
            cfg,
            eq,
            wq: load_i8(dir, "wq", &[h, h])?,
            wk: load_i8(dir, "wk", &[h, h])?,
            wv: load_i8(dir, "wv", &[h, h])?,
            wo: load_i8(dir, "wo", &[h, h])?,
            w1: load_i8(dir, "w1", &[h, f])?,
            w2: load_i8(dir, "w2", &[f, h])?,
            bq: load_i32(dir, "bq", h)?,
            bk: load_i32(dir, "bk", h)?,
            bv: load_i32(dir, "bv", h)?,
            bo: load_i32(dir, "bo", h)?,
            b1: load_i32(dir, "b1", f)?,
            b2: load_i32(dir, "b2", h)?,
            ln1_gamma: load_i64(dir, "ln1_gamma", h)?,
            ln1_beta: load_i64(dir, "ln1_beta", h)?,
            ln2_gamma: load_i64(dir, "ln2_gamma", h)?,
            ln2_beta: load_i64(dir, "ln2_beta", h)?,
        })
    }

    /// Deterministic synthetic parameters for an arbitrary geometry:
    /// random int8 weights with the I-BERT base quantisation constants.
    /// Lets functional simulation, the native forward, and the benches
    /// run bit-exactly without the `make artifacts` model FS (the
    /// operators don't care whether weights came from a checkpoint).
    pub fn synthetic(cfg: ModelConfig, seed: u64) -> ModelParams {
        use crate::util::rng::Rng;
        assert!(cfg.heads > 0 && cfg.hidden % cfg.heads == 0, "hidden must split over heads");
        let mut r = Rng::new(seed);
        fn w(r: &mut Rng, k: usize, n: usize) -> TensorData<i8> {
            TensorData::new(
                vec![k, n],
                (0..k * n).map(|_| r.range_i64(-127, 127) as i8).collect(),
            )
        }
        fn b32(r: &mut Rng, n: usize) -> Vec<i32> {
            (0..n).map(|_| r.range_i64(-50_000, 50_000) as i32).collect()
        }
        fn gamma(r: &mut Rng, n: usize) -> Vec<i64> {
            (0..n).map(|_| (1i64 << 10) + r.range_i64(-200, 200)).collect()
        }
        fn beta(r: &mut Rng, n: usize) -> Vec<i64> {
            (0..n).map(|_| r.range_i64(-2000, 2000)).collect()
        }
        let (h, f) = (cfg.hidden, cfg.ffn);
        ModelParams {
            cfg,
            eq: EncoderQuant::ibert_base_sample(),
            wq: w(&mut r, h, h),
            wk: w(&mut r, h, h),
            wv: w(&mut r, h, h),
            wo: w(&mut r, h, h),
            w1: w(&mut r, h, f),
            w2: w(&mut r, f, h),
            bq: b32(&mut r, h),
            bk: b32(&mut r, h),
            bv: b32(&mut r, h),
            bo: b32(&mut r, h),
            b1: b32(&mut r, f),
            b2: b32(&mut r, h),
            ln1_gamma: gamma(&mut r, h),
            ln1_beta: beta(&mut r, h),
            ln2_gamma: gamma(&mut r, h),
            ln2_beta: beta(&mut r, h),
        }
    }

    /// Default artifacts directory: $CARGO_MANIFEST_DIR/artifacts or ./artifacts.
    pub fn default_dir() -> PathBuf {
        let mano = std::env::var("CARGO_MANIFEST_DIR").map(PathBuf::from);
        match mano {
            Ok(p) if p.join("artifacts").exists() => p.join("artifacts"),
            _ => PathBuf::from("artifacts"),
        }
    }

    /// On-chip memory footprint of the weights in bytes (everything stays
    /// in BRAM, the Brainwave-style design the paper follows).
    pub fn weight_bytes(&self) -> usize {
        self.wq.len()
            + self.wk.len()
            + self.wv.len()
            + self.wo.len()
            + self.w1.len()
            + self.w2.len()
            + 4 * (self.bq.len() + self.bk.len() + self.bv.len() + self.bo.len()
                + self.b1.len() + self.b2.len())
            + 8 * (self.ln1_gamma.len() + self.ln1_beta.len() + self.ln2_gamma.len()
                + self.ln2_beta.len())
    }
}

/// Deterministic synthetic int8 input rows (pairs with
/// [`ModelParams::synthetic`] for artifact-free runs).
pub fn synthetic_input(hidden: usize, m: usize, seed: u64) -> Vec<Vec<i8>> {
    let mut r = crate::util::rng::Rng::new(seed);
    (0..m).map(|_| (0..hidden).map(|_| r.range_i64(-127, 127) as i8).collect()).collect()
}

/// Read a golden tensor from artifacts/goldens.
pub fn load_golden(artifacts_dir: impl AsRef<Path>, name: &str) -> Result<crate::util::tensorfile::Tensor> {
    read_tensor(artifacts_dir.as_ref().join(format!("goldens/{name}.bin")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_params_are_deterministic() {
        use super::super::config::ModelConfig;
        let cfg = ModelConfig { hidden: 24, heads: 12, ffn: 48, max_seq: 8, num_encoders: 1 };
        let a = ModelParams::synthetic(cfg, 5);
        let b = ModelParams::synthetic(cfg, 5);
        assert_eq!(a.wq.data, b.wq.data);
        assert_eq!(a.ln2_beta, b.ln2_beta);
        assert_eq!(a.w1.dims, vec![24, 48]);
        let c = ModelParams::synthetic(cfg, 6);
        assert_ne!(a.wq.data, c.wq.data);
        assert_eq!(synthetic_input(24, 3, 1), synthetic_input(24, 3, 1));
    }

    // Full loading is covered by integration tests (needs artifacts/).
    #[test]
    fn default_dir_is_artifacts() {
        let d = ModelParams::default_dir();
        assert!(d.ends_with("artifacts"));
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = ModelParams::load("/nonexistent-path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
