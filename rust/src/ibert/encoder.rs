//! Whole-matrix encoder forward — the rust reference implementation,
//! bit-exact against python (golden vectors) and against the streaming
//! kernel graph (integration tests). Matches `model.encoder_fwd`
//! operation-for-operation.

use super::compute::*;
use super::weights::ModelParams;

/// All intermediate stage tensors (names match model.py's `stages`).
#[derive(Debug, Clone)]
pub struct EncoderStages {
    pub q: Vec<Vec<i8>>,
    pub k: Vec<Vec<i8>>,
    pub v: Vec<Vec<i8>>,
    /// [heads][m][m]
    pub probs: Vec<Vec<Vec<i8>>>,
    pub att: Vec<Vec<i8>>,
    pub res: Vec<Vec<i64>>,
    pub ln1: Vec<Vec<i8>>,
    pub gelu_in: Vec<Vec<i8>>,
    pub mid: Vec<Vec<i8>>,
    pub res2: Vec<Vec<i64>>,
    pub out: Vec<Vec<i8>>,
}

/// One encoder layer over `x` [m][hidden] int8. No padding: `m` is the
/// actual sequence length (§7.1's no-padding design).
pub fn encoder_forward(p: &ModelParams, x: &[Vec<i8>]) -> EncoderStages {
    let h = p.cfg.hidden;
    let heads = p.cfg.heads;
    let d = p.cfg.head_dim();
    let f = p.cfg.ffn;
    let m = x.len();
    let eq = &p.eq;

    // ---- Layer 0: Q/K/V linears + Quant ----
    let lin8 = |w: &[i8], b: &[i32], site| -> Vec<Vec<i8>> {
        x.iter()
            .map(|row| {
                linear_row(row, w, h, h, b)
                    .into_iter()
                    .map(|a| requant8(a as i64, site))
                    .collect()
            })
            .collect()
    };
    let q8 = lin8(&p.wq.data, &p.bq, eq.rq_q);
    let k8 = lin8(&p.wk.data, &p.bk, eq.rq_k);
    let v8 = lin8(&p.wv.data, &p.bv, eq.rq_v);

    // ---- Layers 1-3: per-head attention ----
    let mut probs = vec![vec![vec![0i8; m]; m]; heads];
    let mut att = vec![vec![0i8; h]; m];
    for hd in 0..heads {
        let lo = hd * d;
        for r in 0..m {
            // scores row: q_r . k_c over the head slice
            let scores: Vec<i32> = (0..m)
                .map(|c| {
                    let mut acc = 0i32;
                    for j in 0..d {
                        acc += q8[r][lo + j] as i32 * k8[c][lo + j] as i32;
                    }
                    acc
                })
                .collect();
            probs[hd][r] = softmax_row(&scores, eq.softmax);
        }
        for r in 0..m {
            for j in 0..d {
                let mut acc = 0i32;
                for c in 0..m {
                    acc += probs[hd][r][c] as i32 * v8[c][lo + j] as i32;
                }
                att[r][lo + j] = requant8(acc as i64, eq.rq_att);
            }
        }
    }

    // ---- Layer 4: projection + residual + LayerNorm ----
    let res: Vec<Vec<i64>> = x
        .iter()
        .zip(&att)
        .map(|(xr, ar)| {
            let proj = linear_row(ar, &p.wo.data, h, h, &p.bo);
            proj.iter()
                .zip(xr)
                .map(|(&pa, &xi)| {
                    requant32(pa as i64, eq.rq_proj) + requant32(xi as i64, eq.rq_resin)
                })
                .collect()
        })
        .collect();
    let ln1: Vec<Vec<i8>> = res
        .iter()
        .map(|r| layernorm_row(r, &p.ln1_gamma, &p.ln1_beta, eq.ln1))
        .collect();

    // ---- Layer 5: FFN + residual + LayerNorm ----
    let gelu_in: Vec<Vec<i8>> = ln1
        .iter()
        .map(|r| {
            linear_row(r, &p.w1.data, h, f, &p.b1)
                .into_iter()
                .map(|a| requant8(a as i64, eq.rq_gelu_in))
                .collect()
        })
        .collect();
    let mid: Vec<Vec<i8>> = gelu_in.iter().map(|r| gelu_row(r, eq.gelu)).collect();
    let res2: Vec<Vec<i64>> = mid
        .iter()
        .zip(&ln1)
        .map(|(mr, lr)| {
            let ffn2 = linear_row(mr, &p.w2.data, f, h, &p.b2);
            ffn2.iter()
                .zip(lr)
                .map(|(&fa, &li)| {
                    requant32(fa as i64, eq.rq_ffn2) + requant32(li as i64, eq.rq_res2in)
                })
                .collect()
        })
        .collect();
    let out: Vec<Vec<i8>> = res2
        .iter()
        .map(|r| layernorm_row(r, &p.ln2_gamma, &p.ln2_beta, eq.ln2))
        .collect();

    EncoderStages { q: q8, k: k8, v: v8, probs, att, res, ln1, gelu_in, mid, res2, out }
}

/// Full model: `n` identical-weight encoders in series (model.model_fwd).
pub fn model_forward(p: &ModelParams, x: &[Vec<i8>], n: usize) -> Vec<Vec<i8>> {
    let mut cur: Vec<Vec<i8>> = x.to_vec();
    for _ in 0..n {
        cur = encoder_forward(p, &cur).out;
    }
    cur
}

/// Convert a 2-D golden tensor into row vectors.
pub fn rows_i8(t: &crate::util::tensorfile::TensorData<i8>) -> Vec<Vec<i8>> {
    let (m, n) = (t.dims[0], t.dims[1]);
    (0..m).map(|r| t.data[r * n..(r + 1) * n].to_vec()).collect()
}

pub fn rows_i64(t: &crate::util::tensorfile::TensorData<i64>) -> Vec<Vec<i64>> {
    let (m, n) = (t.dims[0], t.dims[1]);
    (0..m).map(|r| t.data[r * n..(r + 1) * n].to_vec()).collect()
}
