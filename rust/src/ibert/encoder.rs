//! Whole-matrix encoder forward — the rust reference implementation,
//! bit-exact against python (golden vectors) and against the streaming
//! kernel graph (integration tests). Matches `model.encoder_fwd`
//! operation-for-operation.
//!
//! Two implementations share that contract:
//! * [`encoder_forward`] — the hot path: cache-blocked int8 GEMMs over
//!   once-per-matrix pre-transposed weights (`compute::PackedWeights` +
//!   `compute::linear_rows_packed`, contiguous i8xi8->i32 inner loops)
//!   with rows and heads fanned out over the in-crate worker pool
//!   (`util::pool`). Work is partitioned into fixed chunks computed
//!   exactly as in the serial loop, so outputs are bit-identical at any
//!   thread count.
//! * [`encoder_forward_reference`] — the straight-line row-at-a-time
//!   original, kept as the equivalence baseline (tests + `bench`'s
//!   before/after comparison).

use super::compute::*;
use super::weights::ModelParams;
use crate::util::pool;

/// Rows per worker-pool chunk (aligned with the GEMM row block).
const PAR_CHUNK: usize = GEMM_ROW_BLOCK;

/// All intermediate stage tensors (names match model.py's `stages`).
#[derive(Debug, Clone)]
pub struct EncoderStages {
    pub q: Vec<Vec<i8>>,
    pub k: Vec<Vec<i8>>,
    pub v: Vec<Vec<i8>>,
    /// [heads][m][m]
    pub probs: Vec<Vec<Vec<i8>>>,
    pub att: Vec<Vec<i8>>,
    pub res: Vec<Vec<i64>>,
    pub ln1: Vec<Vec<i8>>,
    pub gelu_in: Vec<Vec<i8>>,
    pub mid: Vec<Vec<i8>>,
    pub res2: Vec<Vec<i64>>,
    pub out: Vec<Vec<i8>>,
}

/// One encoder layer over `x` [m][hidden] int8. No padding: `m` is the
/// actual sequence length (§7.1's no-padding design).
pub fn encoder_forward(p: &ModelParams, x: &[Vec<i8>]) -> EncoderStages {
    let h = p.cfg.hidden;
    let heads = p.cfg.heads;
    let d = p.cfg.head_dim();
    let f = p.cfg.ffn;
    let m = x.len();
    let eq = p.eq;

    // ---- Layer 0: Q/K/V linears + Quant (blocked GEMM, parallel rows) ----
    // weights pack once per matrix (contiguous-column layout), OUTSIDE
    // the worker-pool chunks — every 8-row block then reuses the pack
    let lin8 = |w: &[i8], b: &[i32], site| -> Vec<Vec<i8>> {
        let pw = PackedWeights::pack(w, h, h);
        let mut out = vec![Vec::new(); m];
        pool::parallel_chunks(&mut out, PAR_CHUNK, |start, sl| {
            let ys = linear_rows_packed(&x[start..start + sl.len()], &pw, b);
            for (o, y) in sl.iter_mut().zip(ys) {
                *o = y.into_iter().map(|a| requant8(a as i64, site)).collect();
            }
        });
        out
    };
    let q8 = lin8(&p.wq.data, &p.bq, eq.rq_q);
    let k8 = lin8(&p.wk.data, &p.bk, eq.rq_k);
    let v8 = lin8(&p.wv.data, &p.bv, eq.rq_v);

    // ---- Layers 1-3: attention, one worker per head ----
    let mut per_head: Vec<(Vec<Vec<i8>>, Vec<Vec<i8>>)> =
        (0..heads).map(|_| (Vec::new(), Vec::new())).collect();
    pool::parallel_chunks(&mut per_head, 1, |hd, sl| {
        let lo = hd * d;
        let mut probs_h = Vec::with_capacity(m);
        for r in 0..m {
            // scores row: q_r . k_c over the head slice
            let scores: Vec<i32> = (0..m)
                .map(|c| {
                    let mut acc = 0i32;
                    for j in 0..d {
                        acc += q8[r][lo + j] as i32 * k8[c][lo + j] as i32;
                    }
                    acc
                })
                .collect();
            probs_h.push(softmax_row(&scores, eq.softmax));
        }
        let mut att_h = vec![vec![0i8; d]; m];
        for r in 0..m {
            for j in 0..d {
                let mut acc = 0i32;
                for c in 0..m {
                    acc += probs_h[r][c] as i32 * v8[c][lo + j] as i32;
                }
                att_h[r][j] = requant8(acc as i64, eq.rq_att);
            }
        }
        sl[0] = (probs_h, att_h);
    });
    let mut probs = Vec::with_capacity(heads);
    let mut att = vec![vec![0i8; h]; m];
    for (hd, (probs_h, att_h)) in per_head.into_iter().enumerate() {
        let lo = hd * d;
        for (r, row) in att_h.into_iter().enumerate() {
            att[r][lo..lo + d].copy_from_slice(&row);
        }
        probs.push(probs_h);
    }

    // ---- Layer 4: projection + residual + LayerNorm ----
    let pwo = PackedWeights::pack(&p.wo.data, h, h);
    let mut res: Vec<Vec<i64>> = vec![Vec::new(); m];
    pool::parallel_chunks(&mut res, PAR_CHUNK, |start, sl| {
        let proj = linear_rows_packed(&att[start..start + sl.len()], &pwo, &p.bo);
        for ((o, pr), xr) in sl.iter_mut().zip(proj).zip(&x[start..start + sl.len()]) {
            *o = pr
                .iter()
                .zip(xr)
                .map(|(&pa, &xi)| {
                    requant32(pa as i64, eq.rq_proj) + requant32(xi as i64, eq.rq_resin)
                })
                .collect();
        }
    });
    let mut ln1: Vec<Vec<i8>> = vec![Vec::new(); m];
    pool::parallel_chunks(&mut ln1, PAR_CHUNK, |start, sl| {
        for (i, o) in sl.iter_mut().enumerate() {
            *o = layernorm_row(&res[start + i], &p.ln1_gamma, &p.ln1_beta, eq.ln1);
        }
    });

    // ---- Layer 5: FFN + residual + LayerNorm ----
    let pw1 = PackedWeights::pack(&p.w1.data, h, f);
    let mut gelu_in: Vec<Vec<i8>> = vec![Vec::new(); m];
    pool::parallel_chunks(&mut gelu_in, PAR_CHUNK, |start, sl| {
        let ys = linear_rows_packed(&ln1[start..start + sl.len()], &pw1, &p.b1);
        for (o, y) in sl.iter_mut().zip(ys) {
            *o = y.into_iter().map(|a| requant8(a as i64, eq.rq_gelu_in)).collect();
        }
    });
    let mut mid: Vec<Vec<i8>> = vec![Vec::new(); m];
    pool::parallel_chunks(&mut mid, PAR_CHUNK, |start, sl| {
        for (i, o) in sl.iter_mut().enumerate() {
            *o = gelu_row(&gelu_in[start + i], eq.gelu);
        }
    });
    let pw2 = PackedWeights::pack(&p.w2.data, f, h);
    let mut res2: Vec<Vec<i64>> = vec![Vec::new(); m];
    pool::parallel_chunks(&mut res2, PAR_CHUNK, |start, sl| {
        let ys = linear_rows_packed(&mid[start..start + sl.len()], &pw2, &p.b2);
        for ((o, y), lr) in sl.iter_mut().zip(ys).zip(&ln1[start..start + sl.len()]) {
            *o = y
                .iter()
                .zip(lr)
                .map(|(&fa, &li)| {
                    requant32(fa as i64, eq.rq_ffn2) + requant32(li as i64, eq.rq_res2in)
                })
                .collect();
        }
    });
    let mut out: Vec<Vec<i8>> = vec![Vec::new(); m];
    pool::parallel_chunks(&mut out, PAR_CHUNK, |start, sl| {
        for (i, o) in sl.iter_mut().enumerate() {
            *o = layernorm_row(&res2[start + i], &p.ln2_gamma, &p.ln2_beta, eq.ln2);
        }
    });

    EncoderStages { q: q8, k: k8, v: v8, probs, att, res, ln1, gelu_in, mid, res2, out }
}

/// The original single-threaded row-at-a-time forward. Kept as the
/// bit-exactness baseline that [`encoder_forward`] must reproduce
/// exactly (enforced by `fast_forward_matches_reference` below and the
/// golden-vector integration tests).
pub fn encoder_forward_reference(p: &ModelParams, x: &[Vec<i8>]) -> EncoderStages {
    let h = p.cfg.hidden;
    let heads = p.cfg.heads;
    let d = p.cfg.head_dim();
    let f = p.cfg.ffn;
    let m = x.len();
    let eq = &p.eq;

    let lin8 = |w: &[i8], b: &[i32], site| -> Vec<Vec<i8>> {
        x.iter()
            .map(|row| {
                linear_row(row, w, h, h, b)
                    .into_iter()
                    .map(|a| requant8(a as i64, site))
                    .collect()
            })
            .collect()
    };
    let q8 = lin8(&p.wq.data, &p.bq, eq.rq_q);
    let k8 = lin8(&p.wk.data, &p.bk, eq.rq_k);
    let v8 = lin8(&p.wv.data, &p.bv, eq.rq_v);

    let mut probs = vec![vec![vec![0i8; m]; m]; heads];
    let mut att = vec![vec![0i8; h]; m];
    for hd in 0..heads {
        let lo = hd * d;
        for r in 0..m {
            let scores: Vec<i32> = (0..m)
                .map(|c| {
                    let mut acc = 0i32;
                    for j in 0..d {
                        acc += q8[r][lo + j] as i32 * k8[c][lo + j] as i32;
                    }
                    acc
                })
                .collect();
            probs[hd][r] = softmax_row(&scores, eq.softmax);
        }
        for r in 0..m {
            for j in 0..d {
                let mut acc = 0i32;
                for c in 0..m {
                    acc += probs[hd][r][c] as i32 * v8[c][lo + j] as i32;
                }
                att[r][lo + j] = requant8(acc as i64, eq.rq_att);
            }
        }
    }

    let res: Vec<Vec<i64>> = x
        .iter()
        .zip(&att)
        .map(|(xr, ar)| {
            let proj = linear_row(ar, &p.wo.data, h, h, &p.bo);
            proj.iter()
                .zip(xr)
                .map(|(&pa, &xi)| {
                    requant32(pa as i64, eq.rq_proj) + requant32(xi as i64, eq.rq_resin)
                })
                .collect()
        })
        .collect();
    let ln1: Vec<Vec<i8>> = res
        .iter()
        .map(|r| layernorm_row(r, &p.ln1_gamma, &p.ln1_beta, eq.ln1))
        .collect();

    let gelu_in: Vec<Vec<i8>> = ln1
        .iter()
        .map(|r| {
            linear_row(r, &p.w1.data, h, f, &p.b1)
                .into_iter()
                .map(|a| requant8(a as i64, eq.rq_gelu_in))
                .collect()
        })
        .collect();
    let mid: Vec<Vec<i8>> = gelu_in.iter().map(|r| gelu_row(r, eq.gelu)).collect();
    let res2: Vec<Vec<i64>> = mid
        .iter()
        .zip(&ln1)
        .map(|(mr, lr)| {
            let ffn2 = linear_row(mr, &p.w2.data, f, h, &p.b2);
            ffn2.iter()
                .zip(lr)
                .map(|(&fa, &li)| {
                    requant32(fa as i64, eq.rq_ffn2) + requant32(li as i64, eq.rq_res2in)
                })
                .collect()
        })
        .collect();
    let out: Vec<Vec<i8>> = res2
        .iter()
        .map(|r| layernorm_row(r, &p.ln2_gamma, &p.ln2_beta, eq.ln2))
        .collect();

    EncoderStages { q: q8, k: k8, v: v8, probs, att, res, ln1, gelu_in, mid, res2, out }
}

/// Full model: `n` identical-weight encoders in series (model.model_fwd).
pub fn model_forward(p: &ModelParams, x: &[Vec<i8>], n: usize) -> Vec<Vec<i8>> {
    let mut cur: Vec<Vec<i8>> = x.to_vec();
    for _ in 0..n {
        cur = encoder_forward(p, &cur).out;
    }
    cur
}

/// One decoder layer, *incremental*: `x_new` extends the sequence at
/// positions `cache.len()..`, the new rows' K/V projections are appended
/// to the cache, and each new row attends causally over everything
/// cached so far. Old positions need no recompute — every non-attention
/// op is row-local — so a single-token decode step does O(1) rows of
/// work against O(len) cache reads, exactly the dataflow the simulated
/// attention/SMM kernels execute. Returns output rows for the new
/// positions only.
pub fn decoder_layer_incremental(
    p: &ModelParams,
    cache: &mut KvCache,
    x_new: &[Vec<i8>],
) -> Vec<Vec<i8>> {
    let h = p.cfg.hidden;
    let heads = p.cfg.heads;
    let d = p.cfg.head_dim();
    let f = p.cfg.ffn;
    let eq = &p.eq;
    let base = cache.len();

    let lin8 = |row: &[i8], w: &[i8], b: &[i32], site| -> Vec<i8> {
        linear_row(row, w, h, h, b).into_iter().map(|a| requant8(a as i64, site)).collect()
    };
    let q8: Vec<Vec<i8>> = x_new.iter().map(|r| lin8(r, &p.wq.data, &p.bq, eq.rq_q)).collect();
    for r in x_new {
        cache.k.push(lin8(r, &p.wk.data, &p.bk, eq.rq_k));
        cache.v.push(lin8(r, &p.wv.data, &p.bv, eq.rq_v));
    }

    let mut out = Vec::with_capacity(x_new.len());
    for (i, xr) in x_new.iter().enumerate() {
        let pos = base + i; // causal mask admits cached positions 0..=pos
        let ks: Vec<&[i8]> = cache.k[..=pos].iter().map(|r| r.as_slice()).collect();
        let vs: Vec<&[i8]> = cache.v[..=pos].iter().map(|r| r.as_slice()).collect();
        let mut att = vec![0i8; h];
        for hd in 0..heads {
            let lo = hd * d;
            let scores = causal_head_scores(&q8[i], &ks, lo, d);
            let probs = softmax_row(&scores, eq.softmax);
            let ctx = head_context_row(&probs, &vs, lo, d, eq.rq_att);
            att[lo..lo + d].copy_from_slice(&ctx);
        }
        let proj = linear_row(&att, &p.wo.data, h, h, &p.bo);
        let res: Vec<i64> = proj
            .iter()
            .zip(xr)
            .map(|(&pa, &xi)| requant32(pa as i64, eq.rq_proj) + requant32(xi as i64, eq.rq_resin))
            .collect();
        let ln1 = layernorm_row(&res, &p.ln1_gamma, &p.ln1_beta, eq.ln1);
        let gelu_in: Vec<i8> = linear_row(&ln1, &p.w1.data, h, f, &p.b1)
            .into_iter()
            .map(|a| requant8(a as i64, eq.rq_gelu_in))
            .collect();
        let mid = gelu_row(&gelu_in, eq.gelu);
        let ffn2 = linear_row(&mid, &p.w2.data, f, h, &p.b2);
        let res2: Vec<i64> = ffn2
            .iter()
            .zip(&ln1)
            .map(|(&fa, &li)| requant32(fa as i64, eq.rq_ffn2) + requant32(li as i64, eq.rq_res2in))
            .collect();
        out.push(layernorm_row(&res2, &p.ln2_gamma, &p.ln2_beta, eq.ln2));
    }
    out
}

/// Naive full-recompute decoder layer: the whole sequence from scratch,
/// causal masking by loop bound (position `r` attends `0..=r`), no
/// cache. Deliberately written against [`encoder_forward_reference`]'s
/// structure rather than the incremental path so the bit-exactness test
/// between the two actually exercises the cache bookkeeping.
pub fn decoder_layer_recompute(p: &ModelParams, x: &[Vec<i8>]) -> Vec<Vec<i8>> {
    let h = p.cfg.hidden;
    let heads = p.cfg.heads;
    let d = p.cfg.head_dim();
    let f = p.cfg.ffn;
    let m = x.len();
    let eq = &p.eq;

    let lin8 = |w: &[i8], b: &[i32], site| -> Vec<Vec<i8>> {
        x.iter()
            .map(|row| {
                linear_row(row, w, h, h, b)
                    .into_iter()
                    .map(|a| requant8(a as i64, site))
                    .collect()
            })
            .collect()
    };
    let q8 = lin8(&p.wq.data, &p.bq, eq.rq_q);
    let k8 = lin8(&p.wk.data, &p.bk, eq.rq_k);
    let v8 = lin8(&p.wv.data, &p.bv, eq.rq_v);

    let mut att = vec![vec![0i8; h]; m];
    for hd in 0..heads {
        let lo = hd * d;
        for r in 0..m {
            let scores: Vec<i32> = (0..=r)
                .map(|c| {
                    let mut acc = 0i32;
                    for j in 0..d {
                        acc += q8[r][lo + j] as i32 * k8[c][lo + j] as i32;
                    }
                    acc
                })
                .collect();
            let probs = softmax_row(&scores, eq.softmax);
            for j in 0..d {
                let mut acc = 0i32;
                for c in 0..=r {
                    acc += probs[c] as i32 * v8[c][lo + j] as i32;
                }
                att[r][lo + j] = requant8(acc as i64, eq.rq_att);
            }
        }
    }

    let mut out = Vec::with_capacity(m);
    for (xr, ar) in x.iter().zip(&att) {
        let proj = linear_row(ar, &p.wo.data, h, h, &p.bo);
        let res: Vec<i64> = proj
            .iter()
            .zip(xr)
            .map(|(&pa, &xi)| requant32(pa as i64, eq.rq_proj) + requant32(xi as i64, eq.rq_resin))
            .collect();
        let ln1 = layernorm_row(&res, &p.ln1_gamma, &p.ln1_beta, eq.ln1);
        let gelu_in: Vec<i8> = linear_row(&ln1, &p.w1.data, h, f, &p.b1)
            .into_iter()
            .map(|a| requant8(a as i64, eq.rq_gelu_in))
            .collect();
        let mid = gelu_row(&gelu_in, eq.gelu);
        let ffn2 = linear_row(&mid, &p.w2.data, f, h, &p.b2);
        let res2: Vec<i64> = ffn2
            .iter()
            .zip(&ln1)
            .map(|(&fa, &li)| requant32(fa as i64, eq.rq_ffn2) + requant32(li as i64, eq.rq_res2in))
            .collect();
        out.push(layernorm_row(&res2, &p.ln2_gamma, &p.ln2_beta, eq.ln2));
    }
    out
}

/// Multi-layer decoder state: one KV cache per layer (caches never mix
/// across layers — each layer caches its *own* K/V projections of its
/// own input stream).
#[derive(Debug, Clone, Default)]
pub struct DecoderState {
    pub caches: Vec<KvCache>,
}

impl DecoderState {
    pub fn new(layers: usize) -> DecoderState {
        DecoderState { caches: vec![KvCache::default(); layers] }
    }
}

/// Incremental decoder stack: run the new rows through every layer, each
/// against its own cache. Returns the last layer's output rows.
pub fn decoder_stack_incremental(
    p: &ModelParams,
    st: &mut DecoderState,
    x_new: &[Vec<i8>],
) -> Vec<Vec<i8>> {
    let mut cur = x_new.to_vec();
    for cache in &mut st.caches {
        cur = decoder_layer_incremental(p, cache, &cur);
    }
    cur
}

/// Full-recompute decoder stack over the whole sequence (no state).
pub fn decoder_stack_recompute(p: &ModelParams, x: &[Vec<i8>], layers: usize) -> Vec<Vec<i8>> {
    let mut cur = x.to_vec();
    for _ in 0..layers {
        cur = decoder_layer_recompute(p, &cur);
    }
    cur
}

/// The platform's generation loop, incrementally: prefill the prompt,
/// then feed the stack's last output row back as the next input row
/// `max_new` times (the feedback row stands in for token sampling —
/// deterministic and bit-exactness-testable; see DESIGN.md). Returns
/// `(prefill output rows, one row per generated token)`.
pub fn decode_generate(
    p: &ModelParams,
    prompt: &[Vec<i8>],
    layers: usize,
    max_new: usize,
) -> (Vec<Vec<i8>>, Vec<Vec<i8>>) {
    assert!(!prompt.is_empty(), "decode needs a non-empty prompt");
    let mut st = DecoderState::new(layers);
    let prefill = decoder_stack_incremental(p, &mut st, prompt);
    let mut toks: Vec<Vec<i8>> = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let fed = toks.last().unwrap_or_else(|| prefill.last().unwrap()).clone();
        let out = decoder_stack_incremental(p, &mut st, &[fed]);
        toks.push(out.into_iter().next().unwrap());
    }
    (prefill, toks)
}

/// The same generation loop via full recompute: step `k` re-runs the
/// whole causal stack over `prompt ++ fed-back rows` and takes the last
/// output row. Quadratically wasteful by design — it is the equivalence
/// oracle for [`decode_generate`] and for the simulated pipeline.
pub fn decode_generate_recompute(
    p: &ModelParams,
    prompt: &[Vec<i8>],
    layers: usize,
    max_new: usize,
) -> (Vec<Vec<i8>>, Vec<Vec<i8>>) {
    assert!(!prompt.is_empty(), "decode needs a non-empty prompt");
    let mut seq = prompt.to_vec();
    let prefill = decoder_stack_recompute(p, &seq, layers);
    let mut toks: Vec<Vec<i8>> = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let fed = toks.last().unwrap_or_else(|| prefill.last().unwrap()).clone();
        seq.push(fed);
        let outs = decoder_stack_recompute(p, &seq, layers);
        toks.push(outs.last().unwrap().clone());
    }
    (prefill, toks)
}

/// Convert a 2-D golden tensor into row vectors.
pub fn rows_i8(t: &crate::util::tensorfile::TensorData<i8>) -> Vec<Vec<i8>> {
    let (m, n) = (t.dims[0], t.dims[1]);
    (0..m).map(|r| t.data[r * n..(r + 1) * n].to_vec()).collect()
}

pub fn rows_i64(t: &crate::util::tensorfile::TensorData<i64>) -> Vec<Vec<i64>> {
    let (m, n) = (t.dims[0], t.dims[1]);
    (0..m).map(|r| t.data[r * n..(r + 1) * n].to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ibert::config::ModelConfig;
    use crate::ibert::weights::synthetic_input;

    #[test]
    fn fast_forward_matches_reference() {
        // small synthetic model: every stage of the parallel/blocked
        // forward must be bit-identical to the row-at-a-time original
        let cfg = ModelConfig { hidden: 96, heads: 12, ffn: 192, max_seq: 32, num_encoders: 2 };
        let p = ModelParams::synthetic(cfg, 0xC0FFEE);
        for m in [1usize, 2, 7, 19, 32] {
            let x = synthetic_input(cfg.hidden, m, 42 + m as u64);
            let fast = encoder_forward(&p, &x);
            let slow = encoder_forward_reference(&p, &x);
            assert_eq!(fast.q, slow.q, "q mismatch at m={m}");
            assert_eq!(fast.probs, slow.probs, "probs mismatch at m={m}");
            assert_eq!(fast.att, slow.att, "att mismatch at m={m}");
            assert_eq!(fast.res, slow.res, "res mismatch at m={m}");
            assert_eq!(fast.ln1, slow.ln1, "ln1 mismatch at m={m}");
            assert_eq!(fast.mid, slow.mid, "mid mismatch at m={m}");
            assert_eq!(fast.out, slow.out, "out mismatch at m={m}");
        }
    }

    #[test]
    fn incremental_decode_matches_full_recompute() {
        let cfg = ModelConfig { hidden: 96, heads: 12, ffn: 192, max_seq: 32, num_encoders: 2 };
        let p = ModelParams::synthetic(cfg, 0xDEC0DE);
        let prompt = synthetic_input(cfg.hidden, 5, 17);
        let (pre_i, toks_i) = decode_generate(&p, &prompt, 2, 4);
        let (pre_r, toks_r) = decode_generate_recompute(&p, &prompt, 2, 4);
        assert_eq!(pre_i, pre_r, "prefill rows diverge");
        assert_eq!(toks_i, toks_r, "token rows diverge");
        assert_eq!(toks_i.len(), 4);
    }

    #[test]
    fn causal_outputs_are_prefix_invariant() {
        // position i of the recompute layer must not change when later
        // rows are appended — the property that makes a KV cache sound
        let cfg = ModelConfig { hidden: 48, heads: 12, ffn: 96, max_seq: 16, num_encoders: 1 };
        let p = ModelParams::synthetic(cfg, 99);
        let x = synthetic_input(cfg.hidden, 9, 3);
        let full = decoder_layer_recompute(&p, &x);
        for cut in [1usize, 4, 8] {
            let part = decoder_layer_recompute(&p, &x[..cut]);
            assert_eq!(part[..], full[..cut], "prefix {cut} diverges");
        }
    }

    #[test]
    fn pure_prefill_decode_is_a_causal_forward() {
        let cfg = ModelConfig { hidden: 48, heads: 12, ffn: 96, max_seq: 16, num_encoders: 1 };
        let p = ModelParams::synthetic(cfg, 5);
        let x = synthetic_input(cfg.hidden, 6, 8);
        let (pre, toks) = decode_generate(&p, &x, 1, 0);
        assert!(toks.is_empty());
        assert_eq!(pre, decoder_layer_recompute(&p, &x));
    }

    #[test]
    fn model_forward_chains_encoders() {
        let cfg = ModelConfig { hidden: 48, heads: 12, ffn: 96, max_seq: 8, num_encoders: 2 };
        let p = ModelParams::synthetic(cfg, 7);
        let x = synthetic_input(cfg.hidden, 4, 9);
        let once = encoder_forward(&p, &x).out;
        let twice = model_forward(&p, &x, 2);
        assert_eq!(twice, encoder_forward(&p, &once).out);
    }
}
