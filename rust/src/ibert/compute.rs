//! Bit-exact integer I-BERT operators — the rust mirror of
//! `python/compile/iops.py`. Every function here matches its python twin
//! operation-for-operation (same floor-division semantics, same shift
//! rounding, same Newton schedule); golden vectors exported at build time
//! enforce the contract (rust/tests/golden_numerics.rs).

use super::config::{GeluParams, LayerNormParams, RequantSite, SoftmaxParams};

/// == jnp.floor_divide for b > 0 (floors toward -inf).
#[inline]
pub fn floor_div(a: i64, b: i64) -> i64 {
    a.div_euclid(b)
}

/// Round-half-up right shift: (x + 2^(n-1)) >> n, arithmetic.
#[inline]
pub fn rshift_round(x: i64, n: u32) -> i64 {
    if n == 0 {
        x
    } else {
        (x + (1i64 << (n - 1))) >> n
    }
}

#[inline]
pub fn clip8(x: i64) -> i8 {
    x.clamp(-127, 127) as i8
}

/// int32/int64 accumulator -> int8 at the site's output scale.
#[inline]
pub fn requant8(acc: i64, s: RequantSite) -> i8 {
    clip8(rshift_round(acc * s.m, s.n))
}

/// int32/int64 accumulator -> wide value (residual/LayerNorm domain).
#[inline]
pub fn requant32(acc: i64, s: RequantSite) -> i64 {
    rshift_round(acc * s.m, s.n)
}

/// Fixed-iteration Newton integer sqrt — EXACTLY the schedule of
/// iops.isqrt (35 iterations from 2^32, two floor-corrections).
pub fn isqrt(n: i64) -> i64 {
    debug_assert!(n >= 0);
    if n == 0 {
        return 0;
    }
    let mut x: i64 = 1 << 32;
    for _ in 0..35 {
        x = std::cmp::max(floor_div(x + floor_div(n, std::cmp::max(x, 1)), 2), 1);
    }
    if x * x > n {
        x -= 1;
    }
    if x * x > n {
        x -= 1;
    }
    x
}

/// One output element of an int8 linear: dot(x_row, w_col) + bias (int32
/// accumulate — the PE of Fig. 11).
#[inline]
pub fn pe_dot(x_row: &[i8], w_col: impl Iterator<Item = i8>, bias: i32) -> i32 {
    let mut acc = bias;
    for (&x, w) in x_row.iter().zip(w_col) {
        acc += (x as i32) * (w as i32);
    }
    acc
}

/// Full linear row: x_row [K] x W [K, N] + b [N] -> [N] int32.
/// `w` is row-major [K][N].
pub fn linear_row(x_row: &[i8], w: &[i8], k: usize, n: usize, bias: &[i32]) -> Vec<i32> {
    debug_assert_eq!(x_row.len(), k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    let mut out = bias.to_vec();
    // row-major weight walk: accumulate x[i] * W[i, :] into the output row
    // (cache-friendly; mathematically identical to per-column PE dots)
    for (i, &x) in x_row.iter().enumerate() {
        if x == 0 {
            continue;
        }
        let x = x as i32;
        let wrow = &w[i * n..(i + 1) * n];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += x * wv as i32;
        }
    }
    out
}

/// Output rows computed per weight-matrix pass by [`linear_rows_packed`].
/// A block of x rows (8 x K x 1B) stays in L1 while each packed weight
/// column is reused across the whole block — W traffic drops by the
/// block factor vs the one-row-at-a-time walk.
pub const GEMM_ROW_BLOCK: usize = 8;

/// Tile edge of the [`PackedWeights::pack`] transpose (source rows and
/// destination columns both stay cache-resident during the pack).
const PACK_TILE: usize = 64;

/// `W [K, N]` pre-transposed into contiguous columns (`wt[j*k + i] =
/// w[i*n + j]`), so the GEMM microkernel's inner loop is a straight
/// `i8 x i8 -> i32` dot over two sequential streams — the FMA-friendly
/// layout the DSP PE of Fig. 11 gets for free in hardware. Pack once per
/// weight matrix and reuse across every row block (`ibert::encoder`
/// hoists the pack out of its worker-pool chunks).
pub struct PackedWeights {
    wt: Vec<i8>,
    pub k: usize,
    pub n: usize,
}

impl PackedWeights {
    /// Tile-wise transpose of row-major `w [K, N]`.
    pub fn pack(w: &[i8], k: usize, n: usize) -> PackedWeights {
        debug_assert_eq!(w.len(), k * n);
        let mut wt = vec![0i8; k * n];
        for j0 in (0..n).step_by(PACK_TILE) {
            let j1 = (j0 + PACK_TILE).min(n);
            for i0 in (0..k).step_by(PACK_TILE) {
                let i1 = (i0 + PACK_TILE).min(k);
                for j in j0..j1 {
                    for i in i0..i1 {
                        wt[j * k + i] = w[i * n + j];
                    }
                }
            }
        }
        PackedWeights { wt, k, n }
    }

    /// Column `j` of the original `W`, contiguous.
    #[inline]
    pub fn col(&self, j: usize) -> &[i8] {
        &self.wt[j * self.k..(j + 1) * self.k]
    }
}

/// Cache-blocked multi-row int8 linear over pre-transposed weights:
/// `Y[r] = X[r] . W + b`. Bit-identical to calling [`linear_row`] per
/// row (integer accumulation is exact and order-independent; i8*i8 dots
/// cannot overflow i32 at any K <= 2^15): each output element sums the
/// same products in ascending-`i` order. Each packed column is walked
/// once per [`GEMM_ROW_BLOCK`] rows while both dot operands stream
/// contiguously.
pub fn linear_rows_packed(xs: &[Vec<i8>], pw: &PackedWeights, bias: &[i32]) -> Vec<Vec<i32>> {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(bias.len(), n);
    let mut out: Vec<Vec<i32>> = xs.iter().map(|_| Vec::with_capacity(n)).collect();
    for (xb, ob) in xs.chunks(GEMM_ROW_BLOCK).zip(out.chunks_mut(GEMM_ROW_BLOCK)) {
        for j in 0..n {
            let col = pw.col(j);
            let b = bias[j];
            for (x_row, o_row) in xb.iter().zip(ob.iter_mut()) {
                debug_assert_eq!(x_row.len(), k);
                let mut acc = b;
                for (&x, &wv) in x_row.iter().zip(col) {
                    acc += x as i32 * wv as i32;
                }
                o_row.push(acc);
            }
        }
    }
    out
}

/// Multi-row int8 linear on row-major weights: packs `w` once, then runs
/// the contiguous-column microkernel. A single row skips the pack (it
/// would double the W traffic) and takes the streaming row walk. Hot
/// callers that reuse one W across many calls should hoist
/// [`PackedWeights::pack`] and call [`linear_rows_packed`] directly.
pub fn linear_rows(xs: &[Vec<i8>], w: &[i8], k: usize, n: usize, bias: &[i32]) -> Vec<Vec<i32>> {
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    if xs.len() < 2 {
        return xs.iter().map(|x| linear_row(x, w, k, n, bias)).collect();
    }
    linear_rows_packed(xs, &PackedWeights::pack(w, k, n), bias)
}

/// Per-layer KV cache of an autoregressive decoder: the K and V
/// projections of every position processed so far, as full hidden rows
/// (heads slice at use, exactly like the scatter kernels do on the
/// fabric). Prefill appends `m` rows at once; each decode step appends
/// one. Plain storage — all arithmetic lives in the row helpers below so
/// the simulated kernels and the native reference share one code path.
#[derive(Debug, Clone, Default)]
pub struct KvCache {
    pub k: Vec<Vec<i8>>,
    pub v: Vec<Vec<i8>>,
}

impl KvCache {
    /// Cached positions (rows) so far.
    pub fn len(&self) -> usize {
        debug_assert_eq!(self.k.len(), self.v.len());
        self.k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Masked-attention scores of one query row over one head's cached K
/// rows: `scores[c] = dot(q[lo..lo+d], ks[c][lo..lo+d])`. The caller
/// passes exactly the rows the causal mask admits (positions `0..=p` for
/// a query at position `p`) — masking is row selection, not a -inf add,
/// matching the hardware's no-padding dataflow.
pub fn causal_head_scores(q: &[i8], ks: &[&[i8]], lo: usize, d: usize) -> Vec<i32> {
    ks.iter()
        .map(|k| {
            let mut acc = 0i32;
            for j in 0..d {
                acc += q[lo + j] as i32 * k[lo + j] as i32;
            }
            acc
        })
        .collect()
}

/// Context row of one head: probability-weighted sum of the cached V
/// rows' head slice, requantized to int8. `probs.len() == vs.len()` is
/// the attended length (variable under the causal mask).
pub fn head_context_row(probs: &[i8], vs: &[&[i8]], lo: usize, d: usize, rq: RequantSite) -> Vec<i8> {
    debug_assert_eq!(probs.len(), vs.len());
    (0..d)
        .map(|j| {
            let acc: i64 = probs
                .iter()
                .zip(vs)
                .map(|(&p, v)| p as i64 * v[lo + j] as i64)
                .sum();
            requant8(acc, rq)
        })
        .collect()
}

/// i-Softmax over one score row (actual sequence length only — the
/// hardware no-padding path). Mirrors iops.i_softmax with all-valid mask.
pub fn softmax_row(scores: &[i32], sm: SoftmaxParams) -> Vec<i8> {
    const OUT_SHIFT: u32 = 15; // quantize.SOFTMAX_OUT_SHIFT
    const OUT_SCALE: i64 = 127; // quantize.SOFTMAX_OUT_SCALE
    const SHIFT_MAX: i64 = 31; // quantize.EXP_SHIFT_MAX

    let qmax = scores.iter().copied().max().unwrap_or(0) as i64;
    let mut e: Vec<i64> = Vec::with_capacity(scores.len());
    for &s in scores {
        let qt = s as i64 - qmax; // <= 0
        let z = floor_div(-qt, sm.q_ln2);
        let p = qt + z * sm.q_ln2;
        let v = (p + sm.q_b) * (p + sm.q_b) + sm.q_c;
        let zc = z.min(SHIFT_MAX);
        e.push(v >> zc);
    }
    let total: i64 = e.iter().sum::<i64>().max(1);
    e.iter()
        .map(|&ei| {
            let q15 = floor_div(ei << OUT_SHIFT, total);
            let p8 = rshift_round(q15 * OUT_SCALE, OUT_SHIFT);
            p8.clamp(0, 127) as i8
        })
        .collect()
}

/// i-GELU on one int8 value (mirrors iops.i_gelu; note the sign flip for
/// the negative s_erf — see quantize.GeluParams).
#[inline]
pub fn gelu_i8(q: i8, gp: GeluParams) -> i8 {
    let q = q as i64;
    let sgn = q.signum();
    let qa = q.abs().min(-gp.q_b);
    let poly = (qa + gp.q_b) * (qa + gp.q_b) + gp.q_c;
    let q_erf = sgn * poly;
    let q_out = q * (q_erf + gp.q_one);
    requant8(-q_out, gp.out)
}

pub fn gelu_row(row: &[i8], gp: GeluParams) -> Vec<i8> {
    row.iter().map(|&q| gelu_i8(q, gp)).collect()
}

/// i-LayerNorm over one row in the wide residual domain.
/// gamma_q/beta_q are the Q{kg} per-channel constants from the model FS.
pub fn layernorm_row(q: &[i64], gamma_q: &[i64], beta_q: &[i64], ln: LayerNormParams) -> Vec<i8> {
    let h = q.len() as i64;
    let sum_q: i64 = q.iter().sum();
    let mean = floor_div(2 * sum_q + h, 2 * h);
    let var = floor_div(q.iter().map(|&x| (x - mean) * (x - mean)).sum::<i64>(), h);
    let std = isqrt(var).max(1);
    q.iter()
        .zip(gamma_q.iter().zip(beta_q))
        .map(|(&x, (&g, &b))| {
            let d = x - mean;
            let t = floor_div(d * g, std) + b;
            clip8(rshift_round(t, ln.kg))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_div_floors_negatives() {
        assert_eq!(floor_div(-7, 2), -4); // python -7 // 2
        assert_eq!(floor_div(7, 2), 3);
        assert_eq!(floor_div(-6, 3), -2);
    }

    #[test]
    fn rshift_round_matches_half_up() {
        assert_eq!(rshift_round(5, 1), 3); // 2.5 -> 3
        assert_eq!(rshift_round(-5, 1), -2); // -2.5 -> -2 (floor(x+.5))
        assert_eq!(rshift_round(4, 2), 1);
        assert_eq!(rshift_round(100, 0), 100);
    }

    #[test]
    fn isqrt_is_floor_sqrt() {
        for n in [0i64, 1, 2, 3, 4, 15, 16, 17, 1_000_000, (1 << 40) - 1, 1 << 40] {
            let r = isqrt(n);
            assert!(r * r <= n, "isqrt({n})={r}");
            assert!((r + 1) * (r + 1) > n, "isqrt({n})={r}");
        }
    }

    #[test]
    fn isqrt_property() {
        crate::util::quickcheck::check("isqrt-floor", |g| {
            let n = g.i64_in(0, 1 << 50);
            let r = isqrt(n);
            crate::prop_assert!(r * r <= n && (r + 1) * (r + 1) > n, "isqrt({n}) = {r}");
            Ok(())
        });
    }

    #[test]
    fn clip_and_requant() {
        assert_eq!(clip8(500), 127);
        assert_eq!(clip8(-500), -127);
        let s = RequantSite { m: 1 << 14, n: 14 }; // identity
        assert_eq!(requant8(100, s), 100);
        assert_eq!(requant32(-5_000, s), -5_000);
    }

    #[test]
    fn linear_row_matches_pe_dot() {
        let k = 8;
        let n = 3;
        let x: Vec<i8> = (0..k as i8).collect();
        let w: Vec<i8> = (0..(k * n) as i32).map(|v| (v % 17 - 8) as i8).collect();
        let bias = vec![5i32, -7, 0];
        let full = linear_row(&x, &w, k, n, &bias);
        for j in 0..n {
            let col = (0..k).map(|i| w[i * n + j]);
            assert_eq!(full[j], pe_dot(&x, col, bias[j]));
        }
    }

    #[test]
    fn linear_rows_blocked_matches_row_at_a_time() {
        let k = 37;
        let n = 19;
        let w: Vec<i8> = (0..(k * n) as i32).map(|v| (v % 31 - 15) as i8).collect();
        let bias: Vec<i32> = (0..n as i32).map(|v| v * 7 - 50).collect();
        // more rows than one block, with a ragged tail
        let xs: Vec<Vec<i8>> = (0..GEMM_ROW_BLOCK * 2 + 3)
            .map(|r| (0..k).map(|i| ((r * 13 + i * 5) % 29) as i8 - 14).collect())
            .collect();
        let blocked = linear_rows(&xs, &w, k, n, &bias);
        for (r, x) in xs.iter().enumerate() {
            assert_eq!(blocked[r], linear_row(x, &w, k, n, &bias), "row {r}");
        }
    }

    #[test]
    fn pack_transposes_exactly_at_ragged_tile_edges() {
        // k, n straddle the 64-wide pack tile in all four quadrants
        for (k, n) in [(1usize, 1usize), (64, 64), (65, 63), (130, 67), (3, 200)] {
            let w: Vec<i8> = (0..(k * n) as i32).map(|v| (v % 37 - 18) as i8).collect();
            let pw = PackedWeights::pack(&w, k, n);
            for j in 0..n {
                let col = pw.col(j);
                for i in 0..k {
                    assert_eq!(col[i], w[i * n + j], "({i},{j}) of {k}x{n}");
                }
            }
        }
    }

    #[test]
    fn packed_gemm_matches_reference_all_row_counts() {
        // incl. the single-row (unpacked) path and empty input
        let (k, n) = (70, 33);
        let w: Vec<i8> = (0..(k * n) as i32).map(|v| (v % 23 - 11) as i8).collect();
        let bias: Vec<i32> = (0..n as i32).map(|v| 31 - v * 3).collect();
        let pw = PackedWeights::pack(&w, k, n);
        for rows in [0usize, 1, 2, GEMM_ROW_BLOCK, GEMM_ROW_BLOCK + 1, 3 * GEMM_ROW_BLOCK] {
            let xs: Vec<Vec<i8>> = (0..rows)
                .map(|r| (0..k).map(|i| ((r * 7 + i * 11) % 27) as i8 - 13).collect())
                .collect();
            let want: Vec<Vec<i32>> =
                xs.iter().map(|x| linear_row(x, &w, k, n, &bias)).collect();
            assert_eq!(linear_rows_packed(&xs, &pw, &bias), want, "packed rows={rows}");
            assert_eq!(linear_rows(&xs, &w, k, n, &bias), want, "linear_rows rows={rows}");
        }
    }

    #[test]
    fn softmax_row_sums_to_one_ish() {
        let sm = SoftmaxParams { q_ln2: 1051, q_b: 2052, q_c: 2_209_112 };
        let scores: Vec<i32> = vec![-3000, 0, 2500, 2500, -10_000];
        let p = softmax_row(&scores, sm);
        assert!(p.iter().all(|&x| x >= 0));
        let total: i64 = p.iter().map(|&x| x as i64).sum();
        assert!((total - 127).abs() <= 13, "sum={total}");
        assert_eq!(p[2], p[3]);
        assert!(p[2] > p[1] && p[1] >= p[0]);
    }

    #[test]
    fn gelu_monotone_nonneg_side() {
        let gp = GeluParams {
            q_b: -70,
            q_c: -5272,
            q_one: -5272,
            out: RequantSite { m: 25463, n: 28 },
        };
        let ys: Vec<i8> = (0..=127).map(|q| gelu_i8(q as i8, gp)).collect();
        for w in ys.windows(2) {
            assert!(w[1] >= w[0], "gelu must be monotone for q >= 0");
        }
        // gelu(0) == 0
        assert_eq!(gelu_i8(0, gp), 0);
        // large negative inputs approach 0 from below
        assert!(gelu_i8(-127, gp) >= -15);
    }

    #[test]
    fn causal_head_helpers_match_manual_dots() {
        let d = 4;
        let lo = d; // head 1 of a 2-head toy row
        let q: Vec<i8> = (0..8).map(|i| i as i8 - 3).collect();
        let rows: Vec<Vec<i8>> = (0..3)
            .map(|r| (0..8).map(|i| ((r * 5 + i * 3) % 17) as i8 - 8).collect())
            .collect();
        let refs: Vec<&[i8]> = rows.iter().map(|r| r.as_slice()).collect();
        let scores = causal_head_scores(&q, &refs, lo, d);
        for (c, row) in rows.iter().enumerate() {
            let want: i32 = (0..d).map(|j| q[lo + j] as i32 * row[lo + j] as i32).sum();
            assert_eq!(scores[c], want, "score col {c}");
        }
        // shorter prefix = causal mask at an earlier position
        assert_eq!(causal_head_scores(&q, &refs[..2], lo, d), scores[..2]);

        let rq = RequantSite { m: 1 << 14, n: 14 }; // identity
        let probs: Vec<i8> = vec![10, 20, 97];
        let ctx = head_context_row(&probs, &refs, lo, d, rq);
        for j in 0..d {
            let acc: i64 = probs
                .iter()
                .zip(&rows)
                .map(|(&p, v)| p as i64 * v[lo + j] as i64)
                .sum();
            assert_eq!(ctx[j], requant8(acc, rq), "ctx col {j}");
        }
    }

    #[test]
    fn kv_cache_grows_by_appended_rows() {
        let mut c = KvCache::default();
        assert!(c.is_empty());
        c.k.push(vec![1i8; 8]);
        c.v.push(vec![2i8; 8]);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn layernorm_row_zero_mean_unit_gamma() {
        let ln = LayerNormParams { kg: 10 };
        let h = 64;
        let gamma = vec![1i64 << 10; h];
        let beta = vec![0i64; h];
        // alternating +-1000 => mean 0, std 1000
        let q: Vec<i64> = (0..h).map(|i| if i % 2 == 0 { 1000 } else { -1000 }).collect();
        let out = layernorm_row(&q, &gamma, &beta, ln);
        // normalized to +-1 at Q10 scale => clip8(round(1024/1024)) = 1
        assert!(out.iter().all(|&v| v == 1 || v == -1));
    }
}
