//! I-BERT on Galapagos (§7): the test application.
//!
//! * [`config`] — geometry + quantisation constants (from quantparams.json;
//!   rust never re-derives a constant from floats — see quantize.py).
//! * [`compute`] — bit-exact integer operators mirroring
//!   `python/compile/iops.py` operation-for-operation.
//! * [`weights`] — the Model File System loader (artifacts/weights).
//! * [`encoder`] — whole-matrix reference forward (golden verification and
//!   the PJRT cross-check).
//! * [`timing`] — PE/tile cycle models behind Table 1 / Figs 16, 20.
//! * [`kernels`] — the streaming kernel behaviors of the Fig. 14 graph.
//! * [`graph`] — construction of the 38-kernel encoder cluster.

pub mod compute;
pub mod config;
pub mod encoder;
pub mod graph;
pub mod kernels;
pub mod timing;
pub mod weights;

pub use config::{EncoderQuant, GeluParams, LayerNormParams, ModelConfig, RequantSite, SoftmaxParams};
pub use weights::ModelParams;
