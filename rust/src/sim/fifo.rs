//! AXIS FIFO accounting (§8.2.1): every kernel front/back FIFO must be
//! sized to hold at least one full matrix to avoid overflow; this is what
//! makes BRAM the limiting resource on the paper's FPGAs.

/// Occupancy tracker for one kernel-input FIFO.
#[derive(Debug, Clone)]
pub struct Fifo {
    pub capacity_bytes: usize,
    pub occupancy: usize,
    pub high_water: usize,
    pub overflows: u64,
}

/// Size of one BRAM18 in bytes (18 Kbit).
pub const BRAM18_BYTES: usize = 18 * 1024 / 8;

impl Fifo {
    pub fn new(capacity_bytes: usize) -> Self {
        Fifo { capacity_bytes, occupancy: 0, high_water: 0, overflows: 0 }
    }

    /// FIFO sized to hold `rows` rows of `row_bytes` (the paper's "at
    /// least one matrix" rule).
    pub fn for_matrix(rows: usize, row_bytes: usize) -> Self {
        Self::new(rows * row_bytes)
    }

    pub fn push(&mut self, bytes: usize) {
        self.occupancy += bytes;
        if self.occupancy > self.capacity_bytes {
            self.overflows += 1;
        }
        self.high_water = self.high_water.max(self.occupancy);
    }

    pub fn pop(&mut self, bytes: usize) {
        self.occupancy = self.occupancy.saturating_sub(bytes);
    }

    /// Worst observed occupancy as a fraction of capacity (> 1 means the
    /// §8.2.1 sizing rule was violated at some point of the run).
    pub fn peak_fraction(&self) -> f64 {
        self.high_water as f64 / self.capacity_bytes.max(1) as f64
    }

    /// Number of BRAM18 blocks this FIFO's capacity consumes.
    pub fn bram18(&self) -> usize {
        self.capacity_bytes.div_ceil(BRAM18_BYTES)
    }

    /// Point-in-time state for the telemetry exporters.
    pub fn snapshot(&self) -> crate::obs::FifoSnapshot {
        crate::obs::FifoSnapshot {
            occupancy: self.occupancy as u64,
            high_water: self.high_water as u64,
            capacity_bytes: self.capacity_bytes as u64,
            overflows: self.overflows,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_matrix_fifo_is_43_brams() {
        // §8.2.1: "for the matrix of dimension 128 x 768, we need about 43
        // 18Kb BRAMs to avoid overflow"
        let f = Fifo::for_matrix(128, 768);
        assert_eq!(f.bram18(), 43);
    }

    #[test]
    fn tracks_high_water_and_overflow() {
        let mut f = Fifo::new(100);
        f.push(60);
        f.push(60);
        assert_eq!(f.overflows, 1);
        assert_eq!(f.high_water, 120);
        assert!((f.peak_fraction() - 1.2).abs() < 1e-12);
        f.pop(100);
        assert_eq!(f.occupancy, 20);
        f.pop(100);
        assert_eq!(f.occupancy, 0); // saturates
        assert!((Fifo::new(0).peak_fraction() - 0.0).abs() < 1e-12, "never divides by zero");
    }

    #[test]
    fn snapshot_mirrors_state() {
        let mut f = Fifo::new(100);
        f.push(60);
        let s = f.snapshot();
        assert_eq!((s.occupancy, s.high_water, s.capacity_bytes, s.overflows), (60, 60, 100, 0));
    }
}
