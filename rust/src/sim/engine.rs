//! The discrete-event engine: kernels are actors; the fabric computes
//! analytic delivery times (one event per packet — see fabric.rs).
//!
//! Hot-path design (DESIGN.md "Event queue and row-burst coalescing"):
//!
//! * destinations resolve through a flat 64K id->slot table filled at
//!   build time — dispatch and send never hash a kernel id;
//! * the scheduler is a calendar wheel (one bucket per cycle over an
//!   8192-cycle horizon) with a binary-heap overflow for far-future
//!   events — O(1) push/pop at the fabric's short-horizon event density,
//!   heap behavior for sparse tails;
//! * same-cycle events dispatch in (kernel slot, push order) — a fixed
//!   arbitration that makes timing independent of how events were
//!   batched, which is what lets burst coalescing stay cycle-exact;
//! * `KernelIo::send_burst` ships a run of consecutive rows as ONE event
//!   whose per-row emission/arrival schedule the fabric computes
//!   analytically (intra-FPGA edges only — `can_burst`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::fxhash::FxHashMap;

use anyhow::{bail, Result};

use super::fabric::{Fabric, FpgaId};
use super::fifo::Fifo;
use super::packet::{Burst, GlobalKernelId, MsgMeta, Packet, Payload, DENSE_IDS};
use super::trace::Trace;

/// Wake tag delivered to every kernel at simulation start.
pub const START_TAG: u64 = u64::MAX;

#[derive(Debug)]
enum Ev {
    Packet(Packet),
    Wake(u64),
}

/// One scheduled event. Dispatch order is the total order
/// (time, target, seq): same-cycle events go in kernel-slot order, and
/// within one kernel in push order.
#[derive(Debug)]
struct QEv {
    time: u64,
    target: u32,
    seq: u64,
    ev: Ev,
}

impl QEv {
    fn key(&self) -> (u64, u32, u64) {
        (self.time, self.target, self.seq)
    }
    fn hole() -> QEv {
        QEv { time: 0, target: 0, seq: 0, ev: Ev::Wake(0) }
    }
}

impl PartialEq for QEv {
    fn eq(&self, o: &Self) -> bool {
        self.key() == o.key()
    }
}
impl Eq for QEv {}
impl PartialOrd for QEv {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for QEv {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.key().cmp(&o.key())
    }
}

const WHEEL_BITS: u32 = 13;
/// Wheel horizon in cycles: events within this window of the current
/// time use O(1) buckets; anything farther falls back to the heap.
const WHEEL_SIZE: u64 = 1 << WHEEL_BITS;
const WHEEL_MASK: u64 = WHEEL_SIZE - 1;
const OCC_WORDS: usize = (WHEEL_SIZE as usize) / 64;

#[derive(Default)]
struct Bucket {
    /// entries sorted by (target, seq); `head` marks the popped prefix.
    items: Vec<QEv>,
    head: usize,
}

/// Calendar-wheel event queue with heap fallback.
struct EventQueue {
    buckets: Vec<Bucket>,
    occ: Vec<u64>,
    /// lower bound on every queued ring time (== last popped time).
    cursor: u64,
    ring_len: usize,
    heap: BinaryHeap<Reverse<QEv>>,
    seq: u64,
    /// route everything through the heap (the reference scheduler).
    heap_only: bool,
}

impl EventQueue {
    fn new() -> Self {
        EventQueue {
            buckets: (0..WHEEL_SIZE).map(|_| Bucket::default()).collect(),
            occ: vec![0u64; OCC_WORDS],
            cursor: 0,
            ring_len: 0,
            heap: BinaryHeap::new(),
            seq: 0,
            heap_only: false,
        }
    }

    fn push(&mut self, time: u64, target: u32, ev: Ev) {
        self.seq += 1;
        let e = QEv { time, target, seq: self.seq, ev };
        if self.heap_only || time < self.cursor || time - self.cursor >= WHEEL_SIZE {
            self.heap.push(Reverse(e));
            return;
        }
        let b = (time & WHEEL_MASK) as usize;
        let bucket = &mut self.buckets[b];
        debug_assert!(
            bucket.head == bucket.items.len() || bucket.items[bucket.head].time == time,
            "wheel bucket holds mixed timestamps"
        );
        let pos =
            bucket.head + bucket.items[bucket.head..].partition_point(|x| x.target <= target);
        bucket.items.insert(pos, e);
        self.occ[b >> 6] |= 1 << (b & 63);
        self.ring_len += 1;
    }

    /// Bucket index of the earliest occupied ring slot, scanning
    /// circularly from the cursor position via the occupancy bitmap.
    fn first_bucket(&self) -> usize {
        let start = (self.cursor & WHEEL_MASK) as usize;
        let sw = start >> 6;
        let masked = self.occ[sw] & (!0u64 << (start & 63));
        if masked != 0 {
            return (sw << 6) | masked.trailing_zeros() as usize;
        }
        for off in 1..=OCC_WORDS {
            let w = (sw + off) % OCC_WORDS;
            if self.occ[w] != 0 {
                return (w << 6) | self.occ[w].trailing_zeros() as usize;
            }
        }
        unreachable!("ring_len > 0 with an empty occupancy bitmap")
    }

    fn ring_peek(&self) -> Option<(usize, (u64, u32, u64))> {
        if self.ring_len == 0 {
            return None;
        }
        let b = self.first_bucket();
        let bucket = &self.buckets[b];
        Some((b, bucket.items[bucket.head].key()))
    }

    fn peek_time(&self) -> Option<u64> {
        let r = self.ring_peek().map(|(_, k)| k);
        let h = self.heap.peek().map(|Reverse(e)| e.key());
        match (r, h) {
            (Some(a), Some(b)) => Some(a.min(b).0),
            (Some(a), None) => Some(a.0),
            (None, Some(b)) => Some(b.0),
            (None, None) => None,
        }
    }

    fn pop(&mut self) -> Option<QEv> {
        let ring = self.ring_peek();
        let heap = self.heap.peek().map(|Reverse(e)| e.key());
        match (ring, heap) {
            (None, None) => None,
            (Some((b, rk)), hk) if hk.is_none_or(|h| rk < h) => {
                let bucket = &mut self.buckets[b];
                let e = std::mem::replace(&mut bucket.items[bucket.head], QEv::hole());
                bucket.head += 1;
                if bucket.head == bucket.items.len() {
                    bucket.items.clear();
                    bucket.head = 0;
                    self.occ[b >> 6] &= !(1 << (b & 63));
                }
                self.ring_len -= 1;
                self.cursor = e.time;
                Some(e)
            }
            _ => {
                let Reverse(e) = self.heap.pop().unwrap();
                if e.time > self.cursor {
                    self.cursor = e.time;
                }
                Some(e)
            }
        }
    }
}

/// Behavior of one streaming kernel (the paper's HLS kernel body).
/// `Send` so whole simulations can run on worker threads (parallel
/// sweeps and placer replays).
pub trait KernelBehavior: Send {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo);
    fn on_wake(&mut self, tag: u64, io: &mut KernelIo);
    fn name(&self) -> String {
        "kernel".to_string()
    }
}

/// The side-effect interface handed to behaviors.
pub struct KernelIo<'a> {
    pub now: u64,
    pub self_id: GlobalKernelId,
    /// dense trace slot of this kernel (stats resolved once per dispatch).
    tslot: usize,
    coalescing: bool,
    fabric: &'a mut Fabric,
    fifo: &'a mut Fifo,
    trace: &'a mut Trace,
    slot16: &'a [u32],
    /// (arrival_time, destination slot, event)
    pending: Vec<(u64, u32, Ev)>,
    wakes: Vec<(u64, u64)>,
    errors: &'a mut Vec<String>,
}

impl KernelIo<'_> {
    #[inline]
    fn resolve(&self, dst: GlobalKernelId) -> Option<u32> {
        match self.slot16[dst.dense()] {
            0 => None,
            s => Some(s - 1),
        }
    }

    /// Send a payload to `dst` (any kernel, any cluster). The sender-side
    /// GMI protocol is applied automatically: an inter-cluster destination
    /// is rewritten to the destination cluster's gateway with the one-byte
    /// GMI header carrying the final kernel id (§4, §5.2 — the "GMI Header
    /// Attacher" on the kernel's output stream).
    pub fn send(&mut self, dst: GlobalKernelId, meta: MsgMeta, payload: Payload) {
        let mut pkt = Packet::new(self.self_id, dst, meta, payload);
        if pkt.inter_cluster {
            pkt.gmi_dst = Some(dst.kernel);
            pkt.dst = GlobalKernelId::gateway_of(dst.cluster);
        }
        self.send_raw(pkt);
    }

    /// Send a pre-built packet without sender-side rewriting (used by the
    /// gateway's forwarding module, which must preserve headers).
    pub fn send_raw(&mut self, pkt: Packet) {
        debug_assert!(pkt.burst.is_none(), "use send_burst for coalesced runs");
        match self.fabric.deliver(self.now, &pkt) {
            Ok(Some(arrival)) => {
                self.trace.on_tx_slot(self.tslot, self.now);
                match self.resolve(pkt.dst) {
                    Some(slot) => self.pending.push((arrival, slot, Ev::Packet(pkt))),
                    None => self.errors.push(format!("send to unknown kernel {}", pkt.dst)),
                }
            }
            Ok(None) => {
                // dropped by the lossy network: accounted in fabric stats
                self.trace.on_tx_slot(self.tslot, self.now);
            }
            Err(e) => self.errors.push(e.to_string()),
        }
    }

    /// True when a run of rows to `dst` may be coalesced into one burst:
    /// same cluster, same FPGA (the only serializing resource on the path
    /// is this kernel's exclusive egress port), and coalescing enabled.
    pub fn can_burst(&self, dst: GlobalKernelId) -> bool {
        self.coalescing
            && dst.cluster == self.self_id.cluster
            && self.fabric.same_fpga(self.self_id, dst)
    }

    /// Ship consecutive rows `meta.row ..` of one stream as a single
    /// coalesced event. `emit_times` (nondecreasing, all >= now) are the
    /// per-row emission cycles; `head` is row `meta.row`'s payload and
    /// `tail` the rest. Caller must have checked [`KernelIo::can_burst`].
    pub fn send_burst(
        &mut self,
        dst: GlobalKernelId,
        meta: MsgMeta,
        emit_times: Vec<u64>,
        head: Payload,
        tail: Vec<Payload>,
    ) {
        debug_assert_eq!(tail.len() + 1, emit_times.len());
        debug_assert!(self.can_burst(dst), "send_burst to a non-coalescible destination");
        debug_assert!(emit_times[0] >= self.now);
        debug_assert!(tail.iter().all(|p| p.bytes() == head.bytes()));
        let mut pkt = Packet::new(self.self_id, dst, meta, head);
        pkt.burst = Some(Box::new(Burst { emit_times, arrivals: Vec::new(), tail }));
        match self.fabric.deliver_burst(&pkt) {
            Ok(arrivals) => {
                let first = arrivals[0];
                let b = pkt.burst.as_mut().unwrap();
                self.trace.on_tx_burst(self.tslot, &b.emit_times);
                b.arrivals = arrivals;
                match self.resolve(pkt.dst) {
                    Some(slot) => self.pending.push((first, slot, Ev::Packet(pkt))),
                    None => self.errors.push(format!("send to unknown kernel {}", pkt.dst)),
                }
            }
            Err(e) => self.errors.push(e.to_string()),
        }
    }

    /// Visit each row of `pkt` as `(io, meta, arrival, payload)`,
    /// mirroring per-packet delivery for coalesced runs: the row's wire
    /// bytes enter the input FIFO just before the row is handed over (the
    /// engine already accounted the single-packet case).
    pub fn rows<F: FnMut(&mut KernelIo<'_>, MsgMeta, u64, Payload)>(
        &mut self,
        pkt: Packet,
        mut f: F,
    ) {
        let wire = pkt.wire_bytes();
        let single = pkt.burst.is_none();
        let now = self.now;
        let io = self;
        pkt.for_each_row(now, |meta, at, payload| {
            if !single {
                io.fifo.push(wire);
            }
            f(io, meta, at, payload);
        });
    }

    /// Schedule `on_wake(tag)` after `delay` cycles.
    pub fn wake_in(&mut self, delay: u64, tag: u64) {
        self.wakes.push((self.now + delay, tag));
    }

    /// Mark `bytes` drained from this kernel's input FIFO.
    pub fn consume(&mut self, bytes: usize) {
        self.fifo.pop(bytes);
    }
}

struct Slot {
    id: GlobalKernelId,
    behavior: Box<dyn KernelBehavior>,
    fifo: Fifo,
    tslot: usize,
}

/// The simulator: kernels + fabric + event queue.
pub struct Sim {
    pub time: u64,
    queue: EventQueue,
    pub fabric: Fabric,
    kernels: Vec<Slot>,
    index: FxHashMap<GlobalKernelId, usize>,
    /// dense id -> kernel slot + 1 (send/dispatch resolution).
    slot16: Box<[u32]>,
    pub trace: Trace,
    pub errors: Vec<String>,
    /// hard event budget (runaway guard)
    pub max_events: u64,
    /// intra-FPGA row-burst coalescing (on by default; `reference_mode`
    /// disables it for golden-determinism comparisons).
    pub coalescing: bool,
    // reusable dispatch buffers (avoid per-event allocation)
    pending_buf: Vec<(u64, u32, Ev)>,
    wakes_buf: Vec<(u64, u64)>,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Self {
        Sim {
            time: 0,
            queue: EventQueue::new(),
            fabric: Fabric::new(),
            kernels: Vec::new(),
            index: FxHashMap::default(),
            slot16: vec![0u32; DENSE_IDS].into_boxed_slice(),
            trace: Trace::default(),
            errors: Vec::new(),
            max_events: 500_000_000,
            coalescing: true,
            pending_buf: Vec::new(),
            wakes_buf: Vec::new(),
        }
    }

    /// Put the simulator in the pre-optimization reference configuration:
    /// no row-burst coalescing, pure binary-heap scheduling. Timing and
    /// functional outputs are contractually identical to the default
    /// engine (rust/tests/proptests.rs golden-determinism properties);
    /// only the event count and wall-clock differ.
    pub fn reference_mode(&mut self) {
        self.coalescing = false;
        self.queue.heap_only = true;
    }

    /// Register a kernel on an FPGA with the given input FIFO.
    pub fn add_kernel(
        &mut self,
        id: GlobalKernelId,
        fpga: FpgaId,
        fifo: Fifo,
        behavior: Box<dyn KernelBehavior>,
    ) -> Result<()> {
        if self.index.contains_key(&id) {
            bail!("kernel {id} registered twice");
        }
        self.fabric.place(id, fpga);
        self.index.insert(id, self.kernels.len());
        self.slot16[id.dense()] = self.kernels.len() as u32 + 1;
        let tslot = self.trace.register(id);
        self.kernels.push(Slot { id, behavior, fifo, tslot });
        Ok(())
    }

    pub fn kernel_count(&self) -> usize {
        self.kernels.len()
    }

    pub fn fifo_of(&self, id: GlobalKernelId) -> Option<&Fifo> {
        self.index.get(&id).map(|&i| &self.kernels[i].fifo)
    }

    /// Deliver the START wake to every kernel at t=0.
    pub fn start(&mut self) {
        for i in 0..self.kernels.len() {
            self.queue.push(0, i as u32, Ev::Wake(START_TAG));
        }
    }

    /// Inject a packet from "outside" (e.g. a test harness) at time t.
    pub fn inject(&mut self, t: u64, pkt: Packet) -> Result<()> {
        let slot = match self.slot16[pkt.dst.dense()] {
            0 => bail!("inject: unknown destination {}", pkt.dst),
            s => s - 1,
        };
        self.queue.push(t, slot, Ev::Packet(pkt));
        Ok(())
    }

    /// Run until the queue drains or `until` cycles elapse.
    ///
    /// Note on pausing with coalescing enabled: a burst event is
    /// delivered atomically at its FIRST row's arrival, so a pause may
    /// observe rx stats/probe entries for rows whose (exact) arrival
    /// times lie beyond `until` — final results are unaffected (the
    /// golden-determinism contract covers completed runs). Use
    /// `reference_mode` when inspecting mid-run state at a cycle
    /// boundary matters.
    pub fn run_until(&mut self, until: u64) -> Result<u64> {
        let mut processed = 0u64;
        while let Some(t) = self.queue.peek_time() {
            if t > until {
                break;
            }
            let e = self.queue.pop().unwrap();
            self.dispatch(e)?;
            processed += 1;
            if self.trace.events_processed > self.max_events {
                bail!("event budget exceeded ({} events)", self.max_events);
            }
            if !self.errors.is_empty() {
                bail!("simulation error: {}", self.errors.join("; "));
            }
        }
        Ok(processed)
    }

    /// Run to quiescence.
    pub fn run(&mut self) -> Result<u64> {
        self.run_until(u64::MAX)
    }

    fn dispatch(&mut self, entry: QEv) -> Result<()> {
        debug_assert!(entry.time >= self.time, "time went backwards");
        self.time = entry.time;
        self.trace.events_processed += 1;

        let target = entry.target;
        let slot = &mut self.kernels[target as usize];
        let tslot = slot.tslot;
        self.pending_buf.clear();
        self.wakes_buf.clear();
        let mut io = KernelIo {
            now: self.time,
            self_id: slot.id,
            tslot,
            coalescing: self.coalescing,
            fabric: &mut self.fabric,
            fifo: &mut slot.fifo,
            trace: &mut self.trace,
            slot16: &self.slot16,
            pending: std::mem::take(&mut self.pending_buf),
            wakes: std::mem::take(&mut self.wakes_buf),
            errors: &mut self.errors,
        };

        match entry.ev {
            Ev::Packet(pkt) => {
                match pkt.burst.as_ref() {
                    None => {
                        io.fifo.push(pkt.wire_bytes());
                        io.trace.on_rx_slot(tslot, io.now);
                        if io.trace.probe_slot(tslot) {
                            io.trace.record_probe_slot(tslot, io.now);
                        }
                    }
                    Some(b) => {
                        // per-row rx accounting at the analytic arrival
                        // times; FIFO bytes enter row-by-row inside
                        // `KernelIo::rows` so occupancy stays row-paced
                        let probe = io.trace.probe_slot(tslot);
                        for &a in &b.arrivals {
                            io.trace.on_rx_slot(tslot, a);
                            if probe {
                                io.trace.record_probe_slot(tslot, a);
                            }
                        }
                    }
                }
                slot.behavior.on_packet(pkt, &mut io);
            }
            Ev::Wake(tag) => {
                io.trace.wake_slot(tslot);
                slot.behavior.on_wake(tag, &mut io);
            }
        }

        let mut pending = std::mem::take(&mut io.pending);
        let mut wakes = std::mem::take(&mut io.wakes);
        for (t, dst_slot, ev) in pending.drain(..) {
            self.queue.push(t, dst_slot, ev);
        }
        for (t, tag) in wakes.drain(..) {
            self.queue.push(t, target, Ev::Wake(tag));
        }
        // hand the buffers back for the next dispatch
        self.pending_buf = pending;
        self.wakes_buf = wakes;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::SwitchId;

    /// Emits `n` rows to `dst`, one every `gap` cycles.
    struct Source {
        dst: GlobalKernelId,
        n: u32,
        gap: u64,
        sent: u32,
    }
    impl KernelBehavior for Source {
        fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
        fn on_wake(&mut self, _tag: u64, io: &mut KernelIo) {
            if self.sent < self.n {
                let meta =
                    MsgMeta { stream: 0, row: self.sent, rows: self.n, inference: 0 };
                io.send(self.dst, meta, Payload::Timing(768));
                self.sent += 1;
                io.wake_in(self.gap, 1);
            }
        }
    }

    /// Counts arrivals; consumes immediately.
    struct Sink {
        got: u32,
    }
    impl KernelBehavior for Sink {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            self.got += pkt.rows_in_packet() as u32;
            io.consume(pkt.wire_bytes() * pkt.rows_in_packet());
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    #[test]
    fn source_to_sink_delivers_all() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), Box::new(Source {
            dst: k(0, 2), n: 10, gap: 12, sent: 0,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
            .unwrap();
        sim.trace.add_probe(k(0, 2));
        sim.start();
        sim.run().unwrap();
        let st = sim.trace.kernel(k(0, 2)).unwrap();
        assert_eq!(st.rx_packets, 10);
        let (x, t, i) = sim.trace.xti(k(0, 2)).unwrap();
        assert!(x > 0);
        assert_eq!(i, 12, "line-rate packets arrive every 12 cycles");
        assert_eq!(t - x, 9 * 12);
    }

    #[test]
    fn wake_ordering_is_deterministic() {
        struct Recorder {
            seen: Vec<u64>,
        }
        impl KernelBehavior for Recorder {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    // schedule in scrambled order, same target time
                    io.wake_in(5, 1);
                    io.wake_in(5, 2);
                    io.wake_in(3, 3);
                } else {
                    self.seen.push(tag);
                }
            }
        }
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1024), Box::new(Recorder { seen: vec![] }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        // tag 3 at t=3 first; tags 1,2 at t=5 in insertion order
        // (we can't easily read back the box; rerun pattern asserted via trace)
        assert_eq!(sim.trace.kernel(k(0, 1)).unwrap().wakes, 4);
        assert_eq!(sim.time, 5);
    }

    #[test]
    fn inter_cluster_send_goes_via_gateway() {
        struct Fwd;
        impl KernelBehavior for Fwd {
            fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
                // minimal gateway: decode GMI header, forward locally
                let final_dst = GlobalKernelId::new(io.self_id.cluster, pkt.gmi_dst.unwrap());
                io.consume(pkt.wire_bytes());
                let mut fwd = pkt;
                fwd.src = io.self_id;
                fwd.dst = final_dst;
                fwd.inter_cluster = false;
                fwd.gmi_dst = None;
                io.send_raw(fwd);
            }
            fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
        }
        struct Once {
            dst: GlobalKernelId,
        }
        impl KernelBehavior for Once {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    io.send(self.dst, MsgMeta::default(), Payload::Timing(100));
                }
            }
        }
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1024), Box::new(Once { dst: k(1, 5) }))
            .unwrap();
        sim.add_kernel(k(1, 0), FpgaId(1), Fifo::new(1024), Box::new(Fwd)).unwrap();
        sim.add_kernel(k(1, 5), FpgaId(1), Fifo::new(1024), Box::new(Sink { got: 0 }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        // the gateway relayed it: final kernel got exactly one packet
        assert_eq!(sim.trace.kernel(k(1, 5)).unwrap().rx_packets, 1);
        assert_eq!(sim.trace.kernel(k(1, 0)).unwrap().rx_packets, 1);
    }

    #[test]
    fn duplicate_registration_fails() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        assert!(sim
            .add_kernel(k(0, 1), FpgaId(0), Fifo::new(1), Box::new(Sink { got: 0 }))
            .is_ok());
        assert!(sim
            .add_kernel(k(0, 1), FpgaId(0), Fifo::new(1), Box::new(Sink { got: 0 }))
            .is_err());
    }

    #[test]
    fn far_future_wakes_use_the_heap_fallback() {
        // delays far beyond the wheel horizon must still fire in order
        struct LongWaits {
            fired: Vec<u64>,
        }
        impl KernelBehavior for LongWaits {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    io.wake_in(3 * WHEEL_SIZE, 1);
                    io.wake_in(10, 2);
                    io.wake_in(WHEEL_SIZE + 7, 3);
                } else {
                    self.fired.push(tag);
                    if tag == 2 {
                        // from t=10, the horizon covers tag 3's time
                        io.wake_in(1, 4);
                    }
                }
            }
        }
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(64), Box::new(LongWaits { fired: vec![] }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        assert_eq!(sim.time, 3 * WHEEL_SIZE);
        assert_eq!(sim.trace.kernel(k(0, 1)).unwrap().wakes, 5);
    }

    #[test]
    fn run_until_pauses_and_resumes() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), Box::new(Source {
            dst: k(0, 2), n: 100, gap: 50, sent: 0,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
            .unwrap();
        sim.start();
        let a = sim.run_until(500).unwrap();
        assert!(sim.time <= 500);
        let b = sim.run().unwrap();
        assert!(a > 0 && b > 0);
        assert_eq!(sim.trace.kernel(k(0, 2)).unwrap().rx_packets, 100);
    }

    #[test]
    fn send_burst_arrivals_match_per_row_sends() {
        // one kernel ships 4 rows as a burst; a reference sim sends the
        // same rows individually at the same emission times
        struct BurstTx {
            dst: GlobalKernelId,
        }
        impl KernelBehavior for BurstTx {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    assert!(io.can_burst(self.dst));
                    let meta = MsgMeta { stream: 0, row: 0, rows: 4, inference: 0 };
                    io.send_burst(
                        self.dst,
                        meta,
                        vec![0, 5, 10, 15],
                        Payload::Timing(768),
                        vec![Payload::Timing(768); 3],
                    );
                }
            }
        }
        struct RowTx {
            dst: GlobalKernelId,
            sent: u32,
        }
        impl KernelBehavior for RowTx {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if (tag == START_TAG || tag == 1) && self.sent < 4 {
                    let meta = MsgMeta { stream: 0, row: self.sent, rows: 4, inference: 0 };
                    io.send(self.dst, meta, Payload::Timing(768));
                    self.sent += 1;
                    io.wake_in(5, 1);
                }
            }
        }
        let run = |burst: bool| -> Vec<u64> {
            let mut sim = Sim::new();
            sim.fabric.attach(FpgaId(0), SwitchId(0));
            let b: Box<dyn KernelBehavior> = if burst {
                Box::new(BurstTx { dst: k(0, 2) })
            } else {
                Box::new(RowTx { dst: k(0, 2), sent: 0 })
            };
            sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), b).unwrap();
            sim.add_kernel(k(0, 2), FpgaId(0), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
                .unwrap();
            sim.trace.add_probe(k(0, 2));
            sim.start();
            sim.run().unwrap();
            sim.trace.probe_times(k(0, 2)).unwrap().to_vec()
        };
        let coalesced = run(true);
        let reference = run(false);
        assert_eq!(coalesced, reference);
        assert_eq!(coalesced.len(), 4);
    }
}
