//! The discrete-event engine: kernels are actors; the fabric computes
//! analytic delivery times (one event per packet — see fabric.rs).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::fxhash::FxHashMap;

use anyhow::{bail, Result};

use super::fabric::{Fabric, FpgaId};
use super::fifo::Fifo;
use super::packet::{GlobalKernelId, MsgMeta, Packet, Payload};
use super::trace::Trace;

/// Wake tag delivered to every kernel at simulation start.
pub const START_TAG: u64 = u64::MAX;

#[derive(Debug)]
enum Ev {
    Packet(Packet),
    Wake(u64),
}

struct EventEntry {
    time: u64,
    seq: u64,
    target: usize,
    ev: Ev,
}

impl PartialEq for EventEntry {
    fn eq(&self, o: &Self) -> bool {
        (self.time, self.seq) == (o.time, o.seq)
    }
}
impl Eq for EventEntry {}
impl PartialOrd for EventEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for EventEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(o.time, o.seq))
    }
}

/// Behavior of one streaming kernel (the paper's HLS kernel body).
pub trait KernelBehavior {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo);
    fn on_wake(&mut self, tag: u64, io: &mut KernelIo);
    fn name(&self) -> String {
        "kernel".to_string()
    }
}

/// The side-effect interface handed to behaviors.
pub struct KernelIo<'a> {
    pub now: u64,
    pub self_id: GlobalKernelId,
    fabric: &'a mut Fabric,
    fifo: &'a mut Fifo,
    trace: &'a mut Trace,
    /// (arrival_time, destination, event)
    pending: Vec<(u64, GlobalKernelId, Ev)>,
    wakes: Vec<(u64, u64)>,
    errors: &'a mut Vec<String>,
}

impl KernelIo<'_> {
    /// Send a payload to `dst` (any kernel, any cluster). The sender-side
    /// GMI protocol is applied automatically: an inter-cluster destination
    /// is rewritten to the destination cluster's gateway with the one-byte
    /// GMI header carrying the final kernel id (§4, §5.2 — the "GMI Header
    /// Attacher" on the kernel's output stream).
    pub fn send(&mut self, dst: GlobalKernelId, meta: MsgMeta, payload: Payload) {
        let mut pkt = Packet::new(self.self_id, dst, meta, payload);
        if pkt.inter_cluster {
            pkt.gmi_dst = Some(dst.kernel);
            pkt.dst = GlobalKernelId::gateway_of(dst.cluster);
        }
        self.send_raw(pkt);
    }

    /// Send a pre-built packet without sender-side rewriting (used by the
    /// gateway's forwarding module, which must preserve headers).
    pub fn send_raw(&mut self, pkt: Packet) {
        match self.fabric.deliver(self.now, &pkt) {
            Ok(Some(arrival)) => {
                self.trace.stats(self.self_id).on_tx(self.now);
                let dst = pkt.dst;
                self.pending.push((arrival, dst, Ev::Packet(pkt)));
            }
            Ok(None) => {
                // dropped by the lossy network: accounted in fabric stats
                self.trace.stats(self.self_id).on_tx(self.now);
            }
            Err(e) => self.errors.push(e.to_string()),
        }
    }

    /// Schedule `on_wake(tag)` after `delay` cycles.
    pub fn wake_in(&mut self, delay: u64, tag: u64) {
        self.wakes.push((self.now + delay, tag));
    }

    /// Mark `bytes` drained from this kernel's input FIFO.
    pub fn consume(&mut self, bytes: usize) {
        self.fifo.pop(bytes);
    }
}

struct Slot {
    id: GlobalKernelId,
    behavior: Box<dyn KernelBehavior>,
    fifo: Fifo,
}

/// The simulator: kernels + fabric + event queue.
pub struct Sim {
    pub time: u64,
    seq: u64,
    heap: BinaryHeap<Reverse<EventEntry>>,
    pub fabric: Fabric,
    kernels: Vec<Slot>,
    index: FxHashMap<GlobalKernelId, usize>,
    pub trace: Trace,
    pub errors: Vec<String>,
    /// hard event budget (runaway guard)
    pub max_events: u64,
    // reusable dispatch buffers (avoid per-event allocation)
    pending_buf: Vec<(u64, GlobalKernelId, Ev)>,
    wakes_buf: Vec<(u64, u64)>,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Self {
        Sim {
            time: 0,
            seq: 0,
            heap: BinaryHeap::new(),
            fabric: Fabric::new(),
            kernels: Vec::new(),
            index: FxHashMap::default(),
            trace: Trace::default(),
            errors: Vec::new(),
            max_events: 500_000_000,
            pending_buf: Vec::new(),
            wakes_buf: Vec::new(),
        }
    }

    /// Register a kernel on an FPGA with the given input FIFO.
    pub fn add_kernel(
        &mut self,
        id: GlobalKernelId,
        fpga: FpgaId,
        fifo: Fifo,
        behavior: Box<dyn KernelBehavior>,
    ) -> Result<()> {
        if self.index.contains_key(&id) {
            bail!("kernel {id} registered twice");
        }
        self.fabric.place(id, fpga);
        self.index.insert(id, self.kernels.len());
        self.kernels.push(Slot { id, behavior, fifo });
        Ok(())
    }

    pub fn kernel_count(&self) -> usize {
        self.kernels.len()
    }

    pub fn fifo_of(&self, id: GlobalKernelId) -> Option<&Fifo> {
        self.index.get(&id).map(|&i| &self.kernels[i].fifo)
    }

    /// Deliver the START wake to every kernel at t=0.
    pub fn start(&mut self) {
        for i in 0..self.kernels.len() {
            self.push_event(0, i, Ev::Wake(START_TAG));
        }
    }

    /// Inject a packet from "outside" (e.g. a test harness) at time t.
    pub fn inject(&mut self, t: u64, pkt: Packet) -> Result<()> {
        let Some(&idx) = self.index.get(&pkt.dst) else {
            bail!("inject: unknown destination {}", pkt.dst)
        };
        self.push_event(t, idx, Ev::Packet(pkt));
        Ok(())
    }

    fn push_event(&mut self, time: u64, target: usize, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse(EventEntry { time, seq: self.seq, target, ev }));
    }

    /// Run until the queue drains or `until` cycles elapse.
    pub fn run_until(&mut self, until: u64) -> Result<u64> {
        let mut processed = 0u64;
        while let Some(Reverse(entry)) = self.heap.peek().map(|e| Reverse(&e.0)) {
            if entry.time > until {
                break;
            }
            let Reverse(entry) = self.heap.pop().unwrap();
            self.dispatch(entry)?;
            processed += 1;
            if self.trace.events_processed > self.max_events {
                bail!("event budget exceeded ({} events)", self.max_events);
            }
            if !self.errors.is_empty() {
                bail!("simulation error: {}", self.errors.join("; "));
            }
        }
        Ok(processed)
    }

    /// Run to quiescence.
    pub fn run(&mut self) -> Result<u64> {
        self.run_until(u64::MAX)
    }

    fn dispatch(&mut self, entry: EventEntry) -> Result<()> {
        debug_assert!(entry.time >= self.time, "time went backwards");
        self.time = entry.time;
        self.trace.events_processed += 1;

        let slot = &mut self.kernels[entry.target];
        self.pending_buf.clear();
        self.wakes_buf.clear();
        let mut io = KernelIo {
            now: self.time,
            self_id: slot.id,
            fabric: &mut self.fabric,
            fifo: &mut slot.fifo,
            trace: &mut self.trace,
            pending: std::mem::take(&mut self.pending_buf),
            wakes: std::mem::take(&mut self.wakes_buf),
            errors: &mut self.errors,
        };

        match entry.ev {
            Ev::Packet(pkt) => {
                io.fifo.push(pkt.wire_bytes());
                io.trace.stats(slot.id).on_rx(io.now);
                if io.trace.is_probe(slot.id) {
                    io.trace.record_probe(slot.id, io.now);
                }
                slot.behavior.on_packet(pkt, &mut io);
            }
            Ev::Wake(tag) => {
                io.trace.stats(slot.id).wakes += 1;
                slot.behavior.on_wake(tag, &mut io);
            }
        }

        let mut pending = std::mem::take(&mut io.pending);
        let mut wakes = std::mem::take(&mut io.wakes);
        let target = entry.target;
        for (t, dst, ev) in pending.drain(..) {
            match self.index.get(&dst) {
                Some(&i) => self.push_event(t, i, ev),
                None => bail!("send to unknown kernel {dst}"),
            }
        }
        for (t, tag) in wakes.drain(..) {
            self.push_event(t, target, Ev::Wake(tag));
        }
        // hand the buffers back for the next dispatch
        self.pending_buf = pending;
        self.wakes_buf = wakes;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::SwitchId;

    /// Emits `n` rows to `dst`, one every `gap` cycles.
    struct Source {
        dst: GlobalKernelId,
        n: u32,
        gap: u64,
        sent: u32,
    }
    impl KernelBehavior for Source {
        fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
        fn on_wake(&mut self, _tag: u64, io: &mut KernelIo) {
            if self.sent < self.n {
                let meta =
                    MsgMeta { stream: 0, row: self.sent, rows: self.n, inference: 0 };
                io.send(self.dst, meta, Payload::Timing(768));
                self.sent += 1;
                io.wake_in(self.gap, 1);
            }
        }
    }

    /// Counts arrivals; consumes immediately.
    struct Sink {
        got: u32,
    }
    impl KernelBehavior for Sink {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            self.got += 1;
            io.consume(pkt.wire_bytes());
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    #[test]
    fn source_to_sink_delivers_all() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), Box::new(Source {
            dst: k(0, 2), n: 10, gap: 12, sent: 0,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
            .unwrap();
        sim.trace.add_probe(k(0, 2));
        sim.start();
        sim.run().unwrap();
        let st = sim.trace.kernels.get(&k(0, 2)).unwrap();
        assert_eq!(st.rx_packets, 10);
        let (x, t, i) = sim.trace.xti(k(0, 2)).unwrap();
        assert!(x > 0);
        assert_eq!(i, 12, "line-rate packets arrive every 12 cycles");
        assert_eq!(t - x, 9 * 12);
    }

    #[test]
    fn wake_ordering_is_deterministic() {
        struct Recorder {
            seen: Vec<u64>,
        }
        impl KernelBehavior for Recorder {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    // schedule in scrambled order, same target time
                    io.wake_in(5, 1);
                    io.wake_in(5, 2);
                    io.wake_in(3, 3);
                } else {
                    self.seen.push(tag);
                }
            }
        }
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1024), Box::new(Recorder { seen: vec![] }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        // tag 3 at t=3 first; tags 1,2 at t=5 in insertion order
        // (we can't easily read back the box; rerun pattern asserted via trace)
        assert_eq!(sim.trace.kernels.get(&k(0, 1)).unwrap().wakes, 4);
        assert_eq!(sim.time, 5);
    }

    #[test]
    fn inter_cluster_send_goes_via_gateway() {
        struct Fwd;
        impl KernelBehavior for Fwd {
            fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
                // minimal gateway: decode GMI header, forward locally
                let final_dst = GlobalKernelId::new(io.self_id.cluster, pkt.gmi_dst.unwrap());
                io.consume(pkt.wire_bytes());
                let mut fwd = pkt;
                fwd.src = io.self_id;
                fwd.dst = final_dst;
                fwd.inter_cluster = false;
                fwd.gmi_dst = None;
                io.send_raw(fwd);
            }
            fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
        }
        struct Once {
            dst: GlobalKernelId,
        }
        impl KernelBehavior for Once {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    io.send(self.dst, MsgMeta::default(), Payload::Timing(100));
                }
            }
        }
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1024), Box::new(Once { dst: k(1, 5) }))
            .unwrap();
        sim.add_kernel(k(1, 0), FpgaId(1), Fifo::new(1024), Box::new(Fwd)).unwrap();
        sim.add_kernel(k(1, 5), FpgaId(1), Fifo::new(1024), Box::new(Sink { got: 0 }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        // the gateway relayed it: final kernel got exactly one packet
        assert_eq!(sim.trace.kernels.get(&k(1, 5)).unwrap().rx_packets, 1);
        assert_eq!(sim.trace.kernels.get(&k(1, 0)).unwrap().rx_packets, 1);
    }

    #[test]
    fn duplicate_registration_fails() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        assert!(sim
            .add_kernel(k(0, 1), FpgaId(0), Fifo::new(1), Box::new(Sink { got: 0 }))
            .is_ok());
        assert!(sim
            .add_kernel(k(0, 1), FpgaId(0), Fifo::new(1), Box::new(Sink { got: 0 }))
            .is_err());
    }
}
