//! The discrete-event engine: kernels are actors; the fabric computes
//! analytic delivery times (one event per packet — see fabric.rs).
//!
//! Hot-path design (DESIGN.md "Event queue and row-burst coalescing",
//! "Parallel simulation: shards, lookahead, and determinism"):
//!
//! * destinations resolve through a flat 64K id->slot table filled at
//!   build time — dispatch and send never hash a kernel id;
//! * the scheduler is a calendar wheel (one bucket per cycle over an
//!   8192-cycle horizon) with a binary-heap overflow for far-future
//!   events — O(1) push/pop at the fabric's short-horizon event density,
//!   heap behavior for sparse tails;
//! * same-cycle events dispatch in (kernel slot, push order) — a fixed
//!   arbitration that makes timing independent of how events were
//!   batched, which is what lets burst coalescing stay cycle-exact.
//!   "Push order" is encoded as an explicit causal `Rank`
//!   (kind, send cycle, sender slot, counter) rather than one global
//!   counter, so the sharded parallel engine (shard.rs) can reproduce
//!   the exact same total order without cross-thread coordination;
//! * `KernelIo::send_burst` ships a run of consecutive rows as ONE event
//!   whose per-row emission/arrival schedule the fabric computes
//!   analytically (intra-FPGA edges only — `can_burst`);
//! * `Sim::run` transparently shards the fleet across worker threads at
//!   inter-FPGA link boundaries when `threads != 1` (see shard.rs);
//!   `threads = 1` is the exact sequential engine and `reference_mode`
//!   the pre-optimization heap engine — all three are contractually
//!   cycle- and trace-identical (rust/tests/proptests.rs). Lossy drops,
//!   reliable ack/retransmit transport, and §6 failure injection all run
//!   on the sharded engine too: drop decisions come from per-link RNG
//!   streams (fabric.rs) and failure runs execute in phases around the
//!   outage window (`run_phased_failure`), so there is no sequential
//!   fallback left beyond `threads = 1` itself.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::fxhash::FxHashMap;
use crate::util::pool;

use anyhow::{bail, ensure, Result};

use super::fabric::{Fabric, FpgaId};
use super::fifo::Fifo;
use super::packet::{Burst, GlobalKernelId, MsgMeta, Packet, Payload, DENSE_IDS};
use super::params::RETX_TIMEOUT;
use super::shard::{self, ShardGranularity, ShardPlan};
use super::trace::Trace;

/// Wake tag delivered to every kernel at simulation start.
pub const START_TAG: u64 = u64::MAX;

#[derive(Debug)]
pub(crate) enum Ev {
    Packet(Packet),
    Wake(u64),
}

/// Deterministic tie-break for same-`(time, target)` events — the
/// engine's "push order", made explicit so it can be computed identically
/// by the sequential engine and by every shard of the parallel engine.
///
/// Ordering is lexicographic over the fields:
///
/// * `kind` — genesis events (`start` wakes, pre-run `inject`s) sort
///   before any dispatch emission, exactly as their pushes precede every
///   dispatch in the sequential engine;
/// * `(send_time, sender)` — emissions from different dispatches compare
///   by their sender dispatch's own pop order. Pops leave the priority
///   queue sorted by `(time, target, rank)`, so `(send_time, sender
///   slot)` reproduces the global-counter order whenever the two senders
///   differ — and two *shards* never share a sender slot;
/// * `ctr` — a per-engine (per-shard) monotone counter breaking the one
///   remaining tie: two emissions of the same kernel at the same cycle
///   (two dispatches, or two sends of one dispatch), which is inherently
///   shard-local.
///
/// The equivalence of this order with the previous global push counter
/// was additionally cross-validated exhaustively on randomized
/// tie-adversarial workloads (sequential-vs-rank-vs-sharded trace
/// equality; see DESIGN.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) struct Rank {
    /// 0 = genesis (pre-run push), 1 = dispatch emission.
    kind: u8,
    /// Cycle of the emitting dispatch (0 for genesis).
    send_time: u64,
    /// Global kernel slot of the emitter (0 for genesis).
    sender: u32,
    /// Monotone per-engine-partition push counter.
    ctr: u64,
}

impl Rank {
    pub(crate) fn genesis(ctr: u64) -> Rank {
        Rank { kind: 0, send_time: 0, sender: 0, ctr }
    }
    pub(crate) fn emission(send_time: u64, sender: u32, ctr: u64) -> Rank {
        Rank { kind: 1, send_time, sender, ctr }
    }
}

/// One scheduled event. Dispatch order is the total order
/// (time, target, rank): same-cycle events go in kernel-slot order, and
/// within one kernel in push order (see [`Rank`]).
#[derive(Debug)]
pub(crate) struct QEv {
    pub(crate) time: u64,
    /// global kernel slot of the destination
    pub(crate) target: u32,
    pub(crate) rank: Rank,
    pub(crate) ev: Ev,
}

impl QEv {
    fn key(&self) -> (u64, u32, Rank) {
        (self.time, self.target, self.rank)
    }
    fn hole() -> QEv {
        QEv { time: 0, target: 0, rank: Rank::genesis(0), ev: Ev::Wake(0) }
    }
}

impl PartialEq for QEv {
    fn eq(&self, o: &Self) -> bool {
        self.key() == o.key()
    }
}
impl Eq for QEv {}
impl PartialOrd for QEv {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for QEv {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.key().cmp(&o.key())
    }
}

const WHEEL_BITS: u32 = 13;
/// Wheel horizon in cycles: events within this window of the current
/// time use O(1) buckets; anything farther falls back to the heap.
const WHEEL_SIZE: u64 = 1 << WHEEL_BITS;
const WHEEL_MASK: u64 = WHEEL_SIZE - 1;
const OCC_WORDS: usize = (WHEEL_SIZE as usize) / 64;

#[derive(Default)]
struct Bucket {
    /// entries sorted by (target, rank); `head` marks the popped prefix.
    items: Vec<QEv>,
    head: usize,
}

/// Calendar-wheel event queue with heap fallback.
pub(crate) struct EventQueue {
    buckets: Vec<Bucket>,
    occ: Vec<u64>,
    /// lower bound on every queued ring time (== last popped time).
    cursor: u64,
    ring_len: usize,
    heap: BinaryHeap<Reverse<QEv>>,
    /// route everything through the heap (the reference scheduler).
    pub(crate) heap_only: bool,
}

impl EventQueue {
    pub(crate) fn new() -> Self {
        EventQueue {
            buckets: (0..WHEEL_SIZE).map(|_| Bucket::default()).collect(),
            occ: vec![0u64; OCC_WORDS],
            cursor: 0,
            ring_len: 0,
            heap: BinaryHeap::new(),
            heap_only: false,
        }
    }

    pub(crate) fn push(&mut self, e: QEv) {
        if self.heap_only || e.time < self.cursor || e.time - self.cursor >= WHEEL_SIZE {
            self.heap.push(Reverse(e));
            return;
        }
        let b = (e.time & WHEEL_MASK) as usize;
        let bucket = &mut self.buckets[b];
        debug_assert!(
            bucket.head == bucket.items.len() || bucket.items[bucket.head].time == e.time,
            "wheel bucket holds mixed timestamps"
        );
        // full (target, rank) binary search: merged cross-shard events
        // may carry ranks below already-queued same-bucket entries
        let key = (e.target, e.rank);
        let pos = bucket.head
            + bucket.items[bucket.head..].partition_point(|x| (x.target, x.rank) <= key);
        bucket.items.insert(pos, e);
        self.occ[b >> 6] |= 1 << (b & 63);
        self.ring_len += 1;
    }

    /// Bucket index of the earliest occupied ring slot, scanning
    /// circularly from the cursor position via the occupancy bitmap.
    fn first_bucket(&self) -> usize {
        let start = (self.cursor & WHEEL_MASK) as usize;
        let sw = start >> 6;
        let masked = self.occ[sw] & (!0u64 << (start & 63));
        if masked != 0 {
            return (sw << 6) | masked.trailing_zeros() as usize;
        }
        for off in 1..=OCC_WORDS {
            let w = (sw + off) % OCC_WORDS;
            if self.occ[w] != 0 {
                return (w << 6) | self.occ[w].trailing_zeros() as usize;
            }
        }
        unreachable!("ring_len > 0 with an empty occupancy bitmap")
    }

    fn ring_peek(&self) -> Option<(usize, (u64, u32, Rank))> {
        if self.ring_len == 0 {
            return None;
        }
        let b = self.first_bucket();
        let bucket = &self.buckets[b];
        Some((b, bucket.items[bucket.head].key()))
    }

    pub(crate) fn peek_time(&self) -> Option<u64> {
        let r = self.ring_peek().map(|(_, k)| k);
        let h = self.heap.peek().map(|Reverse(e)| e.key());
        match (r, h) {
            (Some(a), Some(b)) => Some(a.min(b).0),
            (Some(a), None) => Some(a.0),
            (None, Some(b)) => Some(b.0),
            (None, None) => None,
        }
    }

    pub(crate) fn pop(&mut self) -> Option<QEv> {
        let ring = self.ring_peek();
        let heap = self.heap.peek().map(|Reverse(e)| e.key());
        match (ring, heap) {
            (None, None) => None,
            (Some((b, rk)), hk) if hk.is_none_or(|h| rk < h) => {
                let bucket = &mut self.buckets[b];
                let e = std::mem::replace(&mut bucket.items[bucket.head], QEv::hole());
                bucket.head += 1;
                if bucket.head == bucket.items.len() {
                    bucket.items.clear();
                    bucket.head = 0;
                    self.occ[b >> 6] &= !(1 << (b & 63));
                }
                self.ring_len -= 1;
                self.cursor = e.time;
                Some(e)
            }
            _ => {
                let Reverse(e) = self.heap.pop().unwrap();
                if e.time > self.cursor {
                    self.cursor = e.time;
                }
                Some(e)
            }
        }
    }

    /// Pop every queued event in dispatch order (partition/teardown of
    /// the sharded engine; ranks are absolute, so re-pushing elsewhere
    /// preserves the global total order).
    pub(crate) fn drain_ordered(&mut self) -> Vec<QEv> {
        let mut out = Vec::with_capacity(self.ring_len + self.heap.len());
        while let Some(e) = self.pop() {
            out.push(e);
        }
        out
    }

}

/// Behavior of one streaming kernel (the paper's HLS kernel body).
/// `Send` so whole simulations — and, since the sharded engine, single
/// fleet shards — can run on worker threads.
pub trait KernelBehavior: Send {
    fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo);
    fn on_wake(&mut self, tag: u64, io: &mut KernelIo);
    fn name(&self) -> String {
        "kernel".to_string()
    }
}

/// The side-effect interface handed to behaviors.
pub struct KernelIo<'a> {
    pub now: u64,
    pub self_id: GlobalKernelId,
    /// dense trace slot of this kernel (stats resolved once per dispatch).
    tslot: usize,
    coalescing: bool,
    fabric: &'a mut Fabric,
    fifo: &'a mut Fifo,
    trace: &'a mut Trace,
    slot16: &'a [u32],
    /// (arrival_time, destination GLOBAL slot, event)
    pending: &'a mut Vec<(u64, u32, Ev)>,
    wakes: &'a mut Vec<(u64, u64)>,
    errors: &'a mut Vec<String>,
}

impl KernelIo<'_> {
    #[inline]
    fn resolve(&self, dst: GlobalKernelId) -> Option<u32> {
        match self.slot16[dst.dense()] {
            0 => None,
            s => Some(s - 1),
        }
    }

    /// Send a payload to `dst` (any kernel, any cluster). The sender-side
    /// GMI protocol is applied automatically: an inter-cluster destination
    /// is rewritten to the destination cluster's gateway with the one-byte
    /// GMI header carrying the final kernel id (§4, §5.2 — the "GMI Header
    /// Attacher" on the kernel's output stream).
    pub fn send(&mut self, dst: GlobalKernelId, meta: MsgMeta, payload: Payload) {
        let mut pkt = Packet::new(self.self_id, dst, meta, payload);
        if pkt.inter_cluster {
            pkt.gmi_dst = Some(dst.kernel);
            pkt.dst = GlobalKernelId::gateway_of(dst.cluster);
        }
        self.send_raw(pkt);
    }

    /// Send a pre-built packet without sender-side rewriting (used by the
    /// gateway's forwarding module, which must preserve headers).
    pub fn send_raw(&mut self, pkt: Packet) {
        debug_assert!(pkt.burst.is_none(), "use send_burst for coalesced runs");
        match self.fabric.deliver(self.now, &pkt) {
            Ok(Some(arrival)) => {
                self.trace.on_tx_slot(self.tslot, self.now);
                self.trace.obs_tx(self.tslot, pkt.meta.inference, self.now);
                match self.resolve(pkt.dst) {
                    Some(slot) => self.pending.push((arrival, slot, Ev::Packet(pkt))),
                    None => self.errors.push(format!("send to unknown kernel {}", pkt.dst)),
                }
            }
            Ok(None) => {
                // dropped by the lossy network: accounted in fabric stats
                self.trace.on_tx_slot(self.tslot, self.now);
                self.trace.obs_tx(self.tslot, pkt.meta.inference, self.now);
            }
            Err(e) => self.errors.push(e.to_string()),
        }
    }

    /// True when a run of rows to `dst` may be coalesced into one burst:
    /// same cluster, same FPGA (the only serializing resource on the path
    /// is this kernel's exclusive egress port), and coalescing enabled.
    /// Same-FPGA also means same *shard* under any FPGA-aligned shard
    /// plan, so bursts never cross a parallel-engine boundary.
    pub fn can_burst(&self, dst: GlobalKernelId) -> bool {
        self.coalescing
            && dst.cluster == self.self_id.cluster
            && self.fabric.same_fpga(self.self_id, dst)
    }

    /// Ship consecutive rows `meta.row ..` of one stream as a single
    /// coalesced event. `emit_times` (nondecreasing, all >= now) are the
    /// per-row emission cycles; `head` is row `meta.row`'s payload and
    /// `tail` the rest. Caller must have checked [`KernelIo::can_burst`].
    pub fn send_burst(
        &mut self,
        dst: GlobalKernelId,
        meta: MsgMeta,
        emit_times: Vec<u64>,
        head: Payload,
        tail: Vec<Payload>,
    ) {
        debug_assert_eq!(tail.len() + 1, emit_times.len());
        debug_assert!(self.can_burst(dst), "send_burst to a non-coalescible destination");
        debug_assert!(emit_times[0] >= self.now);
        debug_assert!(tail.iter().all(|p| p.bytes() == head.bytes()));
        let mut pkt = Packet::new(self.self_id, dst, meta, head);
        pkt.burst = Some(Box::new(Burst { emit_times, arrivals: Vec::new(), tail }));
        match self.fabric.deliver_burst(&pkt) {
            Ok(arrivals) => {
                let first = arrivals[0];
                let inference = pkt.meta.inference;
                let b = pkt.burst.as_mut().unwrap();
                self.trace.on_tx_burst(self.tslot, &b.emit_times);
                for &e in &b.emit_times {
                    self.trace.obs_tx(self.tslot, inference, e);
                }
                b.arrivals = arrivals;
                match self.resolve(pkt.dst) {
                    Some(slot) => self.pending.push((first, slot, Ev::Packet(pkt))),
                    None => self.errors.push(format!("send to unknown kernel {}", pkt.dst)),
                }
            }
            Err(e) => self.errors.push(e.to_string()),
        }
    }

    /// Visit each row of `pkt` as `(io, meta, arrival, payload)`,
    /// mirroring per-packet delivery for coalesced runs: the row's wire
    /// bytes enter the input FIFO just before the row is handed over (the
    /// engine already accounted the single-packet case).
    pub fn rows<F: FnMut(&mut KernelIo<'_>, MsgMeta, u64, Payload)>(
        &mut self,
        pkt: Packet,
        mut f: F,
    ) {
        let wire = pkt.wire_bytes();
        let single = pkt.burst.is_none();
        let now = self.now;
        let io = self;
        pkt.for_each_row(now, |meta, at, payload| {
            if !single {
                io.fifo.push(wire);
                io.trace.obs_fifo_depth(at, io.fifo.occupancy as u64);
            }
            f(io, meta, at, payload);
        });
    }

    /// Schedule `on_wake(tag)` after `delay` cycles.
    pub fn wake_in(&mut self, delay: u64, tag: u64) {
        self.wakes.push((self.now + delay, tag));
    }

    /// Mark `bytes` drained from this kernel's input FIFO.
    pub fn consume(&mut self, bytes: usize) {
        self.fifo.pop(bytes);
    }
}

/// One registered kernel: behavior + input FIFO + trace slot. The trace
/// slot is engine-partition-local (the sharded engine re-registers its
/// kernels in per-shard traces and restores the master slot afterwards).
pub(crate) struct Slot {
    pub(crate) id: GlobalKernelId,
    pub(crate) behavior: Box<dyn KernelBehavior>,
    pub(crate) fifo: Fifo,
    pub(crate) tslot: usize,
}

/// Deliver one event to a kernel: rx/FIFO/probe accounting, then the
/// behavior callback. Emissions land in `pending` (packets, with GLOBAL
/// destination slots) and `wakes` in call order; the caller assigns
/// [`Rank`]s and routes them to its queue or, in the sharded engine, to
/// a cross-shard mailbox. Shared verbatim by `Sim::dispatch` and
/// `shard::Shard::dispatch` so the engines cannot drift apart.
#[allow(clippy::too_many_arguments)]
pub(crate) fn deliver_event(
    now: u64,
    slot: &mut Slot,
    ev: Ev,
    coalescing: bool,
    fabric: &mut Fabric,
    trace: &mut Trace,
    slot16: &[u32],
    errors: &mut Vec<String>,
    pending: &mut Vec<(u64, u32, Ev)>,
    wakes: &mut Vec<(u64, u64)>,
) {
    let tslot = slot.tslot;
    let mut io = KernelIo {
        now,
        self_id: slot.id,
        tslot,
        coalescing,
        fabric,
        fifo: &mut slot.fifo,
        trace,
        slot16,
        pending,
        wakes,
        errors,
    };
    match ev {
        Ev::Packet(pkt) => {
            let inference = pkt.meta.inference;
            match pkt.burst.as_ref() {
                None => {
                    io.fifo.push(pkt.wire_bytes());
                    io.trace.on_rx_slot(tslot, io.now);
                    io.trace.obs_rx(tslot, inference, io.now);
                    io.trace.obs_fifo_depth(io.now, io.fifo.occupancy as u64);
                    if io.trace.probe_slot(tslot) {
                        io.trace.record_probe_slot(tslot, io.now);
                    }
                }
                Some(b) => {
                    // per-row rx accounting at the analytic arrival
                    // times; FIFO bytes enter row-by-row inside
                    // `KernelIo::rows` so occupancy stays row-paced
                    let probe = io.trace.probe_slot(tslot);
                    for &a in &b.arrivals {
                        io.trace.on_rx_slot(tslot, a);
                        io.trace.obs_rx(tslot, inference, a);
                        if probe {
                            io.trace.record_probe_slot(tslot, a);
                        }
                    }
                }
            }
            slot.behavior.on_packet(pkt, &mut io);
        }
        Ev::Wake(tag) => {
            io.trace.wake_slot(tslot);
            io.trace.obs_wake(io.now);
            slot.behavior.on_wake(tag, &mut io);
        }
    }
}

/// A scheduled FPGA failure — the §6 operational scenario. At cycle
/// `at` the FPGA dies; per the paper's cluster-level fault isolation,
/// the *whole cluster* holding it goes down for `recovery_cycles` while
/// it is re-configured. During the outage:
///
/// * packets addressed to the cluster from outside (which the router
///   model guarantees land at its gateway) buffer in the modeled
///   **cluster input buffer** — the gateway's input FIFO accounts their
///   bytes, so §8.2.1-style sizing/overflow analysis applies — and
///   drain in arrival order when the cluster comes back;
/// * intra-cluster packets in flight are lost (they lived on wires and
///   FIFOs of the application region being wiped); the inferences they
///   belonged to never complete and are reported, not silently retried;
/// * kernel-internal wakes are suspended and resume at recovery (the
///   model keeps kernel state across reconfiguration — see DESIGN.md
///   "Fault tolerance" for why this simplification is safe).
///
/// `remap` is the recovery placement — typically produced by
/// `placer::recover::replace_after_failure` — applied to the fabric the
/// moment the cluster comes back; it may only move kernels of the failed
/// cluster (reconfiguring anything else would violate §6's isolation
/// claim, so `schedule_failure` rejects it).
#[derive(Debug, Clone)]
pub struct FailurePlan {
    pub fpga: FpgaId,
    /// failure cycle
    pub at: u64,
    /// reconfiguration latency: the cluster is down for exactly this long
    pub recovery_cycles: u64,
    /// kernel -> surviving-FPGA assignments applied at recovery
    pub remap: Vec<(GlobalKernelId, FpgaId)>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FailPhase {
    /// scheduled, not yet reached
    Armed,
    /// outage in progress: events to the cluster buffer or are lost
    Down,
    /// recovery applied; the engine is back to normal operation
    Done,
}

struct FailureState {
    plan: FailurePlan,
    /// the cluster being re-configured (all kernels of the failed FPGA)
    cluster: u8,
    recover_at: u64,
    phase: FailPhase,
    /// gateway-inbound packets + suspended wakes, in outage pop order
    held: Vec<QEv>,
    held_packets: u64,
    lost_events: u64,
}

/// Read-only view of a run's failure outcome (drives the serving
/// report's fault section and the failover tests/bench).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailureReport {
    pub fpga: FpgaId,
    pub cluster: u8,
    pub fail_cycle: u64,
    pub recover_cycle: u64,
    /// packets buffered in the cluster input buffer during the outage
    pub held_packets: u64,
    /// intra-cluster events lost to the reconfiguration
    pub lost_events: u64,
    /// kernels the recovery placement moved off the failed FPGA
    pub moved_kernels: usize,
    /// true once the recovery actually ran (false = the run never
    /// reached the failure window, or paused inside it)
    pub recovered: bool,
}

/// The simulator: kernels + fabric + event queue(s).
pub struct Sim {
    pub time: u64,
    queue: EventQueue,
    pub fabric: Fabric,
    kernels: Vec<Slot>,
    index: FxHashMap<GlobalKernelId, usize>,
    /// dense id -> kernel slot + 1 (send/dispatch resolution).
    slot16: Box<[u32]>,
    pub trace: Trace,
    pub errors: Vec<String>,
    /// hard event budget (runaway guard)
    pub max_events: u64,
    /// intra-FPGA row-burst coalescing (on by default; `reference_mode`
    /// disables it for golden-determinism comparisons).
    pub coalescing: bool,
    /// worker threads for the sharded parallel engine: 0 = auto
    /// (`PALLAS_SIM_THREADS` / `--threads` / available parallelism),
    /// 1 = exact sequential engine, N = up to N workers. The parallel
    /// engine is contractually trace-identical at every thread count.
    pub threads: usize,
    /// how the fleet is cut into shards (see [`ShardGranularity`]).
    pub granularity: ShardGranularity,
    /// dispatch-emission rank counter (see [`Rank`]).
    ctr: u64,
    /// genesis rank counter (`start` wakes + `inject`s).
    genesis_ctr: u64,
    /// scheduled FPGA failure (None = the §6 scenario is off).
    failure: Option<FailureState>,
    /// collect the simulator self-profile (wall-clock timing, per-shard
    /// event counts, barrier wait). Off by default: wall-clock numbers
    /// are nondeterministic and never feed a determinism-checked
    /// surface (see obs/profile.rs).
    pub profile: bool,
    /// accumulated self-profile (populated while `profile` is on).
    pub last_profile: Option<crate::obs::SimProfile>,
    // reusable dispatch buffers (avoid per-event allocation)
    pending_buf: Vec<(u64, u32, Ev)>,
    wakes_buf: Vec<(u64, u64)>,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Self {
        Sim {
            time: 0,
            queue: EventQueue::new(),
            fabric: Fabric::new(),
            kernels: Vec::new(),
            index: FxHashMap::default(),
            slot16: vec![0u32; DENSE_IDS].into_boxed_slice(),
            trace: Trace::default(),
            errors: Vec::new(),
            max_events: 500_000_000,
            coalescing: true,
            threads: 0,
            granularity: ShardGranularity::PerCluster,
            ctr: 0,
            genesis_ctr: 0,
            failure: None,
            profile: false,
            last_profile: None,
            pending_buf: Vec::new(),
            wakes_buf: Vec::new(),
        }
    }

    /// Put the simulator in the pre-optimization reference configuration:
    /// no row-burst coalescing, pure binary-heap scheduling, sequential
    /// execution. Timing and functional outputs are contractually
    /// identical to the default engine (rust/tests/proptests.rs
    /// golden-determinism properties); only the event count and
    /// wall-clock differ.
    pub fn reference_mode(&mut self) {
        self.coalescing = false;
        self.queue.heap_only = true;
        self.threads = 1;
    }

    /// Pin the worker-thread count (0 = auto, 1 = sequential).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }

    /// Enable cycle-domain telemetry (obs/): bucketed fleet series on
    /// the trace, link-occupancy attribution on the fabric, and
    /// per-inference endpoint stats on the `marked` kernels (span
    /// roles). Call before `start()`; when never called, the hot paths
    /// pay one predictable untaken branch per event.
    pub fn enable_obs(&mut self, interval: u64, marked: &[GlobalKernelId]) {
        self.trace.enable_obs(interval, marked);
        self.fabric.enable_obs(interval);
    }

    /// Per-kernel input-FIFO snapshots in registration order (metrics
    /// export).
    pub fn fifo_snapshots(&self) -> Vec<(GlobalKernelId, crate::obs::FifoSnapshot)> {
        self.kernels.iter().map(|s| (s.id, s.fifo.snapshot())).collect()
    }

    fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            pool::sim_threads()
        }
    }

    /// Register a kernel on an FPGA with the given input FIFO.
    pub fn add_kernel(
        &mut self,
        id: GlobalKernelId,
        fpga: FpgaId,
        fifo: Fifo,
        behavior: Box<dyn KernelBehavior>,
    ) -> Result<()> {
        if self.index.contains_key(&id) {
            bail!("kernel {id} registered twice");
        }
        self.fabric.place(id, fpga);
        self.index.insert(id, self.kernels.len());
        self.slot16[id.dense()] = self.kernels.len() as u32 + 1;
        let tslot = self.trace.register(id);
        self.kernels.push(Slot { id, behavior, fifo, tslot });
        Ok(())
    }

    pub fn kernel_count(&self) -> usize {
        self.kernels.len()
    }

    pub fn fifo_of(&self, id: GlobalKernelId) -> Option<&Fifo> {
        self.index.get(&id).map(|&i| &self.kernels[i].fifo)
    }

    /// Deliver the START wake to every kernel at t=0.
    pub fn start(&mut self) {
        for i in 0..self.kernels.len() {
            self.genesis_ctr += 1;
            self.queue.push(QEv {
                time: 0,
                target: i as u32,
                rank: Rank::genesis(self.genesis_ctr),
                ev: Ev::Wake(START_TAG),
            });
        }
    }

    /// Inject a packet from "outside" (e.g. a test harness) at time t.
    /// Injections carry genesis rank: injected before the run starts they
    /// order exactly as in the pre-rank engine; a mid-run injection at an
    /// already-in-flight `(t, target)` orders ahead of the in-flight
    /// packet (the engine has no external-injection ordering contract
    /// mid-run).
    pub fn inject(&mut self, t: u64, pkt: Packet) -> Result<()> {
        let slot = match self.slot16[pkt.dst.dense()] {
            0 => bail!("inject: unknown destination {}", pkt.dst),
            s => s - 1,
        };
        self.genesis_ctr += 1;
        self.queue.push(QEv {
            time: t,
            target: slot,
            rank: Rank::genesis(self.genesis_ctr),
            ev: Ev::Packet(pkt),
        });
        Ok(())
    }

    /// Schedule a §6 FPGA failure (see [`FailurePlan`]). At most one per
    /// run. Failure runs execute on the sharded parallel engine too (in
    /// phases around the outage window — see `run_phased_failure`), with
    /// results bit-identical at every thread count.
    pub fn schedule_failure(&mut self, plan: FailurePlan) -> Result<()> {
        ensure!(self.failure.is_none(), "only one failure can be scheduled per run");
        ensure!(plan.recovery_cycles >= 1, "recovery must take at least one cycle");
        let on_fpga = self.fabric.kernels_on(plan.fpga);
        ensure!(!on_fpga.is_empty(), "failed FPGA {:?} hosts no kernels", plan.fpga);
        let cluster = on_fpga[0].cluster;
        debug_assert!(
            on_fpga.iter().all(|k| k.cluster == cluster),
            "platform validation guarantees one cluster per FPGA"
        );
        for (kid, f) in &plan.remap {
            ensure!(
                kid.cluster == cluster,
                "remap moves {kid}, which is outside the failed cluster {cluster} — §6 \
                 isolation re-configures only the failed FPGA's own cluster"
            );
            ensure!(*f != plan.fpga, "remap places {kid} back on the failed FPGA");
            ensure!(self.slot16[kid.dense()] != 0, "remap names unregistered kernel {kid}");
            ensure!(
                self.fabric.switch_of(*f).is_some(),
                "remap target {f:?} is not attached to any switch"
            );
        }
        let recover_at = plan.at.saturating_add(plan.recovery_cycles);
        self.failure = Some(FailureState {
            plan,
            cluster,
            recover_at,
            phase: FailPhase::Armed,
            held: Vec::new(),
            held_packets: 0,
            lost_events: 0,
        });
        Ok(())
    }

    /// The failure outcome of this run (None when no failure was
    /// scheduled). Populated incrementally: read after `run()` for the
    /// final picture.
    pub fn failure_report(&self) -> Option<FailureReport> {
        self.failure.as_ref().map(|fs| FailureReport {
            fpga: fs.plan.fpga,
            cluster: fs.cluster,
            fail_cycle: fs.plan.at,
            recover_cycle: fs.recover_at,
            held_packets: fs.held_packets,
            lost_events: fs.lost_events,
            moved_kernels: fs.plan.remap.len(),
            recovered: fs.phase == FailPhase::Done,
        })
    }

    /// Run until the queue drains or `until` cycles elapse.
    ///
    /// With `threads != 1` and a fleet that splits into 2+ FPGA-aligned
    /// shards, the run executes on the sharded conservative-window engine
    /// (shard.rs) — trace-identical to the sequential engine by contract.
    /// That contract covers lossy-network mode (per-link drop-RNG streams
    /// make drop decisions shard-plan-invariant; the drop trace is
    /// canonically ordered at the end of every run), reliable transport
    /// (retries only add sender-side latency, and the window is clamped
    /// to `RETX_TIMEOUT` belt-and-braces), and §6 failure injection
    /// (executed in phases around the outage window). Only
    /// `reference_mode` / `threads = 1` take the sequential path.
    ///
    /// Note on pausing with coalescing enabled: a burst event is
    /// delivered atomically at its FIRST row's arrival, so a pause may
    /// observe rx stats/probe entries for rows whose (exact) arrival
    /// times lie beyond `until` — final results are unaffected (the
    /// golden-determinism contract covers completed runs). Use
    /// `reference_mode` when inspecting mid-run state at a cycle
    /// boundary matters.
    pub fn run_until(&mut self, until: u64) -> Result<u64> {
        let r = if !self.profile {
            self.run_until_inner(until)
        } else {
            let (cyc0, ev0) = (self.time, self.trace.events_processed);
            let t0 = std::time::Instant::now();
            let r = self.run_until_inner(until);
            let wall = t0.elapsed().as_nanos() as u64;
            let p = self.last_profile.get_or_insert_with(Default::default);
            p.wall_ns += wall;
            p.sim_cycles += self.time.saturating_sub(cyc0);
            p.events += self.trace.events_processed.saturating_sub(ev0);
            r
        };
        // both engines leave the drop log in the same canonical total
        // order (see DropRecord) — idempotent across pause/resume
        self.fabric.canonicalize_drop_trace();
        r
    }

    fn run_until_inner(&mut self, until: u64) -> Result<u64> {
        let threads = self.effective_threads();
        if threads == 1 || self.queue.heap_only {
            return self.run_sequential(until);
        }
        if matches!(
            self.failure.as_ref().map(|f| f.phase),
            Some(FailPhase::Armed | FailPhase::Down)
        ) {
            return self.run_phased_failure(until, threads);
        }
        self.run_segment(until, threads)
    }

    /// One bounded segment on the best engine available: sharded when the
    /// fleet splits into 2+ shards, sequential otherwise.
    fn run_segment(&mut self, until: u64, threads: usize) -> Result<u64> {
        if let Some(plan) =
            ShardPlan::build(self.granularity, self.kernels.iter().map(|s| s.id), &self.fabric)
        {
            self.run_parallel(until, &plan, threads)
        } else {
            self.run_sequential(until)
        }
    }

    /// Failure injection on the parallel engine, executed in phases that
    /// keep the §6 outage semantics exactly sequential-equivalent:
    ///
    /// * **Phase A** — run normally (sharded) up to the failure instant;
    ///   afterwards every queued event's time is `>= at`.
    /// * **Phase B** — the outage window `[at, recover_at)`: shards run
    ///   with a per-shard outage filter replicating `filter_failed`'s
    ///   Down branch (hold/lose/suspend decisions depend only on the
    ///   event and the static failure plan, so they are shard-local);
    ///   held events merge back into the global dispatch order at
    ///   teardown (`absorb_outage`).
    /// * **Recovery** — `perform_recovery` runs between segments on the
    ///   master thread (a natural global barrier), so recovery-cycle
    ///   backlog releases never cross a live window boundary and need no
    ///   lookahead slack.
    /// * **Phase C** — re-partition under the post-remap placement and
    ///   continue normally: the shard plan and the conservative window
    ///   are rebuilt from the recovered topology, so the remap can never
    ///   invalidate the lookahead of a running round.
    fn run_phased_failure(&mut self, until: u64, threads: usize) -> Result<u64> {
        let mut processed = 0u64;
        let (at, phase) = {
            let fs = self.failure.as_ref().expect("caller checked a failure is pending");
            (fs.plan.at, fs.phase)
        };
        if phase == FailPhase::Armed {
            // ---- Phase A: everything strictly before the failure ----
            if at > 0 {
                processed += self.run_segment(until.min(at - 1), threads)?;
            }
            match self.queue.peek_time() {
                // drained before the failure instant: the outage never
                // happens (the sequential engine arms lazily at pop
                // time and agrees)
                None => return Ok(processed),
                // paused before reaching any event at/after the instant
                Some(t) if t > until => return Ok(processed),
                Some(_) => {
                    let fs = self.failure.as_mut().expect("armed above");
                    fs.phase = FailPhase::Down;
                    let (t, f) = (fs.plan.at, fs.plan.fpga.0 as u32);
                    if let Some(o) = self.trace.obs.as_deref_mut() {
                        o.on_instant(t, f, "fail");
                    }
                }
            }
        }
        // ---- Phase B: the outage window [at, recover_at) ----
        let recover_at = self.failure.as_ref().expect("phase is Down").recover_at;
        processed += self.run_segment(until.min(recover_at - 1), threads)?;
        if recover_at > until {
            // paused mid-outage — matches the sequential engine, whose
            // recovery_due gate also refuses to recover past the horizon
            return Ok(processed);
        }
        self.perform_recovery();
        // ---- Phase C: post-recovery topology, fresh shard plan ----
        processed += self.run_until_inner(until)?;
        Ok(processed)
    }

    /// Fold shard-collected outage state back into the failure record
    /// (Phase B teardown). Re-sorting the held backlog by event key
    /// reproduces the sequential hold order exactly: sequential pops
    /// (and therefore holds) arrive in strictly increasing key order,
    /// and each shard's holds are a key-ordered subsequence of it.
    pub(crate) fn absorb_outage(&mut self, held: Vec<QEv>, held_packets: u64, lost_events: u64) {
        let Some(fs) = self.failure.as_mut() else {
            debug_assert!(
                held.is_empty() && held_packets == 0 && lost_events == 0,
                "outage state collected without a scheduled failure"
            );
            return;
        };
        fs.held.extend(held);
        fs.held.sort_unstable_by_key(|e| e.key());
        fs.held_packets += held_packets;
        fs.lost_events += lost_events;
    }

    fn run_sequential(&mut self, until: u64) -> Result<u64> {
        if self.profile {
            let p = self.last_profile.get_or_insert_with(Default::default);
            p.note_engine("sequential");
            p.threads = p.threads.max(1);
        }
        let mut processed = 0u64;
        loop {
            let next = self.queue.peek_time();
            // a pending recovery fires once simulated time passes the
            // outage window — including when the held backlog is all the
            // activity that is left and the queue is otherwise empty
            if self.recovery_due(next, until) {
                self.perform_recovery();
                continue;
            }
            let Some(t) = next else { break };
            if t > until {
                break;
            }
            let e = self.queue.pop().unwrap();
            let Some(e) = self.filter_failed(e) else { continue };
            self.dispatch(e)?;
            processed += 1;
            if self.trace.events_processed > self.max_events {
                bail!("event budget exceeded ({} events)", self.max_events);
            }
            if !self.errors.is_empty() {
                bail!("simulation error: {}", self.errors.join("; "));
            }
        }
        Ok(processed)
    }

    /// True when the scheduled outage has elapsed relative to the next
    /// queued event (or the queue drained) and the pause horizon allows
    /// the recovery to run.
    fn recovery_due(&self, next: Option<u64>, until: u64) -> bool {
        match &self.failure {
            Some(fs) if fs.phase == FailPhase::Down => {
                fs.recover_at <= until && next.is_none_or(|t| t >= fs.recover_at)
            }
            _ => false,
        }
    }

    /// Failure-window gate on one popped event. Returns the event back
    /// when it should dispatch normally; absorbs it (hold or lose) when
    /// the target cluster is down.
    fn filter_failed(&mut self, e: QEv) -> Option<QEv> {
        let Some(fs) = self.failure.as_mut() else { return Some(e) };
        match fs.phase {
            FailPhase::Done => return Some(e),
            FailPhase::Armed if e.time < fs.plan.at => return Some(e),
            // the failure instant has been reached: the cluster is down
            FailPhase::Armed => {
                fs.phase = FailPhase::Down;
                if let Some(o) = self.trace.obs.as_deref_mut() {
                    o.on_instant(fs.plan.at, fs.plan.fpga.0 as u32, "fail");
                }
            }
            FailPhase::Down => {}
        }
        if e.time >= fs.recover_at {
            // the whole outage fits inside an event gap: recover first,
            // then let this event dispatch normally
            self.perform_recovery();
            return Some(e);
        }
        let fs = self.failure.as_mut().expect("failure state checked above");
        let id = self.kernels[e.target as usize].id;
        if id.cluster != fs.cluster {
            return Some(e);
        }
        enum Hold {
            Buffer(usize),
            Lose,
            Suspend,
        }
        let action = match &e.ev {
            // §6: traffic from outside the cluster buffers in the cluster
            // input buffer (the router model guarantees it targets the
            // gateway); its bytes occupy the gateway FIFO until recovery
            Ev::Packet(p) if p.src.cluster != fs.cluster => Hold::Buffer(p.wire_bytes()),
            // intra-cluster rows lived on wires/FIFOs of the application
            // region being wiped: lost — their inferences stay incomplete
            Ev::Packet(_) => Hold::Lose,
            // kernel-internal schedules pause and resume at recovery
            Ev::Wake(_) => Hold::Suspend,
        };
        match action {
            Hold::Buffer(bytes) => {
                self.kernels[e.target as usize].fifo.push(bytes);
                fs.held_packets += 1;
                // attribute the hold: the packet sits in the cluster
                // input buffer until the recovery cycle releases it
                if let (Some(o), Ev::Packet(p)) = (self.trace.obs.as_deref_mut(), &e.ev) {
                    o.on_outage_hold(p.meta.inference, fs.recover_at - e.time);
                }
                fs.held.push(e);
            }
            Hold::Suspend => fs.held.push(e),
            Hold::Lose => fs.lost_events += 1,
        }
        None
    }

    /// Bring the failed cluster back: apply the recovery placement to the
    /// fabric, then release the held backlog at the recovery cycle, in
    /// exactly the order it was buffered (genesis ranks sort the drained
    /// events ahead of any same-cycle emission, and the per-event counter
    /// preserves the buffer's FIFO order).
    fn perform_recovery(&mut self) {
        let Some(fs) = self.failure.as_mut() else { return };
        debug_assert!(fs.phase == FailPhase::Down);
        fs.phase = FailPhase::Done;
        let recover_at = fs.recover_at;
        if let Some(o) = self.trace.obs.as_deref_mut() {
            o.on_instant(recover_at, fs.plan.fpga.0 as u32, "recover");
        }
        let remap = fs.plan.remap.clone();
        let held = std::mem::take(&mut fs.held);
        for (kid, f) in &remap {
            self.fabric.place(*kid, *f);
        }
        self.time = self.time.max(recover_at);
        for e in held {
            if let Ev::Packet(p) = &e.ev {
                // the buffered bytes leave the cluster input buffer as
                // each packet is handed to the gateway (dispatch re-pushes
                // them through the normal rx path)
                self.kernels[e.target as usize].fifo.pop(p.wire_bytes());
            }
            self.genesis_ctr += 1;
            self.queue.push(QEv {
                time: recover_at,
                target: e.target,
                rank: Rank::genesis(self.genesis_ctr),
                ev: e.ev,
            });
        }
    }

    /// Run to quiescence.
    pub fn run(&mut self) -> Result<u64> {
        self.run_until(u64::MAX)
    }

    fn dispatch(&mut self, entry: QEv) -> Result<()> {
        debug_assert!(entry.time >= self.time, "time went backwards");
        self.time = entry.time;
        self.trace.events_processed += 1;

        let target = entry.target;
        let slot = &mut self.kernels[target as usize];
        self.pending_buf.clear();
        self.wakes_buf.clear();
        deliver_event(
            self.time,
            slot,
            entry.ev,
            self.coalescing,
            &mut self.fabric,
            &mut self.trace,
            &self.slot16,
            &mut self.errors,
            &mut self.pending_buf,
            &mut self.wakes_buf,
        );

        // packet emissions first, then wakes — the pre-rank engine
        // assigned its global counter in exactly this drain order
        for (t, dst_slot, ev) in self.pending_buf.drain(..) {
            self.ctr += 1;
            self.queue.push(QEv {
                time: t,
                target: dst_slot,
                rank: Rank::emission(self.time, target, self.ctr),
                ev,
            });
        }
        for (t, tag) in self.wakes_buf.drain(..) {
            self.ctr += 1;
            self.queue.push(QEv {
                time: t,
                target,
                rank: Rank::emission(self.time, target, self.ctr),
                ev: Ev::Wake(tag),
            });
        }
        Ok(())
    }

    // ---- sharded parallel engine (shard.rs holds the executor) ----

    /// Partition the simulator into shards, run the bounded-window loop
    /// on the worker pool, and merge everything back so the post-run
    /// `Sim` is indistinguishable from a sequential run.
    fn run_parallel(&mut self, until: u64, plan: &ShardPlan, threads: usize) -> Result<u64> {
        let mut window = match super::window::conservative_window(
            plan,
            &self.fabric,
            self.kernels.iter().map(|s| s.id),
        ) {
            // zero-lookahead cut (or no cross-shard edge at all): the
            // conservative window degenerates — run sequentially
            Some(w) if w >= 1 => w,
            _ => return self.run_sequential(until),
        };
        // Reliable lossy transport delays a boundary packet's wire copies
        // by RETX_TIMEOUT per retry, but retries only ever ADD sender-side
        // latency on top of the base path, so `arrival >= send + window`
        // still holds. The clamp is belt-and-braces: it keeps the
        // conservative claim checkable without that argument
        // (placer::cost::retx_aware_lookahead_cycles mirrors it in `plan`
        // output), and only binds on cuts wider than RETX_TIMEOUT.
        if self.fabric.reliable && self.fabric.drop_probability > 0.0 {
            window = window.min(RETX_TIMEOUT);
        }
        // §6 outage segment (Phase B of run_phased_failure): shards
        // filter their own pops with a replica of filter_failed
        let outage = match self.failure.as_ref() {
            Some(fs) if fs.phase == FailPhase::Down => Some((fs.cluster, fs.recover_at)),
            _ => None,
        };

        // ---- partition ----
        let owner = plan.owner_of_slots(self.kernels.iter().map(|s| s.id), &self.fabric);
        let slot16 = std::sync::Arc::new(self.slot16.to_vec());
        let owner = std::sync::Arc::new(owner);
        let (ctr0, coalescing) = (self.ctr, self.coalescing);
        let mut shards = shard::partition(self, plan, &owner, &slot16, ctr0, coalescing);
        if let Some((cluster, recover_at)) = outage {
            for sh in &mut shards {
                sh.arm_outage(cluster, recover_at);
            }
        }

        // route queued events to their target's shard
        for e in self.queue.drain_ordered() {
            shards[owner[e.target as usize] as usize].queue.push(e);
        }

        // ---- bounded-window execution on the worker pool ----
        let events_left = self.max_events.saturating_sub(self.trace.events_processed);
        let outcome =
            shard::run_windowed(shards, threads, window, until, events_left, self.profile);

        // ---- teardown: merge shards back into the master state ----
        let budget_hit = outcome.budget_exceeded;
        let processed = outcome.processed;
        if self.profile {
            let p = self.last_profile.get_or_insert_with(Default::default);
            p.note_engine("parallel");
            p.threads = p.threads.max(threads.min(outcome.shards.len()));
            p.shards = outcome.shards.len();
            p.window = window;
            p.rounds += outcome.rounds;
            p.barrier_wait_ns += outcome.barrier_wait_ns;
            for (i, &e) in outcome.per_shard_events.iter().enumerate() {
                if p.per_shard_events.len() <= i {
                    p.per_shard_events.resize(i + 1, 0);
                }
                p.per_shard_events[i] += e;
            }
        }
        shard::absorb(self, outcome.shards);

        if !self.errors.is_empty() {
            bail!("simulation error: {}", self.errors.join("; "));
        }
        if budget_hit {
            bail!("event budget exceeded ({} events)", self.max_events);
        }
        Ok(processed)
    }

    // ---- shard.rs accessors (partition/teardown live over there) ----

    pub(crate) fn take_kernels(&mut self) -> Vec<Slot> {
        std::mem::take(&mut self.kernels)
    }
    pub(crate) fn put_kernels(&mut self, kernels: Vec<Slot>) {
        debug_assert!(self.kernels.is_empty());
        self.kernels = kernels;
    }
    pub(crate) fn push_event(&mut self, e: QEv) {
        self.queue.push(e);
    }
    pub(crate) fn merge_clock(&mut self, shard_time: u64, shard_ctr: u64) {
        self.time = self.time.max(shard_time);
        self.ctr = self.ctr.max(shard_ctr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::SwitchId;

    /// Emits `n` rows to `dst`, one every `gap` cycles.
    struct Source {
        dst: GlobalKernelId,
        n: u32,
        gap: u64,
        sent: u32,
    }
    impl KernelBehavior for Source {
        fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
        fn on_wake(&mut self, _tag: u64, io: &mut KernelIo) {
            if self.sent < self.n {
                let meta =
                    MsgMeta { stream: 0, row: self.sent, rows: self.n, inference: 0 };
                io.send(self.dst, meta, Payload::Timing(768));
                self.sent += 1;
                io.wake_in(self.gap, 1);
            }
        }
    }

    /// Counts arrivals; consumes immediately.
    struct Sink {
        got: u32,
    }
    impl KernelBehavior for Sink {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            self.got += pkt.rows_in_packet() as u32;
            io.consume(pkt.wire_bytes() * pkt.rows_in_packet());
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    #[test]
    fn source_to_sink_delivers_all() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), Box::new(Source {
            dst: k(0, 2), n: 10, gap: 12, sent: 0,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
            .unwrap();
        sim.trace.add_probe(k(0, 2));
        sim.start();
        sim.run().unwrap();
        let st = sim.trace.kernel(k(0, 2)).unwrap();
        assert_eq!(st.rx_packets, 10);
        let (x, t, i) = sim.trace.xti(k(0, 2)).unwrap();
        assert!(x > 0);
        assert_eq!(i, 12, "line-rate packets arrive every 12 cycles");
        assert_eq!(t - x, 9 * 12);
    }

    #[test]
    fn wake_ordering_is_deterministic() {
        struct Recorder {
            seen: Vec<u64>,
        }
        impl KernelBehavior for Recorder {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    // schedule in scrambled order, same target time
                    io.wake_in(5, 1);
                    io.wake_in(5, 2);
                    io.wake_in(3, 3);
                } else {
                    self.seen.push(tag);
                }
            }
        }
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1024), Box::new(Recorder { seen: vec![] }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        // tag 3 at t=3 first; tags 1,2 at t=5 in insertion order
        // (we can't easily read back the box; rerun pattern asserted via trace)
        assert_eq!(sim.trace.kernel(k(0, 1)).unwrap().wakes, 4);
        assert_eq!(sim.time, 5);
    }

    #[test]
    fn inter_cluster_send_goes_via_gateway() {
        struct Fwd;
        impl KernelBehavior for Fwd {
            fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
                // minimal gateway: decode GMI header, forward locally
                let final_dst = GlobalKernelId::new(io.self_id.cluster, pkt.gmi_dst.unwrap());
                io.consume(pkt.wire_bytes());
                let mut fwd = pkt;
                fwd.src = io.self_id;
                fwd.dst = final_dst;
                fwd.inter_cluster = false;
                fwd.gmi_dst = None;
                io.send_raw(fwd);
            }
            fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
        }
        struct Once {
            dst: GlobalKernelId,
        }
        impl KernelBehavior for Once {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    io.send(self.dst, MsgMeta::default(), Payload::Timing(100));
                }
            }
        }
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1024), Box::new(Once { dst: k(1, 5) }))
            .unwrap();
        sim.add_kernel(k(1, 0), FpgaId(1), Fifo::new(1024), Box::new(Fwd)).unwrap();
        sim.add_kernel(k(1, 5), FpgaId(1), Fifo::new(1024), Box::new(Sink { got: 0 }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        // the gateway relayed it: final kernel got exactly one packet
        assert_eq!(sim.trace.kernel(k(1, 5)).unwrap().rx_packets, 1);
        assert_eq!(sim.trace.kernel(k(1, 0)).unwrap().rx_packets, 1);
    }

    #[test]
    fn duplicate_registration_fails() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        assert!(sim
            .add_kernel(k(0, 1), FpgaId(0), Fifo::new(1), Box::new(Sink { got: 0 }))
            .is_ok());
        assert!(sim
            .add_kernel(k(0, 1), FpgaId(0), Fifo::new(1), Box::new(Sink { got: 0 }))
            .is_err());
    }

    #[test]
    fn far_future_wakes_use_the_heap_fallback() {
        // delays far beyond the wheel horizon must still fire in order
        struct LongWaits {
            fired: Vec<u64>,
        }
        impl KernelBehavior for LongWaits {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    io.wake_in(3 * WHEEL_SIZE, 1);
                    io.wake_in(10, 2);
                    io.wake_in(WHEEL_SIZE + 7, 3);
                } else {
                    self.fired.push(tag);
                    if tag == 2 {
                        // from t=10, the horizon covers tag 3's time
                        io.wake_in(1, 4);
                    }
                }
            }
        }
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(64), Box::new(LongWaits { fired: vec![] }))
            .unwrap();
        sim.start();
        sim.run().unwrap();
        assert_eq!(sim.time, 3 * WHEEL_SIZE);
        assert_eq!(sim.trace.kernel(k(0, 1)).unwrap().wakes, 5);
    }

    #[test]
    fn run_until_pauses_and_resumes() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), Box::new(Source {
            dst: k(0, 2), n: 100, gap: 50, sent: 0,
        })).unwrap();
        sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
            .unwrap();
        sim.start();
        let a = sim.run_until(500).unwrap();
        assert!(sim.time <= 500);
        let b = sim.run().unwrap();
        assert!(a > 0 && b > 0);
        assert_eq!(sim.trace.kernel(k(0, 2)).unwrap().rx_packets, 100);
    }

    #[test]
    fn send_burst_arrivals_match_per_row_sends() {
        // one kernel ships 4 rows as a burst; a reference sim sends the
        // same rows individually at the same emission times
        struct BurstTx {
            dst: GlobalKernelId,
        }
        impl KernelBehavior for BurstTx {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG {
                    assert!(io.can_burst(self.dst));
                    let meta = MsgMeta { stream: 0, row: 0, rows: 4, inference: 0 };
                    io.send_burst(
                        self.dst,
                        meta,
                        vec![0, 5, 10, 15],
                        Payload::Timing(768),
                        vec![Payload::Timing(768); 3],
                    );
                }
            }
        }
        struct RowTx {
            dst: GlobalKernelId,
            sent: u32,
        }
        impl KernelBehavior for RowTx {
            fn on_packet(&mut self, _: Packet, _: &mut KernelIo) {}
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if (tag == START_TAG || tag == 1) && self.sent < 4 {
                    let meta = MsgMeta { stream: 0, row: self.sent, rows: 4, inference: 0 };
                    io.send(self.dst, meta, Payload::Timing(768));
                    self.sent += 1;
                    io.wake_in(5, 1);
                }
            }
        }
        let run = |burst: bool| -> Vec<u64> {
            let mut sim = Sim::new();
            sim.fabric.attach(FpgaId(0), SwitchId(0));
            let b: Box<dyn KernelBehavior> = if burst {
                Box::new(BurstTx { dst: k(0, 2) })
            } else {
                Box::new(RowTx { dst: k(0, 2), sent: 0 })
            };
            sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), b).unwrap();
            sim.add_kernel(k(0, 2), FpgaId(0), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
                .unwrap();
            sim.trace.add_probe(k(0, 2));
            sim.start();
            sim.run().unwrap();
            sim.trace.probe_times(k(0, 2)).unwrap().to_vec()
        };
        let coalesced = run(true);
        let reference = run(false);
        assert_eq!(coalesced, reference);
        assert_eq!(coalesced.len(), 4);
    }

    #[test]
    fn rank_order_is_lexicographic_and_genesis_first() {
        let g1 = Rank::genesis(1);
        let g2 = Rank::genesis(2);
        let d = Rank::emission(0, 0, 0);
        assert!(g1 < g2, "genesis pushes keep call order");
        assert!(g2 < d, "genesis sorts before any dispatch emission");
        assert!(Rank::emission(5, 3, 9) < Rank::emission(5, 4, 1), "sender slot before ctr");
        assert!(Rank::emission(4, 9, 9) < Rank::emission(5, 0, 0), "send time first");
        assert!(Rank::emission(5, 3, 1) < Rank::emission(5, 3, 2), "ctr breaks the last tie");
    }

    #[test]
    fn queue_orders_merged_low_rank_events_correctly() {
        // a cross-shard merge can push an event whose rank sorts BELOW
        // entries already queued in the same (time, target) bucket; the
        // wheel must place it first, not append it
        let mut q = EventQueue::new();
        q.push(QEv { time: 50, target: 3, rank: Rank::emission(40, 7, 9), ev: Ev::Wake(1) });
        q.push(QEv { time: 50, target: 3, rank: Rank::emission(10, 2, 1), ev: Ev::Wake(2) });
        q.push(QEv { time: 50, target: 2, rank: Rank::emission(49, 9, 9), ev: Ev::Wake(3) });
        let tags: Vec<u64> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.ev {
                Ev::Wake(t) => t,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(tags, vec![3, 2, 1], "(target, rank) order, rank-regressing insert first");
    }

    /// A two-FPGA ping-pong with same-cycle ties: the parallel engine
    /// (forced 2 shards, various thread counts) must reproduce the
    /// sequential engine's trace exactly.
    #[test]
    fn parallel_matches_sequential_on_cross_fpga_pingpong() {
        struct Ping {
            peer: GlobalKernelId,
            left: u32,
        }
        impl KernelBehavior for Ping {
            fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
                io.consume(pkt.wire_bytes());
                if self.left > 0 {
                    self.left -= 1;
                    io.send(self.peer, pkt.meta, Payload::Timing(64));
                    io.send(self.peer, pkt.meta, Payload::Timing(64)); // tie on arrival
                    io.wake_in(0, 9); // same-cycle self wake
                }
            }
            fn on_wake(&mut self, tag: u64, io: &mut KernelIo) {
                if tag == START_TAG && self.left % 2 == 1 {
                    io.send(self.peer, MsgMeta::default(), Payload::Timing(64));
                }
            }
        }
        let build = |threads: usize| {
            let mut sim = Sim::new();
            sim.fabric.attach(FpgaId(0), SwitchId(0));
            sim.fabric.attach(FpgaId(1), SwitchId(0));
            sim.granularity = ShardGranularity::PerFpga;
            sim.set_threads(threads);
            sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Ping {
                peer: k(0, 2),
                left: 13,
            }))
            .unwrap();
            sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 16), Box::new(Ping {
                peer: k(0, 1),
                left: 12,
            }))
            .unwrap();
            sim.trace.add_probe(k(0, 1));
            sim.trace.add_probe(k(0, 2));
            sim.start();
            sim.run().unwrap();
            (
                sim.trace.probe_times(k(0, 1)).unwrap().to_vec(),
                sim.trace.probe_times(k(0, 2)).unwrap().to_vec(),
                sim.time,
                sim.trace.events_processed,
                sim.fabric.stats.packets,
            )
        };
        let seq = build(1);
        for threads in [2, 4, 8] {
            assert_eq!(build(threads), seq, "parallel diverged at threads={threads}");
        }
    }

    /// Gateway used by the failure tests: decode GMI header, forward to
    /// the named local kernel (same shape as the inter-cluster test's).
    struct FwdGw;
    impl KernelBehavior for FwdGw {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            let final_dst = GlobalKernelId::new(io.self_id.cluster, pkt.gmi_dst.unwrap());
            io.consume(pkt.wire_bytes());
            let mut fwd = pkt;
            fwd.src = io.self_id;
            fwd.dst = final_dst;
            fwd.inter_cluster = false;
            fwd.gmi_dst = None;
            io.send_raw(fwd);
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    /// Records (row, arrival) pairs for order assertions.
    struct RecSink {
        got: std::sync::Arc<std::sync::Mutex<Vec<(u32, u64)>>>,
    }
    impl KernelBehavior for RecSink {
        fn on_packet(&mut self, pkt: Packet, io: &mut KernelIo) {
            io.consume(pkt.wire_bytes());
            self.got.lock().unwrap().push((pkt.meta.row, io.now));
        }
        fn on_wake(&mut self, _: u64, _: &mut KernelIo) {}
    }

    /// The §6 scenario in miniature: a cluster-1 source streams 20 rows
    /// to k(0,5) through cluster 0's gateway; the FPGA hosting k(0,5)
    /// dies mid-stream and recovers onto a spare. Inbound rows buffer at
    /// the gateway and drain in order; rows in intra-cluster flight at
    /// the failure are lost; everything is deterministic and identical
    /// at any thread count (at `threads > 1` the run executes in phases
    /// on the sharded engine — see `Sim::run_phased_failure`).
    fn run_failover(threads: usize) -> (Vec<(u32, u64)>, FailureReport, FpgaId, u64) {
        let mut sim = Sim::new();
        sim.set_threads(threads);
        for f in 0..4 {
            sim.fabric.attach(FpgaId(f), SwitchId(0));
        }
        let got = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(k(1, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Source {
            dst: k(0, 5),
            n: 20,
            gap: 40,
            sent: 0,
        }))
        .unwrap();
        sim.add_kernel(k(0, 0), FpgaId(1), Fifo::new(1 << 16), Box::new(FwdGw)).unwrap();
        sim.add_kernel(k(0, 5), FpgaId(2), Fifo::new(1 << 16), Box::new(RecSink {
            got: got.clone(),
        }))
        .unwrap();
        sim.schedule_failure(FailurePlan {
            fpga: FpgaId(2),
            at: 700,
            recovery_cycles: 5_000,
            remap: vec![(k(0, 5), FpgaId(3))],
        })
        .unwrap();
        sim.start();
        sim.run().unwrap();
        let report = sim.failure_report().unwrap();
        let new_home = sim.fabric.fpga_of(k(0, 5)).unwrap();
        let rows = got.lock().unwrap().clone();
        (rows, report, new_home, sim.time)
    }

    #[test]
    fn failure_buffers_at_the_gateway_loses_in_flight_and_recovers() {
        let (rows, report, new_home, _) = run_failover(1);
        assert!(report.recovered, "recovery must have run");
        assert_eq!(report.moved_kernels, 1);
        assert_eq!(new_home, FpgaId(3), "the remap must be live after recovery");
        // §6 accounting: every row is either delivered or was lost on an
        // intra-cluster wire during the outage — never duplicated
        assert_eq!(rows.len() as u64 + report.lost_events, 20);
        assert!(report.lost_events > 0, "rows in gateway->sink flight at T are lost");
        assert!(report.held_packets > 0, "rows arriving during the outage buffer");
        // the buffered backlog drains in order: row indices stay strictly
        // increasing across the outage, and the tail rows all arrive
        assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "in-order drain violated: {rows:?}");
        assert_eq!(rows.last().unwrap().0, 19, "held rows must drain after recovery");
        // nothing reaches the sink inside the outage window
        assert!(rows
            .iter()
            .all(|&(_, t)| t < report.fail_cycle || t >= report.recover_cycle));
        assert_eq!(report.recover_cycle, report.fail_cycle + 5_000);
    }

    #[test]
    fn failover_is_deterministic_and_thread_count_invariant() {
        let seq = run_failover(1);
        assert_eq!(run_failover(1), seq, "same run, same outcome");
        for threads in [2, 8] {
            assert_eq!(
                run_failover(threads),
                seq,
                "the phased sharded failure run must match the sequential engine bit-for-bit"
            );
        }
    }

    #[test]
    fn parallel_matches_sequential_on_lossy_pingpong() {
        // lossy (and lossy + reliable) traffic on the sharded engine:
        // bit-identical to --threads 1, because drop decisions come from
        // per-link RNG streams and the drop log is canonically ordered
        let build = |threads: usize, reliable: bool| {
            let mut sim = Sim::new();
            sim.fabric.attach(FpgaId(0), SwitchId(0));
            sim.fabric.attach(FpgaId(1), SwitchId(0));
            sim.granularity = ShardGranularity::PerFpga;
            sim.set_threads(threads);
            sim.fabric.drop_probability = 0.15;
            sim.fabric.reliable = reliable;
            sim.fabric.seed_drop_rng(13);
            sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Source {
                dst: k(0, 2), n: 40, gap: 35, sent: 0,
            })).unwrap();
            sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 16), Box::new(Sink { got: 0 }))
                .unwrap();
            sim.trace.add_probe(k(0, 2));
            sim.start();
            sim.run().unwrap();
            (
                sim.trace.probe_times(k(0, 2)).unwrap().to_vec(),
                sim.time,
                sim.trace.events_processed,
                sim.fabric.stats.packets,
                (sim.fabric.stats.dropped, sim.fabric.stats.retransmits),
                sim.fabric.drop_trace.clone(),
                sim.fabric.link_audit(),
            )
        };
        for reliable in [false, true] {
            let seq = build(1, reliable);
            assert!(seq.4 .0 > 0, "the 15% run must drop something (reliable={reliable})");
            for threads in [2, 4, 8] {
                assert_eq!(
                    build(threads, reliable),
                    seq,
                    "lossy run diverged at threads={threads} (reliable={reliable})"
                );
            }
        }
    }

    #[test]
    fn schedule_failure_validates_its_plan() {
        let mut sim = Sim::new();
        sim.fabric.attach(FpgaId(0), SwitchId(0));
        sim.fabric.attach(FpgaId(1), SwitchId(0));
        sim.fabric.attach(FpgaId(2), SwitchId(0)); // spare for recovery
        sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(64), Box::new(Sink { got: 0 })).unwrap();
        sim.add_kernel(k(1, 1), FpgaId(1), Fifo::new(64), Box::new(Sink { got: 0 })).unwrap();
        // empty FPGA
        let plan = |fpga, remap| FailurePlan { fpga, at: 10, recovery_cycles: 100, remap };
        assert!(sim.schedule_failure(plan(FpgaId(7), vec![])).is_err());
        // remap crossing the cluster boundary violates §6 isolation
        assert!(sim
            .schedule_failure(plan(FpgaId(0), vec![(k(1, 1), FpgaId(2))]))
            .is_err());
        // remap back onto the failed board
        assert!(sim
            .schedule_failure(plan(FpgaId(0), vec![(k(0, 1), FpgaId(0))]))
            .is_err());
        // a sound plan arms exactly once
        assert!(sim.schedule_failure(plan(FpgaId(0), vec![(k(0, 1), FpgaId(2))])).is_ok());
        assert!(sim.schedule_failure(plan(FpgaId(0), vec![])).is_err(), "one per run");
        let r = sim.failure_report().unwrap();
        assert!(!r.recovered);
        assert_eq!((r.fpga, r.cluster, r.moved_kernels), (FpgaId(0), 0, 1));
    }

    #[test]
    fn obs_records_failure_instants_and_outage_holds() {
        // the run_failover scenario with telemetry enabled: the fail /
        // recover instants and the gateway buffering must be attributed
        let mut sim = Sim::new();
        for f in 0..4 {
            sim.fabric.attach(FpgaId(f), SwitchId(0));
        }
        let got = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        sim.add_kernel(k(1, 1), FpgaId(0), Fifo::new(1 << 16), Box::new(Source {
            dst: k(0, 5),
            n: 20,
            gap: 40,
            sent: 0,
        }))
        .unwrap();
        sim.add_kernel(k(0, 0), FpgaId(1), Fifo::new(1 << 16), Box::new(FwdGw)).unwrap();
        sim.add_kernel(k(0, 5), FpgaId(2), Fifo::new(1 << 16), Box::new(RecSink {
            got: got.clone(),
        }))
        .unwrap();
        sim.enable_obs(1024, &[k(1, 1)]);
        sim.schedule_failure(FailurePlan {
            fpga: FpgaId(2),
            at: 700,
            recovery_cycles: 5_000,
            remap: vec![(k(0, 5), FpgaId(3))],
        })
        .unwrap();
        sim.start();
        sim.run().unwrap();
        let report = sim.failure_report().unwrap();
        assert!(report.recovered);
        let o = sim.trace.obs.as_ref().unwrap();
        let inst = o.sorted_instants();
        assert_eq!(inst.len(), 2);
        assert_eq!((inst[0].kind, inst[0].t, inst[0].fpga), ("fail", 700, 2));
        assert_eq!((inst[1].kind, inst[1].t, inst[1].fpga), ("recover", 5_700, 2));
        assert_eq!(o.outage_holds, report.held_packets);
        assert!(o.outage_hold.get(&0).copied().unwrap_or(0) > 0, "inference 0 held");
    }

    #[test]
    fn self_profile_accumulates_when_enabled() {
        let build = |threads: usize| {
            let mut sim = Sim::new();
            sim.fabric.attach(FpgaId(0), SwitchId(0));
            sim.fabric.attach(FpgaId(1), SwitchId(0));
            sim.granularity = ShardGranularity::PerFpga;
            sim.set_threads(threads);
            sim.profile = true;
            sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), Box::new(Source {
                dst: k(0, 2), n: 30, gap: 25, sent: 0,
            })).unwrap();
            sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
                .unwrap();
            sim.start();
            sim.run().unwrap();
            sim.last_profile.expect("profile requested")
        };
        let seq = build(1);
        assert_eq!(seq.engine, "sequential");
        assert!(seq.events > 0 && seq.sim_cycles > 0);
        let par = build(2);
        assert_eq!(par.engine, "parallel");
        assert_eq!((par.shards, par.threads), (2, 2));
        assert!(par.rounds > 0 && par.window > 0);
        assert_eq!(par.per_shard_events.iter().sum::<u64>(), par.events);
    }

    #[test]
    fn parallel_run_until_pauses_like_sequential() {
        let build = |threads: usize| {
            let mut sim = Sim::new();
            sim.fabric.attach(FpgaId(0), SwitchId(0));
            sim.fabric.attach(FpgaId(1), SwitchId(0));
            sim.granularity = ShardGranularity::PerFpga;
            sim.set_threads(threads);
            sim.add_kernel(k(0, 1), FpgaId(0), Fifo::new(1 << 20), Box::new(Source {
                dst: k(0, 2), n: 60, gap: 40, sent: 0,
            })).unwrap();
            sim.add_kernel(k(0, 2), FpgaId(1), Fifo::new(1 << 20), Box::new(Sink { got: 0 }))
                .unwrap();
            sim.trace.add_probe(k(0, 2));
            sim.start();
            sim.run_until(777).unwrap();
            let mid = (sim.time, sim.trace.events_processed);
            sim.run().unwrap();
            (mid, sim.trace.probe_times(k(0, 2)).unwrap().to_vec(), sim.time)
        };
        assert_eq!(build(4), build(1));
    }
}
