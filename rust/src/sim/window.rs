//! Conservative lookahead for the sharded parallel engine: the safe
//! window is the minimum latency any packet can experience on a
//! cross-shard path, read off the actual placement + switch topology.
//!
//! Every cross-shard edge is inter-FPGA (shards are FPGA-aligned), so
//! the cheapest possible packet pays the full uncontended 1-flit path —
//! output switch, egress, router, NIC serialization, NIC/switch/NIC
//! traversal, any serial inter-switch hops (the `d` of Eq. 1), and the
//! ingress router; `fabric::Fabric::deliver` only ever *adds* link
//! contention on top. A packet emitted at cycle `t` therefore arrives at
//! `>= t + W`, which is exactly the bounded-window guarantee shard.rs
//! relies on.

use super::fabric::Fabric;
use super::packet::GlobalKernelId;
use super::params::point_to_point_latency;
use super::shard::ShardPlan;

/// Minimum serialization cost of any packet (payloads are at least one
/// flit — `params::flits_for_bytes` never returns 0).
pub const MIN_FLITS: u64 = 1;

/// The conservative window of `plan` on `fabric`'s topology: the minimum
/// 1-flit point-to-point latency over every ordered cross-shard FPGA
/// pair that hosts kernels. `None` when no cross-shard pair can
/// communicate (unattached FPGAs) — the shards are then fully
/// independent and the caller may use an unbounded window; in practice
/// the fabric's constants make any real window >= 33 cycles (one-switch
/// inter-FPGA hop), and >= 253 cycles across encoder boundaries.
pub(crate) fn conservative_window(
    plan: &ShardPlan,
    fabric: &Fabric,
    ids: impl Iterator<Item = GlobalKernelId>,
) -> Option<u64> {
    // (fpga, shard, switch) for every FPGA hosting at least one kernel
    let mut used: Vec<(usize, usize, Option<usize>)> = Vec::new();
    for id in ids {
        let f = fabric.fpga_of(id)?;
        if used.iter().any(|&(uf, _, _)| uf == f.0) {
            continue;
        }
        let shard = plan.shard_of(f)?;
        used.push((f.0, shard, fabric.switch_of(f).map(|s| s.0)));
    }
    let mut best: Option<u64> = None;
    for &(fa, sa, swa) in &used {
        for &(fb, sb, swb) in &used {
            if sa == sb {
                continue;
            }
            debug_assert_ne!(fa, fb, "FPGA-aligned shards cannot share an FPGA");
            let (Some(swa), Some(swb)) = (swa, swb) else {
                // unattached endpoint: a send on this pair errors out in
                // the fabric before any event is created — no constraint
                continue;
            };
            let hops = swa.abs_diff(swb) as u64;
            let lat = point_to_point_latency(MIN_FLITS, false, hops);
            best = Some(best.map_or(lat, |b: u64| b.min(lat)));
        }
    }
    // no communicating cross-shard pair at all: unbounded lookahead
    Some(best.unwrap_or(u64::MAX))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::{FpgaId, SwitchId};
    use crate::sim::params::{INTER_SWITCH_LAT, NIC_LAT, OUT_SWITCH_LAT, ROUTER_LAT, SWITCH_LAT};
    use crate::sim::shard::{ShardGranularity, ShardPlan};

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    const ONE_SWITCH_MIN: u64 =
        OUT_SWITCH_LAT + 1 + ROUTER_LAT + 1 + NIC_LAT + SWITCH_LAT + NIC_LAT + ROUTER_LAT;

    #[test]
    fn same_switch_window_is_the_one_switch_hop() {
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(1, 1), FpgaId(1));
        f.attach(FpgaId(0), SwitchId(0));
        f.attach(FpgaId(1), SwitchId(0));
        let ids = [k(0, 1), k(1, 1)];
        let plan =
            ShardPlan::build(ShardGranularity::PerFpga, ids.iter().copied(), &f).unwrap();
        let w = conservative_window(&plan, &f, ids.iter().copied()).unwrap();
        assert_eq!(w, ONE_SWITCH_MIN);
        assert_eq!(w, 33, "paper constants: 33-cycle same-switch lookahead");
    }

    #[test]
    fn cross_switch_window_includes_eq1_d() {
        // shards split at an encoder boundary one serial switch hop
        // apart: the window gains the paper's d = 220 cycles
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(0, 2), FpgaId(1));
        f.place(k(1, 1), FpgaId(2));
        f.attach(FpgaId(0), SwitchId(0));
        f.attach(FpgaId(1), SwitchId(0));
        f.attach(FpgaId(2), SwitchId(1));
        let ids = [k(0, 1), k(0, 2), k(1, 1)];
        let plan =
            ShardPlan::build(ShardGranularity::PerCluster, ids.iter().copied(), &f).unwrap();
        assert_eq!(plan.n_shards, 2);
        let w = conservative_window(&plan, &f, ids.iter().copied()).unwrap();
        assert_eq!(w, ONE_SWITCH_MIN + INTER_SWITCH_LAT);
    }

    #[test]
    fn per_fpga_cut_takes_the_cheapest_edge() {
        // 3 FPGAs, one per shard: the same-switch pair bounds the window
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(0, 2), FpgaId(1));
        f.place(k(0, 3), FpgaId(2));
        f.attach(FpgaId(0), SwitchId(0));
        f.attach(FpgaId(1), SwitchId(0));
        f.attach(FpgaId(2), SwitchId(5));
        let ids = [k(0, 1), k(0, 2), k(0, 3)];
        let plan =
            ShardPlan::build(ShardGranularity::PerFpga, ids.iter().copied(), &f).unwrap();
        let w = conservative_window(&plan, &f, ids.iter().copied()).unwrap();
        assert_eq!(w, ONE_SWITCH_MIN);
    }

    #[test]
    fn unattached_fpgas_do_not_constrain() {
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(1, 1), FpgaId(1));
        // neither FPGA attached: no deliverable cross-shard path at all
        let ids = [k(0, 1), k(1, 1)];
        let plan =
            ShardPlan::build(ShardGranularity::PerFpga, ids.iter().copied(), &f).unwrap();
        let w = conservative_window(&plan, &f, ids.iter().copied()).unwrap();
        assert_eq!(w, u64::MAX, "independent shards get an unbounded window");
    }
}
