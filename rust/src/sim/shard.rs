//! Sharded conservative parallel DES: the fleet is partitioned at
//! inter-FPGA link boundaries and the shards run on worker threads with
//! bounded-window barrier synchronization.
//!
//! Why this is safe (DESIGN.md "Parallel simulation"): every cross-shard
//! packet crosses a physical inter-FPGA path whose minimum latency —
//! computed from the actual topology by `window::conservative_window` —
//! is the lookahead `W` of a classic conservative PDES. Each round, all
//! shards process events in `[gmin, gmin + W)`; any packet emitted in the
//! round arrives at `>= gmin + W`, i.e. strictly after the window, so
//! merging the per-edge mailboxes at the barrier can never violate
//! causality.
//!
//! Why it is *deterministic and trace-identical* to the sequential
//! engine: events are totally ordered by `(time, target slot, Rank)`
//! (see `engine::Rank`), a causal key both engines compute identically —
//! mailbox merges re-sort into the destination wheel by that key, so the
//! destination shard dispatches exactly the sequence the sequential
//! engine would. Sender-side link state (kernel egress, source NIC) is
//! owned by the sender's shard, which is why shards must be FPGA-aligned
//! (`ShardGranularity` groupings never split an FPGA).
//!
//! Lossy and failure runs shard too: drop decisions come from per-link
//! RNG streams owned by the sender's shard (`Fabric::shard_clone`
//! carries them; `absorb_shard` merges them back), and the §6 outage
//! window executes with a per-shard [`OutageFilter`] replica of the
//! sequential gate, armed by `Sim::run_phased_failure` for the segment
//! that runs strictly inside the outage.
//!
//! The bit-identical contract covers runs that complete (or pause)
//! without simulation errors. On a fatal error — unroutable send,
//! event-budget blowout — both engines bail with an error, but the
//! parallel engine stops at a round boundary: sibling shards may have
//! processed up to one extra window and several shards' errors may
//! join, so post-error counters/messages can differ from `threads = 1`
//! (error paths are programming-bug paths, not modeled behavior).

use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::pool;

use super::engine::{deliver_event, Ev, EventQueue, QEv, Rank, Sim, Slot};
use super::fabric::{Fabric, FpgaId};
use super::packet::GlobalKernelId;
use super::trace::Trace;

/// How the fleet is cut into shards. Both options are FPGA-aligned (an
/// FPGA is never split across shards — its NIC egress is a serializing
/// resource the owning shard must model alone).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardGranularity {
    /// One shard per FPGA: maximum parallelism, but the window shrinks
    /// to the cheapest inter-FPGA hop (~33 cycles on one switch), so
    /// barrier rounds dominate on large fleets.
    PerFpga,
    /// One shard per cluster (= per encoder in the testbeds), merging
    /// FPGAs that host kernels of the same cluster (union-find, so a
    /// placement co-locating two clusters on one FPGA merges their
    /// shards). Cross-shard edges then cross encoder boundaries — the
    /// serial switch hop of Eq. 1 — giving a ~253-cycle window. Default.
    PerCluster,
}

/// The fleet partition: a dense shard id per FPGA (only FPGAs hosting
/// kernels participate).
#[derive(Debug, Clone)]
pub(crate) struct ShardPlan {
    /// FPGA index -> shard id + 1; 0 = hosts no kernels.
    shard_of_fpga: Vec<u32>,
    pub(crate) n_shards: usize,
}

impl ShardPlan {
    /// Build the partition, or None when it would not split the fleet
    /// (single shard — the sequential engine is the parallel engine).
    pub(crate) fn build(
        granularity: ShardGranularity,
        ids: impl Iterator<Item = GlobalKernelId> + Clone,
        fabric: &Fabric,
    ) -> Option<ShardPlan> {
        let mut max_fpga = 0usize;
        for id in ids.clone() {
            max_fpga = max_fpga.max(fabric.fpga_of(id)?.0);
        }
        // union-find over FPGA indices
        let mut parent: Vec<usize> = (0..=max_fpga).collect();
        fn find(parent: &mut [usize], mut x: usize) -> usize {
            while parent[x] != x {
                parent[x] = parent[parent[x]];
                x = parent[x];
            }
            x
        }
        let mut hosts = vec![false; max_fpga + 1];
        let mut cluster_first: [usize; 256] = [usize::MAX; 256];
        for id in ids {
            let f = fabric.fpga_of(id)?.0;
            hosts[f] = true;
            if granularity == ShardGranularity::PerCluster {
                let c = id.cluster as usize;
                if cluster_first[c] == usize::MAX {
                    cluster_first[c] = f;
                } else {
                    let (a, b) = (find(&mut parent, cluster_first[c]), find(&mut parent, f));
                    parent[a.max(b)] = a.min(b);
                }
            }
        }
        // dense shard ids in ascending root-FPGA order (deterministic)
        let mut shard_of_fpga = vec![0u32; max_fpga + 1];
        let mut next = 0u32;
        let mut of_root = vec![0u32; max_fpga + 1];
        for f in 0..=max_fpga {
            if !hosts[f] {
                continue;
            }
            let r = find(&mut parent, f);
            if of_root[r] == 0 {
                next += 1;
                of_root[r] = next;
            }
            shard_of_fpga[f] = of_root[r];
        }
        let n_shards = next as usize;
        (n_shards >= 2).then_some(ShardPlan { shard_of_fpga, n_shards })
    }

    #[inline]
    pub(crate) fn shard_of(&self, f: FpgaId) -> Option<usize> {
        match self.shard_of_fpga.get(f.0).copied().unwrap_or(0) {
            0 => None,
            s => Some(s as usize - 1),
        }
    }

    /// Shard id per global kernel slot (in slot order).
    pub(crate) fn owner_of_slots(
        &self,
        ids: impl Iterator<Item = GlobalKernelId>,
        fabric: &Fabric,
    ) -> Vec<u16> {
        ids.map(|id| {
            let f = fabric.fpga_of(id).expect("registered kernels are placed");
            self.shard_of(f).expect("kernel-hosting FPGA has a shard") as u16
        })
        .collect()
    }
}

// ---------------------------------------------------------------------------
// Lock-free per-edge mailbox (Treiber stack). Each (src shard, dst
// shard) edge has exactly one producer (the src worker, during the
// compute phase) and one consumer (the dst worker, after the barrier),
// so the CAS never spins in practice; the stack keeps it safe even for
// hypothetical multi-producer use. Drain order is irrelevant — the
// destination wheel re-sorts by (time, target, rank).
// ---------------------------------------------------------------------------

struct MbNode {
    ev: QEv,
    next: *mut MbNode,
}

pub(crate) struct Mailbox {
    head: AtomicPtr<MbNode>,
}

// Safety: nodes are heap-allocated and ownership transfers wholesale on
// push (producer gives up the node) and drain (consumer takes the whole
// chain with one swap); QEv is Send.
unsafe impl Send for Mailbox {}
unsafe impl Sync for Mailbox {}

impl Mailbox {
    fn new() -> Mailbox {
        Mailbox { head: AtomicPtr::new(std::ptr::null_mut()) }
    }

    fn push(&self, ev: QEv) {
        let node = Box::into_raw(Box::new(MbNode { ev, next: std::ptr::null_mut() }));
        let mut cur = self.head.load(Ordering::Relaxed);
        loop {
            unsafe { (*node).next = cur };
            match self.head.compare_exchange_weak(cur, node, Ordering::Release, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    fn drain(&self, out: &mut Vec<QEv>) {
        let mut p = self.head.swap(std::ptr::null_mut(), Ordering::Acquire);
        while !p.is_null() {
            let node = unsafe { Box::from_raw(p) };
            p = node.next;
            out.push(node.ev);
        }
    }

    fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire).is_null()
    }
}

impl Drop for Mailbox {
    fn drop(&mut self) {
        let mut sink = Vec::new();
        self.drain(&mut sink);
    }
}

// ---------------------------------------------------------------------------
// One shard: a slice of the fleet with its own wheel, link state, trace.
// ---------------------------------------------------------------------------

/// Shard-local replica of the sequential engine's §6 outage gate
/// (`Sim::filter_failed`). During the Down phase of a phased failure
/// run (`Sim::run_phased_failure`) every shard filters the events it
/// pops exactly like the sequential engine would: cross-cluster packets
/// buffer (FIFO bytes charged, hold attributed to the observer),
/// intra-cluster packets are lost, wakes suspend. The held events are
/// key-ordered subsequences of the sequential hold order, so the master
/// can merge them back with one sort (`Sim::absorb_outage`).
pub(crate) struct OutageFilter {
    cluster: u8,
    recover_at: u64,
    held: Vec<QEv>,
    held_packets: u64,
    lost_events: u64,
}

pub(crate) struct Shard {
    idx: usize,
    pub(crate) queue: EventQueue,
    kernels: Vec<Slot>,
    /// local index -> global kernel slot (ascending).
    global_slots: Vec<u32>,
    /// local index -> the master trace slot to restore at teardown.
    master_tslots: Vec<usize>,
    /// global kernel slot -> local index + 1; 0 = foreign shard.
    local_of: Vec<u32>,
    /// global kernel slot -> owning shard.
    owner: Arc<Vec<u16>>,
    /// shared dense id -> global slot + 1 resolution table.
    slot16: Arc<Vec<u32>>,
    /// private fabric copy: only this shard's kernel-egress / NIC
    /// entries are ever exercised (FPGA alignment); stats start zeroed.
    fabric: Fabric,
    trace: Trace,
    errors: Vec<String>,
    time: u64,
    ctr: u64,
    coalescing: bool,
    /// dense kernel ids / FPGA indices owned (for link-state merge-back).
    kernel_dense: Vec<usize>,
    fpgas: Vec<usize>,
    pending_buf: Vec<(u64, u32, Ev)>,
    wakes_buf: Vec<(u64, u64)>,
    /// Some = this window runs inside a §6 outage (phase B of a phased
    /// failure run); popped events targeting the failed cluster are
    /// absorbed instead of dispatched.
    outage: Option<OutageFilter>,
}

impl Shard {
    /// Install the outage gate for a phase-B run. The master only calls
    /// this when the failure is in the Down phase, so every event this
    /// shard will pop satisfies `at <= t < recover_at` by construction.
    pub(crate) fn arm_outage(&mut self, cluster: u8, recover_at: u64) {
        self.outage = Some(OutageFilter {
            cluster,
            recover_at,
            held: Vec::new(),
            held_packets: 0,
            lost_events: 0,
        });
    }

    /// Shard-side mirror of `Sim::filter_failed`'s Down branch. Returns
    /// the event back when it should dispatch normally; absorbs it
    /// (hold or lose) when the target cluster is down. Filtered pops do
    /// not advance shard time or count as processed events — exactly
    /// like the sequential engine's `continue`.
    fn filter_outage(&mut self, e: QEv) -> Option<QEv> {
        let Some(fo) = self.outage.as_mut() else { return Some(e) };
        debug_assert!(e.time < fo.recover_at, "phase B runs strictly inside the outage");
        let local = self.local_of[e.target as usize];
        debug_assert!(local != 0, "event routed to the wrong shard");
        let slot = &mut self.kernels[local as usize - 1];
        if slot.id.cluster != fo.cluster {
            return Some(e);
        }
        enum Hold {
            Buffer(usize),
            Lose,
            Suspend,
        }
        let action = match &e.ev {
            // §6: traffic from outside the cluster buffers in the
            // cluster input buffer; its bytes occupy the gateway FIFO
            // until recovery
            Ev::Packet(p) if p.src.cluster != fo.cluster => Hold::Buffer(p.wire_bytes()),
            // intra-cluster rows lived on wires/FIFOs of the region
            // being wiped: lost
            Ev::Packet(_) => Hold::Lose,
            // kernel-internal schedules pause and resume at recovery
            Ev::Wake(_) => Hold::Suspend,
        };
        match action {
            Hold::Buffer(bytes) => {
                slot.fifo.push(bytes);
                fo.held_packets += 1;
                if let (Some(o), Ev::Packet(p)) = (self.trace.obs.as_deref_mut(), &e.ev) {
                    o.on_outage_hold(p.meta.inference, fo.recover_at - e.time);
                }
                fo.held.push(e);
            }
            Hold::Suspend => fo.held.push(e),
            Hold::Lose => fo.lost_events += 1,
        }
        None
    }
    /// Process queued events with `time <= wlast`, at most `cap` of
    /// them; returns the event count. Cross-shard emissions go to
    /// `mailboxes[dst][src]`. The cap is the runaway-kernel guard: a
    /// same-cycle self-wake loop would otherwise keep `peek_time() <=
    /// wlast` forever and hang the window instead of tripping the
    /// `max_events` error the sequential engine raises.
    fn run_window(&mut self, wlast: u64, cap: u64, mailboxes: &[Vec<Mailbox>]) -> u64 {
        let mut processed = 0u64;
        while let Some(t) = self.queue.peek_time() {
            if t > wlast || processed >= cap || !self.errors.is_empty() {
                break;
            }
            let e = self.queue.pop().unwrap();
            // §6 outage gate (phase B only): absorbed events do not
            // advance shard time or count as processed — exactly like
            // the sequential engine's `continue` after `filter_failed`
            let Some(e) = self.filter_outage(e) else { continue };
            self.dispatch(e, wlast, mailboxes);
            processed += 1;
        }
        processed
    }

    fn dispatch(&mut self, entry: QEv, wlast: u64, mailboxes: &[Vec<Mailbox>]) {
        debug_assert!(entry.time >= self.time, "shard time went backwards");
        self.time = entry.time;
        self.trace.events_processed += 1;

        let target = entry.target;
        let local = self.local_of[target as usize];
        debug_assert!(local != 0, "event routed to the wrong shard");
        let slot = &mut self.kernels[local as usize - 1];
        self.pending_buf.clear();
        self.wakes_buf.clear();
        deliver_event(
            self.time,
            slot,
            entry.ev,
            self.coalescing,
            &mut self.fabric,
            &mut self.trace,
            &self.slot16,
            &mut self.errors,
            &mut self.pending_buf,
            &mut self.wakes_buf,
        );

        for (t, dst_slot, ev) in self.pending_buf.drain(..) {
            self.ctr += 1;
            let e = QEv {
                time: t,
                target: dst_slot,
                rank: Rank::emission(self.time, target, self.ctr),
                ev,
            };
            let dst_shard = self.owner[dst_slot as usize] as usize;
            if dst_shard == self.idx {
                self.queue.push(e);
            } else {
                debug_assert!(t > wlast, "conservative lookahead violated");
                mailboxes[dst_shard][self.idx].push(e);
            }
        }
        for (t, tag) in self.wakes_buf.drain(..) {
            self.ctr += 1;
            self.queue.push(QEv {
                time: t,
                target,
                rank: Rank::emission(self.time, target, self.ctr),
                ev: Ev::Wake(tag),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Partition / execute / absorb
// ---------------------------------------------------------------------------

/// Carve the master `Sim` into shards: kernels, probe registration,
/// per-shard fabric copies. The event queue is routed by the caller.
pub(crate) fn partition(
    sim: &mut Sim,
    plan: &ShardPlan,
    owner: &Arc<Vec<u16>>,
    slot16: &Arc<Vec<u32>>,
    ctr0: u64,
    coalescing: bool,
) -> Vec<Shard> {
    let kernels = sim.take_kernels();
    let n_slots = kernels.len();
    let mut shards: Vec<Shard> = (0..plan.n_shards)
        .map(|idx| Shard {
            idx,
            queue: EventQueue::new(),
            kernels: Vec::new(),
            global_slots: Vec::new(),
            master_tslots: Vec::new(),
            local_of: vec![0u32; n_slots],
            owner: owner.clone(),
            slot16: slot16.clone(),
            fabric: sim.fabric.shard_clone(),
            trace: Trace::default(),
            errors: Vec::new(),
            time: sim.time,
            ctr: ctr0,
            coalescing,
            kernel_dense: Vec::new(),
            fpgas: Vec::new(),
            pending_buf: Vec::new(),
            wakes_buf: Vec::new(),
            outage: None,
        })
        .collect();
    // matching per-shard telemetry collectors — installed before kernel
    // registration so the per-slot mark flags build up as slots appear
    if let Some((interval, mark_set)) = sim.trace.obs_spec() {
        for sh in &mut shards {
            sh.trace.obs =
                Some(Box::new(crate::obs::span::TraceObs::new(interval, mark_set.clone())));
        }
    }
    for (gslot, mut slot) in kernels.into_iter().enumerate() {
        let sh = &mut shards[owner[gslot] as usize];
        sh.local_of[gslot] = sh.kernels.len() as u32 + 1;
        sh.global_slots.push(gslot as u32);
        sh.master_tslots.push(slot.tslot);
        sh.kernel_dense.push(slot.id.dense());
        let f = sim.fabric.fpga_of(slot.id).expect("registered kernels are placed").0;
        if !sh.fpgas.contains(&f) {
            sh.fpgas.push(f);
        }
        // per-shard trace slots, with the master's probe set carried over
        slot.tslot = sh.trace.register(slot.id);
        if sim.trace.is_probe(slot.id) {
            sh.trace.add_probe(slot.id);
        }
        sh.kernels.push(slot);
    }
    shards
}

/// Result of one windowed parallel execution.
pub(crate) struct Outcome {
    pub(crate) shards: Vec<Shard>,
    pub(crate) processed: u64,
    pub(crate) budget_exceeded: bool,
    /// barrier rounds executed (self-profile; 0 unless profiling).
    pub(crate) rounds: u64,
    /// summed wall-time workers spent blocked on the three per-round
    /// barriers (self-profile; 0 unless profiling).
    pub(crate) barrier_wait_ns: u64,
    /// events each shard processed, in shard-index order (self-profile;
    /// empty unless profiling).
    pub(crate) per_shard_events: Vec<u64>,
}

/// Sense-reversing barrier with an abort path: `std::sync::Barrier`
/// cannot be poisoned, so a panicking worker would leave the survivors
/// waiting forever. `wait` returns false once the formation is aborted
/// and every current + future waiter is released immediately.
struct AbortBarrier {
    state: Mutex<(usize, u64, bool)>, // (count, generation, aborted)
    cv: Condvar,
    parties: usize,
}

impl AbortBarrier {
    fn new(parties: usize) -> AbortBarrier {
        AbortBarrier { state: Mutex::new((0, 0, false)), cv: Condvar::new(), parties }
    }

    /// Block until all parties arrive; false = formation aborted.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.2 {
            return false;
        }
        st.0 += 1;
        if st.0 == self.parties {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            return true;
        }
        let gen = st.1;
        while st.1 == gen && !st.2 {
            st = self.cv.wait(st).unwrap();
        }
        !st.2
    }

    fn abort(&self) {
        self.state.lock().unwrap().2 = true;
        self.cv.notify_all();
    }
}

struct Coord {
    barrier: AbortBarrier,
    /// next global event time, double-buffered by round parity so the
    /// reset of round r+1's slot cannot race round r's reads.
    next: [AtomicU64; 2],
    stop: AtomicBool,
    budget_hit: AtomicBool,
    processed: AtomicU64,
    /// self-profile accumulators — written only when profiling is on,
    /// so the default path never touches them inside the round loop.
    rounds: AtomicU64,
    barrier_wait_ns: AtomicU64,
}

/// Barrier wait, optionally timed for the simulator self-profile.
#[inline]
fn barrier_wait(coord: &Coord, profile: bool, acc: &mut u64) -> bool {
    if !profile {
        return coord.barrier.wait();
    }
    let t0 = std::time::Instant::now();
    let ok = coord.barrier.wait();
    *acc += t0.elapsed().as_nanos() as u64;
    ok
}

/// Run the bounded-window loop: `threads` workers (capped at the shard
/// count) each own a fixed round-robin set of shards; three barriers per
/// round separate (a) the global-min reduction, (b) window processing
/// with mailbox sends, and (c) mailbox merges.
pub(crate) fn run_windowed(
    shards: Vec<Shard>,
    threads: usize,
    window: u64,
    until: u64,
    events_budget: u64,
    profile: bool,
) -> Outcome {
    let n_shards = shards.len();
    let workers = threads.clamp(1, n_shards);
    let mut per_worker: Vec<Vec<Shard>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, sh) in shards.into_iter().enumerate() {
        per_worker[i % workers].push(sh);
    }
    let slots: Vec<Mutex<Vec<Shard>>> = per_worker.into_iter().map(Mutex::new).collect();
    let mailboxes: Vec<Vec<Mailbox>> = (0..n_shards)
        .map(|_| (0..n_shards).map(|_| Mailbox::new()).collect())
        .collect();
    let coord = Coord {
        barrier: AbortBarrier::new(workers),
        next: [AtomicU64::new(u64::MAX), AtomicU64::new(u64::MAX)],
        stop: AtomicBool::new(false),
        budget_hit: AtomicBool::new(false),
        processed: AtomicU64::new(0),
        rounds: AtomicU64::new(0),
        barrier_wait_ns: AtomicU64::new(0),
    };
    let panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);

    pool::run_workers(workers, |w| {
        // a panic anywhere in the round loop aborts the barrier so the
        // other workers return instead of deadlocking, then re-raises
        // after the join (same observable behavior as the sequential
        // engine's panic)
        let body =
            || worker_rounds(w, &slots, &coord, &mailboxes, window, until, events_budget, profile);
        if let Err(p) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body)) {
            coord.barrier.abort();
            *panic_payload.lock().unwrap() = Some(p);
        }
    });

    if let Some(p) = panic_payload.into_inner().unwrap() {
        std::panic::resume_unwind(p);
    }
    debug_assert!(
        mailboxes.iter().flatten().all(|m| m.is_empty()),
        "undelivered cross-shard events after the run"
    );
    let mut shards: Vec<Shard> =
        slots.into_iter().flat_map(|m| m.into_inner().unwrap()).collect();
    shards.sort_by_key(|s| s.idx);
    let per_shard_events = if profile {
        shards.iter().map(|s| s.trace.events_processed).collect()
    } else {
        Vec::new()
    };
    Outcome {
        shards,
        processed: coord.processed.load(Ordering::SeqCst),
        budget_exceeded: coord.budget_hit.load(Ordering::SeqCst),
        rounds: coord.rounds.load(Ordering::SeqCst),
        barrier_wait_ns: coord.barrier_wait_ns.load(Ordering::SeqCst),
        per_shard_events,
    }
}

/// One worker's barrier-round loop over its owned shards.
#[allow(clippy::too_many_arguments)]
fn worker_rounds(
    w: usize,
    slots: &[Mutex<Vec<Shard>>],
    coord: &Coord,
    mailboxes: &[Vec<Mailbox>],
    window: u64,
    until: u64,
    events_budget: u64,
    profile: bool,
) {
    let mut my = slots[w].lock().unwrap();
    let mut round = 0usize;
    let mut worker_done = 0u64;
    let mut wait_ns = 0u64;
    let mut merged: Vec<QEv> = Vec::new();
    'rounds: loop {
        // (a) reduce the global minimum next event time. `stop` is
        // snapshotted HERE, in the read-only phase: writes only
        // happen during window processing (b), which every worker
        // finished before the previous round's merge barrier — a
        // fresh load at the decision point below could race a fast
        // worker's new write and split the break decision (deadlock)
        let stopped = coord.stop.load(Ordering::SeqCst);
        let slot = &coord.next[round & 1];
        let mut lmin = u64::MAX;
        for sh in my.iter() {
            if let Some(t) = sh.queue.peek_time() {
                lmin = lmin.min(t);
            }
        }
        slot.fetch_min(lmin, Ordering::SeqCst);
        if !barrier_wait(coord, profile, &mut wait_ns) {
            break 'rounds; // another worker panicked: unwind cleanly
        }
        let gmin = slot.load(Ordering::SeqCst);
        // every worker takes the same branch: gmin is the barrier-
        // reduced value and `stopped` predates the barrier
        if gmin == u64::MAX || gmin > until || stopped {
            break 'rounds;
        }
        // pre-arm the other parity slot; it is not read before the
        // next round's barrier, and every worker writes the same MAX
        coord.next[(round + 1) & 1].store(u64::MAX, Ordering::SeqCst);

        // (b) process the window [gmin, gmin + window) (clamped)
        let wlast = gmin.saturating_add(window - 1).min(until);
        let mut processed = 0u64;
        let mut had_err = false;
        for sh in my.iter_mut() {
            // each shard may at most exhaust the whole remaining
            // global budget (+1 so the overshoot trips the check)
            let cap = events_budget.saturating_sub(worker_done + processed) + 1;
            processed += sh.run_window(wlast, cap, mailboxes);
            had_err |= !sh.errors.is_empty();
        }
        worker_done += processed;
        let total = coord.processed.fetch_add(processed, Ordering::SeqCst) + processed;
        if had_err {
            coord.stop.store(true, Ordering::SeqCst);
        }
        if total > events_budget {
            coord.budget_hit.store(true, Ordering::SeqCst);
            coord.stop.store(true, Ordering::SeqCst);
        }
        if !barrier_wait(coord, profile, &mut wait_ns) {
            break 'rounds;
        }

        // (c) merge this worker's inbound mailboxes
        for sh in my.iter_mut() {
            merged.clear();
            for src in &mailboxes[sh.idx] {
                src.drain(&mut merged);
            }
            for e in merged.drain(..) {
                sh.queue.push(e);
            }
        }
        if !barrier_wait(coord, profile, &mut wait_ns) {
            break 'rounds;
        }
        round += 1;
    }
    if profile {
        coord.rounds.fetch_max(round as u64, Ordering::SeqCst);
        coord.barrier_wait_ns.fetch_add(wait_ns, Ordering::SeqCst);
    }
}

/// Merge shard state back into the master `Sim`: kernels in global slot
/// order (master trace slots restored), remaining events, link state,
/// traces, clocks, errors. After this the `Sim` is indistinguishable
/// from one that ran sequentially.
pub(crate) fn absorb(sim: &mut Sim, shards: Vec<Shard>) {
    let n_slots: usize = shards.iter().map(|s| s.kernels.len()).sum();
    let mut slots: Vec<Option<Slot>> = (0..n_slots).map(|_| None).collect();
    for mut sh in shards {
        sim.fabric.absorb_shard(&sh.fabric, &sh.kernel_dense, &sh.fpgas);
        sim.merge_clock(sh.time, sh.ctr);
        sim.errors.append(&mut sh.errors);
        // §6 outage gate: hand the absorbed backlog back to the master
        // failure state (key-sorted there into sequential hold order)
        if let Some(fo) = sh.outage.take() {
            sim.absorb_outage(fo.held, fo.held_packets, fo.lost_events);
        }
        for e in sh.queue.drain_ordered() {
            sim.push_event(e);
        }
        for ((mut slot, gslot), mtslot) in sh
            .kernels
            .into_iter()
            .zip(sh.global_slots.iter())
            .zip(sh.master_tslots.iter())
        {
            slot.tslot = *mtslot;
            slots[*gslot as usize] = Some(slot);
        }
        sim.trace.absorb(sh.trace);
    }
    sim.put_kernels(slots.into_iter().map(|s| s.expect("every slot restored")).collect());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::SwitchId;

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    fn fabric_3fpga() -> Fabric {
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(0, 2), FpgaId(1));
        f.place(k(1, 0), FpgaId(2));
        f.place(k(1, 1), FpgaId(1));
        f.attach(FpgaId(0), SwitchId(0));
        f.attach(FpgaId(1), SwitchId(0));
        f.attach(FpgaId(2), SwitchId(1));
        f
    }

    #[test]
    fn per_fpga_plan_is_one_shard_per_fpga() {
        let f = fabric_3fpga();
        let ids = [k(0, 1), k(0, 2), k(1, 0), k(1, 1)];
        let plan =
            ShardPlan::build(ShardGranularity::PerFpga, ids.iter().copied(), &f).unwrap();
        assert_eq!(plan.n_shards, 3);
        let owner = plan.owner_of_slots(ids.iter().copied(), &f);
        assert_eq!(owner, vec![0, 1, 2, 1]);
    }

    #[test]
    fn per_cluster_plan_merges_shared_fpgas() {
        // cluster 1 spans FPGAs 1 and 2, but FPGA 1 also hosts cluster 0
        // kernels -> union-find must merge everything reachable
        let f = fabric_3fpga();
        let ids = [k(0, 1), k(0, 2), k(1, 0), k(1, 1)];
        let plan = ShardPlan::build(ShardGranularity::PerCluster, ids.iter().copied(), &f);
        // clusters 0 {f0,f1} and 1 {f1,f2} share FPGA 1: one shard only
        assert!(plan.is_none(), "overlapping clusters must collapse to a single shard");
        // disjoint clusters split cleanly
        let ids2 = [k(0, 1), k(1, 0)];
        let plan2 =
            ShardPlan::build(ShardGranularity::PerCluster, ids2.iter().copied(), &f).unwrap();
        assert_eq!(plan2.n_shards, 2);
    }

    #[test]
    fn single_fpga_never_splits() {
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(0, 2), FpgaId(0));
        f.attach(FpgaId(0), SwitchId(0));
        let ids = [k(0, 1), k(0, 2)];
        assert!(ShardPlan::build(ShardGranularity::PerFpga, ids.iter().copied(), &f).is_none());
    }

    #[test]
    fn mailbox_transfers_everything_exactly_once() {
        let mb = Mailbox::new();
        assert!(mb.is_empty());
        for i in 0..100u64 {
            mb.push(QEv {
                time: i,
                target: (i % 7) as u32,
                rank: Rank::genesis(i),
                ev: Ev::Wake(i),
            });
        }
        assert!(!mb.is_empty());
        let mut out = Vec::new();
        mb.drain(&mut out);
        assert!(mb.is_empty());
        let mut tags: Vec<u64> = out
            .iter()
            .map(|e| match e.ev {
                Ev::Wake(t) => t,
                _ => unreachable!(),
            })
            .collect();
        tags.sort_unstable();
        assert_eq!(tags, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn mailbox_concurrent_pushes_survive_drain() {
        let mb = std::sync::Arc::new(Mailbox::new());
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let mb = mb.clone();
                s.spawn(move || {
                    for i in 0..250u64 {
                        mb.push(QEv {
                            time: t * 1000 + i,
                            target: 0,
                            rank: Rank::genesis(t * 1000 + i),
                            ev: Ev::Wake(t * 1000 + i),
                        });
                    }
                });
            }
        });
        let mut out = Vec::new();
        mb.drain(&mut out);
        assert_eq!(out.len(), 1000);
    }
}
