//! The physical fabric model: FPGAs on 100G switches, link serialization,
//! and the two-table routing semantics of the enhanced Galapagos (§4).
//!
//! Hops are computed analytically (no per-hop events): each shared link
//! keeps a `next_free` cycle; a packet occupies its links for `flits()`
//! cycles in sequence, which preserves serialization contention while the
//! event count stays one-per-packet.
//!
//! All lookup state is held in dense flat tables indexed by
//! `GlobalKernelId::dense()` / FPGA index — the per-packet hot path does
//! no hashing (the seed engine paid several hash lookups per delivery).

use anyhow::{bail, Result};

use crate::util::fxhash::FxHashMap;

use super::packet::{GlobalKernelId, Packet, DENSE_IDS};
use super::params::{
    INTER_SWITCH_LAT, NIC_LAT, OUT_SWITCH_LAT, RETX_TIMEOUT, ROUTER_LAT, SWITCH_LAT,
};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FpgaId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SwitchId(pub usize);

/// Occupy a serializing link for `dur` cycles starting no earlier than
/// `t`; returns the cycle at which the last flit has left.
#[inline]
fn occupy(next_free: &mut u64, t: u64, dur: u64) -> u64 {
    let start = t.max(*next_free);
    *next_free = start + dur;
    *next_free
}

/// Statistics the fabric accumulates.
///
/// The counting contract (drops accounted separately from deliveries —
/// the drop-rate arithmetic over these fields is exact, not approximate):
///
/// * `packets` — logical packets offered to the fabric (one per send,
///   regardless of how many wire copies the reliable layer needed);
/// * `intra_fpga_packets` / `inter_fpga_packets` — packets **delivered**
///   on each path class; a lossy-mode loss is counted in `dropped` only;
/// * `inter_switch_packets` — delivered packets that crossed at least
///   one serial inter-switch hop (a subset of `inter_fpga_packets`);
/// * `dropped` — wire copies lost by the lossy network (in reliable mode
///   every one of them was retransmitted, so `dropped == retransmits`);
/// * `retransmits` — extra wire copies the reliable layer serialized;
/// * `flits` — flits actually serialized, retransmitted copies included.
///
/// Invariants (enforced by tests):
/// `packets == intra + inter + dropped` without reliable transport, and
/// `packets == intra + inter` (with `dropped == retransmits`) with it.
#[derive(Debug, Clone, Default)]
pub struct FabricStats {
    pub packets: u64,
    pub flits: u64,
    pub intra_fpga_packets: u64,
    pub inter_fpga_packets: u64,
    pub inter_switch_packets: u64,
    pub dropped: u64,
    pub retransmits: u64,
}

impl FabricStats {
    /// Fold another counter set in (shard merge-back).
    pub(crate) fn absorb(&mut self, o: &FabricStats) {
        self.packets += o.packets;
        self.flits += o.flits;
        self.intra_fpga_packets += o.intra_fpga_packets;
        self.inter_fpga_packets += o.inter_fpga_packets;
        self.inter_switch_packets += o.inter_switch_packets;
        self.dropped += o.dropped;
        self.retransmits += o.retransmits;
    }
}

/// Per-link sequence accounting of the reliable/lossy transport: one
/// entry per (source FPGA, destination FPGA) pair that carried lossy
/// traffic. `sent` is the link's tx sequence counter (one per logical
/// packet), `delivered` the packets that reached the far side, and
/// `dropped_copies` the wire copies the network ate. Exactly-once is
/// the testable identity `delivered == sent` under reliable transport
/// (and `sent == delivered + dropped_copies` without it).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkSeq {
    pub sent: u64,
    pub delivered: u64,
    pub dropped_copies: u64,
}

/// One wire copy the lossy network ate — the canonical record of a drop.
///
/// The derived `Ord` (send cycle, then source FPGA, destination FPGA,
/// per-link copy number) is a *total* order: `seq` is the link's
/// `dropped_copies` counter at the moment of the loss, so no two records
/// compare equal. Both engines sort the trace by this key at the end of a
/// run, which is what makes lossy traces byte-identical across thread
/// counts and shard granularities — the per-link RNG streams guarantee the
/// *multiset* of drops is plan-invariant, and the canonical sort removes
/// the only remaining degree of freedom (the interleaving of pushes from
/// different links within a cycle).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct DropRecord {
    /// send cycle of the lost wire copy.
    pub t: u64,
    /// source FPGA index.
    pub src: u32,
    /// destination FPGA index.
    pub dst: u32,
    /// per-link copy number (the link's `dropped_copies` after this loss).
    pub seq: u64,
}

/// Derive the seed of one directed link's drop-RNG stream from the run
/// seed: a splitmix64-style finalizer over (seed, link id), so streams are
/// statistically independent per link yet fully determined by the run
/// seed — no cross-link draw order exists to preserve, which is exactly
/// what makes lossy outcomes shard-plan-invariant.
#[inline]
fn link_stream_seed(seed: u64, src_f: u32, dst_f: u32) -> u64 {
    let id = ((src_f as u64) << 32) | dst_f as u64;
    let mut z = seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Placement and topology of the platform.
///
/// All per-link mutable state is *sender-side* (the sending kernel's
/// egress port, the source FPGA's NIC): delivery times are computed
/// entirely from resources the sender owns, which is what lets the
/// sharded engine give every FPGA-aligned shard a private copy
/// (`shard_clone`) and merge the touched entries back afterwards
/// (`absorb_shard`).
#[derive(Debug, Clone)]
pub struct Fabric {
    /// kernel (dense id) -> FPGA index + 1; 0 = unplaced. `Arc`d so the
    /// per-shard fabric copies share the (build-time-frozen) table
    /// instead of duplicating 256 KB per shard; `place` copies-on-write.
    placement: std::sync::Arc<Vec<u32>>,
    /// serialization state per kernel egress port (dense id -> next_free).
    kernel_egress: Box<[u64]>,
    /// FPGA index -> switch index + 1; 0 = unattached. Grows on attach.
    attachment: Vec<u32>,
    /// serialization state per FPGA NIC (egress); grows with attachment.
    nic_egress: Vec<u64>,
    /// optional packet-loss probability on inter-FPGA hops (UDP is
    /// unreliable; off by default like the paper's testbed experience).
    pub drop_probability: f64,
    /// reliable transport (§2.1 hardening): lost copies are detected a
    /// [`RETX_TIMEOUT`] after their last flit left the NIC and
    /// re-serialized on the sender's NIC until one gets through — every
    /// logical packet is delivered exactly once, and every retry's
    /// serialization cost lands on the sender's link state.
    pub reliable: bool,
    /// base seed of the per-link drop-RNG streams (set by
    /// [`Fabric::seed_drop_rng`]; streams derive lazily per directed link).
    drop_seed: u64,
    /// per-(src FPGA, dst FPGA) drop-RNG streams, created on first lossy
    /// use of the link. Each stream's draw sequence depends only on the
    /// link's own traffic, so drop decisions are identical under any shard
    /// plan and thread count (every link is owned by its sender's shard).
    drop_rngs: FxHashMap<(u32, u32), crate::util::rng::Rng>,
    /// every wire copy the lossy network ate — the seed-determinism
    /// regression surface for lossy runs. Engines canonicalize the order
    /// ([`Fabric::canonicalize_drop_trace`]) at the end of a run.
    pub drop_trace: Vec<DropRecord>,
    /// per-(src FPGA, dst FPGA) sequence accounting; only populated in
    /// lossy mode (`drop_probability > 0`) so the zero-loss hot path
    /// stays hash-free.
    link_seq: FxHashMap<(u32, u32), LinkSeq>,
    pub stats: FabricStats,
    /// Optional telemetry collector (None = telemetry off): per-bucket
    /// link occupancy, drop/retransmit series, per-inference serialize
    /// waits and retransmit stalls. See [`crate::obs`].
    pub obs: Option<Box<crate::obs::FabricObs>>,
}

impl Default for Fabric {
    fn default() -> Self {
        Self::new()
    }
}

impl Fabric {
    pub fn new() -> Self {
        Fabric {
            placement: std::sync::Arc::new(vec![0u32; DENSE_IDS]),
            kernel_egress: vec![0u64; DENSE_IDS].into_boxed_slice(),
            attachment: Vec::new(),
            nic_egress: Vec::new(),
            drop_probability: 0.0,
            reliable: false,
            drop_seed: 0xD1CE,
            drop_rngs: FxHashMap::default(),
            drop_trace: Vec::new(),
            link_seq: FxHashMap::default(),
            stats: FabricStats::default(),
            obs: None,
        }
    }

    /// Enable the link-telemetry collector at the given bucket width.
    pub fn enable_obs(&mut self, interval: u64) {
        self.obs = Some(Box::new(crate::obs::FabricObs::new(interval)));
    }

    /// Derive the lossy-network RNG streams from the run seed. Every
    /// harness that seeds its traffic (testbed, serve) routes the same
    /// seed here, so lossy runs are seed-deterministic AND different seeds
    /// produce different drop patterns (the fixed 0xD1CE default is only
    /// the fallback for harnesses with no seed of their own). The actual
    /// per-link streams derive lazily from this base seed ⊕ link id.
    pub fn seed_drop_rng(&mut self, seed: u64) {
        self.drop_seed = seed ^ 0xD1CE;
        self.drop_rngs.clear();
    }

    /// Sort the drop log into its canonical total order (see
    /// [`DropRecord`]). Idempotent; safe across run segments because a
    /// later segment's records all carry later send cycles.
    pub(crate) fn canonicalize_drop_trace(&mut self) {
        self.drop_trace.sort_unstable();
    }

    /// Per-link transport audit, ascending by (src FPGA, dst FPGA).
    /// Empty unless the run was lossy (see [`LinkSeq`]).
    pub fn link_audit(&self) -> Vec<((FpgaId, FpgaId), LinkSeq)> {
        let mut v: Vec<_> = self
            .link_seq
            .iter()
            .map(|(&(s, d), &seq)| ((FpgaId(s as usize), FpgaId(d as usize)), seq))
            .collect();
        v.sort_by_key(|&(k, _)| k);
        v
    }

    pub fn place(&mut self, k: GlobalKernelId, f: FpgaId) {
        std::sync::Arc::make_mut(&mut self.placement)[k.dense()] = f.0 as u32 + 1;
        if f.0 >= self.nic_egress.len() {
            self.nic_egress.resize(f.0 + 1, 0);
        }
    }

    pub fn attach(&mut self, f: FpgaId, s: SwitchId) {
        if f.0 >= self.attachment.len() {
            self.attachment.resize(f.0 + 1, 0);
        }
        if f.0 >= self.nic_egress.len() {
            self.nic_egress.resize(f.0 + 1, 0);
        }
        self.attachment[f.0] = s.0 as u32 + 1;
    }

    #[inline]
    pub fn fpga_of(&self, k: GlobalKernelId) -> Option<FpgaId> {
        match self.placement[k.dense()] {
            0 => None,
            f => Some(FpgaId(f as usize - 1)),
        }
    }

    pub fn switch_of(&self, f: FpgaId) -> Option<SwitchId> {
        match self.attachment.get(f.0).copied().unwrap_or(0) {
            0 => None,
            s => Some(SwitchId(s as usize - 1)),
        }
    }

    /// True when both kernels are placed on the same FPGA — the burst
    /// coalescing eligibility test (the only serializing resource on an
    /// intra-FPGA path is the sender's exclusive egress port).
    #[inline]
    pub fn same_fpga(&self, a: GlobalKernelId, b: GlobalKernelId) -> bool {
        let fa = self.placement[a.dense()];
        fa != 0 && fa == self.placement[b.dense()]
    }

    pub fn fpgas(&self) -> Vec<FpgaId> {
        (0..self.attachment.len()).filter(|&f| self.attachment[f] != 0).map(FpgaId).collect()
    }

    pub fn kernels_on(&self, f: FpgaId) -> Vec<GlobalKernelId> {
        let want = f.0 as u32 + 1;
        (0..DENSE_IDS)
            .filter(|&i| self.placement[i] == want)
            .map(|i| GlobalKernelId::new((i >> 8) as u8, (i & 0xFF) as u8))
            .collect()
    }

    fn route_check(&self, pkt: &Packet) -> Result<(usize, usize)> {
        let src_f = match self.placement[pkt.src.dense()] {
            0 => bail!("source kernel {} is not placed on any FPGA", pkt.src),
            f => f as usize - 1,
        };
        let dst_f = match self.placement[pkt.dst.dense()] {
            0 => bail!("destination kernel {} is not placed on any FPGA", pkt.dst),
            f => f as usize - 1,
        };
        if pkt.inter_cluster {
            if !pkt.dst.is_gateway() {
                bail!(
                    "router violation: inter-cluster packet {} -> {} does not target a gateway",
                    pkt.src,
                    pkt.dst
                );
            }
            if pkt.gmi_dst.is_none() {
                bail!(
                    "protocol violation: inter-cluster packet {} -> {} has no GMI header",
                    pkt.src,
                    pkt.dst
                );
            }
        }
        Ok((src_f, dst_f))
    }

    /// Compute the delivery time of `pkt` sent at cycle `t`, updating link
    /// serialization state. Returns None if the (lossy) network dropped it
    /// — impossible with [`Fabric::reliable`] transport on, which keeps
    /// retransmitting until a copy gets through (each retry declared lost
    /// [`RETX_TIMEOUT`] after its last flit and re-serialized on the NIC).
    ///
    /// The router semantics of §4 are enforced here: a packet whose
    /// destination is in another cluster MUST be addressed to that
    /// cluster's gateway kernel (kernel 0); anything else is a routing
    /// error — direct inter-cluster kernel addressing is forbidden.
    pub fn deliver(&mut self, t: u64, pkt: &Packet) -> Result<Option<u64>> {
        let (src_f, dst_f) = self.route_check(pkt)?;

        let flits = pkt.flits();
        self.stats.packets += 1;
        self.stats.flits += flits;

        // kernel output switch + egress port serialization
        let t0 = t + OUT_SWITCH_LAT;
        let egress_free = self.kernel_egress[pkt.src.dense()];
        let egress_done = occupy(&mut self.kernel_egress[pkt.src.dense()], t0, flits);
        if let Some(o) = &mut self.obs {
            let start = t0.max(egress_free);
            o.on_egress(pkt.src.dense() as u32, pkt.meta.inference, start, flits, start - t0);
        }

        if src_f == dst_f {
            self.stats.intra_fpga_packets += 1;
            // stays inside the FPGA: router hop only
            return Ok(Some(egress_done + ROUTER_LAT));
        }

        // router -> network bridge -> NIC: serialize on the FPGA's NIC
        let nic_ready = egress_done + ROUTER_LAT;
        let nic_free = self.nic_egress[src_f];
        let mut nic_done = occupy(&mut self.nic_egress[src_f], nic_ready, flits);
        if let Some(o) = &mut self.obs {
            let start = nic_ready.max(nic_free);
            o.on_nic(src_f as u32, pkt.meta.inference, start, flits, start - nic_ready);
        }

        if self.drop_probability > 0.0 {
            let link = (src_f as u32, dst_f as u32);
            let drop_seed = self.drop_seed;
            let rng = self
                .drop_rngs
                .entry(link)
                .or_insert_with(|| crate::util::rng::Rng::new(link_stream_seed(drop_seed, link.0, link.1)));
            let seq = self.link_seq.entry(link).or_default();
            seq.sent += 1;
            if self.reliable {
                if self.drop_probability >= 1.0 {
                    bail!("reliable transport cannot make progress at drop probability >= 1");
                }
                // every lost copy occupied the NIC before vanishing; the
                // retry re-serializes RETX_TIMEOUT after its last flit
                let first_nic_done = nic_done;
                let mut copies = 0u64;
                while rng.bool_with_p(self.drop_probability) {
                    self.stats.dropped += 1;
                    self.stats.retransmits += 1;
                    self.stats.flits += flits;
                    seq.dropped_copies += 1;
                    self.drop_trace.push(DropRecord {
                        t,
                        src: link.0,
                        dst: link.1,
                        seq: seq.dropped_copies,
                    });
                    copies += 1;
                    if let Some(o) = &mut self.obs {
                        o.on_drop(t);
                    }
                    nic_done =
                        occupy(&mut self.nic_egress[src_f], nic_done + RETX_TIMEOUT, flits);
                }
                if copies > 0 {
                    if let Some(o) = &mut self.obs {
                        o.on_retx(
                            pkt.meta.inference,
                            first_nic_done,
                            nic_done - first_nic_done,
                            copies,
                            src_f as u32,
                            dst_f as u32,
                        );
                    }
                }
            } else if rng.bool_with_p(self.drop_probability) {
                self.stats.dropped += 1;
                seq.dropped_copies += 1;
                self.drop_trace.push(DropRecord {
                    t,
                    src: link.0,
                    dst: link.1,
                    seq: seq.dropped_copies,
                });
                if let Some(o) = &mut self.obs {
                    o.on_drop(t);
                }
                return Ok(None);
            }
            seq.delivered += 1;
        }
        self.stats.inter_fpga_packets += 1;

        let s_src = match self.attachment.get(src_f).copied().unwrap_or(0) {
            0 => bail!("FPGA FpgaId({src_f}) not attached to a switch"),
            s => s as usize - 1,
        };
        let s_dst = match self.attachment.get(dst_f).copied().unwrap_or(0) {
            0 => bail!("FPGA FpgaId({dst_f}) not attached to a switch"),
            s => s as usize - 1,
        };

        let mut lat = NIC_LAT + SWITCH_LAT + NIC_LAT;
        if s_src != s_dst {
            // switches are connected serially (Fig. 17): hop count is the
            // index distance in the chain
            let hops = s_src.abs_diff(s_dst) as u64;
            lat += hops * INTER_SWITCH_LAT;
            self.stats.inter_switch_packets += 1;
        }
        // ingress side: router hop into the destination kernel
        Ok(Some(nic_done + lat + ROUTER_LAT))
    }

    /// A private copy for one shard of the parallel engine: identical
    /// topology and current link state, zeroed statistics (the shard's
    /// deltas are folded back by [`Fabric::absorb_shard`]). Only the
    /// shard's own kernels/FPGAs ever exercise the copy's mutable state
    /// — FPGA alignment guarantees it.
    pub(crate) fn shard_clone(&self) -> Fabric {
        let mut f = self.clone();
        f.stats = FabricStats::default();
        // lossy-transport state (per-link RNG streams + sequence counters)
        // is keyed by directed link, and every link belongs to its sender's
        // shard — all mutable fabric state is sender-side — so the copies
        // carry the current streams/counters (`self.clone()` above) and
        // absorb_shard overwrites the owned entries back. The drop trace is
        // an append-only log: shards start empty and absorb appends.
        f.drop_trace = Vec::new();
        // each shard collects telemetry deltas into a fresh collector of
        // the same bucket width; absorb_shard folds them back
        f.obs = self.obs.as_ref().map(|o| Box::new(crate::obs::FabricObs::new(o.interval)));
        f
    }

    /// Fold a shard's link-state + statistics deltas back into the
    /// master fabric: `kernel_dense` / `fpgas` are the dense kernel ids
    /// and FPGA indices the shard owned (the only entries it can have
    /// advanced).
    pub(crate) fn absorb_shard(&mut self, sh: &Fabric, kernel_dense: &[usize], fpgas: &[usize]) {
        for &d in kernel_dense {
            self.kernel_egress[d] = sh.kernel_egress[d];
        }
        for &f in fpgas {
            self.nic_egress[f] = sh.nic_egress[f];
        }
        // lossy-transport state: a directed link's stream/counter only
        // advances on the shard that owns its source FPGA, so overwriting
        // the owned entries is exact (and idempotent for untouched links)
        for (&(s, d), seq) in sh.link_seq.iter() {
            if fpgas.contains(&(s as usize)) {
                self.link_seq.insert((s, d), *seq);
            }
        }
        for (&(s, d), rng) in sh.drop_rngs.iter() {
            if fpgas.contains(&(s as usize)) {
                self.drop_rngs.insert((s, d), rng.clone());
            }
        }
        self.drop_trace.extend_from_slice(&sh.drop_trace);
        self.stats.absorb(&sh.stats);
        if let (Some(mine), Some(theirs)) = (&mut self.obs, &sh.obs) {
            mine.merge(theirs);
        }
    }

    /// Deliver a coalesced intra-FPGA burst: rows emitted at
    /// `pkt.burst.emit_times`, each serializing `pkt.flits()` on the
    /// sender's exclusive egress port. Returns the per-row arrival times —
    /// cycle-identical to delivering each row as its own packet at its
    /// emission time, because no shared resource (NIC) is on the path.
    pub fn deliver_burst(&mut self, pkt: &Packet) -> Result<Vec<u64>> {
        let Some(b) = pkt.burst.as_ref() else {
            bail!("deliver_burst on a packet without burst info");
        };
        let (src_f, dst_f) = self.route_check(pkt)?;
        if src_f != dst_f {
            bail!(
                "burst {} -> {} crosses FPGAs: coalescing is intra-FPGA only (split the burst)",
                pkt.src,
                pkt.dst
            );
        }
        let flits = pkt.flits();
        let n = b.emit_times.len() as u64;
        self.stats.packets += n;
        self.stats.flits += n * flits;
        self.stats.intra_fpga_packets += n;

        let dense = pkt.src.dense();
        let mut arrivals = Vec::with_capacity(b.emit_times.len());
        let mut prev = 0u64;
        for &t in &b.emit_times {
            debug_assert!(t >= prev, "burst emission times must be nondecreasing");
            prev = t;
            let t0 = t + OUT_SWITCH_LAT;
            let free = self.kernel_egress[dense];
            let done = occupy(&mut self.kernel_egress[dense], t0, flits);
            if let Some(o) = &mut self.obs {
                let start = t0.max(free);
                o.on_egress(dense as u32, pkt.meta.inference, start, flits, start - t0);
            }
            arrivals.push(done + ROUTER_LAT);
        }
        Ok(arrivals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::packet::{Burst, MsgMeta, Payload};

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    fn fabric_2fpga() -> Fabric {
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(0, 2), FpgaId(1));
        f.place(k(0, 3), FpgaId(0));
        f.place(k(1, 0), FpgaId(1));
        f.attach(FpgaId(0), SwitchId(0));
        f.attach(FpgaId(1), SwitchId(0));
        f
    }

    #[test]
    fn intra_fpga_latency() {
        let mut f = fabric_2fpga();
        let p = Packet::new(k(0, 1), k(0, 3), MsgMeta::default(), Payload::Timing(768));
        let arr = f.deliver(0, &p).unwrap().unwrap();
        assert_eq!(arr, OUT_SWITCH_LAT + 12 + ROUTER_LAT);
        assert_eq!(f.stats.intra_fpga_packets, 1);
    }

    #[test]
    fn inter_fpga_latency_includes_switch() {
        let mut f = fabric_2fpga();
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(768));
        let arr = f.deliver(0, &p).unwrap().unwrap();
        let expect = OUT_SWITCH_LAT + 12 + ROUTER_LAT + 12 + NIC_LAT + SWITCH_LAT + NIC_LAT + ROUTER_LAT;
        assert_eq!(arr, expect);
    }

    #[test]
    fn egress_serialization_backpressure() {
        let mut f = fabric_2fpga();
        let p = Packet::new(k(0, 1), k(0, 3), MsgMeta::default(), Payload::Timing(768));
        let a1 = f.deliver(0, &p).unwrap().unwrap();
        let a2 = f.deliver(0, &p).unwrap().unwrap();
        // second packet waits for the first to finish serializing
        assert_eq!(a2, a1 + 12);
    }

    #[test]
    fn burst_matches_per_row_delivery_exactly() {
        // the coalescing contract: same arrival schedule as per-row sends
        let p = Packet::new(k(0, 1), k(0, 3), MsgMeta::default(), Payload::Timing(768));
        // a paced run (gap > flits) and a congested run (gap < flits)
        for times in [vec![100u64, 900, 1700], vec![100, 103, 106, 109]] {
            let mut ref_f = fabric_2fpga();
            let want: Vec<u64> =
                times.iter().map(|&t| ref_f.deliver(t, &p).unwrap().unwrap()).collect();
            let mut q = p.clone();
            q.burst = Some(Box::new(Burst {
                tail: vec![Payload::Timing(768); times.len() - 1],
                emit_times: times,
                arrivals: Vec::new(),
            }));
            let mut f2 = fabric_2fpga();
            let got = f2.deliver_burst(&q).unwrap();
            assert_eq!(got, want);
            assert_eq!(f2.stats.packets, ref_f.stats.packets);
            assert_eq!(f2.stats.flits, ref_f.stats.flits);
        }
    }

    #[test]
    fn burst_rejects_inter_fpga_paths() {
        let mut f = fabric_2fpga();
        let mut p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        p.burst = Some(Box::new(Burst {
            emit_times: vec![0, 10],
            arrivals: Vec::new(),
            tail: vec![Payload::Timing(64)],
        }));
        assert!(f.deliver_burst(&p).is_err());
    }

    #[test]
    fn inter_cluster_requires_gateway_and_header() {
        let mut f = fabric_2fpga();
        // direct inter-cluster to non-gateway: forbidden
        let mut bad = Packet::new(k(0, 1), k(1, 0), MsgMeta::default(), Payload::Timing(8));
        bad.dst = k(1, 7); // tamper: non-gateway
        bad.inter_cluster = true;
        bad.gmi_dst = Some(7);
        f.place(k(1, 7), FpgaId(1));
        assert!(f.deliver(0, &bad).is_err());
        // gateway without GMI header: protocol violation
        let nohdr = Packet::new(k(0, 1), k(1, 0), MsgMeta::default(), Payload::Timing(8));
        assert!(f.deliver(0, &nohdr).is_err());
        // proper: gateway + header
        let mut good = Packet::new(k(0, 1), k(1, 0), MsgMeta::default(), Payload::Timing(8));
        good.gmi_dst = Some(7);
        assert!(f.deliver(0, &good).unwrap().is_some());
    }

    #[test]
    fn serial_switch_chain_adds_d_per_hop() {
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(0, 2), FpgaId(1));
        f.attach(FpgaId(0), SwitchId(0));
        f.attach(FpgaId(1), SwitchId(3));
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        let arr = f.deliver(0, &p).unwrap().unwrap();
        let base = OUT_SWITCH_LAT + 1 + ROUTER_LAT + 1 + NIC_LAT + SWITCH_LAT + NIC_LAT + ROUTER_LAT;
        assert_eq!(arr, base + 3 * INTER_SWITCH_LAT);
    }

    #[test]
    fn lossy_mode_drops_some() {
        let mut f = fabric_2fpga();
        f.drop_probability = 0.5;
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        let mut dropped = 0;
        for _ in 0..200 {
            if f.deliver(0, &p).unwrap().is_none() {
                dropped += 1;
            }
        }
        assert!(dropped > 50 && dropped < 150, "dropped={dropped}");
        assert_eq!(f.stats.dropped, dropped);
    }

    #[test]
    fn lossy_stats_contract_counts_drops_separately() {
        // packets == intra + inter + dropped, and inter_switch only ever
        // counts delivered packets (the drop-rate arithmetic is exact)
        let mut f = fabric_2fpga();
        f.attach(FpgaId(1), SwitchId(1)); // force a switch hop on delivery
        f.drop_probability = 0.5;
        let inter = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        let intra = Packet::new(k(0, 1), k(0, 3), MsgMeta::default(), Payload::Timing(64));
        for i in 0..300u64 {
            let p = if i % 3 == 0 { &intra } else { &inter };
            let _ = f.deliver(i * 40, p).unwrap();
        }
        let s = &f.stats;
        assert_eq!(s.packets, s.intra_fpga_packets + s.inter_fpga_packets + s.dropped);
        assert_eq!(s.inter_switch_packets, s.inter_fpga_packets, "all delivered crossed a hop");
        assert!(s.dropped > 0 && s.inter_fpga_packets > 0);
        // the per-link audit tells the same story
        let audit = f.link_audit();
        assert_eq!(audit.len(), 1);
        let (link, seq) = audit[0];
        assert_eq!(link, (FpgaId(0), FpgaId(1)));
        assert_eq!(seq.sent, seq.delivered + seq.dropped_copies);
        assert_eq!(seq.dropped_copies, s.dropped);
    }

    #[test]
    fn reliable_transport_delivers_exactly_once_and_charges_the_nic() {
        let mut f = fabric_2fpga();
        f.drop_probability = 0.5;
        f.reliable = true;
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        let mut arrivals = Vec::new();
        for i in 0..200u64 {
            // widely spaced sends so retry serialization is visible
            arrivals.push(f.deliver(i * 10_000, &p).unwrap().expect("reliable never drops"));
        }
        assert_eq!(arrivals.len(), 200);
        let s = &f.stats;
        assert_eq!(s.packets, s.intra_fpga_packets + s.inter_fpga_packets);
        assert_eq!(s.inter_fpga_packets, 200, "every logical packet delivered");
        assert!(s.dropped > 0, "losses must have occurred at p=0.5");
        assert_eq!(s.dropped, s.retransmits, "every lost copy was retried");
        let (_, seq) = f.link_audit()[0];
        assert_eq!(seq.sent, 200);
        assert_eq!(seq.delivered, 200, "exactly once per logical packet");
        assert_eq!(seq.dropped_copies, s.dropped);
        // a retried packet arrives at least one timeout + one extra
        // serialization later than a clean one
        let clean = OUT_SWITCH_LAT + 1 + ROUTER_LAT + 1 + NIC_LAT + SWITCH_LAT + NIC_LAT
            + ROUTER_LAT;
        let retried = arrivals.iter().enumerate().find(|&(i, &a)| a > i as u64 * 10_000 + clean);
        let (i, &a) = retried.expect("some packet must have been retried");
        assert!(
            a >= i as u64 * 10_000 + clean + RETX_TIMEOUT,
            "retry must pay at least the retransmission timeout"
        );
    }

    #[test]
    fn drop_pattern_is_seed_derived() {
        let run = |seed: u64| {
            let mut f = fabric_2fpga();
            f.seed_drop_rng(seed);
            f.drop_probability = 0.3;
            let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
            for i in 0..100u64 {
                let _ = f.deliver(i * 50, &p).unwrap();
            }
            f.drop_trace
        };
        assert_eq!(run(7), run(7), "same seed, same drop trace");
        assert_ne!(run(7), run(8), "different seeds must produce different drop patterns");
        assert!(!run(7).is_empty());
    }

    #[test]
    fn per_link_streams_are_interleaving_invariant() {
        // the shard-plan-invariance argument in miniature: drop decisions
        // on link 0->1 must not depend on traffic crossing any other link
        let mk = || {
            let mut f = Fabric::new();
            f.place(k(0, 1), FpgaId(0));
            f.place(k(0, 2), FpgaId(1));
            f.place(k(1, 1), FpgaId(2));
            f.place(k(1, 2), FpgaId(3));
            for i in 0..4 {
                f.attach(FpgaId(i), SwitchId(0));
            }
            f.seed_drop_rng(42);
            f.drop_probability = 0.3;
            f
        };
        let pa = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        let pb = Packet::new(k(1, 1), k(1, 2), MsgMeta::default(), Payload::Timing(64));
        let mut both = mk();
        for i in 0..100u64 {
            let _ = both.deliver(i * 50, &pa).unwrap();
            let _ = both.deliver(i * 50 + 25, &pb).unwrap();
        }
        let on_a: Vec<DropRecord> =
            both.drop_trace.iter().filter(|r| r.src == 0).copied().collect();
        let mut solo = mk();
        for i in 0..100u64 {
            let _ = solo.deliver(i * 50, &pa).unwrap();
        }
        assert_eq!(solo.drop_trace, on_a, "link 0->1 stream must ignore other links");
        assert!(!on_a.is_empty(), "the 30% run must drop something");
    }

    #[test]
    fn shard_clone_carries_lossy_streams_and_absorbs_drop_state() {
        let run_ref = || {
            let mut f = fabric_2fpga();
            f.seed_drop_rng(9);
            f.drop_probability = 0.4;
            let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
            for i in 0..100u64 {
                let _ = f.deliver(i * 50, &p).unwrap();
            }
            f
        };
        let reference = run_ref();
        // same traffic, but the second half runs on a shard copy
        let mut master = fabric_2fpga();
        master.seed_drop_rng(9);
        master.drop_probability = 0.4;
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        for i in 0..50u64 {
            let _ = master.deliver(i * 50, &p).unwrap();
        }
        let trace_before = master.drop_trace.clone();
        let mut sh = master.shard_clone();
        assert!(sh.drop_trace.is_empty(), "drop log is append-only: shards start empty");
        for i in 50..100u64 {
            let _ = sh.deliver(i * 50, &p).unwrap();
        }
        master.absorb_shard(&sh, &[k(0, 1).dense()], &[0]);
        let mut merged = trace_before;
        merged.extend_from_slice(&sh.drop_trace);
        assert_eq!(master.drop_trace, merged);
        assert_eq!(
            master.drop_trace, reference.drop_trace,
            "shard must continue the per-link stream exactly where the master left off"
        );
        assert_eq!(master.link_audit(), reference.link_audit());
        // and the next master delivery continues the stream seamlessly too
        let mut m2 = master;
        let mut r2 = reference;
        for i in 100..150u64 {
            assert_eq!(m2.deliver(i * 50, &p).unwrap(), r2.deliver(i * 50, &p).unwrap());
        }
    }

    #[test]
    fn unplaced_kernel_errors() {
        let mut f = fabric_2fpga();
        let p = Packet::new(k(0, 9), k(0, 1), MsgMeta::default(), Payload::Timing(8));
        assert!(f.deliver(0, &p).is_err());
    }

    #[test]
    fn shard_clone_and_absorb_roundtrip_link_state() {
        let mut master = fabric_2fpga();
        // master sees some pre-partition traffic
        let p01 = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(768));
        master.deliver(0, &p01).unwrap();
        let before = master.stats.clone();

        // shard copy carries the link state but starts stats at zero
        let mut sh = master.shard_clone();
        assert_eq!(sh.stats.packets, 0);
        let a1 = sh.deliver(100, &p01).unwrap().unwrap();
        // serialization state carried over: the copy continues where the
        // master's egress left off if re-delivered at the same cycle
        let mut fresh = fabric_2fpga();
        let b0 = fresh.deliver(0, &p01).unwrap().unwrap();
        let b1 = fresh.deliver(100, &p01).unwrap().unwrap();
        assert_eq!((a1, b0 > 0), (b1, true));

        master.absorb_shard(&sh, &[k(0, 1).dense()], &[0]);
        assert_eq!(master.stats.packets, before.packets + sh.stats.packets);
        // a third delivery on the master serializes after the shard's
        let c = master.deliver(100, &p01).unwrap().unwrap();
        assert!(c > a1, "absorbed egress state must advance the master clock");
    }

    #[test]
    fn obs_charges_links_and_attributes_waits() {
        let mut f = fabric_2fpga();
        f.enable_obs(100);
        let mut p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(768));
        p.meta.inference = 5;
        // back-to-back sends: the second waits on egress AND nic
        f.deliver(0, &p).unwrap().unwrap();
        f.deliver(0, &p).unwrap().unwrap();
        let o = f.obs.as_ref().unwrap();
        // 2 packets x 12 flits on egress and nic
        assert_eq!(o.bucket_egress_busy.iter().sum::<u64>(), 24);
        assert_eq!(o.bucket_nic_busy.iter().sum::<u64>(), 24);
        assert_eq!(o.egress_busy.get(&(k(0, 1).dense() as u32)), Some(&24));
        assert_eq!(o.nic_busy.get(&0), Some(&24));
        let wait = o.serialize_wait.get(&5).copied().unwrap_or(0);
        assert!(wait >= 12, "second packet must wait behind the first, got {wait}");

        // telemetry must not change timing: a clean fabric agrees
        let mut clean = fabric_2fpga();
        let a = clean.deliver(0, &p).unwrap().unwrap();
        let b = clean.deliver(0, &p).unwrap().unwrap();
        let mut f2 = fabric_2fpga();
        f2.enable_obs(100);
        assert_eq!(f2.deliver(0, &p).unwrap().unwrap(), a);
        assert_eq!(f2.deliver(0, &p).unwrap().unwrap(), b);
    }

    #[test]
    fn obs_counts_reliable_retransmit_stalls() {
        let mut f = fabric_2fpga();
        f.enable_obs(1000);
        f.drop_probability = 0.5;
        f.reliable = true;
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        for i in 0..100u64 {
            f.deliver(i * 10_000, &p).unwrap().unwrap();
        }
        let o = f.obs.as_ref().unwrap();
        let retx: u64 = o.bucket_retx.iter().sum();
        let drops: u64 = o.bucket_drops.iter().sum();
        assert_eq!(retx, f.stats.retransmits);
        assert_eq!(drops, f.stats.dropped);
        let stall: u64 = o.retx_stall.values().sum();
        assert!(stall >= f.stats.retransmits * RETX_TIMEOUT);
        assert!(!o.retx_spans.is_empty());
        for &(_, dur, src, dst) in &o.retx_spans {
            assert!(dur >= RETX_TIMEOUT);
            assert_eq!((src, dst), (0, 1));
        }
    }

    #[test]
    fn obs_shard_clone_starts_fresh_and_absorbs_back() {
        let mut master = fabric_2fpga();
        master.enable_obs(100);
        let p = Packet::new(k(0, 1), k(0, 3), MsgMeta::default(), Payload::Timing(768));
        master.deliver(0, &p).unwrap();
        let mut sh = master.shard_clone();
        let so = sh.obs.as_ref().unwrap();
        assert_eq!(so.interval, 100);
        assert!(so.bucket_egress_busy.is_empty(), "shard collector starts empty");
        sh.deliver(100, &p).unwrap();
        master.absorb_shard(&sh, &[k(0, 1).dense()], &[0]);
        let o = master.obs.as_ref().unwrap();
        assert_eq!(o.bucket_egress_busy.iter().sum::<u64>(), 24);
    }

    #[test]
    fn dense_queries() {
        let f = fabric_2fpga();
        assert_eq!(f.fpga_of(k(0, 1)), Some(FpgaId(0)));
        assert_eq!(f.fpga_of(k(9, 9)), None);
        assert!(f.same_fpga(k(0, 1), k(0, 3)));
        assert!(!f.same_fpga(k(0, 1), k(0, 2)));
        assert!(!f.same_fpga(k(9, 9), k(9, 9)), "unplaced kernels never coalesce");
        assert_eq!(f.fpgas(), vec![FpgaId(0), FpgaId(1)]);
        assert_eq!(f.kernels_on(FpgaId(0)), vec![k(0, 1), k(0, 3)]);
        assert_eq!(f.switch_of(FpgaId(7)), None);
    }
}
