//! The physical fabric model: FPGAs on 100G switches, link serialization,
//! and the two-table routing semantics of the enhanced Galapagos (§4).
//!
//! Hops are computed analytically (no per-hop events): each shared link
//! keeps a `next_free` cycle; a packet occupies its links for `flits()`
//! cycles in sequence, which preserves serialization contention while the
//! event count stays one-per-packet.


use anyhow::{bail, Result};

use crate::util::fxhash::FxHashMap;

use super::packet::{GlobalKernelId, Packet};
use super::params::{INTER_SWITCH_LAT, NIC_LAT, OUT_SWITCH_LAT, ROUTER_LAT, SWITCH_LAT};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FpgaId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SwitchId(pub usize);

/// One shared serializing resource (kernel egress port, NIC, ...).
#[derive(Debug, Clone, Copy, Default)]
struct LinkState {
    next_free: u64,
}

impl LinkState {
    /// Occupy the link for `dur` cycles starting no earlier than `t`;
    /// returns the cycle at which the last flit has left.
    fn occupy(&mut self, t: u64, dur: u64) -> u64 {
        let start = t.max(self.next_free);
        self.next_free = start + dur;
        self.next_free
    }
}

/// Statistics the fabric accumulates.
#[derive(Debug, Clone, Default)]
pub struct FabricStats {
    pub packets: u64,
    pub flits: u64,
    pub intra_fpga_packets: u64,
    pub inter_fpga_packets: u64,
    pub inter_switch_packets: u64,
    pub dropped: u64,
}

/// Placement and topology of the platform.
#[derive(Debug, Default)]
pub struct Fabric {
    /// kernel -> FPGA placement.
    placement: FxHashMap<GlobalKernelId, FpgaId>,
    /// FPGA -> switch attachment.
    attachment: FxHashMap<FpgaId, SwitchId>,
    /// serialization state per kernel egress port.
    kernel_egress: FxHashMap<GlobalKernelId, LinkState>,
    /// serialization state per FPGA NIC (egress).
    nic_egress: FxHashMap<FpgaId, LinkState>,
    /// optional packet-loss probability on inter-FPGA hops (UDP is
    /// unreliable; off by default like the paper's testbed experience).
    pub drop_probability: f64,
    drop_rng: crate::util::rng::Rng,
    pub stats: FabricStats,
}

impl Fabric {
    pub fn new() -> Self {
        Fabric { drop_rng: crate::util::rng::Rng::new(0xD1CE), ..Default::default() }
    }

    pub fn place(&mut self, k: GlobalKernelId, f: FpgaId) {
        self.placement.insert(k, f);
    }

    pub fn attach(&mut self, f: FpgaId, s: SwitchId) {
        self.attachment.insert(f, s);
    }

    pub fn fpga_of(&self, k: GlobalKernelId) -> Option<FpgaId> {
        self.placement.get(&k).copied()
    }

    pub fn switch_of(&self, f: FpgaId) -> Option<SwitchId> {
        self.attachment.get(&f).copied()
    }

    pub fn fpgas(&self) -> Vec<FpgaId> {
        let mut v: Vec<FpgaId> = self.attachment.keys().copied().collect();
        v.sort();
        v
    }

    pub fn kernels_on(&self, f: FpgaId) -> Vec<GlobalKernelId> {
        let mut v: Vec<GlobalKernelId> =
            self.placement.iter().filter(|(_, &pf)| pf == f).map(|(k, _)| *k).collect();
        v.sort();
        v
    }

    /// Compute the delivery time of `pkt` sent at cycle `t`, updating link
    /// serialization state. Returns None if the (lossy) network dropped it.
    ///
    /// The router semantics of §4 are enforced here: a packet whose
    /// destination is in another cluster MUST be addressed to that
    /// cluster's gateway kernel (kernel 0); anything else is a routing
    /// error — direct inter-cluster kernel addressing is forbidden.
    pub fn deliver(&mut self, t: u64, pkt: &Packet) -> Result<Option<u64>> {
        let src_f = match self.fpga_of(pkt.src) {
            Some(f) => f,
            None => bail!("source kernel {} is not placed on any FPGA", pkt.src),
        };
        let dst_f = match self.fpga_of(pkt.dst) {
            Some(f) => f,
            None => bail!("destination kernel {} is not placed on any FPGA", pkt.dst),
        };
        if pkt.inter_cluster {
            if !pkt.dst.is_gateway() {
                bail!(
                    "router violation: inter-cluster packet {} -> {} does not target a gateway",
                    pkt.src,
                    pkt.dst
                );
            }
            if pkt.gmi_dst.is_none() {
                bail!(
                    "protocol violation: inter-cluster packet {} -> {} has no GMI header",
                    pkt.src,
                    pkt.dst
                );
            }
        }

        let flits = pkt.flits();
        self.stats.packets += 1;
        self.stats.flits += flits;

        // kernel output switch + egress port serialization
        let t0 = t + OUT_SWITCH_LAT;
        let egress_done = self.kernel_egress.entry(pkt.src).or_default().occupy(t0, flits);

        if src_f == dst_f {
            self.stats.intra_fpga_packets += 1;
            // stays inside the FPGA: router hop only
            return Ok(Some(egress_done + ROUTER_LAT));
        }

        self.stats.inter_fpga_packets += 1;
        // router -> network bridge -> NIC: serialize on the FPGA's NIC
        let nic_done =
            self.nic_egress.entry(src_f).or_default().occupy(egress_done + ROUTER_LAT, flits);

        if self.drop_probability > 0.0 && self.drop_rng.bool_with_p(self.drop_probability) {
            self.stats.dropped += 1;
            return Ok(None);
        }

        let s_src = self
            .switch_of(src_f)
            .ok_or_else(|| anyhow::anyhow!("FPGA {src_f:?} not attached to a switch"))?;
        let s_dst = self
            .switch_of(dst_f)
            .ok_or_else(|| anyhow::anyhow!("FPGA {dst_f:?} not attached to a switch"))?;

        let mut lat = NIC_LAT + SWITCH_LAT + NIC_LAT;
        if s_src != s_dst {
            // switches are connected serially (Fig. 17): hop count is the
            // index distance in the chain
            let hops = s_src.0.abs_diff(s_dst.0) as u64;
            lat += hops * INTER_SWITCH_LAT;
            self.stats.inter_switch_packets += 1;
        }
        // ingress side: router hop into the destination kernel
        Ok(Some(nic_done + lat + ROUTER_LAT))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::packet::{MsgMeta, Payload};

    fn k(c: u8, n: u8) -> GlobalKernelId {
        GlobalKernelId::new(c, n)
    }

    fn fabric_2fpga() -> Fabric {
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(0, 2), FpgaId(1));
        f.place(k(0, 3), FpgaId(0));
        f.place(k(1, 0), FpgaId(1));
        f.attach(FpgaId(0), SwitchId(0));
        f.attach(FpgaId(1), SwitchId(0));
        f
    }

    #[test]
    fn intra_fpga_latency() {
        let mut f = fabric_2fpga();
        let p = Packet::new(k(0, 1), k(0, 3), MsgMeta::default(), Payload::Timing(768));
        let arr = f.deliver(0, &p).unwrap().unwrap();
        assert_eq!(arr, OUT_SWITCH_LAT + 12 + ROUTER_LAT);
        assert_eq!(f.stats.intra_fpga_packets, 1);
    }

    #[test]
    fn inter_fpga_latency_includes_switch() {
        let mut f = fabric_2fpga();
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(768));
        let arr = f.deliver(0, &p).unwrap().unwrap();
        let expect = OUT_SWITCH_LAT + 12 + ROUTER_LAT + 12 + NIC_LAT + SWITCH_LAT + NIC_LAT + ROUTER_LAT;
        assert_eq!(arr, expect);
    }

    #[test]
    fn egress_serialization_backpressure() {
        let mut f = fabric_2fpga();
        let p = Packet::new(k(0, 1), k(0, 3), MsgMeta::default(), Payload::Timing(768));
        let a1 = f.deliver(0, &p).unwrap().unwrap();
        let a2 = f.deliver(0, &p).unwrap().unwrap();
        // second packet waits for the first to finish serializing
        assert_eq!(a2, a1 + 12);
    }

    #[test]
    fn inter_cluster_requires_gateway_and_header() {
        let mut f = fabric_2fpga();
        // direct inter-cluster to non-gateway: forbidden
        let mut bad = Packet::new(k(0, 1), k(1, 0), MsgMeta::default(), Payload::Timing(8));
        bad.dst = k(1, 7); // tamper: non-gateway
        bad.inter_cluster = true;
        bad.gmi_dst = Some(7);
        f.place(k(1, 7), FpgaId(1));
        assert!(f.deliver(0, &bad).is_err());
        // gateway without GMI header: protocol violation
        let nohdr = Packet::new(k(0, 1), k(1, 0), MsgMeta::default(), Payload::Timing(8));
        assert!(f.deliver(0, &nohdr).is_err());
        // proper: gateway + header
        let mut good = Packet::new(k(0, 1), k(1, 0), MsgMeta::default(), Payload::Timing(8));
        good.gmi_dst = Some(7);
        assert!(f.deliver(0, &good).unwrap().is_some());
    }

    #[test]
    fn serial_switch_chain_adds_d_per_hop() {
        let mut f = Fabric::new();
        f.place(k(0, 1), FpgaId(0));
        f.place(k(0, 2), FpgaId(1));
        f.attach(FpgaId(0), SwitchId(0));
        f.attach(FpgaId(1), SwitchId(3));
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        let arr = f.deliver(0, &p).unwrap().unwrap();
        let base = OUT_SWITCH_LAT + 1 + ROUTER_LAT + 1 + NIC_LAT + SWITCH_LAT + NIC_LAT + ROUTER_LAT;
        assert_eq!(arr, base + 3 * INTER_SWITCH_LAT);
    }

    #[test]
    fn lossy_mode_drops_some() {
        let mut f = fabric_2fpga();
        f.drop_probability = 0.5;
        let p = Packet::new(k(0, 1), k(0, 2), MsgMeta::default(), Payload::Timing(64));
        let mut dropped = 0;
        for _ in 0..200 {
            if f.deliver(0, &p).unwrap().is_none() {
                dropped += 1;
            }
        }
        assert!(dropped > 50 && dropped < 150, "dropped={dropped}");
        assert_eq!(f.stats.dropped, dropped);
    }

    #[test]
    fn unplaced_kernel_errors() {
        let mut f = fabric_2fpga();
        let p = Packet::new(k(0, 9), k(0, 1), MsgMeta::default(), Payload::Timing(8));
        assert!(f.deliver(0, &p).is_err());
    }
}
