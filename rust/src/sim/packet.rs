//! Galapagos packets as the simulator sees them.
//!
//! A packet carries the Galapagos bridge header (sender id, receiver id,
//! message size — §2.1 Fig. 2), the TUSER bit16 inter-cluster flag (§4),
//! an optional one-byte GMI header (§5.2), and a payload that is either
//! pure-timing or an actual matrix row (functional simulation).

use super::params::flits_for_bytes;

/// Hierarchical kernel address: 256 clusters x 256 kernels (§4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GlobalKernelId {
    pub cluster: u8,
    pub kernel: u8,
}

impl GlobalKernelId {
    pub const fn new(cluster: u8, kernel: u8) -> Self {
        GlobalKernelId { cluster, kernel }
    }
    /// The gateway kernel of a cluster is kernel 0 by convention (§4).
    pub const fn gateway_of(cluster: u8) -> Self {
        GlobalKernelId { cluster, kernel: 0 }
    }
    pub fn is_gateway(&self) -> bool {
        self.kernel == 0
    }
}

impl std::fmt::Display for GlobalKernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "c{}k{}", self.cluster, self.kernel)
    }
}

/// Stream metadata: which logical stream of a multi-input kernel this row
/// belongs to, its index, and the total row count of the message (the
/// Galapagos header's "message size").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MsgMeta {
    /// Logical input port tag at the destination (e.g. Q vs K matrix).
    pub stream: u8,
    /// Row index within the message.
    pub row: u32,
    /// Total rows in the message.
    pub rows: u32,
    /// Inference id (for pipelined multi-inference runs).
    pub inference: u32,
}

/// Payload: timing-only or functional data.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Pure-timing packet of the given byte size.
    Timing(usize),
    /// One int8 row (e.g. activations).
    RowI8(Vec<i8>),
    /// One int32 row (e.g. matmul accumulators crossing kernels).
    RowI32(Vec<i32>),
    /// One int64 row (residual / layernorm domain).
    RowI64(Vec<i64>),
    /// Control/token message (barrier, credit, weight-swap command, ...).
    Control(u64),
}

impl Payload {
    pub fn bytes(&self) -> usize {
        match self {
            Payload::Timing(b) => *b,
            Payload::RowI8(v) => v.len(),
            Payload::RowI32(v) => 4 * v.len(),
            Payload::RowI64(v) => 8 * v.len(),
            Payload::Control(_) => 8,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    pub src: GlobalKernelId,
    pub dst: GlobalKernelId,
    /// TUSER bit16: this message leaves the source cluster (§4). Set by the
    /// router model; determines which routing table is consulted.
    pub inter_cluster: bool,
    /// One-byte GMI header carrying the final destination kernel id within
    /// the destination cluster (§5.2). Present iff inter_cluster.
    pub gmi_dst: Option<u8>,
    pub meta: MsgMeta,
    pub payload: Payload,
}

impl Packet {
    pub fn new(src: GlobalKernelId, dst: GlobalKernelId, meta: MsgMeta, payload: Payload) -> Self {
        Packet { src, dst, inter_cluster: src.cluster != dst.cluster, gmi_dst: None, meta, payload }
    }

    /// Wire size in bytes: payload + the one-byte GMI header when attached.
    pub fn wire_bytes(&self) -> usize {
        self.payload.bytes() + usize::from(self.gmi_dst.is_some())
    }

    /// Serialization cost in flits.
    pub fn flits(&self) -> u64 {
        flits_for_bytes(self.wire_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addressing_scheme() {
        let g = GlobalKernelId::gateway_of(7);
        assert!(g.is_gateway());
        assert_eq!(g.cluster, 7);
        assert_eq!(format!("{}", GlobalKernelId::new(1, 2)), "c1k2");
    }

    #[test]
    fn inter_cluster_flag_set_from_addresses() {
        let a = GlobalKernelId::new(0, 3);
        let b = GlobalKernelId::new(1, 0);
        let p = Packet::new(a, b, MsgMeta::default(), Payload::Timing(768));
        assert!(p.inter_cluster);
        let q = Packet::new(a, GlobalKernelId::new(0, 5), MsgMeta::default(), Payload::Timing(8));
        assert!(!q.inter_cluster);
    }

    #[test]
    fn gmi_header_costs_one_byte() {
        let a = GlobalKernelId::new(0, 3);
        let b = GlobalKernelId::new(1, 0);
        let mut p = Packet::new(a, b, MsgMeta::default(), Payload::RowI8(vec![0; 768]));
        assert_eq!(p.flits(), 12);
        p.gmi_dst = Some(9);
        assert_eq!(p.wire_bytes(), 769);
        assert_eq!(p.flits(), 13);
    }

    #[test]
    fn payload_sizes() {
        assert_eq!(Payload::RowI32(vec![0; 10]).bytes(), 40);
        assert_eq!(Payload::RowI64(vec![0; 10]).bytes(), 80);
        assert_eq!(Payload::Control(1).bytes(), 8);
        assert_eq!(Payload::Timing(5).bytes(), 5);
    }
}
